//! Modeling walkthrough: collect measurements with the serving engine,
//! fit the Alg. 1 analytic model, and use it to *explain* a speedup —
//! decomposing the Eq. 4 terms the way §3.3 promises ("transparent and
//! explainable").
//!
//! Run: `cargo run --release --example modeling_fit`

use moesd::arch::presets;
use moesd::experiments::{run_pair, RunOpts};
use moesd::fit::fit_perfmodel;
use moesd::hardware::platform_2x_gpu_a;
use moesd::perfmodel::{Measurement, ParamBounds, PerfModel, PerfParams};
use moesd::theory;

fn main() -> anyhow::Result<()> {
    let target = presets::qwen2_57b_a14b();
    let draft = presets::qwen2_0_5b();
    let platform = platform_2x_gpu_a();
    let opts = RunOpts::default();
    let alpha = 0.9;

    // 1. Collect 24 measurements across (γ, B) like the paper's profiling.
    println!("collecting measurements from the serving engine...");
    let mut measurements = Vec::new();
    for &gamma in &[2usize, 4] {
        for &b in &[1usize, 2, 4, 8, 16, 24, 32, 40, 48, 56, 80, 100] {
            let s = run_pair(&target, &draft, &platform, alpha, gamma, b, &opts)?;
            measurements.push(Measurement {
                batch: b,
                gamma,
                k: 8,
                e: 64,
                sigma: s.sigma,
                speedup: s.speedup,
            });
        }
    }

    // 2. Fit the 10 relaxation parameters (Alg. 1 line 13).
    let model = PerfModel::new(&platform);
    let bounds = ParamBounds::for_setup(&target, &draft, &platform, 1e-3);
    let t0 = std::time::Instant::now();
    let (params, mse) = fit_perfmodel(&model, &measurements, &bounds, 42);
    println!(
        "fit {} measurements in {:.3}s — MSE {:.4} (paper: ~0.1s, MSE ~1.5)\n",
        measurements.len(),
        t0.elapsed().as_secs_f64(),
        mse
    );
    for (name, v) in PerfParams::names().iter().zip(params.to_vec()) {
        println!("  {name:12} = {v:.6e}");
    }

    // 3. Explain one operating point with the fitted model.
    let (b, gamma) = (24usize, 4usize);
    let t1 = model.t_target(&params, b, 1, 8, 64);
    let tg = model.t_target(&params, b, gamma + 1, 8, 64);
    let td = model.t_draft(&params, b);
    let tr = model.t_reject(&params, b, gamma);
    let sigma = theory::sigma_from_alpha(alpha, gamma);
    let terms = theory::speedup_decomposition(t1, tg, td, tr, sigma, gamma);
    println!("\ndecomposition at B={b}, γ={gamma} (Eq. 4):");
    println!("  T_T(B,1)      = {:.2} ms", t1 * 1e3);
    println!("  T_T(B,γ+1)    = {:.2} ms  → target efficiency {:.3}", tg * 1e3, t1 / tg);
    println!("  γ·T_D/T_T     = {:.3}", terms.draft_term);
    println!("  T_verify/T_T  = {:.3}", terms.verify_term);
    println!("  T_rej/T_T     = {:.4}", terms.reject_term);
    println!("  S/R = σ(γ+1)  = {:.3}", terms.round_len);
    println!("  ⇒ modeled speedup {:.2}x", terms.speedup());
    let measured = measurements
        .iter()
        .find(|m| m.batch == b && m.gamma == gamma)
        .unwrap()
        .speedup;
    println!("  measured        {measured:.2}x");
    Ok(())
}
