//! Sparsity sweep: how MoE sparsity ρ = K/E shifts the SD sweet spot —
//! the paper's §4.2 experiment as a library-API walkthrough.
//!
//! Run: `cargo run --release --example sparsity_sweep`

use moesd::arch::presets;
use moesd::experiments::{paper_batch_grid, peak_speedup, run_pair, RunOpts};
use moesd::hardware::platform_2x_gpu_a;
use moesd::theory;
use moesd::util::table::{f2, MdTable};

fn main() -> anyhow::Result<()> {
    let base = presets::qwen2_57b_a14b();
    let draft = presets::qwen2_0_5b();
    let platform = platform_2x_gpu_a();
    let opts = RunOpts::default();
    let gamma = 4;
    let alpha = 0.88;

    let mut table = MdTable::new(&[
        "K", "ρ", "T_thres(τ=.95)", "peak x", "peak B", "x/√2 width",
    ]);
    for k in [1usize, 2, 4, 8, 16, 32] {
        let target = base.with_topk(k);
        let rho = target.rho();
        let stats: Vec<_> = paper_batch_grid()
            .into_iter()
            .map(|b| run_pair(&target, &draft, &platform, alpha, gamma, b, &opts))
            .collect::<anyhow::Result<_>>()?;
        let peak = peak_speedup(&stats);
        let width = stats
            .iter()
            .filter(|s| s.speedup >= peak.speedup / std::f64::consts::SQRT_2)
            .count();
        table.push(vec![
            k.to_string(),
            format!("{rho:.3}"),
            theory::token_threshold(rho, 0.95).to_string(),
            f2(peak.speedup),
            peak.batch.to_string(),
            width.to_string(),
        ]);
    }
    println!("SD speedup vs sparsity (Qwen2-57B variants, 2×GPU-A, γ={gamma}, α={alpha}):\n");
    println!("{}", table.render());
    println!("Sparser MoEs (small ρ) need more tokens to saturate experts");
    println!("(T_thres ↑) but then stay memory-bound longer: the peak batch");
    println!("moves right and the useful range (x/√2 width) widens — §4.2.");
    Ok(())
}
