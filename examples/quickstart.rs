//! Quickstart: serve a handful of requests through the MoESD engine on the
//! paper-scale synthetic backend and print the SD-vs-AR comparison.
//!
//! Run: `cargo run --release --example quickstart`

use moesd::arch::presets;
use moesd::batching::{Request, SamplingParams};
use moesd::engine::{Engine, EngineConfig};
use moesd::hardware::platform_2x_gpu_a;
use moesd::simulator::ExecSim;
use moesd::spec::synthetic::SyntheticLm;
use moesd::theory;

fn build_engine(gamma: usize, alpha: f64) -> Engine<SyntheticLm> {
    // Qwen2-57B-A14B target + Qwen2-0.5B draft on a 2×GPU-A platform,
    // timed by the roofline simulator (virtual clock).
    let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
    let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
    let backend = SyntheticLm::new(target, draft, alpha, 1234);
    Engine::new(
        EngineConfig {
            gamma,
            ..Default::default()
        },
        backend,
    )
}

fn main() -> anyhow::Result<()> {
    let batch = 24; // a "moderate" batch — the paper's sweet spot
    let alpha = 0.85; // draft acceptance (≈ humaneval-quality speculation)
    let gamma = 4;

    let mut results = Vec::new();
    for g in [gamma, 0] {
        let mut engine = build_engine(g, alpha);
        for id in 0..batch {
            engine.submit(Request {
                id,
                prompt: (0..32u32).collect(),
                params: SamplingParams {
                    temperature: 0.0,
                    max_new_tokens: 64,
                    eos_token: None,
                },
                arrival: 0.0,
                class: 0,
            });
        }
        let done = engine.run_to_completion(10_000)?;
        println!(
            "{}",
            engine
                .metrics
                .report(if g > 0 { "speculative γ=4" } else { "autoregressive" }, g.max(1))
        );
        assert_eq!(done.len(), batch as usize);
        results.push(engine.metrics.decode_time());
    }
    let speedup = results[1] / results[0];
    println!("\nSD speedup at B={batch}: {speedup:.2}x (paper's Fig. 2 regime)");
    println!(
        "Eq. 5 expected round length: {:.2} tokens/round at α={alpha}, γ={gamma}",
        theory::expected_round_length(alpha, gamma)
    );
    Ok(())
}
