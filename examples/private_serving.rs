//! **End-to-end driver** (DESIGN.md deliverable): load the real tiny MoE
//! model through PJRT and serve batched requests over the full stack —
//! router → continuous batcher → speculative decoder → paged KV — at
//! several batch sizes, reporting latency/throughput and the SD-vs-AR
//! speedup on wall clock. This is the paper's "private serving" scenario
//! on the real three-layer system (Python never runs here).
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example private_serving`

use moesd::batching::{Request, SamplingParams};
use moesd::engine::{Engine, EngineConfig};
use moesd::kvcache::KvConfig;
use moesd::runtime::hlo_model::HloBackend;
use moesd::scheduler::SchedulerConfig;
use moesd::tokenizer;
use moesd::util::table::{f2, MdTable};
use std::path::Path;

const PROMPTS: &[&str] = &[
    "INFO GET /api/v1/users 200 OK in ",
    "INFO PUT /api/v1/items 404 NOT_",
    "DEBUG expert[5] load=",
    "INFO worker=3 queue=",
    "WARN POST /api/v2/orders 500 ",
    "INFO HEAD /metrics 200 OK in ",
    "DEBUG expert[0] load=12 acti",
    "INFO worker=7 queue=41 batch=",
];

fn run_batch(dir: &Path, gamma: usize, batch: usize) -> anyhow::Result<(f64, f64, f64, f64)> {
    let mut backend = HloBackend::new(dir)?;
    backend.warmup(backend.manifest().bucket_for(batch.min(8))?)?;
    let mut engine = Engine::new(
        EngineConfig {
            gamma,
            kv: KvConfig {
                num_blocks: 1024,
                block_size: 16,
            },
            scheduler: SchedulerConfig {
                max_batch: batch,
                admit_reserve_tokens: 48,
                tpot_slo: None,
            },
            ..Default::default()
        },
        backend,
    );
    for i in 0..batch {
        engine.submit(Request {
            id: i as u64,
            prompt: tokenizer::encode(PROMPTS[i % PROMPTS.len()], true),
            params: SamplingParams {
                temperature: 0.0,
                max_new_tokens: 48,
                eos_token: None,
            },
            arrival: 0.0,
            class: 0,
        });
    }
    let done = engine.run_to_completion(10_000)?;
    assert_eq!(done.len(), batch);
    if gamma > 0 && batch == 4 {
        println!("\nsample generations (γ={gamma}):");
        for c in done.iter().take(3) {
            println!(
                "  {:?} → {:?}",
                PROMPTS[c.id as usize % PROMPTS.len()],
                tokenizer::decode(&c.tokens)
            );
        }
    }
    let m = &engine.metrics;
    Ok((
        m.decode_time(),
        m.tokens_per_second(),
        m.sigma(gamma.max(1)),
        m.acceptance_rate(),
    ))
}

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }
    println!("=== private serving on the real tiny MoE (PJRT CPU, wall clock) ===");

    let mut table = MdTable::new(&[
        "batch", "T_AR (s)", "T_SD (s)", "speedup", "AR tok/s", "SD tok/s", "σ", "α",
    ]);
    for batch in [1usize, 2, 4, 8] {
        let (t_ar, ar_tps, _, _) = run_batch(dir, 0, batch)?;
        let (t_sd, sd_tps, sigma, alpha) = run_batch(dir, 3, batch)?;
        table.push(vec![
            batch.to_string(),
            f2(t_ar),
            f2(t_sd),
            f2(t_ar / t_sd),
            f2(ar_tps),
            f2(sd_tps),
            f2(sigma),
            f2(alpha),
        ]);
    }
    let rendered = table.render();
    println!("\n{rendered}");
    moesd::benchlib::write_report("private_serving_e2e.md", &rendered)?;
    println!("note: CPU-interpret execution is compute-bound from B=1 (no HBM");
    println!("roofline), so a γ+1-token verify costs ≈(γ+1)× a decode step and SD");
    println!("loses at batch ≥ 2 — the paper's compute-bound regime, reached at");
    println!("tiny batch on this substrate. This driver validates composition +");
    println!("losslessness; the memory-bound window is in the fig2/fig4 benches.");
    Ok(())
}
