//! Adaptive speculation control plane on a shifting-traffic ramp.
//!
//! Drives one engine with the model-guided controller while concurrency
//! climbs 1 → 512, printing the γ the control plane settles on per phase
//! and comparing its throughput against the static-γ baselines — the §3
//! analysis of MoESD turned into a closed control loop.
//!
//! Run: `cargo run --release --example adaptive_ramp`

use moesd::experiments::adaptive::{check_shape, ramp_batches, run, static_gammas};

fn main() -> anyhow::Result<()> {
    let alpha = 0.85;
    println!("traffic ramp, α = {alpha} (Qwen2-57B-A14B + 0.5B draft on 2×GPU-A)\n");
    let out = run(alpha, 42)?;

    println!(
        "{:>8} {:>12} {:>12} {:>12} {:>10} {:>8}",
        "phase B", "adaptive", "best static", "worst static", "γ chosen", "AR bulk"
    );
    for b in ramp_batches() {
        let adaptive = out
            .rows
            .iter()
            .find(|r| r.policy == "adaptive" && r.batch == b)
            .unwrap();
        let statics: Vec<f64> = static_gammas()
            .iter()
            .map(|g| {
                out.rows
                    .iter()
                    .find(|r| r.policy == format!("static-{g}") && r.batch == b)
                    .unwrap()
                    .tok_s
            })
            .collect();
        let best = statics.iter().cloned().fold(f64::MIN, f64::max);
        let worst = statics.iter().cloned().fold(f64::MAX, f64::min);
        println!(
            "{:>8} {:>12.1} {:>12.1} {:>12.1} {:>10} {:>8}",
            b, adaptive.tok_s, best, worst, adaptive.gamma_end, adaptive.ar_bulk_rounds
        );
    }

    match check_shape(&out) {
        Ok(()) => println!("\nadaptive tracked the best static γ in every phase ✓"),
        Err(e) => println!("\nshape check failed: {e}"),
    }
    Ok(())
}
