//! Offline stand-in for the `anyhow` crate.
//!
//! This build environment has no crates.io access, so the subset of the
//! `anyhow` API the workspace uses is vendored here with identical
//! semantics:
//!
//! - [`Error`]: an opaque boxed error with a context chain. `{}` prints
//!   the outermost message, `{:#}` the whole chain colon-separated, `{:?}`
//!   the chain as a "Caused by" report.
//! - [`Result<T>`] with `E` defaulted to [`Error`].
//! - `?` conversion from any `std::error::Error + Send + Sync + 'static`.
//! - The [`anyhow!`], [`bail!`] and [`ensure!`] macros.
//! - [`Context`] for adding context to `Result` and `Option`.

use std::error::Error as StdError;
use std::fmt;

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: an innermost message or source plus a stack of
/// human-readable context frames (outermost last).
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
    context: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            source: None,
            context: Vec::new(),
        }
    }

    /// Wrap a concrete `std::error::Error`.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error {
            msg: error.to_string(),
            source: Some(Box::new(error)),
            context: Vec::new(),
        }
    }

    /// Push an outer context frame (mirrors `anyhow::Error::context`).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.context.push(context.to_string());
        self
    }

    /// Messages from outermost to innermost.
    fn chain_messages(&self) -> Vec<&str> {
        let mut out: Vec<&str> = self.context.iter().rev().map(String::as_str).collect();
        out.push(self.msg.as_str());
        out
    }

    /// The innermost (root) message.
    pub fn root_cause_message(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let chain = self.chain_messages();
        if f.alternate() {
            write!(f, "{}", chain.join(": "))
        } else {
            write!(f, "{}", chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let chain = self.chain_messages();
        write!(f, "{}", chain[0])?;
        if chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for msg in &chain[1..] {
                write!(f, "\n    {msg}")?;
            }
        }
        Ok(())
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes the blanket `From` below coherent (same trick as the real
// crate's specialization-free fallback).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Early-return with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_modes() {
        let e = Error::new(io_err()).context("opening manifest");
        assert_eq!(format!("{e}"), "opening manifest");
        assert_eq!(format!("{e:#}"), "opening manifest: disk on fire");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"));
        assert!(dbg.contains("disk on fire"));
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros_work() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x > 0, "x must be positive, got {x}");
            ensure!(x < 100);
            if x == 13 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(format!("{:#}", f(0).unwrap_err()).contains("positive"));
        assert!(format!("{:#}", f(200).unwrap_err()).contains("condition failed"));
        assert!(format!("{:#}", f(13).unwrap_err()).contains("unlucky 13"));
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
    }

    #[test]
    fn context_trait_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("stage A").unwrap_err();
        assert_eq!(format!("{e:#}"), "stage A: disk on fire");
        let o: Option<u32> = None;
        assert!(o.with_context(|| "missing").is_err());
    }
}
