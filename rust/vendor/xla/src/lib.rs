//! Offline stub of the `xla` (xla_extension / PJRT) bindings.
//!
//! The real crate links against `libxla_extension`, which is not present
//! in this build environment. The runtime layer only needs the PJRT
//! surface when `artifacts/` exists (the HLO-backend tests skip themselves
//! otherwise), so this stub keeps the crate compiling and fails with a
//! clear message the moment device execution is actually attempted:
//!
//! - [`Literal`] is fully functional host-side (`vec1`, `reshape`,
//!   `to_vec`) — unit tests exercise it.
//! - [`PjRtClient::cpu`] and everything downstream return
//!   [`Error::StubUnavailable`]-style errors.

use std::fmt;

/// Error type matching the real crate's `{:?}`-reported errors.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

type XlaResult<T> = Result<T, Error>;

fn stub_err(what: &str) -> Error {
    Error(format!(
        "{what}: built against the offline `xla` stub (libxla_extension is \
         unavailable in this environment); PJRT execution is disabled"
    ))
}

/// Element dtype tag for [`Literal`] buffers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElemKind {
    F32,
    I32,
}

/// Host element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    const KIND: ElemKind;
    fn to_f64(self) -> f64;
    fn from_f64(v: f64) -> Self;
}

impl NativeType for f32 {
    const KIND: ElemKind = ElemKind::F32;

    fn to_f64(self) -> f64 {
        self as f64
    }

    fn from_f64(v: f64) -> f32 {
        v as f32
    }
}

impl NativeType for i32 {
    const KIND: ElemKind = ElemKind::I32;

    fn to_f64(self) -> f64 {
        self as f64
    }

    fn from_f64(v: f64) -> i32 {
        v as i32
    }
}

/// A host literal: flat data + logical dims (+ optional tuple children).
#[derive(Debug, Clone)]
pub struct Literal {
    kind: ElemKind,
    dims: Vec<i64>,
    data: Vec<f64>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            kind: T::KIND,
            dims: vec![data.len() as i64],
            data: data.iter().map(|v| v.to_f64()).collect(),
            tuple: None,
        }
    }

    /// Reinterpret the literal with new logical dims (element count must
    /// match, as in the real bindings).
    pub fn reshape(&self, dims: &[i64]) -> XlaResult<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into dims {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal {
            kind: self.kind,
            dims: dims.to_vec(),
            data: self.data.clone(),
            tuple: None,
        })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Read the literal back as a host vector of `T`.
    pub fn to_vec<T: NativeType>(&self) -> XlaResult<Vec<T>> {
        if self.kind != T::KIND {
            return Err(Error(format!(
                "to_vec: literal holds {:?}, requested a different element type",
                self.kind
            )));
        }
        Ok(self.data.iter().map(|&v| T::from_f64(v)).collect())
    }

    /// Destructure a 3-tuple literal.
    pub fn to_tuple3(self) -> XlaResult<(Literal, Literal, Literal)> {
        match self.tuple {
            Some(mut children) if children.len() == 3 => {
                let c = children.pop().unwrap();
                let b = children.pop().unwrap();
                let a = children.pop().unwrap();
                Ok((a, b, c))
            }
            _ => Err(stub_err("to_tuple3")),
        }
    }
}

/// Parsed HLO module (stub: never constructible from real artifacts).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> XlaResult<HloModuleProto> {
        Err(stub_err("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle (stub: never materialized).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        Err(stub_err("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle (stub: never materialized).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client (stub: construction reports unavailability up front).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> XlaResult<PjRtClient> {
        Err(stub_err("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> XlaResult<PjRtLoadedExecutable> {
        Err(stub_err("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> XlaResult<PjRtBuffer> {
        Err(stub_err("PjRtClient::buffer_from_host_buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_roundtrip_i32() {
        let l = Literal::vec1(&[5i32, -6]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![5, -6]);
    }

    #[test]
    fn device_paths_report_stub() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e:?}").contains("stub"));
    }
}
