//! Closed-form theory from §3 of the paper.
//!
//! Every equation in the paper's analysis section lives here, named after
//! its equation number, so simulator/perfmodel/experiment code shares one
//! audited implementation:
//!
//! - Eq. 1  — roofline ridge point (in [`crate::hardware`]) and arithmetic
//!   intensity helpers.
//! - Eq. 4  — SD speedup decomposition [`speedup_decomposition`].
//! - Eq. 5  — σ(α, γ) [`sigma_from_alpha`] and its numeric inverse
//!   [`alpha_from_sigma`].
//! - Eq. 8  — expected number of activated experts N(t)
//!   [`expected_active_experts`].
//! - Eq. 9  — full-activation token threshold T_thres [`token_threshold`].
//! - Eq. 10 — per-expert token load T̄_exp(t; ρ) [`expert_load`].
//! - Eq. 11 — the roofline ramp G(t; λRP, s) [`roofline_g`].
//! - §3.1   — *target efficiency* T_T(B,1)/T_T(B,γ) [`target_efficiency`].
//! - App. B — monotonicity of T̄_exp in ρ (property-tested below).
//! - §3.4   — expert-parallel sharding corollaries of Eq. 8
//!   ([`ep_active_experts_per_device`], [`ep_remote_fraction`]): under EP
//!   the token pool stays *global*, so per-expert load T̄_exp is
//!   d-invariant while per-device activation and weight traffic divide
//!   by d.

/// σ (Eq. 5): expected generated tokens per round divided by the maximal
/// γ+1, given per-token acceptance probability α and draft length γ.
///
/// σ = [(1 - α^{γ+1}) / (1 - α)] / (γ + 1), with the α → 1 limit equal to 1.
///
/// ```
/// use moesd::theory::sigma_from_alpha;
/// // γ=2, α=0.8: (1 − 0.8³)/(1 − 0.8)/3 = 0.813̄ (the Eq. 5 closed form).
/// assert!((sigma_from_alpha(0.8, 2) - 0.8133333333).abs() < 1e-9);
/// // A draft that is never right still yields the bonus token: σ = 1/(γ+1).
/// assert_eq!(sigma_from_alpha(0.0, 3), 0.25);
/// ```
pub fn sigma_from_alpha(alpha: f64, gamma: usize) -> f64 {
    assert!((0.0..=1.0).contains(&alpha), "alpha out of [0,1]: {alpha}");
    let g1 = (gamma + 1) as f64;
    if (1.0 - alpha).abs() < 1e-12 {
        return 1.0;
    }
    (1.0 - alpha.powf(g1)) / (1.0 - alpha) / g1
}

/// Expected accepted length per SD round, S/R = σ · (γ + 1)  (§3.1).
pub fn expected_round_length(alpha: f64, gamma: usize) -> f64 {
    sigma_from_alpha(alpha, gamma) * (gamma + 1) as f64
}

/// Expected tokens committed by a **ragged** round (per-sequence draft
/// lengths): Σᵢ σ(αᵢ, γᵢ)·(γᵢ+1) — the numerator of the per-sequence
/// Eq. 4 extension (see [`crate::perfmodel::PerfModel::ragged_goodput`]).
///
/// ```
/// use moesd::theory::{expected_round_length, ragged_round_tokens};
/// let mixed = ragged_round_tokens(&[0.9, 0.5], &[6, 2]);
/// let by_hand = expected_round_length(0.9, 6) + expected_round_length(0.5, 2);
/// assert!((mixed - by_hand).abs() < 1e-12);
/// // A uniform round is the degenerate case: B equal terms.
/// let uni = ragged_round_tokens(&[0.8, 0.8], &[3, 3]);
/// assert!((uni - 2.0 * expected_round_length(0.8, 3)).abs() < 1e-12);
/// ```
pub fn ragged_round_tokens(alphas: &[f64], gammas: &[usize]) -> f64 {
    assert_eq!(alphas.len(), gammas.len(), "alphas/gammas length mismatch");
    alphas
        .iter()
        .zip(gammas)
        .map(|(&a, &g)| expected_round_length(a, g))
        .sum()
}

/// Numeric inverse of Eq. 5: recover α from a measured σ at draft length γ
/// by bisection. Used to calibrate the synthetic workloads to the σ values
/// the paper reports in Tables 1–2.
///
/// σ is monotonically increasing in α on [0, 1], ranging from 1/(γ+1) to 1.
pub fn alpha_from_sigma(sigma: f64, gamma: usize) -> f64 {
    let lo_sigma = 1.0 / (gamma + 1) as f64;
    assert!(
        sigma >= lo_sigma - 1e-9 && sigma <= 1.0 + 1e-9,
        "sigma {sigma} outside attainable range [{lo_sigma}, 1] for gamma={gamma}"
    );
    let target = sigma.clamp(lo_sigma, 1.0);
    let (mut lo, mut hi) = (0.0f64, 1.0f64);
    for _ in 0..80 {
        let mid = 0.5 * (lo + hi);
        if sigma_from_alpha(mid, gamma) < target {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// N(t) (Eq. 8): expected number of activated experts when `t` tokens pass
/// a gate with `e` experts and `k` activated per token, assuming i.i.d.
/// uniform routing:  N(t) = E · (1 − ((E−K)/E)^t).
pub fn expected_active_experts(e: usize, k: usize, t: u64) -> f64 {
    assert!(k <= e && e > 0, "invalid MoE config E={e} K={k}");
    let e_f = e as f64;
    let miss = (e_f - k as f64) / e_f;
    e_f * (1.0 - miss.powf(t as f64))
}

/// T_thres (Eq. 9): the smallest token count whose expected activation
/// reaches τ·E:  T_thres = ⌈ log_{1−ρ}(1−τ) ⌉ with ρ = K/E.
pub fn token_threshold(rho: f64, tau: f64) -> u64 {
    assert!(rho > 0.0 && rho < 1.0, "rho must be in (0,1): {rho}");
    assert!(tau > 0.0 && tau < 1.0, "tau must be in (0,1): {tau}");
    ((1.0 - tau).ln() / (1.0 - rho).ln()).ceil() as u64
}

/// T̄_exp(t; ρ) (Eq. 10): average tokens processed per *activated* expert:
/// ρ·t / (1 − (1−ρ)^t). For dense models ρ = 1 and T̄_exp = t.
pub fn expert_load(t: f64, rho: f64) -> f64 {
    assert!(rho > 0.0 && rho <= 1.0, "rho must be in (0,1]: {rho}");
    assert!(t >= 0.0);
    if t == 0.0 {
        return 0.0;
    }
    if (rho - 1.0).abs() < 1e-15 {
        return t;
    }
    rho * t / (1.0 - (1.0 - rho).powf(t))
}

/// G(t; λRP, s) (Eq. 11): the roofline execution-time ramp. Exponential
/// (slowly growing, memory-bound) up to the transition point t = λRP, then
/// linear with matching first derivative (compute-bound):
///
/// ```text
/// G(t) = s^t                                   , t ≤ λRP
///      = s^{λRP} · (1 + ln(s) · (t − λRP))     , t > λRP
/// ```
///
/// `s` must be ≥ 1 (monotone growth; Appendix C.2 bounds it to [1, 2]).
/// Computed in log-space and clamped to avoid overflow during fitting when
/// the optimizer probes extreme `s`.
pub fn roofline_g(t: f64, lambda_rp: f64, s: f64) -> f64 {
    assert!(s >= 1.0, "s must be >= 1: {s}");
    assert!(lambda_rp >= 0.0);
    assert!(t >= 0.0);
    let ln_s = s.ln();
    let exp_clamped = |x: f64| -> f64 {
        // e^709 is the f64 overflow edge; residuals stay finite so LM can
        // retreat from pathological parameter probes.
        x.min(700.0).exp()
    };
    if t <= lambda_rp {
        exp_clamped(t * ln_s)
    } else {
        let at_rp = exp_clamped(lambda_rp * ln_s);
        at_rp * (1.0 + ln_s * (t - lambda_rp))
    }
}

/// Expected activated experts **per EP rank** when `t` global tokens hit a
/// gate whose `e` experts are partitioned evenly across `d` ranks.
///
/// By symmetry each expert is activated with the same probability
/// `1 − ((E−K)/E)^t` wherever it lives, so a rank holding `E/d` experts
/// expects exactly `N(t)/d` of them active — Eq. 8 divided by the EP
/// degree. This is what makes EP attractive for sparse MoE: per-rank
/// expert *weight traffic* divides by `d` while per-expert *load*
/// (`T̄_exp`, [`expert_load`]) is unchanged, because the token pool stays
/// global.
///
/// ```
/// use moesd::theory::{ep_active_experts_per_device, expected_active_experts};
/// let global = expected_active_experts(64, 8, 128);
/// let per_rank = ep_active_experts_per_device(64, 8, 128, 4);
/// assert!((per_rank - global / 4.0).abs() < 1e-12);
/// // d = 1 is exactly the unsharded Eq. 8.
/// assert_eq!(ep_active_experts_per_device(64, 8, 128, 1), global);
/// ```
pub fn ep_active_experts_per_device(e: usize, k: usize, t: u64, d: usize) -> f64 {
    assert!(d >= 1, "EP degree must be >= 1");
    expected_active_experts(e, k, t) / d as f64
}

/// Expert-budgeted N(t): the expected activation of Eq. 8 capped at a
/// verify-time expert budget, `min(N(t), budget)` (the MoE-Spec knob —
/// see PAPERS.md). `budget = None` **is** Eq. 8, bit-for-bit, and any
/// budget ≥ E is a no-op because N(t) ≤ E always (IEEE `min` against a
/// larger bound returns the original value exactly; property-tested in
/// `rust/tests/prop_invariants.rs`).
///
/// ```
/// use moesd::theory::{budgeted_active_experts, expected_active_experts};
/// let n = expected_active_experts(64, 8, 28);
/// assert_eq!(budgeted_active_experts(64, 8, 28, None), n);
/// assert_eq!(budgeted_active_experts(64, 8, 28, Some(64)), n);
/// assert_eq!(budgeted_active_experts(64, 8, 28, Some(16)), 16.0);
/// ```
pub fn budgeted_active_experts(e: usize, k: usize, t: u64, budget: Option<usize>) -> f64 {
    let n = expected_active_experts(e, k, t);
    match budget {
        Some(b) => n.min(b as f64),
        None => n,
    }
}

/// Coverage fraction of a verify-expert budget at verify width `t`:
/// `min(1, budget / N(t))` — the share of the expectedly-activated
/// experts the budgeted verify actually runs. `None` (and any budget
/// ≥ N(t)) is full coverage, exactly 1.
pub fn budget_coverage(e: usize, k: usize, t: u64, budget: Option<usize>) -> f64 {
    let n = expected_active_experts(e, k, t);
    match budget {
        Some(b) if (b as f64) < n => b as f64 / n,
        _ => 1.0,
    }
}

/// Acceptance degradation under an expert budget: α_eff =
/// α · coverage^sensitivity. A draft token whose top-K experts fall
/// outside the budget verifies against a degraded target distribution
/// and is (more often) rejected; `sensitivity` calibrates how sharply
/// acceptance tracks coverage (MoE-Spec reports mild degradation —
/// sensitivity well below 1 — because hot experts are shared across
/// tokens). Full coverage returns α **exactly** (the off-switch
/// contract: `coverage = 1` short-circuits before any float op).
pub fn budgeted_alpha(alpha: f64, coverage: f64, sensitivity: f64) -> f64 {
    assert!((0.0..=1.0).contains(&alpha), "alpha out of [0,1]: {alpha}");
    assert!(sensitivity >= 0.0, "sensitivity must be non-negative");
    if coverage >= 1.0 {
        return alpha;
    }
    alpha * coverage.clamp(0.0, 1.0).powf(sensitivity)
}

/// Fraction of dispatched tokens that must cross the EP fabric under
/// uniform routing: `(d − 1)/d` (a token's expert lives on its own rank
/// with probability `1/d`). Zero for a single rank.
///
/// ```
/// use moesd::theory::ep_remote_fraction;
/// assert_eq!(ep_remote_fraction(1), 0.0);
/// assert_eq!(ep_remote_fraction(4), 0.75);
/// ```
pub fn ep_remote_fraction(d: usize) -> f64 {
    if d <= 1 {
        0.0
    } else {
        (d - 1) as f64 / d as f64
    }
}

/// Target efficiency (§3.1): T_T(B,1) / T_T(B,γ) ∈ (0, 1].
/// Values near 1 mean verification is "free"; small values mean SD pays a
/// heavy verification penalty.
///
/// ```
/// use moesd::theory::target_efficiency;
/// // Verification that costs the same as decode is "free": efficiency 1.
/// assert_eq!(target_efficiency(5.0, 5.0), 1.0);
/// // A 2× costlier verify step halves it.
/// assert_eq!(target_efficiency(5.0, 10.0), 0.5);
/// ```
pub fn target_efficiency(t_target_1: f64, t_target_gamma: f64) -> f64 {
    assert!(t_target_1 > 0.0 && t_target_gamma > 0.0);
    t_target_1 / t_target_gamma
}

/// Components of the Eq. 4 denominator, kept separate so experiments can
/// report each term (the paper's "transparent and explainable" modeling).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpeedupTerms {
    /// γ · T_D(B,1) / T_T(B,1) — relative draft cost.
    pub draft_term: f64,
    /// T_T(B,γ) / T_T(B,1) — inverse of target efficiency.
    pub verify_term: f64,
    /// T_reject / T_T(B,1).
    pub reject_term: f64,
    /// S/R = σ(γ+1) — expected accepted length per round.
    pub round_len: f64,
}

impl SpeedupTerms {
    pub fn speedup(&self) -> f64 {
        self.round_len / (self.draft_term + self.verify_term + self.reject_term)
    }
}

/// Eq. 4: assemble SD speedup from measured/simulated component times.
///
/// ```
/// use moesd::theory::speedup_decomposition;
/// // T_T(B,1)=10, T_T(B,γ+1)=12, T_D=1, T_rej=0.2, σ=0.9, γ=3:
/// // x = σ(γ+1) / (γ·T_D/T_T1 + T_Tγ/T_T1 + T_rej/T_T1) = 3.6/1.52.
/// let terms = speedup_decomposition(10.0, 12.0, 1.0, 0.2, 0.9, 3);
/// assert!((terms.speedup() - 3.6 / 1.52).abs() < 1e-12);
/// assert!((terms.verify_term - 1.2).abs() < 1e-12);
/// ```
pub fn speedup_decomposition(
    t_target_1: f64,
    t_target_gamma: f64,
    t_draft_1: f64,
    t_reject: f64,
    sigma: f64,
    gamma: usize,
) -> SpeedupTerms {
    assert!(t_target_1 > 0.0);
    SpeedupTerms {
        draft_term: gamma as f64 * t_draft_1 / t_target_1,
        verify_term: t_target_gamma / t_target_1,
        reject_term: t_reject / t_target_1,
        round_len: sigma * (gamma + 1) as f64,
    }
}

/// Arithmetic intensity of a GEMM processing `t` tokens against a resident
/// weight matrix (Eq. 1 software side): 2·t·params FLOPs over
/// (params + activations)·bytes ≈ t for large weights. We expose the
/// simplified per-expert form used throughout §3.2: AI ≈ T̄_exp.
pub fn ffn_arithmetic_intensity(tokens_per_expert: f64) -> f64 {
    tokens_per_expert
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{ensure, ensure_close, Runner};

    #[test]
    fn sigma_limits() {
        // α = 0: only the bonus token survives → σ = 1/(γ+1).
        for gamma in 1..6 {
            assert!((sigma_from_alpha(0.0, gamma) - 1.0 / (gamma + 1) as f64).abs() < 1e-12);
            assert!((sigma_from_alpha(1.0, gamma) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn sigma_known_value() {
        // γ=2, α=0.8: (1-0.8^3)/(1-0.8)/3 = (0.488/0.2)/3 = 0.8133...
        let s = sigma_from_alpha(0.8, 2);
        assert!((s - 0.81333333).abs() < 1e-6, "s={s}");
    }

    #[test]
    fn sigma_monotone_in_alpha() {
        let mut r = Runner::new("sigma_monotone_alpha");
        r.run(300, |g| {
            let gamma = g.usize_in(1, 8);
            let a1 = g.prob();
            let a2 = g.prob();
            let (lo, hi) = if a1 < a2 { (a1, a2) } else { (a2, a1) };
            ensure(
                sigma_from_alpha(lo, gamma) <= sigma_from_alpha(hi, gamma) + 1e-12,
                format!("σ not monotone: α {lo}->{hi} γ={gamma}"),
            )
        });
    }

    #[test]
    fn alpha_sigma_roundtrip() {
        let mut r = Runner::new("alpha_sigma_roundtrip");
        r.run(300, |g| {
            let gamma = g.usize_in(1, 6);
            let alpha = g.prob();
            let sigma = sigma_from_alpha(alpha, gamma);
            let back = alpha_from_sigma(sigma, gamma);
            ensure_close(back, alpha, 1e-6, "alpha roundtrip")
        });
    }

    #[test]
    fn paper_sigma_values_invert() {
        // Table 1 row: Qwen2/humaneval/T=0, γ=4 has σ=0.91 → α ≈ high.
        let a = alpha_from_sigma(0.91, 4);
        assert!(a > 0.85 && a < 1.0, "α={a}");
        // Table 1: mtbench γ=4 σ=0.55 → lower α.
        let a2 = alpha_from_sigma(0.55, 4);
        assert!(a2 < a, "expected mtbench α < humaneval α");
    }

    #[test]
    fn active_experts_limits() {
        // t=1 activates exactly K experts in expectation.
        assert!((expected_active_experts(64, 8, 1) - 8.0).abs() < 1e-9);
        // Large t saturates at E.
        assert!(expected_active_experts(64, 8, 10_000) > 63.999);
        // Dense edge: K = E means everything active from t = 1.
        assert!((expected_active_experts(8, 8, 1) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn active_experts_monotone_in_t() {
        let mut r = Runner::new("n_t_monotone");
        r.run(200, |g| {
            let e = g.usize_in(2, 128);
            let k = g.usize_in(1, e);
            let t = g.u64_in(1, 500);
            let n1 = expected_active_experts(e, k, t);
            let n2 = expected_active_experts(e, k, t + 1);
            ensure(
                n2 >= n1 - 1e-9 && n2 <= e as f64 + 1e-9,
                format!("N(t) not monotone/bounded: E={e} K={k} t={t}"),
            )
        });
    }

    #[test]
    fn threshold_matches_paper_models() {
        // DeepSeek-V2-Lite-ish (ρ=6/62, paper Fig. 1a), τ=0.95:
        // log_{1-6/62}(0.05) = ln(0.05)/ln(0.9032) ≈ 29.4 → 30.
        let t = token_threshold(6.0 / 62.0, 0.95);
        assert_eq!(t, 30, "T_thres={t}");
        // Qwen1.5-MoE (ρ=4/60): ln(.05)/ln(1-1/15) ≈ 43.4 → 44.
        let t2 = token_threshold(4.0 / 60.0, 0.95);
        assert_eq!(t2, 44);
        // Sparser → larger threshold.
        assert!(token_threshold(0.05, 0.95) > token_threshold(0.2, 0.95));
    }

    #[test]
    fn threshold_is_the_crossing_point() {
        let mut r = Runner::new("threshold_crossing");
        r.run(200, |g| {
            let e = g.usize_in(8, 128);
            let k = g.usize_in(1, e - 1);
            let rho = k as f64 / e as f64;
            let tau = g.f64_in(0.5, 0.99);
            let thres = token_threshold(rho, tau);
            let at = expected_active_experts(e, k, thres) / e as f64;
            let before = if thres > 1 {
                expected_active_experts(e, k, thres - 1) / e as f64
            } else {
                0.0
            };
            ensure(
                at >= tau - 1e-9 && before < tau + 1e-9,
                format!("threshold wrong: E={e} K={k} tau={tau} thres={thres} at={at} before={before}"),
            )
        });
    }

    #[test]
    fn expert_load_limits() {
        // t=1: exactly 1 token per activated expert regardless of ρ.
        assert!((expert_load(1.0, 0.125) - 1.0).abs() < 1e-9);
        // Dense (ρ=1): every "expert" sees all tokens.
        assert!((expert_load(37.0, 1.0) - 37.0).abs() < 1e-12);
        // Large t: load → ρ·t (all experts active).
        let l = expert_load(100_000.0, 0.1);
        assert!((l - 10_000.0).abs() / 10_000.0 < 1e-6);
    }

    #[test]
    fn appendix_b_expert_load_monotone_in_rho() {
        // App. B: for T > 1, T̄_exp decreases as ρ decreases.
        let mut r = Runner::new("texp_monotone_rho");
        r.run(400, |g| {
            let t = g.f64_in(1.001, 512.0);
            let r1 = g.f64_in(0.005, 1.0);
            let r2 = g.f64_in(0.005, 1.0);
            let (lo, hi) = if r1 < r2 { (r1, r2) } else { (r2, r1) };
            ensure(
                expert_load(t, lo) <= expert_load(t, hi) + 1e-9,
                format!("T̄_exp not monotone in ρ at t={t}: ρ {lo} vs {hi}"),
            )
        });
    }

    #[test]
    fn roofline_g_shape() {
        let (lrp, s) = (32.0, 1.05);
        // Monotone increasing.
        let mut prev = 0.0;
        for t in 0..200 {
            let g = roofline_g(t as f64, lrp, s);
            assert!(g >= prev, "G not monotone at t={t}");
            prev = g;
        }
        // C¹ continuity at the transition: linear extrapolation from just
        // below matches just above.
        let eps = 1e-6;
        let below = roofline_g(lrp - eps, lrp, s);
        let above = roofline_g(lrp + eps, lrp, s);
        assert!((below - above).abs() < 1e-4, "discontinuity at λRP");
        let slope_below = (roofline_g(lrp, lrp, s) - roofline_g(lrp - 1e-4, lrp, s)) / 1e-4;
        let slope_above = (roofline_g(lrp + 1e-4, lrp, s) - roofline_g(lrp, lrp, s)) / 1e-4;
        assert!(
            (slope_below - slope_above).abs() / slope_above < 1e-3,
            "gradient discontinuity at λRP: {slope_below} vs {slope_above}"
        );
    }

    #[test]
    fn roofline_g_linear_after_transition() {
        let (lrp, s) = (16.0, 1.08);
        let g1 = roofline_g(100.0, lrp, s);
        let g2 = roofline_g(200.0, lrp, s);
        let g3 = roofline_g(300.0, lrp, s);
        assert!(
            ((g3 - g2) - (g2 - g1)).abs() < 1e-9,
            "not linear in compute-bound regime"
        );
    }

    #[test]
    fn roofline_g_no_overflow() {
        // Extreme s probed by the fitter must stay finite.
        let g = roofline_g(5000.0, 4000.0, 2.0);
        assert!(g.is_finite());
    }

    #[test]
    fn speedup_decomposition_matches_formula() {
        // Hand example: T_T(B,1)=10, T_T(B,γ)=12, T_D=1, T_rej=0.2, σ=0.9, γ=3.
        let terms = speedup_decomposition(10.0, 12.0, 1.0, 0.2, 0.9, 3);
        assert!((terms.draft_term - 0.3).abs() < 1e-12);
        assert!((terms.verify_term - 1.2).abs() < 1e-12);
        assert!((terms.reject_term - 0.02).abs() < 1e-12);
        assert!((terms.round_len - 3.6).abs() < 1e-12);
        let s = terms.speedup();
        assert!((s - 3.6 / 1.52).abs() < 1e-12, "speedup={s}");
    }

    #[test]
    fn speedup_increases_with_target_efficiency() {
        let mut r = Runner::new("speedup_vs_teff");
        r.run(300, |g| {
            let t1 = g.f64_in(1.0, 100.0);
            let tg_a = t1 * g.f64_in(1.0, 4.0);
            let tg_b = tg_a * g.f64_in(1.0, 2.0); // worse efficiency
            let td = t1 * g.f64_in(0.01, 0.2);
            let sigma = g.f64_in(0.3, 1.0);
            let gamma = g.usize_in(1, 5);
            let sa = speedup_decomposition(t1, tg_a, td, 0.0, sigma, gamma).speedup();
            let sb = speedup_decomposition(t1, tg_b, td, 0.0, sigma, gamma).speedup();
            ensure(
                sa >= sb - 1e-12,
                format!("higher verify cost should not speed up: {sa} vs {sb}"),
            )
        });
    }

    #[test]
    fn target_efficiency_bounds() {
        assert!((target_efficiency(5.0, 5.0) - 1.0).abs() < 1e-12);
        assert!(target_efficiency(5.0, 10.0) < 1.0);
    }

    #[test]
    fn ep_activation_splits_evenly_and_load_is_d_invariant() {
        let mut r = Runner::new("ep_activation");
        r.run(200, |g| {
            let e = g.usize_in(2, 128);
            let k = g.usize_in(1, e);
            let t = g.u64_in(1, 512);
            let d = g.usize_in(1, 16);
            let global = expected_active_experts(e, k, t);
            let per = ep_active_experts_per_device(e, k, t, d);
            ensure_close(per * d as f64, global, 1e-9, "per-rank activation × d")?;
            // Per-expert load (Eq. 10) references the *global* token pool,
            // so nothing about it changes under EP — asserted here as the
            // invariant the sharded simulator relies on.
            let rho = k as f64 / e as f64;
            let load = expert_load(t as f64, rho);
            ensure(
                load > 0.0 && load <= t as f64 + 1e-9,
                format!("load {load} out of range"),
            )
        });
    }

    #[test]
    fn ep_remote_fraction_limits() {
        assert_eq!(ep_remote_fraction(1), 0.0);
        assert_eq!(ep_remote_fraction(2), 0.5);
        assert!((ep_remote_fraction(8) - 0.875).abs() < 1e-12);
        // Approaches 1 as the group grows: almost every token goes remote.
        assert!(ep_remote_fraction(1024) > 0.999);
    }
}
