//! Worker thread: wraps an [`SdBackend`] replica and serves frames.
//!
//! Every worker holds a *full* backend replica (draft and target
//! simulators both) built from the same factory as its peers, so any
//! cost or token it computes is bit-identical to what the single-process
//! engine would have computed. Roles differ only in which ops the
//! coordinator routes to them and which [`StateOp`]s they apply:
//!
//! * each **draft** rank serves its propose stripe and applies
//!   `RollbackDraft`/`SyncBase`/`Release`;
//! * each **verify** rank serves verify and applies
//!   `RollbackTarget`/`Release`.
//!
//! This strict routing is what keeps each replica's state consistent
//! with the subset of the computation it actually runs — e.g. a draft
//! replica never executes verify, so the coordinator pushes the
//! committed base forward with `SyncBase` instead.
//!
//! Hot path: requests arrive as raw bytes and decode into a pooled
//! [`wire::ReqScratch`] (no per-frame Vec churn); responses encode
//! straight from the backend's borrowed outputs into a buffer recycled
//! from the retransmit ring.
//!
//! Retransmit safety: the worker keeps a ring of its last
//! [`REPLAY_RING`] `(op, response bytes)` pairs and replays the cached
//! response verbatim when a known op id arrives again, so a retried
//! frame never re-executes a compute op (state ops are idempotent,
//! compute ops are not). The ring must cover the coordinator's pipeline
//! window — `DistConfig::max_in_flight` is validated against it.

use std::collections::VecDeque;

use crate::spec::SdBackend;

use super::transport::WorkerEndpoint;
use super::wire::{self, Frame, StateOp, Subject, WorkerStats};

/// Retransmit-dedup ring depth. Must be at least the coordinator's
/// maximum in-flight window plus slack for retries of already-answered
/// ops ([`super::DistConfig`] validates `max_in_flight` against this).
pub const REPLAY_RING: usize = 32;

/// Which half of the speculative loop this worker serves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Draft,
    Verify,
}

impl Role {
    pub fn as_u8(self) -> u8 {
        match self {
            Role::Draft => 0,
            Role::Verify => 1,
        }
    }
}

/// Spawn-time knobs, mostly for fault injection.
#[derive(Debug, Clone, Default)]
pub struct WorkerOptions {
    /// Exit (simulating a crash) after this many *compute* ops have
    /// executed. Responses for the final op are still sent — the death
    /// is detected by the coordinator via the endpoint liveness flag.
    pub die_after_ops: Option<u64>,
}

/// Serve frames until the coordinator hangs up (or `die_after_ops`
/// fires). Runs on its own thread; the endpoint's `Drop` marks the
/// worker dead for the coordinator on any exit path, panics included.
pub fn run_worker<B: SdBackend>(
    role: Role,
    rank: u32,
    mut backend: B,
    ep: WorkerEndpoint,
    opts: WorkerOptions,
) {
    let mut ops_executed: u64 = 0;
    let mut seqs_live: u64 = 0;
    let mut ring: VecDeque<(u64, Vec<u8>)> = VecDeque::with_capacity(REPLAY_RING);
    let mut scratch = wire::ReqScratch::default();
    let mut lens_buf: Vec<u64> = Vec::new();

    while let Some(bytes) = ep.recv_bytes() {
        // Undecodable preambles are skipped, as before: the worker
        // cannot reply to a frame it cannot parse; the coordinator's
        // retry path re-sends.
        let Ok((op, tag)) = wire::peek_header(&bytes) else {
            continue;
        };
        // Retransmit of an op still in the ring: replay the cached
        // response instead of re-executing.
        if let Some((_, resp)) = ring.iter().find(|(o, _)| *o == op) {
            if !ep.send_bytes(resp.clone()) {
                return;
            }
            continue;
        }

        // The response buffer is recycled from the ring's evicted slot:
        // steady-state serving allocates only for payload growth.
        let mut out = match ring.len() >= REPLAY_RING {
            true => ring.pop_front().map(|(_, b)| b).unwrap_or_default(),
            false => Vec::new(),
        };
        out.clear();

        let is_compute = matches!(
            tag,
            wire::TAG_PROPOSE_REQ | wire::TAG_VERIFY_REQ | wire::TAG_PREFILL_CHUNK
        );
        let served = match tag {
            wire::TAG_PROPOSE_REQ => match wire::decode_propose_req(&bytes, &mut scratch) {
                Err(_) => None,
                Ok(()) => {
                    apply_state_ops(role, &mut backend, &mut seqs_live, &scratch.state_ops);
                    match backend.propose(
                        &scratch.seqs,
                        &scratch.rows[..scratch.n],
                        &scratch.gammas,
                        &scratch.temps,
                        scratch.seed,
                    ) {
                        Ok(o) => {
                            lens_buf.clear();
                            lens_buf
                                .extend(scratch.seqs.iter().map(|&s| backend.draft_len(s) as u64));
                            wire::encode_propose_resp(
                                &mut out, op, &o.tokens, &o.probs, &lens_buf, o.cost,
                            );
                            Some(())
                        }
                        Err(e) => {
                            error_resp(&mut out, op, &format!("propose: {e:#}"));
                            Some(())
                        }
                    }
                }
            },
            wire::TAG_VERIFY_REQ => match wire::decode_verify_req(&bytes, &mut scratch) {
                Err(_) => None,
                Ok(()) => {
                    apply_state_ops(role, &mut backend, &mut seqs_live, &scratch.state_ops);
                    backend.set_verify_budget(scratch.budget.map(|b| b as usize));
                    match backend.verify(
                        &scratch.seqs,
                        &scratch.feed,
                        &scratch.rows[..scratch.n],
                        &scratch.temps,
                    ) {
                        Ok(o) => {
                            lens_buf.clear();
                            lens_buf
                                .extend(scratch.seqs.iter().map(|&s| backend.target_len(s) as u64));
                            wire::encode_verify_resp(&mut out, op, &o.probs, &lens_buf, o.cost);
                            Some(())
                        }
                        Err(e) => {
                            error_resp(&mut out, op, &format!("verify: {e:#}"));
                            Some(())
                        }
                    }
                }
            },
            // Control / cold ops go through the typed decoder.
            _ => match Frame::decode(&bytes) {
                Err(_) => None,
                Ok(frame) => {
                    serve_cold(
                        role, rank, &mut backend, &mut seqs_live, ops_executed, frame, &mut out,
                    );
                    Some(())
                }
            },
        };
        if served.is_none() {
            continue;
        }
        if is_compute {
            ops_executed += 1;
        }
        if !ep.send_bytes(out.clone()) {
            return;
        }
        ring.push_back((op, out));

        if let Some(limit) = opts.die_after_ops {
            if is_compute && ops_executed >= limit {
                // Simulated crash: the endpoint drops here and the
                // coordinator sees the slot detach.
                return;
            }
        }
    }
}

fn error_resp(out: &mut Vec<u8>, op: u64, message: &str) {
    *out = Frame {
        op,
        subject: Subject::ErrorResp {
            message: message.to_string(),
        },
    }
    .encode();
}

/// Apply the state ops this role owns, skip the rest. All owned ops are
/// idempotent against already-updated state (rollbacks set/clamp,
/// release tolerates absent sequences), which is what makes retried
/// frames safe to re-apply.
fn apply_state_ops<B: SdBackend>(role: Role, backend: &mut B, seqs_live: &mut u64, ops: &[StateOp]) {
    for op in ops {
        match (role, op) {
            (Role::Verify, StateOp::RollbackTarget { seq, len }) => {
                backend.rollback_target(*seq, *len as usize);
            }
            (Role::Draft, StateOp::RollbackDraft { seq, len }) => {
                backend.rollback_draft(*seq, *len as usize);
            }
            (Role::Draft, StateOp::SyncBase { seq, len }) => {
                backend.sync_target_base(*seq, *len as usize);
            }
            (_, StateOp::Release { seq }) => {
                backend.release(*seq);
                *seqs_live = seqs_live.saturating_sub(1);
            }
            _ => {}
        }
    }
}

/// Cold-path ops (prefill, admit/evict, stats, heartbeat, misroutes):
/// typed decode, response encoded into `out`.
fn serve_cold<B: SdBackend>(
    role: Role,
    rank: u32,
    backend: &mut B,
    seqs_live: &mut u64,
    ops_executed: u64,
    frame: Frame,
    out: &mut Vec<u8>,
) {
    let op = frame.op;
    match frame.subject {
        Subject::PrefillChunk { state_ops, batch } => {
            apply_state_ops(role, backend, seqs_live, &state_ops);
            match backend.prefill(&batch) {
                Ok(cost) => {
                    *seqs_live += batch.len() as u64;
                    let target_lens: Vec<u64> = batch
                        .iter()
                        .map(|(s, _)| backend.target_len(*s) as u64)
                        .collect();
                    let draft_lens: Vec<u64> = batch
                        .iter()
                        .map(|(s, _)| backend.draft_len(*s) as u64)
                        .collect();
                    wire::encode_prefill_done(out, op, &target_lens, &draft_lens, cost);
                }
                Err(e) => error_resp(out, op, &format!("prefill: {e:#}")),
            }
        }
        Subject::AdmitEvict { state_ops } => {
            apply_state_ops(role, backend, seqs_live, &state_ops);
            *out = Frame {
                op,
                subject: Subject::AdmitEvictAck,
            }
            .encode();
        }
        Subject::Heartbeat { nonce } => {
            *out = Frame {
                op,
                subject: Subject::HeartbeatAck { nonce },
            }
            .encode();
        }
        Subject::StatsPull => {
            *out = Frame {
                op,
                subject: Subject::StatsResp(WorkerStats {
                    role: role.as_u8(),
                    rank,
                    vocab: backend.vocab() as u64,
                    ops_executed,
                    seqs_live: *seqs_live,
                }),
            }
            .encode();
        }
        // Responses / unknown-direction frames: echo an error so the
        // coordinator sees misrouting instead of a hang.
        other => error_resp(
            out,
            op,
            &format!("unexpected frame for worker: tag {other:?}"),
        ),
    }
}
