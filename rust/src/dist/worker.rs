//! Worker thread: wraps an [`SdBackend`] replica and serves frames.
//!
//! Every worker holds a *full* backend replica (draft and target
//! simulators both) built from the same factory as its peers, so any
//! cost or token it computes is bit-identical to what the single-process
//! engine would have computed. Roles differ only in which ops the
//! coordinator routes to them and which [`StateOp`]s they apply:
//!
//! * the **draft** worker serves propose and applies
//!   `RollbackDraft`/`SyncBase`/`Release`;
//! * each **verify** rank serves verify and applies
//!   `RollbackTarget`/`Release`.
//!
//! This strict routing is what keeps each replica's state consistent
//! with the subset of the computation it actually runs — e.g. a draft
//! replica never executes verify, so the coordinator pushes the
//! committed base forward with `SyncBase` instead.
//!
//! Retransmit safety: the worker remembers its last `(op, response)`
//! pair and replays the cached response verbatim when the same op id
//! arrives again, so a retried frame never re-executes a compute op
//! (state ops are idempotent, compute ops are not).

use crate::spec::SdBackend;

use super::transport::WorkerEndpoint;
use super::wire::{Frame, StateOp, Subject, WorkerStats};

/// Which half of the speculative loop this worker serves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Draft,
    Verify,
}

impl Role {
    pub fn as_u8(self) -> u8 {
        match self {
            Role::Draft => 0,
            Role::Verify => 1,
        }
    }
}

/// Spawn-time knobs, mostly for fault injection.
#[derive(Debug, Clone, Default)]
pub struct WorkerOptions {
    /// Exit (simulating a crash) after this many *compute* ops have
    /// executed. Responses for the final op are still sent — the death
    /// is detected by the coordinator via the endpoint liveness flag.
    pub die_after_ops: Option<u64>,
}

/// Serve frames until the coordinator hangs up (or `die_after_ops`
/// fires). Runs on its own thread; the endpoint's `Drop` marks the
/// worker dead for the coordinator on any exit path, panics included.
pub fn run_worker<B: SdBackend>(
    role: Role,
    rank: u32,
    mut backend: B,
    ep: WorkerEndpoint,
    opts: WorkerOptions,
) {
    let mut ops_executed: u64 = 0;
    let mut seqs_live: u64 = 0;
    let mut last: Option<(u64, Frame)> = None;

    while let Some(frame) = ep.recv() {
        // Retransmit of the op we just answered: replay the cached
        // response instead of re-executing.
        if let Some((op, resp)) = &last {
            if *op == frame.op {
                if !ep.send(resp) {
                    return;
                }
                continue;
            }
        }

        let is_compute = frame.subject.is_compute();
        let resp_subject = serve(role, &mut backend, &mut seqs_live, frame.subject);
        if is_compute {
            ops_executed += 1;
        }
        let resp_subject = match resp_subject {
            Subject::StatsPull => Subject::StatsResp(WorkerStats {
                role: role.as_u8(),
                rank,
                vocab: backend.vocab() as u64,
                ops_executed,
                seqs_live,
            }),
            s => s,
        };
        let resp = Frame {
            op: frame.op,
            subject: resp_subject,
        };
        if !ep.send(&resp) {
            return;
        }
        last = Some((frame.op, resp));

        if let Some(limit) = opts.die_after_ops {
            if ops_executed >= limit {
                // Simulated crash: the endpoint drops here and the
                // coordinator sees the slot detach.
                return;
            }
        }
    }
}

/// Apply the state ops this role owns, skip the rest. All owned ops are
/// idempotent against already-updated state (rollbacks set/clamp,
/// release tolerates absent sequences), which is what makes retried
/// frames safe to re-apply.
fn apply_state_ops<B: SdBackend>(role: Role, backend: &mut B, seqs_live: &mut u64, ops: &[StateOp]) {
    for op in ops {
        match (role, op) {
            (Role::Verify, StateOp::RollbackTarget { seq, len }) => {
                backend.rollback_target(*seq, *len as usize);
            }
            (Role::Draft, StateOp::RollbackDraft { seq, len }) => {
                backend.rollback_draft(*seq, *len as usize);
            }
            (Role::Draft, StateOp::SyncBase { seq, len }) => {
                backend.sync_target_base(*seq, *len as usize);
            }
            (_, StateOp::Release { seq }) => {
                backend.release(*seq);
                *seqs_live = seqs_live.saturating_sub(1);
            }
            _ => {}
        }
    }
}

fn serve<B: SdBackend>(
    role: Role,
    backend: &mut B,
    seqs_live: &mut u64,
    subject: Subject,
) -> Subject {
    match subject {
        Subject::ProposeReq {
            state_ops,
            seqs,
            pending,
            gammas,
            temps,
            seed,
        } => {
            apply_state_ops(role, backend, seqs_live, &state_ops);
            let gammas: Vec<usize> = gammas.iter().map(|&g| g as usize).collect();
            match backend.propose(&seqs, &pending, &gammas, &temps, seed) {
                Ok(out) => Subject::ProposeResp {
                    tokens: out.tokens,
                    probs: out.probs,
                    draft_lens: seqs.iter().map(|&s| backend.draft_len(s) as u64).collect(),
                    cost: out.cost,
                },
                Err(e) => Subject::ErrorResp {
                    message: format!("propose: {e:#}"),
                },
            }
        }
        Subject::VerifyReq {
            state_ops,
            seqs,
            feed,
            drafts,
            temps,
            budget,
        } => {
            apply_state_ops(role, backend, seqs_live, &state_ops);
            backend.set_verify_budget(budget.map(|b| b as usize));
            match backend.verify(&seqs, &feed, &drafts, &temps) {
                Ok(out) => Subject::VerifyResp {
                    probs: out.probs,
                    target_lens: seqs.iter().map(|&s| backend.target_len(s) as u64).collect(),
                    cost: out.cost,
                },
                Err(e) => Subject::ErrorResp {
                    message: format!("verify: {e:#}"),
                },
            }
        }
        Subject::PrefillChunk { state_ops, batch } => {
            apply_state_ops(role, backend, seqs_live, &state_ops);
            let batch: Vec<(u64, Vec<u32>)> = batch;
            match backend.prefill(&batch) {
                Ok(cost) => {
                    *seqs_live += batch.len() as u64;
                    Subject::PrefillDone {
                        target_lens: batch
                            .iter()
                            .map(|(s, _)| backend.target_len(*s) as u64)
                            .collect(),
                        draft_lens: batch
                            .iter()
                            .map(|(s, _)| backend.draft_len(*s) as u64)
                            .collect(),
                        cost,
                    }
                }
                Err(e) => Subject::ErrorResp {
                    message: format!("prefill: {e:#}"),
                },
            }
        }
        Subject::AdmitEvict { state_ops } => {
            apply_state_ops(role, backend, seqs_live, &state_ops);
            Subject::AdmitEvictAck
        }
        Subject::Heartbeat { nonce } => Subject::HeartbeatAck { nonce },
        // Filled in by the caller with live counters.
        Subject::StatsPull => Subject::StatsPull,
        // Responses / unknown-direction frames: echo an error so the
        // coordinator sees misrouting instead of a hang.
        other => Subject::ErrorResp {
            message: format!("unexpected frame for worker: tag {:?}", other),
        },
    }
}
