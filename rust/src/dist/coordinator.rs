//! Coordinator side of the distributed engine.
//!
//! [`DistBackend`] implements [`SdBackend`] by dispatching each backend
//! op over a [`Transport`] to worker threads, so the *unmodified*
//! `Engine` — scheduler, control plane, KV bookkeeping, both the
//! lock-step and continuous pipelines — drives a distributed fleet
//! simply by being instantiated as `Engine<DistBackend<SyntheticLm>>`.
//! Bit-exactness with the single-process engine is by construction:
//!
//! * every worker holds a *full* backend replica built by the same
//!   factory, so any cost/token computed anywhere equals the
//!   single-process value (roles only partition which state mutations
//!   apply where);
//! * verify is fanned across `d` EP ranks and per-rank costs combine as
//!   `max + fabric hop`, where the loopback fabric's hop is exactly
//!   `0.0` — so `max` over bit-identical values plus zero preserves the
//!   single-process clock bit-for-bit;
//! * all RNG (rejection sampling) stays on the coordinator inside the
//!   engine, consuming [`LogitsView`] rows that round-trip the wire
//!   codec losslessly (`f64` travels as raw bits).
//!
//! Robustness is part of the op contract: every round trip carries a
//! per-op deadline and bounded retries; worker death (detected by the
//! endpoint liveness flag, no joins) triggers a respawn that rebuilds
//! the replica by replaying the coordinator's op log — event-sourced
//! recovery, valid because the backend contract is deterministic. Op ids
//! make retries idempotent (workers replay cached responses; the
//! coordinator discards stale duplicates).

use std::collections::{HashMap, VecDeque};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::hardware::ShardingSpec;
use crate::spec::{ProposeOut, SdBackend, SeqId, VerifyOut};
use crate::util::json::Json;

use super::transport::{
    FaultPlan, FaultyTransport, InProcTransport, Transport, TransportError, WorkerEndpoint,
};
use super::wire::{Frame, StateOp, Subject};
use super::worker::{run_worker, Role, WorkerOptions};

/// Pending draft-side state ops are normally drained by the next
/// propose; AR-only phases (γ=0) never propose, so verify flushes the
/// queue with an explicit [`Subject::AdmitEvict`] once it exceeds this.
const STATE_OP_FLUSH_THRESHOLD: usize = 64;

/// How verify-rank costs combine across the worker fabric.
#[derive(Debug, Clone, PartialEq)]
pub enum DistFabric {
    /// In-process loopback: zero communication cost, so the distributed
    /// clock is bit-identical to single-process. The conformance suite
    /// pins this.
    Loopback,
    /// Price the rank fan-out on a real fabric via
    /// [`ShardingSpec::comm_time`] — the simulator's topology axis and
    /// the worker topology agree by sharing the same pricing.
    Sharded(ShardingSpec),
}

impl DistFabric {
    pub fn hop_cost(&self, tokens: f64) -> f64 {
        match self {
            DistFabric::Loopback => 0.0,
            DistFabric::Sharded(spec) => spec.comm_time(tokens),
        }
    }
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct DistConfig {
    /// Verify EP ranks (worker count is `1 + verify_ranks`).
    pub verify_ranks: usize,
    /// Per-attempt deadline for one op round trip.
    pub deadline: Duration,
    /// Retries per op before escalating to a respawn.
    pub max_retries: u32,
    pub fabric: DistFabric,
    /// Fault injection (tests only): wraps the transport.
    pub faults: Option<FaultPlan>,
    /// Fault injection (tests only): `(role, rank, ops)` — that worker
    /// exits after executing `ops` compute ops. Respawned workers never
    /// inherit a death sentence.
    pub die_after: Vec<(Role, u32, u64)>,
}

impl Default for DistConfig {
    fn default() -> Self {
        DistConfig {
            verify_ranks: 1,
            deadline: Duration::from_secs(5),
            max_retries: 2,
            fabric: DistFabric::Loopback,
            faults: None,
            die_after: Vec::new(),
        }
    }
}

impl DistConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            (1..=64).contains(&self.verify_ranks),
            "dist: verify_ranks must be in 1..=64, got {}",
            self.verify_ranks
        );
        anyhow::ensure!(
            !self.deadline.is_zero(),
            "dist: per-op deadline must be non-zero"
        );
        Ok(())
    }
}

/// Coordinator-side view of one worker, refreshed on every op.
#[derive(Debug, Clone)]
pub struct WorkerHealth {
    pub role: Role,
    pub rank: u32,
    pub alive: bool,
    pub queue_depth: usize,
    /// Compute ops dispatched to this worker (incl. replayed ones).
    pub ops: u64,
    pub retries: u64,
    pub respawns: u64,
    /// Last heartbeat nonce acknowledged (0 = never pinged).
    pub heartbeat: u64,
}

impl WorkerHealth {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            (
                "role",
                match self.role {
                    Role::Draft => "draft".into(),
                    Role::Verify => "verify".into(),
                },
            ),
            ("rank", (self.rank as usize).into()),
            ("alive", self.alive.into()),
            ("queue_depth", self.queue_depth.into()),
            ("ops", (self.ops as usize).into()),
            ("retries", (self.retries as usize).into()),
            ("respawns", (self.respawns as usize).into()),
            ("heartbeat", (self.heartbeat as usize).into()),
        ])
    }
}

/// Snapshot surfaced through `ServerStats` (the `"dist"` key).
#[derive(Debug, Clone)]
pub struct DistStatus {
    pub workers: Vec<WorkerHealth>,
    pub retries: u64,
    pub respawns: u64,
    pub stale_discarded: u64,
    pub wire_errors: u64,
}

impl DistStatus {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            (
                "workers",
                Json::Arr(self.workers.iter().map(WorkerHealth::to_json).collect()),
            ),
            ("retries", (self.retries as usize).into()),
            ("respawns", (self.respawns as usize).into()),
            ("stale_discarded", (self.stale_discarded as usize).into()),
            ("wire_errors", (self.wire_errors as usize).into()),
        ])
    }
}

/// One completed op as remembered for worker recovery. Verify ranks all
/// receive identical subjects, so one entry covers the whole rank fan.
struct LoggedOp {
    to_draft: Option<Subject>,
    to_verify: Option<Subject>,
}

#[derive(Debug, Default)]
struct Counters {
    retries: u64,
    respawns: u64,
    stale_discarded: u64,
    wire_errors: u64,
}

/// The coordinator-resident backend. See the module docs for the
/// design; the field order matters only for `transport`, which must
/// drop first so worker threads see hangup and exit before anything
/// else is torn down.
pub struct DistBackend<B: SdBackend + Send + 'static> {
    transport: Box<dyn Transport>,
    cfg: DistConfig,
    /// Local replica used for pure pricing queries (`reject_cost`,
    /// `prefill_chunk_cost`, `vocab`) that need no worker round trip.
    pricer: B,
    factory: Box<dyn Fn() -> anyhow::Result<B> + Send>,
    handles: Vec<Option<JoinHandle<()>>>,
    health: Vec<WorkerHealth>,
    /// Event log of every completed state-bearing op, replayed into
    /// fresh replicas on respawn. Grows for the life of the backend;
    /// compaction (snapshot + truncate) is a known follow-up.
    oplog: Vec<LoggedOp>,
    pending_draft: Vec<StateOp>,
    pending_verify: Vec<StateOp>,
    /// Coordinator-authoritative (target_len, draft_len) per sequence,
    /// mirrored from worker responses.
    lens: HashMap<SeqId, (usize, usize)>,
    /// Frames received while waiting for a different op (e.g. responses
    /// to the outer op arriving during a respawn replay).
    stash: VecDeque<(usize, Frame)>,
    next_op: u64,
    budget: Option<usize>,
    counters: Counters,
}

impl<B: SdBackend + Send + 'static> DistBackend<B> {
    /// Spawn `1 + verify_ranks` worker threads, each with its own
    /// replica from `factory`, plus a local pricing replica.
    pub fn launch<F>(cfg: DistConfig, factory: F) -> anyhow::Result<Self>
    where
        F: Fn() -> anyhow::Result<B> + Send + 'static,
    {
        cfg.validate()?;
        let n = 1 + cfg.verify_ranks;
        let (inproc, endpoints) = InProcTransport::new(n);
        let transport: Box<dyn Transport> = match &cfg.faults {
            Some(plan) => Box::new(FaultyTransport::new(inproc, plan.clone())),
            None => Box::new(inproc),
        };
        let mut handles = Vec::with_capacity(n);
        let mut health = Vec::with_capacity(n);
        for ep in endpoints {
            let w = ep.index();
            let (role, rank) = Self::slot(w);
            let die = cfg
                .die_after
                .iter()
                .find(|(r, k, _)| *r == role && *k == rank)
                .map(|(_, _, ops)| *ops);
            let backend = factory()?;
            handles.push(Some(Self::spawn(role, rank, backend, ep, die)));
            health.push(WorkerHealth {
                role,
                rank,
                alive: true,
                queue_depth: 0,
                ops: 0,
                retries: 0,
                respawns: 0,
                heartbeat: 0,
            });
        }
        let pricer = factory()?;
        Ok(DistBackend {
            transport,
            cfg,
            pricer,
            factory: Box::new(factory),
            handles,
            health,
            oplog: Vec::new(),
            pending_draft: Vec::new(),
            pending_verify: Vec::new(),
            lens: HashMap::new(),
            stash: VecDeque::new(),
            next_op: 1,
            budget: None,
            counters: Counters::default(),
        })
    }

    /// Worker slot layout: 0 is the draft worker, `1..=d` are verify
    /// EP ranks `0..d`.
    fn slot(w: usize) -> (Role, u32) {
        if w == 0 {
            (Role::Draft, 0)
        } else {
            (Role::Verify, (w - 1) as u32)
        }
    }

    fn spawn(
        role: Role,
        rank: u32,
        backend: B,
        ep: WorkerEndpoint,
        die_after_ops: Option<u64>,
    ) -> JoinHandle<()> {
        std::thread::spawn(move || {
            run_worker(role, rank, backend, ep, WorkerOptions { die_after_ops })
        })
    }

    fn verify_workers(&self) -> std::ops::RangeInclusive<usize> {
        1..=self.cfg.verify_ranks
    }

    /// Liveness ping: round-trips a heartbeat through every worker and
    /// records the acknowledged nonce in the health table.
    pub fn ping(&mut self) -> anyhow::Result<()> {
        let nonce = self.next_op;
        let targets: Vec<usize> = (0..self.transport.workers()).collect();
        let subjects: Vec<Subject> = targets
            .iter()
            .map(|_| Subject::Heartbeat { nonce })
            .collect();
        let resps = self.rpc(&targets, subjects)?;
        for (i, resp) in resps.into_iter().enumerate() {
            if let Subject::HeartbeatAck { nonce } = resp {
                self.health[targets[i]].heartbeat = nonce;
            }
        }
        Ok(())
    }

    /// Health/robustness snapshot for `ServerStats`.
    pub fn status(&self) -> DistStatus {
        let mut workers = self.health.clone();
        for (w, h) in workers.iter_mut().enumerate() {
            h.alive = self.transport.is_attached(w);
            h.queue_depth = self.transport.queue_depth(w);
        }
        DistStatus {
            workers,
            retries: self.counters.retries,
            respawns: self.counters.respawns,
            stale_discarded: self.counters.stale_discarded,
            wire_errors: self.counters.wire_errors,
        }
    }

    /// Dispatch `subjects[i]` to `targets[i]` under one op id and wait
    /// for every response, enforcing the per-op deadline, bounded
    /// retries, respawn-on-death, and stale-duplicate discard.
    fn rpc(&mut self, targets: &[usize], subjects: Vec<Subject>) -> anyhow::Result<Vec<Subject>> {
        debug_assert_eq!(targets.len(), subjects.len());
        let op = self.next_op;
        self.next_op += 1;

        let mut results: Vec<Option<Subject>> = vec![None; targets.len()];
        let mut attempts: Vec<u32> = vec![0; targets.len()];
        let mut respawned: Vec<bool> = vec![false; targets.len()];

        for (i, &w) in targets.iter().enumerate() {
            self.send_or_respawn(w, op, &subjects[i], &mut respawned[i])?;
        }

        while results.iter().any(Option::is_none) {
            // Drain the stash first: frames for this op that arrived
            // while a respawn replay owned the receive loop.
            let mut matched = None;
            while let Some((w, frame)) = self.stash.pop_front() {
                if frame.op == op {
                    matched = Some((w, frame));
                    break;
                }
                self.counters.stale_discarded += 1;
            }
            let (w, frame) = match matched {
                Some(hit) => hit,
                None => match self.transport.recv_timeout(self.cfg.deadline) {
                    Ok(got) => got,
                    Err(TransportError::Timeout) => {
                        self.handle_timeout(op, targets, &subjects, &results, &mut attempts, &mut respawned)?;
                        continue;
                    }
                    Err(TransportError::Wire(_)) => {
                        self.counters.wire_errors += 1;
                        continue;
                    }
                    Err(TransportError::Closed) => {
                        anyhow::bail!("dist: coordinator upstream channel closed")
                    }
                },
            };
            let slot = targets
                .iter()
                .position(|&t| t == w)
                .filter(|&i| results[i].is_none());
            match slot {
                Some(i) if frame.op == op => {
                    if let Subject::ErrorResp { message } = &frame.subject {
                        // Deterministic backend failure: remember the op
                        // (replicas that executed it must replay it on
                        // respawn) and surface the error — no retry.
                        self.log_op(targets, &subjects);
                        anyhow::bail!("dist: worker {w} failed op {op}: {message}");
                    }
                    results[i] = Some(frame.subject);
                    self.health[w].ops += u64::from(subjects[i].is_compute());
                }
                _ => {
                    // Wrong op id, unexpected worker, or a duplicate of
                    // an already-satisfied slot (e.g. the late copy of a
                    // delayed-then-retried response).
                    self.counters.stale_discarded += 1;
                }
            }
        }

        self.log_op(targets, &subjects);
        Ok(results.into_iter().map(Option::unwrap).collect())
    }

    /// One deadline expiry: for every unsatisfied target, either retry,
    /// respawn a dead/wedged worker, or give up.
    #[allow(clippy::too_many_arguments)]
    fn handle_timeout(
        &mut self,
        op: u64,
        targets: &[usize],
        subjects: &[Subject],
        results: &[Option<Subject>],
        attempts: &mut [u32],
        respawned: &mut [bool],
    ) -> anyhow::Result<()> {
        for (i, &w) in targets.iter().enumerate() {
            if results[i].is_some() {
                continue;
            }
            if !self.transport.is_attached(w) {
                // Worker died mid-op: respawn (replaying the log), then
                // re-dispatch this op. A second death on the same op is
                // a hard failure.
                anyhow::ensure!(
                    !respawned[i],
                    "dist: worker {w} died twice during op {op}"
                );
                self.respawn(w)?;
                respawned[i] = true;
                attempts[i] = 0;
                self.send(w, op, &subjects[i])?;
            } else if attempts[i] < self.cfg.max_retries {
                attempts[i] += 1;
                self.counters.retries += 1;
                self.health[w].retries += 1;
                self.send(w, op, &subjects[i])?;
            } else if !respawned[i] {
                // Retries exhausted against a live worker: treat it as
                // wedged. Reattach orphans the old endpoint (its queue
                // channel closes, so the zombie thread exits on its next
                // recv) and the replica is rebuilt from the log.
                self.respawn(w)?;
                respawned[i] = true;
                attempts[i] = 0;
                self.send(w, op, &subjects[i])?;
            } else {
                anyhow::bail!(
                    "dist: op {op} to worker {w} exceeded per-op deadline \
                     ({:?} x {} retries, 1 respawn)",
                    self.cfg.deadline,
                    self.cfg.max_retries
                );
            }
        }
        Ok(())
    }

    fn send(&mut self, w: usize, op: u64, subject: &Subject) -> anyhow::Result<()> {
        let frame = Frame {
            op,
            subject: subject.clone(),
        };
        match self.transport.send(w, &frame) {
            Ok(()) => Ok(()),
            Err(TransportError::Closed) => anyhow::bail!("dist: worker {w} channel closed"),
            Err(e) => anyhow::bail!("dist: send to worker {w} failed: {e}"),
        }
    }

    fn send_or_respawn(
        &mut self,
        w: usize,
        op: u64,
        subject: &Subject,
        respawned: &mut bool,
    ) -> anyhow::Result<()> {
        let frame = Frame {
            op,
            subject: subject.clone(),
        };
        match self.transport.send(w, &frame) {
            Ok(()) => Ok(()),
            Err(TransportError::Closed) => {
                self.respawn(w)?;
                *respawned = true;
                self.send(w, op, subject)
            }
            Err(e) => anyhow::bail!("dist: send to worker {w} failed: {e}"),
        }
    }

    /// Remember a completed state-bearing op for replica recovery.
    /// Verify ranks receive identical subjects, so the first verify
    /// target's subject stands for the whole fan.
    fn log_op(&mut self, targets: &[usize], subjects: &[Subject]) {
        let mut to_draft = None;
        let mut to_verify = None;
        for (i, &w) in targets.iter().enumerate() {
            let state_bearing = subjects[i].is_compute()
                || matches!(subjects[i], Subject::AdmitEvict { .. });
            if !state_bearing {
                continue;
            }
            if w == 0 {
                to_draft = Some(subjects[i].clone());
            } else if to_verify.is_none() {
                to_verify = Some(subjects[i].clone());
            }
        }
        if to_draft.is_some() || to_verify.is_some() {
            self.oplog.push(LoggedOp { to_draft, to_verify });
        }
    }

    /// Replace a dead or wedged worker: detach the old thread handle
    /// (never join — it may be wedged), reattach the transport slot,
    /// build a fresh replica, and replay the op log so its state
    /// reconverges with its peers. Determinism of the backend contract
    /// makes the replayed replica bit-identical to the lost one.
    fn respawn(&mut self, w: usize) -> anyhow::Result<()> {
        self.counters.respawns += 1;
        self.health[w].respawns += 1;
        drop(self.handles[w].take());
        let ep = self.transport.reattach(w);
        let (role, rank) = Self::slot(w);
        let backend = (self.factory)()?;
        self.handles[w] = Some(Self::spawn(role, rank, backend, ep, None));
        self.replay(w, role)
    }

    fn replay(&mut self, w: usize, role: Role) -> anyhow::Result<()> {
        // Clone the routed subjects up front: replay sends through the
        // same transport and must not alias the log.
        let subjects: Vec<Subject> = self
            .oplog
            .iter()
            .filter_map(|entry| match role {
                Role::Draft => entry.to_draft.clone(),
                Role::Verify => entry.to_verify.clone(),
            })
            .collect();
        for subject in subjects {
            let op = self.next_op;
            self.next_op += 1;
            self.send(w, op, &subject)?;
            self.health[w].ops += u64::from(subject.is_compute());
            // Await this replay step's response; stash anything else
            // (e.g. outer-op responses from other workers) for the
            // interrupted rpc to consume.
            let mut attempts = 0u32;
            loop {
                match self.transport.recv_timeout(self.cfg.deadline) {
                    Ok((from, frame)) if from == w && frame.op == op => {
                        // ErrorResp included: if the original op failed
                        // deterministically, the replay fails the same
                        // way and state still reconverges.
                        break;
                    }
                    Ok(other) => {
                        self.stash.push_back(other);
                    }
                    Err(TransportError::Timeout) => {
                        anyhow::ensure!(
                            attempts < self.cfg.max_retries,
                            "dist: replay op {op} to worker {w} timed out"
                        );
                        attempts += 1;
                        self.counters.retries += 1;
                        self.send(w, op, &subject)?;
                    }
                    Err(TransportError::Wire(_)) => {
                        self.counters.wire_errors += 1;
                    }
                    Err(TransportError::Closed) => {
                        anyhow::bail!("dist: upstream closed during replay")
                    }
                }
            }
        }
        Ok(())
    }

    fn drain_draft_ops(&mut self) -> Vec<StateOp> {
        std::mem::take(&mut self.pending_draft)
    }

    fn drain_verify_ops(&mut self) -> Vec<StateOp> {
        std::mem::take(&mut self.pending_verify)
    }

    fn lens_mut(&mut self, seq: SeqId) -> &mut (usize, usize) {
        self.lens.get_mut(&seq).expect("unknown sequence")
    }
}

impl<B: SdBackend + Send + 'static> SdBackend for DistBackend<B> {
    fn vocab(&self) -> usize {
        self.pricer.vocab()
    }

    fn prefill(&mut self, batch: &[(SeqId, Vec<u32>)]) -> anyhow::Result<f64> {
        // Every replica needs the new sequences registered; piggyback
        // each role's pending state ops on its copy.
        let draft_subject = Subject::PrefillChunk {
            state_ops: self.drain_draft_ops(),
            batch: batch.to_vec(),
        };
        let verify_subject = Subject::PrefillChunk {
            state_ops: self.drain_verify_ops(),
            batch: batch.to_vec(),
        };
        let mut targets = vec![0usize];
        let mut subjects = vec![draft_subject];
        for w in self.verify_workers() {
            targets.push(w);
            subjects.push(verify_subject.clone());
        }
        let resps = self.rpc(&targets, subjects)?;
        let mut cost = f64::NEG_INFINITY;
        let mut lens_from_verify: Option<(Vec<u64>, Vec<u64>)> = None;
        let mut draft_lens_from_draft: Option<Vec<u64>> = None;
        for (i, resp) in resps.into_iter().enumerate() {
            match resp {
                Subject::PrefillDone {
                    target_lens,
                    draft_lens,
                    cost: c,
                } => {
                    cost = cost.max(c);
                    if targets[i] == 0 {
                        draft_lens_from_draft = Some(draft_lens);
                    } else if lens_from_verify.is_none() {
                        lens_from_verify = Some((target_lens, draft_lens));
                    }
                }
                other => anyhow::bail!("dist: unexpected prefill response {other:?}"),
            }
        }
        let (target_lens, _) =
            lens_from_verify.ok_or_else(|| anyhow::anyhow!("dist: no verify prefill response"))?;
        let draft_lens = draft_lens_from_draft
            .ok_or_else(|| anyhow::anyhow!("dist: no draft prefill response"))?;
        for (i, (seq, _)) in batch.iter().enumerate() {
            self.lens
                .insert(*seq, (target_lens[i] as usize, draft_lens[i] as usize));
        }
        Ok(cost)
    }

    fn prefill_chunk_cost(&self, tokens: usize, ctx: usize) -> f64 {
        self.pricer.prefill_chunk_cost(tokens, ctx)
    }

    fn prefill_chunks_cost(&self, parts: &[(usize, usize)]) -> f64 {
        self.pricer.prefill_chunks_cost(parts)
    }

    fn propose(
        &mut self,
        seqs: &[SeqId],
        pending: &[Vec<u32>],
        gammas: &[usize],
        temps: &[f64],
        seed: u64,
    ) -> anyhow::Result<ProposeOut> {
        let subject = Subject::ProposeReq {
            state_ops: self.drain_draft_ops(),
            seqs: seqs.to_vec(),
            pending: pending.to_vec(),
            gammas: gammas.iter().map(|&g| g as u32).collect(),
            temps: temps.to_vec(),
            seed,
        };
        let resps = self.rpc(&[0], vec![subject])?;
        match resps.into_iter().next() {
            Some(Subject::ProposeResp {
                tokens,
                probs,
                draft_lens,
                cost,
            }) => {
                for (i, seq) in seqs.iter().enumerate() {
                    self.lens_mut(*seq).1 = draft_lens[i] as usize;
                }
                Ok(ProposeOut {
                    tokens,
                    probs,
                    cost,
                })
            }
            other => anyhow::bail!("dist: unexpected propose response {other:?}"),
        }
    }

    fn verify(
        &mut self,
        seqs: &[SeqId],
        feed: &[u32],
        drafts: &[Vec<u32>],
        temps: &[f64],
    ) -> anyhow::Result<VerifyOut> {
        // AR-only phases never propose, so the draft-side queue is
        // flushed here once it builds up (stays bounded either way).
        if self.pending_draft.len() >= STATE_OP_FLUSH_THRESHOLD {
            let subject = Subject::AdmitEvict {
                state_ops: self.drain_draft_ops(),
            };
            self.rpc(&[0], vec![subject])?;
        }
        let subject = Subject::VerifyReq {
            state_ops: self.drain_verify_ops(),
            seqs: seqs.to_vec(),
            feed: feed.to_vec(),
            drafts: drafts.to_vec(),
            temps: temps.to_vec(),
            budget: self.budget.map(|b| b as u64),
        };
        let targets: Vec<usize> = self.verify_workers().collect();
        let subjects: Vec<Subject> = targets.iter().map(|_| subject.clone()).collect();
        let resps = self.rpc(&targets, subjects)?;
        // Per-rank costs combine as max (ranks run concurrently) plus
        // the fabric hop for the fan-out of this round's token payload.
        // Replicas are bit-identical so max() returns the exact
        // single-process cost; Loopback's hop is exactly 0.0.
        let mut out: Option<VerifyOut> = None;
        let mut max_cost = f64::NEG_INFINITY;
        for resp in resps {
            match resp {
                Subject::VerifyResp {
                    probs,
                    target_lens,
                    cost,
                } => {
                    max_cost = max_cost.max(cost);
                    if out.is_none() {
                        for (i, seq) in seqs.iter().enumerate() {
                            self.lens_mut(*seq).0 = target_lens[i] as usize;
                        }
                        out = Some(VerifyOut { probs, cost });
                    }
                }
                other => anyhow::bail!("dist: unexpected verify response {other:?}"),
            }
        }
        let mut out = out.ok_or_else(|| anyhow::anyhow!("dist: no verify response"))?;
        let round_tokens: f64 = drafts.iter().map(|d| (d.len() + 1) as f64).sum();
        out.cost = max_cost + self.cfg.fabric.hop_cost(round_tokens);
        Ok(out)
    }

    fn rollback_target(&mut self, seq: SeqId, len: usize) {
        if let Some(l) = self.lens.get_mut(&seq) {
            l.0 = len;
        }
        self.pending_verify.push(StateOp::RollbackTarget {
            seq,
            len: len as u64,
        });
        // The draft replica never runs verify, so its committed base
        // only moves when the coordinator pushes it.
        self.pending_draft.push(StateOp::SyncBase {
            seq,
            len: len as u64,
        });
    }

    fn rollback_draft(&mut self, seq: SeqId, len: usize) {
        if let Some(l) = self.lens.get_mut(&seq) {
            l.1 = l.1.min(len);
        }
        self.pending_draft.push(StateOp::RollbackDraft {
            seq,
            len: len as u64,
        });
    }

    fn target_len(&self, seq: SeqId) -> usize {
        self.lens.get(&seq).expect("unknown sequence").0
    }

    fn draft_len(&self, seq: SeqId) -> usize {
        self.lens.get(&seq).expect("unknown sequence").1
    }

    fn release(&mut self, seq: SeqId) {
        self.lens.remove(&seq);
        self.pending_draft.push(StateOp::Release { seq });
        self.pending_verify.push(StateOp::Release { seq });
    }

    fn reject_cost(&self, gammas: &[usize]) -> f64 {
        self.pricer.reject_cost(gammas)
    }

    fn set_verify_budget(&mut self, budget: Option<usize>) {
        self.budget = budget;
        self.pricer.set_verify_budget(budget);
    }

    fn verify_budget(&self) -> Option<usize> {
        self.budget
    }

    fn dist_status(&self) -> Option<DistStatus> {
        Some(self.status())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::Topology;

    #[test]
    fn loopback_hop_is_exactly_zero() {
        assert_eq!(DistFabric::Loopback.hop_cost(1e9).to_bits(), 0.0f64.to_bits());
    }

    #[test]
    fn sharded_hop_matches_comm_time() {
        let spec = ShardingSpec::new(Topology::nvlink(4));
        let fabric = DistFabric::Sharded(spec.clone());
        for tokens in [1.0, 16.0, 4096.0] {
            assert_eq!(
                fabric.hop_cost(tokens).to_bits(),
                spec.comm_time(tokens).to_bits()
            );
        }
    }

    #[test]
    fn config_validation() {
        assert!(DistConfig::default().validate().is_ok());
        let bad = DistConfig {
            verify_ranks: 0,
            ..DistConfig::default()
        };
        assert!(bad.validate().is_err());
        let bad = DistConfig {
            verify_ranks: 65,
            ..DistConfig::default()
        };
        assert!(bad.validate().is_err());
    }
}
