//! Coordinator side of the distributed engine.
//!
//! [`DistBackend`] implements [`SdBackend`] by dispatching each backend
//! op over a [`Transport`] to worker threads, so the *unmodified*
//! `Engine` — scheduler, control plane, KV bookkeeping, both the
//! lock-step and continuous pipelines — drives a distributed fleet
//! simply by being instantiated as `Engine<DistBackend<SyntheticLm>>`.
//! Bit-exactness with the single-process engine is by construction:
//!
//! * every worker holds a *full* backend replica built by the same
//!   factory, so any cost/token computed anywhere equals the
//!   single-process value (roles only partition which state mutations
//!   apply where);
//! * verify is fanned across `d` EP ranks and per-rank costs combine as
//!   `max + fabric hop`, where the loopback fabric's hop is exactly
//!   `0.0` — so `max` over bit-identical values plus zero preserves the
//!   single-process clock bit-for-bit;
//! * propose is striped across `draft_ranks` replicas by home rank
//!   `seq % N` with a per-rank seed derivative; rank 0 receives the
//!   verbatim engine seed, so `N = 1` (the default) is byte-identical
//!   to the single-process propose call;
//! * all RNG (rejection sampling) stays on the coordinator inside the
//!   engine, consuming [`LogitsView`] rows that round-trip the wire
//!   codec losslessly (`f64` travels as raw bits).
//!
//! Hot-path shape (the PR-10 overhaul):
//!
//! * **Zero-copy requests** — each op is encoded exactly once, straight
//!   from engine-native slices into a pooled buffer, and the resulting
//!   `Arc<Vec<u8>>` is shared by the wire send, any retransmit, and the
//!   op log. No `Subject` is materialized and no batch is cloned on
//!   the request path.
//! * **Pipelining** — ops that do not produce a result the engine is
//!   waiting for (verify fan stragglers past the first response,
//!   prefill fan stragglers, admit/evict flushes) stay *in flight* and
//!   complete out of order, matched by op id, while the engine's next
//!   op is already on the wire. This is how the next round's propose
//!   overlaps the current verify fan: the engine prices the pair as
//!   `max(draft, verify)` (see `engine/continuous.rs`) and the
//!   transport no longer serializes them. Because every op is still
//!   *dispatched* in program order over FIFO links and replicas are
//!   deterministic, pipelining changes no computed value — `pipeline:
//!   false` (drain after every op) is bit-identical and the
//!   conformance suite pins it.
//! * **Op-log compaction** — the recovery log is periodically replaced
//!   by a state snapshot synthesized from the coordinator's committed
//!   token mirror, so respawn replay is `O(live state + window)` rather
//!   than `O(lifetime ops)` and coordinator memory stays bounded.
//!
//! Robustness is part of the op contract: every round trip carries a
//! per-op deadline and bounded retries; worker death (detected by the
//! endpoint liveness flag, no joins) triggers a respawn that rebuilds
//! the replica by replaying snapshot + log — event-sourced recovery,
//! valid because the backend contract is deterministic. Op ids make
//! retries idempotent (workers replay cached responses from a
//! [`REPLAY_RING`]-deep ring; the coordinator discards stale
//! duplicates). Failures of in-flight ops cannot surface mid-engine
//! -step, so they are deferred and raised at the next backend call.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::hardware::ShardingSpec;
use crate::kvcache::SeqId;
use crate::spec::{LogitsView, ProposeOut, SdBackend, VerifyOut};
use crate::util::json::Json;

use super::transport::{
    FaultPlan, FaultyTransport, InProcTransport, Transport, TransportError, WorkerEndpoint,
};
use super::wire::{self, Frame, StateOp, Subject};
use super::worker::{run_worker, Role, WorkerOptions, REPLAY_RING};

/// Pending draft-side state ops are normally drained by the next
/// propose; AR-only phases (γ=0) never propose, so verify flushes the
/// queue with an explicit [`Subject::AdmitEvict`] once it exceeds this.
const STATE_OP_FLUSH_THRESHOLD: usize = 64;

/// Sequences per synthesized `PrefillChunk` when compaction snapshots
/// live state (keeps each snapshot frame well under `MAX_FRAME_BYTES`).
const SNAPSHOT_CHUNK: usize = 256;

/// Retired request buffers kept for reuse by the encoder pool.
const POOL_CAP: usize = 64;

/// Per-rank derivative of the engine's propose seed. Rank 0 is the
/// *identity* — a single draft rank sees exactly the single-process
/// seed, which is what makes `draft_ranks = 1` bit-exact. Higher ranks
/// decorrelate with a splitmix-style odd multiplier.
pub fn stripe_seed(seed: u64, rank: usize) -> u64 {
    seed ^ (rank as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// How verify-rank costs combine across the worker fabric.
#[derive(Debug, Clone, PartialEq)]
pub enum DistFabric {
    /// In-process loopback: zero communication cost, so the distributed
    /// clock is bit-identical to single-process. The conformance suite
    /// pins this.
    Loopback,
    /// Price the rank fan-out on a real fabric via
    /// [`ShardingSpec::comm_time`] — the simulator's topology axis and
    /// the worker topology agree by sharing the same pricing.
    Sharded(ShardingSpec),
}

impl DistFabric {
    pub fn hop_cost(&self, tokens: f64) -> f64 {
        match self {
            DistFabric::Loopback => 0.0,
            DistFabric::Sharded(spec) => spec.comm_time(tokens),
        }
    }
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct DistConfig {
    /// Verify EP ranks (worker count is `draft_ranks + verify_ranks`).
    pub verify_ranks: usize,
    /// Draft replicas the propose path stripes across (`--draft-workers`).
    /// `1` (the default) is byte-identical to the single-process draft.
    pub draft_ranks: usize,
    /// Allow out-of-order completion of non-result-bearing ops. `false`
    /// drains after every op (bit-identical; useful for debugging).
    pub pipeline: bool,
    /// In-flight op cap before the coordinator stops and drains. Must
    /// stay within the workers' [`REPLAY_RING`] so a retransmit of any
    /// outstanding op still hits the dedup ring instead of re-executing.
    pub max_in_flight: usize,
    /// Compact the recovery log (snapshot + truncate) once it holds
    /// this many ops. `0` disables compaction (the log then grows for
    /// the backend's lifetime, as in PR 9).
    pub oplog_window: usize,
    /// Per-attempt deadline for one op round trip.
    pub deadline: Duration,
    /// Retries per op before escalating to a respawn.
    pub max_retries: u32,
    pub fabric: DistFabric,
    /// Fault injection (tests only): wraps the transport.
    pub faults: Option<FaultPlan>,
    /// Fault injection (tests only): `(role, rank, ops)` — that worker
    /// exits after executing `ops` compute ops. Respawned workers never
    /// inherit a death sentence.
    pub die_after: Vec<(Role, u32, u64)>,
}

impl Default for DistConfig {
    fn default() -> Self {
        DistConfig {
            verify_ranks: 1,
            draft_ranks: 1,
            pipeline: true,
            max_in_flight: 8,
            oplog_window: 512,
            deadline: Duration::from_secs(5),
            max_retries: 2,
            fabric: DistFabric::Loopback,
            faults: None,
            die_after: Vec::new(),
        }
    }
}

impl DistConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            (1..=64).contains(&self.verify_ranks),
            "dist: verify_ranks must be in 1..=64, got {}",
            self.verify_ranks
        );
        anyhow::ensure!(
            (1..=16).contains(&self.draft_ranks),
            "dist: draft_ranks must be in 1..=16, got {}",
            self.draft_ranks
        );
        anyhow::ensure!(
            (1..=REPLAY_RING).contains(&self.max_in_flight),
            "dist: max_in_flight must be in 1..={REPLAY_RING} \
             (the worker retransmit-dedup ring), got {}",
            self.max_in_flight
        );
        anyhow::ensure!(
            !self.deadline.is_zero(),
            "dist: per-op deadline must be non-zero"
        );
        Ok(())
    }
}

/// Coordinator-side view of one worker, refreshed on every op.
#[derive(Debug, Clone)]
pub struct WorkerHealth {
    pub role: Role,
    pub rank: u32,
    pub alive: bool,
    pub queue_depth: usize,
    /// Compute ops dispatched to this worker (incl. replayed ones).
    pub ops: u64,
    pub retries: u64,
    pub respawns: u64,
    /// Last heartbeat nonce acknowledged (0 = never pinged).
    pub heartbeat: u64,
}

impl WorkerHealth {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            (
                "role",
                match self.role {
                    Role::Draft => "draft".into(),
                    Role::Verify => "verify".into(),
                },
            ),
            ("rank", (self.rank as usize).into()),
            ("alive", self.alive.into()),
            ("queue_depth", self.queue_depth.into()),
            ("ops", (self.ops as usize).into()),
            ("retries", (self.retries as usize).into()),
            ("respawns", (self.respawns as usize).into()),
            ("heartbeat", (self.heartbeat as usize).into()),
        ])
    }
}

/// Snapshot surfaced through `ServerStats` (the `"dist"` key).
#[derive(Debug, Clone)]
pub struct DistStatus {
    pub workers: Vec<WorkerHealth>,
    pub retries: u64,
    pub respawns: u64,
    pub stale_discarded: u64,
    pub wire_errors: u64,
    /// Ops currently awaiting out-of-order completion.
    pub in_flight: usize,
    /// Responses consumed out-of-band (while a later op was current).
    pub pipelined: u64,
    /// Recovery-log length (ops since the last snapshot).
    pub oplog_len: usize,
    /// Compactions performed.
    pub snapshots: u64,
    /// Ops retired from the log by compaction over the lifetime.
    pub compacted_ops: u64,
    /// Frames re-sent into respawned replicas (replay volume).
    pub replayed_ops: u64,
}

impl DistStatus {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            (
                "workers",
                Json::Arr(self.workers.iter().map(WorkerHealth::to_json).collect()),
            ),
            ("retries", (self.retries as usize).into()),
            ("respawns", (self.respawns as usize).into()),
            ("stale_discarded", (self.stale_discarded as usize).into()),
            ("wire_errors", (self.wire_errors as usize).into()),
            ("in_flight", self.in_flight.into()),
            ("pipelined", (self.pipelined as usize).into()),
            ("oplog_len", self.oplog_len.into()),
            ("snapshots", (self.snapshots as usize).into()),
            ("compacted_ops", (self.compacted_ops as usize).into()),
            ("replayed_ops", (self.replayed_ops as usize).into()),
        ])
    }
}

/// One completed op as remembered for worker recovery: the encoded
/// request bytes themselves, per draft rank (stripes differ) and once
/// for the verify fan (ranks receive identical frames). The `Arc`s are
/// the very buffers that went over the wire — logging costs no copy.
struct LoggedOp {
    draft: Vec<Option<Arc<Vec<u8>>>>,
    verify: Option<Arc<Vec<u8>>>,
}

/// One dispatched-but-unanswered target of an in-flight op.
struct PendTarget {
    w: usize,
    frame: Arc<Vec<u8>>,
    attempts: u32,
}

/// An op whose remaining targets complete out of order. Invariant: the
/// op's [`LoggedOp`] entry is already in the log (registration happens
/// after logging), so a respawn's replay always covers it.
struct Pending {
    targets: Vec<PendTarget>,
}

/// Coordinator-side mirror of one sequence's committed token stream,
/// maintained so compaction can synthesize prefill snapshots. The dirty
/// flags mark "a compute op has run whose state rollback has not yet
/// been issued" — compaction only cuts at fully-clean points, where
/// replica state is a pure function of the mirror.
struct SeqMirror {
    /// Committed tokens (`content.len() == target_len` at clean points).
    /// Token *values* only matter to content-addressed backends; the
    /// synthetic backend's state is length-determined and the
    /// conformance suite pins the reconstruction.
    content: Vec<u32>,
    draft_dirty: bool,
    target_dirty: bool,
}

#[derive(Debug, Default)]
struct Counters {
    retries: u64,
    respawns: u64,
    stale_discarded: u64,
    wire_errors: u64,
    pipelined: u64,
    snapshots: u64,
    compacted_ops: u64,
    replayed_ops: u64,
}

/// Completion requirement of one dispatch fan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Quorum {
    /// Every target's response carries needed data (propose stripes,
    /// heartbeats).
    All,
    /// Replicas are bit-identical, so the first response *is* the
    /// result (`max` over equal costs); the rest are acks that may
    /// trail as in-flight stragglers.
    First,
}

/// The coordinator-resident backend. See the module docs for the
/// design; the field order matters only for `transport`, which must
/// drop first so worker threads see hangup and exit before anything
/// else is torn down.
pub struct DistBackend<B: SdBackend + Send + 'static> {
    transport: Box<dyn Transport>,
    cfg: DistConfig,
    /// Local replica used for pure pricing queries (`reject_cost`,
    /// `prefill_chunk_cost`, `vocab`) that need no worker round trip.
    pricer: B,
    factory: Box<dyn Fn() -> anyhow::Result<B> + Send>,
    handles: Vec<Option<JoinHandle<()>>>,
    health: Vec<WorkerHealth>,
    /// Recovery log since the last snapshot; bounded by `oplog_window`
    /// (plus the in-progress round) when compaction is enabled.
    oplog: Vec<LoggedOp>,
    /// Synthesized state snapshot replayed before `oplog` on respawn.
    snapshot: Vec<LoggedOp>,
    /// Out-of-order completions keyed by op id.
    in_flight: HashMap<u64, Pending>,
    pending_draft: Vec<StateOp>,
    pending_verify: Vec<StateOp>,
    /// Coordinator-authoritative (target_len, draft_len) per sequence,
    /// mirrored from worker responses.
    lens: HashMap<SeqId, (usize, usize)>,
    /// Committed-stream mirror feeding compaction snapshots.
    mirror: HashMap<SeqId, SeqMirror>,
    /// Frames received while waiting for a different op (e.g. responses
    /// to the outer op arriving during a respawn replay).
    stash: VecDeque<(usize, Frame)>,
    /// Failure of an in-flight op, surfaced at the next backend call.
    deferred_error: Option<String>,
    /// Retired request buffers for encoder reuse (refilled when
    /// compaction retires log entries whose `Arc` became unique).
    pool: Vec<Vec<u8>>,
    next_op: u64,
    budget: Option<usize>,
    counters: Counters,
}

impl<B: SdBackend + Send + 'static> DistBackend<B> {
    /// Spawn `draft_ranks + verify_ranks` worker threads, each with its
    /// own replica from `factory`, plus a local pricing replica.
    pub fn launch<F>(cfg: DistConfig, factory: F) -> anyhow::Result<Self>
    where
        F: Fn() -> anyhow::Result<B> + Send + 'static,
    {
        cfg.validate()?;
        let n = cfg.draft_ranks + cfg.verify_ranks;
        let (inproc, endpoints) = InProcTransport::new(n);
        let transport: Box<dyn Transport> = match &cfg.faults {
            Some(plan) => Box::new(FaultyTransport::new(inproc, plan.clone())),
            None => Box::new(inproc),
        };
        let mut handles = Vec::with_capacity(n);
        let mut health = Vec::with_capacity(n);
        for ep in endpoints {
            let w = ep.index();
            let (role, rank) = Self::slot_of(cfg.draft_ranks, w);
            let die = cfg
                .die_after
                .iter()
                .find(|(r, k, _)| *r == role && *k == rank)
                .map(|(_, _, ops)| *ops);
            let backend = factory()?;
            handles.push(Some(Self::spawn(role, rank, backend, ep, die)));
            health.push(WorkerHealth {
                role,
                rank,
                alive: true,
                queue_depth: 0,
                ops: 0,
                retries: 0,
                respawns: 0,
                heartbeat: 0,
            });
        }
        let pricer = factory()?;
        Ok(DistBackend {
            transport,
            cfg,
            pricer,
            factory: Box::new(factory),
            handles,
            health,
            oplog: Vec::new(),
            snapshot: Vec::new(),
            in_flight: HashMap::new(),
            pending_draft: Vec::new(),
            pending_verify: Vec::new(),
            lens: HashMap::new(),
            mirror: HashMap::new(),
            stash: VecDeque::new(),
            deferred_error: None,
            pool: Vec::new(),
            next_op: 1,
            budget: None,
            counters: Counters::default(),
        })
    }

    /// Worker slot layout: `0..draft_ranks` are draft ranks, the rest
    /// are verify EP ranks.
    fn slot_of(draft_ranks: usize, w: usize) -> (Role, u32) {
        if w < draft_ranks {
            (Role::Draft, w as u32)
        } else {
            (Role::Verify, (w - draft_ranks) as u32)
        }
    }

    fn slot(&self, w: usize) -> (Role, u32) {
        Self::slot_of(self.cfg.draft_ranks, w)
    }

    fn spawn(
        role: Role,
        rank: u32,
        backend: B,
        ep: WorkerEndpoint,
        die_after_ops: Option<u64>,
    ) -> JoinHandle<()> {
        std::thread::spawn(move || {
            run_worker(role, rank, backend, ep, WorkerOptions { die_after_ops })
        })
    }

    fn draft_workers(&self) -> std::ops::Range<usize> {
        0..self.cfg.draft_ranks
    }

    fn verify_workers(&self) -> std::ops::Range<usize> {
        self.cfg.draft_ranks..self.cfg.draft_ranks + self.cfg.verify_ranks
    }

    fn alloc_op(&mut self) -> u64 {
        let op = self.next_op;
        self.next_op += 1;
        op
    }

    /// Grab a retired request buffer (or a fresh one) for the encoder.
    fn take_buf(&mut self) -> Vec<u8> {
        self.pool.pop().unwrap_or_default()
    }

    /// Return a retired log entry's buffers to the pool where the `Arc`
    /// is no longer shared.
    fn recycle_entry(&mut self, entry: LoggedOp) {
        for arc in entry.draft.into_iter().flatten().chain(entry.verify) {
            if self.pool.len() >= POOL_CAP {
                return;
            }
            if let Ok(mut buf) = Arc::try_unwrap(arc) {
                buf.clear();
                self.pool.push(buf);
            }
        }
    }

    /// Liveness ping: round-trips a heartbeat through every worker and
    /// records the acknowledged nonce in the health table.
    pub fn ping(&mut self) -> anyhow::Result<()> {
        let op = self.alloc_op();
        let targets: Vec<usize> = (0..self.transport.workers()).collect();
        let arc = Arc::new(
            Frame {
                op,
                subject: Subject::Heartbeat { nonce: op },
            }
            .encode(),
        );
        let frames: Vec<Arc<Vec<u8>>> = targets.iter().map(|_| Arc::clone(&arc)).collect();
        let resps = self.rpc_frames(op, &targets, frames, Quorum::All, None)?;
        for (i, resp) in resps.into_iter().enumerate() {
            if let Some(Subject::HeartbeatAck { nonce }) = resp {
                self.health[targets[i]].heartbeat = nonce;
            }
        }
        Ok(())
    }

    /// Health/robustness snapshot for `ServerStats`.
    pub fn status(&self) -> DistStatus {
        let mut workers = self.health.clone();
        for (w, h) in workers.iter_mut().enumerate() {
            h.alive = self.transport.is_attached(w);
            h.queue_depth = self.transport.queue_depth(w);
        }
        DistStatus {
            workers,
            retries: self.counters.retries,
            respawns: self.counters.respawns,
            stale_discarded: self.counters.stale_discarded,
            wire_errors: self.counters.wire_errors,
            in_flight: self.in_flight.len(),
            pipelined: self.counters.pipelined,
            oplog_len: self.oplog.len(),
            snapshots: self.counters.snapshots,
            compacted_ops: self.counters.compacted_ops,
            replayed_ops: self.counters.replayed_ops,
        }
    }

    /// Raise a failure recorded for an op that completed out-of-band.
    fn fail_deferred(&mut self) -> anyhow::Result<()> {
        if let Some(msg) = self.deferred_error.take() {
            anyhow::bail!("dist: deferred in-flight failure: {msg}");
        }
        Ok(())
    }

    /// Stop issuing new ops once the in-flight window is full — the
    /// cap keeps every outstanding op inside the workers' retransmit
    /// rings, which is what makes retries of them idempotent.
    fn backpressure(&mut self) -> anyhow::Result<()> {
        if self.in_flight.len() >= self.cfg.max_in_flight {
            self.drain_in_flight()?;
        }
        Ok(())
    }

    /// Pull the next frame: stashed first, then the wire. `None` means
    /// the deadline expired with nothing to read.
    fn next_frame(&mut self) -> anyhow::Result<Option<(usize, Frame)>> {
        if let Some(hit) = self.stash.pop_front() {
            return Ok(Some(hit));
        }
        loop {
            match self.transport.recv_timeout(self.cfg.deadline) {
                Ok(got) => return Ok(Some(got)),
                Err(TransportError::Timeout) => return Ok(None),
                Err(TransportError::Wire(_)) => {
                    self.counters.wire_errors += 1;
                }
                Err(TransportError::Closed) => {
                    anyhow::bail!("dist: coordinator upstream channel closed")
                }
            }
        }
    }

    /// Route a frame that does not belong to the current blocking op:
    /// either it completes an in-flight straggler or it is a stale
    /// duplicate. Errors from in-flight ops cannot unwind the engine
    /// mid-step, so they are deferred to the next backend call.
    fn route_other(&mut self, w: usize, frame: Frame) {
        let completed = match self.in_flight.get_mut(&frame.op) {
            None => false,
            Some(pend) => match pend.targets.iter().position(|t| t.w == w) {
                None => false,
                Some(pos) => {
                    pend.targets.swap_remove(pos);
                    if pend.targets.is_empty() {
                        self.in_flight.remove(&frame.op);
                    }
                    true
                }
            },
        };
        if !completed {
            self.counters.stale_discarded += 1;
            return;
        }
        self.counters.pipelined += 1;
        if let Subject::ErrorResp { message } = frame.subject {
            let op = frame.op;
            self.deferred_error
                .get_or_insert_with(|| format!("worker {w} failed op {op}: {message}"));
        }
    }

    /// Block until every in-flight op has completed (or escalated
    /// through the retry/respawn ladder).
    fn drain_in_flight(&mut self) -> anyhow::Result<()> {
        while !self.in_flight.is_empty() {
            match self.next_frame()? {
                Some((w, frame)) => self.route_other(w, frame),
                None => self.sweep_in_flight()?,
            }
        }
        Ok(())
    }

    /// Deadline sweep over in-flight stragglers: retransmit live slow
    /// workers (bounded), respawn dead or wedged ones. Respawn replay
    /// covers in-flight ops — they are logged before registration — so
    /// a respawn simply removes the worker from every pending fan.
    fn sweep_in_flight(&mut self) -> anyhow::Result<()> {
        let lagging: Vec<(u64, usize, u32)> = self
            .in_flight
            .iter()
            .flat_map(|(&op, p)| p.targets.iter().map(move |t| (op, t.w, t.attempts)))
            .collect();
        let mut respawned: Vec<usize> = Vec::new();
        for (op, w, attempts) in lagging {
            if respawned.contains(&w) {
                continue;
            }
            // A respawn above may have already cleared this entry.
            let still_pending = self
                .in_flight
                .get(&op)
                .is_some_and(|p| p.targets.iter().any(|t| t.w == w));
            if !still_pending {
                continue;
            }
            if !self.transport.is_attached(w) || attempts >= self.cfg.max_retries {
                self.respawn(w)?;
                respawned.push(w);
            } else {
                let bytes = {
                    let pend = self.in_flight.get_mut(&op).expect("checked above");
                    let t = pend
                        .targets
                        .iter_mut()
                        .find(|t| t.w == w)
                        .expect("checked above");
                    t.attempts += 1;
                    Arc::clone(&t.frame)
                };
                self.counters.retries += 1;
                self.health[w].retries += 1;
                self.send_raw(w, &bytes)?;
            }
        }
        Ok(())
    }

    /// Dispatch `frames[i]` to `targets[i]` under one op id and wait
    /// for the quorum, enforcing the per-op deadline, bounded retries,
    /// respawn-on-death, and stale-duplicate discard. Unanswered
    /// targets past the quorum are registered in flight (after `entry`
    /// lands in the log, so recovery always covers them); with
    /// `pipeline: false` they are drained before returning, which is
    /// exactly the PR-9 serial behaviour.
    fn rpc_frames(
        &mut self,
        op: u64,
        targets: &[usize],
        frames: Vec<Arc<Vec<u8>>>,
        quorum: Quorum,
        mut entry: Option<LoggedOp>,
    ) -> anyhow::Result<Vec<Option<Subject>>> {
        debug_assert_eq!(targets.len(), frames.len());
        let mut results: Vec<Option<Subject>> = vec![None; targets.len()];
        let mut attempts: Vec<u32> = vec![0; targets.len()];
        let mut respawned: Vec<bool> = vec![false; targets.len()];

        for (i, &w) in targets.iter().enumerate() {
            self.dispatch_or_respawn(w, &frames[i], &mut respawned[i])?;
        }

        let need = match quorum {
            Quorum::All => targets.len(),
            Quorum::First => 1,
        };
        let mut have = 0usize;
        while have < need {
            match self.next_frame()? {
                None => {
                    self.sweep_current(op, targets, &frames, &results, &mut attempts, &mut respawned)?;
                    self.sweep_in_flight()?;
                }
                Some((w, frame)) if frame.op == op => {
                    let slot = targets
                        .iter()
                        .position(|&t| t == w)
                        .filter(|&i| results[i].is_none());
                    match slot {
                        Some(i) => {
                            if let Subject::ErrorResp { message } = &frame.subject {
                                // Deterministic backend failure: remember
                                // the op (replicas that executed it must
                                // replay it on respawn) and surface the
                                // error — no retry.
                                if let Some(e) = entry.take() {
                                    self.oplog.push(e);
                                }
                                anyhow::bail!("dist: worker {w} failed op {op}: {message}");
                            }
                            results[i] = Some(frame.subject);
                            have += 1;
                        }
                        None => self.counters.stale_discarded += 1,
                    }
                }
                Some((w, frame)) => self.route_other(w, frame),
            }
        }

        // Log first, then register stragglers: the in-flight invariant
        // is that recovery replay always covers a pending op.
        if let Some(e) = entry.take() {
            self.oplog.push(e);
        }
        let stragglers: Vec<PendTarget> = targets
            .iter()
            .enumerate()
            .filter(|&(i, _)| results[i].is_none())
            .map(|(i, &w)| PendTarget {
                w,
                frame: Arc::clone(&frames[i]),
                attempts: attempts[i],
            })
            .collect();
        if !stragglers.is_empty() {
            self.in_flight.insert(op, Pending { targets: stragglers });
        }
        if !self.cfg.pipeline {
            self.drain_in_flight()?;
        }
        Ok(results)
    }

    /// One deadline expiry for the current blocking op: for every
    /// unsatisfied target, either retry, respawn a dead/wedged worker,
    /// or give up. The current op is *not yet logged*, so after a
    /// respawn (which replays only logged ops) it is re-sent explicitly.
    #[allow(clippy::too_many_arguments)]
    fn sweep_current(
        &mut self,
        op: u64,
        targets: &[usize],
        frames: &[Arc<Vec<u8>>],
        results: &[Option<Subject>],
        attempts: &mut [u32],
        respawned: &mut [bool],
    ) -> anyhow::Result<()> {
        for (i, &w) in targets.iter().enumerate() {
            if results[i].is_some() {
                continue;
            }
            if !self.transport.is_attached(w) {
                // Worker died mid-op: respawn (replaying the log), then
                // re-dispatch this op. A second death on the same op is
                // a hard failure.
                anyhow::ensure!(!respawned[i], "dist: worker {w} died twice during op {op}");
                self.respawn(w)?;
                respawned[i] = true;
                attempts[i] = 0;
                self.send_raw(w, &frames[i])?;
            } else if attempts[i] < self.cfg.max_retries {
                attempts[i] += 1;
                self.counters.retries += 1;
                self.health[w].retries += 1;
                self.send_raw(w, &frames[i])?;
            } else if !respawned[i] {
                // Retries exhausted against a live worker: treat it as
                // wedged. Reattach orphans the old endpoint (its queue
                // channel closes, so the zombie thread exits on its next
                // recv) and the replica is rebuilt from snapshot + log.
                self.respawn(w)?;
                respawned[i] = true;
                attempts[i] = 0;
                self.send_raw(w, &frames[i])?;
            } else {
                anyhow::bail!(
                    "dist: op {op} to worker {w} exceeded per-op deadline \
                     ({:?} x {} retries, 1 respawn)",
                    self.cfg.deadline,
                    self.cfg.max_retries
                );
            }
        }
        Ok(())
    }

    fn send_raw(&mut self, w: usize, bytes: &[u8]) -> anyhow::Result<()> {
        match self.transport.send_bytes(w, bytes) {
            Ok(()) => Ok(()),
            Err(TransportError::Closed) => anyhow::bail!("dist: worker {w} channel closed"),
            Err(e) => anyhow::bail!("dist: send to worker {w} failed: {e}"),
        }
    }

    /// First dispatch of an op to one worker; a closed slot (death
    /// noticed at send time) respawns and re-sends. Compute dispatches
    /// are counted here — once per op per worker, retransmits excluded.
    fn dispatch_or_respawn(
        &mut self,
        w: usize,
        bytes: &Arc<Vec<u8>>,
        respawned: &mut bool,
    ) -> anyhow::Result<()> {
        match self.transport.send_bytes(w, bytes) {
            Ok(()) => {}
            Err(TransportError::Closed) => {
                self.respawn(w)?;
                *respawned = true;
                self.send_raw(w, bytes)?;
            }
            Err(e) => anyhow::bail!("dist: send to worker {w} failed: {e}"),
        }
        self.health[w].ops += u64::from(wire::peek_is_compute(bytes));
        Ok(())
    }

    /// Fire an `AdmitEvict` carrying `ops` at every rank of one role,
    /// without waiting: the frame is logged and the acks complete in
    /// flight (FIFO links guarantee the state ops land before any later
    /// compute op).
    fn flush_role_ops(&mut self, role: Role, ops: Vec<StateOp>) -> anyhow::Result<()> {
        if ops.is_empty() {
            return Ok(());
        }
        let op = self.alloc_op();
        let mut buf = self.take_buf();
        wire::encode_admit_evict(&mut buf, op, &ops);
        let arc = Arc::new(buf);
        let targets: Vec<usize> = match role {
            Role::Draft => self.draft_workers().collect(),
            Role::Verify => self.verify_workers().collect(),
        };
        let mut pend = Vec::with_capacity(targets.len());
        for &w in &targets {
            let mut respawned = false;
            self.dispatch_or_respawn(w, &arc, &mut respawned)?;
            pend.push(PendTarget {
                w,
                frame: Arc::clone(&arc),
                attempts: 0,
            });
        }
        let dr = self.cfg.draft_ranks;
        let entry = match role {
            Role::Draft => LoggedOp {
                draft: (0..dr).map(|_| Some(Arc::clone(&arc))).collect(),
                verify: None,
            },
            Role::Verify => LoggedOp {
                draft: vec![None; dr],
                verify: Some(Arc::clone(&arc)),
            },
        };
        self.oplog.push(entry);
        self.in_flight.insert(op, Pending { targets: pend });
        if !self.cfg.pipeline {
            self.drain_in_flight()?;
        }
        Ok(())
    }

    /// Compact the recovery log when it exceeds the configured window
    /// and the mirror is at a clean cut (no compute op's rollback still
    /// outstanding). Pending state-op queues are flushed to the fleet
    /// first so the snapshot base and the log tail stay order-consistent
    /// (a post-snapshot replay must never roll back a sequence the
    /// snapshot no longer contains).
    fn maybe_compact(&mut self) -> anyhow::Result<()> {
        if self.cfg.oplog_window == 0 || self.oplog.len() < self.cfg.oplog_window {
            return Ok(());
        }
        if self
            .mirror
            .values()
            .any(|m| m.draft_dirty || m.target_dirty)
        {
            return Ok(());
        }
        let draft_ops = std::mem::take(&mut self.pending_draft);
        self.flush_role_ops(Role::Draft, draft_ops)?;
        let verify_ops = std::mem::take(&mut self.pending_verify);
        self.flush_role_ops(Role::Verify, verify_ops)?;
        self.drain_in_flight()?;
        self.compact()
    }

    /// Replace snapshot + log with a fresh snapshot synthesized from
    /// the committed-stream mirror: chunked `PrefillChunk` frames that
    /// re-admit every live sequence (the placeholder tail token is the
    /// not-yet-processed "next input", superseded by the next verify's
    /// feed), plus one draft-side `AdmitEvict` clamping draft lengths
    /// below the committed base where rollbacks had shortened them.
    fn compact(&mut self) -> anyhow::Result<()> {
        let dr = self.cfg.draft_ranks;
        let mut entries: Vec<LoggedOp> = Vec::new();
        let mut live: Vec<SeqId> = self.mirror.keys().copied().collect();
        live.sort_unstable();
        let mut batch: Vec<(u64, Vec<u32>)> = Vec::new();
        for chunk in live.chunks(SNAPSHOT_CHUNK) {
            batch.clear();
            for &seq in chunk {
                let mut prompt = self.mirror[&seq].content.clone();
                prompt.push(0);
                batch.push((seq, prompt));
            }
            let mut buf = self.take_buf();
            wire::encode_prefill_chunk(&mut buf, 0, &[], &batch);
            let arc = Arc::new(buf);
            entries.push(LoggedOp {
                draft: (0..dr).map(|_| Some(Arc::clone(&arc))).collect(),
                verify: Some(arc),
            });
        }
        let clamps: Vec<StateOp> = live
            .iter()
            .map(|&seq| StateOp::RollbackDraft {
                seq,
                len: self.lens[&seq].1 as u64,
            })
            .collect();
        if !clamps.is_empty() {
            let mut buf = self.take_buf();
            wire::encode_admit_evict(&mut buf, 0, &clamps);
            let arc = Arc::new(buf);
            entries.push(LoggedOp {
                draft: (0..dr).map(|_| Some(Arc::clone(&arc))).collect(),
                verify: None,
            });
        }
        self.counters.compacted_ops += self.oplog.len() as u64;
        self.counters.snapshots += 1;
        for entry in std::mem::take(&mut self.oplog) {
            self.recycle_entry(entry);
        }
        for entry in std::mem::take(&mut self.snapshot) {
            self.recycle_entry(entry);
        }
        self.snapshot = entries;
        Ok(())
    }

    /// Replace a dead or wedged worker: detach the old thread handle
    /// (never join — it may be wedged), reattach the transport slot,
    /// build a fresh replica, and replay snapshot + log so its state
    /// reconverges with its peers. Determinism of the backend contract
    /// makes the replayed replica bit-identical to the lost one. Any
    /// in-flight entries for this worker are dropped — the replay
    /// covers them (they are logged by construction).
    fn respawn(&mut self, w: usize) -> anyhow::Result<()> {
        self.counters.respawns += 1;
        self.health[w].respawns += 1;
        drop(self.handles[w].take());
        let ep = self.transport.reattach(w);
        let (role, rank) = self.slot(w);
        let backend = (self.factory)()?;
        self.handles[w] = Some(Self::spawn(role, rank, backend, ep, None));
        self.in_flight.retain(|_, pend| {
            pend.targets.retain(|t| t.w != w);
            !pend.targets.is_empty()
        });
        self.replay(w, role, rank)
    }

    /// Re-send this worker's slice of snapshot + log into the fresh
    /// replica under fresh op ids (a replayed op must not collide with
    /// the retransmit-dedup ring), awaiting each response so the
    /// rebuild is strictly ordered.
    fn replay(&mut self, w: usize, role: Role, rank: u32) -> anyhow::Result<()> {
        let frames: Vec<Arc<Vec<u8>>> = self
            .snapshot
            .iter()
            .chain(self.oplog.iter())
            .filter_map(|entry| match role {
                Role::Draft => entry.draft.get(rank as usize).and_then(Clone::clone),
                Role::Verify => entry.verify.clone(),
            })
            .collect();
        let mut patch_buf: Vec<u8> = self.take_buf();
        for arc in frames {
            let op = self.alloc_op();
            patch_buf.clear();
            patch_buf.extend_from_slice(&arc);
            wire::patch_op(&mut patch_buf, op);
            self.counters.replayed_ops += 1;
            self.health[w].ops += u64::from(wire::peek_is_compute(&patch_buf));
            self.send_raw(w, &patch_buf)?;
            // Await this replay step's response; stash anything else
            // (e.g. outer-op responses from other workers) for the
            // interrupted rpc to consume.
            let mut attempts = 0u32;
            loop {
                match self.transport.recv_timeout(self.cfg.deadline) {
                    Ok((from, frame)) if from == w && frame.op == op => {
                        // ErrorResp included: if the original op failed
                        // deterministically, the replay fails the same
                        // way and state still reconverges.
                        break;
                    }
                    Ok(other) => {
                        self.stash.push_back(other);
                    }
                    Err(TransportError::Timeout) => {
                        anyhow::ensure!(
                            attempts < self.cfg.max_retries,
                            "dist: replay op {op} to worker {w} timed out"
                        );
                        attempts += 1;
                        self.counters.retries += 1;
                        self.send_raw(w, &patch_buf)?;
                    }
                    Err(TransportError::Wire(_)) => {
                        self.counters.wire_errors += 1;
                    }
                    Err(TransportError::Closed) => {
                        anyhow::bail!("dist: upstream closed during replay")
                    }
                }
            }
        }
        patch_buf.clear();
        if self.pool.len() < POOL_CAP {
            self.pool.push(patch_buf);
        }
        Ok(())
    }

    fn drain_draft_ops(&mut self) -> Vec<StateOp> {
        std::mem::take(&mut self.pending_draft)
    }

    fn drain_verify_ops(&mut self) -> Vec<StateOp> {
        std::mem::take(&mut self.pending_verify)
    }

    fn lens_mut(&mut self, seq: SeqId) -> &mut (usize, usize) {
        self.lens.get_mut(&seq).expect("unknown sequence")
    }
}

impl<B: SdBackend + Send + 'static> SdBackend for DistBackend<B> {
    fn vocab(&self) -> usize {
        self.pricer.vocab()
    }

    fn prefill(&mut self, batch: &[(SeqId, Vec<u32>)]) -> anyhow::Result<f64> {
        self.fail_deferred()?;
        self.maybe_compact()?;
        self.backpressure()?;
        // Every replica needs the new sequences registered; piggyback
        // each role's pending state ops on its copy. Full replicas all
        // return the same `PrefillDone` (both length tables and the
        // cost), so the first response is the result.
        let draft_ops = self.drain_draft_ops();
        let verify_ops = self.drain_verify_ops();
        let op = self.alloc_op();
        let mut dbuf = self.take_buf();
        wire::encode_prefill_chunk(&mut dbuf, op, &draft_ops, batch);
        let darc = Arc::new(dbuf);
        let mut vbuf = self.take_buf();
        wire::encode_prefill_chunk(&mut vbuf, op, &verify_ops, batch);
        let varc = Arc::new(vbuf);
        let mut targets: Vec<usize> = Vec::new();
        let mut frames: Vec<Arc<Vec<u8>>> = Vec::new();
        for w in self.draft_workers() {
            targets.push(w);
            frames.push(Arc::clone(&darc));
        }
        for w in self.verify_workers() {
            targets.push(w);
            frames.push(Arc::clone(&varc));
        }
        let entry = LoggedOp {
            draft: (0..self.cfg.draft_ranks)
                .map(|_| Some(Arc::clone(&darc)))
                .collect(),
            verify: Some(varc),
        };
        let resps = self.rpc_frames(op, &targets, frames, Quorum::First, Some(entry))?;
        match resps.into_iter().flatten().next() {
            Some(Subject::PrefillDone {
                target_lens,
                draft_lens,
                cost,
            }) => {
                for (i, (seq, prompt)) in batch.iter().enumerate() {
                    self.lens
                        .insert(*seq, (target_lens[i] as usize, draft_lens[i] as usize));
                    self.mirror.insert(
                        *seq,
                        SeqMirror {
                            content: prompt[..prompt.len().saturating_sub(1)].to_vec(),
                            draft_dirty: false,
                            target_dirty: false,
                        },
                    );
                }
                Ok(cost)
            }
            other => anyhow::bail!("dist: unexpected prefill response {other:?}"),
        }
    }

    fn prefill_chunk_cost(&self, tokens: usize, ctx: usize) -> f64 {
        self.pricer.prefill_chunk_cost(tokens, ctx)
    }

    fn prefill_chunks_cost(&self, parts: &[(usize, usize)]) -> f64 {
        self.pricer.prefill_chunks_cost(parts)
    }

    fn propose(
        &mut self,
        seqs: &[SeqId],
        pending: &[Vec<u32>],
        gammas: &[usize],
        temps: &[f64],
        seed: u64,
    ) -> anyhow::Result<ProposeOut> {
        self.fail_deferred()?;
        self.maybe_compact()?;
        self.backpressure()?;
        for (i, seq) in seqs.iter().enumerate() {
            if gammas[i] > 0 {
                if let Some(m) = self.mirror.get_mut(seq) {
                    m.draft_dirty = true;
                }
            }
        }
        let state_ops = self.drain_draft_ops();
        let dr = self.cfg.draft_ranks;
        let op = self.alloc_op();

        if dr == 1 {
            // Single draft rank: verbatim seed, verbatim frame, cost
            // passed through untouched — byte-identical to PR 9 and to
            // the single-process call.
            let mut buf = self.take_buf();
            wire::encode_propose_req(
                &mut buf, op, &state_ops, seqs, pending, gammas, temps, seed, None,
            );
            let arc = Arc::new(buf);
            let entry = LoggedOp {
                draft: vec![Some(Arc::clone(&arc))],
                verify: None,
            };
            let resps = self.rpc_frames(op, &[0], vec![arc], Quorum::All, Some(entry))?;
            return match resps.into_iter().flatten().next() {
                Some(Subject::ProposeResp {
                    tokens,
                    probs,
                    draft_lens,
                    cost,
                }) => {
                    for (i, seq) in seqs.iter().enumerate() {
                        self.lens_mut(*seq).1 = draft_lens[i] as usize;
                    }
                    Ok(ProposeOut {
                        tokens,
                        probs,
                        cost,
                    })
                }
                other => anyhow::bail!("dist: unexpected propose response {other:?}"),
            };
        }

        // Striped scale-out: home rank `seq % dr` (stable across a
        // sequence's lifetime, so each rank's draft KV stays warm for
        // its stripe). Every rank is always in the fan — empty stripes
        // still carry the state-op broadcast — and per-rank costs
        // combine as `max + hop`, mirroring the verify fan.
        let mut stripes: Vec<Vec<usize>> = vec![Vec::new(); dr];
        for (i, seq) in seqs.iter().enumerate() {
            stripes[(*seq % dr as u64) as usize].push(i);
        }
        let targets: Vec<usize> = (0..dr).collect();
        let mut frames: Vec<Arc<Vec<u8>>> = Vec::with_capacity(dr);
        let mut entry_draft: Vec<Option<Arc<Vec<u8>>>> = Vec::with_capacity(dr);
        for (r, stripe) in stripes.iter().enumerate() {
            let mut buf = self.take_buf();
            wire::encode_propose_req(
                &mut buf,
                op,
                &state_ops,
                seqs,
                pending,
                gammas,
                temps,
                stripe_seed(seed, r),
                Some(stripe),
            );
            let arc = Arc::new(buf);
            entry_draft.push(Some(Arc::clone(&arc)));
            frames.push(arc);
        }
        let entry = LoggedOp {
            draft: entry_draft,
            verify: None,
        };
        let resps = self.rpc_frames(op, &targets, frames, Quorum::All, Some(entry))?;

        let b = seqs.len();
        let mut tokens: Vec<Vec<u32>> = vec![Vec::new(); b];
        let mut probs: Vec<Vec<LogitsView>> = vec![Vec::new(); b];
        let mut max_cost = f64::NEG_INFINITY;
        for (r, resp) in resps.into_iter().enumerate() {
            match resp {
                Some(Subject::ProposeResp {
                    tokens: t,
                    probs: p,
                    draft_lens,
                    cost,
                }) => {
                    let stripe = &stripes[r];
                    anyhow::ensure!(
                        t.len() == stripe.len(),
                        "dist: draft rank {r} returned {} rows for a {}-seq stripe",
                        t.len(),
                        stripe.len()
                    );
                    max_cost = max_cost.max(cost);
                    for (k, row) in t.into_iter().enumerate() {
                        tokens[stripe[k]] = row;
                    }
                    for (k, row) in p.into_iter().enumerate() {
                        probs[stripe[k]] = row;
                    }
                    for (k, &dl) in draft_lens.iter().enumerate() {
                        self.lens_mut(seqs[stripe[k]]).1 = dl as usize;
                    }
                }
                other => anyhow::bail!("dist: unexpected propose response {other:?}"),
            }
        }
        let total_gamma: usize = gammas.iter().sum();
        Ok(ProposeOut {
            tokens,
            probs,
            cost: max_cost + self.cfg.fabric.hop_cost(total_gamma as f64),
        })
    }

    fn verify(
        &mut self,
        seqs: &[SeqId],
        feed: &[u32],
        drafts: &[Vec<u32>],
        temps: &[f64],
    ) -> anyhow::Result<VerifyOut> {
        self.fail_deferred()?;
        self.maybe_compact()?;
        self.backpressure()?;
        // AR-only phases never propose, so the draft-side queue is
        // flushed here once it builds up (stays bounded either way).
        if self.pending_draft.len() >= STATE_OP_FLUSH_THRESHOLD {
            let ops = self.drain_draft_ops();
            self.flush_role_ops(Role::Draft, ops)?;
        }
        for (i, seq) in seqs.iter().enumerate() {
            if let Some(m) = self.mirror.get_mut(seq) {
                m.content.push(feed[i]);
                m.content.extend_from_slice(&drafts[i]);
                m.target_dirty = true;
            }
        }
        let state_ops = self.drain_verify_ops();
        let op = self.alloc_op();
        let mut buf = self.take_buf();
        wire::encode_verify_req(
            &mut buf,
            op,
            &state_ops,
            seqs,
            feed,
            drafts,
            temps,
            self.budget.map(|b| b as u64),
        );
        let arc = Arc::new(buf);
        let targets: Vec<usize> = self.verify_workers().collect();
        let frames: Vec<Arc<Vec<u8>>> = targets.iter().map(|_| Arc::clone(&arc)).collect();
        let entry = LoggedOp {
            draft: vec![None; self.cfg.draft_ranks],
            verify: Some(arc),
        };
        // First responder wins: replicas are bit-identical, so the
        // earliest VerifyResp *is* `max` over the fan, and the
        // remaining ranks complete in flight — this is the overlap that
        // lets the next propose ride alongside the verify fan tail.
        let resps = self.rpc_frames(op, &targets, frames, Quorum::First, Some(entry))?;
        let mut out = None;
        for resp in resps.into_iter().flatten() {
            match resp {
                Subject::VerifyResp {
                    probs,
                    target_lens,
                    cost,
                } => {
                    for (i, seq) in seqs.iter().enumerate() {
                        self.lens_mut(*seq).0 = target_lens[i] as usize;
                    }
                    out = Some(VerifyOut { probs, cost });
                }
                other => anyhow::bail!("dist: unexpected verify response {other:?}"),
            }
        }
        let mut out = out.ok_or_else(|| anyhow::anyhow!("dist: no verify response"))?;
        // Per-rank costs combine as max (ranks run concurrently) plus
        // the fabric hop for the fan-out of this round's token payload;
        // Loopback's hop is exactly 0.0.
        let round_tokens: f64 = drafts.iter().map(|d| (d.len() + 1) as f64).sum();
        out.cost += self.cfg.fabric.hop_cost(round_tokens);
        Ok(out)
    }

    fn rollback_target(&mut self, seq: SeqId, len: usize) {
        if let Some(l) = self.lens.get_mut(&seq) {
            l.0 = len;
        }
        if let Some(m) = self.mirror.get_mut(&seq) {
            m.content.truncate(len);
            m.target_dirty = false;
        }
        self.pending_verify.push(StateOp::RollbackTarget {
            seq,
            len: len as u64,
        });
        // The draft replica never runs verify, so its committed base
        // only moves when the coordinator pushes it.
        self.pending_draft.push(StateOp::SyncBase {
            seq,
            len: len as u64,
        });
    }

    fn rollback_draft(&mut self, seq: SeqId, len: usize) {
        if let Some(l) = self.lens.get_mut(&seq) {
            l.1 = l.1.min(len);
        }
        if let Some(m) = self.mirror.get_mut(&seq) {
            m.draft_dirty = false;
        }
        self.pending_draft.push(StateOp::RollbackDraft {
            seq,
            len: len as u64,
        });
    }

    fn target_len(&self, seq: SeqId) -> usize {
        self.lens.get(&seq).expect("unknown sequence").0
    }

    fn draft_len(&self, seq: SeqId) -> usize {
        self.lens.get(&seq).expect("unknown sequence").1
    }

    fn release(&mut self, seq: SeqId) {
        self.lens.remove(&seq);
        self.mirror.remove(&seq);
        self.pending_draft.push(StateOp::Release { seq });
        self.pending_verify.push(StateOp::Release { seq });
    }

    fn reject_cost(&self, gammas: &[usize]) -> f64 {
        self.pricer.reject_cost(gammas)
    }

    fn set_verify_budget(&mut self, budget: Option<usize>) {
        self.budget = budget;
        self.pricer.set_verify_budget(budget);
    }

    fn verify_budget(&self) -> Option<usize> {
        self.budget
    }

    fn dist_status(&self) -> Option<DistStatus> {
        Some(self.status())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::Topology;

    #[test]
    fn loopback_hop_is_exactly_zero() {
        assert_eq!(DistFabric::Loopback.hop_cost(1e9).to_bits(), 0.0f64.to_bits());
    }

    #[test]
    fn sharded_hop_matches_comm_time() {
        let spec = ShardingSpec::new(Topology::nvlink(4));
        let fabric = DistFabric::Sharded(spec.clone());
        for tokens in [1.0, 16.0, 4096.0] {
            assert_eq!(
                fabric.hop_cost(tokens).to_bits(),
                spec.comm_time(tokens).to_bits()
            );
        }
    }

    #[test]
    fn config_validation() {
        assert!(DistConfig::default().validate().is_ok());
        for bad in [
            DistConfig {
                verify_ranks: 0,
                ..DistConfig::default()
            },
            DistConfig {
                verify_ranks: 65,
                ..DistConfig::default()
            },
            DistConfig {
                draft_ranks: 0,
                ..DistConfig::default()
            },
            DistConfig {
                draft_ranks: 17,
                ..DistConfig::default()
            },
            DistConfig {
                max_in_flight: 0,
                ..DistConfig::default()
            },
            DistConfig {
                max_in_flight: REPLAY_RING + 1,
                ..DistConfig::default()
            },
        ] {
            assert!(bad.validate().is_err(), "{bad:?} should be rejected");
        }
        // Compaction off (window 0) is a valid configuration.
        assert!(DistConfig {
            oplog_window: 0,
            ..DistConfig::default()
        }
        .validate()
        .is_ok());
    }

    #[test]
    fn stripe_seed_rank0_is_identity() {
        for seed in [0u64, 1, 42, u64::MAX, 0x9E37_79B9_7F4A_7C15] {
            assert_eq!(stripe_seed(seed, 0), seed);
            assert_ne!(stripe_seed(seed, 1), stripe_seed(seed, 2));
        }
    }

    #[test]
    fn slot_layout_draft_then_verify() {
        type DB = DistBackend<crate::spec::synthetic::SyntheticLm>;
        assert_eq!(DB::slot_of(2, 0), (Role::Draft, 0));
        assert_eq!(DB::slot_of(2, 1), (Role::Draft, 1));
        assert_eq!(DB::slot_of(2, 2), (Role::Verify, 0));
        assert_eq!(DB::slot_of(2, 3), (Role::Verify, 1));
        assert_eq!(DB::slot_of(1, 1), (Role::Verify, 0));
    }
}
