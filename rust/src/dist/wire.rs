//! Wire protocol for the coordinator/worker engine.
//!
//! Every message between the coordinator and a worker is a [`Frame`]: an
//! op id (the coordinator's idempotency key — retransmits reuse it, the
//! response echoes it) plus a [`Subject`] payload. Frames cross the
//! transport as length-prefixed bytes produced by a hand-rolled codec in
//! the `benchlib::Json` spirit — no serde, no external dependency — so
//! the exact same encoding lifts from the in-process channel transport to
//! sockets unchanged.
//!
//! ## Encoding
//!
//! ```text
//! frame    := len:u32le body              (len = body length in bytes)
//! body     := version:u8 tag:u8 op:u64le payload
//! u32/u64  := little-endian
//! f64      := IEEE-754 bits as u64le      (bit-exact round-trip)
//! bool     := u8 (0|1)
//! option T := u8 (0|1) [T]
//! vec T    := count:u32le T*
//! string   := len:u32le utf-8 bytes
//! ```
//!
//! Malformed input decodes to a typed [`WireError`] — never a panic: the
//! decoder bounds-checks every read ([`WireError::Truncated`]), rejects
//! frames whose declared length exceeds [`MAX_FRAME_BYTES`]
//! ([`WireError::Oversized`]) before allocating, and rejects unknown
//! tags/versions and non-canonical scalars. The golden-byte tests in
//! `rust/tests/codec_wire.rs` pin one encoding per variant so the format
//! cannot drift silently between releases (a socket peer from an older
//! build must either interoperate or fail loudly on the version byte).

use crate::sampling::LogitsView;

/// Protocol version stamped into every frame body.
pub const WIRE_VERSION: u8 = 1;

/// Byte offset of the subject tag inside an encoded frame
/// (`[len u32][version u8][tag u8][op u64]...`). Lets the transport
/// layer classify frames without decoding them.
pub const TAG_OFFSET: usize = 5;

/// Byte offset of the op id inside an encoded frame. Replay re-sends
/// logged request bytes with a fresh op id patched in place here.
pub const OP_ID_OFFSET: usize = 6;

/// Request tags the zero-copy paths key off (they equal what
/// `Subject::tag()` assigns to the matching variants).
pub const TAG_PROPOSE_REQ: u8 = 0;
pub const TAG_VERIFY_REQ: u8 = 2;
pub const TAG_PREFILL_CHUNK: u8 = 4;
pub const TAG_ADMIT_EVICT: u8 = 6;
pub const TAG_STATS_PULL: u8 = 8;
pub const TAG_HEARTBEAT: u8 = 10;

/// Hard ceiling on one frame's body size. Propose/verify frames carry
/// per-token rows, so real frames sit in the kilobytes; anything claiming
/// more than this is a corrupt or hostile length prefix and is rejected
/// before any allocation happens.
pub const MAX_FRAME_BYTES: usize = 16 << 20;

/// Typed codec failure. Every decoder path returns one of these; the
/// codec never panics on untrusted bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// The buffer ended before a read completed.
    Truncated { need: usize, have: usize },
    /// The length prefix claims a body larger than [`MAX_FRAME_BYTES`].
    Oversized { len: usize, max: usize },
    /// Version byte from an incompatible peer.
    BadVersion(u8),
    /// Unknown discriminant for the named enum.
    BadTag { what: &'static str, tag: u8 },
    /// Bytes left over after a complete decode (framing desync).
    Trailing { extra: usize },
    /// A scalar failed validation (non-0/1 bool, invalid UTF-8, …).
    BadValue(&'static str),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { need, have } => {
                write!(f, "truncated frame: need {need} bytes, have {have}")
            }
            WireError::Oversized { len, max } => {
                write!(f, "oversized frame: {len} bytes exceeds cap {max}")
            }
            WireError::BadVersion(v) => write!(f, "unsupported wire version {v}"),
            WireError::BadTag { what, tag } => write!(f, "unknown {what} tag {tag}"),
            WireError::Trailing { extra } => write!(f, "{extra} trailing bytes after frame"),
            WireError::BadValue(what) => write!(f, "malformed {what}"),
        }
    }
}

impl std::error::Error for WireError {}

type Result<T> = std::result::Result<T, WireError>;

/// A state mutation the coordinator forwards to a worker ahead of its
/// next op. Rollbacks and releases are cheap bookkeeping, so they ride as
/// a prefix on the next compute frame instead of paying a round trip
/// each ([`Subject::AdmitEvict`] carries them standalone when an explicit
/// flush is needed). All four are idempotent — a retransmitted frame may
/// re-apply them against unchanged state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StateOp {
    /// Roll the target KV back to `len` tokens (verify workers).
    RollbackTarget { seq: u64, len: u64 },
    /// Clamp the draft KV to at most `len` tokens (draft worker).
    RollbackDraft { seq: u64, len: u64 },
    /// Sync the committed-stream base to `len` (draft worker: its local
    /// replica never runs verify, so the coordinator pushes the
    /// authoritative base its next propose must continue from).
    SyncBase { seq: u64, len: u64 },
    /// Drop all state for a finished sequence (both roles).
    Release { seq: u64 },
}

/// Per-worker stats returned by [`Subject::StatsPull`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkerStats {
    /// 0 = draft, 1 = verify.
    pub role: u8,
    /// Verify EP rank (0 for the draft worker).
    pub rank: u32,
    pub vocab: u64,
    /// Compute ops (propose/verify/prefill) executed since spawn.
    pub ops_executed: u64,
    /// Sequences currently registered on the worker's backend.
    pub seqs_live: u64,
}

/// The message payload. Requests flow coordinator → worker; each has a
/// paired response flowing back with the same op id.
#[derive(Debug, Clone, PartialEq)]
pub enum Subject {
    /// Draft worker: apply `state_ops`, then propose `gammas[i]` tokens
    /// per sequence (the [`crate::spec::SdBackend::propose`] contract).
    ProposeReq {
        state_ops: Vec<StateOp>,
        seqs: Vec<u64>,
        pending: Vec<Vec<u32>>,
        gammas: Vec<u32>,
        temps: Vec<f64>,
        seed: u64,
    },
    /// `draft_lens[i]` is the worker's post-op draft context length for
    /// `seqs[i]` — the authoritative value the coordinator mirrors.
    ProposeResp {
        tokens: Vec<Vec<u32>>,
        probs: Vec<Vec<LogitsView>>,
        draft_lens: Vec<u64>,
        cost: f64,
    },
    /// Verify workers (broadcast to every EP rank): apply `state_ops`,
    /// set the verify-expert `budget`, then run the target forward.
    VerifyReq {
        state_ops: Vec<StateOp>,
        seqs: Vec<u64>,
        feed: Vec<u32>,
        drafts: Vec<Vec<u32>>,
        temps: Vec<f64>,
        budget: Option<u64>,
    },
    VerifyResp {
        probs: Vec<Vec<LogitsView>>,
        target_lens: Vec<u64>,
        cost: f64,
    },
    /// Prompt registration, broadcast to every worker (each replica needs
    /// the sequence). Named after the chunked-prefill op it will carry
    /// when the continuous pipeline splits prompts across frames.
    PrefillChunk {
        state_ops: Vec<StateOp>,
        batch: Vec<(u64, Vec<u32>)>,
    },
    PrefillDone {
        target_lens: Vec<u64>,
        draft_lens: Vec<u64>,
        cost: f64,
    },
    /// Standalone state-op flush (admissions/evictions between rounds
    /// with no compute frame to ride on).
    AdmitEvict { state_ops: Vec<StateOp> },
    AdmitEvictAck,
    StatsPull,
    StatsResp(WorkerStats),
    /// Liveness ping; the ack echoes the nonce.
    Heartbeat { nonce: u64 },
    HeartbeatAck { nonce: u64 },
    /// The worker's backend rejected the op (deterministic failure — the
    /// coordinator propagates it instead of retrying).
    ErrorResp { message: String },
}

impl Subject {
    fn tag(&self) -> u8 {
        match self {
            Subject::ProposeReq { .. } => 0,
            Subject::ProposeResp { .. } => 1,
            Subject::VerifyReq { .. } => 2,
            Subject::VerifyResp { .. } => 3,
            Subject::PrefillChunk { .. } => 4,
            Subject::PrefillDone { .. } => 5,
            Subject::AdmitEvict { .. } => 6,
            Subject::AdmitEvictAck => 7,
            Subject::StatsPull => 8,
            Subject::StatsResp(_) => 9,
            Subject::Heartbeat { .. } => 10,
            Subject::HeartbeatAck { .. } => 11,
            Subject::ErrorResp { .. } => 12,
        }
    }

    /// Compute ops mutate worker model state and get retried/replayed;
    /// everything else is control traffic.
    pub fn is_compute(&self) -> bool {
        matches!(
            self,
            Subject::ProposeReq { .. } | Subject::VerifyReq { .. } | Subject::PrefillChunk { .. }
        )
    }
}

/// One wire message: op id + payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    /// Coordinator-assigned, strictly increasing per coordinator.
    /// Responses echo the request's op; a retransmit reuses it, which is
    /// how workers deduplicate and coordinators discard stale replies.
    pub op: u64,
    pub subject: Subject,
}

// --- encoder -------------------------------------------------------------

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    /// Frame preamble: length-prefix placeholder (patched by
    /// [`Enc::finish`]), version, tag, op id.
    fn header(&mut self, tag: u8, op: u64) {
        self.u32(0);
        self.u8(WIRE_VERSION);
        self.u8(tag);
        self.u64(op);
    }
    /// Patch the length prefix once the body is complete.
    fn finish(&mut self) {
        let body_len = (self.buf.len() - 4) as u32;
        self.buf[0..4].copy_from_slice(&body_len.to_le_bytes());
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn count(&mut self, n: usize) {
        debug_assert!(n <= u32::MAX as usize);
        self.u32(n as u32);
    }
    fn str(&mut self, s: &str) {
        self.count(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn vec_u32(&mut self, v: &[u32]) {
        self.count(v.len());
        for &x in v {
            self.u32(x);
        }
    }
    fn vec_u64(&mut self, v: &[u64]) {
        self.count(v.len());
        for &x in v {
            self.u64(x);
        }
    }
    fn vec_f64(&mut self, v: &[f64]) {
        self.count(v.len());
        for &x in v {
            self.f64(x);
        }
    }
    fn vec_vec_u32(&mut self, v: &[Vec<u32>]) {
        self.count(v.len());
        for row in v {
            self.vec_u32(row);
        }
    }

    fn state_ops(&mut self, ops: &[StateOp]) {
        self.count(ops.len());
        for op in ops {
            match op {
                StateOp::RollbackTarget { seq, len } => {
                    self.u8(0);
                    self.u64(*seq);
                    self.u64(*len);
                }
                StateOp::RollbackDraft { seq, len } => {
                    self.u8(1);
                    self.u64(*seq);
                    self.u64(*len);
                }
                StateOp::SyncBase { seq, len } => {
                    self.u8(2);
                    self.u64(*seq);
                    self.u64(*len);
                }
                StateOp::Release { seq } => {
                    self.u8(3);
                    self.u64(*seq);
                }
            }
        }
    }

    fn logits(&mut self, v: &LogitsView) {
        match v {
            LogitsView::OneHot { token, vocab } => {
                self.u8(0);
                self.u32(*token);
                self.u32(*vocab);
            }
            LogitsView::TopK { entries, vocab } => {
                self.u8(1);
                self.u32(*vocab);
                self.count(entries.len());
                for &(t, p) in entries {
                    self.u32(t);
                    self.f64(p);
                }
            }
            LogitsView::Dense(row) => {
                self.u8(2);
                self.vec_f64(row);
            }
        }
    }

    fn probs(&mut self, probs: &[Vec<LogitsView>]) {
        self.count(probs.len());
        for rows in probs {
            self.count(rows.len());
            for r in rows {
                self.logits(r);
            }
        }
    }
}

// --- decoder -------------------------------------------------------------

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let have = self.buf.len() - self.pos;
        if n > have {
            return Err(WireError::Truncated { need: n, have });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
    /// A declared element count, capacity-capped by the bytes actually
    /// present so a hostile count can't trigger a huge allocation (the
    /// reads themselves will hit `Truncated` first).
    fn count(&mut self, min_elem_bytes: usize) -> Result<(usize, usize)> {
        let n = self.u32()? as usize;
        let cap = n.min(self.remaining() / min_elem_bytes.max(1) + 1);
        Ok((n, cap))
    }
    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec()).map_err(|_| WireError::BadValue("utf-8 string"))
    }
    fn vec_u32_into(&mut self, out: &mut Vec<u32>) -> Result<()> {
        let (n, cap) = self.count(4)?;
        out.clear();
        out.reserve(cap);
        for _ in 0..n {
            out.push(self.u32()?);
        }
        Ok(())
    }
    fn vec_u32(&mut self) -> Result<Vec<u32>> {
        let mut v = Vec::new();
        self.vec_u32_into(&mut v)?;
        Ok(v)
    }
    fn vec_u64_into(&mut self, out: &mut Vec<u64>) -> Result<()> {
        let (n, cap) = self.count(8)?;
        out.clear();
        out.reserve(cap);
        for _ in 0..n {
            out.push(self.u64()?);
        }
        Ok(())
    }
    fn vec_u64(&mut self) -> Result<Vec<u64>> {
        let mut v = Vec::new();
        self.vec_u64_into(&mut v)?;
        Ok(v)
    }
    fn vec_f64_into(&mut self, out: &mut Vec<f64>) -> Result<()> {
        let (n, cap) = self.count(8)?;
        out.clear();
        out.reserve(cap);
        for _ in 0..n {
            out.push(self.f64()?);
        }
        Ok(())
    }
    fn vec_f64(&mut self) -> Result<Vec<f64>> {
        let mut v = Vec::new();
        self.vec_f64_into(&mut v)?;
        Ok(v)
    }
    /// Count-capped row decode into a pooled `Vec<Vec<u32>>`: rows
    /// beyond the returned count keep their capacity for later frames.
    fn rows_into(&mut self, rows: &mut Vec<Vec<u32>>) -> Result<usize> {
        let (n, _cap) = self.count(4)?;
        for i in 0..n {
            if i == rows.len() {
                rows.push(Vec::new());
            }
            self.vec_u32_into(&mut rows[i])?;
        }
        Ok(n)
    }
    fn vec_vec_u32(&mut self) -> Result<Vec<Vec<u32>>> {
        let (n, cap) = self.count(4)?;
        let mut v = Vec::with_capacity(cap);
        for _ in 0..n {
            v.push(self.vec_u32()?);
        }
        Ok(v)
    }
    fn opt_u64(&mut self) -> Result<Option<u64>> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.u64()?)),
            _ => Err(WireError::BadValue("option tag")),
        }
    }

    fn state_ops_into(&mut self, out: &mut Vec<StateOp>) -> Result<()> {
        let (n, cap) = self.count(9)?;
        out.clear();
        out.reserve(cap);
        for _ in 0..n {
            let tag = self.u8()?;
            out.push(match tag {
                0 => StateOp::RollbackTarget {
                    seq: self.u64()?,
                    len: self.u64()?,
                },
                1 => StateOp::RollbackDraft {
                    seq: self.u64()?,
                    len: self.u64()?,
                },
                2 => StateOp::SyncBase {
                    seq: self.u64()?,
                    len: self.u64()?,
                },
                3 => StateOp::Release { seq: self.u64()? },
                t => return Err(WireError::BadTag { what: "state op", tag: t }),
            });
        }
        Ok(())
    }

    fn state_ops(&mut self) -> Result<Vec<StateOp>> {
        let mut v = Vec::new();
        self.state_ops_into(&mut v)?;
        Ok(v)
    }

    fn logits(&mut self) -> Result<LogitsView> {
        match self.u8()? {
            0 => Ok(LogitsView::OneHot {
                token: self.u32()?,
                vocab: self.u32()?,
            }),
            1 => {
                let vocab = self.u32()?;
                let (n, cap) = self.count(12)?;
                let mut entries = Vec::with_capacity(cap);
                for _ in 0..n {
                    let t = self.u32()?;
                    let p = self.f64()?;
                    entries.push((t, p));
                }
                Ok(LogitsView::TopK { entries, vocab })
            }
            2 => Ok(LogitsView::Dense(self.vec_f64()?)),
            t => Err(WireError::BadTag { what: "logits view", tag: t }),
        }
    }

    fn probs(&mut self) -> Result<Vec<Vec<LogitsView>>> {
        let (n, cap) = self.count(4)?;
        let mut v = Vec::with_capacity(cap);
        for _ in 0..n {
            let (m, mcap) = self.count(9)?;
            let mut rows = Vec::with_capacity(mcap);
            for _ in 0..m {
                rows.push(self.logits()?);
            }
            v.push(rows);
        }
        Ok(v)
    }
}

impl Frame {
    /// Encode to a length-prefixed byte string (the exact bytes a socket
    /// transport would write).
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc {
            buf: Vec::with_capacity(64),
        };
        e.header(self.subject.tag(), self.op);
        match &self.subject {
            Subject::ProposeReq {
                state_ops,
                seqs,
                pending,
                gammas,
                temps,
                seed,
            } => {
                e.state_ops(state_ops);
                e.vec_u64(seqs);
                e.vec_vec_u32(pending);
                e.vec_u32(gammas);
                e.vec_f64(temps);
                e.u64(*seed);
            }
            Subject::ProposeResp {
                tokens,
                probs,
                draft_lens,
                cost,
            } => {
                e.vec_vec_u32(tokens);
                e.probs(probs);
                e.vec_u64(draft_lens);
                e.f64(*cost);
            }
            Subject::VerifyReq {
                state_ops,
                seqs,
                feed,
                drafts,
                temps,
                budget,
            } => {
                e.state_ops(state_ops);
                e.vec_u64(seqs);
                e.vec_u32(feed);
                e.vec_vec_u32(drafts);
                e.vec_f64(temps);
                match budget {
                    None => e.u8(0),
                    Some(b) => {
                        e.u8(1);
                        e.u64(*b);
                    }
                }
            }
            Subject::VerifyResp {
                probs,
                target_lens,
                cost,
            } => {
                e.probs(probs);
                e.vec_u64(target_lens);
                e.f64(*cost);
            }
            Subject::PrefillChunk { state_ops, batch } => {
                e.state_ops(state_ops);
                e.count(batch.len());
                for (seq, prompt) in batch {
                    e.u64(*seq);
                    e.vec_u32(prompt);
                }
            }
            Subject::PrefillDone {
                target_lens,
                draft_lens,
                cost,
            } => {
                e.vec_u64(target_lens);
                e.vec_u64(draft_lens);
                e.f64(*cost);
            }
            Subject::AdmitEvict { state_ops } => e.state_ops(state_ops),
            Subject::AdmitEvictAck | Subject::StatsPull => {}
            Subject::StatsResp(s) => {
                e.u8(s.role);
                e.u32(s.rank);
                e.u64(s.vocab);
                e.u64(s.ops_executed);
                e.u64(s.seqs_live);
            }
            Subject::Heartbeat { nonce } | Subject::HeartbeatAck { nonce } => e.u64(*nonce),
            Subject::ErrorResp { message } => e.str(message),
        }
        e.finish();
        e.buf
    }

    /// Decode exactly one length-prefixed frame. The buffer must contain
    /// the frame and nothing else (discrete-message transports); trailing
    /// bytes are a framing error, short bodies are `Truncated`.
    pub fn decode(bytes: &[u8]) -> Result<Frame> {
        let mut d = Dec { buf: bytes, pos: 0 };
        let len = d.u32()? as usize;
        if len > MAX_FRAME_BYTES {
            return Err(WireError::Oversized {
                len,
                max: MAX_FRAME_BYTES,
            });
        }
        if bytes.len() - 4 < len {
            return Err(WireError::Truncated {
                need: len,
                have: bytes.len() - 4,
            });
        }
        if bytes.len() - 4 > len {
            return Err(WireError::Trailing {
                extra: bytes.len() - 4 - len,
            });
        }
        let version = d.u8()?;
        if version != WIRE_VERSION {
            return Err(WireError::BadVersion(version));
        }
        let tag = d.u8()?;
        let op = d.u64()?;
        let subject = match tag {
            0 => Subject::ProposeReq {
                state_ops: d.state_ops()?,
                seqs: d.vec_u64()?,
                pending: d.vec_vec_u32()?,
                gammas: d.vec_u32()?,
                temps: d.vec_f64()?,
                seed: d.u64()?,
            },
            1 => Subject::ProposeResp {
                tokens: d.vec_vec_u32()?,
                probs: d.probs()?,
                draft_lens: d.vec_u64()?,
                cost: d.f64()?,
            },
            2 => Subject::VerifyReq {
                state_ops: d.state_ops()?,
                seqs: d.vec_u64()?,
                feed: d.vec_u32()?,
                drafts: d.vec_vec_u32()?,
                temps: d.vec_f64()?,
                budget: d.opt_u64()?,
            },
            3 => Subject::VerifyResp {
                probs: d.probs()?,
                target_lens: d.vec_u64()?,
                cost: d.f64()?,
            },
            4 => {
                let state_ops = d.state_ops()?;
                let (n, cap) = d.count(12)?;
                let mut batch = Vec::with_capacity(cap);
                for _ in 0..n {
                    let seq = d.u64()?;
                    let prompt = d.vec_u32()?;
                    batch.push((seq, prompt));
                }
                Subject::PrefillChunk { state_ops, batch }
            }
            5 => Subject::PrefillDone {
                target_lens: d.vec_u64()?,
                draft_lens: d.vec_u64()?,
                cost: d.f64()?,
            },
            6 => Subject::AdmitEvict {
                state_ops: d.state_ops()?,
            },
            7 => Subject::AdmitEvictAck,
            8 => Subject::StatsPull,
            9 => Subject::StatsResp(WorkerStats {
                role: d.u8()?,
                rank: d.u32()?,
                vocab: d.u64()?,
                ops_executed: d.u64()?,
                seqs_live: d.u64()?,
            }),
            10 => Subject::Heartbeat { nonce: d.u64()? },
            11 => Subject::HeartbeatAck { nonce: d.u64()? },
            12 => Subject::ErrorResp { message: d.str()? },
            t => return Err(WireError::BadTag { what: "subject", tag: t }),
        };
        if d.remaining() != 0 {
            return Err(WireError::Trailing {
                extra: d.remaining(),
            });
        }
        Ok(Frame { op, subject })
    }
}

// --- zero-copy request path ----------------------------------------------
//
// The coordinator's hot loop never materializes a `Subject` for requests:
// the functions below encode a complete frame straight from engine-native
// slices into a caller-owned buffer (whose ownership then transfers to
// the op log — one encode, one buffer, shared by the wire and the log),
// and workers decode requests into a pooled [`ReqScratch`] instead of
// allocating fresh Vecs per frame. Byte output is identical to
// `Frame::encode` of the equivalent `Subject` — pinned by tests below and
// by the golden bytes in `rust/tests/codec_wire.rs`.

/// Patch the op id of an already-encoded frame in place. Replay re-sends
/// logged request bytes under fresh op ids (a replayed op must not match
/// the worker's retransmit-dedup ring).
pub fn patch_op(bytes: &mut [u8], op: u64) {
    bytes[OP_ID_OFFSET..OP_ID_OFFSET + 8].copy_from_slice(&op.to_le_bytes());
}

/// Validate the frame preamble and return `(op, tag)` without touching
/// the payload — the worker's dispatch peek.
pub fn peek_header(bytes: &[u8]) -> Result<(u64, u8)> {
    let mut d = Dec { buf: bytes, pos: 0 };
    let len = d.u32()? as usize;
    if len > MAX_FRAME_BYTES {
        return Err(WireError::Oversized {
            len,
            max: MAX_FRAME_BYTES,
        });
    }
    if bytes.len() - 4 < len {
        return Err(WireError::Truncated {
            need: len,
            have: bytes.len() - 4,
        });
    }
    if bytes.len() - 4 > len {
        return Err(WireError::Trailing {
            extra: bytes.len() - 4 - len,
        });
    }
    let version = d.u8()?;
    if version != WIRE_VERSION {
        return Err(WireError::BadVersion(version));
    }
    let tag = d.u8()?;
    let op = d.u64()?;
    Ok((op, tag))
}

/// Tag-level compute classification for raw frame bytes — the byte-path
/// twin of [`Subject::is_compute`], used by fault injection on the send
/// side where no `Subject` exists.
pub fn peek_is_compute(bytes: &[u8]) -> bool {
    matches!(
        bytes.get(TAG_OFFSET),
        Some(&TAG_PROPOSE_REQ) | Some(&TAG_VERIFY_REQ) | Some(&TAG_PREFILL_CHUNK)
    )
}

/// Encode a `ProposeReq` frame from borrowed engine slices into `buf`
/// (cleared first). `gammas` stays `usize` (the engine's native type);
/// the wire carries `u32` exactly as `Subject::ProposeReq` does. When
/// `idx` is `Some`, only the listed row positions are encoded — the
/// draft-stripe gather without copying any row.
#[allow(clippy::too_many_arguments)]
pub fn encode_propose_req(
    buf: &mut Vec<u8>,
    op: u64,
    state_ops: &[StateOp],
    seqs: &[u64],
    pending: &[Vec<u32>],
    gammas: &[usize],
    temps: &[f64],
    seed: u64,
    idx: Option<&[usize]>,
) {
    let mut e = Enc {
        buf: std::mem::take(buf),
    };
    e.buf.clear();
    e.header(TAG_PROPOSE_REQ, op);
    e.state_ops(state_ops);
    match idx {
        None => {
            e.vec_u64(seqs);
            e.vec_vec_u32(pending);
            e.count(gammas.len());
            for &g in gammas {
                e.u32(g as u32);
            }
            e.vec_f64(temps);
        }
        Some(ix) => {
            e.count(ix.len());
            for &i in ix {
                e.u64(seqs[i]);
            }
            e.count(ix.len());
            for &i in ix {
                e.vec_u32(&pending[i]);
            }
            e.count(ix.len());
            for &i in ix {
                e.u32(gammas[i] as u32);
            }
            e.count(ix.len());
            for &i in ix {
                e.f64(temps[i]);
            }
        }
    }
    e.u64(seed);
    e.finish();
    *buf = e.buf;
}

/// Encode a `VerifyReq` frame from borrowed engine slices into `buf`.
#[allow(clippy::too_many_arguments)]
pub fn encode_verify_req(
    buf: &mut Vec<u8>,
    op: u64,
    state_ops: &[StateOp],
    seqs: &[u64],
    feed: &[u32],
    drafts: &[Vec<u32>],
    temps: &[f64],
    budget: Option<u64>,
) {
    let mut e = Enc {
        buf: std::mem::take(buf),
    };
    e.buf.clear();
    e.header(TAG_VERIFY_REQ, op);
    e.state_ops(state_ops);
    e.vec_u64(seqs);
    e.vec_u32(feed);
    e.vec_vec_u32(drafts);
    e.vec_f64(temps);
    match budget {
        None => e.u8(0),
        Some(b) => {
            e.u8(1);
            e.u64(b);
        }
    }
    e.finish();
    *buf = e.buf;
}

/// Encode a `PrefillChunk` frame from the borrowed batch into `buf`.
pub fn encode_prefill_chunk(
    buf: &mut Vec<u8>,
    op: u64,
    state_ops: &[StateOp],
    batch: &[(u64, Vec<u32>)],
) {
    let mut e = Enc {
        buf: std::mem::take(buf),
    };
    e.buf.clear();
    e.header(TAG_PREFILL_CHUNK, op);
    e.state_ops(state_ops);
    e.count(batch.len());
    for (seq, prompt) in batch {
        e.u64(*seq);
        e.vec_u32(prompt);
    }
    e.finish();
    *buf = e.buf;
}

/// Encode an `AdmitEvict` flush from the borrowed state-op queue.
pub fn encode_admit_evict(buf: &mut Vec<u8>, op: u64, state_ops: &[StateOp]) {
    let mut e = Enc {
        buf: std::mem::take(buf),
    };
    e.buf.clear();
    e.header(TAG_ADMIT_EVICT, op);
    e.state_ops(state_ops);
    e.finish();
    *buf = e.buf;
}

/// Encode a `ProposeResp` from the backend's borrowed outputs (worker
/// response path — the ring buffer owns `buf` afterwards).
pub fn encode_propose_resp(
    buf: &mut Vec<u8>,
    op: u64,
    tokens: &[Vec<u32>],
    probs: &[Vec<LogitsView>],
    draft_lens: &[u64],
    cost: f64,
) {
    let mut e = Enc {
        buf: std::mem::take(buf),
    };
    e.buf.clear();
    e.header(1, op);
    e.vec_vec_u32(tokens);
    e.probs(probs);
    e.vec_u64(draft_lens);
    e.f64(cost);
    e.finish();
    *buf = e.buf;
}

/// Encode a `VerifyResp` from the backend's borrowed outputs.
pub fn encode_verify_resp(
    buf: &mut Vec<u8>,
    op: u64,
    probs: &[Vec<LogitsView>],
    target_lens: &[u64],
    cost: f64,
) {
    let mut e = Enc {
        buf: std::mem::take(buf),
    };
    e.buf.clear();
    e.header(3, op);
    e.probs(probs);
    e.vec_u64(target_lens);
    e.f64(cost);
    e.finish();
    *buf = e.buf;
}

/// Encode a `PrefillDone` from borrowed length tables.
pub fn encode_prefill_done(
    buf: &mut Vec<u8>,
    op: u64,
    target_lens: &[u64],
    draft_lens: &[u64],
    cost: f64,
) {
    let mut e = Enc {
        buf: std::mem::take(buf),
    };
    e.buf.clear();
    e.header(5, op);
    e.vec_u64(target_lens);
    e.vec_u64(draft_lens);
    e.f64(cost);
    e.finish();
    *buf = e.buf;
}

/// Pooled request-decode scratch for the worker hot path: decoding a
/// propose/verify frame refills these buffers in place (count-capped
/// reads, inner row Vecs reused), so steady-state serving allocates
/// nothing on the request side. Only `rows[..n]` is live after a
/// decode; spare rows keep their capacity for later frames.
#[derive(Debug, Default)]
pub struct ReqScratch {
    pub state_ops: Vec<StateOp>,
    pub seqs: Vec<u64>,
    /// `pending` rows for propose, `drafts` rows for verify.
    pub rows: Vec<Vec<u32>>,
    /// Live row count in `rows`.
    pub n: usize,
    pub gammas: Vec<usize>,
    pub temps: Vec<f64>,
    pub feed: Vec<u32>,
    pub seed: u64,
    pub budget: Option<u64>,
}

/// Header validation shared by the scratch decoders: identical checks to
/// [`Frame::decode`], plus a tag match.
fn req_body(bytes: &[u8], want: u8) -> Result<Dec<'_>> {
    let (_, tag) = peek_header(bytes)?;
    if tag != want {
        return Err(WireError::BadTag {
            what: "request",
            tag,
        });
    }
    Ok(Dec {
        buf: bytes,
        pos: OP_ID_OFFSET + 8,
    })
}

/// Decode a `ProposeReq` body into pooled scratch. Field semantics match
/// [`Frame::decode`] exactly (including the trailing-bytes check).
pub fn decode_propose_req(bytes: &[u8], s: &mut ReqScratch) -> Result<()> {
    let mut d = req_body(bytes, TAG_PROPOSE_REQ)?;
    d.state_ops_into(&mut s.state_ops)?;
    d.vec_u64_into(&mut s.seqs)?;
    s.n = d.rows_into(&mut s.rows)?;
    let (n, cap) = d.count(4)?;
    s.gammas.clear();
    s.gammas.reserve(cap);
    for _ in 0..n {
        s.gammas.push(d.u32()? as usize);
    }
    d.vec_f64_into(&mut s.temps)?;
    s.seed = d.u64()?;
    if d.remaining() != 0 {
        return Err(WireError::Trailing {
            extra: d.remaining(),
        });
    }
    Ok(())
}

/// Decode a `VerifyReq` body into pooled scratch (`rows` = drafts).
pub fn decode_verify_req(bytes: &[u8], s: &mut ReqScratch) -> Result<()> {
    let mut d = req_body(bytes, TAG_VERIFY_REQ)?;
    d.state_ops_into(&mut s.state_ops)?;
    d.vec_u64_into(&mut s.seqs)?;
    d.vec_u32_into(&mut s.feed)?;
    s.n = d.rows_into(&mut s.rows)?;
    d.vec_f64_into(&mut s.temps)?;
    s.budget = d.opt_u64()?;
    if d.remaining() != 0 {
        return Err(WireError::Trailing {
            extra: d.remaining(),
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt(f: Frame) {
        let bytes = f.encode();
        let back = Frame::decode(&bytes).expect("decode");
        assert_eq!(back, f);
    }

    #[test]
    fn roundtrip_basic_frames() {
        rt(Frame {
            op: 7,
            subject: Subject::Heartbeat { nonce: 99 },
        });
        rt(Frame {
            op: 8,
            subject: Subject::AdmitEvictAck,
        });
        rt(Frame {
            op: 1,
            subject: Subject::ProposeReq {
                state_ops: vec![
                    StateOp::SyncBase { seq: 3, len: 10 },
                    StateOp::Release { seq: 4 },
                ],
                seqs: vec![3, 5],
                pending: vec![vec![1, 2], vec![]],
                gammas: vec![4, 0],
                temps: vec![0.0, 0.7],
                seed: 42,
            },
        });
        rt(Frame {
            op: 2,
            subject: Subject::VerifyResp {
                probs: vec![vec![
                    LogitsView::OneHot { token: 5, vocab: 64 },
                    LogitsView::TopK {
                        entries: vec![(1, 0.5), (9, 0.5)],
                        vocab: 64,
                    },
                    LogitsView::Dense(vec![0.25; 4]),
                ]],
                target_lens: vec![11],
                cost: 1.5e-3,
            },
        });
    }

    #[test]
    fn truncation_is_typed_not_a_panic() {
        let bytes = Frame {
            op: 1,
            subject: Subject::Heartbeat { nonce: 5 },
        }
        .encode();
        for cut in 0..bytes.len() {
            let err = Frame::decode(&bytes[..cut]).unwrap_err();
            assert!(
                matches!(err, WireError::Truncated { .. }),
                "cut at {cut}: {err:?}"
            );
        }
    }

    #[test]
    fn oversized_and_trailing_rejected() {
        let mut bytes = ((MAX_FRAME_BYTES + 1) as u32).to_le_bytes().to_vec();
        bytes.extend_from_slice(&[0u8; 16]);
        assert!(matches!(
            Frame::decode(&bytes),
            Err(WireError::Oversized { .. })
        ));
        let mut ok = Frame {
            op: 1,
            subject: Subject::StatsPull,
        }
        .encode();
        ok.push(0xFF);
        assert!(matches!(
            Frame::decode(&ok),
            Err(WireError::Trailing { extra: 1 })
        ));
    }

    #[test]
    fn bad_version_and_tag_rejected() {
        let mut bytes = Frame {
            op: 1,
            subject: Subject::StatsPull,
        }
        .encode();
        bytes[4] = 99; // version byte
        assert_eq!(Frame::decode(&bytes), Err(WireError::BadVersion(99)));
        bytes[4] = WIRE_VERSION;
        bytes[5] = 200; // subject tag
        assert_eq!(
            Frame::decode(&bytes),
            Err(WireError::BadTag {
                what: "subject",
                tag: 200
            })
        );
    }

    #[test]
    fn borrowed_encoders_match_frame_encode() {
        let state_ops = vec![
            StateOp::SyncBase { seq: 3, len: 10 },
            StateOp::Release { seq: 4 },
        ];
        let seqs: Vec<u64> = vec![3, 5];
        let pending = vec![vec![1u32, 2], vec![]];
        let gammas_us: Vec<usize> = vec![4, 0];
        let temps = vec![0.0, 0.7];

        let golden = Frame {
            op: 9,
            subject: Subject::ProposeReq {
                state_ops: state_ops.clone(),
                seqs: seqs.clone(),
                pending: pending.clone(),
                gammas: vec![4, 0],
                temps: temps.clone(),
                seed: 42,
            },
        }
        .encode();
        let mut buf = Vec::new();
        encode_propose_req(
            &mut buf, 9, &state_ops, &seqs, &pending, &gammas_us, &temps, 42, None,
        );
        assert_eq!(buf, golden);
        // The indexed (stripe) gather with the identity index is
        // byte-identical too.
        encode_propose_req(
            &mut buf,
            9,
            &state_ops,
            &seqs,
            &pending,
            &gammas_us,
            &temps,
            42,
            Some(&[0, 1]),
        );
        assert_eq!(buf, golden);
        // A strict-subset stripe equals encoding the gathered rows.
        let sub = Frame {
            op: 9,
            subject: Subject::ProposeReq {
                state_ops: state_ops.clone(),
                seqs: vec![5],
                pending: vec![vec![]],
                gammas: vec![0],
                temps: vec![0.7],
                seed: 42,
            },
        }
        .encode();
        encode_propose_req(
            &mut buf, 9, &state_ops, &seqs, &pending, &gammas_us, &temps, 42, Some(&[1]),
        );
        assert_eq!(buf, sub);

        let golden = Frame {
            op: 11,
            subject: Subject::VerifyReq {
                state_ops: state_ops.clone(),
                seqs: seqs.clone(),
                feed: vec![7, 8],
                drafts: pending.clone(),
                temps: temps.clone(),
                budget: Some(16),
            },
        }
        .encode();
        encode_verify_req(
            &mut buf,
            11,
            &state_ops,
            &seqs,
            &[7, 8],
            &pending,
            &temps,
            Some(16),
        );
        assert_eq!(buf, golden);

        let batch = vec![(3u64, vec![1u32, 2, 3]), (5, vec![9])];
        let golden = Frame {
            op: 12,
            subject: Subject::PrefillChunk {
                state_ops: state_ops.clone(),
                batch: batch.clone(),
            },
        }
        .encode();
        encode_prefill_chunk(&mut buf, 12, &state_ops, &batch);
        assert_eq!(buf, golden);

        let golden = Frame {
            op: 13,
            subject: Subject::AdmitEvict {
                state_ops: state_ops.clone(),
            },
        }
        .encode();
        encode_admit_evict(&mut buf, 13, &state_ops);
        assert_eq!(buf, golden);

        let probs = vec![vec![LogitsView::OneHot { token: 5, vocab: 64 }]];
        let golden = Frame {
            op: 14,
            subject: Subject::ProposeResp {
                tokens: vec![vec![5]],
                probs: probs.clone(),
                draft_lens: vec![10],
                cost: 1.5,
            },
        }
        .encode();
        encode_propose_resp(&mut buf, 14, &[vec![5]], &probs, &[10], 1.5);
        assert_eq!(buf, golden);

        let golden = Frame {
            op: 15,
            subject: Subject::VerifyResp {
                probs: probs.clone(),
                target_lens: vec![11],
                cost: 0.5,
            },
        }
        .encode();
        encode_verify_resp(&mut buf, 15, &probs, &[11], 0.5);
        assert_eq!(buf, golden);

        let golden = Frame {
            op: 16,
            subject: Subject::PrefillDone {
                target_lens: vec![4],
                draft_lens: vec![4],
                cost: 2.0,
            },
        }
        .encode();
        encode_prefill_done(&mut buf, 16, &[4], &[4], 2.0);
        assert_eq!(buf, golden);
    }

    #[test]
    fn scratch_decode_matches_frame_decode_and_pools_rows() {
        let frame = Frame {
            op: 21,
            subject: Subject::ProposeReq {
                state_ops: vec![StateOp::RollbackDraft { seq: 1, len: 2 }],
                seqs: vec![1, 2, 3],
                pending: vec![vec![10, 11], vec![12], vec![]],
                gammas: vec![2, 1, 0],
                temps: vec![0.0, 0.0, 1.0],
                seed: 77,
            },
        };
        let bytes = frame.encode();
        let mut s = ReqScratch::default();
        decode_propose_req(&bytes, &mut s).unwrap();
        assert_eq!(s.seqs, vec![1, 2, 3]);
        assert_eq!(s.n, 3);
        assert_eq!(&s.rows[..s.n], &[vec![10, 11], vec![12], vec![]]);
        assert_eq!(s.gammas, vec![2, 1, 0]);
        assert_eq!(s.seed, 77);

        // A smaller follow-up frame reuses the pooled rows: live count
        // shrinks, spare rows keep their capacity.
        let frame2 = Frame {
            op: 22,
            subject: Subject::VerifyReq {
                state_ops: vec![],
                seqs: vec![9],
                feed: vec![5],
                drafts: vec![vec![6, 7, 8]],
                temps: vec![0.0],
                budget: None,
            },
        };
        decode_verify_req(&frame2.encode(), &mut s).unwrap();
        assert_eq!(s.n, 1);
        assert_eq!(&s.rows[..s.n], &[vec![6, 7, 8]]);
        assert_eq!(s.feed, vec![5]);
        assert_eq!(s.budget, None);
        assert!(s.rows.len() >= 3, "spare rows stay pooled");

        // Truncated bytes give typed errors, never panics.
        for cut in 0..bytes.len() {
            assert!(decode_propose_req(&bytes[..cut], &mut s).is_err());
        }
    }

    #[test]
    fn peek_and_patch_op() {
        let mut bytes = Frame {
            op: 40,
            subject: Subject::VerifyReq {
                state_ops: vec![],
                seqs: vec![1],
                feed: vec![2],
                drafts: vec![vec![3]],
                temps: vec![0.0],
                budget: None,
            },
        }
        .encode();
        assert_eq!(peek_header(&bytes).unwrap(), (40, TAG_VERIFY_REQ));
        assert!(peek_is_compute(&bytes));
        patch_op(&mut bytes, 99);
        let back = Frame::decode(&bytes).unwrap();
        assert_eq!(back.op, 99);
        let hb = Frame {
            op: 1,
            subject: Subject::Heartbeat { nonce: 7 },
        }
        .encode();
        assert_eq!(peek_header(&hb).unwrap(), (1, TAG_HEARTBEAT));
        assert!(!peek_is_compute(&hb));
        assert!(peek_header(&[1, 2, 3]).is_err());
    }

    #[test]
    fn f64_bits_roundtrip_exactly() {
        for v in [0.0, -0.0, 1.5e-9, f64::MAX, f64::MIN_POSITIVE, 0.1 + 0.2] {
            rt(Frame {
                op: 0,
                subject: Subject::PrefillDone {
                    target_lens: vec![],
                    draft_lens: vec![],
                    cost: v,
                },
            });
        }
    }
}
