//! Message-passing coordinator/worker distributed engine.
//!
//! The structural split the ROADMAP's rack-scale items build on: the
//! coordinator keeps the scheduler, control plane, KV bookkeeping, and
//! all rejection-sampling RNG; draft and verify work executes on worker
//! threads behind a [`transport::Transport`]. The protocol
//! ([`wire::Frame`]/[`wire::Subject`]) is length-prefix encoded even
//! in-process, so lifting to sockets changes the transport impl and
//! nothing else.
//!
//! Module map:
//!
//! | module | what lives there |
//! |---|---|
//! | [`wire`] | frame/subject enums, hand-rolled codec, borrowed-slice encoders, pooled decode |
//! | [`transport`] | `Transport` trait (byte-addressed), in-process channels, fault injection |
//! | [`worker`] | worker thread loop: role-filtered state ops, retransmit-dedup ring |
//! | [`coordinator`] | `DistBackend` (an `SdBackend`): pipelined in-flight ops, op-log compaction, draft striping |
//!
//! Entry point: [`DistBackend::launch`] with a backend factory, then
//! hand the result to `Engine::new` or `Server::start_with_opts` like
//! any other backend. `--dist-workers N` on `moesd serve` does exactly
//! that with `N` verify ranks; `--draft-workers M` adds `M − 1` extra
//! draft replicas that the propose path stripes across.
//!
//! The hot path is zero-copy end to end: requests encode once from
//! engine-native slices into an `Arc`-shared buffer that serves the
//! wire, the recovery log, and any retransmit; workers decode into
//! pooled scratch. Non-result-bearing completions (verify fan
//! stragglers, admit/evict acks) finish *in flight*, out of order,
//! overlapping the next round's op — see [`coordinator`] for why this
//! changes no computed bit.
//!
//! The conformance suite (`rust/tests/prop_distributed.rs`) pins the
//! load-bearing property: a distributed engine on the loopback fabric
//! is bit-for-bit the single-process engine — same tokens, same clock,
//! same metrics — for any worker count, with pipelining and compaction
//! on, under faults included (`rust/tests/fault_injection.rs`).

pub mod coordinator;
pub mod transport;
pub mod wire;
pub mod worker;

pub use coordinator::{stripe_seed, DistBackend, DistConfig, DistFabric, DistStatus, WorkerHealth};
pub use transport::{FaultPlan, FaultyTransport, InProcTransport, Transport, TransportError};
pub use wire::{Frame, StateOp, Subject, WireError, WorkerStats};
pub use worker::{Role, WorkerOptions, REPLAY_RING};
