//! Message-passing coordinator/worker distributed engine.
//!
//! The structural split the ROADMAP's rack-scale items build on: the
//! coordinator keeps the scheduler, control plane, KV bookkeeping, and
//! all rejection-sampling RNG; draft and verify work executes on worker
//! threads behind a [`transport::Transport`]. The protocol
//! ([`wire::Frame`]/[`wire::Subject`]) is length-prefix encoded even
//! in-process, so lifting to sockets changes the transport impl and
//! nothing else.
//!
//! Module map:
//!
//! | module | what lives there |
//! |---|---|
//! | [`wire`] | frame/subject enums, hand-rolled codec, typed `WireError` |
//! | [`transport`] | `Transport` trait, in-process channels, fault injection |
//! | [`worker`] | worker thread loop: role-filtered state ops, idempotent replay |
//! | [`coordinator`] | `DistBackend` (an `SdBackend`), deadlines/retry/respawn, health |
//!
//! Entry point: [`DistBackend::launch`] with a backend factory, then
//! hand the result to `Engine::new` or `Server::start_with_opts` like
//! any other backend. `--dist-workers N` on `moesd serve` does exactly
//! that with `N` verify ranks.
//!
//! The conformance suite (`rust/tests/prop_distributed.rs`) pins the
//! load-bearing property: a distributed engine on the loopback fabric
//! is bit-for-bit the single-process engine — same tokens, same clock,
//! same metrics — for any worker count, under faults included
//! (`rust/tests/fault_injection.rs`).

pub mod coordinator;
pub mod transport;
pub mod wire;
pub mod worker;

pub use coordinator::{DistBackend, DistConfig, DistFabric, DistStatus, WorkerHealth};
pub use transport::{FaultPlan, FaultyTransport, InProcTransport, Transport, TransportError};
pub use wire::{Frame, StateOp, Subject, WireError, WorkerStats};
pub use worker::{Role, WorkerOptions};
