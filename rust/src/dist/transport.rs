//! Transports carry [`Frame`]s between the coordinator and its workers.
//!
//! The coordinator is written against the [`Transport`] trait so the
//! in-process channel implementation here and a future socket
//! implementation are interchangeable. Even in-process, every frame is
//! round-tripped through the wire codec — the channels carry encoded
//! bytes, not `Frame` values — so the codec is exercised on every op and
//! nothing can accidentally depend on sharing memory with a worker.
//!
//! [`FaultyTransport`] wraps any transport and injects deterministic,
//! counter-based faults (dropped requests, dropped/delayed responses)
//! for the fault-injection test suite. Faults are counted per compute
//! frame, not wall-clock timed, so failing runs replay exactly.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

use super::wire::{Frame, WireError};

/// Transport-level failure, distinct from protocol-level errors carried
/// inside frames ([`super::wire::Subject::ErrorResp`]).
#[derive(Debug)]
pub enum TransportError {
    /// The peer's channel is gone (worker thread exited or panicked).
    Closed,
    /// No frame arrived within the deadline.
    Timeout,
    /// A frame failed to decode.
    Wire(WireError),
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Closed => write!(f, "transport closed"),
            TransportError::Timeout => write!(f, "transport timeout"),
            TransportError::Wire(e) => write!(f, "wire error: {e}"),
        }
    }
}

impl std::error::Error for TransportError {}

/// Coordinator-side view of the worker fabric.
///
/// `send` is addressed (coordinator → worker `w`); `recv_timeout` drains
/// a single shared upstream queue and reports which worker a frame came
/// from, because responses from fanned-out ranks arrive in any order.
pub trait Transport: Send {
    /// Send pre-encoded frame bytes to worker `w` — the zero-copy hot
    /// path: the coordinator encodes once per op fan and the same
    /// buffer serves every rank, the op log, and any retransmit.
    /// `Closed` means the worker is dead.
    fn send_bytes(&mut self, w: usize, bytes: &[u8]) -> Result<(), TransportError>;
    /// Convenience wrapper for control traffic (encodes per call).
    fn send(&mut self, w: usize, frame: &Frame) -> Result<(), TransportError> {
        self.send_bytes(w, &frame.encode())
    }
    /// Wait up to `timeout` for any worker's next frame.
    fn recv_timeout(&mut self, timeout: Duration) -> Result<(usize, Frame), TransportError>;
    /// Number of worker slots (fixed at construction).
    fn workers(&self) -> usize;
    /// Frames sent to worker `w` not yet consumed by it.
    fn queue_depth(&self, w: usize) -> usize;
    /// Whether worker `w`'s endpoint is still held by a live thread.
    fn is_attached(&self, w: usize) -> bool;
    /// Replace worker `w`'s channel pair, returning a fresh endpoint for
    /// a respawned worker thread. Frames queued to the dead worker are
    /// dropped (the coordinator re-drives state via its op log).
    fn reattach(&mut self, w: usize) -> WorkerEndpoint;
}

struct Link {
    tx: Sender<Vec<u8>>,
    depth: Arc<AtomicUsize>,
    alive: Arc<AtomicBool>,
}

/// Channel-pair transport: one downstream byte channel per worker, one
/// shared upstream channel. The coordinator keeps an upstream sender
/// clone so `recv_timeout` reports `Timeout` (not `Closed`) even when
/// every worker has exited.
pub struct InProcTransport {
    links: Vec<Link>,
    up_rx: Receiver<(usize, Vec<u8>)>,
    up_tx: Sender<(usize, Vec<u8>)>,
}

/// Worker-side half of one link. Dropping it (worker return *or* panic)
/// flips the shared liveness flag, which is how the coordinator detects
/// death without joining the thread.
pub struct WorkerEndpoint {
    idx: usize,
    rx: Receiver<Vec<u8>>,
    up: Sender<(usize, Vec<u8>)>,
    depth: Arc<AtomicUsize>,
    alive: Arc<AtomicBool>,
}

impl InProcTransport {
    /// Build a transport with `n` worker slots, returning the worker
    /// endpoints to hand to worker threads (index order).
    pub fn new(n: usize) -> (Self, Vec<WorkerEndpoint>) {
        let (up_tx, up_rx) = channel();
        let mut links = Vec::with_capacity(n);
        let mut endpoints = Vec::with_capacity(n);
        for idx in 0..n {
            let (tx, rx) = channel();
            let depth = Arc::new(AtomicUsize::new(0));
            let alive = Arc::new(AtomicBool::new(true));
            links.push(Link {
                tx,
                depth: Arc::clone(&depth),
                alive: Arc::clone(&alive),
            });
            endpoints.push(WorkerEndpoint {
                idx,
                rx,
                up: up_tx.clone(),
                depth,
                alive,
            });
        }
        (
            InProcTransport {
                links,
                up_rx,
                up_tx,
            },
            endpoints,
        )
    }
}

impl Transport for InProcTransport {
    fn send_bytes(&mut self, w: usize, bytes: &[u8]) -> Result<(), TransportError> {
        let link = &self.links[w];
        if !link.alive.load(Ordering::SeqCst) {
            return Err(TransportError::Closed);
        }
        // The single copy a socket write would also pay; the channel
        // owns its message like the kernel owns a send buffer.
        link.depth.fetch_add(1, Ordering::SeqCst);
        link.tx.send(bytes.to_vec()).map_err(|_| {
            link.depth.fetch_sub(1, Ordering::SeqCst);
            TransportError::Closed
        })
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<(usize, Frame), TransportError> {
        match self.up_rx.recv_timeout(timeout) {
            Ok((w, bytes)) => Frame::decode(&bytes)
                .map(|f| (w, f))
                .map_err(TransportError::Wire),
            Err(RecvTimeoutError::Timeout) => Err(TransportError::Timeout),
            // Unreachable while self.up_tx is held, but map it anyway.
            Err(RecvTimeoutError::Disconnected) => Err(TransportError::Closed),
        }
    }

    fn workers(&self) -> usize {
        self.links.len()
    }

    fn queue_depth(&self, w: usize) -> usize {
        self.links[w].depth.load(Ordering::SeqCst)
    }

    fn is_attached(&self, w: usize) -> bool {
        self.links[w].alive.load(Ordering::SeqCst)
    }

    fn reattach(&mut self, w: usize) -> WorkerEndpoint {
        let (tx, rx) = channel();
        let depth = Arc::new(AtomicUsize::new(0));
        let alive = Arc::new(AtomicBool::new(true));
        self.links[w] = Link {
            tx,
            depth: Arc::clone(&depth),
            alive: Arc::clone(&alive),
        };
        WorkerEndpoint {
            idx: w,
            rx,
            up: self.up_tx.clone(),
            depth,
            alive,
        }
    }
}

impl WorkerEndpoint {
    /// This endpoint's worker index (what the coordinator addresses).
    pub fn index(&self) -> usize {
        self.idx
    }

    /// Block for the next raw frame bytes. `None` means the coordinator
    /// hung up — the worker should exit. The worker's serve loop decodes
    /// into pooled scratch from here.
    pub fn recv_bytes(&self) -> Option<Vec<u8>> {
        let bytes = self.rx.recv().ok()?;
        self.depth.fetch_sub(1, Ordering::SeqCst);
        Some(bytes)
    }

    /// Block for the next decodable frame. Undecodable frames are
    /// skipped (the coordinator's retry path re-sends; the worker cannot
    /// reply to a frame it cannot parse).
    pub fn recv(&self) -> Option<Frame> {
        loop {
            let bytes = self.recv_bytes()?;
            if let Ok(frame) = Frame::decode(&bytes) {
                return Some(frame);
            }
        }
    }

    /// Send pre-encoded frame bytes upstream, taking ownership (the
    /// channel is the wire). Returns false if the coordinator is gone.
    pub fn send_bytes(&self, bytes: Vec<u8>) -> bool {
        self.up.send((self.idx, bytes)).is_ok()
    }

    /// Send a frame upstream. Returns false if the coordinator is gone.
    pub fn send(&self, frame: &Frame) -> bool {
        self.send_bytes(frame.encode())
    }
}

impl Drop for WorkerEndpoint {
    fn drop(&mut self) {
        // Runs on worker return and on worker panic alike: the liveness
        // flag is the coordinator's death signal.
        self.alive.store(false, Ordering::SeqCst);
    }
}

/// Deterministic fault plan for [`FaultyTransport`]. Counters tick once
/// per *compute* frame (propose/verify/prefill) so control traffic and
/// retransmits of dropped frames don't shift the schedule chaotically;
/// `None` disables that fault.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Silently drop every Nth compute request (worker never sees it).
    pub drop_req_every: Option<u64>,
    /// Drop every Nth compute response (coordinator times out; the
    /// worker has executed and cached the op, so the retry exercises
    /// the idempotency path).
    pub drop_resp_every: Option<u64>,
    /// Delay every Nth compute response past the deadline: the
    /// coordinator times out and retries, then the held response is
    /// delivered *before* the retry's — exercising late-duplicate
    /// discard on whichever copy loses the race.
    pub delay_resp_every: Option<u64>,
}

/// Wraps a transport and injects the faults described by a [`FaultPlan`].
pub struct FaultyTransport<T: Transport> {
    inner: T,
    plan: FaultPlan,
    sent_reqs: u64,
    recvd: u64,
    held: VecDeque<(usize, Frame)>,
}

impl<T: Transport> FaultyTransport<T> {
    pub fn new(inner: T, plan: FaultPlan) -> Self {
        FaultyTransport {
            inner,
            plan,
            sent_reqs: 0,
            recvd: 0,
            held: VecDeque::new(),
        }
    }

    fn nth(count: u64, every: Option<u64>) -> bool {
        matches!(every, Some(n) if n > 0 && count % n == 0)
    }
}

impl<T: Transport> Transport for FaultyTransport<T> {
    fn send_bytes(&mut self, w: usize, bytes: &[u8]) -> Result<(), TransportError> {
        // Classification peeks the tag byte: the zero-copy path never
        // materializes a `Subject` on the send side.
        if super::wire::peek_is_compute(bytes) {
            self.sent_reqs += 1;
            if Self::nth(self.sent_reqs, self.plan.drop_req_every) {
                // Lost on the wire: report success, deliver nothing.
                return Ok(());
            }
        }
        self.inner.send_bytes(w, bytes)
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<(usize, Frame), TransportError> {
        // Held (delayed) responses are delivered ahead of fresh traffic:
        // by the time the coordinator listens again it has already timed
        // out and retried, so this frame arrives as a late duplicate.
        if let Some(held) = self.held.pop_front() {
            return Ok(held);
        }
        let (w, frame) = self.inner.recv_timeout(timeout)?;
        // Response-side faults key off compute responses only; acks and
        // heartbeats pass through untouched.
        let computeish = matches!(
            frame.subject,
            super::wire::Subject::ProposeResp { .. }
                | super::wire::Subject::VerifyResp { .. }
                | super::wire::Subject::PrefillDone { .. }
                | super::wire::Subject::ErrorResp { .. }
        );
        if computeish {
            self.recvd += 1;
            if Self::nth(self.recvd, self.plan.drop_resp_every) {
                return Err(TransportError::Timeout);
            }
            if Self::nth(self.recvd, self.plan.delay_resp_every) {
                self.held.push_back((w, frame));
                return Err(TransportError::Timeout);
            }
        }
        Ok((w, frame))
    }

    fn workers(&self) -> usize {
        self.inner.workers()
    }

    fn queue_depth(&self, w: usize) -> usize {
        self.inner.queue_depth(w)
    }

    fn is_attached(&self, w: usize) -> bool {
        self.inner.is_attached(w)
    }

    fn reattach(&mut self, w: usize) -> WorkerEndpoint {
        self.inner.reattach(w)
    }
}

#[cfg(test)]
mod tests {
    use super::super::wire::Subject;
    use super::*;

    #[test]
    fn frames_roundtrip_through_channels() {
        let (mut t, eps) = InProcTransport::new(2);
        let f = Frame {
            op: 5,
            subject: Subject::Heartbeat { nonce: 1 },
        };
        t.send(1, &f).unwrap();
        assert_eq!(t.queue_depth(1), 1);
        let got = eps[1].recv().unwrap();
        assert_eq!(got, f);
        assert_eq!(t.queue_depth(1), 0);
        assert!(eps[1].send(&Frame {
            op: 5,
            subject: Subject::HeartbeatAck { nonce: 1 },
        }));
        let (w, resp) = t.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(w, 1);
        assert!(matches!(resp.subject, Subject::HeartbeatAck { nonce: 1 }));
    }

    #[test]
    fn dropping_endpoint_detaches() {
        let (mut t, eps) = InProcTransport::new(1);
        assert!(t.is_attached(0));
        drop(eps);
        assert!(!t.is_attached(0));
        let err = t
            .send(
                0,
                &Frame {
                    op: 1,
                    subject: Subject::StatsPull,
                },
            )
            .unwrap_err();
        assert!(matches!(err, TransportError::Closed));
        // Reattach yields a live endpoint on the same slot.
        let ep = t.reattach(0);
        assert!(t.is_attached(0));
        assert_eq!(ep.index(), 0);
    }

    #[test]
    fn recv_times_out_rather_than_closing() {
        let (mut t, _eps) = InProcTransport::new(1);
        let err = t.recv_timeout(Duration::from_millis(10)).unwrap_err();
        assert!(matches!(err, TransportError::Timeout));
    }

    #[test]
    fn faulty_transport_drops_every_nth_request() {
        let (inner, eps) = InProcTransport::new(1);
        let mut t = FaultyTransport::new(
            inner,
            FaultPlan {
                drop_req_every: Some(2),
                ..FaultPlan::default()
            },
        );
        let compute = Frame {
            op: 1,
            subject: Subject::ProposeReq {
                state_ops: vec![],
                seqs: vec![],
                pending: vec![],
                gammas: vec![],
                temps: vec![],
                seed: 0,
            },
        };
        for _ in 0..4 {
            t.send(0, &compute).unwrap();
        }
        // 1st and 3rd delivered, 2nd and 4th dropped.
        assert_eq!(t.queue_depth(0), 2);
        drop(eps);
    }
}
