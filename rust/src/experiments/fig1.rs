//! Fig. 1 — expert activation statistics.
//!
//! (a)/(b): theoretical N(t) (Eq. 8) vs empirically sampled routing for
//! DeepSeek-V2-Lite (ρ=6/62) and Qwen1.5-MoE (ρ=4/60).
//! (c): normalized per-expert load T̄_exp(T; ρ) vs sparsity ρ.

use crate::arch::{presets, ModelArch};
use crate::simulator::routing::Router;
use crate::theory;
use crate::util::csv::CsvTable;
use crate::util::rng::Rng;

/// One activation-curve sample.
#[derive(Debug, Clone, Copy)]
pub struct ActivationPoint {
    pub tokens: u64,
    pub theory: f64,
    pub empirical: f64,
}

/// Theoretical vs empirical N(t) for a model (Fig. 1a/b).
pub fn activation_curve(
    model: &ModelArch,
    token_counts: &[u64],
    trials: usize,
    seed: u64,
) -> Vec<ActivationPoint> {
    let e = model.experts();
    let k = model.topk();
    let router = Router::balanced(e, k);
    let mut rng = Rng::seeded(seed);
    token_counts
        .iter()
        .map(|&t| ActivationPoint {
            tokens: t,
            theory: theory::expected_active_experts(e, k, t),
            empirical: router.empirical_activation(t, trials, &mut rng),
        })
        .collect()
}

/// T̄_exp(T; ρ)/T vs ρ for several T (Fig. 1c: normalized per-expert load).
pub fn expert_load_curve(rhos: &[f64], token_counts: &[f64]) -> CsvTable {
    let mut header = vec!["rho".to_string()];
    for &t in token_counts {
        header.push(format!("texp_norm_T{}", t as u64));
    }
    let mut table = CsvTable {
        header,
        rows: Vec::new(),
    };
    for &rho in rhos {
        let mut row = vec![crate::util::csv::format_num(rho)];
        for &t in token_counts {
            row.push(crate::util::csv::format_num(theory::expert_load(t, rho) / t));
        }
        table.rows.push(row);
    }
    table
}

/// The full Fig. 1 experiment: returns (fig1a, fig1b, fig1c) tables.
pub fn run(trials: usize, seed: u64) -> (CsvTable, CsvTable, CsvTable) {
    let ts: Vec<u64> = (0..10).map(|i| 1u64 << i).collect();
    let mk = |model: &ModelArch| -> CsvTable {
        let mut t = CsvTable::new(&["tokens", "theory", "empirical"]);
        for p in activation_curve(model, &ts, trials, seed) {
            t.push_nums(&[p.tokens as f64, p.theory, p.empirical]);
        }
        t
    };
    let fig1a = mk(&presets::deepseek_v2_lite());
    let fig1b = mk(&presets::qwen15_moe());
    let rhos: Vec<f64> = (1..=40).map(|i| i as f64 * 0.025).collect();
    let fig1c = expert_load_curve(&rhos, &[8.0, 32.0, 128.0]);
    (fig1a, fig1b, fig1c)
}

/// Shape claims for the bench gate.
pub fn max_rel_error(points: &[ActivationPoint]) -> f64 {
    points
        .iter()
        .map(|p| (p.theory - p.empirical).abs() / p.theory.max(1.0))
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theory_matches_sampled_routing() {
        // The Fig. 1a/b claim: the i.i.d. derivation matches real routing.
        for model in [presets::deepseek_v2_lite(), presets::qwen15_moe()] {
            let pts = activation_curve(&model, &[1, 8, 64, 256], 300, 1);
            assert!(
                max_rel_error(&pts) < 0.05,
                "{}: rel err {}",
                model.name,
                max_rel_error(&pts)
            );
        }
    }

    #[test]
    fn load_curve_monotone_in_rho() {
        let t = expert_load_curve(&[0.05, 0.2, 0.5, 1.0], &[32.0]);
        let col = t.column_f64("texp_norm_T32").unwrap();
        for w in col.windows(2) {
            assert!(w[1] >= w[0] - 1e-12, "not monotone: {col:?}");
        }
        // Dense endpoint: T̄_exp/T = 1.
        assert!((col.last().unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn run_produces_full_tables() {
        let (a, b, c) = run(50, 2);
        assert_eq!(a.rows.len(), 10);
        assert_eq!(b.rows.len(), 10);
        assert_eq!(c.rows.len(), 40);
    }
}
