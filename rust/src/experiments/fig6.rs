//! Fig. 6 — end-to-end SD speedup: MoE vs dense models across datasets
//! and temperatures (App. A.2).

use super::{paper_batch_grid, run_pair_grid, RunOpts};
use crate::arch::presets;
use crate::hardware::platform_2x_gpu_a;
use crate::util::csv::CsvTable;
use crate::workload::{calibrated_alpha, Dataset};

pub struct Fig6Output {
    pub table: CsvTable,
    pub moe: Vec<f64>,
    pub dense: Vec<f64>,
    pub batches: Vec<usize>,
}

pub fn run(dataset: Dataset, temp: f64, gamma: usize, seed: u64) -> anyhow::Result<Fig6Output> {
    let platform = platform_2x_gpu_a();
    let batches = paper_batch_grid();
    let opts = RunOpts {
        seed,
        max_new_tokens: 24,
        ..Default::default()
    };

    let moe_alpha = calibrated_alpha("qwen2", dataset, temp, gamma);
    let dense_alpha = calibrated_alpha("opt", dataset, temp, gamma);
    let (moe_t, moe_d) = (presets::qwen2_57b_a14b(), presets::qwen2_0_5b());
    let (opt_t, opt_d) = (presets::opt_30b(), presets::opt_350m());

    let moe_stats = run_pair_grid(&moe_t, &moe_d, &platform, moe_alpha, gamma, &batches, &opts)?;
    let dense_stats =
        run_pair_grid(&opt_t, &opt_d, &platform, dense_alpha, gamma, &batches, &opts)?;
    let mut table = CsvTable::new(&["batch", "moe_speedup", "dense_speedup"]);
    let mut moe = Vec::new();
    let mut dense = Vec::new();
    for (i, &b) in batches.iter().enumerate() {
        moe.push(moe_stats[i].speedup);
        dense.push(dense_stats[i].speedup);
        table.push_nums(&[b as f64, moe_stats[i].speedup, dense_stats[i].speedup]);
    }
    Ok(Fig6Output {
        table,
        moe,
        dense,
        batches,
    })
}

/// Fig. 6's two observations: MoE rises-then-falls while dense only falls,
/// and MoE wins at moderate batch (B ≥ 16).
pub fn check_shape(out: &Fig6Output) -> Result<(), String> {
    let peak = crate::util::stats::argmax(&out.moe);
    if peak == 0 {
        return Err(format!("MoE speedup should rise first: {:?}", out.moe));
    }
    // Dense: overall decreasing (allow small local noise).
    let d0 = out.dense[0];
    let dlast = *out.dense.last().unwrap();
    if dlast >= d0 {
        return Err(format!("dense speedup should decay: {d0} → {dlast}"));
    }
    let mid = out.batches.iter().position(|&b| b >= 16).unwrap();
    for i in mid..out.batches.len() {
        if out.moe[i] <= out.dense[i] {
            return Err(format!(
                "MoE should beat dense at B={}: {} vs {}",
                out.batches[i], out.moe[i], out.dense[i]
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moe_beats_dense_past_b16() {
        let out = run(Dataset::HumanEval, 0.0, 3, 11).unwrap();
        check_shape(&out).unwrap();
    }
}
