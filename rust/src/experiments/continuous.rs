//! Continuous-batching ablation sweep — trace-driven load × pipeline
//! feature set (exercises the ROADMAP's "kill the synchronous round"
//! item: chunked prefill + draft-ahead overlap + per-sequence round
//! boundaries, each switched on cumulatively).
//!
//! ## Scenario
//!
//! A classless FIFO deployment (qwen2-57B + 0.5B draft on 2×GPU-A,
//! virtual clock, static γ = 4, α = 0.9) replays the *prefill-heavy*
//! bundled trace ([`ArrivalTrace::synthetic_production_heavy`]: the same
//! calm/burst Markov modulation as the production shape, but prompts
//! centered ≈256 tokens with tails to 1024). Long prompts are exactly
//! where the lock-step engine's bulk prefill stalls every running
//! sequence for whole-prompt forwards — the TTFT pathology continuous
//! batching exists to fix.
//!
//! Each (load, arm) point replays the identical request sequence through
//! the real engine and measures inside the trace window (steady-state
//! under backlog at overload, same design as [`super::multitenant`]).
//!
//! ## Arms (cumulative feature sets)
//!
//! - `lockstep` — the synchronous round engine (`PipelineConfig`
//!   default): bulk prefill at admission, global round barrier;
//! - `+chunked` — continuous pipeline with chunked prefill only (serial
//!   lanes, batch round boundaries): prompts stream in
//!   [`PREFILL_CHUNK`]-token chunks between decode rounds;
//! - `+draft-ahead` — chunked prefill plus the draft-ahead overlap:
//!   fully-accepted sequences re-draft under the current verify window,
//!   priced `max(draft, verify)` instead of `draft + verify`;
//! - `full` — all three mechanisms (adds per-sequence round boundaries
//!   with the 1/2 coalescing guard).
//!
//! `check_shape` pins the acceptance criteria: at the saturation knee
//! the full pipeline's TTFT p99 is strictly below lock-step's (and
//! ≤ 0.97×), at deep overload the full pipeline's goodput is ≥ 1.02×
//! lock-step's, and TPOT/goodput stay ≥ 0.98× lock-step at every load.
//! Every margin was calibrated against a from-scratch python replica of
//! the roofline pricing + both engine loops
//! (`python/replica_continuous.py`); see `check_shape` for the measured
//! ratios behind each bound.

use super::parallel_sweep;
use crate::arch::presets;
use crate::batching::{Completion, Request, SamplingParams, DEFAULT_CLASS};
use crate::engine::{Engine, EngineConfig, PipelineConfig};
use crate::hardware::{platform_2x_gpu_a, Platform};
use crate::kvcache::KvConfig;
use crate::scheduler::SchedulerConfig;
use crate::simulator::ExecSim;
use crate::spec::synthetic::SyntheticLm;
use crate::util::csv::CsvTable;
use crate::util::json::Json;
use crate::workload::ArrivalTrace;

/// Decode batch ceiling: inside the speculative band for this
/// model/platform, so the sweep isolates *pipeline* effects.
pub const MAX_BATCH: usize = 32;

/// True draft acceptance (uniform; the sweep is classless).
pub const ALPHA: f64 = 0.9;

/// Static speculation depth (no controller: adaptive γ would confound
/// the pipeline ablation).
pub const GAMMA: usize = 4;

/// Chunked-prefill per-op token budget for the continuous arms. 512
/// sits at the weight/compute roofline crossover of the 57B MoE target
/// (below it a chunk op re-reads all expert weights without enough
/// compute to amortize them), so chunk ops price like bulk prefill
/// while still bounding the decode bubble to ~1.5 rounds.
pub const PREFILL_CHUNK: usize = 512;

/// Trace shape: base duration and rate (before load rescaling).
pub const TRACE_DURATION_S: f64 = 120.0;
pub const TRACE_BASE_RATE: f64 = 4.0;

/// Load sweep: trace-rate multipliers (light → saturation knee → deep
/// overload). The middle point is the knee where the TTFT-tail margins
/// are pinned ([`ContinuousOut::knee_load`]); the top point is where
/// the goodput win is pinned.
pub fn default_loads() -> Vec<f64> {
    vec![0.5, 1.5, 3.0]
}

/// The four cumulative pipeline feature sets.
pub fn arms() -> Vec<(&'static str, PipelineConfig)> {
    vec![
        ("lockstep", PipelineConfig::default()),
        (
            "+chunked",
            PipelineConfig {
                continuous: true,
                prefill_chunk: Some(PREFILL_CHUNK),
                draft_ahead: false,
                per_seq_boundaries: false,
            },
        ),
        (
            "+draft-ahead",
            PipelineConfig {
                continuous: true,
                prefill_chunk: Some(PREFILL_CHUNK),
                draft_ahead: true,
                per_seq_boundaries: false,
            },
        ),
        ("full", PipelineConfig::full(PREFILL_CHUNK)),
    ]
}

/// One (load, arm) measurement.
#[derive(Debug, Clone)]
pub struct ArmRow {
    pub load: f64,
    /// `lockstep`, `+chunked`, `+draft-ahead` or `full`.
    pub arm: String,
    pub requests_offered: usize,
    pub requests_completed: u64,
    pub tokens: u64,
    /// Virtual clock at the end of the window run.
    pub clock_s: f64,
    pub ttft_mean: f64,
    pub ttft_p99: f64,
    pub tpot_mean: f64,
    pub tpot_p99: f64,
    /// Committed tokens per second of window clock — the serving-level
    /// throughput a latency ablation must not regress.
    pub goodput: f64,
    pub mean_batch: f64,
    /// Fraction of draft seconds hidden under verify windows
    /// (`time_draft_hidden / time_draft`; zero without draft-ahead).
    pub hidden_frac: f64,
    pub prefill_chunks: u64,
}

#[derive(Debug, Clone)]
pub struct ContinuousOut {
    pub rows: Vec<ArmRow>,
    pub loads: Vec<f64>,
}

fn sims() -> (ExecSim, ExecSim) {
    let platform = platform_2x_gpu_a();
    let target = ExecSim::new(presets::qwen2_57b_a14b(), platform.clone());
    let draft_platform = Platform::new(platform.gpu.clone(), 1, platform.interconnect_bw);
    let draft = ExecSim::new(presets::qwen2_0_5b(), draft_platform);
    (target, draft)
}

/// Materialize the (classless) request sequence for one load point.
fn trace_requests(trace: &ArrivalTrace, seed: u64) -> Vec<Request> {
    let _ = seed;
    trace
        .events()
        .iter()
        .enumerate()
        .map(|(i, e)| Request {
            id: i as u64,
            prompt: (0..e.prompt_len as u32).map(|p| p % 251).collect(),
            params: SamplingParams {
                temperature: 0.0,
                max_new_tokens: e.output_len,
                eos_token: None,
            },
            arrival: e.t,
            class: DEFAULT_CLASS,
        })
        .collect()
}

fn build_engine(pipeline: PipelineConfig, seed: u64) -> Engine<SyntheticLm> {
    let (tsim, dsim) = sims();
    let backend = SyntheticLm::new(tsim, dsim, ALPHA, seed);
    let config = EngineConfig {
        gamma: GAMMA,
        kv: KvConfig {
            num_blocks: 1 << 16,
            block_size: 16,
        },
        scheduler: SchedulerConfig {
            max_batch: MAX_BATCH,
            admit_reserve_tokens: 32,
            tpot_slo: None,
        },
        seed,
        pipeline,
        ..Default::default()
    };
    Engine::new(config, backend)
}

/// Replay one arm inside the trace window: submit everything, step until
/// the clock passes `horizon` (or the engine drains), keeping every
/// completion for exact latency quantiles.
fn run_arm(
    requests: &[Request],
    pipeline: PipelineConfig,
    seed: u64,
    horizon: f64,
) -> anyhow::Result<(Engine<SyntheticLm>, Vec<Completion>)> {
    let mut engine = build_engine(pipeline, seed);
    for r in requests {
        engine.submit(r.clone());
    }
    let mut done = Vec::new();
    let mut guard = 0usize;
    while !engine.is_idle() && engine.clock() < horizon {
        done.extend(engine.step()?);
        guard += 1;
        anyhow::ensure!(guard < 400_000, "window run exceeded the step guard");
    }
    anyhow::ensure!(
        engine.metrics.tokens_generated > 0,
        "arm committed no tokens inside the window"
    );
    Ok((engine, done))
}

/// Exact q-quantile over the sample set (the ⌈q·n⌉-th order statistic —
/// the value the metrics `Histogram` would bucket). The engine's
/// histograms quantize to ×2 geometric buckets, far too coarse for
/// cross-arm ratio margins, so the sweep computes latency quantiles
/// from the raw completions instead.
fn pct(xs: &mut [f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    let rank = ((q * xs.len() as f64).ceil() as usize).clamp(1, xs.len());
    xs[rank - 1]
}

fn collect(
    load: f64,
    arm: &str,
    offered: usize,
    engine: &Engine<SyntheticLm>,
    done: &[Completion],
) -> ArmRow {
    let m = &engine.metrics;
    let clock = engine.clock().max(1e-9);
    let mut ttfts: Vec<f64> = done.iter().map(Completion::ttft).collect();
    let mut tpots: Vec<f64> = done.iter().map(Completion::tpot).collect();
    let mean = |xs: &[f64]| {
        if xs.is_empty() {
            0.0
        } else {
            xs.iter().sum::<f64>() / xs.len() as f64
        }
    };
    ArmRow {
        load,
        arm: arm.to_string(),
        requests_offered: offered,
        requests_completed: m.requests_completed,
        tokens: m.tokens_generated,
        clock_s: clock,
        ttft_mean: mean(&ttfts),
        ttft_p99: pct(&mut ttfts, 0.99),
        tpot_mean: mean(&tpots),
        tpot_p99: pct(&mut tpots, 0.99),
        goodput: m.tokens_generated as f64 / clock,
        mean_batch: m.mean_batch(),
        hidden_frac: if m.time_draft > 0.0 {
            m.time_draft_hidden / m.time_draft
        } else {
            0.0
        },
        prefill_chunks: m.prefill_chunks,
    }
}

/// Run the full load × arm sweep over `trace` (each load fanned across
/// worker threads; every arm builds its own seeded engine).
pub fn run(trace: &ArrivalTrace, loads: &[f64], seed: u64) -> anyhow::Result<ContinuousOut> {
    let per_load: Vec<anyhow::Result<Vec<ArmRow>>> = parallel_sweep(loads, |&load| {
        let scaled = trace.rescale_rate(load);
        let horizon = scaled.duration().max(1e-6);
        let requests = trace_requests(&scaled, seed);
        let offered = requests.len();
        let mut rows = Vec::new();
        for (name, pipeline) in arms() {
            let (engine, done) = run_arm(&requests, pipeline, seed, horizon)?;
            rows.push(collect(load, name, offered, &engine, &done));
        }
        Ok(rows)
    });
    let mut rows = Vec::new();
    for r in per_load {
        rows.extend(r?);
    }
    Ok(ContinuousOut {
        rows,
        loads: loads.to_vec(),
    })
}

impl ContinuousOut {
    pub fn arm(&self, load: f64, arm: &str) -> Option<&ArmRow> {
        self.rows.iter().find(|r| r.load == load && r.arm == arm)
    }

    pub fn top_load(&self) -> f64 {
        self.loads.iter().cloned().fold(f64::MIN, f64::max)
    }

    /// The saturation-knee load: the median of the sweep grid. The
    /// default grid is (light, knee, deep-overload) by construction;
    /// the TTFT-tail acceptance margins are calibrated at this point
    /// because deep overload pins every arm's p99 to queue residence.
    pub fn knee_load(&self) -> f64 {
        let mut ls = self.loads.clone();
        if ls.is_empty() {
            return f64::MIN;
        }
        ls.sort_by(|a, b| a.partial_cmp(b).expect("loads are finite"));
        ls[ls.len() / 2]
    }
}

pub fn to_csv(out: &ContinuousOut) -> CsvTable {
    let mut t = CsvTable::new(&[
        "load",
        "arm",
        "offered",
        "completed",
        "tokens",
        "clock_s",
        "ttft_mean",
        "ttft_p99",
        "tpot_mean",
        "tpot_p99",
        "goodput",
        "mean_batch",
        "hidden_frac",
        "prefill_chunks",
    ]);
    for r in &out.rows {
        t.push_row(vec![
            format!("{}", r.load),
            r.arm.clone(),
            r.requests_offered.to_string(),
            r.requests_completed.to_string(),
            r.tokens.to_string(),
            format!("{:.6}", r.clock_s),
            format!("{:.6}", r.ttft_mean),
            format!("{:.6}", r.ttft_p99),
            format!("{:.6}", r.tpot_mean),
            format!("{:.6}", r.tpot_p99),
            format!("{:.2}", r.goodput),
            format!("{:.2}", r.mean_batch),
            format!("{:.4}", r.hidden_frac),
            r.prefill_chunks.to_string(),
        ]);
    }
    t
}

/// Per-arm stats JSON (the shape ci.sh's smoke gate validates).
pub fn to_json(out: &ContinuousOut) -> Json {
    let arms = out
        .rows
        .iter()
        .map(|r| {
            Json::from_pairs(vec![
                ("load", r.load.into()),
                ("arm", r.arm.as_str().into()),
                ("offered", r.requests_offered.into()),
                ("completed", r.requests_completed.into()),
                ("tokens", r.tokens.into()),
                ("ttft_mean", r.ttft_mean.into()),
                ("ttft_p99", r.ttft_p99.into()),
                ("tpot_mean", r.tpot_mean.into()),
                ("tpot_p99", r.tpot_p99.into()),
                ("goodput", r.goodput.into()),
                ("mean_batch", r.mean_batch.into()),
                ("hidden_frac", r.hidden_frac.into()),
                ("prefill_chunks", r.prefill_chunks.into()),
            ])
        })
        .collect();
    Json::from_pairs(vec![
        ("experiment", "continuous".into()),
        ("max_batch", MAX_BATCH.into()),
        ("gamma", GAMMA.into()),
        ("prefill_chunk", PREFILL_CHUNK.into()),
        ("loads", Json::Arr(out.loads.iter().map(|&l| l.into()).collect())),
        ("arms", Json::Arr(arms)),
    ])
}

/// The acceptance-criteria shape claims. Every margin below was
/// calibrated against the python replica of the roofline pricing +
/// pipeline accounting (`python/replica_continuous.py`) on the default
/// trace/engine seed 42, with trace seeds 7 and 11 as robustness checks.
///
/// The TTFT-tail claims are pinned at the *knee* load (the saturation
/// onset, middle of the default grid), not the deepest overload point:
/// at 3× the window is so saturated that the p99 completed request's
/// TTFT is pure queue residence for every arm (replica ratios 0.90–1.00
/// across seeds — statistically flat), while at the knee the pipeline's
/// extra capacity compounds through 1/(1−ρ) queueing into a clear tail
/// win (replica full-vs-lockstep ratios 0.913 / 0.950 / 0.800 for seeds
/// 42 / 7 / 11). Deep overload is instead where the throughput win is
/// asserted (replica full goodput 1.051–1.061× lockstep).
pub fn check_shape(out: &ContinuousOut) -> Result<(), String> {
    let top = out.top_load();
    let knee = out.knee_load();
    for &load in &out.loads {
        for arm in ["lockstep", "+chunked", "+draft-ahead", "full"] {
            let r = out
                .arm(load, arm)
                .ok_or_else(|| format!("missing arm {arm} at load {load}"))?;
            if r.tokens == 0 || r.goodput <= 0.0 {
                return Err(format!("arm {arm}@{load} produced no work: {r:?}"));
            }
            // Chunked prefill actually engages on every continuous arm.
            if arm == "lockstep" {
                if r.prefill_chunks != 0 {
                    return Err(format!("lockstep@{load} ran chunk ops: {r:?}"));
                }
            } else if r.prefill_chunks == 0 {
                return Err(format!("{arm}@{load} never chunked a prefill"));
            }
        }
        // A latency optimisation must not buy TTFT with throughput: every
        // pipeline arm holds ≥ 0.98× lock-step goodput and TPOT at every
        // load. Replica-measured worst ratios across loads and seeds:
        // goodput 0.998× (seed 11 at the knee; ≥ 1.02× at deep overload),
        // TPOT 0.84× (batched chunk ops stop bulk prefill from blocking
        // decode, so TPOT *improves* roughly 2× under load).
        let base = out.arm(load, "lockstep").unwrap();
        for arm in ["+chunked", "+draft-ahead", "full"] {
            let r = out.arm(load, arm).unwrap();
            if r.goodput < 0.98 * base.goodput {
                return Err(format!(
                    "load {load}: {arm} goodput {:.1} under 0.98× lockstep {:.1}",
                    r.goodput, base.goodput
                ));
            }
            if r.tpot_mean > base.tpot_mean / 0.98 {
                return Err(format!(
                    "load {load}: {arm} TPOT {:.5} worse than lockstep {:.5}/0.98",
                    r.tpot_mean, base.tpot_mean
                ));
            }
        }
    }
    // At the saturation knee the full pipeline's TTFT p99 is strictly
    // below lock-step's (replica ratios 0.80–0.95 across seeds; 0.913 on
    // the bench seed — ≤ 0.97 asserted for headroom), and chunked
    // prefill alone already improves the tail (replica 0.85–0.95; ≤ 0.98
    // asserted).
    let base = out.arm(knee, "lockstep").unwrap();
    let full = out.arm(knee, "full").unwrap();
    if full.ttft_p99 >= base.ttft_p99 {
        return Err(format!(
            "knee load {knee}: full TTFT p99 {:.3} not strictly below lockstep {:.3}",
            full.ttft_p99, base.ttft_p99
        ));
    }
    if full.ttft_p99 > 0.97 * base.ttft_p99 {
        return Err(format!(
            "knee load {knee}: full TTFT p99 {:.3} should clear 0.97× lockstep {:.3}",
            full.ttft_p99, base.ttft_p99
        ));
    }
    let chunked = out.arm(knee, "+chunked").unwrap();
    if chunked.ttft_p99 > 0.98 * base.ttft_p99 {
        return Err(format!(
            "knee load {knee}: +chunked TTFT p99 {:.3} should clear 0.98× lockstep {:.3}",
            chunked.ttft_p99, base.ttft_p99
        ));
    }
    // At deep overload the pipeline converts its freed bubble time into
    // throughput: replica full goodput 1.051× lockstep on the bench
    // seed (1.05–1.06 across seeds); ≥ 1.02 asserted.
    let base = out.arm(top, "lockstep").unwrap();
    let full = out.arm(top, "full").unwrap();
    if full.goodput < 1.02 * base.goodput {
        return Err(format!(
            "top load: full goodput {:.1} under 1.02× lockstep {:.1}",
            full.goodput, base.goodput
        ));
    }
    // Draft-ahead earns its keep: hidden draft time exists in the ahead
    // arms (replica hidden_frac 0.50–0.55 at every load) and is absent
    // elsewhere.
    for arm in ["lockstep", "+chunked"] {
        let r = out.arm(top, arm).unwrap();
        if r.hidden_frac != 0.0 {
            return Err(format!("{arm} hid draft time: {}", r.hidden_frac));
        }
    }
    for arm in ["+draft-ahead", "full"] {
        let r = out.arm(top, arm).unwrap();
        if !(0.3..0.7).contains(&r.hidden_frac) {
            return Err(format!(
                "{arm} hidden draft fraction {:.2} outside the replica band (0.3, 0.7)",
                r.hidden_frac
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arm_table_is_cumulative() {
        let a = arms();
        assert_eq!(a.len(), 4);
        assert_eq!(a[0].0, "lockstep");
        assert!(!a[0].1.continuous);
        assert!(a[1].1.continuous && !a[1].1.draft_ahead);
        assert!(a[2].1.draft_ahead && !a[2].1.per_seq_boundaries);
        assert_eq!(a[3].1, PipelineConfig::full(PREFILL_CHUNK));
        for (_, p) in &a[1..] {
            assert_eq!(p.prefill_chunk, Some(PREFILL_CHUNK));
        }
    }

    #[test]
    fn csv_and_json_render() {
        let row = ArmRow {
            load: 3.0,
            arm: "full".into(),
            requests_offered: 100,
            requests_completed: 80,
            tokens: 2500,
            clock_s: 40.0,
            ttft_mean: 0.5,
            ttft_p99: 2.0,
            tpot_mean: 0.02,
            tpot_p99: 0.04,
            goodput: 62.5,
            mean_batch: 24.0,
            hidden_frac: 0.35,
            prefill_chunks: 412,
        };
        let out = ContinuousOut {
            rows: vec![row],
            loads: vec![3.0],
        };
        let t = to_csv(&out);
        assert_eq!(t.rows.len(), 1);
        let parsed = CsvTable::parse(&t.to_string()).unwrap();
        assert_eq!(parsed.column_str("arm").unwrap()[0], "full");
        let j = to_json(&out);
        let s = j.to_pretty();
        assert!(s.contains("\"ttft_p99\""));
        assert!(s.contains("\"prefill_chunks\""));
        let back = Json::parse(&s).unwrap();
        let arms_j = back.req_arr("arms").unwrap();
        assert_eq!(arms_j.len(), 1);
        assert_eq!(arms_j[0].req_str("arm").unwrap(), "full");
        assert_eq!(out.top_load(), 3.0);
        assert_eq!(out.knee_load(), 3.0);
        let grid = ContinuousOut {
            rows: vec![],
            loads: vec![3.0, 0.5, 1.5],
        };
        assert_eq!(grid.knee_load(), 1.5);
    }

    #[test]
    fn single_point_smoke_runs_all_arms() {
        // One cheap point on a short heavy trace: every arm finishes the
        // window with positive goodput, the continuous arms chunk
        // prefills, and the ahead arms hide draft time. (The strict TTFT
        // separation needs the full 120s trace; `moesd bench continuous`
        // gates it via `check_shape`.)
        let trace = ArrivalTrace::synthetic_production_heavy(10.0, 4.0, 11);
        let out = run(&trace, &[2.0], 11).unwrap();
        assert_eq!(out.rows.len(), 4);
        for r in &out.rows {
            assert!(r.goodput > 0.0, "{r:?}");
            assert!(r.requests_completed > 0, "{r:?}");
        }
        let base = out.arm(2.0, "lockstep").unwrap();
        assert_eq!(base.prefill_chunks, 0);
        assert_eq!(base.hidden_frac, 0.0);
        for arm in ["+chunked", "+draft-ahead", "full"] {
            let r = out.arm(2.0, arm).unwrap();
            assert!(r.prefill_chunks > 0, "{arm} never chunked a prefill");
        }
        for arm in ["+draft-ahead", "full"] {
            let r = out.arm(2.0, arm).unwrap();
            assert!(r.hidden_frac > 0.0, "{arm} hid no draft time");
        }
    }
}
