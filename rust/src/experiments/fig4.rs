//! Fig. 4 — analytic modeling vs measurement across MoE sparsity.
//!
//! The paper varies K (activated experts/token) of Qwen2-57B over
//! {1, 2, 4, 8, 16, 32} and γ over {2, 4}, measures SD speedup on 19
//! batch sizes (228 points), fits the Alg. 1 model on a 21-point
//! stride-11 subsample, and overlays model vs measurement. We reproduce
//! the full pipeline against the roofline simulator.

use super::{paper_batch_grid, parallel_sweep, run_pair, RunOpts};
use crate::arch::presets;
use crate::fit::fit_perfmodel;
use crate::hardware::platform_2x_gpu_a;
use crate::perfmodel::{Measurement, ParamBounds, PerfModel, PerfParams};
use crate::util::csv::CsvTable;

pub const K_VALUES: [usize; 6] = [1, 2, 4, 8, 16, 32];
pub const GAMMAS: [usize; 2] = [2, 4];

/// One grid point with both measured and modeled speedups.
#[derive(Debug, Clone, Copy)]
pub struct GridPoint {
    pub k: usize,
    pub gamma: usize,
    pub batch: usize,
    pub sigma: f64,
    pub measured: f64,
    pub modeled: f64,
}

pub struct Fig4Output {
    pub points: Vec<GridPoint>,
    pub params: PerfParams,
    pub fit_mse: f64,
    pub full_mse: f64,
    pub fit_count: usize,
}

/// Generate the full 228-point measurement grid (sorted by K, γ, B —
/// the paper's dataframe ordering, which Table 3's stride sampling
/// depends on). The independent grid points fan across worker threads;
/// `parallel_sweep` keeps the dataframe order.
pub fn measure_grid(alpha: f64, seed: u64) -> anyhow::Result<Vec<Measurement>> {
    let draft = presets::qwen2_0_5b();
    let platform = platform_2x_gpu_a();
    let base = presets::qwen2_57b_a14b();
    let opts = RunOpts {
        max_new_tokens: 24,
        seed,
        ..Default::default()
    };
    let mut points = Vec::new();
    for &k in &K_VALUES {
        for &gamma in &GAMMAS {
            for &b in &paper_batch_grid() {
                points.push((k, gamma, b));
            }
        }
    }
    parallel_sweep(&points, |&(k, gamma, b)| -> anyhow::Result<Measurement> {
        let target = base.with_topk(k);
        let s = run_pair(&target, &draft, &platform, alpha, gamma, b, &opts)?;
        Ok(Measurement {
            batch: b,
            gamma,
            k,
            e: base.experts(),
            sigma: s.sigma,
            speedup: s.speedup,
        })
    })
    .into_iter()
    .collect()
}

/// Stride-subsample the sorted grid (`df[begin:end:stride]`, App. C.2).
pub fn stride_sample(grid: &[Measurement], stride: usize) -> Vec<Measurement> {
    grid.iter().step_by(stride).copied().collect()
}

/// Fit on a subsample, evaluate on the full grid.
pub fn fit_and_eval(
    grid: &[Measurement],
    fit_set: &[Measurement],
    seed: u64,
) -> (PerfParams, f64, f64) {
    let platform = platform_2x_gpu_a();
    let model = PerfModel::new(&platform);
    let t_rej_max = 1e-3;
    let bounds = ParamBounds::for_setup(
        &presets::qwen2_57b_a14b(),
        &presets::qwen2_0_5b(),
        &platform,
        t_rej_max,
    );
    let (params, fit_mse) = fit_perfmodel(&model, fit_set, &bounds, seed);
    let full_mse = model.mse(&params, grid);
    (params, fit_mse, full_mse)
}

/// The full Fig. 4 pipeline with the paper's m=21 (stride 11) selection.
pub fn run(alpha: f64, seed: u64) -> anyhow::Result<Fig4Output> {
    let grid = measure_grid(alpha, seed)?;
    let fit_set = stride_sample(&grid, 11);
    let (params, fit_mse, full_mse) = fit_and_eval(&grid, &fit_set, seed);
    let platform = platform_2x_gpu_a();
    let model = PerfModel::new(&platform);
    let points = grid
        .iter()
        .map(|m| GridPoint {
            k: m.k,
            gamma: m.gamma,
            batch: m.batch,
            sigma: m.sigma,
            measured: m.speedup,
            modeled: model.compute_speedup(&params, m),
        })
        .collect();
    Ok(Fig4Output {
        points,
        params,
        fit_mse,
        full_mse,
        fit_count: fit_set.len(),
    })
}

pub fn to_csv(out: &Fig4Output) -> CsvTable {
    let mut t = CsvTable::new(&["k", "gamma", "batch", "sigma", "measured", "modeled"]);
    for p in &out.points {
        t.push_nums(&[
            p.k as f64,
            p.gamma as f64,
            p.batch as f64,
            p.sigma,
            p.measured,
            p.modeled,
        ]);
    }
    t
}

/// Peak batch size for a (K, γ) series.
pub fn peak_batch(points: &[GridPoint], k: usize, gamma: usize) -> usize {
    let series: Vec<&GridPoint> = points
        .iter()
        .filter(|p| p.k == k && p.gamma == gamma)
        .collect();
    let speeds: Vec<f64> = series.iter().map(|p| p.measured).collect();
    series[crate::util::stats::argmax(&speeds)].batch
}

/// Width of the batch range maintaining speedup ≥ peak/√2 (the brown
/// dashed annotation in the paper's Fig. 4).
pub fn plateau_width(points: &[GridPoint], k: usize, gamma: usize) -> usize {
    let series: Vec<&GridPoint> = points
        .iter()
        .filter(|p| p.k == k && p.gamma == gamma)
        .collect();
    let peak = series
        .iter()
        .map(|p| p.measured)
        .fold(f64::NEG_INFINITY, f64::max);
    let threshold = peak / std::f64::consts::SQRT_2;
    series.iter().filter(|p| p.measured >= threshold).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    // One smaller-grid test keeps unit runtime bounded; the full 228-point
    // pipeline runs in the fig4 bench and integration tests.
    #[test]
    fn fit_tracks_simulated_measurements() {
        let draft = presets::qwen2_0_5b();
        let platform = platform_2x_gpu_a();
        let base = presets::qwen2_57b_a14b();
        let opts = RunOpts {
            max_new_tokens: 16,
            ..Default::default()
        };
        let mut grid = Vec::new();
        for &k in &[2usize, 8] {
            let target = base.with_topk(k);
            for &b in &[1usize, 4, 8, 16, 32, 64, 100] {
                let s = run_pair(&target, &draft, &platform, 0.85, 3, b, &opts).unwrap();
                grid.push(Measurement {
                    batch: b,
                    gamma: 3,
                    k,
                    e: 64,
                    sigma: s.sigma,
                    speedup: s.speedup,
                });
            }
        }
        let (_, fit_mse, full_mse) = fit_and_eval(&grid, &grid, 5);
        // Engine measurements carry stochastic σ noise; the paper's own
        // Table 3 reports MSE ≈ 1.5 on speedups of O(1–2.5). We demand an
        // order of magnitude better on the simulator.
        assert!(fit_mse < 0.12, "fit MSE {fit_mse}");
        assert!(full_mse < 0.12, "full MSE {full_mse}");
    }

    #[test]
    fn stride_sampling_counts() {
        let grid: Vec<Measurement> = (0..228)
            .map(|i| Measurement {
                batch: i + 1,
                gamma: 2,
                k: 8,
                e: 64,
                sigma: 0.9,
                speedup: 1.0,
            })
            .collect();
        assert_eq!(stride_sample(&grid, 11).len(), 21);
        assert_eq!(stride_sample(&grid, 25).len(), 10);
        assert_eq!(stride_sample(&grid, 1).len(), 228);
    }
}
