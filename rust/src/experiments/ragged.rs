//! Ragged-speculation sweep — per-sequence γᵢ vs the best uniform γ on
//! mixed-acceptance populations (not from the paper's evaluation; it
//! extends Eq. 4's per-workload argmax to the per-sequence form the
//! ROADMAP's "batch-heterogeneous rounds" item asks for).
//!
//! The paper's Eq. 4 picks one γ per workload, but acceptance α varies
//! per sequence: a bimodal batch (half easy α≈0.9, half hard α≈0.5)
//! forces any uniform γ into a compromise — too shallow for the easy
//! sequences, too deep for the hard ones. Ragged rounds give each
//! sequence its own depth (DISCO's and SpecInfer's dynamic-depth
//! observations, PAPERS.md, reproduced on this stack's virtual clock).
//!
//! ## Methodology: saturated two-class slots, fixed round window
//!
//! Each sweep point runs a **steady-state** serving scenario: B/2 "easy"
//! slots and B/2 "hard" slots (two request classes with different draft
//! acceptance — think two tenants or two prompt domains sharing an
//! instance), every completion immediately replaced from its own class,
//! measured over a fixed window of decode rounds. This pins the round
//! composition at 50/50 and measures exactly the per-round goodput the
//! per-sequence Eq. 4 optimizes. A drain-to-empty measurement would
//! instead measure *makespan of a fixed population*, which is dominated
//! by the slow class finishing alone at a degraded batch — a real
//! phenomenon, but a different objective (the round time of this MoE is
//! nearly batch-independent in the memory-bound regime, so the lopsided
//! tail swamps the steady-state signal; verified against the python
//! replica of the pricing model during design).
//!
//! Three arms per point (α-mix × batch × K), all through the real engine:
//!
//! - `uniform-γ` over a grid — launch-config baselines; the per-point
//!   best is the **uniform oracle**;
//! - `ragged-oracle` — static per-class depths from the production
//!   water-filling argmax
//!   ([`crate::control::GammaPolicy::gamma_for_sequences`]) at the true
//!   αs, applied via [`crate::engine::EngineConfig::gamma_overrides`];
//! - `ragged-adaptive` — the full online loop
//!   ([`crate::control::ControlConfig::model_guided_ragged`]) learning
//!   per-sequence α̂ᵢ from scratch.
//!
//! `check_shape` pins (validated against the python replica of the
//! pricing model: edges 1.02–1.11 across the default grid): the ragged
//! oracle stays within 2% of the best uniform γ everywhere, beats it by
//! >2% somewhere in the memory-bound regime (B ≤ 32), and the adaptive
//! arm clears the worst uniform baseline at every point.

use std::collections::HashMap;

use super::parallel_sweep;
use crate::arch::presets;
use crate::batching::{Buckets, Request, SamplingParams};
use crate::control::{
    ControlConfig, CostModelSpec, CostTable, Estimates, GammaPolicy, ModelGuidedPolicy,
};
use crate::engine::{Engine, EngineConfig};
use crate::hardware::{platform_2x_gpu_a, Platform};
use crate::kvcache::{KvConfig, SeqId};
use crate::scheduler::SchedulerConfig;
use crate::simulator::ExecSim;
use crate::spec::synthetic::SyntheticLm;
use crate::util::csv::CsvTable;
use crate::util::json::Json;

/// Tokens generated per request.
pub const MAX_NEW_TOKENS: usize = 48;

/// Prompt length (uniform; the comparison is about decode).
pub const PROMPT_LEN: usize = 16;

/// Largest per-sequence depth considered.
pub const GAMMA_MAX: usize = 8;

/// Decode rounds measured per arm (steady-state window).
pub const WINDOW_ROUNDS: usize = 120;

/// The bimodal acceptance mixes swept (α_easy, α_hard; even request ids
/// are the easy class, odd the hard class — a pinned 50/50 population).
pub fn default_alpha_pairs() -> Vec<(f64, f64)> {
    vec![(0.9, 0.5), (0.95, 0.6)]
}

/// Batch sizes swept: memory-bound through the compute-bound collapse.
pub fn default_batches() -> Vec<usize> {
    vec![4, 16, 64, 256]
}

/// Target sparsity (activated experts per token) sweep.
pub fn default_topks() -> Vec<usize> {
    vec![4, 8]
}

/// The uniform-γ baselines swept as oracle candidates.
pub fn uniform_gammas() -> Vec<usize> {
    vec![0, 1, 2, 3, 4, 6, 8]
}

/// One (sweep point, policy arm) measurement.
#[derive(Debug, Clone)]
pub struct RaggedStat {
    pub alpha_hi: f64,
    pub alpha_lo: f64,
    pub k: usize,
    pub batch: usize,
    /// `uniform-gN`, `ragged-oracle` or `ragged-adaptive`.
    pub policy: String,
    /// Depths the arm ran for the easy/hard classes (uniform arms repeat
    /// the single γ; the adaptive arm reports its controller γ ceiling).
    pub gamma_hi: usize,
    pub gamma_lo: usize,
    pub tokens: u64,
    pub decode_s: f64,
    /// Goodput: committed tokens per second of virtual clock.
    pub tok_s: f64,
}

/// Full sweep output.
#[derive(Debug, Clone)]
pub struct RaggedOut {
    pub rows: Vec<RaggedStat>,
    pub batches: Vec<usize>,
}

fn sims(k: usize) -> (ExecSim, ExecSim) {
    let platform = platform_2x_gpu_a();
    let target = ExecSim::new(presets::qwen2_57b_a14b().with_topk(k), platform.clone());
    // The draft stays single-GPU (as in the paper's deployments).
    let draft_platform = Platform::new(platform.gpu.clone(), 1, platform.interconnect_bw);
    let draft = ExecSim::new(presets::qwen2_0_5b(), draft_platform);
    (target, draft)
}

/// Class of a request id: even = easy (α_hi), odd = hard (α_lo).
fn is_easy(id: SeqId) -> bool {
    id % 2 == 0
}

/// The production per-sequence Eq. 4 argmax (water-fill) at the true αs —
/// the depths the ragged-oracle arm runs, one per class.
pub fn oracle_gammas(k: usize, batch: usize, alpha_hi: f64, alpha_lo: f64) -> (usize, usize) {
    let (tsim, dsim) = sims(k);
    let cfg = ControlConfig {
        gamma_max: GAMMA_MAX,
        ..ControlConfig::default()
    };
    let policy = ModelGuidedPolicy::new(CostModelSpec::roofline(tsim, dsim), &cfg);
    let costs = CostTable::default();
    let b = batch.max(2);
    // Full-batch alpha vector: the water-fill prices the round at the
    // real batch size and class counts.
    let alphas: Vec<f64> = (0..b as u64)
        .map(|id| if is_easy(id) { alpha_hi } else { alpha_lo })
        .collect();
    let est = Estimates {
        batch: b,
        alpha: Some(0.5 * (alpha_hi + alpha_lo)),
        sigma: None,
        current_gamma: 0,
        current_budget: None,
        regime_shift: false,
        costs: &costs,
    };
    let mut out = Vec::new();
    policy.gamma_for_sequences(&est, &alphas, &mut out);
    (out[0].min(GAMMA_MAX), out[1].min(GAMMA_MAX))
}

#[allow(clippy::too_many_arguments)]
fn build_engine(
    k: usize,
    batch: usize,
    alpha_hi: f64,
    alpha_lo: f64,
    gamma: usize,
    overrides: HashMap<SeqId, usize>,
    control: Option<ControlConfig>,
    seed: u64,
) -> Engine<SyntheticLm> {
    let (tsim, dsim) = sims(k);
    // Enough per-class ids for every possible replacement in the window.
    let max_ids = (batch * (WINDOW_ROUNDS + 2)) as u64;
    let seq_alphas: Vec<(SeqId, f64)> = (0..max_ids)
        .map(|id| (id, if is_easy(id) { alpha_hi } else { alpha_lo }))
        .collect();
    let backend = SyntheticLm::new(tsim, dsim, alpha_hi, seed).with_seq_alphas(&seq_alphas);
    let config = EngineConfig {
        gamma,
        kv: KvConfig {
            num_blocks: 1 << 16,
            block_size: 16,
        },
        scheduler: SchedulerConfig {
            max_batch: batch,
            admit_reserve_tokens: MAX_NEW_TOKENS,
            tpot_slo: None,
        },
        buckets: Buckets::pow2_up_to(batch.max(1)),
        seed,
        control,
        gamma_overrides: overrides,
        ..Default::default()
    };
    Engine::new(config, backend)
}

/// Static per-class override map covering every id an arm can touch.
fn class_overrides(batch: usize, gamma_hi: usize, gamma_lo: usize) -> HashMap<SeqId, usize> {
    (0..(batch * (WINDOW_ROUNDS + 2)) as u64)
        .map(|id| (id, if is_easy(id) { gamma_hi } else { gamma_lo }))
        .collect()
}

fn mk_request(id: SeqId, arrival: f64) -> Request {
    Request {
        id,
        prompt: (0..PROMPT_LEN as u32).collect(),
        params: SamplingParams {
            temperature: 0.0,
            max_new_tokens: MAX_NEW_TOKENS,
            eos_token: None,
        },
        arrival,
        class: 0,
    }
}

/// Drive one arm for [`WINDOW_ROUNDS`] decode rounds with class-preserving
/// slot replacement, twice (independent seeds, summed), returning
/// (tokens, decode seconds). Two trials halve the draw variance so the
/// ≥-best-uniform comparison measures policies, not acceptance luck.
#[allow(clippy::too_many_arguments)]
fn run_arm(
    k: usize,
    batch: usize,
    alpha_hi: f64,
    alpha_lo: f64,
    gamma: usize,
    overrides: &HashMap<SeqId, usize>,
    control: Option<ControlConfig>,
    seed: u64,
) -> anyhow::Result<(u64, f64)> {
    let mut tokens = 0u64;
    let mut decode = 0.0f64;
    for trial in 0..2u64 {
        let mut engine = build_engine(
            k,
            batch,
            alpha_hi,
            alpha_lo,
            gamma,
            overrides.clone(),
            control.clone(),
            seed.wrapping_add(trial),
        );
        // Class slots: even/odd ids alternate, so the initial batch is
        // half easy, half hard; replacements keep each slot's class by
        // skipping ids two at a time.
        let mut next_easy: u64 = batch as u64;
        if !is_easy(next_easy) {
            next_easy += 1;
        }
        let mut next_hard: u64 = batch as u64;
        if is_easy(next_hard) {
            next_hard += 1;
        }
        for id in 0..batch as u64 {
            engine.submit(mk_request(id, 0.0));
        }
        for _ in 0..WINDOW_ROUNDS {
            let completions = engine.step()?;
            for c in completions {
                let id = if is_easy(c.id) {
                    let id = next_easy;
                    next_easy += 2;
                    id
                } else {
                    let id = next_hard;
                    next_hard += 2;
                    id
                };
                engine.submit(mk_request(id, engine.clock()));
            }
        }
        tokens += engine.metrics.tokens_generated;
        decode += engine.metrics.decode_time();
    }
    anyhow::ensure!(decode > 0.0, "arm measured no decode time");
    Ok((tokens, decode))
}

/// Run the full comparison over `pairs × batches × ks` (each point fanned
/// across worker threads; every arm builds its own seeded engine, so the
/// sweep is bit-identical to a serial run).
pub fn run(
    pairs: &[(f64, f64)],
    batches: &[usize],
    ks: &[usize],
    seed: u64,
) -> anyhow::Result<RaggedOut> {
    let mut grid: Vec<(f64, f64, usize, usize)> = Vec::new();
    for &(hi, lo) in pairs {
        for &k in ks {
            for &b in batches {
                grid.push((hi, lo, k, b));
            }
        }
    }
    let per_point: Vec<anyhow::Result<Vec<RaggedStat>>> =
        parallel_sweep(&grid, |&(alpha_hi, alpha_lo, k, batch)| {
            let mut rows = Vec::new();
            let stat = |policy: String,
                        gamma_hi: usize,
                        gamma_lo: usize,
                        tokens: u64,
                        decode_s: f64| RaggedStat {
                alpha_hi,
                alpha_lo,
                k,
                batch,
                policy,
                gamma_hi,
                gamma_lo,
                tokens,
                decode_s,
                tok_s: tokens as f64 / decode_s,
            };
            let no_overrides = HashMap::new();
            for g in uniform_gammas() {
                let (tok, dec) =
                    run_arm(k, batch, alpha_hi, alpha_lo, g, &no_overrides, None, seed)?;
                rows.push(stat(format!("uniform-g{g}"), g, g, tok, dec));
            }
            // Ragged oracle: per-class depths from the water-fill at the
            // true αs, applied as static overrides. (If the water level
            // collapses to a uniform depth, this arm runs the same seeds
            // and γ vector as that uniform arm — identical by design.)
            let (g_hi, g_lo) = oracle_gammas(k, batch, alpha_hi, alpha_lo);
            let overrides = class_overrides(batch, g_hi, g_lo);
            let (tok, dec) = run_arm(k, batch, alpha_hi, alpha_lo, 0, &overrides, None, seed)?;
            rows.push(stat("ragged-oracle".into(), g_hi, g_lo, tok, dec));
            // Ragged adaptive: the online loop learns α̂ᵢ from scratch
            // (fast warm-up window so the window run reaches steady state).
            let (tsim, dsim) = sims(k);
            let control = ControlConfig {
                alpha_prior: 0.5 * (alpha_hi + alpha_lo),
                gamma_max: GAMMA_MAX,
                seq_window_rounds: 4,
                ..ControlConfig::model_guided_ragged(CostModelSpec::roofline(tsim, dsim))
            };
            let (tok, dec) = run_arm(
                k,
                batch,
                alpha_hi,
                alpha_lo,
                0,
                &no_overrides,
                Some(control),
                seed,
            )?;
            rows.push(stat("ragged-adaptive".into(), GAMMA_MAX, GAMMA_MAX, tok, dec));
            Ok(rows)
        });
    let mut rows = Vec::new();
    for r in per_point {
        rows.extend(r?);
    }
    Ok(RaggedOut {
        rows,
        batches: batches.to_vec(),
    })
}

impl RaggedOut {
    /// All sweep points (α-mix, K, batch) present in the output.
    pub fn points(&self) -> Vec<(f64, f64, usize, usize)> {
        let mut pts: Vec<(f64, f64, usize, usize)> = Vec::new();
        for r in &self.rows {
            let p = (r.alpha_hi, r.alpha_lo, r.k, r.batch);
            if !pts.contains(&p) {
                pts.push(p);
            }
        }
        pts
    }

    fn arm(&self, p: (f64, f64, usize, usize), policy: &str) -> Option<&RaggedStat> {
        self.rows
            .iter()
            .find(|r| (r.alpha_hi, r.alpha_lo, r.k, r.batch) == p && r.policy == policy)
    }

    fn uniform_arms(&self, p: (f64, f64, usize, usize)) -> Vec<&RaggedStat> {
        self.rows
            .iter()
            .filter(|r| {
                (r.alpha_hi, r.alpha_lo, r.k, r.batch) == p && r.policy.starts_with("uniform-")
            })
            .collect()
    }
}

pub fn to_csv(out: &RaggedOut) -> CsvTable {
    let mut t = CsvTable::new(&[
        "alpha_hi", "alpha_lo", "k", "batch", "policy", "gamma_hi", "gamma_lo", "tokens",
        "decode_s", "tok_s",
    ]);
    for r in &out.rows {
        t.push_row(vec![
            format!("{}", r.alpha_hi),
            format!("{}", r.alpha_lo),
            r.k.to_string(),
            r.batch.to_string(),
            r.policy.clone(),
            r.gamma_hi.to_string(),
            r.gamma_lo.to_string(),
            r.tokens.to_string(),
            format!("{:.6}", r.decode_s),
            format!("{:.2}", r.tok_s),
        ]);
    }
    t
}

/// Per-point summary JSON: ragged-vs-best-uniform edges for the report.
pub fn to_json(out: &RaggedOut) -> Json {
    let mut pts = Vec::new();
    for p in out.points() {
        let uniforms = out.uniform_arms(p);
        let best = uniforms.iter().map(|r| r.tok_s).fold(f64::MIN, f64::max);
        let best_gamma = uniforms
            .iter()
            .max_by(|a, b| a.tok_s.partial_cmp(&b.tok_s).unwrap())
            .map_or(0, |r| r.gamma_hi);
        let oracle = out.arm(p, "ragged-oracle");
        let adaptive = out.arm(p, "ragged-adaptive");
        pts.push(Json::from_pairs(vec![
            ("alpha_hi", p.0.into()),
            ("alpha_lo", p.1.into()),
            ("k", p.2.into()),
            ("batch", p.3.into()),
            ("best_uniform_gamma", best_gamma.into()),
            ("best_uniform_tok_s", best.into()),
            (
                "ragged_oracle_tok_s",
                oracle.map_or(Json::Null, |r| r.tok_s.into()),
            ),
            (
                "ragged_gamma_hi",
                oracle.map_or(Json::Null, |r| r.gamma_hi.into()),
            ),
            (
                "ragged_gamma_lo",
                oracle.map_or(Json::Null, |r| r.gamma_lo.into()),
            ),
            (
                "ragged_edge",
                oracle.map_or(Json::Null, |r| (r.tok_s / best).into()),
            ),
            (
                "ragged_adaptive_tok_s",
                adaptive.map_or(Json::Null, |r| r.tok_s.into()),
            ),
        ]));
    }
    Json::from_pairs(vec![("points", Json::Arr(pts))])
}

/// The acceptance-criteria shape claims (margins validated against the
/// python replica: per-point ragged/best-uniform edges 1.02–1.11 on the
/// default grid, ±~1% two-trial sampling noise).
pub fn check_shape(out: &RaggedOut) -> Result<(), String> {
    let mut memory_bound_win = false;
    for p in out.points() {
        let uniforms = out.uniform_arms(p);
        if uniforms.is_empty() {
            return Err(format!("point {p:?}: no uniform arms"));
        }
        let best = uniforms.iter().map(|r| r.tok_s).fold(f64::MIN, f64::max);
        let worst = uniforms.iter().map(|r| r.tok_s).fold(f64::MAX, f64::min);
        let oracle = out
            .arm(p, "ragged-oracle")
            .ok_or_else(|| format!("point {p:?}: ragged-oracle arm missing"))?;
        if oracle.tok_s < 0.98 * best {
            return Err(format!(
                "point {p:?}: ragged oracle {:.1} tok/s < 0.98 × best uniform {best:.1}",
                oracle.tok_s
            ));
        }
        if p.3 <= 32 && oracle.tok_s > 1.02 * best {
            memory_bound_win = true;
        }
        if let Some(adaptive) = out.arm(p, "ragged-adaptive") {
            if adaptive.tok_s <= worst {
                return Err(format!(
                    "point {p:?}: adaptive {:.1} tok/s does not beat worst uniform {worst:.1}",
                    adaptive.tok_s
                ));
            }
        }
    }
    if !memory_bound_win {
        return Err("no memory-bound point where ragged beats the best uniform γ by >2%".into());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_depths_are_ordered_and_bounded() {
        // Validated against the python replica: (8, 3) at K=8, B=16 for
        // the 0.9/0.5 mix; compute-bound B=4096 collapses to uniform AR.
        let (hi, lo) = oracle_gammas(8, 16, 0.9, 0.5);
        assert!(hi <= GAMMA_MAX && lo <= GAMMA_MAX);
        assert!(hi > lo, "easy class should draft deeper: {hi} vs {lo}");
        let (hi_big, lo_big) = oracle_gammas(8, 4096, 0.9, 0.5);
        assert_eq!((hi_big, lo_big), (0, 0), "compute-bound must collapse to AR");
    }

    #[test]
    fn csv_and_json_render() {
        let out = RaggedOut {
            batches: vec![8],
            rows: vec![
                RaggedStat {
                    alpha_hi: 0.9,
                    alpha_lo: 0.5,
                    k: 8,
                    batch: 8,
                    policy: "uniform-g3".into(),
                    gamma_hi: 3,
                    gamma_lo: 3,
                    tokens: 768,
                    decode_s: 0.5,
                    tok_s: 1536.0,
                },
                RaggedStat {
                    alpha_hi: 0.9,
                    alpha_lo: 0.5,
                    k: 8,
                    batch: 8,
                    policy: "ragged-oracle".into(),
                    gamma_hi: 6,
                    gamma_lo: 2,
                    tokens: 768,
                    decode_s: 0.45,
                    tok_s: 1706.7,
                },
            ],
        };
        let t = to_csv(&out);
        assert_eq!(t.rows.len(), 2);
        let parsed = CsvTable::parse(&t.to_string()).unwrap();
        assert_eq!(parsed.column_str("policy").unwrap()[1], "ragged-oracle");
        let j = to_json(&out).to_string();
        assert!(j.contains("\"ragged_edge\""));
        assert!(j.contains("\"best_uniform_gamma\""));
    }

    #[test]
    fn class_slots_replace_in_kind() {
        assert!(is_easy(0) && !is_easy(1) && is_easy(2));
        let ov = class_overrides(4, 6, 2);
        assert_eq!(ov[&0], 6);
        assert_eq!(ov[&1], 2);
        assert_eq!(ov.len(), 4 * (WINDOW_ROUNDS + 2));
    }

    #[test]
    fn single_point_smoke_runs_all_arms() {
        // One cheap point: every arm completes the window and produces
        // positive goodput. (The comparative shape claims run in the
        // integration test and `moesd bench ragged`.)
        let out = run(&[(0.9, 0.5)], &[8], &[8], 11).unwrap();
        assert_eq!(out.rows.len(), uniform_gammas().len() + 2);
        for r in &out.rows {
            assert!(r.tok_s > 0.0, "{r:?}");
            assert!(r.tokens > 0, "{r:?}");
        }
        // The oracle arm is genuinely ragged at this memory-bound point.
        let oracle = out
            .arm((0.9, 0.5, 8, 8), "ragged-oracle")
            .expect("oracle arm");
        assert!(oracle.gamma_hi > oracle.gamma_lo, "{oracle:?}");
    }
}
