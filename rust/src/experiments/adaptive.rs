//! Adaptive-speculation traffic ramp — the control-plane headline
//! experiment (not from the paper's evaluation; it *operationalizes* the
//! paper's §3 analysis).
//!
//! A traffic ramp sweeps concurrency B through 1 → 512, crossing every
//! regime of the paper's analysis: at B=1 the MoE target is maximally
//! memory-bound (SD paradise, large γ wins), around B=32 target
//! efficiency peaks, by B=128 the argmax γ has dropped to ~3, and at
//! B=512 the platform is compute-bound and γ=0 (plain autoregressive
//! decoding) is optimal. No *static* γ wins everywhere — the launch-config
//! choice every current serving stack makes is provably wrong somewhere
//! on the ramp.
//!
//! Each phase runs **closed-loop**: B requests in flight, each completion
//! immediately replaced until the phase's request budget drains, so
//! concurrency stays pinned at B for the bulk of the phase (realistic
//! steady traffic, and low-variance measurement). The same engine runs
//! the whole ramp, so the adaptive controller carries its learned α̂
//! across phases and must *re-decide* as the load shifts.
//!
//! The shape claims (asserted by `check_shape` and the bench target):
//! adaptive tokens/sec ≥ 0.95× the best static-γ oracle in **every**
//! phase, strictly above the worst static γ in every phase, and the
//! controller demonstrably falls back to γ=0 during the compute-bound
//! phase.

use crate::arch::presets;
use crate::batching::{Buckets, Request, SamplingParams};
use crate::control::{ControlConfig, CostModelSpec};
use crate::engine::{Engine, EngineConfig};
use crate::hardware::{platform_2x_gpu_a, Platform};
use crate::kvcache::KvConfig;
use crate::scheduler::SchedulerConfig;
use crate::simulator::ExecSim;
use crate::spec::synthetic::SyntheticLm;
use crate::util::csv::CsvTable;

/// Concurrency per ramp phase (B rising through the §3.1 regimes).
pub fn ramp_batches() -> Vec<usize> {
    vec![1, 8, 32, 128, 512]
}

/// Tokens generated per request.
pub const MAX_NEW_TOKENS: usize = 48;

/// Prompt length (uniform; the control comparison is about decode).
pub const PROMPT_LEN: usize = 16;

/// Requests per phase: enough cohorts that the steady-state bulk
/// dominates the drain tail.
pub fn phase_requests(batch: usize) -> usize {
    (8 * batch).max(128)
}

/// The static γ baselines swept as oracle candidates.
pub fn static_gammas() -> Vec<usize> {
    vec![0, 1, 2, 4, 8]
}

/// One (policy, phase) measurement.
#[derive(Debug, Clone)]
pub struct PhaseStat {
    pub policy: String,
    /// Target concurrency of the phase.
    pub batch: usize,
    pub tokens: u64,
    pub decode_s: f64,
    pub tok_s: f64,
    /// γ in effect when the phase finished.
    pub gamma_end: usize,
    /// Rounds spent at γ=0 while the batch was at ≥ half the phase target
    /// (the AR-fallback evidence for compute-bound phases).
    pub ar_bulk_rounds: u64,
    /// Controller α̂ at phase end (NaN for static policies).
    pub alpha_hat: f64,
}

/// Full experiment output.
#[derive(Debug, Clone)]
pub struct AdaptiveOut {
    pub rows: Vec<PhaseStat>,
    pub alpha: f64,
}

fn sims() -> (ExecSim, ExecSim) {
    let platform = platform_2x_gpu_a();
    let target = ExecSim::new(presets::qwen2_57b_a14b(), platform.clone());
    // The draft stays single-GPU (as in the paper's deployments).
    let draft_platform = Platform::new(platform.gpu.clone(), 1, platform.interconnect_bw);
    let draft = ExecSim::new(presets::qwen2_0_5b(), draft_platform);
    (target, draft)
}

fn build_engine(alpha: f64, control: Option<ControlConfig>, gamma: usize, seed: u64) -> Engine<SyntheticLm> {
    let (tsim, dsim) = sims();
    let backend = SyntheticLm::new(tsim, dsim, alpha, seed);
    let max_batch = *ramp_batches().last().unwrap();
    let config = EngineConfig {
        gamma,
        kv: KvConfig {
            num_blocks: 1 << 16,
            block_size: 16,
        },
        scheduler: SchedulerConfig {
            max_batch,
            admit_reserve_tokens: MAX_NEW_TOKENS,
            tpot_slo: None,
        },
        buckets: Buckets::pow2_up_to(max_batch),
        seed,
        control,
        ..Default::default()
    };
    Engine::new(config, backend)
}

/// The adaptive controller under test: model-guided over the same
/// roofline oracle the synthetic backend prices rounds with, α prior set
/// to the workload's calibrated value and refined online.
pub fn adaptive_control(alpha: f64) -> ControlConfig {
    let (tsim, dsim) = sims();
    ControlConfig {
        alpha_prior: alpha,
        ..ControlConfig::model_guided(CostModelSpec::roofline(tsim, dsim))
    }
}

fn mk_request(id: u64, arrival: f64) -> Request {
    Request {
        id,
        prompt: (0..PROMPT_LEN as u32).collect(),
        params: SamplingParams {
            temperature: 0.0,
            max_new_tokens: MAX_NEW_TOKENS,
            eos_token: None,
        },
        arrival,
        class: 0,
    }
}

/// Drive one policy through the full ramp; phases are measured via
/// metric deltas on the shared engine.
fn run_policy(
    label: &str,
    alpha: f64,
    control: Option<ControlConfig>,
    static_gamma: usize,
    seed: u64,
) -> anyhow::Result<Vec<PhaseStat>> {
    let mut engine = build_engine(alpha, control, static_gamma, seed);
    let mut next_id: u64 = 0;
    let mut stats = Vec::new();
    for batch in ramp_batches() {
        let mut budget = phase_requests(batch) - batch;
        let tokens0 = engine.metrics.tokens_generated;
        let decode0 = engine.metrics.decode_time();
        for _ in 0..batch {
            engine.submit(mk_request(next_id, engine.clock()));
            next_id += 1;
        }
        let mut ar_bulk_rounds = 0u64;
        let mut steps = 0usize;
        while !engine.is_idle() {
            let completions = engine.step()?;
            if engine.current_gamma() == 0 && engine.num_running() * 2 >= batch {
                ar_bulk_rounds += 1;
            }
            for _ in completions {
                if budget > 0 {
                    budget -= 1;
                    engine.submit(mk_request(next_id, engine.clock()));
                    next_id += 1;
                }
            }
            steps += 1;
            anyhow::ensure!(steps < 1_000_000, "phase B={batch} did not drain");
        }
        let tokens = engine.metrics.tokens_generated - tokens0;
        let decode_s = engine.metrics.decode_time() - decode0;
        anyhow::ensure!(decode_s > 0.0, "phase B={batch} measured no decode time");
        stats.push(PhaseStat {
            policy: label.to_string(),
            batch,
            tokens,
            decode_s,
            tok_s: tokens as f64 / decode_s,
            gamma_end: engine.current_gamma(),
            ar_bulk_rounds,
            alpha_hat: engine
                .controller_state()
                .and_then(|s| s.alpha_hat)
                .unwrap_or(f64::NAN),
        });
    }
    Ok(stats)
}

/// Aggregate two independent trials of one policy (per-phase sums):
/// halves the draw variance so the 5%-of-oracle comparison measures the
/// policies, not the acceptance-sampling luck of a single trial.
fn run_policy_avg(
    label: &str,
    alpha: f64,
    control: Option<ControlConfig>,
    static_gamma: usize,
    seed: u64,
) -> anyhow::Result<Vec<PhaseStat>> {
    let a = run_policy(label, alpha, control.clone(), static_gamma, seed)?;
    let b = run_policy(label, alpha, control, static_gamma, seed.wrapping_add(1))?;
    Ok(a.into_iter()
        .zip(b)
        .map(|(x, y)| PhaseStat {
            policy: x.policy,
            batch: x.batch,
            tokens: x.tokens + y.tokens,
            decode_s: x.decode_s + y.decode_s,
            tok_s: (x.tokens + y.tokens) as f64 / (x.decode_s + y.decode_s),
            gamma_end: y.gamma_end,
            ar_bulk_rounds: x.ar_bulk_rounds + y.ar_bulk_rounds,
            alpha_hat: y.alpha_hat,
        })
        .collect())
}

/// Run the full comparison: every static γ plus the adaptive policy.
pub fn run(alpha: f64, seed: u64) -> anyhow::Result<AdaptiveOut> {
    let mut rows = Vec::new();
    for gamma in static_gammas() {
        rows.extend(run_policy_avg(
            &format!("static-{gamma}"),
            alpha,
            None,
            gamma,
            seed,
        )?);
    }
    rows.extend(run_policy_avg(
        "adaptive",
        alpha,
        Some(adaptive_control(alpha)),
        0,
        seed,
    )?);
    Ok(AdaptiveOut { rows, alpha })
}

impl AdaptiveOut {
    /// Rows for one phase, adaptive last.
    fn phase_rows(&self, batch: usize) -> (Vec<&PhaseStat>, &PhaseStat) {
        let statics: Vec<&PhaseStat> = self
            .rows
            .iter()
            .filter(|r| r.batch == batch && r.policy != "adaptive")
            .collect();
        let adaptive = self
            .rows
            .iter()
            .find(|r| r.batch == batch && r.policy == "adaptive")
            .expect("adaptive row missing");
        (statics, adaptive)
    }
}

pub fn to_csv(out: &AdaptiveOut) -> CsvTable {
    let mut t = CsvTable::new(&[
        "policy",
        "phase_batch",
        "tokens",
        "decode_s",
        "tok_s",
        "gamma_end",
        "ar_bulk_rounds",
        "alpha_hat",
    ]);
    for r in &out.rows {
        t.push_row(vec![
            r.policy.clone(),
            r.batch.to_string(),
            r.tokens.to_string(),
            format!("{:.6}", r.decode_s),
            format!("{:.2}", r.tok_s),
            r.gamma_end.to_string(),
            r.ar_bulk_rounds.to_string(),
            if r.alpha_hat.is_nan() {
                String::new()
            } else {
                format!("{:.4}", r.alpha_hat)
            },
        ]);
    }
    t
}

/// The acceptance-criteria shape claims.
pub fn check_shape(out: &AdaptiveOut) -> Result<(), String> {
    for batch in ramp_batches() {
        let (statics, adaptive) = out.phase_rows(batch);
        if statics.is_empty() {
            return Err(format!("phase B={batch}: no static rows"));
        }
        let best = statics.iter().map(|r| r.tok_s).fold(f64::MIN, f64::max);
        let worst = statics.iter().map(|r| r.tok_s).fold(f64::MAX, f64::min);
        if adaptive.tok_s < 0.95 * best {
            return Err(format!(
                "phase B={batch}: adaptive {:.1} tok/s < 0.95 × best static {best:.1}",
                adaptive.tok_s
            ));
        }
        if adaptive.tok_s <= worst {
            return Err(format!(
                "phase B={batch}: adaptive {:.1} tok/s does not beat worst static {worst:.1}",
                adaptive.tok_s
            ));
        }
    }
    // The compute-bound phase must show the AR fallback in action.
    let (_, adaptive_large) = out.phase_rows(*ramp_batches().last().unwrap());
    if adaptive_large.ar_bulk_rounds == 0 {
        return Err("largest phase: controller never fell back to γ=0".into());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_request_floor() {
        assert_eq!(phase_requests(1), 128);
        assert_eq!(phase_requests(512), 4096);
    }

    #[test]
    fn single_static_policy_runs_all_phases() {
        // Cheap smoke: one static policy across the ramp produces sane,
        // monotone-batch rows. (The full comparison runs in the
        // integration test and the bench target.)
        let stats = run_policy("static-2", 0.85, None, 2, 7).unwrap();
        assert_eq!(stats.len(), ramp_batches().len());
        for (s, b) in stats.iter().zip(ramp_batches()) {
            assert_eq!(s.batch, b);
            assert_eq!(s.tokens as usize, phase_requests(b) * MAX_NEW_TOKENS);
            assert!(s.tok_s > 0.0);
            assert_eq!(s.gamma_end, 2);
            assert!(s.alpha_hat.is_nan());
        }
        // Throughput grows with batch for a fixed γ on this sweep.
        assert!(stats.last().unwrap().tok_s > stats[0].tok_s);
    }

    #[test]
    fn csv_has_all_rows() {
        let out = AdaptiveOut {
            alpha: 0.85,
            rows: vec![PhaseStat {
                policy: "static-0".into(),
                batch: 8,
                tokens: 64,
                decode_s: 0.5,
                tok_s: 128.0,
                gamma_end: 0,
                ar_bulk_rounds: 3,
                alpha_hat: f64::NAN,
            }],
        };
        let t = to_csv(&out);
        assert_eq!(t.rows.len(), 1);
        assert_eq!(t.header.len(), 8);
        let parsed = CsvTable::parse(&t.to_string()).unwrap();
        assert_eq!(parsed.column_f64("tok_s").unwrap(), vec![128.0]);
    }
}
