//! Multi-tenant SLO-class serving sweep — trace-driven load × admission
//! policy (not from the paper's evaluation; it exercises the ROADMAP's
//! "multi-tenant SLO classes feeding the scheduler's admission search"
//! against the §3.4 latency-constrained serving scenario).
//!
//! ## Scenario
//!
//! Three tenant classes share one engine (qwen2-57B + 0.5B draft on
//! 2×GPU-A, virtual clock):
//!
//! - `chat` — interactive: priority 2, 20% of traffic, a TTFT SLO only
//!   priority admission can hold at overload, easy drafts (α 0.90);
//! - `code` — bulk completions: priority 1, 40% of traffic, easy drafts
//!   (α 0.92);
//! - `open` — bulk open-ended chat: priority 1, 40% of traffic, hard
//!   drafts (α 0.45).
//!
//! Arrivals come from the bundled production-shaped synthetic trace
//! ([`crate::workload::ArrivalTrace::synthetic_production`]: calm/burst
//! Markov modulation, correlated prompt/output lengths), replayed at a
//! sweep of rate factors ([`ArrivalTrace::rescale_rate`]). Each (load,
//! policy) point replays the identical classed request sequence through
//! the real engine and measures inside the trace window (steady-state
//! under backlog at overload — a drain-to-empty design would measure the
//! lopsided slow-class tail instead; see `experiments::ragged` for the
//! same argument).
//!
//! ## Arms
//!
//! - `fifo` — the pre-multi-tenant baseline: arrival order, class-blind;
//! - `class` — [`crate::scheduler::ClassAwareAdmission`], α-blind:
//!   priority tiers + aging + weighted fairness;
//! - `class+mix` — the same policy consulting the controller's priced
//!   regime oracle: candidates chosen to keep the batch's acceptance mix
//!   (and size) inside the speculative band;
//! - `ar` — the shared speedup reference: FIFO admission, γ = 0.
//!
//! All speculative arms run the adaptive controller (model-guided γ).
//!
//! `check_shape` pins the acceptance criteria: at the top load factor the
//! class-aware arms meet strictly more (class, SLO) targets than FIFO,
//! and the mix arm's measured speedup (shared AR denominator) stays at or
//! above the α-blind arm at every load and clears it at the top load —
//! margins validated against the python replica of the pricing model +
//! engine loop (`replica_multitenant.py` during PR development).

use super::parallel_sweep;
use crate::arch::presets;
use crate::batching::Request;
use crate::control::{ControlConfig, CostModelSpec};
use crate::engine::{Engine, EngineConfig};
use crate::hardware::{platform_2x_gpu_a, Platform};
use crate::kvcache::KvConfig;
use crate::scheduler::{AdmissionPolicyConfig, ClassAwareConfig, SchedulerConfig};
use crate::simulator::ExecSim;
use crate::spec::synthetic::SyntheticLm;
use crate::util::csv::CsvTable;
use crate::util::json::Json;
use crate::workload::{ArrivalTrace, TenantClass};

/// Batch ceiling: comfortably inside the speculative band for this
/// model/platform, so the sweep isolates admission *composition*.
pub const MAX_BATCH: usize = 64;

/// Per-class true draft acceptance (and the classes' admission hints).
pub const ALPHA_CHAT: f64 = 0.90;
pub const ALPHA_CODE: f64 = 0.92;
pub const ALPHA_OPEN: f64 = 0.45;

/// Interactive TTFT promise (virtual seconds) — holdable with priority
/// admission + bulk slot reservation at every swept load, hopeless under
/// FIFO at overload (replica-validated: fifo attainment 0.42–0.57 at the
/// top load across trace seeds, class-aware 0.94–1.0).
pub const CHAT_TTFT_SLO: f64 = 4.0;

/// Interactive TPOT promise (generous: per-class ceilings are exercised,
/// not load-bearing).
pub const CHAT_TPOT_SLO: f64 = 0.2;

/// Attainment threshold for counting an SLO as met.
pub const SLO_ATTAIN: f64 = 0.9;

/// Per-bulk-class running cap: reserves batch headroom so interactive
/// admissions never wait out a full bulk batch (the measurement window
/// at the top load spans ~12 virtual seconds of sustained backlog).
pub const BULK_MAX_RUNNING: usize = 20;

/// Trace shape: base duration and rate (before load rescaling).
pub const TRACE_DURATION_S: f64 = 36.0;
pub const TRACE_BASE_RATE: f64 = 30.0;

/// Load sweep: trace-rate multipliers (light → ~capacity → overload;
/// serving capacity for this workload is ≈ 1.2× the base rate).
pub fn default_loads() -> Vec<f64> {
    vec![0.5, 1.5, 3.0]
}

/// The experiment's tenant table.
pub fn tenant_classes() -> Vec<TenantClass> {
    let mut chat = TenantClass::new("chat");
    chat.priority = 2;
    chat.arrival_weight = 0.2;
    chat.ttft_slo = Some(CHAT_TTFT_SLO);
    chat.tpot_slo = Some(CHAT_TPOT_SLO);
    chat.alpha_hint = Some(ALPHA_CHAT);
    chat.max_new_tokens = 32;
    let mut code = TenantClass::new("code");
    code.arrival_weight = 0.4;
    code.alpha_hint = Some(ALPHA_CODE);
    code.max_new_tokens = 32;
    code.max_running = Some(BULK_MAX_RUNNING);
    let mut open = TenantClass::new("open");
    open.arrival_weight = 0.4;
    open.alpha_hint = Some(ALPHA_OPEN);
    open.max_new_tokens = 32;
    open.max_running = Some(BULK_MAX_RUNNING);
    vec![chat, code, open]
}

fn class_alpha(class: usize) -> f64 {
    [ALPHA_CHAT, ALPHA_CODE, ALPHA_OPEN][class.min(2)]
}

/// One class's in-window outcome.
#[derive(Debug, Clone, Default)]
pub struct ClassOutcome {
    pub name: String,
    pub completed: u64,
    pub tokens: u64,
    pub ttft_p99: f64,
    pub ttft_attainment: Option<f64>,
    pub tpot_attainment: Option<f64>,
}

/// One (load, policy) measurement.
#[derive(Debug, Clone)]
pub struct ArmStat {
    pub load: f64,
    /// `fifo`, `class`, `class+mix` or `ar`.
    pub policy: String,
    pub requests_offered: usize,
    pub requests_completed: u64,
    pub tokens: u64,
    pub decode_s: f64,
    /// Goodput inside the window (committed tokens / decode seconds).
    pub tok_s: f64,
    pub mean_batch: f64,
    /// tok_s over the shared AR reference's tok_s at the same load.
    pub speedup: f64,
    /// (class, SLO-kind) targets attained at [`SLO_ATTAIN`].
    pub slos_met: usize,
    pub classes: Vec<ClassOutcome>,
}

#[derive(Debug, Clone)]
pub struct MultitenantOut {
    pub rows: Vec<ArmStat>,
    pub loads: Vec<f64>,
}

fn sims() -> (ExecSim, ExecSim) {
    let platform = platform_2x_gpu_a();
    let target = ExecSim::new(presets::qwen2_57b_a14b(), platform.clone());
    let draft_platform = Platform::new(platform.gpu.clone(), 1, platform.interconnect_bw);
    let draft = ExecSim::new(presets::qwen2_0_5b(), draft_platform);
    (target, draft)
}

fn adaptive_control(mix: bool) -> ControlConfig {
    let (tsim, dsim) = sims();
    ControlConfig {
        alpha_prior: 0.75,
        track_seq_alpha: mix,
        seq_window_rounds: 4,
        ..ControlConfig::model_guided(CostModelSpec::roofline(tsim, dsim))
    }
}

/// Build one arm's engine over the classed request set.
fn build_engine(
    requests: &[Request],
    admission: AdmissionPolicyConfig,
    gamma: usize,
    control: Option<ControlConfig>,
    seed: u64,
) -> Engine<SyntheticLm> {
    let (tsim, dsim) = sims();
    let seq_alphas: Vec<(u64, f64)> = requests
        .iter()
        .map(|r| (r.id, class_alpha(r.class)))
        .collect();
    let backend = SyntheticLm::new(tsim, dsim, 0.8, seed).with_seq_alphas(&seq_alphas);
    let config = EngineConfig {
        gamma,
        kv: KvConfig {
            num_blocks: 1 << 16,
            block_size: 16,
        },
        scheduler: SchedulerConfig {
            max_batch: MAX_BATCH,
            admit_reserve_tokens: 32,
            tpot_slo: None,
        },
        seed,
        control,
        tenants: tenant_classes(),
        admission,
        ..Default::default()
    };
    Engine::new(config, backend)
}

/// Replay one arm inside the trace window: submit everything, step until
/// the clock passes `horizon` (or the engine drains), snapshot metrics.
fn run_arm(
    requests: &[Request],
    admission: AdmissionPolicyConfig,
    gamma: usize,
    control: Option<ControlConfig>,
    seed: u64,
    horizon: f64,
) -> anyhow::Result<(Engine<SyntheticLm>, u64, f64)> {
    let mut engine = build_engine(requests, admission, gamma, control, seed);
    for r in requests {
        engine.submit(r.clone());
    }
    let mut guard = 0usize;
    while !engine.is_idle() && engine.clock() < horizon {
        engine.step()?;
        guard += 1;
        anyhow::ensure!(guard < 200_000, "window run exceeded the step guard");
    }
    let tokens = engine.metrics.tokens_generated;
    let decode = engine.metrics.decode_time();
    anyhow::ensure!(decode > 0.0, "arm measured no decode time");
    Ok((engine, tokens, decode))
}

fn collect(
    load: f64,
    policy: &str,
    offered: usize,
    engine: &Engine<SyntheticLm>,
    tokens: u64,
    decode: f64,
    ar_tok_s: f64,
) -> ArmStat {
    let tenants = tenant_classes();
    let m = &engine.metrics;
    let mut classes = Vec::new();
    let mut slos_met = 0usize;
    for (i, t) in tenants.iter().enumerate() {
        let mut out = ClassOutcome {
            name: t.name.clone(),
            ..ClassOutcome::default()
        };
        if let Some(cm) = m.class.get(i) {
            out.completed = cm.requests_completed;
            out.tokens = cm.tokens_generated;
            out.ttft_p99 = cm.ttft.0.quantile(0.99);
            out.ttft_attainment = cm.ttft_attainment();
            out.tpot_attainment = cm.tpot_attainment();
            for a in [out.ttft_attainment, out.tpot_attainment].into_iter().flatten() {
                if a >= SLO_ATTAIN {
                    slos_met += 1;
                }
            }
        }
        classes.push(out);
    }
    let tok_s = tokens as f64 / decode;
    ArmStat {
        load,
        policy: policy.to_string(),
        requests_offered: offered,
        requests_completed: m.requests_completed,
        tokens,
        decode_s: decode,
        tok_s,
        mean_batch: m.mean_batch(),
        speedup: if ar_tok_s > 0.0 { tok_s / ar_tok_s } else { 0.0 },
        slos_met,
        classes,
    }
}

/// Run the full load × policy sweep over `trace` (each load fanned across
/// worker threads; every arm builds its own seeded engine).
pub fn run(trace: &ArrivalTrace, loads: &[f64], seed: u64) -> anyhow::Result<MultitenantOut> {
    let tenants = tenant_classes();
    let per_load: Vec<anyhow::Result<Vec<ArmStat>>> = parallel_sweep(loads, |&load| {
        let scaled = trace.rescale_rate(load);
        let horizon = scaled.duration().max(1e-6);
        let requests = scaled.to_requests(&tenants, 0, seed ^ 0x3b);
        let offered = requests.len();
        // Shared AR reference: FIFO admission, γ = 0.
        let (ar_engine, ar_tokens, ar_decode) = run_arm(
            &requests,
            AdmissionPolicyConfig::Fifo,
            0,
            None,
            seed,
            horizon,
        )?;
        let ar_tok_s = ar_tokens as f64 / ar_decode;
        let mut rows = vec![collect(
            load, "ar", offered, &ar_engine, ar_tokens, ar_decode, ar_tok_s,
        )];
        let arms: [(&str, AdmissionPolicyConfig, Option<ControlConfig>); 3] = [
            (
                "fifo",
                AdmissionPolicyConfig::Fifo,
                Some(adaptive_control(false)),
            ),
            (
                "class",
                AdmissionPolicyConfig::ClassAware(ClassAwareConfig {
                    aging_tau: 6.0,
                    ..ClassAwareConfig::default()
                }),
                Some(adaptive_control(false)),
            ),
            (
                "class+mix",
                AdmissionPolicyConfig::ClassAware(ClassAwareConfig {
                    aging_tau: 6.0,
                    mix_hold_max: 12.0,
                    ..ClassAwareConfig::mix_aware(1.05)
                }),
                Some(adaptive_control(true)),
            ),
        ];
        for (name, admission, control) in arms {
            let (engine, tokens, decode) =
                run_arm(&requests, admission, 0, control, seed, horizon)?;
            rows.push(collect(load, name, offered, &engine, tokens, decode, ar_tok_s));
        }
        Ok(rows)
    });
    let mut rows = Vec::new();
    for r in per_load {
        rows.extend(r?);
    }
    Ok(MultitenantOut {
        rows,
        loads: loads.to_vec(),
    })
}

impl MultitenantOut {
    pub fn arm(&self, load: f64, policy: &str) -> Option<&ArmStat> {
        self.rows
            .iter()
            .find(|r| r.load == load && r.policy == policy)
    }

    pub fn top_load(&self) -> f64 {
        self.loads.iter().cloned().fold(f64::MIN, f64::max)
    }
}

pub fn to_csv(out: &MultitenantOut) -> CsvTable {
    let mut t = CsvTable::new(&[
        "load",
        "policy",
        "offered",
        "completed",
        "tokens",
        "decode_s",
        "tok_s",
        "mean_batch",
        "speedup",
        "slos_met",
        "chat_ttft_attainment",
        "chat_tpot_attainment",
        "chat_ttft_p99",
    ]);
    for r in &out.rows {
        let chat = &r.classes[0];
        let opt = |v: Option<f64>| v.map_or("".to_string(), |x| format!("{x:.4}"));
        t.push_row(vec![
            format!("{}", r.load),
            r.policy.clone(),
            r.requests_offered.to_string(),
            r.requests_completed.to_string(),
            r.tokens.to_string(),
            format!("{:.6}", r.decode_s),
            format!("{:.2}", r.tok_s),
            format!("{:.2}", r.mean_batch),
            format!("{:.4}", r.speedup),
            r.slos_met.to_string(),
            opt(chat.ttft_attainment),
            opt(chat.tpot_attainment),
            format!("{:.4}", chat.ttft_p99),
        ]);
    }
    t
}

/// Per-tenant stats JSON (the shape ci.sh's smoke gate validates).
pub fn to_json(out: &MultitenantOut) -> Json {
    let arms = out
        .rows
        .iter()
        .map(|r| {
            Json::from_pairs(vec![
                ("load", r.load.into()),
                ("policy", r.policy.as_str().into()),
                ("offered", r.requests_offered.into()),
                ("completed", r.requests_completed.into()),
                ("tok_s", r.tok_s.into()),
                ("mean_batch", r.mean_batch.into()),
                ("speedup", r.speedup.into()),
                ("slos_met", r.slos_met.into()),
                (
                    "classes",
                    Json::Arr(
                        r.classes
                            .iter()
                            .map(|c| {
                                let opt = |v: Option<f64>| match v {
                                    Some(x) => x.into(),
                                    None => Json::Null,
                                };
                                Json::from_pairs(vec![
                                    ("name", c.name.as_str().into()),
                                    ("completed", c.completed.into()),
                                    ("tokens", c.tokens.into()),
                                    ("ttft_p99", c.ttft_p99.into()),
                                    ("ttft_slo_attainment", opt(c.ttft_attainment)),
                                    ("tpot_slo_attainment", opt(c.tpot_attainment)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ])
        })
        .collect();
    Json::from_pairs(vec![
        ("experiment", "multitenant".into()),
        ("max_batch", MAX_BATCH.into()),
        ("loads", Json::Arr(out.loads.iter().map(|&l| l.into()).collect())),
        ("arms", Json::Arr(arms)),
    ])
}

/// The acceptance-criteria shape claims (margins validated against the
/// python replica of the pricing model + engine/admission loop; see the
/// module docs).
pub fn check_shape(out: &MultitenantOut) -> Result<(), String> {
    let top = out.top_load();
    for &load in &out.loads {
        for policy in ["ar", "fifo", "class", "class+mix"] {
            let r = out
                .arm(load, policy)
                .ok_or_else(|| format!("missing arm {policy} at load {load}"))?;
            if r.tokens == 0 || r.tok_s <= 0.0 {
                return Err(format!("arm {policy}@{load} produced no work: {r:?}"));
            }
        }
        // Mix-aware admission sustains the blind arm's measured speedup
        // everywhere (replica-validated floor: per-load mix/blind ratios
        // 0.992–1.114 across trace seeds; 0.97 leaves noise room).
        let mix = out.arm(load, "class+mix").unwrap();
        let blind = out.arm(load, "class").unwrap();
        if mix.speedup < 0.97 * blind.speedup {
            return Err(format!(
                "load {load}: mix speedup {:.3} under α-blind {:.3}",
                mix.speedup, blind.speedup
            ));
        }
    }
    // At overload: class-aware admission meets strictly more SLO targets
    // than FIFO (the chat TTFT promise is unholdable behind the backlog).
    let fifo = out.arm(top, "fifo").unwrap();
    for policy in ["class", "class+mix"] {
        let arm = out.arm(top, policy).unwrap();
        if arm.slos_met <= fifo.slos_met {
            return Err(format!(
                "top load: {policy} met {} SLOs vs fifo {} — not strictly more",
                arm.slos_met, fifo.slos_met
            ));
        }
        let chat = &arm.classes[0];
        if chat.ttft_attainment.unwrap_or(0.0) < SLO_ATTAIN {
            return Err(format!(
                "top load: {policy} chat TTFT attainment {:?} under {SLO_ATTAIN}",
                chat.ttft_attainment
            ));
        }
    }
    // And the mix arm's deliberate easy/hard balancing clears the α-blind
    // composition at overload: the served-mix α is higher, so is goodput
    // (replica-validated edges 1.043–1.114 at the top load; ≥2% asserted).
    let mix = out.arm(top, "class+mix").unwrap();
    let blind = out.arm(top, "class").unwrap();
    if mix.tok_s < 1.02 * blind.tok_s {
        return Err(format!(
            "top load: mix goodput {:.1} should clear α-blind {:.1} by ≥2%",
            mix.tok_s, blind.tok_s
        ));
    }
    // Sustained overload stays deep inside the speculative band for the
    // mix arm (replica: speedup ≈ 2.0 over the shared AR reference).
    if mix.speedup < 1.3 {
        return Err(format!(
            "top load: mix arm speedup {:.3} should stay well above AR",
            mix.speedup
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tenant_table_matches_design() {
        let ts = tenant_classes();
        assert_eq!(ts.len(), 3);
        assert_eq!(ts[0].name, "chat");
        assert_eq!(ts[0].priority, 2);
        assert!(ts[0].ttft_slo.is_some() && ts[0].tpot_slo.is_some());
        assert!(ts[1].ttft_slo.is_none());
        let share: f64 = ts.iter().map(|t| t.arrival_weight).sum();
        assert!((share - 1.0).abs() < 1e-12);
        assert!(class_alpha(1) > class_alpha(2));
    }

    #[test]
    fn csv_and_json_render() {
        let row = ArmStat {
            load: 2.0,
            policy: "class".into(),
            requests_offered: 100,
            requests_completed: 80,
            tokens: 2500,
            decode_s: 1.25,
            tok_s: 2000.0,
            mean_batch: 40.0,
            speedup: 1.4,
            slos_met: 2,
            classes: vec![
                ClassOutcome {
                    name: "chat".into(),
                    completed: 20,
                    tokens: 640,
                    ttft_p99: 0.4,
                    ttft_attainment: Some(0.95),
                    tpot_attainment: Some(1.0),
                },
                ClassOutcome::default(),
                ClassOutcome::default(),
            ],
        };
        let out = MultitenantOut {
            rows: vec![row],
            loads: vec![2.0],
        };
        let t = to_csv(&out);
        assert_eq!(t.rows.len(), 1);
        let parsed = CsvTable::parse(&t.to_string()).unwrap();
        assert_eq!(parsed.column_str("policy").unwrap()[0], "class");
        let j = to_json(&out);
        let s = j.to_pretty();
        assert!(s.contains("\"ttft_slo_attainment\""));
        assert!(s.contains("\"slos_met\""));
        // The smoke gate's shape contract: parse back and walk the arms.
        let back = Json::parse(&s).unwrap();
        let arms = back.req_arr("arms").unwrap();
        assert_eq!(arms.len(), 1);
        assert_eq!(arms[0].req_str("policy").unwrap(), "class");
        assert_eq!(arms[0].req_arr("classes").unwrap().len(), 3);
        assert_eq!(out.top_load(), 2.0);
    }

    #[test]
    fn single_point_smoke_runs_all_arms() {
        // One cheap overload point on a short trace: every arm completes
        // the window with positive goodput, classed completions land in
        // the right buckets, and the class-aware arms never do worse on
        // the chat TTFT SLO than FIFO. (Short windows don't build enough
        // backlog for the *strict* separation — that claim needs the full
        // trace and runs in rust/tests/integration_multitenant.rs and
        // `moesd bench multitenant`.)
        let trace = ArrivalTrace::synthetic_production(6.0, 30.0, 11);
        let out = run(&trace, &[4.0], 11).unwrap();
        assert_eq!(out.rows.len(), 4);
        for r in &out.rows {
            assert!(r.tok_s > 0.0, "{r:?}");
            assert!(r.requests_completed > 0, "{r:?}");
            assert_eq!(r.classes.len(), 3);
            let by_class: u64 = r.classes.iter().map(|c| c.completed).sum();
            assert_eq!(by_class, r.requests_completed, "{r:?}");
        }
        let fifo = out.arm(4.0, "fifo").unwrap();
        for policy in ["class", "class+mix"] {
            let arm = out.arm(4.0, policy).unwrap();
            assert!(
                arm.classes[0].ttft_attainment.unwrap_or(0.0) + 1e-9
                    >= fifo.classes[0].ttft_attainment.unwrap_or(0.0),
                "{policy} must not do worse on chat TTFT than fifo: {:?} vs {:?}",
                arm.classes[0].ttft_attainment,
                fifo.classes[0].ttft_attainment
            );
        }
    }
}
