//! Fig. 3 — target efficiency: MoE vs dense model.
//!
//! MoE (Qwen2-57B) target efficiency rises then falls with batch size;
//! the dense model's (OPT-30B) only falls. Computed directly from the
//! simulator's T_T(B, s) (the paper computes it from vLLM runtime logs).

use crate::arch::presets;
use crate::hardware::platform_2x_gpu_a;
use crate::simulator::ExecSim;
use crate::util::csv::CsvTable;

pub struct Fig3Output {
    pub table: CsvTable,
    pub moe_eff: Vec<f64>,
    pub dense_eff: Vec<f64>,
    pub batches: Vec<usize>,
}

pub fn run(gamma: usize) -> Fig3Output {
    let batches = super::paper_batch_grid();
    let moe = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
    let dense = ExecSim::new(presets::opt_30b(), platform_2x_gpu_a());
    let mut table = CsvTable::new(&["batch", "moe_target_eff", "dense_target_eff"]);
    let mut moe_eff = Vec::new();
    let mut dense_eff = Vec::new();
    for &b in &batches {
        let m = moe.target_efficiency(b, gamma, 512);
        let d = dense.target_efficiency(b, gamma, 512);
        moe_eff.push(m);
        dense_eff.push(d);
        table.push_nums(&[b as f64, m, d]);
    }
    Fig3Output {
        table,
        moe_eff,
        dense_eff,
        batches,
    }
}

/// The Fig. 3 shape claims.
pub fn check_shape(out: &Fig3Output) -> Result<(), String> {
    let peak = crate::util::stats::argmax(&out.moe_eff);
    if peak == 0 {
        return Err(format!("MoE efficiency should rise first: {:?}", out.moe_eff));
    }
    if out.moe_eff[peak] <= *out.moe_eff.last().unwrap() + 0.02 {
        return Err("MoE efficiency should fall at large B".into());
    }
    for w in out.dense_eff.windows(2) {
        if w[1] > w[0] + 0.02 {
            return Err(format!("dense efficiency rose: {:?}", out.dense_eff));
        }
    }
    // A crossover exists at a moderate batch size, past which MoE
    // efficiency exceeds dense for the rest of the sweep (the paper's
    // "stronger potential across a wider range of larger batch sizes" —
    // dense holds efficiency ≈1 while fully memory-bound, so the cross
    // happens where dense turns compute-bound, B ≈ 30–60 on GPU-A).
    let cross = out
        .batches
        .iter()
        .position(|&b| {
            let i = out.batches.iter().position(|&x| x == b).unwrap();
            out.moe_eff[i] > out.dense_eff[i]
        })
        .ok_or("no MoE/dense efficiency crossover in the sweep")?;
    if out.batches[cross] > 64 {
        return Err(format!(
            "crossover too late: B={} ({:?} vs {:?})",
            out.batches[cross], out.moe_eff, out.dense_eff
        ));
    }
    for i in cross..out.batches.len() {
        if out.moe_eff[i] <= out.dense_eff[i] {
            return Err(format!(
                "MoE should stay above dense past crossover at B={}",
                out.batches[i]
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_shape_holds() {
        let out = run(3);
        check_shape(&out).unwrap();
        assert_eq!(out.table.rows.len(), out.batches.len());
    }
}
