//! Fig. 5 — SD speedup trends across more settings, with 5 individual
//! runs + their mean, including the tile-quantization sawtooth (App. A.1).

use super::{paper_batch_grid, parallel_sweep, run_pair, RunOpts};
use crate::arch::presets;
use crate::hardware::platform_by_name;
use crate::util::csv::CsvTable;
use crate::workload::{calibrated_alpha, Dataset};

pub struct Fig5Output {
    /// rows: batch × runs (run0..run4, mean).
    pub table: CsvTable,
    pub mean_speedups: Vec<f64>,
    pub run_stddev: f64,
}

/// One Fig. 5 panel: `runs` independent noisy runs of a batch sweep.
pub fn run(
    model: &str,
    platform: &str,
    dataset: Dataset,
    temp: f64,
    gamma: usize,
    runs: usize,
) -> anyhow::Result<Fig5Output> {
    let (target, draft) = match model {
        "qwen2" => (presets::qwen2_57b_a14b(), presets::qwen2_0_5b()),
        "mixtral" => (presets::mixtral_8x7b(), presets::eagle_head_mixtral()),
        other => anyhow::bail!("unknown model {other}"),
    };
    let platform = platform_by_name(platform)?;
    let alpha = calibrated_alpha(model, dataset, temp, gamma);
    let batches = paper_batch_grid();

    // The whole runs × batches grid fans across worker threads at once
    // (run-major order, reshaped below).
    let mut points: Vec<(u64, usize)> = Vec::with_capacity(runs * batches.len());
    for r in 0..runs {
        for &b in &batches {
            points.push((1000 + r as u64, b));
        }
    }
    let flat: Vec<f64> = parallel_sweep(&points, |&(seed, b)| {
        let opts = RunOpts {
            seed,
            noise: true,
            tile_effects: true,
            max_new_tokens: 24,
            ..Default::default()
        };
        run_pair(&target, &draft, &platform, alpha, gamma, b, &opts).map(|s| s.speedup)
    })
    .into_iter()
    .collect::<anyhow::Result<_>>()?;
    let per_run: Vec<Vec<f64>> = flat.chunks(batches.len()).map(<[f64]>::to_vec).collect();

    let mut header = vec!["batch".to_string()];
    for r in 0..runs {
        header.push(format!("run{r}"));
    }
    header.push("mean".into());
    let mut table = CsvTable {
        header,
        rows: Vec::new(),
    };
    let mut mean_speedups = Vec::with_capacity(batches.len());
    let mut devs = Vec::new();
    for (i, &b) in batches.iter().enumerate() {
        let vals: Vec<f64> = per_run.iter().map(|r| r[i]).collect();
        let mean = crate::util::stats::mean(&vals);
        devs.push(crate::util::stats::stddev(&vals));
        mean_speedups.push(mean);
        let mut row = vec![b as f64];
        row.extend(&vals);
        row.push(mean);
        table.push_nums(&row);
    }
    Ok(Fig5Output {
        table,
        mean_speedups,
        run_stddev: crate::util::stats::mean(&devs),
    })
}

/// Shape checks: rise-then-fall of the mean, and small run-to-run
/// variance (App. A.1: "the variance across different runs is minimal").
pub fn check_shape(out: &Fig5Output) -> Result<(), String> {
    let peak = crate::util::stats::argmax(&out.mean_speedups);
    if peak == 0 || peak == out.mean_speedups.len() - 1 {
        return Err(format!("mean speedup peak not interior: {:?}", out.mean_speedups));
    }
    let peak_val = out.mean_speedups[peak];
    if out.run_stddev > 0.15 * peak_val {
        return Err(format!(
            "run-to-run stddev too large: {} vs peak {peak_val}",
            out.run_stddev
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noisy_runs_have_small_variance_and_paper_shape() {
        let out = run("qwen2", "2xGPU-A", Dataset::HumanEval, 0.0, 3, 3).unwrap();
        check_shape(&out).unwrap();
        assert!(out.run_stddev > 0.0, "noise should produce some variance");
    }
}
