//! Expert-parallel topology sweep — the §3.4 "extensive EP
//! configurations" scale axis, turned into a figure family.
//!
//! Every existing experiment prices one serving group; this sweep widens
//! the axis to a rack: SD speedup × batch size × EP degree × MoE sparsity,
//! across an NVLink-class and a PCIe-class fabric. Speedups come from the
//! Eq. 4 decomposition over the EP-sharded roofline prices
//! ([`crate::simulator::ExecSim::with_sharding`]), with one draft replica
//! per EP rank (a dense model's EP walk is pure data parallelism —
//! per-rank `B/d` tokens on replicated weights — the same pricing the
//! engine's backend charges, so sweep and engine numbers reconcile).
//!
//! The qualitative claims `check_shape` pins (each validated against an
//! independent python replica of the pricing model):
//! 1. the SD-favorable batch range — the largest B whose Eq. 4 speedup
//!    exceeds 1 ([`crossover_batch`]) — grows monotonically with EP
//!    degree at every sparsity, on both fabrics;
//! 2. sparser MoE (smaller K) pushes the crossover further out at every
//!    topology — sparsity × EP degree compound;
//! 3. on the payload-heavy K=8 axis a communication-bound fabric (PCIe)
//!    drags target efficiency below NVLink's and narrows the
//!    high-efficiency batch band. (Curiosity, deliberately *not*
//!    asserted: at very sparse K with many ranks the comparison can
//!    invert — the all-to-all payload shrinks with K while PCIe's
//!    γ-independent launch latency dilutes the verify-term growth.)

use super::parallel_sweep;
use crate::arch::presets;
use crate::hardware::{platform_2x_gpu_a, Platform, ShardingSpec, Topology};
use crate::simulator::ExecSim;
use crate::theory;
use crate::util::csv::CsvTable;

/// Fabric class of an EP group (the `d = 1` baseline has none).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fabric {
    /// Single rank — no inter-rank fabric.
    None,
    /// NVLink/NVSwitch-class ([`Topology::nvlink`]).
    NvLink,
    /// PCIe-class ([`Topology::pcie`]) — the communication-bound regime.
    Pcie,
}

impl Fabric {
    pub fn name(&self) -> &'static str {
        match self {
            Fabric::None => "none",
            Fabric::NvLink => "nvlink",
            Fabric::Pcie => "pcie",
        }
    }

    /// Topology for `devices` ranks (`None` iff `devices == 1`).
    pub fn topology(&self, devices: usize) -> Option<Topology> {
        match self {
            Fabric::None => None,
            Fabric::NvLink => Some(Topology::nvlink(devices)),
            Fabric::Pcie => Some(Topology::pcie(devices)),
        }
    }
}

/// EP degrees swept (1 is the unsharded baseline).
pub const EP_DEGREES: [usize; 4] = [1, 2, 4, 8];

/// Activated-experts-per-token sweep (Qwen2-57B's K=8 plus the sparser
/// Fig. 4-style variants).
pub const TOPK_SWEEP: [usize; 3] = [2, 4, 8];

/// Power-of-two batch grid 1..4096 — wide enough to cross every regime
/// from memory-bound EP ranks to the compute-bound collapse.
pub fn sharding_batch_grid() -> Vec<usize> {
    (0..=12).map(|i| 1usize << i).collect()
}

/// One point of the sweep.
#[derive(Debug, Clone, Copy)]
pub struct ShardPoint {
    pub devices: usize,
    pub fabric: Fabric,
    pub k: usize,
    pub batch: usize,
    /// Sharded target efficiency T_T(B,1)/T_T(B,γ+1) (§3.1).
    pub target_efficiency: f64,
    /// Eq. 4 analytic speedup over the sharded prices.
    pub speedup: f64,
}

pub struct ShardingOutput {
    pub gamma: usize,
    pub alpha: f64,
    pub points: Vec<ShardPoint>,
    pub table: CsvTable,
}

/// The sharded target simulator for one (fabric, d, K) configuration.
fn target_sim(fabric: Fabric, devices: usize, k: usize) -> ExecSim {
    let target = presets::qwen2_57b_a14b().with_topk(k);
    let mut sim = ExecSim::new(target.clone(), platform_2x_gpu_a());
    if let Some(topo) = fabric.topology(devices) {
        sim = sim.with_sharding(ShardingSpec::for_arch(topo, &target));
    }
    sim
}

/// Draft replica on one GPU of its rank (same convention as the engine
/// builder in `experiments::build_engine`): one replica per EP rank,
/// which for a dense draft is the EP walk's data-parallel degenerate
/// case (per-rank `B/d` tokens, replicated weights, zero fabric
/// payload) — identical pricing to what the engine's backend charges.
fn draft_sim(fabric: Fabric, devices: usize) -> ExecSim {
    let platform = platform_2x_gpu_a();
    let draft_platform = Platform::new(platform.gpu.clone(), 1, platform.interconnect_bw);
    let draft = presets::qwen2_0_5b();
    let mut sim = ExecSim::new(draft.clone(), draft_platform);
    if let Some(topo) = fabric.topology(devices) {
        sim = sim.with_sharding(ShardingSpec::for_arch(topo, &draft));
    }
    sim
}

/// Eq. 4 point evaluation: (target efficiency, speedup) at one setting.
fn eval_point(
    tsim: &ExecSim,
    dsim: &ExecSim,
    batch: usize,
    gamma: usize,
    alpha: f64,
) -> (f64, f64) {
    let ctx = 512;
    let t1 = tsim.t_forward(batch, 1, ctx);
    let tg = tsim.t_forward(batch, gamma + 1, ctx);
    let td = dsim.t_forward(batch, 1, ctx);
    let rej = tsim.t_reject(batch, gamma);
    let sigma = theory::sigma_from_alpha(alpha, gamma);
    let terms = theory::speedup_decomposition(t1, tg, td, rej, sigma, gamma);
    (theory::target_efficiency(t1, tg), terms.speedup())
}

/// The fabric × EP-degree configurations swept (d = 1 baseline once).
pub fn default_configs() -> Vec<(Fabric, usize)> {
    let mut cfgs = vec![(Fabric::None, 1)];
    for &d in &EP_DEGREES[1..] {
        cfgs.push((Fabric::NvLink, d));
        cfgs.push((Fabric::Pcie, d));
    }
    cfgs
}

/// Run the full sweep: every (fabric, d) × K × batch point, fanned across
/// worker threads (each point builds its own simulators, so results are
/// bit-identical to a serial sweep).
pub fn run(gamma: usize, alpha: f64) -> ShardingOutput {
    let batches = sharding_batch_grid();
    let mut grid: Vec<(Fabric, usize, usize, usize)> = Vec::new();
    for &(fabric, d) in &default_configs() {
        for &k in &TOPK_SWEEP {
            for &b in &batches {
                grid.push((fabric, d, k, b));
            }
        }
    }
    let points: Vec<ShardPoint> = parallel_sweep(&grid, |&(fabric, d, k, b)| {
        let tsim = target_sim(fabric, d, k);
        let dsim = draft_sim(fabric, d);
        let (teff, x) = eval_point(&tsim, &dsim, b, gamma, alpha);
        ShardPoint {
            devices: d,
            fabric,
            k,
            batch: b,
            target_efficiency: teff,
            speedup: x,
        }
    });
    let mut table = CsvTable::new(&[
        "devices",
        "fabric",
        "link_gbps",
        "k",
        "batch",
        "target_efficiency",
        "speedup",
    ]);
    for p in &points {
        let link = p
            .fabric
            .topology(p.devices)
            .map_or(0.0, |t| t.link_bw / 1e9);
        table.push_row(vec![
            format!("{}", p.devices),
            p.fabric.name().to_string(),
            crate::util::csv::format_num(link),
            format!("{}", p.k),
            format!("{}", p.batch),
            format!("{:.4}", p.target_efficiency),
            format!("{:.4}", p.speedup),
        ]);
    }
    ShardingOutput {
        gamma,
        alpha,
        points,
        table,
    }
}

/// The SD-favorable upper edge: largest B (16-step scan up to 2048) whose
/// Eq. 4 speedup exceeds 1 at this configuration.
pub fn crossover_batch(
    fabric: Fabric,
    devices: usize,
    k: usize,
    gamma: usize,
    alpha: f64,
) -> usize {
    let tsim = target_sim(fabric, devices, k);
    let dsim = draft_sim(fabric, devices);
    let mut best = 0;
    let mut b = 16;
    while b <= 2048 {
        let (_, x) = eval_point(&tsim, &dsim, b, gamma, alpha);
        if x > 1.0 {
            best = b;
        }
        b += 16;
    }
    best
}

/// Width of the high-efficiency band: how many grid batches keep sharded
/// target efficiency ≥ `tau`.
pub fn teff_band_width(fabric: Fabric, devices: usize, k: usize, gamma: usize, tau: f64) -> usize {
    let tsim = target_sim(fabric, devices, k);
    sharding_batch_grid()
        .into_iter()
        .filter(|&b| tsim.target_efficiency(b, gamma, 512) >= tau)
        .count()
}

/// The monotonicity claims of the module docs, asserted on the sweep
/// (validated against the python replica — see module docs).
pub fn check_shape(out: &ShardingOutput) -> Result<(), String> {
    for p in &out.points {
        if !(p.speedup.is_finite() && p.speedup > 0.0) {
            return Err(format!("non-finite speedup at {p:?}"));
        }
        if !(p.target_efficiency > 0.0 && p.target_efficiency <= 1.0 + 1e-9) {
            return Err(format!("target efficiency out of range at {p:?}"));
        }
    }
    let (gamma, alpha) = (out.gamma, out.alpha);

    // 1. Favorable range grows with EP degree, per sparsity and fabric.
    for &k in &TOPK_SWEEP {
        for fabric in [Fabric::NvLink, Fabric::Pcie] {
            let mut prev = crossover_batch(Fabric::None, 1, k, gamma, alpha);
            let base = prev;
            for &d in &EP_DEGREES[1..] {
                let edge = crossover_batch(fabric, d, k, gamma, alpha);
                if edge < prev {
                    return Err(format!(
                        "favorable edge shrank with EP: K={k} {} d={d}: {edge} < {prev}",
                        fabric.name()
                    ));
                }
                prev = edge;
            }
            if prev <= base {
                return Err(format!(
                    "8-way EP should strictly widen the favorable range: K={k} {}: {prev} vs {base}",
                    fabric.name()
                ));
            }
        }
    }

    // 2. Sparser MoE pushes the edge out at every topology.
    for &(fabric, d) in &default_configs() {
        let mut prev = usize::MAX;
        for &k in &TOPK_SWEEP {
            let edge = crossover_batch(fabric, d, k, gamma, alpha);
            if edge > prev {
                return Err(format!(
                    "sparser K should not narrow the range: {} d={d} K={k}: {edge} > {prev}",
                    fabric.name()
                ));
            }
            prev = edge;
        }
    }

    // 3. Communication-bound fabric (payload-heavy K=8 axis): PCIe target
    //    efficiency sits below NVLink's, and the ≥0.85 band is narrower.
    for &d in &EP_DEGREES[1..] {
        for b in [16usize, 32, 64, 128] {
            let nv = target_sim(Fabric::NvLink, d, 8).target_efficiency(b, gamma, 512);
            let pc = target_sim(Fabric::Pcie, d, 8).target_efficiency(b, gamma, 512);
            if pc >= nv {
                return Err(format!(
                    "PCIe teff should trail NVLink at K=8 d={d} B={b}: {pc} vs {nv}"
                ));
            }
        }
        let w_nv = teff_band_width(Fabric::NvLink, d, 8, gamma, 0.85);
        let w_pc = teff_band_width(Fabric::Pcie, d, 8, gamma, 0.85);
        if w_pc > w_nv {
            return Err(format!(
                "PCIe high-efficiency band wider than NVLink at d={d}: {w_pc} > {w_nv}"
            ));
        }
        if d >= 4 && w_pc >= w_nv {
            return Err(format!(
                "PCIe band should be strictly narrower at d={d}: {w_pc} vs {w_nv}"
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_grid_and_passes_shape() {
        let out = run(3, 0.9);
        let want = default_configs().len() * TOPK_SWEEP.len() * sharding_batch_grid().len();
        assert_eq!(out.points.len(), want);
        assert_eq!(out.table.rows.len(), want);
        check_shape(&out).unwrap();
    }

    #[test]
    fn crossover_values_match_python_replica() {
        // Spot values computed by the independent python replica of the
        // pricing model (16-step scan, γ=3, α=0.9): K=8 crossovers
        // 352 (d=1) → 384 (d=4 nvlink) → 464 (d=8 nvlink).
        assert_eq!(crossover_batch(Fabric::None, 1, 8, 3, 0.9), 352);
        assert_eq!(crossover_batch(Fabric::NvLink, 4, 8, 3, 0.9), 384);
        assert_eq!(crossover_batch(Fabric::NvLink, 8, 8, 3, 0.9), 464);
    }

    #[test]
    fn baseline_points_match_unsharded_simulator() {
        // The d=1 column of the sweep must be exactly the unsharded
        // simulator's numbers (no spec, no fabric).
        let out = run(3, 0.9);
        let plain = ExecSim::new(presets::qwen2_57b_a14b().with_topk(8), platform_2x_gpu_a());
        for p in out.points.iter().filter(|p| p.devices == 1 && p.k == 8) {
            assert_eq!(
                p.target_efficiency,
                plain.target_efficiency(p.batch, 3, 512),
                "B={}",
                p.batch
            );
        }
    }
}
