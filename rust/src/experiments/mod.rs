//! Experiment implementations — one module per paper table/figure.
//!
//! Each `figN`/`tableN` module exposes a `run(...)` returning structured
//! results; the matching `rust/benches/*` target prints the paper-style
//! rows, writes CSV/markdown under `results/`, and asserts the paper's
//! qualitative *shape* claims (who wins, where peaks fall). The CLI
//! (`moesd bench <id>`) calls the same code.
//!
//! Shared machinery here: [`run_pair`] measures one (platform, model,
//! α, γ, B) point by driving the *actual serving engine* twice — once
//! speculative, once autoregressive — on the synthetic backend's virtual
//! clock, exactly how the paper measures T_AR / T_SD on vLLM.

pub mod ablations;
pub mod adaptive;
pub mod budget;
pub mod continuous;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod multitenant;
pub mod ragged;
pub mod sharding;
pub mod table3;
pub mod tables;
pub mod vocab_scale;

use crate::arch::ModelArch;
use crate::batching::{Buckets, Request, SamplingParams};
use crate::engine::{Engine, EngineConfig};
use crate::hardware::{Platform, ShardingSpec, Topology};
use crate::kvcache::KvConfig;
use crate::scheduler::SchedulerConfig;
use crate::simulator::ExecSim;
use crate::spec::synthetic::SyntheticLm;
use crate::theory;

/// One measured operating point.
#[derive(Debug, Clone, Copy)]
pub struct PairStats {
    pub batch: usize,
    pub gamma: usize,
    /// Total decode time, autoregressive baseline.
    pub t_ar: f64,
    /// Total decode time, speculative.
    pub t_sd: f64,
    /// Measured σ (accepted fraction of γ+1).
    pub sigma: f64,
    /// End-to-end SD speedup T_AR / T_SD.
    pub speedup: f64,
    /// Target efficiency T_T(B,1)/T_T(B,γ+1) from the simulator.
    pub target_efficiency: f64,
}

/// Options for a measurement run.
#[derive(Debug, Clone)]
pub struct RunOpts {
    pub max_new_tokens: usize,
    pub prompt_len: usize,
    pub seed: u64,
    /// Sampled expert activation + per-run noise (Fig. 5 individual runs).
    pub noise: bool,
    /// GEMM tile quantization (Fig. 5 sawtooth).
    pub tile_effects: bool,
    /// Synthetic token-space size. The virtual clock is vocab-independent
    /// (the roofline prices the arch's real LM head throughout); this only
    /// sizes the coordinator-side token math, which the sparse
    /// `LogitsView` interface keeps O(1) per row — so realistic values up
    /// to Qwen2's 151 936 are now feasible (see `vocab_scale`).
    pub vocab: usize,
    /// Expert-parallel topology for the *target* model (the draft replica
    /// serves its own rank). `None` keeps the unsharded single-group
    /// pricing; `Some(topology)` prices the EP deployment via
    /// [`ShardingSpec::for_arch`] (see [`sharding`]).
    pub topology: Option<Topology>,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts {
            max_new_tokens: 32,
            prompt_len: 16,
            seed: 0,
            noise: false,
            tile_effects: false,
            vocab: 64,
            topology: None,
        }
    }
}

fn build_engine(
    target: &ModelArch,
    draft: &ModelArch,
    platform: &Platform,
    alpha: f64,
    gamma: usize,
    batch: usize,
    opts: &RunOpts,
) -> Engine<SyntheticLm> {
    let mut tsim = ExecSim::new(target.clone(), platform.clone());
    tsim = tsim.with_tile_effects(opts.tile_effects);
    // The draft runs on a single device of the platform (the paper notes
    // the small draft model stays single-GPU while the target shards).
    let draft_platform = Platform::new(platform.gpu.clone(), 1, platform.interconnect_bw);
    let mut dsim = ExecSim::new(draft.clone(), draft_platform);
    if let Some(topo) = &opts.topology {
        tsim = tsim.with_sharding(ShardingSpec::for_arch(topo.clone(), target));
        // One draft replica per EP rank: for a dense draft the EP walk
        // degenerates to data parallelism (per-rank B/d tokens, replicated
        // weights, zero fabric payload) — the same pricing the analytic
        // sharding sweep uses, so engine-measured and sweep numbers
        // reconcile.
        dsim = dsim.with_sharding(ShardingSpec::for_arch(topo.clone(), draft));
    }
    let mut backend = SyntheticLm::new(tsim, dsim, alpha, opts.seed).with_vocab(opts.vocab);
    if opts.noise {
        backend = backend.with_noise(opts.seed ^ 0xabcd);
    }
    let config = EngineConfig {
        gamma,
        kv: KvConfig {
            num_blocks: 1 << 16,
            block_size: 16,
        },
        scheduler: SchedulerConfig {
            max_batch: batch,
            admit_reserve_tokens: opts.max_new_tokens,
            tpot_slo: None,
        },
        buckets: Buckets::pow2_up_to(batch.max(1)),
        seed: opts.seed,
        control: None,
        ..Default::default()
    };
    Engine::new(config, backend)
}

fn run_one(
    target: &ModelArch,
    draft: &ModelArch,
    platform: &Platform,
    alpha: f64,
    gamma: usize,
    batch: usize,
    opts: &RunOpts,
) -> anyhow::Result<(f64, f64)> {
    let mut engine = build_engine(target, draft, platform, alpha, gamma, batch, opts);
    for id in 0..batch as u64 {
        engine.submit(Request {
            id,
            prompt: (0..opts.prompt_len as u32).collect(),
            params: SamplingParams {
                temperature: 0.0,
                max_new_tokens: opts.max_new_tokens,
                eos_token: None,
            },
            arrival: 0.0,
            class: 0,
        });
    }
    engine.run_to_completion(100_000)?;
    let sigma = engine.metrics.sigma(gamma.max(1));
    Ok((engine.metrics.decode_time(), sigma))
}

/// Measure SD vs AR at one operating point (the paper's basic unit).
pub fn run_pair(
    target: &ModelArch,
    draft: &ModelArch,
    platform: &Platform,
    alpha: f64,
    gamma: usize,
    batch: usize,
    opts: &RunOpts,
) -> anyhow::Result<PairStats> {
    assert!(gamma >= 1, "run_pair needs a speculative γ");
    let (t_sd, sigma) = run_one(target, draft, platform, alpha, gamma, batch, opts)?;
    let (t_ar, _) = run_one(target, draft, platform, alpha, 0, batch, opts)?;
    let mut sim = ExecSim::new(target.clone(), platform.clone());
    if let Some(topo) = &opts.topology {
        sim = sim.with_sharding(ShardingSpec::for_arch(topo.clone(), target));
    }
    let teff = sim.target_efficiency(batch, gamma, 512);
    Ok(PairStats {
        batch,
        gamma,
        t_ar,
        t_sd,
        sigma,
        speedup: t_ar / t_sd,
        target_efficiency: teff,
    })
}

/// Worker-thread count for parallel sweeps: `MOESD_THREADS` overrides
/// (set to 1 to force serial execution), otherwise the machine's
/// available parallelism.
pub fn sweep_threads() -> usize {
    if let Ok(v) = std::env::var("MOESD_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map_or(1, |n| n.get())
}

/// Map `f` over `items` on scoped worker threads, returning results in
/// item order.
///
/// Every figure/table sweep is hundreds of *independent* `run_pair`
/// engine runs (each builds its own seeded engine + simulators), so the
/// grid fans across cores with no shared state and the output is
/// bit-identical to the serial map. Work is striped round-robin
/// (worker t takes items t, t+T, t+2T, …) so the expensive large-batch
/// end of a grid spreads across workers instead of landing on one.
pub fn parallel_sweep<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let threads = sweep_threads().min(n.max(1));
    if threads <= 1 {
        return items.iter().map(f).collect();
    }
    let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    std::thread::scope(|scope| {
        let f = &f;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                scope.spawn(move || {
                    items
                        .iter()
                        .enumerate()
                        .skip(t)
                        .step_by(threads)
                        .map(|(i, item)| (i, f(item)))
                        .collect::<Vec<(usize, R)>>()
                })
            })
            .collect();
        for h in handles {
            for (i, r) in h.join().expect("sweep worker panicked") {
                slots[i] = Some(r);
            }
        }
    });
    slots
        .into_iter()
        .map(|r| r.expect("sweep slot unfilled"))
        .collect()
}

/// Fan one (target, draft, platform, α, γ) setting's batch sweep across
/// worker threads — the unit every figure/table sweep is built from.
/// Results keep `batches` order; the first error (if any) is returned.
#[allow(clippy::too_many_arguments)]
pub fn run_pair_grid(
    target: &ModelArch,
    draft: &ModelArch,
    platform: &Platform,
    alpha: f64,
    gamma: usize,
    batches: &[usize],
    opts: &RunOpts,
) -> anyhow::Result<Vec<PairStats>> {
    parallel_sweep(batches, |&b| {
        run_pair(target, draft, platform, alpha, gamma, b, opts)
    })
    .into_iter()
    .collect()
}

/// The batch-size sweep used across Figs. 2/4/5/6 and the peak-speedup
/// tables (mirrors the paper's 19-point grid).
pub fn paper_batch_grid() -> Vec<usize> {
    vec![1, 2, 4, 8, 12, 16, 20, 24, 28, 32, 36, 40, 44, 48, 52, 56, 60, 80, 100]
}

/// Find the peak speedup across a batch sweep (the paper's `x`).
pub fn peak_speedup(stats: &[PairStats]) -> &PairStats {
    stats
        .iter()
        .max_by(|a, b| a.speedup.partial_cmp(&b.speedup).unwrap())
        .expect("empty sweep")
}

/// σ-adjustment of Fig. 4: raw speedups at modified K are scaled by
/// σ_{K=8}/σ_K to remove the acceptance-rate confound (our synthetic
/// backend holds α constant across K, so the factor is ≈1; kept for
/// fidelity with the paper's method and exercised in tests).
pub fn sigma_adjust(raw_speedup: f64, sigma_k: f64, sigma_ref: f64) -> f64 {
    raw_speedup * sigma_ref / sigma_k
}

/// Eq. 5 σ for the calibrated α at this γ (the expectation the measured
/// σ should track).
pub fn expected_sigma(alpha: f64, gamma: usize) -> f64 {
    theory::sigma_from_alpha(alpha, gamma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::hardware::platform_2x_gpu_a;

    #[test]
    fn run_pair_produces_consistent_stats() {
        let target = presets::qwen2_57b_a14b();
        let draft = presets::qwen2_0_5b();
        let p = platform_2x_gpu_a();
        let opts = RunOpts {
            max_new_tokens: 16,
            ..Default::default()
        };
        let s = run_pair(&target, &draft, &p, 0.9, 3, 8, &opts).unwrap();
        assert!(s.t_ar > 0.0 && s.t_sd > 0.0);
        assert!((s.speedup - s.t_ar / s.t_sd).abs() < 1e-12);
        assert!(s.sigma > 0.5 && s.sigma <= 1.0);
        assert!(s.target_efficiency > 0.0 && s.target_efficiency <= 1.0);
    }

    #[test]
    fn moderate_batch_beats_batch_one() {
        // The headline claim, as measured end-to-end by the engine.
        let target = presets::qwen2_57b_a14b();
        let draft = presets::qwen2_0_5b();
        let p = platform_2x_gpu_a();
        let opts = RunOpts::default();
        let s1 = run_pair(&target, &draft, &p, 0.9, 4, 1, &opts).unwrap();
        let s32 = run_pair(&target, &draft, &p, 0.9, 4, 32, &opts).unwrap();
        assert!(
            s32.speedup > s1.speedup,
            "B=32 {} should beat B=1 {}",
            s32.speedup,
            s1.speedup
        );
        assert!(s32.speedup > 1.3, "moderate-batch SD should win: {}", s32.speedup);
    }

    #[test]
    fn sigma_tracks_eq5() {
        let target = presets::qwen2_57b_a14b();
        let draft = presets::qwen2_0_5b();
        let p = platform_2x_gpu_a();
        let opts = RunOpts {
            max_new_tokens: 48,
            ..Default::default()
        };
        let alpha = 0.8;
        let s = run_pair(&target, &draft, &p, alpha, 3, 16, &opts).unwrap();
        let want = expected_sigma(alpha, 3);
        assert!((s.sigma - want).abs() < 0.08, "σ {} vs Eq.5 {want}", s.sigma);
    }

    #[test]
    fn sigma_adjust_identity_when_equal() {
        assert_eq!(sigma_adjust(2.0, 0.9, 0.9), 2.0);
        assert!((sigma_adjust(2.0, 0.45, 0.9) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn parallel_sweep_preserves_order() {
        let items: Vec<usize> = (0..37).collect();
        let out = parallel_sweep(&items, |&x| x * x + 1);
        let want: Vec<usize> = items.iter().map(|&x| x * x + 1).collect();
        assert_eq!(out, want);
        // Degenerate inputs.
        assert_eq!(parallel_sweep(&[] as &[usize], |&x| x), Vec::<usize>::new());
        assert_eq!(parallel_sweep(&[9usize], |&x| x + 1), vec![10]);
    }

    #[test]
    fn parallel_grid_is_bit_identical_to_serial_runs() {
        // Each grid point builds its own seeded engine, so fanning across
        // threads must not change a single measurement.
        let target = presets::qwen2_57b_a14b();
        let draft = presets::qwen2_0_5b();
        let p = platform_2x_gpu_a();
        let opts = RunOpts {
            max_new_tokens: 12,
            ..Default::default()
        };
        let batches = [1usize, 8, 32];
        let grid = run_pair_grid(&target, &draft, &p, 0.9, 3, &batches, &opts).unwrap();
        for (i, &b) in batches.iter().enumerate() {
            let s = run_pair(&target, &draft, &p, 0.9, 3, b, &opts).unwrap();
            assert_eq!(grid[i].batch, b);
            assert_eq!(grid[i].t_ar, s.t_ar, "B={b}");
            assert_eq!(grid[i].t_sd, s.t_sd, "B={b}");
            assert_eq!(grid[i].sigma, s.sigma, "B={b}");
        }
    }

    #[test]
    fn batch_grid_matches_paper_table3() {
        let g = paper_batch_grid();
        assert_eq!(g.len(), 19);
        assert_eq!(g[0], 1);
        assert_eq!(*g.last().unwrap(), 100);
    }
}
