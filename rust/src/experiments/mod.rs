//! Experiment implementations — one module per paper table/figure.
//!
//! Each `figN`/`tableN` module exposes a `run(...)` returning structured
//! results; the matching `rust/benches/*` target prints the paper-style
//! rows, writes CSV/markdown under `results/`, and asserts the paper's
//! qualitative *shape* claims (who wins, where peaks fall). The CLI
//! (`moesd bench <id>`) calls the same code.
//!
//! Shared machinery here: [`run_pair`] measures one (platform, model,
//! α, γ, B) point by driving the *actual serving engine* twice — once
//! speculative, once autoregressive — on the synthetic backend's virtual
//! clock, exactly how the paper measures T_AR / T_SD on vLLM.

pub mod ablations;
pub mod adaptive;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod table3;
pub mod tables;

use crate::arch::ModelArch;
use crate::batching::{Buckets, Request, SamplingParams};
use crate::engine::{Engine, EngineConfig};
use crate::hardware::Platform;
use crate::kvcache::KvConfig;
use crate::scheduler::SchedulerConfig;
use crate::simulator::ExecSim;
use crate::spec::synthetic::SyntheticLm;
use crate::theory;

/// One measured operating point.
#[derive(Debug, Clone, Copy)]
pub struct PairStats {
    pub batch: usize,
    pub gamma: usize,
    /// Total decode time, autoregressive baseline.
    pub t_ar: f64,
    /// Total decode time, speculative.
    pub t_sd: f64,
    /// Measured σ (accepted fraction of γ+1).
    pub sigma: f64,
    /// End-to-end SD speedup T_AR / T_SD.
    pub speedup: f64,
    /// Target efficiency T_T(B,1)/T_T(B,γ+1) from the simulator.
    pub target_efficiency: f64,
}

/// Options for a measurement run.
#[derive(Debug, Clone)]
pub struct RunOpts {
    pub max_new_tokens: usize,
    pub prompt_len: usize,
    pub seed: u64,
    /// Sampled expert activation + per-run noise (Fig. 5 individual runs).
    pub noise: bool,
    /// GEMM tile quantization (Fig. 5 sawtooth).
    pub tile_effects: bool,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts {
            max_new_tokens: 32,
            prompt_len: 16,
            seed: 0,
            noise: false,
            tile_effects: false,
        }
    }
}

fn build_engine(
    target: &ModelArch,
    draft: &ModelArch,
    platform: &Platform,
    alpha: f64,
    gamma: usize,
    batch: usize,
    opts: &RunOpts,
) -> Engine<SyntheticLm> {
    let mut tsim = ExecSim::new(target.clone(), platform.clone());
    tsim = tsim.with_tile_effects(opts.tile_effects);
    // The draft runs on a single device of the platform (the paper notes
    // the small draft model stays single-GPU while the target shards).
    let draft_platform = Platform::new(platform.gpu.clone(), 1, platform.interconnect_bw);
    let dsim = ExecSim::new(draft.clone(), draft_platform);
    let mut backend = SyntheticLm::new(tsim, dsim, alpha, opts.seed);
    if opts.noise {
        backend = backend.with_noise(opts.seed ^ 0xabcd);
    }
    let config = EngineConfig {
        gamma,
        kv: KvConfig {
            num_blocks: 1 << 16,
            block_size: 16,
        },
        scheduler: SchedulerConfig {
            max_batch: batch,
            admit_reserve_tokens: opts.max_new_tokens,
            tpot_slo: None,
        },
        buckets: Buckets::pow2_up_to(batch.max(1)),
        seed: opts.seed,
        control: None,
    };
    Engine::new(config, backend)
}

fn run_one(
    target: &ModelArch,
    draft: &ModelArch,
    platform: &Platform,
    alpha: f64,
    gamma: usize,
    batch: usize,
    opts: &RunOpts,
) -> anyhow::Result<(f64, f64)> {
    let mut engine = build_engine(target, draft, platform, alpha, gamma, batch, opts);
    for id in 0..batch as u64 {
        engine.submit(Request {
            id,
            prompt: (0..opts.prompt_len as u32).collect(),
            params: SamplingParams {
                temperature: 0.0,
                max_new_tokens: opts.max_new_tokens,
                eos_token: None,
            },
            arrival: 0.0,
        });
    }
    engine.run_to_completion(100_000)?;
    let sigma = engine.metrics.sigma(gamma.max(1));
    Ok((engine.metrics.decode_time(), sigma))
}

/// Measure SD vs AR at one operating point (the paper's basic unit).
pub fn run_pair(
    target: &ModelArch,
    draft: &ModelArch,
    platform: &Platform,
    alpha: f64,
    gamma: usize,
    batch: usize,
    opts: &RunOpts,
) -> anyhow::Result<PairStats> {
    assert!(gamma >= 1, "run_pair needs a speculative γ");
    let (t_sd, sigma) = run_one(target, draft, platform, alpha, gamma, batch, opts)?;
    let (t_ar, _) = run_one(target, draft, platform, alpha, 0, batch, opts)?;
    let sim = ExecSim::new(target.clone(), platform.clone());
    let teff = sim.target_efficiency(batch, gamma, 512);
    Ok(PairStats {
        batch,
        gamma,
        t_ar,
        t_sd,
        sigma,
        speedup: t_ar / t_sd,
        target_efficiency: teff,
    })
}

/// The batch-size sweep used across Figs. 2/4/5/6 and the peak-speedup
/// tables (mirrors the paper's 19-point grid).
pub fn paper_batch_grid() -> Vec<usize> {
    vec![1, 2, 4, 8, 12, 16, 20, 24, 28, 32, 36, 40, 44, 48, 52, 56, 60, 80, 100]
}

/// Find the peak speedup across a batch sweep (the paper's `x`).
pub fn peak_speedup(stats: &[PairStats]) -> &PairStats {
    stats
        .iter()
        .max_by(|a, b| a.speedup.partial_cmp(&b.speedup).unwrap())
        .expect("empty sweep")
}

/// σ-adjustment of Fig. 4: raw speedups at modified K are scaled by
/// σ_{K=8}/σ_K to remove the acceptance-rate confound (our synthetic
/// backend holds α constant across K, so the factor is ≈1; kept for
/// fidelity with the paper's method and exercised in tests).
pub fn sigma_adjust(raw_speedup: f64, sigma_k: f64, sigma_ref: f64) -> f64 {
    raw_speedup * sigma_ref / sigma_k
}

/// Eq. 5 σ for the calibrated α at this γ (the expectation the measured
/// σ should track).
pub fn expected_sigma(alpha: f64, gamma: usize) -> f64 {
    theory::sigma_from_alpha(alpha, gamma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::hardware::platform_2x_gpu_a;

    #[test]
    fn run_pair_produces_consistent_stats() {
        let target = presets::qwen2_57b_a14b();
        let draft = presets::qwen2_0_5b();
        let p = platform_2x_gpu_a();
        let opts = RunOpts {
            max_new_tokens: 16,
            ..Default::default()
        };
        let s = run_pair(&target, &draft, &p, 0.9, 3, 8, &opts).unwrap();
        assert!(s.t_ar > 0.0 && s.t_sd > 0.0);
        assert!((s.speedup - s.t_ar / s.t_sd).abs() < 1e-12);
        assert!(s.sigma > 0.5 && s.sigma <= 1.0);
        assert!(s.target_efficiency > 0.0 && s.target_efficiency <= 1.0);
    }

    #[test]
    fn moderate_batch_beats_batch_one() {
        // The headline claim, as measured end-to-end by the engine.
        let target = presets::qwen2_57b_a14b();
        let draft = presets::qwen2_0_5b();
        let p = platform_2x_gpu_a();
        let opts = RunOpts::default();
        let s1 = run_pair(&target, &draft, &p, 0.9, 4, 1, &opts).unwrap();
        let s32 = run_pair(&target, &draft, &p, 0.9, 4, 32, &opts).unwrap();
        assert!(
            s32.speedup > s1.speedup,
            "B=32 {} should beat B=1 {}",
            s32.speedup,
            s1.speedup
        );
        assert!(s32.speedup > 1.3, "moderate-batch SD should win: {}", s32.speedup);
    }

    #[test]
    fn sigma_tracks_eq5() {
        let target = presets::qwen2_57b_a14b();
        let draft = presets::qwen2_0_5b();
        let p = platform_2x_gpu_a();
        let opts = RunOpts {
            max_new_tokens: 48,
            ..Default::default()
        };
        let alpha = 0.8;
        let s = run_pair(&target, &draft, &p, alpha, 3, 16, &opts).unwrap();
        let want = expected_sigma(alpha, 3);
        assert!((s.sigma - want).abs() < 0.08, "σ {} vs Eq.5 {want}", s.sigma);
    }

    #[test]
    fn sigma_adjust_identity_when_equal() {
        assert_eq!(sigma_adjust(2.0, 0.9, 0.9), 2.0);
        assert!((sigma_adjust(2.0, 0.45, 0.9) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn batch_grid_matches_paper_table3() {
        let g = paper_batch_grid();
        assert_eq!(g.len(), 19);
        assert_eq!(g[0], 1);
        assert_eq!(*g.last().unwrap(), 100);
    }
}
