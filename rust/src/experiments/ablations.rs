//! Ablations over the design choices §3.4 calls out beyond the main
//! evaluation:
//!
//! 1. **Expert parallelism (EP)** — experts sharded across more GPUs: the
//!    paper argues the analyses remain valid and that "under extensive EP
//!    configurations, the inefficiency of SD for MoE at a small batch size
//!    may vanish" (more aggregate bandwidth).
//! 2. **Routing imbalance** — Eq. 8 assumes balanced routing; a skewed
//!    router activates fewer experts, shifting the memory-traffic
//!    structure (the paper notes imbalance breaks the derivation).
//! 3. **KV-dominant regime (MagicDec)** — the paper's limitation section:
//!    when context length makes KV traffic dominate weights, SD stays
//!    effective even at large batch (KV reads are γ-independent).

use crate::arch::presets;
use crate::hardware::{gpu_a, Platform};
use crate::simulator::routing::Router;
use crate::simulator::ExecSim;
use crate::theory;
use crate::util::csv::CsvTable;
use crate::util::rng::Rng;

/// Ablation 1: SD speedup proxy (target efficiency) at small batch as the
/// EP degree grows. Returns (n_gpus, teff at B=1, teff at B=32). The
/// per-EP-degree evaluations are independent and fan across workers.
pub fn ep_scaling(gammas_gpus: &[usize], gamma: usize) -> Vec<(usize, f64, f64)> {
    super::parallel_sweep(gammas_gpus, |&n| {
        let platform = Platform::new(gpu_a(), n, 300e9);
        let sim = ExecSim::new(presets::qwen2_57b_a14b(), platform);
        (
            n,
            sim.target_efficiency(1, gamma, 512),
            sim.target_efficiency(32, gamma, 512),
        )
    })
}

/// Ablation 2: empirical activation under Dirichlet-skewed routers vs the
/// balanced Eq. 8 curve. Returns rows (alpha, t, N_balanced, N_skewed).
pub fn imbalance_activation(alphas: &[f64], ts: &[u64], seed: u64) -> CsvTable {
    let (e, k) = (64usize, 8usize);
    let mut rng = Rng::seeded(seed);
    let mut table = CsvTable::new(&["dirichlet_alpha", "tokens", "n_balanced", "n_skewed"]);
    for &a in alphas {
        let skewed = Router::imbalanced(e, k, a, &mut rng);
        for &t in ts {
            let balanced = theory::expected_active_experts(e, k, t);
            let emp = skewed.empirical_activation(t, 200, &mut rng);
            table.push_nums(&[a, t as f64, balanced, emp]);
        }
    }
    table
}

/// Ablation 3: target efficiency vs context length at a large batch — the
/// MagicDec handoff. Returns (ctx, teff), one independent point per
/// worker (each builds its own simulator; the pricing cache is
/// per-instance).
pub fn kv_dominant_regime(ctxs: &[usize], batch: usize, gamma: usize) -> Vec<(usize, f64)> {
    super::parallel_sweep(ctxs, |&ctx| {
        let platform = crate::hardware::platform_2x_gpu_a();
        let sim = ExecSim::new(presets::qwen2_57b_a14b(), platform);
        (ctx, sim.target_efficiency(batch, gamma, ctx))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ep_lifts_small_batch_efficiency() {
        // §3.4: extensive EP adds memory bandwidth → the small-batch SD
        // penalty shrinks (B=1 target efficiency rises with GPU count).
        let rows = ep_scaling(&[2, 4, 8, 16], 4);
        for w in rows.windows(2) {
            assert!(
                w[1].1 >= w[0].1 - 1e-9,
                "B=1 teff should not drop with EP: {rows:?}"
            );
        }
        let first = rows.first().unwrap().1;
        let last = rows.last().unwrap().1;
        assert!(
            last > first + 0.02,
            "16-way EP should visibly lift B=1 efficiency: {first} → {last}"
        );
    }

    #[test]
    fn imbalance_reduces_activation() {
        let t = imbalance_activation(&[0.05, 10.0], &[32], 3);
        let skew = t.column_f64("n_skewed").unwrap();
        let bal = t.column_f64("n_balanced").unwrap();
        // Heavy skew (alpha=0.05) activates clearly fewer experts than the
        // balanced expectation; near-uniform (alpha=10) is close to it.
        assert!(skew[0] < bal[0] - 4.0, "skewed {} vs balanced {}", skew[0], bal[0]);
        assert!((skew[1] - bal[1]).abs() < 6.0, "mild skew should be close");
    }

    #[test]
    fn long_context_rescues_large_batch_sd() {
        // MagicDec regime: at B=256 the short-context system is
        // compute-bound (low teff), but growing KV traffic is
        // γ-independent, pushing teff back up.
        let rows = kv_dominant_regime(&[512, 4096, 16384, 65536], 256, 4);
        let short = rows[0].1;
        let long = rows.last().unwrap().1;
        assert!(
            long > short + 0.1,
            "long context should lift teff at B=256: {rows:?}"
        );
        for w in rows.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-9, "teff should grow with ctx: {rows:?}");
        }
    }
}
