//! Tables 1 & 2 — peak SD speedup (x) across datasets, temperatures, γ,
//! models (Table 1: Qwen2 + Mixtral on 2×GPU-A) and hardware platforms
//! (Table 2: Qwen2 on 2×GPU-B / 4×GPU-A / 4×GPU-C). Each cell reports
//! T_AR, T_SD, σ and x at the batch size maximizing x — the paper's exact
//! reporting format.

use super::{paper_batch_grid, peak_speedup, run_pair_grid, PairStats, RunOpts};
use crate::arch::presets;
use crate::hardware::platform_by_name;
use crate::util::csv::CsvTable;
use crate::util::table::{f2, MdTable};
use crate::workload::{calibrated_alpha, Dataset};

/// One table row (one dataset × temperature, three γ columns).
#[derive(Debug, Clone)]
pub struct TableRow {
    pub device: String,
    pub model: String,
    pub dataset: Dataset,
    pub temp: f64,
    /// Indexed by γ−2 (γ ∈ {2, 3, 4}).
    pub cells: Vec<PairStats>,
}

pub const GAMMAS: [usize; 3] = [2, 3, 4];

fn archs_for(model: &str) -> (crate::arch::ModelArch, crate::arch::ModelArch) {
    match model {
        "qwen2" => (presets::qwen2_57b_a14b(), presets::qwen2_0_5b()),
        "mixtral" => (presets::mixtral_8x7b(), presets::eagle_head_mixtral()),
        other => panic!("unknown model {other}"),
    }
}

/// Compute one row: for each γ, sweep batches and keep the peak-x point.
pub fn compute_row(
    device: &str,
    model: &str,
    dataset: Dataset,
    temp: f64,
    seed: u64,
) -> anyhow::Result<TableRow> {
    let (target, draft) = archs_for(model);
    let platform = platform_by_name(device)?;
    let opts = RunOpts {
        seed,
        // Long enough that final-round truncation doesn't bias σ down
        // (the paper decodes long windows; see EngineMetrics::sigma).
        max_new_tokens: 64,
        ..Default::default()
    };
    let mut cells = Vec::new();
    for &gamma in &GAMMAS {
        let alpha = calibrated_alpha(model, dataset, temp, gamma);
        let sweep = run_pair_grid(
            &target,
            &draft,
            &platform,
            alpha,
            gamma,
            &paper_batch_grid(),
            &opts,
        )?;
        cells.push(*peak_speedup(&sweep));
    }
    Ok(TableRow {
        device: device.into(),
        model: model.into(),
        dataset,
        temp,
        cells,
    })
}

/// Table 1: Qwen2 + Mixtral on 2×GPU-A.
pub fn table1(seed: u64) -> anyhow::Result<Vec<TableRow>> {
    let mut rows = Vec::new();
    for model in ["qwen2", "mixtral"] {
        for dataset in [Dataset::HumanEval, Dataset::MtBench] {
            for temp in [0.0, 1.0] {
                rows.push(compute_row("2xGPU-A", model, dataset, temp, seed)?);
            }
        }
    }
    Ok(rows)
}

/// Table 2: Qwen2 across the other platforms.
pub fn table2(seed: u64) -> anyhow::Result<Vec<TableRow>> {
    let mut rows = Vec::new();
    for device in ["2xGPU-B", "4xGPU-A", "4xGPU-C"] {
        for dataset in [Dataset::HumanEval, Dataset::MtBench] {
            for temp in [0.0, 1.0] {
                rows.push(compute_row(device, "qwen2", dataset, temp, seed)?);
            }
        }
    }
    Ok(rows)
}

/// Render rows in the paper's layout.
pub fn render_markdown(rows: &[TableRow]) -> String {
    let mut t = MdTable::new(&[
        "device", "model", "dataset", "temp", "γ=2 T_AR", "T_SD", "σ", "x", "γ=3 T_AR", "T_SD",
        "σ", "x", "γ=4 T_AR", "T_SD", "σ", "x",
    ]);
    for r in rows {
        let mut cells = vec![
            r.device.clone(),
            r.model.clone(),
            r.dataset.name().to_string(),
            format!("{:.1}", r.temp),
        ];
        for c in &r.cells {
            cells.push(f2(c.t_ar));
            cells.push(f2(c.t_sd));
            cells.push(f2(c.sigma));
            cells.push(f2(c.speedup));
        }
        t.push(cells);
    }
    t.render()
}

pub fn to_csv(rows: &[TableRow]) -> CsvTable {
    let mut t = CsvTable::new(&[
        "device", "model", "dataset", "temp", "gamma", "peak_batch", "t_ar", "t_sd", "sigma",
        "x",
    ]);
    for r in rows {
        for (gi, c) in r.cells.iter().enumerate() {
            t.push_row(vec![
                r.device.clone(),
                r.model.clone(),
                r.dataset.name().into(),
                format!("{}", r.temp),
                format!("{}", GAMMAS[gi]),
                format!("{}", c.batch),
                format!("{:.4}", c.t_ar),
                format!("{:.4}", c.t_sd),
                format!("{:.4}", c.sigma),
                format!("{:.4}", c.speedup),
            ]);
        }
    }
    t
}

/// Shape claims shared by the two table benches.
pub fn check_table1(rows: &[TableRow]) -> Result<(), String> {
    let find = |model: &str, ds: Dataset, temp: f64| -> &TableRow {
        rows.iter()
            .find(|r| r.model == model && r.dataset == ds && r.temp == temp)
            .expect("row missing")
    };
    // 1. Every peak beats 1.0 (SD wins somewhere for every config).
    for r in rows {
        for c in &r.cells {
            if c.speedup <= 1.0 {
                return Err(format!(
                    "{} {} T={} γ={}: no speedup ({})",
                    r.model,
                    r.dataset.name(),
                    r.temp,
                    c.gamma,
                    c.speedup
                ));
            }
        }
    }
    // 2. Code at temp 0 (most predictable) beats chat at temp 1 for the
    //    same model and γ=4 (paper: 2.18 vs 1.20 for Qwen2).
    let code = find("qwen2", Dataset::HumanEval, 0.0).cells[2].speedup;
    let chat = find("qwen2", Dataset::MtBench, 1.0).cells[2].speedup;
    if code <= chat {
        return Err(format!("humaneval T0 ({code}) should beat mtbench T1 ({chat})"));
    }
    // 3. Qwen2 humaneval-T0 speedup grows with γ (1.63 → 1.96 → 2.18).
    let r = find("qwen2", Dataset::HumanEval, 0.0);
    if !(r.cells[0].speedup < r.cells[1].speedup && r.cells[1].speedup < r.cells[2].speedup) {
        return Err(format!(
            "γ ordering broken: {:?}",
            r.cells.iter().map(|c| c.speedup).collect::<Vec<_>>()
        ));
    }
    // 4. Peaks occur at moderate batch sizes.
    for r in rows {
        for c in &r.cells {
            if c.batch < 4 || c.batch > 80 {
                return Err(format!("peak at extreme batch {}", c.batch));
            }
        }
    }
    Ok(())
}

/// Table 2's observation (1): GPU-B (higher ridge point) peaks above
/// 2×GPU-A for the matching config.
pub fn check_table2(table1_rows: &[TableRow], table2_rows: &[TableRow]) -> Result<(), String> {
    let t1 = table1_rows
        .iter()
        .find(|r| r.model == "qwen2" && r.dataset == Dataset::HumanEval && r.temp == 0.0)
        .expect("table1 row");
    let t2 = table2_rows
        .iter()
        .find(|r| {
            r.device == "2xGPU-B" && r.dataset == Dataset::HumanEval && r.temp == 0.0
        })
        .expect("table2 row");
    // The paper's own margin is small (2.29 vs 2.18, ~5%); our measured
    // peaks carry sampling noise of the same order, so allow a 3% band on
    // the measured comparison…
    let a = t1.cells[2].speedup; // γ=4
    let b = t2.cells[2].speedup;
    if b <= 0.97 * a {
        return Err(format!(
            "higher-RP GPU-B ({b}) should beat GPU-A ({a}) at γ=4"
        ));
    }
    // …and additionally assert the *deterministic* mechanism behind the
    // observation: GPU-B's higher ridge point keeps target efficiency
    // above GPU-A's at and beyond the peak region.
    use crate::arch::presets as ps;
    use crate::simulator::ExecSim;
    let sim_a = ExecSim::new(ps::qwen2_57b_a14b(), crate::hardware::platform_2x_gpu_a());
    let sim_b = ExecSim::new(ps::qwen2_57b_a14b(), crate::hardware::platform_2x_gpu_b());
    for batch in [32usize, 64, 100] {
        let ea = sim_a.target_efficiency(batch, 4, 512);
        let eb = sim_b.target_efficiency(batch, 4, 512);
        if eb <= ea {
            return Err(format!(
                "GPU-B target efficiency should exceed GPU-A at B={batch}: {eb} vs {ea}"
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_row_computes_with_paper_like_magnitudes() {
        let r = compute_row("2xGPU-A", "qwen2", Dataset::HumanEval, 0.0, 1).unwrap();
        assert_eq!(r.cells.len(), 3);
        // γ=4 peak in the paper is 2.18x on this platform; accept a band.
        // Our idealized simulator overshoots vLLM's absolute peak by
        // ~30-45% (no framework stalls); the band reflects that and is
        // discussed in EXPERIMENTS.md.
        let x = r.cells[2].speedup;
        assert!(x > 1.6 && x < 3.6, "γ=4 peak {x}");
        // σ close to the calibration target 0.91.
        assert!((r.cells[2].sigma - 0.91).abs() < 0.08, "σ {}", r.cells[2].sigma);
    }

    #[test]
    fn markdown_and_csv_render() {
        let r = compute_row("2xGPU-A", "mixtral", Dataset::MtBench, 1.0, 2).unwrap();
        let md = render_markdown(&[r.clone()]);
        assert!(md.contains("mixtral"));
        let csv = to_csv(&[r]);
        assert_eq!(csv.rows.len(), 3);
    }
}
