//! Fig. 2 — SD speedup and target efficiency vs batch size, across
//! platform/model panels. The paper's four panels are (Qwen2, 2×GPU-A),
//! (Qwen2, 2×GPU-B), (Qwen2, 4×GPU-A) and (Mixtral, 2×GPU-A)-style
//! combinations; we regenerate a configurable panel set.

use super::{paper_batch_grid, run_pair_grid, PairStats, RunOpts};
use crate::arch::presets;
use crate::hardware::platform_by_name;
use crate::util::csv::CsvTable;
use crate::workload::{calibrated_alpha, Dataset};

/// One panel description.
#[derive(Debug, Clone)]
pub struct Panel {
    pub model: &'static str,
    pub platform: &'static str,
    pub dataset: Dataset,
    pub temp: f64,
    pub gamma: usize,
}

/// The default panel set (mirrors the paper's Fig. 2 coverage).
pub fn default_panels() -> Vec<Panel> {
    vec![
        Panel {
            model: "qwen2",
            platform: "2xGPU-A",
            dataset: Dataset::HumanEval,
            temp: 0.0,
            gamma: 4,
        },
        Panel {
            model: "qwen2",
            platform: "2xGPU-B",
            dataset: Dataset::HumanEval,
            temp: 0.0,
            gamma: 4,
        },
        Panel {
            model: "qwen2",
            platform: "4xGPU-A",
            dataset: Dataset::MtBench,
            temp: 0.0,
            gamma: 3,
        },
        Panel {
            model: "mixtral",
            platform: "2xGPU-A",
            dataset: Dataset::HumanEval,
            temp: 0.0,
            gamma: 3,
        },
    ]
}

fn archs_for(model: &str) -> (crate::arch::ModelArch, crate::arch::ModelArch) {
    match model {
        "qwen2" => (presets::qwen2_57b_a14b(), presets::qwen2_0_5b()),
        "mixtral" => (presets::mixtral_8x7b(), presets::eagle_head_mixtral()),
        "opt" => (presets::opt_30b(), presets::opt_350m()),
        other => panic!("unknown model family {other}"),
    }
}

/// Sweep one panel across the paper's batch grid (fanned across worker
/// threads; per-point results are bit-identical to a serial sweep).
pub fn sweep_panel(panel: &Panel, seed: u64) -> anyhow::Result<Vec<PairStats>> {
    let (target, draft) = archs_for(panel.model);
    let platform = platform_by_name(panel.platform)?;
    let alpha = calibrated_alpha(panel.model, panel.dataset, panel.temp, panel.gamma);
    let opts = RunOpts {
        seed,
        ..Default::default()
    };
    run_pair_grid(
        &target,
        &draft,
        &platform,
        alpha,
        panel.gamma,
        &paper_batch_grid(),
        &opts,
    )
}

/// CSV rows for one panel: batch, speedup, target_efficiency, sigma.
pub fn panel_csv(panel: &Panel, stats: &[PairStats]) -> CsvTable {
    let mut t = CsvTable::new(&[
        "model",
        "platform",
        "dataset",
        "temp",
        "gamma",
        "batch",
        "speedup",
        "target_efficiency",
        "sigma",
    ]);
    for s in stats {
        t.push_row(vec![
            panel.model.into(),
            panel.platform.into(),
            panel.dataset.name().into(),
            format!("{}", panel.temp),
            format!("{}", panel.gamma),
            format!("{}", s.batch),
            format!("{:.4}", s.speedup),
            format!("{:.4}", s.target_efficiency),
            format!("{:.4}", s.sigma),
        ]);
    }
    t
}

/// Shape checks (used by the bench gate and integration tests):
/// 1. speedup first increases then decreases (peak strictly interior),
/// 2. target efficiency trends with speedup (positive correlation).
pub fn check_shape(stats: &[PairStats]) -> Result<(), String> {
    let speedups: Vec<f64> = stats.iter().map(|s| s.speedup).collect();
    let teff: Vec<f64> = stats.iter().map(|s| s.target_efficiency).collect();
    let peak = crate::util::stats::argmax(&speedups);
    if peak == 0 || peak == speedups.len() - 1 {
        return Err(format!(
            "speedup peak not interior (idx {peak}): {speedups:?}"
        ));
    }
    if speedups[peak] <= speedups[0] || speedups[peak] <= *speedups.last().unwrap() {
        return Err("no clear rise-then-fall".into());
    }
    let corr = crate::util::stats::pearson(&teff, &speedups);
    if corr < 0.5 {
        return Err(format!("target efficiency decorrelated from speedup: r={corr}"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qwen2_panel_has_paper_shape() {
        let panel = &default_panels()[0];
        let stats = sweep_panel(panel, 3).unwrap();
        check_shape(&stats).unwrap();
        // Peak magnitude in the paper's ballpark (x ≈ 1.5–2.5 for γ=4,
        // humaneval, temp 0 — Table 1 reports 2.18 on 2×GPU-A).
        let peak = super::super::peak_speedup(&stats);
        assert!(
            peak.speedup > 1.4 && peak.speedup < 3.2,
            "peak {} out of band",
            peak.speedup
        );
        // Peak is at a *moderate* batch (not 1, not 100).
        assert!(peak.batch >= 8 && peak.batch <= 80, "peak at B={}", peak.batch);
    }

    #[test]
    fn csv_rendering() {
        let panel = Panel {
            model: "qwen2",
            platform: "2xGPU-A",
            dataset: Dataset::HumanEval,
            temp: 0.0,
            gamma: 2,
        };
        let stats = vec![PairStats {
            batch: 8,
            gamma: 2,
            t_ar: 2.0,
            t_sd: 1.0,
            sigma: 0.9,
            speedup: 2.0,
            target_efficiency: 0.9,
        }];
        let csv = panel_csv(&panel, &stats);
        assert_eq!(csv.rows.len(), 1);
        assert_eq!(csv.column_f64("speedup").unwrap()[0], 2.0);
    }
}
