//! Table 3 (+ Figs. 8–28) — modeling robustness vs measurement count m.
//!
//! The paper fits the Alg. 1 model on stride-subsampled measurement sets
//! (`df[begin:end:stride]`, m = ceil(228/stride)) and reports the MSE for
//! m from 10 to 228, observing that biased selections (m = 12, 13: batch
//! coverage gaps) fit worse than smaller-but-uniform ones (m = 11).

use super::fig4;
use crate::perfmodel::Measurement;
use crate::util::csv::CsvTable;

/// The paper's stride list (Table 3 rows).
pub const STRIDES: [usize; 21] = [
    25, 22, 20, 18, 17, 16, 15, 14, 13, 12, 11, 10, 9, 8, 7, 6, 5, 4, 3, 2, 1,
];

#[derive(Debug, Clone)]
pub struct MseRow {
    pub m: usize,
    pub stride: usize,
    /// MSE of the fit evaluated on the FULL 228-point grid.
    pub mse: f64,
    /// Distinct batch sizes covered by the selection.
    pub batch_coverage: Vec<usize>,
}

pub struct Table3Output {
    pub rows: Vec<MseRow>,
}

pub fn run(alpha: f64, seed: u64) -> anyhow::Result<Table3Output> {
    let grid = fig4::measure_grid(alpha, seed)?;
    Ok(run_on_grid(&grid, seed))
}

/// Separate entry so tests can reuse a precomputed grid.
pub fn run_on_grid(grid: &[Measurement], seed: u64) -> Table3Output {
    let mut rows = Vec::new();
    for &stride in &STRIDES {
        let fit_set = fig4::stride_sample(grid, stride);
        if fit_set.len() < crate::perfmodel::N_PARAMS {
            continue;
        }
        let (_, _, full_mse) = fig4::fit_and_eval(grid, &fit_set, seed);
        let mut coverage: Vec<usize> = fit_set.iter().map(|m| m.batch).collect();
        coverage.sort_unstable();
        coverage.dedup();
        rows.push(MseRow {
            m: fit_set.len(),
            stride,
            mse: full_mse,
            batch_coverage: coverage,
        });
    }
    Table3Output { rows }
}

pub fn to_csv(out: &Table3Output) -> CsvTable {
    let mut t = CsvTable::new(&["m", "stride", "mse", "batch_sizes_covered"]);
    for r in &out.rows {
        t.push_row(vec![
            format!("{}", r.m),
            format!("{}", r.stride),
            format!("{:.4}", r.mse),
            format!(
                "{}",
                r.batch_coverage
                    .iter()
                    .map(|b| b.to_string())
                    .collect::<Vec<_>>()
                    .join(" ")
            ),
        ]);
    }
    t
}

/// Table 3's qualitative claims:
/// - with uniform coverage and m ≥ ~15, the fit is stable (MSE within a
///   small factor of the best),
/// - the large-m fits are at least as good as the tiny-m ones.
pub fn check_shape(out: &Table3Output) -> Result<(), String> {
    let best = out
        .rows
        .iter()
        .map(|r| r.mse)
        .fold(f64::INFINITY, f64::min);
    let m228 = out
        .rows
        .iter()
        .find(|r| r.stride == 1)
        .ok_or("missing m=228 row")?;
    if m228.mse > 4.0 * best + 1e-6 {
        return Err(format!("full-grid fit unstable: {} vs best {best}", m228.mse));
    }
    // The paper's own Table 3 has ~40% MSE spread across uniform m ≥ 14
    // selections, with m = 10/12/13 notably worse. We require the m ≥ 21
    // fits (the paper's chosen operating point and denser) to stay within
    // an absolute band — 10-parameter LM from random starts occasionally
    // lands in a mild local minimum at very small m, as scipy TRR does.
    let stable: Vec<&MseRow> = out.rows.iter().filter(|r| r.m >= 21).collect();
    for r in &stable {
        if r.mse > (20.0 * best).max(5e-2) {
            return Err(format!("m={} fit degraded: {} vs best {best}", r.m, r.mse));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::{Measurement, PerfModel, PerfParams};

    /// Synthetic grid with known ground truth keeps this unit test fast;
    /// the measured-grid version runs in the table3 bench.
    #[test]
    fn stride_sweep_on_synthetic_grid() {
        let model = PerfModel::with_ridge_point(150.0);
        let truth = PerfParams {
            bias: 0.02,
            k1: 3e-5,
            k2: 2.5e-4,
            k3: 2e-4,
            draft_bias: 0.0015,
            draft_k: 1e-5,
            reject_bias: 2e-4,
            reject_k: 1e-7,
            lambda: 0.55,
            s: 1.03,
        };
        let mut grid = Vec::new();
        for &k in &fig4::K_VALUES {
            for &gamma in &fig4::GAMMAS {
                for &b in &super::super::paper_batch_grid() {
                    let mut m = Measurement {
                        batch: b,
                        gamma,
                        k,
                        e: 64,
                        sigma: 0.88,
                        speedup: 0.0,
                    };
                    m.speedup = model.compute_speedup(&truth, &m);
                    grid.push(m);
                }
            }
        }
        assert_eq!(grid.len(), 228);
        let out = run_on_grid(&grid, 3);
        assert!(out.rows.len() >= 20);
        check_shape(&out).unwrap();
        // With noise-free synthetic data the large-m fit is near-perfect.
        let m228 = out.rows.iter().find(|r| r.stride == 1).unwrap();
        assert!(m228.mse < 5e-3, "mse={}", m228.mse);
    }

    #[test]
    fn coverage_gaps_reported() {
        let grid: Vec<Measurement> = (0..228)
            .map(|i| Measurement {
                batch: super::super::paper_batch_grid()[i % 19],
                gamma: 2,
                k: 8,
                e: 64,
                sigma: 0.9,
                speedup: 1.5,
            })
            .collect();
        let sel = fig4::stride_sample(&grid, 20); // m=12
        let mut cov: Vec<usize> = sel.iter().map(|m| m.batch).collect();
        cov.sort_unstable();
        cov.dedup();
        assert!(cov.len() < 19, "stride selection should lose coverage");
    }
}
