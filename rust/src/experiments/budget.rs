//! Expert-budgeted verification sweep — the (γ, budget) speedup surface
//! (not from the paper's evaluation; it extends Eq. 4 along the verify
//! expert-budget axis the ROADMAP's MoE-Spec direction asks for).
//!
//! The paper prices verification with the full routed gate: all N(t)
//! activated experts load their weights (Eq. 8), which is exactly what
//! makes verify cheap *per token* but still weight-bound at small
//! batch. Capping the gate at a **budget** of experts
//! (`min(N(t), budget)`, [`crate::theory::budgeted_active_experts`])
//! trades that weight traffic against draft acceptance: tokens whose
//! top-K routing falls outside the cap verify against a degraded
//! distribution, modeled by the calibratable coverage curve
//! `α_eff = α · coverage^sensitivity`
//! ([`crate::theory::budgeted_alpha`],
//! [`crate::spec::synthetic::SyntheticLm::with_budget_alpha_curve`]).
//!
//! ## Methodology: saturated uniform-α slots, fixed round window
//!
//! Each sweep point (α × K × B × EP topology) runs steady-state serving
//! through the real engine: B slots, every completion immediately
//! replaced, measured over a fixed window of decode rounds (same
//! methodology as `experiments::ragged`). Arms:
//!
//! - `off-gN` — unbudgeted uniform γ over a grid (γ = 0 is the AR
//!   baseline the speedup column divides by);
//! - `budN-gM` — a static verify budget N with uniform γ M, priced
//!   through the budgeted roofline walk with acceptance degraded by the
//!   coverage curve at [`SENSITIVITY`].
//!
//! `check_shape` pins two claims:
//!
//! 1. **Off-switch bit-identity** (every point, including EP-sharded):
//!    the `budget = E` arms commit the same tokens in the same virtual
//!    clock as the unbudgeted arms at equal γ, bit-for-bit — `min`
//!    against a cap ≥ E is a no-op and coverage ≥ 1 short-circuits
//!    before any float op touches α.
//! 2. **A sub-coverage budget wins where verify is weight-bound**
//!    (validated against `python/replica_budget.py`, expected-value
//!    ratios 1.13–1.20 across the default grid at sensitivity 0.25):
//!    at the pinned memory-bound point the best budgeted arm beats the
//!    best unbudgeted arm by ≥ 2%, and never loses more than 2%
//!    anywhere on the unsharded grid.

use super::parallel_sweep;
use crate::arch::presets;
use crate::batching::{Buckets, Request, SamplingParams};
use crate::engine::{Engine, EngineConfig};
use crate::experiments::sharding::Fabric;
use crate::hardware::{platform_2x_gpu_a, Platform, ShardingSpec};
use crate::kvcache::{KvConfig, SeqId};
use crate::scheduler::SchedulerConfig;
use crate::simulator::ExecSim;
use crate::spec::synthetic::SyntheticLm;
use crate::spec::SdBackend;
use crate::util::csv::CsvTable;
use crate::util::json::Json;

/// Tokens generated per request.
pub const MAX_NEW_TOKENS: usize = 48;

/// Prompt length (uniform; the comparison is about decode).
pub const PROMPT_LEN: usize = 16;

/// Decode rounds measured per arm (steady-state window).
pub const WINDOW_ROUNDS: usize = 100;

/// Acceptance-vs-budget curve exponent the sweep runs at. MoE routing
/// is skewed — a few popular experts absorb most tokens — so capping
/// the gate loses acceptance sublinearly in coverage; 0.25 is the mild
/// MoE-Spec-style prior the replica margins are calibrated at.
pub const SENSITIVITY: f64 = 0.25;

/// Expert count of the swept target (qwen2-57B-A14B).
pub const EXPERTS: usize = 64;

pub fn default_alphas() -> Vec<f64> {
    vec![0.9]
}

pub fn default_topks() -> Vec<usize> {
    vec![8]
}

/// Batch sizes swept: memory-bound through the compute-bound shoulder.
pub fn default_batches() -> Vec<usize> {
    vec![4, 16, 64]
}

/// Verify budgets swept (E = 64 is the transparent off-switch arm).
pub fn default_budgets() -> Vec<usize> {
    vec![8, 16, 32, 48, EXPERTS]
}

/// Uniform-γ grid for the unbudgeted arms (0 = the AR baseline).
pub fn unbudgeted_gammas() -> Vec<usize> {
    vec![0, 1, 2, 3, 4, 6, 8]
}

/// Uniform-γ grid for the budgeted arms (the replica puts every best
/// budgeted arm at shallow depth; γ = 0 never carries a budget).
pub fn budgeted_gammas() -> Vec<usize> {
    vec![1, 2, 3, 4]
}

/// EP topologies swept: the single-group baseline plus one NVLink
/// expert-parallel deployment (budgets cap the *global* activation
/// before the per-rank split).
pub fn default_topologies() -> Vec<(Fabric, usize)> {
    vec![(Fabric::None, 1), (Fabric::NvLink, 4)]
}

/// One (sweep point, arm) measurement.
#[derive(Debug, Clone)]
pub struct BudgetStat {
    pub alpha: f64,
    pub k: usize,
    pub batch: usize,
    pub fabric: &'static str,
    pub devices: usize,
    /// Verify-expert budget (`None` = unbudgeted arm).
    pub budget: Option<usize>,
    pub gamma: usize,
    pub tokens: u64,
    pub decode_s: f64,
    /// Goodput: committed tokens per second of virtual clock.
    pub tok_s: f64,
    /// `tok_s` over the point's AR (γ = 0, unbudgeted) arm.
    pub speedup: f64,
}

/// Full sweep output.
#[derive(Debug, Clone)]
pub struct BudgetOut {
    pub rows: Vec<BudgetStat>,
    /// Smoke runs skip the replica-calibrated margin claims (tiny grid,
    /// short window) but still enforce the exact off-switch identity.
    pub smoke: bool,
}

/// A sweep point's identity: (alpha, K, batch, fabric, devices).
pub type Point = (f64, usize, usize, &'static str, usize);

fn sims(k: usize, fabric: Fabric, devices: usize) -> (ExecSim, ExecSim) {
    let platform = platform_2x_gpu_a();
    let target = presets::qwen2_57b_a14b().with_topk(k);
    let mut tsim = ExecSim::new(target.clone(), platform.clone());
    if let Some(topo) = fabric.topology(devices) {
        tsim = tsim.with_sharding(ShardingSpec::for_arch(topo, &target));
    }
    // Draft replica on one GPU of its rank (same convention as the
    // sharding sweep): dense draft under EP is the data-parallel
    // degenerate case of the EP walk.
    let draft_platform = Platform::new(platform.gpu.clone(), 1, platform.interconnect_bw);
    let draft = presets::qwen2_0_5b();
    let mut dsim = ExecSim::new(draft.clone(), draft_platform);
    if let Some(topo) = fabric.topology(devices) {
        dsim = dsim.with_sharding(ShardingSpec::for_arch(topo, &draft));
    }
    (tsim, dsim)
}

fn mk_request(id: SeqId, arrival: f64) -> Request {
    Request {
        id,
        prompt: (0..PROMPT_LEN as u32).collect(),
        params: SamplingParams {
            temperature: 0.0,
            max_new_tokens: MAX_NEW_TOKENS,
            eos_token: None,
        },
        arrival,
        class: 0,
    }
}

/// Drive one static (γ, budget) arm for [`WINDOW_ROUNDS`] decode rounds
/// with immediate slot replacement, twice (independent seeds, summed) —
/// the same two-trial variance halving as the ragged sweep. An
/// unbudgeted arm and a `budget ≥ E` arm at the same γ run identical
/// RNG draw sequences and identical prices, so their (tokens, decode)
/// pairs are bit-equal by construction.
fn run_arm(
    k: usize,
    fabric: Fabric,
    devices: usize,
    batch: usize,
    alpha: f64,
    gamma: usize,
    budget: Option<usize>,
    window: usize,
    seed: u64,
) -> anyhow::Result<(u64, f64)> {
    let mut tokens = 0u64;
    let mut decode = 0.0f64;
    for trial in 0..2u64 {
        let (tsim, dsim) = sims(k, fabric, devices);
        let mut backend = SyntheticLm::new(tsim, dsim, alpha, seed.wrapping_add(trial))
            .with_budget_alpha_curve(SENSITIVITY);
        backend.set_verify_budget(budget);
        let config = EngineConfig {
            gamma,
            kv: KvConfig {
                num_blocks: 1 << 16,
                block_size: 16,
            },
            scheduler: SchedulerConfig {
                max_batch: batch,
                admit_reserve_tokens: MAX_NEW_TOKENS,
                tpot_slo: None,
            },
            buckets: Buckets::pow2_up_to(batch.max(1)),
            seed: seed.wrapping_add(trial),
            ..Default::default()
        };
        let mut engine = Engine::new(config, backend);
        let mut next_id: u64 = batch as u64;
        for id in 0..batch as u64 {
            engine.submit(mk_request(id, 0.0));
        }
        for _ in 0..window {
            let completions = engine.step()?;
            for _ in completions {
                engine.submit(mk_request(next_id, engine.clock()));
                next_id += 1;
            }
        }
        tokens += engine.metrics.tokens_generated;
        decode += engine.metrics.decode_time();
    }
    anyhow::ensure!(decode > 0.0, "arm measured no decode time");
    Ok((tokens, decode))
}

#[allow(clippy::too_many_arguments)]
fn sweep_point(
    alpha: f64,
    k: usize,
    fabric: Fabric,
    devices: usize,
    batch: usize,
    budgets: &[usize],
    g_off: &[usize],
    g_bud: &[usize],
    window: usize,
    seed: u64,
) -> anyhow::Result<Vec<BudgetStat>> {
    let mut raw: Vec<(Option<usize>, usize, u64, f64)> = Vec::new();
    for &g in g_off {
        let (tok, dec) = run_arm(k, fabric, devices, batch, alpha, g, None, window, seed)?;
        raw.push((None, g, tok, dec));
    }
    for &bud in budgets {
        for &g in g_bud {
            let (tok, dec) =
                run_arm(k, fabric, devices, batch, alpha, g, Some(bud), window, seed)?;
            raw.push((Some(bud), g, tok, dec));
        }
    }
    let ar = raw
        .iter()
        .find(|(bud, g, _, _)| bud.is_none() && *g == 0)
        .map(|&(_, _, tok, dec)| tok as f64 / dec)
        .unwrap_or(f64::NAN);
    Ok(raw
        .into_iter()
        .map(|(budget, gamma, tokens, decode_s)| {
            let tok_s = tokens as f64 / decode_s;
            BudgetStat {
                alpha,
                k,
                batch,
                fabric: fabric.name(),
                devices,
                budget,
                gamma,
                tokens,
                decode_s,
                tok_s,
                speedup: tok_s / ar,
            }
        })
        .collect())
}

/// Run the full sweep (smoke: one batch, two budgets, short window —
/// the CI gate). Each (point) fans across worker threads; every arm
/// builds its own seeded engine, so the sweep is bit-identical to a
/// serial run.
pub fn run(smoke: bool, seed: u64) -> anyhow::Result<BudgetOut> {
    let (alphas, ks, batches, budgets, g_off, g_bud, topos, window) = if smoke {
        (
            vec![0.9],
            vec![8],
            vec![16],
            vec![32, EXPERTS],
            vec![0, 2, 3],
            vec![2, 3],
            vec![(Fabric::None, 1)],
            40,
        )
    } else {
        (
            default_alphas(),
            default_topks(),
            default_batches(),
            default_budgets(),
            unbudgeted_gammas(),
            budgeted_gammas(),
            default_topologies(),
            WINDOW_ROUNDS,
        )
    };
    let mut grid: Vec<(f64, usize, usize, Fabric, usize)> = Vec::new();
    for &alpha in &alphas {
        for &k in &ks {
            for &(fabric, d) in &topos {
                for &b in &batches {
                    grid.push((alpha, k, b, fabric, d));
                }
            }
        }
    }
    let per_point: Vec<anyhow::Result<Vec<BudgetStat>>> =
        parallel_sweep(&grid, |&(alpha, k, batch, fabric, d)| {
            sweep_point(
                alpha, k, fabric, d, batch, &budgets, &g_off, &g_bud, window, seed,
            )
        });
    let mut rows = Vec::new();
    for r in per_point {
        rows.extend(r?);
    }
    Ok(BudgetOut { rows, smoke })
}

impl BudgetOut {
    /// All sweep points present in the output.
    pub fn points(&self) -> Vec<Point> {
        let mut pts: Vec<Point> = Vec::new();
        for r in &self.rows {
            let p = (r.alpha, r.k, r.batch, r.fabric, r.devices);
            if !pts.contains(&p) {
                pts.push(p);
            }
        }
        pts
    }

    fn arms(&self, p: Point) -> Vec<&BudgetStat> {
        self.rows
            .iter()
            .filter(|r| (r.alpha, r.k, r.batch, r.fabric, r.devices) == p)
            .collect()
    }

    /// Best unbudgeted speculative arm (γ > 0, budget off) at a point.
    fn best_off(&self, p: Point) -> Option<&BudgetStat> {
        self.arms(p)
            .into_iter()
            .filter(|r| r.budget.is_none() && r.gamma > 0)
            .max_by(|a, b| a.tok_s.partial_cmp(&b.tok_s).unwrap())
    }

    /// Best *sub-coverage* budgeted arm (budget < E) at a point.
    fn best_budgeted(&self, p: Point) -> Option<&BudgetStat> {
        self.arms(p)
            .into_iter()
            .filter(|r| r.budget.map_or(false, |b| b < EXPERTS))
            .max_by(|a, b| a.tok_s.partial_cmp(&b.tok_s).unwrap())
    }
}

pub fn to_csv(out: &BudgetOut) -> CsvTable {
    let mut t = CsvTable::new(&[
        "alpha", "k", "batch", "fabric", "devices", "budget", "gamma", "tokens", "decode_s",
        "tok_s", "speedup",
    ]);
    for r in &out.rows {
        t.push_row(vec![
            format!("{}", r.alpha),
            r.k.to_string(),
            r.batch.to_string(),
            r.fabric.to_string(),
            r.devices.to_string(),
            r.budget.map_or_else(|| "off".into(), |b| b.to_string()),
            r.gamma.to_string(),
            r.tokens.to_string(),
            format!("{:.6}", r.decode_s),
            format!("{:.2}", r.tok_s),
            format!("{:.4}", r.speedup),
        ]);
    }
    t
}

/// Per-point summary JSON: the budgeted-vs-unbudgeted edge and the
/// off-switch identity verdict (the CI smoke gate validates this shape).
pub fn to_json(out: &BudgetOut) -> Json {
    let mut pts = Vec::new();
    for p in out.points() {
        let off = out.best_off(p);
        let bud = out.best_budgeted(p);
        let ratio = match (off, bud) {
            (Some(o), Some(b)) => Json::from(b.tok_s / o.tok_s),
            _ => Json::Null,
        };
        pts.push(Json::from_pairs(vec![
            ("alpha", p.0.into()),
            ("k", p.1.into()),
            ("batch", p.2.into()),
            ("fabric", p.3.into()),
            ("devices", p.4.into()),
            (
                "best_off_tok_s",
                off.map_or(Json::Null, |r| r.tok_s.into()),
            ),
            ("best_off_gamma", off.map_or(Json::Null, |r| r.gamma.into())),
            (
                "best_budgeted_tok_s",
                bud.map_or(Json::Null, |r| r.tok_s.into()),
            ),
            (
                "best_budgeted_gamma",
                bud.map_or(Json::Null, |r| r.gamma.into()),
            ),
            (
                "best_budget",
                bud.and_then(|r| r.budget).map_or(Json::Null, Json::from),
            ),
            ("budget_edge", ratio),
            (
                "identity_ok",
                off_switch_identity(out, p).is_ok().into(),
            ),
        ]));
    }
    Json::from_pairs(vec![
        ("sensitivity", SENSITIVITY.into()),
        ("smoke", out.smoke.into()),
        ("points", Json::Arr(pts)),
    ])
}

/// The exact off-switch claim at one point: every `budget = E` arm is
/// bit-identical (tokens and virtual clock) to the unbudgeted arm at
/// the same γ.
fn off_switch_identity(out: &BudgetOut, p: Point) -> Result<(), String> {
    for capped in out.arms(p) {
        if capped.budget != Some(EXPERTS) {
            continue;
        }
        let off = out
            .arms(p)
            .into_iter()
            .find(|r| r.budget.is_none() && r.gamma == capped.gamma)
            .ok_or_else(|| {
                format!("point {p:?}: no unbudgeted twin for γ={}", capped.gamma)
            })?;
        if capped.tokens != off.tokens || capped.decode_s != off.decode_s {
            return Err(format!(
                "point {p:?} γ={}: budget={} arm diverged from unbudgeted \
                 ({} tok / {:.9}s vs {} tok / {:.9}s)",
                capped.gamma, EXPERTS, capped.tokens, capped.decode_s, off.tokens, off.decode_s
            ));
        }
    }
    Ok(())
}

/// The acceptance-criteria shape claims. Margins validated against
/// `python/replica_budget.py` (expected-value ratios at sensitivity
/// 0.25: 1.126 at B=4, 1.196 at B=16, 1.152 at B=64 on the unsharded
/// grid; the pinned assertions leave headroom for the two-trial
/// sampling noise of the real engine, ±~2%).
pub fn check_shape(out: &BudgetOut) -> Result<(), String> {
    for p in out.points() {
        off_switch_identity(out, p)?;
    }
    if out.smoke {
        return Ok(());
    }
    let mut weight_bound_win = false;
    for p in out.points() {
        if p.4 != 1 {
            // EP points assert the identity only — the replica's margins
            // are calibrated on the unsharded walk.
            continue;
        }
        let off = out
            .best_off(p)
            .ok_or_else(|| format!("point {p:?}: no unbudgeted arms"))?;
        let bud = out
            .best_budgeted(p)
            .ok_or_else(|| format!("point {p:?}: no budgeted arms"))?;
        if bud.tok_s < 0.98 * off.tok_s {
            return Err(format!(
                "point {p:?}: best budgeted {:.1} tok/s < 0.98 × best unbudgeted {:.1}",
                bud.tok_s, off.tok_s
            ));
        }
        if p.2 <= 32 && bud.tok_s >= 1.02 * off.tok_s {
            weight_bound_win = true;
        }
    }
    if !weight_bound_win {
        return Err(
            "no memory-bound point where a sub-coverage budget beats the best \
             unbudgeted arm by ≥2%"
                .into(),
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_sweep_passes_shape_and_renders() {
        let out = run(true, 42).unwrap();
        // 3 unbudgeted γ + 2 budgets × 2 γ arms on the single point.
        assert_eq!(out.rows.len(), 3 + 2 * 2);
        for r in &out.rows {
            assert!(r.tok_s > 0.0, "{r:?}");
        }
        check_shape(&out).expect("smoke shape (off-switch identity)");
        let t = to_csv(&out);
        assert_eq!(t.rows.len(), out.rows.len());
        let j = to_json(&out).to_string();
        assert!(j.contains("\"budget_edge\""));
        assert!(j.contains("\"identity_ok\""));
        assert!(j.contains("\"sensitivity\""));
    }

    #[test]
    fn off_switch_identity_is_exact_in_the_smoke_grid() {
        let out = run(true, 7).unwrap();
        for p in out.points() {
            off_switch_identity(&out, p).unwrap();
        }
        // And the capped arms genuinely exist (the claim is not vacuous).
        assert!(out
            .rows
            .iter()
            .any(|r| r.budget == Some(EXPERTS) && r.tokens > 0));
    }

    #[test]
    fn check_shape_rejects_a_forged_divergence() {
        let mut out = run(true, 42).unwrap();
        if let Some(r) = out
            .rows
            .iter_mut()
            .find(|r| r.budget == Some(EXPERTS))
        {
            r.tokens += 1;
        }
        assert!(check_shape(&out).is_err());
    }
}
