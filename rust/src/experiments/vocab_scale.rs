//! Vocab-scaling scenario — the realistic-vocabulary sweep the sparse
//! logits interface unlocks.
//!
//! Before the [`crate::spec::LogitsView`] overhaul the synthetic backend
//! allocated a dense vocab-sized one-hot row for every emitted
//! distribution — O(B·γ·vocab) per round — which pinned every experiment
//! to a toy vocab of 64. With sparse rows the coordinator cost per token
//! is O(1), so the Fig. 2 measurement runs unchanged at Qwen2-57B's real
//! 151 936-entry vocabulary.
//!
//! The scenario doubles as a consistency check on the virtual clock: the
//! roofline simulator prices the *architecture's* LM head (always the
//! real vocab) regardless of the synthetic token space, so the measured
//! speedups must be invariant to the sweep axis up to acceptance-sampling
//! noise. A vocab-dependent drift here would mean coordinator-side token
//! math leaked onto the virtual clock.

use super::{paper_batch_grid, run_pair_grid, RunOpts};
use crate::arch::presets;
use crate::hardware::platform_2x_gpu_a;
use crate::util::csv::CsvTable;

/// Default sweep: toy → GPT-2-scale → Qwen2's real vocabulary.
pub const VOCABS: [usize; 4] = [64, 4096, 32_768, 151_936];

pub struct VocabScaleOutput {
    pub vocabs: Vec<usize>,
    pub batches: Vec<usize>,
    /// `speedups[vi][bi]` — SD speedup at `vocabs[vi]`, `batches[bi]`.
    pub speedups: Vec<Vec<f64>>,
    pub table: CsvTable,
}

/// Run the fig2-style batch sweep at each vocabulary size (each sweep
/// fans across the parallel runner).
pub fn run(
    vocabs: &[usize],
    gamma: usize,
    alpha: f64,
    seed: u64,
) -> anyhow::Result<VocabScaleOutput> {
    let target = presets::qwen2_57b_a14b();
    let draft = presets::qwen2_0_5b();
    let platform = platform_2x_gpu_a();
    let batches = paper_batch_grid();
    let mut speedups = Vec::with_capacity(vocabs.len());
    let mut table = CsvTable::new(&["vocab", "batch", "speedup", "sigma"]);
    for &vocab in vocabs {
        let opts = RunOpts {
            vocab,
            seed,
            max_new_tokens: 24,
            ..Default::default()
        };
        let stats = run_pair_grid(&target, &draft, &platform, alpha, gamma, &batches, &opts)?;
        for s in &stats {
            table.push_nums(&[vocab as f64, s.batch as f64, s.speedup, s.sigma]);
        }
        speedups.push(stats.iter().map(|s| s.speedup).collect());
    }
    Ok(VocabScaleOutput {
        vocabs: vocabs.to_vec(),
        batches,
        speedups,
        table,
    })
}

/// Shape claims: every vocabulary's sweep completes with the paper's
/// interior rise-then-fall peak, and the peak speedup is invariant to the
/// synthetic vocab within the acceptance-sampling noise band (the token
/// space changes which chain tokens are drawn, not their Bernoulli(α)
/// acceptance statistics — and never the virtual-clock prices).
pub fn check_shape(out: &VocabScaleOutput) -> Result<(), String> {
    let mut peaks = Vec::new();
    for (vi, sweep) in out.speedups.iter().enumerate() {
        let peak = crate::util::stats::argmax(sweep);
        if peak == 0 || peak == sweep.len() - 1 {
            return Err(format!(
                "vocab {}: speedup peak not interior: {sweep:?}",
                out.vocabs[vi]
            ));
        }
        peaks.push(sweep[peak]);
    }
    let pmax = peaks.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let pmin = peaks.iter().cloned().fold(f64::INFINITY, f64::min);
    if pmax / pmin > 1.15 {
        return Err(format!(
            "peak speedup should be vocab-invariant within noise: {peaks:?} for vocabs {:?}",
            out.vocabs
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    // The full realistic-vocab grid runs in
    // rust/tests/integration_experiments.rs; this keeps a cheap two-point
    // sanity check in the unit suite.
    #[test]
    fn toy_and_midsize_vocab_agree() {
        let out = run(&[64, 4096], 3, 0.9, 13).unwrap();
        check_shape(&out).unwrap();
        assert_eq!(out.speedups.len(), 2);
        assert_eq!(out.speedups[0].len(), out.batches.len());
        assert_eq!(out.table.rows.len(), 2 * out.batches.len());
    }
}
