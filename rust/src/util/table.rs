//! Markdown table rendering for console reports and EXPERIMENTS.md blocks.
//! Benches print the same rows the paper's tables report via this module.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A markdown table builder with padded, aligned output.
#[derive(Debug, Clone)]
pub struct MdTable {
    header: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl MdTable {
    pub fn new(header: &[&str]) -> Self {
        MdTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            aligns: header.iter().map(|_| Align::Right).collect(),
            rows: Vec::new(),
        }
    }

    pub fn align(mut self, aligns: &[Align]) -> Self {
        assert_eq!(aligns.len(), self.header.len());
        self.aligns = aligns.to_vec();
        self
    }

    pub fn push(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "table row width mismatch");
        self.rows.push(cells);
    }

    pub fn push_display(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.push(cells.iter().map(|c| c.to_string()).collect());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&self.render_row(&self.header, &widths));
        out.push('\n');
        let sep: Vec<String> = widths
            .iter()
            .zip(&self.aligns)
            .map(|(w, a)| match a {
                Align::Left => format!(":{}", "-".repeat(w.max(&2) - 1)),
                Align::Right => format!("{}:", "-".repeat(w.max(&2) - 1)),
            })
            .collect();
        out.push_str(&format!("| {} |", sep.join(" | ")));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&self.render_row(row, &widths));
            out.push('\n');
        }
        out
    }

    fn render_row(&self, cells: &[String], widths: &[usize]) -> String {
        let padded: Vec<String> = cells
            .iter()
            .enumerate()
            .map(|(i, c)| match self.aligns[i] {
                Align::Left => format!("{:<width$}", c, width = widths[i]),
                Align::Right => format!("{:>width$}", c, width = widths[i]),
            })
            .collect();
        format!("| {} |", padded.join(" | "))
    }
}

/// Shorthand float formatting used across reports (2 decimals, like the
/// paper's tables).
pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

/// 3-decimal formatting for σ-like columns.
pub fn f3(v: f64) -> String {
    format!("{v:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = MdTable::new(&["name", "x"]).align(&[Align::Left, Align::Right]);
        t.push(vec!["qwen2".into(), "2.29".into()]);
        t.push(vec!["mixtral-long-name".into(), "1.79".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[1].contains(":-"));
        assert!(lines[2].ends_with("|"));
        // All lines have equal width.
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        let mut t = MdTable::new(&["a", "b"]);
        t.push(vec!["1".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(f2(2.294), "2.29");
        assert_eq!(f3(0.9456), "0.946");
    }
}
