//! Small self-contained substrates the rest of the crate builds on.
//!
//! The build environment has no network access and only the `xla` crate's
//! vendored dependency closure, so the usual ecosystem crates (serde, clap,
//! rand, criterion, ...) are re-implemented here at the scale this project
//! needs: a JSON parser/writer, a CLI parser, a PCG-based RNG with the
//! distributions the simulator needs, descriptive statistics, and CSV /
//! markdown table emitters.

pub mod cli;
pub mod csv;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod table;

/// Clamp helper used across fitting and simulation code.
#[inline]
pub fn clampf(x: f64, lo: f64, hi: f64) -> f64 {
    if x < lo {
        lo
    } else if x > hi {
        hi
    } else {
        x
    }
}

/// Approximate float equality with both absolute and relative tolerance,
/// mirroring `numpy.allclose` semantics (used heavily in tests).
#[inline]
pub fn approx_eq(a: f64, b: f64, rtol: f64, atol: f64) -> bool {
    (a - b).abs() <= atol + rtol * b.abs()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clampf_bounds() {
        assert_eq!(clampf(5.0, 0.0, 1.0), 1.0);
        assert_eq!(clampf(-5.0, 0.0, 1.0), 0.0);
        assert_eq!(clampf(0.5, 0.0, 1.0), 0.5);
    }

    #[test]
    fn approx_eq_tolerances() {
        assert!(approx_eq(1.0, 1.0 + 1e-9, 1e-6, 1e-8));
        assert!(!approx_eq(1.0, 1.1, 1e-6, 1e-8));
        assert!(approx_eq(0.0, 1e-9, 0.0, 1e-8));
    }
}
