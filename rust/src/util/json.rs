//! Minimal JSON parser and writer.
//!
//! Used for configs, the AOT artifact manifest, and experiment result files.
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Object key order is preserved (important for
//! the deterministic artifact manifest diffing in `make artifacts`).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects preserve insertion order via a `Vec` of pairs plus
/// a lookup map (keys are expected to be unique, as in real-world JSON).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(JsonObj),
}

/// Insertion-ordered JSON object.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JsonObj {
    pairs: Vec<(String, Json)>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, value: Json) {
        let key = key.into();
        if let Some(slot) = self.pairs.iter_mut().find(|(k, _)| *k == key) {
            slot.1 = value;
        } else {
            self.pairs.push((key, value));
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Json)> {
        self.pairs.iter().map(|(k, v)| (k.as_str(), v))
    }

    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    /// Convert to a sorted map (useful for canonical comparisons in tests).
    pub fn to_sorted_map(&self) -> BTreeMap<String, Json> {
        self.pairs.iter().cloned().collect()
    }
}

impl Json {
    // ---- constructors -----------------------------------------------------

    pub fn obj() -> JsonObj {
        JsonObj::new()
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        let mut o = JsonObj::new();
        for (k, v) in pairs {
            o.insert(k, v);
        }
        Json::Obj(o)
    }

    // ---- accessors ---------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Path lookup: `j.get("a")` on objects, ignoring other variants.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Required-field helpers that produce readable errors for config loading.
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing or non-numeric field `{key}`"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing or non-integer field `{key}`"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing or non-string field `{key}`"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing or non-array field `{key}`"))
    }

    // ---- parsing -----------------------------------------------------------

    pub fn parse(input: &str) -> anyhow::Result<Json> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            anyhow::bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
    }

    // ---- serialization -----------------------------------------------------

    /// Compact single-line serialization.
    ///
    /// Deliberately shadows `Display::to_string` (same output, no
    /// formatter indirection on the emitter hot path) — the deny-by-
    /// default clippy lint is waived rather than renaming a method the
    /// whole crate calls.
    #[allow(clippy::inherent_to_string_shadow_display)]
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty-printed serialization with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            anyhow::bail!("expected '{}' at byte {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => anyhow::bail!("unexpected character '{}' at byte {}", c as char, self.pos),
            None => anyhow::bail!("unexpected end of input"),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = text
            .parse()
            .map_err(|e| anyhow::anyhow!("bad number `{text}`: {e}"))?;
        Ok(Json::Num(n))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self
                                .bump()
                                .ok_or_else(|| anyhow::anyhow!("truncated \\u escape"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| anyhow::anyhow!("bad hex in \\u escape"))?;
                        }
                        // Surrogate pairs: recombine if a high surrogate is followed
                        // by an escaped low surrogate.
                        let ch = if (0xD800..0xDC00).contains(&code) {
                            if self.bytes[self.pos..].starts_with(b"\\u") {
                                self.pos += 2;
                                let mut low = 0u32;
                                for _ in 0..4 {
                                    let c = self
                                        .bump()
                                        .ok_or_else(|| anyhow::anyhow!("truncated surrogate"))?;
                                    low = low * 16
                                        + (c as char).to_digit(16).ok_or_else(|| {
                                            anyhow::anyhow!("bad hex in surrogate")
                                        })?;
                                }
                                char::from_u32(0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00))
                            } else {
                                None
                            }
                        } else {
                            char::from_u32(code)
                        };
                        s.push(ch.ok_or_else(|| anyhow::anyhow!("invalid unicode escape"))?);
                    }
                    _ => anyhow::bail!("bad escape at byte {}", self.pos),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8: find the full sequence.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    let end = start + len;
                    if end > self.bytes.len() {
                        anyhow::bail!("truncated UTF-8 sequence");
                    }
                    s.push_str(std::str::from_utf8(&self.bytes[start..end])?);
                    self.pos = end;
                }
                None => anyhow::bail!("unterminated string"),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => anyhow::bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let value = self.value()?;
            obj.insert(key, value);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(obj)),
                _ => anyhow::bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }
}

// Convenience From impls for builder-style construction in result emitters.
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"qwen2","experts":64,"topk":8,"ratios":[0.5,1,2.25],"flags":{"moe":true,"dense":false},"note":null}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("line1\nline2\t\"quoted\" \\ \u{1}".into());
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed, j);
    }

    #[test]
    fn unicode_and_surrogates() {
        let j = Json::parse(r#""é 😀 ü""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é 😀 ü");
    }

    #[test]
    fn object_preserves_insertion_order() {
        let j = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<&str> = j.as_obj().unwrap().iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn insert_replaces_duplicate_key() {
        let mut o = JsonObj::new();
        o.insert("k", Json::Num(1.0));
        o.insert("k", Json::Num(2.0));
        assert_eq!(o.len(), 1);
        assert_eq!(o.get("k").unwrap().as_f64().unwrap(), 2.0);
    }

    #[test]
    fn errors_are_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn req_helpers() {
        let j = Json::parse(r#"{"n": 3, "s": "x", "a": [1]}"#).unwrap();
        assert_eq!(j.req_usize("n").unwrap(), 3);
        assert_eq!(j.req_str("s").unwrap(), "x");
        assert_eq!(j.req_arr("a").unwrap().len(), 1);
        assert!(j.req_f64("missing").is_err());
    }

    #[test]
    fn integer_formatting_has_no_decimal_point() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }
}
