//! Descriptive statistics used by metrics reporting and experiment
//! post-processing (means, variance, percentiles, linear regression,
//! mean-squared error — the Alg. 1 line-13 objective).

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile with linear interpolation (numpy's default). `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=100.0).contains(&q), "percentile q out of range");
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Mean squared error between two equal-length series — the fitting
/// objective of Alg. 1 (line 13) and the Table 3 column.
pub fn mse(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "mse length mismatch");
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).powi(2))
        .sum::<f64>()
        / a.len() as f64
}

/// Ordinary least squares fit y = a + b*x; returns (intercept, slope, r2).
pub fn linregress(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2, "linregress needs >= 2 points");
    let mx = mean(x);
    let my = mean(y);
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (&xi, &yi) in x.iter().zip(y) {
        sxx += (xi - mx) * (xi - mx);
        sxy += (xi - mx) * (yi - my);
        syy += (yi - my) * (yi - my);
    }
    let slope = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    let intercept = my - slope * mx;
    let r2 = if sxx > 0.0 && syy > 0.0 {
        (sxy * sxy) / (sxx * syy)
    } else {
        0.0
    };
    (intercept, slope, r2)
}

/// Pearson correlation coefficient. Used to assert "target efficiency shows
/// a consistent trend with speedup" (Fig. 2) quantitatively.
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    if x.len() < 2 {
        return 0.0;
    }
    let (_, _, r2) = linregress(x, y);
    let mx = mean(x);
    let my = mean(y);
    let sign: f64 = x
        .iter()
        .zip(y)
        .map(|(&a, &b)| (a - mx) * (b - my))
        .sum::<f64>();
    r2.sqrt() * sign.signum()
}

/// Index of the maximum value (first occurrence).
pub fn argmax(xs: &[f64]) -> usize {
    assert!(!xs.is_empty());
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Simple χ² goodness-of-fit statistic for observed vs expected counts.
/// Used by the losslessness test of the rejection sampler.
pub fn chi_square(observed: &[f64], expected: &[f64]) -> f64 {
    assert_eq!(observed.len(), expected.len());
    observed
        .iter()
        .zip(expected)
        .filter(|(_, &e)| e > 0.0)
        .map(|(&o, &e)| (o - e).powi(2) / e)
        .sum()
}

/// Running-summary accumulator (Welford) for streaming metrics.
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert!((percentile(&xs, 25.0) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn mse_and_argmax() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 4.0]), 2.0);
        assert_eq!(argmax(&[0.2, 0.9, 0.5]), 1);
    }

    #[test]
    fn linregress_recovers_line() {
        let x: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 + 2.0 * v).collect();
        let (a, b, r2) = linregress(&x, &y);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn pearson_sign() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let up = [2.0, 4.0, 5.0, 9.0];
        let down = [9.0, 5.0, 4.0, 2.0];
        assert!(pearson(&x, &up) > 0.9);
        assert!(pearson(&x, &down) < -0.9);
    }

    #[test]
    fn running_matches_batch() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64 * 0.37).sin() * 5.0).collect();
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert!((r.mean() - mean(&xs)).abs() < 1e-9);
        assert!((r.variance() - variance(&xs)).abs() < 1e-9);
        assert_eq!(r.count(), 100);
        assert_eq!(r.min(), min(&xs));
        assert_eq!(r.max(), max(&xs));
    }

    #[test]
    fn chi_square_zero_when_exact() {
        assert_eq!(chi_square(&[10.0, 20.0], &[10.0, 20.0]), 0.0);
        assert!(chi_square(&[15.0, 15.0], &[10.0, 20.0]) > 0.0);
    }
}
