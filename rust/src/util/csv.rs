//! CSV emission/parsing for experiment results (`results/*.csv`).
//!
//! Every bench target writes its series here so figures can be re-plotted
//! outside the repo; the integration tests parse the files back to check
//! the shape claims.

use std::fmt::Write as _;
use std::path::Path;

/// A rectangular CSV table with a header row.
#[derive(Debug, Clone, Default)]
pub struct CsvTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new(header: &[&str]) -> Self {
        CsvTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Push a row of display-able cells; panics on width mismatch (a bug in
    /// the bench code, not a runtime condition).
    pub fn push_row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "CSV row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
    }

    /// Convenience for numeric rows.
    pub fn push_nums(&mut self, cells: &[f64]) {
        self.push_row(cells.iter().map(|v| format_num(*v)).collect());
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        writeln!(out, "{}", join_escaped(&self.header)).unwrap();
        for row in &self.rows {
            writeln!(out, "{}", join_escaped(row)).unwrap();
        }
        out
    }

    pub fn write_file(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_string())?;
        Ok(())
    }

    pub fn parse(text: &str) -> anyhow::Result<CsvTable> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header = split_row(
            lines
                .next()
                .ok_or_else(|| anyhow::anyhow!("empty CSV"))?,
        );
        let mut rows = Vec::new();
        for line in lines {
            let row = split_row(line);
            if row.len() != header.len() {
                anyhow::bail!(
                    "CSV row width {} != header width {}: {line}",
                    row.len(),
                    header.len()
                );
            }
            rows.push(row);
        }
        Ok(CsvTable { header, rows })
    }

    pub fn read_file(path: &Path) -> anyhow::Result<CsvTable> {
        CsvTable::parse(&std::fs::read_to_string(path)?)
    }

    /// Extract a named column as f64s.
    pub fn column_f64(&self, name: &str) -> anyhow::Result<Vec<f64>> {
        let idx = self
            .header
            .iter()
            .position(|h| h == name)
            .ok_or_else(|| anyhow::anyhow!("no column `{name}`"))?;
        self.rows
            .iter()
            .map(|r| {
                r[idx]
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad number in `{name}`: {e}"))
            })
            .collect()
    }

    pub fn column_str(&self, name: &str) -> anyhow::Result<Vec<String>> {
        let idx = self
            .header
            .iter()
            .position(|h| h == name)
            .ok_or_else(|| anyhow::anyhow!("no column `{name}`"))?;
        Ok(self.rows.iter().map(|r| r[idx].clone()).collect())
    }
}

/// Render a float compactly: integers without a decimal point, otherwise up
/// to 6 significant decimals with trailing zeros trimmed.
pub fn format_num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        let s = format!("{v:.6}");
        let s = s.trim_end_matches('0').trim_end_matches('.');
        s.to_string()
    }
}

fn join_escaped(cells: &[String]) -> String {
    cells
        .iter()
        .map(|c| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        })
        .collect::<Vec<_>>()
        .join(",")
}

fn split_row(line: &str) -> Vec<String> {
    let mut cells = Vec::new();
    let mut cur = String::new();
    let mut chars = line.chars().peekable();
    let mut in_quotes = false;
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes => {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    cur.push('"');
                } else {
                    in_quotes = false;
                }
            }
            '"' => in_quotes = true,
            ',' if !in_quotes => {
                cells.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    cells.push(cur);
    cells
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut t = CsvTable::new(&["batch", "speedup", "note"]);
        t.push_row(vec!["8".into(), "1.63".into(), "hello, \"world\"".into()]);
        t.push_nums(&[16.0, 2.29, 0.0]);
        let parsed = CsvTable::parse(&t.to_string()).unwrap();
        assert_eq!(parsed.header, t.header);
        assert_eq!(parsed.rows, t.rows);
    }

    #[test]
    fn column_extraction() {
        let t = CsvTable::parse("a,b\n1,x\n2,y\n").unwrap();
        assert_eq!(t.column_f64("a").unwrap(), vec![1.0, 2.0]);
        assert_eq!(t.column_str("b").unwrap(), vec!["x", "y"]);
        assert!(t.column_f64("b").is_err());
        assert!(t.column_f64("missing").is_err());
    }

    #[test]
    fn width_mismatch_detected() {
        assert!(CsvTable::parse("a,b\n1\n").is_err());
    }

    #[test]
    fn format_num_trims() {
        assert_eq!(format_num(2.0), "2");
        assert_eq!(format_num(2.5), "2.5");
        assert_eq!(format_num(2.290000), "2.29");
    }
}
