//! Deterministic PCG64-based RNG plus the sampling distributions the
//! simulator and workload generators need (uniform, normal, gamma,
//! Dirichlet, categorical, Bernoulli, permutation).
//!
//! All experiment code takes explicit seeds so every table/figure is
//! bit-reproducible across runs — the same property the paper relies on
//! ("the random seed is fixed across all runs to ensure identical
//! workloads", Appendix A.1).

/// PCG-XSH-RR 64/32 combined into a 64-bit output (two 32-bit draws).
/// Small, fast, and statistically solid for simulation purposes.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Rng {
    /// Create an RNG from a seed and a stream id. Distinct streams are
    /// independent; experiments use the stream id to decorrelate
    /// sub-components (routing vs. acceptance vs. workload arrival).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Rng {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Single-argument convenience constructor.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) using Lemire's multiply-shift rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let low = m as u64;
            if low >= n {
                return (m >> 64) as u64;
            }
            // Rejection branch for unbiased sampling.
            let threshold = n.wrapping_neg() % n;
            if low >= threshold {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value; the pair is not cached to
    /// keep the generator state trivially clonable/replayable).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal sample parameterized by the mean/std of the underlying
    /// normal (used for workload prompt-length draws).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang; shape > 0.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u: f64 = self.f64().max(1e-300);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Symmetric Dirichlet(alpha) over `n` categories. alpha < 1 produces
    /// skewed (imbalanced) distributions — used to model expert-routing
    /// imbalance in `simulator::routing`.
    pub fn dirichlet(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..n).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = v.iter().sum();
        if sum <= 0.0 {
            return vec![1.0 / n as f64; n];
        }
        for x in &mut v {
            *x /= sum;
        }
        v
    }

    /// Draw an index from an unnormalized weight vector.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical with non-positive total weight");
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Sample `k` distinct indices from weights without replacement
    /// (sequential draw-and-zero). This is exactly MoE top-K *sampled*
    /// routing, used by the routing simulator.
    pub fn categorical_k(&mut self, weights: &[f64], k: usize) -> Vec<usize> {
        assert!(k <= weights.len());
        let mut w = weights.to_vec();
        let mut out = Vec::with_capacity(k);
        for _ in 0..k {
            let i = self.categorical(&w);
            out.push(i);
            w[i] = 0.0;
        }
        out
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Exponential inter-arrival sample with the given rate (per second).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        -self.f64().max(1e-300).ln() / rate
    }

    /// Fork a decorrelated child RNG (used to hand independent streams to
    /// worker threads while keeping the parent replayable).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15), tag | 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::seeded(7);
        let mut b = Rng::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seeded(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_roughly() {
        let mut r = Rng::seeded(11);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seeded(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::seeded(9);
        for &shape in &[0.5, 1.0, 2.5, 8.0] {
            let n = 20_000;
            let mean = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() < 0.1 * shape.max(1.0),
                "shape={shape} mean={mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::seeded(13);
        for &alpha in &[0.1, 1.0, 10.0] {
            let v = r.dirichlet(alpha, 16);
            assert_eq!(v.len(), 16);
            let s: f64 = v.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(v.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::seeded(17);
        let w = [1.0, 3.0];
        let mut hits = 0;
        for _ in 0..40_000 {
            if r.categorical(&w) == 1 {
                hits += 1;
            }
        }
        let frac = hits as f64 / 40_000.0;
        assert!((frac - 0.75).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn categorical_k_is_distinct() {
        let mut r = Rng::seeded(19);
        let w = vec![1.0; 10];
        for _ in 0..200 {
            let picks = r.categorical_k(&w, 4);
            let mut sorted = picks.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 4, "picks not distinct: {picks:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(23);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_produces_independent_streams() {
        let mut parent = Rng::seeded(29);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::seeded(31);
        let n = 30_000;
        let mean = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }
}
