//! Tiny declarative CLI parser for the `moesd` launcher.
//!
//! Supports `moesd <subcommand> [--flag] [--key value] [--key=value]
//! [positional...]`. Typed accessors produce readable errors. Kept
//! dependency-free (clap is not available in this build environment).

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, key/value options, boolean flags and
/// positional arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    /// `known_flags` lists options that take *no* value, so that
    /// `--verbose out.csv` parses `out.csv` as positional.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I, known_flags: &[&str]) -> Args {
        let mut args = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&rest) {
                    args.flags.push(rest.to_string());
                } else if let Some(next) = iter.peek() {
                    if next.starts_with("--") {
                        args.flags.push(rest.to_string());
                    } else {
                        let v = iter.next().unwrap();
                        args.options.insert(rest.to_string(), v);
                    }
                } else {
                    args.flags.push(rest.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env(known_flags: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name}: expected integer, got `{v}` ({e})")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name}: expected integer, got `{v}` ({e})")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name}: expected number, got `{v}` ({e})")),
        }
    }

    /// Comma-separated list of usizes, e.g. `--batches 1,2,4,8`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> anyhow::Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|e| anyhow::anyhow!("--{name}: bad element `{s}` ({e})"))
                })
                .collect(),
        }
    }

    pub fn require(&self, name: &str) -> anyhow::Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow::anyhow!("missing required option --{name}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(tokens.iter().map(|s| s.to_string()), &["verbose", "json"])
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["serve", "--port", "8080", "--model=tiny", "extra"]);
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get("model"), Some("tiny"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn known_flags_do_not_consume_values() {
        let a = parse(&["bench", "--verbose", "fig2"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["fig2"]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["bench", "--trailing-unknown"]);
        assert!(a.flag("trailing-unknown"));
    }

    #[test]
    fn unknown_flag_followed_by_flag_is_boolean() {
        let a = parse(&["x", "--a", "--b", "v"]);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&["x", "--n", "12", "--rate", "0.5", "--list", "1,2,3"]);
        assert_eq!(a.usize_or("n", 0).unwrap(), 12);
        assert_eq!(a.f64_or("rate", 0.0).unwrap(), 0.5);
        assert_eq!(a.usize_list_or("list", &[]).unwrap(), vec![1, 2, 3]);
        assert_eq!(a.usize_or("absent", 7).unwrap(), 7);
        assert!(a.usize_or("rate", 0).is_err());
        assert!(a.require("absent").is_err());
    }
}
