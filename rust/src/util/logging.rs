//! Leveled stderr logging with a global verbosity switch.
//!
//! The coordinator's hot loop never formats log strings unless the level is
//! enabled (the macros test the level first), keeping logging out of the
//! steady-state decode path.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level_from_str(s: &str) -> Level {
    match s.to_ascii_lowercase().as_str() {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => Level::Info,
    }
}

#[inline]
pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{:9.3}s {tag} {module}] {msg}", t.as_secs_f64());
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::util::logging::enabled($crate::util::logging::Level::Info) {
            $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), &format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        if $crate::util::logging::enabled($crate::util::logging::Level::Warn) {
            $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), &format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        if $crate::util::logging::enabled($crate::util::logging::Level::Error) {
            $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), &format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        if $crate::util::logging::enabled($crate::util::logging::Level::Debug) {
            $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), &format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing_and_gating() {
        assert_eq!(level_from_str("debug"), Level::Debug);
        assert_eq!(level_from_str("unknown"), Level::Info);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}
