//! Token sampling and the speculative-decoding rejection sampler
//! (§3.1 stage ③; Leviathan et al. 2023, Chen et al. 2023).
//!
//! The rejection sampler is the losslessness-critical piece: accepted
//! tokens must be distributed exactly as if the target model had sampled
//! them autoregressively. [`verify_chain_views`] is the engine's hot-path
//! entry point, consuming [`LogitsView`] rows in whatever representation
//! the backend emits; [`verify_chain`] is the dense reference
//! implementation of the published algorithm. The two are RNG-draw-for-
//! RNG-draw identical — the χ²-based distribution tests here and the
//! sparse/dense equivalence property tests in
//! `rust/tests/prop_invariants.rs` pin that down.

use crate::util::rng::Rng;

/// A next-token probability distribution, in whichever representation the
/// backend can produce cheapest.
///
/// The dense `Vec<f64>` row the spec API used to mandate is O(vocab) to
/// allocate and walk: at Qwen2's real 151 936-entry vocabulary every
/// propose/verify emitted megabytes of one-hot rows per round, which is
/// why the synthetic experiments were pinned to a toy vocab of 64. The
/// sparse variants carry *exactly* the same distribution whenever the
/// mass genuinely lives on few tokens (the synthetic oracle's one-hot
/// chains, greedy temperature-0 rows from the real model), and every
/// consumer in this module mirrors `Rng::categorical`'s dense scan
/// bit-for-bit, so swapping representations never changes an emitted
/// token.
#[derive(Debug, Clone, PartialEq)]
pub enum LogitsView {
    /// All probability mass on `token` (greedy rows, oracle chains).
    OneHot { token: u32, vocab: u32 },
    /// Sparse support: `(token, weight)` pairs sorted by token id; every
    /// omitted token has weight exactly 0. Weights need not be normalized
    /// (mirroring `Rng::categorical`'s unnormalized-weights contract).
    TopK { entries: Vec<(u32, f64)>, vocab: u32 },
    /// Dense vocab-sized row (real-model sampled distributions).
    Dense(Vec<f64>),
}

impl LogitsView {
    /// Degenerate distribution with all mass on `token`.
    pub fn one_hot(token: u32, vocab: usize) -> LogitsView {
        assert!((token as usize) < vocab, "one-hot token {token} out of vocab {vocab}");
        LogitsView::OneHot {
            token,
            vocab: vocab as u32,
        }
    }

    /// Sparse distribution from `(token, weight)` pairs (sorted here;
    /// tokens must be distinct and in-range, weights non-negative).
    pub fn top_k(mut entries: Vec<(u32, f64)>, vocab: usize) -> LogitsView {
        assert!(!entries.is_empty(), "top_k needs at least one entry");
        entries.sort_by_key(|&(t, _)| t);
        for w in entries.windows(2) {
            assert!(w[0].0 < w[1].0, "duplicate token {} in top_k entries", w[1].0);
        }
        for &(t, p) in &entries {
            assert!((t as usize) < vocab, "top_k token {t} out of vocab {vocab}");
            assert!(p >= 0.0, "negative weight {p} for token {t}");
        }
        LogitsView::TopK {
            entries,
            vocab: vocab as u32,
        }
    }

    /// Dense row (the reference representation).
    pub fn dense(row: Vec<f64>) -> LogitsView {
        assert!(!row.is_empty(), "dense row must be non-empty");
        LogitsView::Dense(row)
    }

    pub fn vocab(&self) -> usize {
        match self {
            LogitsView::OneHot { vocab, .. } | LogitsView::TopK { vocab, .. } => *vocab as usize,
            LogitsView::Dense(row) => row.len(),
        }
    }

    /// Probability (weight) of one token — O(1) / O(log k) / O(1).
    pub fn prob(&self, token: u32) -> f64 {
        match self {
            LogitsView::OneHot { token: t, .. } => {
                if token == *t {
                    1.0
                } else {
                    0.0
                }
            }
            LogitsView::TopK { entries, .. } => entries
                .binary_search_by_key(&token, |&(t, _)| t)
                .map_or(0.0, |i| entries[i].1),
            LogitsView::Dense(row) => row[token as usize],
        }
    }

    /// Expand to the dense vocab-sized row (reference path / tests).
    pub fn to_dense(&self) -> Vec<f64> {
        match self {
            LogitsView::Dense(row) => row.clone(),
            LogitsView::OneHot { token, vocab } => {
                let mut out = vec![0.0; *vocab as usize];
                out[*token as usize] = 1.0;
                out
            }
            LogitsView::TopK { entries, vocab } => {
                let mut out = vec![0.0; *vocab as usize];
                for &(t, p) in entries {
                    out[t as usize] = p;
                }
                out
            }
        }
    }

    /// Greedy argmax, ties toward the lower token id (the same contract as
    /// [`argmax_f32`]).
    pub fn argmax(&self) -> u32 {
        match self {
            LogitsView::OneHot { token, .. } => *token,
            LogitsView::TopK { entries, .. } => {
                let mut best = 0usize;
                for (i, e) in entries.iter().enumerate() {
                    if e.1 > entries[best].1 {
                        best = i;
                    }
                }
                entries[best].0
            }
            LogitsView::Dense(row) => {
                let mut best = 0usize;
                for (i, &p) in row.iter().enumerate() {
                    if p > row[best] {
                        best = i;
                    }
                }
                best as u32
            }
        }
    }

    /// Draw a token. Consumes exactly one uniform draw and returns exactly
    /// what `rng.categorical(&self.to_dense())` would have returned — the
    /// sparse walk reproduces the dense scan's partial sums bit-for-bit
    /// (skipped zero weights subtract nothing).
    pub fn sample(&self, rng: &mut Rng) -> u32 {
        match self {
            LogitsView::Dense(row) => rng.categorical(row) as u32,
            LogitsView::OneHot { token, vocab } => {
                sparse_categorical(&[(*token, 1.0)], *vocab as usize, rng)
            }
            LogitsView::TopK { entries, vocab } => {
                sparse_categorical(entries, *vocab as usize, rng)
            }
        }
    }
}

/// Sparse mirror of [`Rng::categorical`]'s dense scan: identical total
/// (float addition with the skipped zeros is exact), identical walk
/// (subtracting a zero weight can't flip the sign test), identical
/// edge-case behavior (an initial draw of exactly 0 stops at index 0; a
/// rounding-residue overshoot falls back to the last index, `vocab - 1`).
fn sparse_categorical(entries: &[(u32, f64)], vocab: usize, rng: &mut Rng) -> u32 {
    let total: f64 = entries.iter().map(|e| e.1).sum();
    assert!(total > 0.0, "categorical with non-positive total weight");
    let mut target = rng.f64() * total;
    if target <= 0.0 {
        // The dense scan checks after subtracting w[0] >= 0, so a zero
        // draw always lands on index 0.
        return 0;
    }
    for &(tok, w) in entries {
        target -= w;
        if target <= 0.0 {
            return tok;
        }
    }
    (vocab - 1) as u32
}

/// Convert logits to a probability distribution at the given temperature.
/// `temperature == 0` produces the greedy one-hot distribution.
pub fn softmax_with_temperature(logits: &[f32], temperature: f64) -> Vec<f64> {
    assert!(!logits.is_empty());
    if temperature <= 0.0 {
        let mut out = vec![0.0; logits.len()];
        out[argmax_f32(logits)] = 1.0;
        return out;
    }
    let inv_t = 1.0 / temperature;
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let mut out: Vec<f64> = logits
        .iter()
        .map(|&l| ((l as f64 - max) * inv_t).exp())
        .collect();
    let sum: f64 = out.iter().sum();
    for v in &mut out {
        *v /= sum;
    }
    out
}

/// Index of the largest logit, breaking ties toward the lower index
/// (deterministic greedy decoding).
pub fn argmax_f32(logits: &[f32]) -> usize {
    let mut best = 0;
    for (i, &l) in logits.iter().enumerate() {
        if l > logits[best] {
            best = i;
        }
    }
    best
}

/// Draw a token from a probability distribution.
pub fn sample(probs: &[f64], rng: &mut Rng) -> usize {
    rng.categorical(probs)
}

/// Keep only the top-k probabilities (renormalized); `k == 0` disables.
pub fn top_k_filter(probs: &[f64], k: usize) -> Vec<f64> {
    if k == 0 || k >= probs.len() {
        return probs.to_vec();
    }
    let mut idx: Vec<usize> = (0..probs.len()).collect();
    idx.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
    let keep: std::collections::HashSet<usize> = idx[..k].iter().copied().collect();
    let mut out: Vec<f64> = probs
        .iter()
        .enumerate()
        .map(|(i, &p)| if keep.contains(&i) { p } else { 0.0 })
        .collect();
    let sum: f64 = out.iter().sum();
    if sum > 0.0 {
        for v in &mut out {
            *v /= sum;
        }
    }
    out
}

/// Outcome of verifying one sequence's draft chain.
#[derive(Debug, Clone, PartialEq)]
pub struct VerifyOutcome {
    /// Tokens emitted this round: accepted draft prefix plus exactly one
    /// extra token (resample-on-reject or bonus-on-full-accept).
    pub tokens: Vec<u32>,
    /// How many of the γ draft tokens were accepted.
    pub accepted: usize,
}

/// Speculative rejection sampling over [`LogitsView`] rows — the engine's
/// hot-path entry point.
///
/// Semantics are exactly [`verify_chain`]'s (same acceptance rule, same
/// residual resampling, same bonus row), but sparse rows are consumed
/// without materializing vocab-sized vectors: the accept test reads two
/// scalars, and residual resampling walks only the target row's support
/// (wherever the target weight is 0 the residual `max(0, t − d)` is 0 as
/// well). Every branch consumes the same RNG draws as the dense
/// reference, so the emitted token stream is byte-identical for equal
/// distributions regardless of representation — the property the
/// equivalence tests in `rust/tests/prop_invariants.rs` pin down.
///
/// The chain length is per call, so **ragged rounds need no special
/// handling here**: the engine invokes this once per sequence with that
/// sequence's own γᵢ-length draft (`draft_tokens.len() == γᵢ`,
/// `target_probs.len() == γᵢ + 1`), in batch order, against one shared
/// RNG. Because every call consumes a deterministic draw count given its
/// outcome, the RNG stream stays in lockstep across ragged and uniform
/// batches alike (asserted by `ragged_batch_keeps_rng_lockstep` below).
pub fn verify_chain_views(
    draft_tokens: &[u32],
    draft_probs: &[LogitsView],
    target_probs: &[LogitsView],
    rng: &mut Rng,
) -> VerifyOutcome {
    let gamma = draft_tokens.len();
    assert_eq!(draft_probs.len(), gamma, "draft probs length mismatch");
    assert_eq!(
        target_probs.len(),
        gamma + 1,
        "target probs must include the bonus row"
    );
    let mut tokens = Vec::with_capacity(gamma + 1);
    for i in 0..gamma {
        let x = draft_tokens[i];
        let p_t = target_probs[i].prob(x);
        let p_d = draft_probs[i].prob(x);
        let accept_prob = if p_d <= 0.0 { 0.0 } else { (p_t / p_d).min(1.0) };
        if rng.f64() < accept_prob {
            tokens.push(x);
            continue;
        }
        tokens.push(sample_residual(&target_probs[i], &draft_probs[i], rng));
        return VerifyOutcome {
            accepted: i,
            tokens,
        };
    }
    tokens.push(target_probs[gamma].sample(rng));
    VerifyOutcome {
        accepted: gamma,
        tokens,
    }
}

/// Sample from `norm(max(0, target − draft))`, falling back to the target
/// row when the residual mass vanishes. RNG-draw-identical to the dense
/// reference path in [`verify_chain`]: the residual's support is a subset
/// of the target's support, and summing it in ascending-token order
/// reproduces the dense sum exactly (interleaved zero terms are exact
/// no-ops in IEEE addition).
fn sample_residual(target: &LogitsView, draft: &LogitsView, rng: &mut Rng) -> u32 {
    match target {
        LogitsView::Dense(t) => {
            let residual: Vec<f64> = t
                .iter()
                .enumerate()
                .map(|(v, &tp)| (tp - draft.prob(v as u32)).max(0.0))
                .collect();
            let sum: f64 = residual.iter().sum();
            if sum > 1e-300 {
                rng.categorical(&residual) as u32
            } else {
                rng.categorical(t) as u32
            }
        }
        LogitsView::OneHot { token, vocab } => {
            let r = (1.0 - draft.prob(*token)).max(0.0);
            if r > 1e-300 {
                sparse_categorical(&[(*token, r)], *vocab as usize, rng)
            } else {
                sparse_categorical(&[(*token, 1.0)], *vocab as usize, rng)
            }
        }
        LogitsView::TopK { entries, vocab } => {
            let residual: Vec<(u32, f64)> = entries
                .iter()
                .map(|&(t, tp)| (t, (tp - draft.prob(t)).max(0.0)))
                .collect();
            let sum: f64 = residual.iter().map(|e| e.1).sum();
            if sum > 1e-300 {
                sparse_categorical(&residual, *vocab as usize, rng)
            } else {
                sparse_categorical(entries, *vocab as usize, rng)
            }
        }
    }
}

/// Speculative rejection sampling over a draft chain (chain speculation,
/// the paper's setting) — the **dense reference** implementation.
///
/// The engine runs [`verify_chain_views`]; this function is kept as the
/// validated dense form of the published algorithm, consumed by the
/// equivalence property tests and the micro-bench baseline.
///
/// Inputs:
/// - `draft_tokens[i]`   — the i-th proposed token,
/// - `draft_probs[i]`    — the draft distribution it was sampled from,
/// - `target_probs[i]`   — the target distribution at the same position,
///   with one extra row at the end (`target_probs.len() == γ + 1`) for the
///   bonus token.
///
/// For each position: accept token x with probability
/// `min(1, p_target(x) / p_draft(x))`; on rejection, sample from
/// `norm(max(0, p_target − p_draft))` and stop. If every draft token is
/// accepted, sample the bonus token from the final target row.
///
/// Guarantees exactly one "fresh" target-distributed token per round, so
/// output length is `accepted + 1 ∈ [1, γ+1]`.
pub fn verify_chain(
    draft_tokens: &[u32],
    draft_probs: &[Vec<f64>],
    target_probs: &[Vec<f64>],
    rng: &mut Rng,
) -> VerifyOutcome {
    let gamma = draft_tokens.len();
    assert_eq!(draft_probs.len(), gamma, "draft probs length mismatch");
    assert_eq!(
        target_probs.len(),
        gamma + 1,
        "target probs must include the bonus row"
    );
    let mut tokens = Vec::with_capacity(gamma + 1);
    for i in 0..gamma {
        let x = draft_tokens[i] as usize;
        let p_t = target_probs[i][x];
        let p_d = draft_probs[i][x];
        let accept_prob = if p_d <= 0.0 {
            // The draft proposed a token it assigned zero probability —
            // only possible with inconsistent inputs; treat as reject.
            0.0
        } else {
            (p_t / p_d).min(1.0)
        };
        if rng.f64() < accept_prob {
            tokens.push(draft_tokens[i]);
            continue;
        }
        // Reject: resample from the residual distribution.
        let residual: Vec<f64> = target_probs[i]
            .iter()
            .zip(&draft_probs[i])
            .map(|(&t, &d)| (t - d).max(0.0))
            .collect();
        let sum: f64 = residual.iter().sum();
        let tok = if sum > 1e-300 {
            rng.categorical(&residual) as u32
        } else {
            // Distributions identical ⇒ residual empty; sample target.
            rng.categorical(&target_probs[i]) as u32
        };
        tokens.push(tok);
        return VerifyOutcome {
            accepted: i,
            tokens,
        };
    }
    // All γ accepted: bonus token from the last target row.
    tokens.push(rng.categorical(&target_probs[gamma]) as u32);
    VerifyOutcome {
        accepted: gamma,
        tokens,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::chi_square;

    #[test]
    fn softmax_basics() {
        let p = softmax_with_temperature(&[1.0, 2.0, 3.0], 1.0);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p[2] > p[1] && p[1] > p[0]);
        // Temperature 0 → one-hot at the argmax.
        let g = softmax_with_temperature(&[1.0, 5.0, 3.0], 0.0);
        assert_eq!(g, vec![0.0, 1.0, 0.0]);
        // High temperature flattens.
        let flat = softmax_with_temperature(&[1.0, 2.0, 3.0], 100.0);
        assert!(flat.iter().all(|&v| (v - 1.0 / 3.0).abs() < 0.01));
    }

    #[test]
    fn top_k_keeps_largest() {
        let p = vec![0.1, 0.4, 0.2, 0.3];
        let f = top_k_filter(&p, 2);
        assert_eq!(f[0], 0.0);
        assert_eq!(f[2], 0.0);
        assert!((f[1] + f[3] - 1.0).abs() < 1e-12);
        assert_eq!(top_k_filter(&p, 0), p);
    }

    #[test]
    fn verify_identical_distributions_accepts_everything() {
        let mut rng = Rng::seeded(1);
        let dist = vec![0.25; 4];
        let out = verify_chain(
            &[0, 1, 2],
            &vec![dist.clone(); 3],
            &vec![dist.clone(); 4],
            &mut rng,
        );
        assert_eq!(out.accepted, 3);
        assert_eq!(out.tokens.len(), 4);
        assert_eq!(&out.tokens[..3], &[0, 1, 2]);
    }

    #[test]
    fn verify_disjoint_distributions_rejects_immediately() {
        let mut rng = Rng::seeded(2);
        let draft = vec![vec![1.0, 0.0]];
        let target = vec![vec![0.0, 1.0], vec![0.0, 1.0]];
        let out = verify_chain(&[0], &draft, &target, &mut rng);
        assert_eq!(out.accepted, 0);
        assert_eq!(out.tokens, vec![1]); // residual forces token 1
    }

    #[test]
    fn output_length_always_accepted_plus_one() {
        let mut rng = Rng::seeded(3);
        for trial in 0..200u64 {
            let gamma = 1 + (trial % 4) as usize;
            let vocab = 8;
            let mk_dist = |seed: u64| -> Vec<f64> {
                let mut r = Rng::seeded(seed);
                let v: Vec<f64> = (0..vocab).map(|_| r.f64() + 0.01).collect();
                let s: f64 = v.iter().sum();
                v.into_iter().map(|x| x / s).collect()
            };
            let draft_probs: Vec<Vec<f64>> =
                (0..gamma).map(|i| mk_dist(trial * 10 + i as u64)).collect();
            let target_probs: Vec<Vec<f64>> = (0..=gamma)
                .map(|i| mk_dist(trial * 17 + i as u64 + 1000))
                .collect();
            let draft_tokens: Vec<u32> = draft_probs
                .iter()
                .map(|d| rng.categorical(d) as u32)
                .collect();
            let out = verify_chain(&draft_tokens, &draft_probs, &target_probs, &mut rng);
            assert_eq!(out.tokens.len(), out.accepted + 1);
            assert!(out.accepted <= gamma);
        }
    }

    /// The losslessness property (Leviathan Thm. 1): the marginal of the
    /// first emitted token equals the target distribution, regardless of
    /// the draft distribution.
    #[test]
    fn first_token_is_target_distributed() {
        let mut rng = Rng::seeded(4);
        let target = vec![0.5, 0.3, 0.15, 0.05];
        let draft = vec![0.1, 0.2, 0.3, 0.4]; // deliberately very different
        let n = 200_000;
        let mut counts = vec![0.0; 4];
        for _ in 0..n {
            let d_tok = rng.categorical(&draft) as u32;
            let out = verify_chain(
                &[d_tok],
                &[draft.clone()],
                &[target.clone(), target.clone()],
                &mut rng,
            );
            counts[out.tokens[0] as usize] += 1.0;
        }
        let expected: Vec<f64> = target.iter().map(|p| p * n as f64).collect();
        let chi2 = chi_square(&counts, &expected);
        // 3 dof, p=0.001 critical value ≈ 16.27.
        assert!(chi2 < 16.27, "χ²={chi2}, counts={counts:?}");
    }

    /// Acceptance rate for identical-support distributions equals
    /// Σ min(p_t, p_d) (the standard SD acceptance formula).
    #[test]
    fn acceptance_rate_matches_overlap() {
        let mut rng = Rng::seeded(5);
        let target: Vec<f64> = vec![0.6, 0.3, 0.1];
        let draft: Vec<f64> = vec![0.3, 0.5, 0.2];
        let overlap: f64 = target.iter().zip(&draft).map(|(&t, &d)| t.min(d)).sum();
        let n = 100_000;
        let mut accepted = 0;
        for _ in 0..n {
            let d_tok = rng.categorical(&draft) as u32;
            let out = verify_chain(
                &[d_tok],
                &[draft.clone()],
                &[target.clone(), target.clone()],
                &mut rng,
            );
            accepted += out.accepted;
        }
        let rate = accepted as f64 / n as f64;
        assert!(
            (rate - overlap).abs() < 0.01,
            "rate={rate} overlap={overlap}"
        );
    }

    #[test]
    fn logits_view_prob_and_dense_roundtrip() {
        let oh = LogitsView::one_hot(3, 8);
        assert_eq!(oh.vocab(), 8);
        assert_eq!(oh.prob(3), 1.0);
        assert_eq!(oh.prob(2), 0.0);
        assert_eq!(oh.argmax(), 3);
        assert_eq!(oh.to_dense(), vec![0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0]);

        let tk = LogitsView::top_k(vec![(5, 0.25), (1, 0.75)], 8);
        assert_eq!(tk.prob(1), 0.75);
        assert_eq!(tk.prob(5), 0.25);
        assert_eq!(tk.prob(0), 0.0);
        assert_eq!(tk.argmax(), 1);
        let dense = tk.to_dense();
        assert_eq!(dense[1], 0.75);
        assert_eq!(dense[5], 0.25);
        assert_eq!(dense.iter().sum::<f64>(), 1.0);

        let dv = LogitsView::dense(vec![0.2, 0.5, 0.3]);
        assert_eq!(dv.vocab(), 3);
        assert_eq!(dv.prob(1), 0.5);
        assert_eq!(dv.argmax(), 1);
    }

    /// `LogitsView::sample` must be bit-identical to `Rng::categorical`
    /// over the dense expansion — same draws, same tokens.
    #[test]
    fn view_sampling_matches_dense_categorical() {
        let views = vec![
            LogitsView::one_hot(7, 32),
            LogitsView::one_hot(0, 32),
            LogitsView::top_k(vec![(2, 0.5), (9, 0.3), (31, 0.2)], 32),
            LogitsView::top_k(vec![(0, 1.0)], 32),
            LogitsView::dense((0..32).map(|i| 1.0 / (1.0 + i as f64)).collect()),
        ];
        for (vi, view) in views.iter().enumerate() {
            let dense = view.to_dense();
            let mut ra = Rng::seeded(100 + vi as u64);
            let mut rb = Rng::seeded(100 + vi as u64);
            for _ in 0..2000 {
                assert_eq!(view.sample(&mut ra), rb.categorical(&dense) as u32);
            }
            // RNG streams stayed in lockstep (same number of draws).
            assert_eq!(ra.next_u64(), rb.next_u64());
        }
    }

    /// One-hot views through `verify_chain_views` reproduce the greedy
    /// accept-iff-match behavior of the dense path.
    #[test]
    fn greedy_one_hot_views_accept_iff_match() {
        let mut rng = Rng::seeded(6);
        let oh = |i: u32| LogitsView::one_hot(i, 4);
        let out = verify_chain_views(&[2], &[oh(2)], &[oh(2), oh(1)], &mut rng);
        assert_eq!(out.tokens, vec![2, 1]);
        assert_eq!(out.accepted, 1);
        let out = verify_chain_views(&[2], &[oh(2)], &[oh(3), oh(0)], &mut rng);
        assert_eq!(out.tokens, vec![3]);
        assert_eq!(out.accepted, 0);
    }

    /// Dense-wrapped views are literally the dense path: identical token
    /// streams for identical seeds across random distributions.
    #[test]
    fn dense_views_match_dense_reference() {
        let mut gen = Rng::seeded(44);
        for trial in 0..100u64 {
            let gamma = (trial % 5) as usize;
            let vocab = 16;
            let mk = |r: &mut Rng| -> Vec<f64> {
                let v: Vec<f64> = (0..vocab).map(|_| r.f64() + 0.01).collect();
                let s: f64 = v.iter().sum();
                v.into_iter().map(|x| x / s).collect()
            };
            let draft: Vec<Vec<f64>> = (0..gamma).map(|_| mk(&mut gen)).collect();
            let target: Vec<Vec<f64>> = (0..=gamma).map(|_| mk(&mut gen)).collect();
            let toks: Vec<u32> = draft.iter().map(|d| gen.categorical(d) as u32).collect();
            let dviews: Vec<LogitsView> = draft.iter().cloned().map(LogitsView::dense).collect();
            let tviews: Vec<LogitsView> = target.iter().cloned().map(LogitsView::dense).collect();
            let mut ra = Rng::seeded(7000 + trial);
            let mut rb = Rng::seeded(7000 + trial);
            let a = verify_chain_views(&toks, &dviews, &tviews, &mut ra);
            let b = verify_chain(&toks, &draft, &target, &mut rb);
            assert_eq!(a, b, "trial {trial}");
            assert_eq!(ra.next_u64(), rb.next_u64(), "rng divergence, trial {trial}");
        }
    }

    /// A ragged batch (per-sequence γᵢ) walked sequence-by-sequence against
    /// one RNG consumes exactly the same draws as verifying each sequence
    /// alone with its own RNG stream — the lockstep property the ragged
    /// engine rounds rely on.
    #[test]
    fn ragged_batch_keeps_rng_lockstep() {
        let vocab = 16;
        let gammas = [4usize, 0, 2, 7, 1];
        let mut gen = Rng::seeded(91);
        let mk = |r: &mut Rng| -> Vec<f64> {
            let v: Vec<f64> = (0..vocab).map(|_| r.f64() + 0.01).collect();
            let s: f64 = v.iter().sum();
            v.into_iter().map(|x| x / s).collect()
        };
        // Build one ragged batch of (draft tokens, draft rows, target rows).
        let batch: Vec<(Vec<u32>, Vec<LogitsView>, Vec<LogitsView>)> = gammas
            .iter()
            .map(|&g| {
                let draft: Vec<Vec<f64>> = (0..g).map(|_| mk(&mut gen)).collect();
                let target: Vec<Vec<f64>> = (0..=g).map(|_| mk(&mut gen)).collect();
                let toks: Vec<u32> = draft.iter().map(|d| gen.categorical(d) as u32).collect();
                (
                    toks,
                    draft.into_iter().map(LogitsView::dense).collect(),
                    target.into_iter().map(LogitsView::dense).collect(),
                )
            })
            .collect();
        // Walk the whole ragged batch against one RNG...
        let mut shared = Rng::seeded(4242);
        let walked: Vec<VerifyOutcome> = batch
            .iter()
            .map(|(t, d, tp)| verify_chain_views(t, d, tp, &mut shared))
            .collect();
        // ...and replay each sequence alone, advancing a twin RNG by the
        // draws the previous sequences consumed. Outcomes must agree and
        // the twin must end in lockstep with the shared stream.
        let mut twin = Rng::seeded(4242);
        for ((t, d, tp), want) in batch.iter().zip(&walked) {
            let got = verify_chain_views(t, d, tp, &mut twin);
            assert_eq!(&got, want);
        }
        assert_eq!(shared.next_u64(), twin.next_u64(), "rng streams diverged");
        // Output-shape sanity on the ragged outcomes.
        for (g, out) in gammas.iter().zip(&walked) {
            assert!(out.accepted <= *g);
            assert_eq!(out.tokens.len(), out.accepted + 1);
        }
    }

    #[test]
    fn greedy_one_hot_accepts_iff_match() {
        let mut rng = Rng::seeded(6);
        let one_hot = |i: usize, v: usize| -> Vec<f64> {
            let mut p = vec![0.0; v];
            p[i] = 1.0;
            p
        };
        // Draft proposes token 2, target wants token 2 → accept + bonus.
        let out = verify_chain(
            &[2],
            &[one_hot(2, 4)],
            &[one_hot(2, 4), one_hot(1, 4)],
            &mut rng,
        );
        assert_eq!(out.tokens, vec![2, 1]);
        // Target wants token 3 → reject, emit 3.
        let out = verify_chain(
            &[2],
            &[one_hot(2, 4)],
            &[one_hot(3, 4), one_hot(0, 4)],
            &mut rng,
        );
        assert_eq!(out.tokens, vec![3]);
        assert_eq!(out.accepted, 0);
    }
}
