//! Token sampling and the speculative-decoding rejection sampler
//! (§3.1 stage ③; Leviathan et al. 2023, Chen et al. 2023).
//!
//! The rejection sampler is the losslessness-critical piece: accepted
//! tokens must be distributed exactly as if the target model had sampled
//! them autoregressively. `verify_chain` implements the published
//! algorithm; the χ²-based distribution test in this module's tests and
//! `rust/tests/prop_invariants.rs` guard it.

use crate::util::rng::Rng;

/// Convert logits to a probability distribution at the given temperature.
/// `temperature == 0` produces the greedy one-hot distribution.
pub fn softmax_with_temperature(logits: &[f32], temperature: f64) -> Vec<f64> {
    assert!(!logits.is_empty());
    if temperature <= 0.0 {
        let mut out = vec![0.0; logits.len()];
        out[argmax_f32(logits)] = 1.0;
        return out;
    }
    let inv_t = 1.0 / temperature;
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let mut out: Vec<f64> = logits
        .iter()
        .map(|&l| ((l as f64 - max) * inv_t).exp())
        .collect();
    let sum: f64 = out.iter().sum();
    for v in &mut out {
        *v /= sum;
    }
    out
}

/// Index of the largest logit, breaking ties toward the lower index
/// (deterministic greedy decoding).
pub fn argmax_f32(logits: &[f32]) -> usize {
    let mut best = 0;
    for (i, &l) in logits.iter().enumerate() {
        if l > logits[best] {
            best = i;
        }
    }
    best
}

/// Draw a token from a probability distribution.
pub fn sample(probs: &[f64], rng: &mut Rng) -> usize {
    rng.categorical(probs)
}

/// Keep only the top-k probabilities (renormalized); `k == 0` disables.
pub fn top_k_filter(probs: &[f64], k: usize) -> Vec<f64> {
    if k == 0 || k >= probs.len() {
        return probs.to_vec();
    }
    let mut idx: Vec<usize> = (0..probs.len()).collect();
    idx.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
    let keep: std::collections::HashSet<usize> = idx[..k].iter().copied().collect();
    let mut out: Vec<f64> = probs
        .iter()
        .enumerate()
        .map(|(i, &p)| if keep.contains(&i) { p } else { 0.0 })
        .collect();
    let sum: f64 = out.iter().sum();
    if sum > 0.0 {
        for v in &mut out {
            *v /= sum;
        }
    }
    out
}

/// Outcome of verifying one sequence's draft chain.
#[derive(Debug, Clone, PartialEq)]
pub struct VerifyOutcome {
    /// Tokens emitted this round: accepted draft prefix plus exactly one
    /// extra token (resample-on-reject or bonus-on-full-accept).
    pub tokens: Vec<u32>,
    /// How many of the γ draft tokens were accepted.
    pub accepted: usize,
}

/// Speculative rejection sampling over a draft chain (chain speculation,
/// the paper's setting).
///
/// Inputs:
/// - `draft_tokens[i]`   — the i-th proposed token,
/// - `draft_probs[i]`    — the draft distribution it was sampled from,
/// - `target_probs[i]`   — the target distribution at the same position,
///   with one extra row at the end (`target_probs.len() == γ + 1`) for the
///   bonus token.
///
/// For each position: accept token x with probability
/// `min(1, p_target(x) / p_draft(x))`; on rejection, sample from
/// `norm(max(0, p_target − p_draft))` and stop. If every draft token is
/// accepted, sample the bonus token from the final target row.
///
/// Guarantees exactly one "fresh" target-distributed token per round, so
/// output length is `accepted + 1 ∈ [1, γ+1]`.
pub fn verify_chain(
    draft_tokens: &[u32],
    draft_probs: &[Vec<f64>],
    target_probs: &[Vec<f64>],
    rng: &mut Rng,
) -> VerifyOutcome {
    let gamma = draft_tokens.len();
    assert_eq!(draft_probs.len(), gamma, "draft probs length mismatch");
    assert_eq!(
        target_probs.len(),
        gamma + 1,
        "target probs must include the bonus row"
    );
    let mut tokens = Vec::with_capacity(gamma + 1);
    for i in 0..gamma {
        let x = draft_tokens[i] as usize;
        let p_t = target_probs[i][x];
        let p_d = draft_probs[i][x];
        let accept_prob = if p_d <= 0.0 {
            // The draft proposed a token it assigned zero probability —
            // only possible with inconsistent inputs; treat as reject.
            0.0
        } else {
            (p_t / p_d).min(1.0)
        };
        if rng.f64() < accept_prob {
            tokens.push(draft_tokens[i]);
            continue;
        }
        // Reject: resample from the residual distribution.
        let residual: Vec<f64> = target_probs[i]
            .iter()
            .zip(&draft_probs[i])
            .map(|(&t, &d)| (t - d).max(0.0))
            .collect();
        let sum: f64 = residual.iter().sum();
        let tok = if sum > 1e-300 {
            rng.categorical(&residual) as u32
        } else {
            // Distributions identical ⇒ residual empty; sample target.
            rng.categorical(&target_probs[i]) as u32
        };
        tokens.push(tok);
        return VerifyOutcome {
            accepted: i,
            tokens,
        };
    }
    // All γ accepted: bonus token from the last target row.
    tokens.push(rng.categorical(&target_probs[gamma]) as u32);
    VerifyOutcome {
        accepted: gamma,
        tokens,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::chi_square;

    #[test]
    fn softmax_basics() {
        let p = softmax_with_temperature(&[1.0, 2.0, 3.0], 1.0);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p[2] > p[1] && p[1] > p[0]);
        // Temperature 0 → one-hot at the argmax.
        let g = softmax_with_temperature(&[1.0, 5.0, 3.0], 0.0);
        assert_eq!(g, vec![0.0, 1.0, 0.0]);
        // High temperature flattens.
        let flat = softmax_with_temperature(&[1.0, 2.0, 3.0], 100.0);
        assert!(flat.iter().all(|&v| (v - 1.0 / 3.0).abs() < 0.01));
    }

    #[test]
    fn top_k_keeps_largest() {
        let p = vec![0.1, 0.4, 0.2, 0.3];
        let f = top_k_filter(&p, 2);
        assert_eq!(f[0], 0.0);
        assert_eq!(f[2], 0.0);
        assert!((f[1] + f[3] - 1.0).abs() < 1e-12);
        assert_eq!(top_k_filter(&p, 0), p);
    }

    #[test]
    fn verify_identical_distributions_accepts_everything() {
        let mut rng = Rng::seeded(1);
        let dist = vec![0.25; 4];
        let out = verify_chain(
            &[0, 1, 2],
            &vec![dist.clone(); 3],
            &vec![dist.clone(); 4],
            &mut rng,
        );
        assert_eq!(out.accepted, 3);
        assert_eq!(out.tokens.len(), 4);
        assert_eq!(&out.tokens[..3], &[0, 1, 2]);
    }

    #[test]
    fn verify_disjoint_distributions_rejects_immediately() {
        let mut rng = Rng::seeded(2);
        let draft = vec![vec![1.0, 0.0]];
        let target = vec![vec![0.0, 1.0], vec![0.0, 1.0]];
        let out = verify_chain(&[0], &draft, &target, &mut rng);
        assert_eq!(out.accepted, 0);
        assert_eq!(out.tokens, vec![1]); // residual forces token 1
    }

    #[test]
    fn output_length_always_accepted_plus_one() {
        let mut rng = Rng::seeded(3);
        for trial in 0..200u64 {
            let gamma = 1 + (trial % 4) as usize;
            let vocab = 8;
            let mk_dist = |seed: u64| -> Vec<f64> {
                let mut r = Rng::seeded(seed);
                let v: Vec<f64> = (0..vocab).map(|_| r.f64() + 0.01).collect();
                let s: f64 = v.iter().sum();
                v.into_iter().map(|x| x / s).collect()
            };
            let draft_probs: Vec<Vec<f64>> =
                (0..gamma).map(|i| mk_dist(trial * 10 + i as u64)).collect();
            let target_probs: Vec<Vec<f64>> = (0..=gamma)
                .map(|i| mk_dist(trial * 17 + i as u64 + 1000))
                .collect();
            let draft_tokens: Vec<u32> = draft_probs
                .iter()
                .map(|d| rng.categorical(d) as u32)
                .collect();
            let out = verify_chain(&draft_tokens, &draft_probs, &target_probs, &mut rng);
            assert_eq!(out.tokens.len(), out.accepted + 1);
            assert!(out.accepted <= gamma);
        }
    }

    /// The losslessness property (Leviathan Thm. 1): the marginal of the
    /// first emitted token equals the target distribution, regardless of
    /// the draft distribution.
    #[test]
    fn first_token_is_target_distributed() {
        let mut rng = Rng::seeded(4);
        let target = vec![0.5, 0.3, 0.15, 0.05];
        let draft = vec![0.1, 0.2, 0.3, 0.4]; // deliberately very different
        let n = 200_000;
        let mut counts = vec![0.0; 4];
        for _ in 0..n {
            let d_tok = rng.categorical(&draft) as u32;
            let out = verify_chain(
                &[d_tok],
                &[draft.clone()],
                &[target.clone(), target.clone()],
                &mut rng,
            );
            counts[out.tokens[0] as usize] += 1.0;
        }
        let expected: Vec<f64> = target.iter().map(|p| p * n as f64).collect();
        let chi2 = chi_square(&counts, &expected);
        // 3 dof, p=0.001 critical value ≈ 16.27.
        assert!(chi2 < 16.27, "χ²={chi2}, counts={counts:?}");
    }

    /// Acceptance rate for identical-support distributions equals
    /// Σ min(p_t, p_d) (the standard SD acceptance formula).
    #[test]
    fn acceptance_rate_matches_overlap() {
        let mut rng = Rng::seeded(5);
        let target: Vec<f64> = vec![0.6, 0.3, 0.1];
        let draft: Vec<f64> = vec![0.3, 0.5, 0.2];
        let overlap: f64 = target.iter().zip(&draft).map(|(&t, &d)| t.min(d)).sum();
        let n = 100_000;
        let mut accepted = 0;
        for _ in 0..n {
            let d_tok = rng.categorical(&draft) as u32;
            let out = verify_chain(
                &[d_tok],
                &[draft.clone()],
                &[target.clone(), target.clone()],
                &mut rng,
            );
            accepted += out.accepted;
        }
        let rate = accepted as f64 / n as f64;
        assert!(
            (rate - overlap).abs() < 0.01,
            "rate={rate} overlap={overlap}"
        );
    }

    #[test]
    fn greedy_one_hot_accepts_iff_match() {
        let mut rng = Rng::seeded(6);
        let one_hot = |i: usize, v: usize| -> Vec<f64> {
            let mut p = vec![0.0; v];
            p[i] = 1.0;
            p
        };
        // Draft proposes token 2, target wants token 2 → accept + bonus.
        let out = verify_chain(
            &[2],
            &[one_hot(2, 4)],
            &[one_hot(2, 4), one_hot(1, 4)],
            &mut rng,
        );
        assert_eq!(out.tokens, vec![2, 1]);
        // Target wants token 3 → reject, emit 3.
        let out = verify_chain(
            &[2],
            &[one_hot(2, 4)],
            &[one_hot(3, 4), one_hot(0, 4)],
            &mut rng,
        );
        assert_eq!(out.tokens, vec![3]);
        assert_eq!(out.accepted, 0);
    }
}
