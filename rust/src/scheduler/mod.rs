//! Admission scheduling: decides which waiting requests join the running
//! batch, respecting (a) the configured batch ceiling, (b) KV-cache
//! capacity with a per-sequence growth reservation, and (c) an optional
//! TPOT-derived batch cap (the §3.4 latency-SLO scenario where "large
//! batch sizes are often not feasible").

use crate::batching::{Request, RequestQueue};
use crate::kvcache::KvManager;

/// Scheduler policy knobs.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Hard ceiling on concurrently running sequences.
    pub max_batch: usize,
    /// Tokens reserved per admitted sequence beyond the prompt, so decode
    /// progress can't immediately deadlock on capacity (preemption still
    /// covers the tail case).
    pub admit_reserve_tokens: usize,
    /// If set, keep the running batch at or below the largest size whose
    /// estimated TPOT meets this bound (seconds/token).
    pub tpot_slo: Option<f64>,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 64,
            admit_reserve_tokens: 16,
            tpot_slo: None,
        }
    }
}

/// The admission scheduler (stateless policy over queue + cache state).
#[derive(Debug, Clone)]
pub struct Scheduler {
    pub config: SchedulerConfig,
}

impl Scheduler {
    pub fn new(config: SchedulerConfig) -> Scheduler {
        Scheduler { config }
    }

    /// Effective batch ceiling given the SLO estimator: `est_tpot(b)`
    /// returns estimated seconds/token at batch size b.
    ///
    /// Contract (also exercised by the edge-case tests below):
    /// - the result is always ≤ `max_batch`, and `max_batch == 0` returns
    ///   0 (admissions fully paused);
    /// - with an SLO, the largest `b` with `est_tpot(b) <= slo` wins;
    /// - if **no** batch size meets the SLO — the SLO is simply
    ///   infeasible on this hardware — the ceiling degrades to 1 rather
    ///   than 0: the system keeps draining at minimum batch (and maximum
    ///   per-request speed) instead of deadlocking with queued work. An
    ///   infeasible SLO is an operator error we make progress under, not
    ///   a reason to stop serving.
    pub fn batch_ceiling<F: Fn(usize) -> f64>(&self, est_tpot: F) -> usize {
        if self.config.max_batch == 0 {
            return 0;
        }
        match self.config.tpot_slo {
            None => self.config.max_batch,
            Some(slo) => {
                let mut best = 1;
                for b in 1..=self.config.max_batch {
                    if est_tpot(b) <= slo {
                        best = b;
                    }
                }
                best
            }
        }
    }

    /// Pull admissible requests off the queue. FIFO order; stops at the
    /// first request that doesn't fit (no head-of-line bypass — keeps
    /// latency fairness, same default as vLLM). Requests with
    /// `arrival > now` are not admitted (the queue is arrival-sorted).
    pub fn admit(
        &self,
        queue: &mut RequestQueue,
        kv: &KvManager,
        running: usize,
        ceiling: usize,
        now: f64,
    ) -> Vec<Request> {
        let mut admitted = Vec::new();
        let mut virtual_free = kv.free_blocks();
        let bs = kv.config().block_size;
        while running + admitted.len() < ceiling.min(self.config.max_batch) {
            let Some(head) = queue.peek() else { break };
            if head.arrival > now {
                break;
            }
            let need_tokens = head.prompt.len() + self.config.admit_reserve_tokens;
            let need_blocks = need_tokens.div_ceil(bs);
            if need_blocks > virtual_free {
                break;
            }
            virtual_free -= need_blocks;
            admitted.push(queue.pop().unwrap());
        }
        admitted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::SamplingParams;
    use crate::kvcache::{KvConfig, KvManager};

    fn req(id: u64, prompt_len: usize) -> Request {
        Request {
            id,
            prompt: vec![1; prompt_len],
            params: SamplingParams::default(),
            arrival: 0.0,
        }
    }

    fn kv(blocks: usize) -> KvManager {
        KvManager::new(KvConfig {
            num_blocks: blocks,
            block_size: 16,
        })
    }

    #[test]
    fn admits_up_to_batch_ceiling() {
        let s = Scheduler::new(SchedulerConfig {
            max_batch: 2,
            admit_reserve_tokens: 0,
            tpot_slo: None,
        });
        let mut q = RequestQueue::new();
        for i in 0..5 {
            q.push(req(i, 8));
        }
        let admitted = s.admit(&mut q, &kv(100), 0, usize::MAX, 0.0);
        assert_eq!(admitted.len(), 2);
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn respects_kv_capacity_with_reservation() {
        let s = Scheduler::new(SchedulerConfig {
            max_batch: 64,
            admit_reserve_tokens: 16,
            tpot_slo: None,
        });
        let mut q = RequestQueue::new();
        // Each request: 16-token prompt + 16 reserve = 2 blocks; 3 blocks
        // total → only one admission.
        q.push(req(1, 16));
        q.push(req(2, 16));
        let admitted = s.admit(&mut q, &kv(3), 0, usize::MAX, 0.0);
        assert_eq!(admitted.len(), 1);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn fifo_no_bypass() {
        let s = Scheduler::new(SchedulerConfig {
            max_batch: 8,
            admit_reserve_tokens: 0,
            tpot_slo: None,
        });
        let mut q = RequestQueue::new();
        q.push(req(1, 1000)); // cannot fit in 4 blocks of 16
        q.push(req(2, 4)); // would fit, but must not bypass
        let admitted = s.admit(&mut q, &kv(4), 0, usize::MAX, 0.0);
        assert!(admitted.is_empty());
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn slo_caps_batch() {
        let s = Scheduler::new(SchedulerConfig {
            max_batch: 64,
            admit_reserve_tokens: 0,
            tpot_slo: Some(0.05),
        });
        // TPOT grows linearly: 0.01·b seconds/token → ceiling 5.
        let ceil = s.batch_ceiling(|b| 0.01 * b as f64);
        assert_eq!(ceil, 5);
        // No SLO → max batch.
        let s2 = Scheduler::new(SchedulerConfig::default());
        assert_eq!(s2.batch_ceiling(|_| 1.0), 64);
    }

    #[test]
    fn batch_ceiling_max_batch_zero_pauses_admissions() {
        for slo in [None, Some(0.05)] {
            let s = Scheduler::new(SchedulerConfig {
                max_batch: 0,
                admit_reserve_tokens: 0,
                tpot_slo: slo,
            });
            assert_eq!(s.batch_ceiling(|_| 0.0), 0, "slo={slo:?}");
            // And admit() honors the zero ceiling.
            let mut q = RequestQueue::new();
            q.push(req(1, 4));
            assert!(s.admit(&mut q, &kv(100), 0, 0, 0.0).is_empty());
        }
    }

    #[test]
    fn batch_ceiling_max_batch_one() {
        let s = Scheduler::new(SchedulerConfig {
            max_batch: 1,
            admit_reserve_tokens: 0,
            tpot_slo: Some(0.05),
        });
        // b=1 meets the SLO → ceiling 1; and that is also the maximum.
        assert_eq!(s.batch_ceiling(|b| 0.01 * b as f64), 1);
        // b=1 misses the SLO → still 1 (degraded-SLO floor, documented).
        assert_eq!(s.batch_ceiling(|_| 1.0), 1);
    }

    #[test]
    fn infeasible_slo_degrades_to_batch_one_not_zero() {
        let s = Scheduler::new(SchedulerConfig {
            max_batch: 64,
            admit_reserve_tokens: 0,
            tpot_slo: Some(1e-9), // no hardware meets this
        });
        assert_eq!(s.batch_ceiling(|b| 0.01 * b as f64), 1);
    }

    #[test]
    fn running_counts_against_ceiling() {
        let s = Scheduler::new(SchedulerConfig {
            max_batch: 4,
            admit_reserve_tokens: 0,
            tpot_slo: None,
        });
        let mut q = RequestQueue::new();
        for i in 0..4 {
            q.push(req(i, 4));
        }
        let admitted = s.admit(&mut q, &kv(100), 3, usize::MAX, 0.0);
        assert_eq!(admitted.len(), 1);
    }
}
