//! Admission scheduling: decides which waiting requests join the running
//! batch, respecting (a) the configured batch ceiling, (b) KV-cache
//! capacity with a per-sequence growth reservation, and (c) an optional
//! TPOT-derived batch cap (the §3.4 latency-SLO scenario where "large
//! batch sizes are often not feasible").
//!
//! Admission is a pluggable [`AdmissionPolicy`]:
//!
//! - [`FifoAdmission`] — the original stateless FIFO loop, kept
//!   **bit-compatible** with the pre-multi-tenant scheduler (the default;
//!   property-tested against [`ClassAwareAdmission`] with one class in
//!   `rust/tests/prop_scheduler.rs`).
//! - [`ClassAwareAdmission`] — multi-tenant SLO-class admission: per-class
//!   logical FIFO queues over the shared arrival-ordered
//!   [`RequestQueue`], strict priority tiers with starvation aging,
//!   deficit-weighted fairness within a tier, per-class running ceilings,
//!   and (optionally) **mix-aware** admission that consults a
//!   [`RegimeOracle`] — the control plane's measured-cost-anchored Eq. 4
//!   pricing — to keep the running batch inside the speculative regime:
//!   candidates are chosen to balance easy/hard α mixes (the PR-4 ragged
//!   sweep's "admit mixes deliberately" finding) and admission pauses
//!   when the priced post-admission speedup would fall below the floor.

use crate::batching::{ClassId, Request, RequestQueue};
use crate::kvcache::KvManager;
use crate::workload::TenantClass;

/// Scheduler policy knobs.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Hard ceiling on concurrently running sequences.
    pub max_batch: usize,
    /// Tokens reserved per admitted sequence beyond the prompt, so decode
    /// progress can't immediately deadlock on capacity (preemption still
    /// covers the tail case).
    pub admit_reserve_tokens: usize,
    /// If set, keep the running batch at or below the largest size whose
    /// estimated TPOT meets this bound (seconds/token).
    pub tpot_slo: Option<f64>,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 64,
            admit_reserve_tokens: 16,
            tpot_slo: None,
        }
    }
}

/// Plain-data admission policy selection, so
/// [`crate::engine::EngineConfig`] stays `Clone + Debug + Send`.
#[derive(Debug, Clone, Default)]
pub enum AdmissionPolicyConfig {
    /// The pre-multi-tenant FIFO loop (bit-compatible baseline).
    #[default]
    Fifo,
    /// Multi-tenant SLO-class admission.
    ClassAware(ClassAwareConfig),
}

/// Knobs of [`ClassAwareAdmission`].
#[derive(Debug, Clone)]
pub struct ClassAwareConfig {
    /// Starvation aging: every `aging_tau` seconds a queued request waits
    /// promotes it by one priority tier, so low-priority classes are
    /// delayed by bursts, never starved (`f64::INFINITY` disables).
    pub aging_tau: f64,
    /// Mix-aware regime test: with `Some(floor)` and a [`RegimeOracle`]
    /// in the [`AdmissionContext`], candidates are picked to maximize the
    /// priced post-admission speedup and admission pauses once even the
    /// best choice would drop it below `floor`. `None` = α-blind.
    pub mix_speedup_floor: Option<f64>,
    /// SLO guard on the mix hold-back: a class head that has waited
    /// longer than this (seconds) is admitted regardless of the regime
    /// test — latency promises outrank throughput shaping.
    pub mix_hold_max: f64,
    /// The regime test never holds the running batch below this size
    /// (an idle engine must always start serving).
    pub min_batch: usize,
    /// Preemptive eviction on admission pressure: when admission comes
    /// back empty while a strictly-higher-priority request is waiting
    /// (arrival due), the engine preempts one running sequence from the
    /// lowest priority tier (least generated progress first) and retries
    /// admission once — so a high-priority arrival is not stuck behind a
    /// full batch of low-priority work until natural completion. Inert
    /// in one-class deployments (no strictly-lower victim exists), which
    /// preserves the class-aware ≡ FIFO degeneracy.
    pub preempt_on_admission: bool,
}

impl Default for ClassAwareConfig {
    fn default() -> Self {
        ClassAwareConfig {
            aging_tau: 30.0,
            mix_speedup_floor: None,
            mix_hold_max: 10.0,
            min_batch: 1,
            preempt_on_admission: false,
        }
    }
}

impl ClassAwareConfig {
    /// Mix-aware variant: regime-test admissions at the given speedup
    /// floor (1.0 = pause admission once speculation stops paying).
    pub fn mix_aware(floor: f64) -> ClassAwareConfig {
        ClassAwareConfig {
            mix_speedup_floor: Some(floor),
            ..ClassAwareConfig::default()
        }
    }
}

/// What the admission policy may ask the control plane: the priced
/// speculative-regime test (measured cost table re-anchoring the Eq. 4
/// model — see `SpecController::predicted_speedup`). Implemented by
/// [`crate::control::SpecController`]; a trait here so the scheduler
/// layer stays consumable without the control plane.
pub trait RegimeOracle {
    /// Predicted best-γ speedup versus AR at `batch` with acceptance mix
    /// `alpha` (`None` = caller has no estimate; the oracle falls back to
    /// its own α̂/prior). 1.0 means speculation is not profitable.
    fn predicted_speedup(&self, batch: usize, alpha: Option<f64>) -> f64;
}

/// One running sequence, as admission sees it.
#[derive(Debug, Clone, Copy)]
pub struct RunningInfo {
    pub class: ClassId,
    /// Windowed per-sequence α̂ᵢ from the control plane, when tracked.
    pub alpha: Option<f64>,
}

/// Everything an [`AdmissionPolicy`] may consult, borrowed from the
/// engine for the duration of one admission call.
pub struct AdmissionContext<'a> {
    pub kv: &'a KvManager,
    /// The running batch (class + per-sequence α̂ᵢ where known).
    pub running: &'a [RunningInfo],
    /// Global batch ceiling for this round (already SLO/controller
    /// derived; policies must also respect `config.max_batch`).
    pub ceiling: usize,
    /// Engine clock; requests with `arrival > now` are not admissible.
    pub now: f64,
    /// Tenant table (`ClassId` indexes it; empty = classless deployment,
    /// every class treated as neutral defaults).
    pub tenants: &'a [TenantClass],
    /// Per-class batch ceilings (same indexing), when the control plane
    /// priced them from per-class TPOT SLOs.
    pub class_ceilings: Option<&'a [usize]>,
    /// The control plane's priced regime test (mix-aware admission).
    pub oracle: Option<&'a dyn RegimeOracle>,
}

impl<'a> AdmissionContext<'a> {
    /// A minimal context for classless callers (compat path).
    pub fn simple(
        kv: &'a KvManager,
        running: &'a [RunningInfo],
        ceiling: usize,
        now: f64,
    ) -> AdmissionContext<'a> {
        AdmissionContext {
            kv,
            running,
            ceiling,
            now,
            tenants: &[],
            class_ceilings: None,
            oracle: None,
        }
    }
}

/// An admission policy: pulls admissible requests off the shared queue.
pub trait AdmissionPolicy: Send {
    fn name(&self) -> &'static str;
    /// Select and remove requests to admit this round. Must respect the
    /// context's ceiling, `config.max_batch`, and KV capacity with the
    /// configured reservation.
    fn admit(
        &mut self,
        config: &SchedulerConfig,
        queue: &mut RequestQueue,
        ctx: &AdmissionContext,
    ) -> Vec<Request>;
}

/// The original FIFO loop, verbatim: admission stops at the first request
/// that doesn't fit (no head-of-line bypass — keeps latency fairness,
/// same default as vLLM). Requests with `arrival > now` are not admitted
/// (the queue is arrival-sorted).
#[derive(Debug, Default, Clone)]
pub struct FifoAdmission;

impl AdmissionPolicy for FifoAdmission {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn admit(
        &mut self,
        config: &SchedulerConfig,
        queue: &mut RequestQueue,
        ctx: &AdmissionContext,
    ) -> Vec<Request> {
        let mut admitted = Vec::new();
        let mut virtual_free = ctx.kv.free_blocks();
        let bs = ctx.kv.config().block_size;
        while ctx.running.len() + admitted.len() < ctx.ceiling.min(config.max_batch) {
            let Some(head) = queue.peek() else { break };
            if head.arrival > ctx.now {
                break;
            }
            let need_tokens = head.prompt.len() + config.admit_reserve_tokens;
            let need_blocks = need_tokens.div_ceil(bs);
            if need_blocks > virtual_free {
                break;
            }
            virtual_free -= need_blocks;
            admitted.push(queue.pop().unwrap());
        }
        admitted
    }
}

/// Neutral per-class attributes for classes beyond the tenant table
/// (classless deployments, or requests tagged with an unknown class).
fn class_attr(tenants: &[TenantClass], c: ClassId) -> (u32, f64, Option<usize>, Option<f64>) {
    match tenants.get(c) {
        Some(t) => (t.priority, t.weight.max(1e-12), t.max_running, t.alpha_hint),
        None => (1, 1.0, None, None),
    }
}

/// Multi-tenant SLO-class admission (see the module docs for the full
/// decision order). Holds the per-class deficit credits across calls so
/// weighted fairness is a long-run property, not a per-round one.
#[derive(Debug)]
pub struct ClassAwareAdmission {
    cfg: ClassAwareConfig,
    /// Deficit credits per class: admitting from class `c` costs its
    /// byte footprint (prompt + reservation, in REF_TOKENS units) over
    /// `weight(c)`, and the most-credited class wins within a priority
    /// tier, so long-run admission shares are proportional to weights
    /// *in claimed KV bytes*, not request counts.
    credits: Vec<f64>,
}

impl ClassAwareAdmission {
    pub fn new(cfg: ClassAwareConfig) -> ClassAwareAdmission {
        ClassAwareAdmission {
            cfg,
            credits: Vec::new(),
        }
    }

    pub fn config(&self) -> &ClassAwareConfig {
        &self.cfg
    }
}

impl AdmissionPolicy for ClassAwareAdmission {
    fn name(&self) -> &'static str {
        if self.cfg.mix_speedup_floor.is_some() {
            "class-aware+mix"
        } else {
            "class-aware"
        }
    }

    fn admit(
        &mut self,
        config: &SchedulerConfig,
        queue: &mut RequestQueue,
        ctx: &AdmissionContext,
    ) -> Vec<Request> {
        let ceiling = ctx.ceiling.min(config.max_batch);
        if ctx.running.len() >= ceiling {
            return Vec::new();
        }
        let bs = ctx.kv.config().block_size;
        let mut virtual_free = ctx.kv.free_blocks();

        // Per-class logical queues: candidate positions in arrival order.
        // The physical queue is arrival-sorted, so scanning until the
        // first future arrival preserves FIFO order within every class.
        let mut n_classes = ctx.tenants.len().max(1);
        for r in ctx.running {
            n_classes = n_classes.max(r.class + 1);
        }
        // Each candidate is snapshotted as (queue index, arrival,
        // prompt_len) so every later head lookup is O(1) — the admission
        // loop would otherwise re-walk the deque per eligibility check,
        // which is quadratic exactly at overload.
        let mut cands: Vec<Vec<(usize, f64, usize)>> = Vec::new();
        for (idx, req) in queue.iter().enumerate() {
            if req.arrival > ctx.now {
                break;
            }
            n_classes = n_classes.max(req.class + 1);
            if cands.len() < n_classes {
                cands.resize_with(n_classes, Vec::new);
            }
            cands[req.class].push((idx, req.arrival, req.prompt.len()));
        }
        if cands.iter().all(Vec::is_empty) {
            return Vec::new();
        }
        cands.resize_with(n_classes, Vec::new);
        if self.credits.len() < n_classes {
            self.credits.resize(n_classes, 0.0);
        }

        let mut running_per_class = vec![0usize; n_classes];
        for r in ctx.running {
            running_per_class[r.class] += 1;
        }
        // Mix estimate of the running batch: per-sequence α̂ᵢ where the
        // control plane has one, the class α hint otherwise.
        let mut alpha_sum = 0.0f64;
        let mut alpha_n = 0usize;
        for r in ctx.running {
            let hint = class_attr(ctx.tenants, r.class).3;
            if let Some(a) = r.alpha.or(hint) {
                alpha_sum += a;
                alpha_n += 1;
            }
        }

        let mut cursor = vec![0usize; n_classes];
        let mut picked_per_class = vec![0usize; n_classes];
        let mut blocked = vec![false; n_classes]; // KV-blocked: no intra-class bypass
        let mut picked: Vec<usize> = Vec::new(); // queue indices, pick order

        loop {
            if ctx.running.len() + picked.len() >= ceiling {
                break;
            }
            // Eligible classes this iteration.
            let mut eligible: Vec<ClassId> = Vec::new();
            for c in 0..n_classes {
                if blocked[c] || cursor[c] >= cands[c].len() {
                    continue;
                }
                let (_, _, max_running, _) = class_attr(ctx.tenants, c);
                let cap = max_running.unwrap_or(usize::MAX).min(
                    ctx.class_ceilings
                        .and_then(|cc| cc.get(c).copied())
                        .unwrap_or(usize::MAX),
                );
                if running_per_class[c] + picked_per_class[c] >= cap {
                    continue;
                }
                eligible.push(c);
            }
            if eligible.is_empty() {
                break;
            }

            // Effective priority: the class tier plus one tier per
            // `aging_tau` seconds its head has waited (bounded starvation).
            let head = |c: ClassId| cands[c][cursor[c]];
            let eff_prio = |c: ClassId| -> u64 {
                let (prio, _, _, _) = class_attr(ctx.tenants, c);
                let wait = (ctx.now - head(c).1).max(0.0);
                let boost = if self.cfg.aging_tau.is_finite() && self.cfg.aging_tau > 0.0 {
                    (wait / self.cfg.aging_tau) as u64
                } else {
                    0
                };
                prio as u64 + boost
            };
            let top = eligible.iter().map(|&c| eff_prio(c)).max().unwrap();
            let mut tier: Vec<ClassId> = eligible
                .iter()
                .copied()
                .filter(|&c| eff_prio(c) == top)
                .collect();
            // Deficit-weighted fairness within the tier: most credits
            // first; ties go to the earliest head arrival, then class id.
            tier.sort_by(|&a, &b| {
                self.credits[b]
                    .partial_cmp(&self.credits[a])
                    .unwrap()
                    .then(head(a).1.partial_cmp(&head(b).1).unwrap())
                    .then(a.cmp(&b))
            });
            let mut chosen = tier[0];

            // Mix-aware regime test: pick the tier candidate whose class
            // α hint keeps the priced post-admission speedup highest, and
            // pause admission once even the best falls below the floor.
            // The pause (not the candidate choice) is overridden when the
            // oldest tier head has waited past `mix_hold_max` — latency
            // promises outrank throughput shaping; class starvation by
            // the *selection* is bounded separately by priority aging,
            // which lifts old heads into their own tier above this one.
            if let (Some(floor), Some(oracle)) = (self.cfg.mix_speedup_floor, ctx.oracle) {
                let batch_after = ctx.running.len() + picked.len() + 1;
                if batch_after > self.cfg.min_batch {
                    let mix_with = |hint: Option<f64>| -> Option<f64> {
                        match hint {
                            Some(a) if alpha_n > 0 => {
                                Some((alpha_sum + a) / (alpha_n + 1) as f64)
                            }
                            Some(a) => Some(a),
                            None if alpha_n > 0 => Some(alpha_sum / alpha_n as f64),
                            None => None,
                        }
                    };
                    let mut best = f64::MIN;
                    let mut best_c = chosen;
                    for &c in &tier {
                        let hint = class_attr(ctx.tenants, c).3;
                        let s = oracle.predicted_speedup(batch_after, mix_with(hint));
                        if s > best {
                            best = s;
                            best_c = c;
                        }
                    }
                    if best < floor {
                        // Before pausing, look past the top tier: an
                        // eligible lower-tier candidate that keeps the
                        // batch in the band weakly dominates a pause —
                        // the top-tier heads are served in neither case,
                        // and aging/hold still bound their wait.
                        let mut alt_best = f64::MIN;
                        let mut alt_c = None;
                        for &c in &eligible {
                            if tier.contains(&c) {
                                continue;
                            }
                            let hint = class_attr(ctx.tenants, c).3;
                            let s = oracle.predicted_speedup(batch_after, mix_with(hint));
                            if s > alt_best {
                                alt_best = s;
                                alt_c = Some(c);
                            }
                        }
                        if let (Some(c), true) = (alt_c, alt_best >= floor) {
                            chosen = c;
                        } else {
                            let (oldest_c, oldest_wait) = tier
                                .iter()
                                .map(|&c| (c, ctx.now - head(c).1))
                                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                                .unwrap();
                            if oldest_wait <= self.cfg.mix_hold_max {
                                break; // hold the batch inside the speculative regime
                            }
                            chosen = oldest_c; // forced through: serve the oldest
                        }
                    } else {
                        chosen = best_c;
                    }
                }
            }

            // KV capacity with the growth reservation; a non-fitting head
            // blocks its class (no intra-class bypass) but not others.
            let (queue_idx, _, prompt_len) = head(chosen);
            let need_tokens = prompt_len + config.admit_reserve_tokens;
            let need_blocks = need_tokens.div_ceil(bs);
            if need_blocks > virtual_free {
                blocked[chosen] = true;
                continue;
            }
            virtual_free -= need_blocks;
            let (_, weight, _, hint) = class_attr(ctx.tenants, chosen);
            if let Some(a) = hint {
                alpha_sum += a;
                alpha_n += 1;
            }
            picked.push(queue_idx);
            cursor[chosen] += 1;
            picked_per_class[chosen] += 1;
            // Weighted-fairness byte accounting: the deficit charge is
            // proportional to the KV footprint the admission claims
            // (prompt + growth reservation), not a flat per-request
            // unit — a class sending 4× longer prompts burns its weight
            // share 4× faster. Normalized by REF_TOKENS so the credit
            // bank cap below keeps its "≈ CREDIT_BANK_CAP typical
            // admissions of banked advantage" meaning.
            const REF_TOKENS: f64 = 64.0;
            let charge = (prompt_len + config.admit_reserve_tokens) as f64 / REF_TOKENS;
            self.credits[chosen] -= charge / weight;
        }

        if picked.is_empty() {
            return Vec::new();
        }
        // Keep credits bounded (DWRR-style deficit cap): pin the max at
        // zero AND floor the deficit, so an idle class can bank at most
        // `CREDIT_BANK_CAP` admissions of advantage over a busy one
        // across quiet stretches — past imbalance is forgiven, not
        // compounded. (Within one admit call credits run unclamped, so
        // single-burst weighted shares still track weights exactly.)
        const CREDIT_BANK_CAP: f64 = 16.0;
        let max_credit = self.credits.iter().cloned().fold(f64::MIN, f64::max);
        if max_credit.is_finite() {
            for c in self.credits.iter_mut() {
                *c = (*c - max_credit).max(-CREDIT_BANK_CAP);
            }
        }
        // Remove the picked queue positions (descending index so earlier
        // removals don't shift later ones), then restore pick order.
        let mut order: Vec<(usize, usize)> =
            picked.iter().copied().enumerate().map(|(k, idx)| (idx, k)).collect();
        order.sort_by(|a, b| b.0.cmp(&a.0));
        let mut admitted_by_rank: Vec<(usize, Request)> = order
            .into_iter()
            .map(|(idx, k)| (k, queue.remove_at(idx).expect("picked index valid")))
            .collect();
        admitted_by_rank.sort_by_key(|(k, _)| *k);
        admitted_by_rank.into_iter().map(|(_, r)| r).collect()
    }
}

/// The admission scheduler: config plus the pluggable policy.
pub struct Scheduler {
    pub config: SchedulerConfig,
    policy: Box<dyn AdmissionPolicy>,
}

impl std::fmt::Debug for Scheduler {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Scheduler")
            .field("config", &self.config)
            .field("policy", &self.policy.name())
            .finish()
    }
}

impl Scheduler {
    /// FIFO scheduler (the pre-multi-tenant default).
    pub fn new(config: SchedulerConfig) -> Scheduler {
        Scheduler::with_policy(config, &AdmissionPolicyConfig::Fifo)
    }

    /// Scheduler with an explicit admission policy.
    pub fn with_policy(config: SchedulerConfig, policy: &AdmissionPolicyConfig) -> Scheduler {
        let policy: Box<dyn AdmissionPolicy> = match policy {
            AdmissionPolicyConfig::Fifo => Box::new(FifoAdmission),
            AdmissionPolicyConfig::ClassAware(cfg) => {
                Box::new(ClassAwareAdmission::new(cfg.clone()))
            }
        };
        Scheduler { config, policy }
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Effective batch ceiling given the SLO estimator: `est_tpot(b)`
    /// returns estimated seconds/token at batch size b.
    ///
    /// Contract (also exercised by the edge-case tests below):
    /// - the result is always ≤ `max_batch`, and `max_batch == 0` returns
    ///   0 (admissions fully paused);
    /// - with an SLO, the largest `b` with `est_tpot(b) <= slo` wins;
    /// - if **no** batch size meets the SLO — the SLO is simply
    ///   infeasible on this hardware — the ceiling degrades to 1 rather
    ///   than 0: the system keeps draining at minimum batch (and maximum
    ///   per-request speed) instead of deadlocking with queued work. An
    ///   infeasible SLO is an operator error we make progress under, not
    ///   a reason to stop serving.
    pub fn batch_ceiling<F: Fn(usize) -> f64>(&self, est_tpot: F) -> usize {
        Self::ceiling_for(&self.config, self.config.tpot_slo, est_tpot)
    }

    /// The same ceiling search for an arbitrary (e.g. per-tenant-class)
    /// TPOT SLO — per-class ceilings share one contract with the global
    /// one instead of re-deriving it.
    pub fn ceiling_for<F: Fn(usize) -> f64>(
        config: &SchedulerConfig,
        tpot_slo: Option<f64>,
        est_tpot: F,
    ) -> usize {
        if config.max_batch == 0 {
            return 0;
        }
        match tpot_slo {
            None => config.max_batch,
            Some(slo) => {
                let mut best = 1;
                for b in 1..=config.max_batch {
                    if est_tpot(b) <= slo {
                        best = b;
                    }
                }
                best
            }
        }
    }

    /// Pull admissible requests off the queue (compat entry point: a
    /// classless context; `running` is the running-batch size). FIFO
    /// callers lose nothing — the FIFO policy only reads the count.
    pub fn admit(
        &mut self,
        queue: &mut RequestQueue,
        kv: &KvManager,
        running: usize,
        ceiling: usize,
        now: f64,
    ) -> Vec<Request> {
        let infos = vec![
            RunningInfo {
                class: crate::batching::DEFAULT_CLASS,
                alpha: None,
            };
            running
        ];
        let ctx = AdmissionContext::simple(kv, &infos, ceiling, now);
        self.admit_with(queue, &ctx)
    }

    /// Policy-dispatched admission with the full context.
    pub fn admit_with(&mut self, queue: &mut RequestQueue, ctx: &AdmissionContext) -> Vec<Request> {
        self.policy.admit(&self.config, queue, ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::SamplingParams;
    use crate::kvcache::{KvConfig, KvManager};

    fn req(id: u64, prompt_len: usize) -> Request {
        Request {
            id,
            prompt: vec![1; prompt_len],
            params: SamplingParams::default(),
            arrival: 0.0,
            class: 0,
        }
    }

    fn creq(id: u64, prompt_len: usize, class: usize, arrival: f64) -> Request {
        Request {
            arrival,
            ..req(id, prompt_len).with_class(class)
        }
    }

    fn kv(blocks: usize) -> KvManager {
        KvManager::new(KvConfig {
            num_blocks: blocks,
            block_size: 16,
        })
    }

    fn sched(max_batch: usize, reserve: usize, slo: Option<f64>) -> Scheduler {
        Scheduler::new(SchedulerConfig {
            max_batch,
            admit_reserve_tokens: reserve,
            tpot_slo: slo,
        })
    }

    #[test]
    fn admits_up_to_batch_ceiling() {
        let mut s = sched(2, 0, None);
        let mut q = RequestQueue::new();
        for i in 0..5 {
            q.push(req(i, 8));
        }
        let admitted = s.admit(&mut q, &kv(100), 0, usize::MAX, 0.0);
        assert_eq!(admitted.len(), 2);
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn respects_kv_capacity_with_reservation() {
        let mut s = sched(64, 16, None);
        let mut q = RequestQueue::new();
        // Each request: 16-token prompt + 16 reserve = 2 blocks; 3 blocks
        // total → only one admission.
        q.push(req(1, 16));
        q.push(req(2, 16));
        let admitted = s.admit(&mut q, &kv(3), 0, usize::MAX, 0.0);
        assert_eq!(admitted.len(), 1);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn fifo_no_bypass() {
        let mut s = sched(8, 0, None);
        let mut q = RequestQueue::new();
        q.push(req(1, 1000)); // cannot fit in 4 blocks of 16
        q.push(req(2, 4)); // would fit, but must not bypass
        let admitted = s.admit(&mut q, &kv(4), 0, usize::MAX, 0.0);
        assert!(admitted.is_empty());
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn slo_caps_batch() {
        let s = sched(64, 0, Some(0.05));
        // TPOT grows linearly: 0.01·b seconds/token → ceiling 5.
        let ceil = s.batch_ceiling(|b| 0.01 * b as f64);
        assert_eq!(ceil, 5);
        // No SLO → max batch.
        let s2 = Scheduler::new(SchedulerConfig::default());
        assert_eq!(s2.batch_ceiling(|_| 1.0), 64);
        // ceiling_for with an override SLO matches a scheduler built with
        // that SLO (per-class ceilings share the contract).
        assert_eq!(
            Scheduler::ceiling_for(&s.config, Some(0.02), |b| 0.01 * b as f64),
            2
        );
    }

    #[test]
    fn batch_ceiling_max_batch_zero_pauses_admissions() {
        for slo in [None, Some(0.05)] {
            let mut s = sched(0, 0, slo);
            assert_eq!(s.batch_ceiling(|_| 0.0), 0, "slo={slo:?}");
            // And admit() honors the zero ceiling.
            let mut q = RequestQueue::new();
            q.push(req(1, 4));
            assert!(s.admit(&mut q, &kv(100), 0, 0, 0.0).is_empty());
        }
    }

    #[test]
    fn batch_ceiling_max_batch_one() {
        let s = sched(1, 0, Some(0.05));
        // b=1 meets the SLO → ceiling 1; and that is also the maximum.
        assert_eq!(s.batch_ceiling(|b| 0.01 * b as f64), 1);
        // b=1 misses the SLO → still 1 (degraded-SLO floor, documented).
        assert_eq!(s.batch_ceiling(|_| 1.0), 1);
    }

    #[test]
    fn infeasible_slo_degrades_to_batch_one_not_zero() {
        let s = sched(64, 0, Some(1e-9)); // no hardware meets this
        assert_eq!(s.batch_ceiling(|b| 0.01 * b as f64), 1);
    }

    #[test]
    fn running_counts_against_ceiling() {
        let mut s = sched(4, 0, None);
        let mut q = RequestQueue::new();
        for i in 0..4 {
            q.push(req(i, 4));
        }
        let admitted = s.admit(&mut q, &kv(100), 3, usize::MAX, 0.0);
        assert_eq!(admitted.len(), 1);
    }

    // --- class-aware admission ---------------------------------------------

    use crate::workload::TenantClass;

    fn two_tenants() -> Vec<TenantClass> {
        let mut hi = TenantClass::new("hi");
        hi.priority = 2;
        let mut lo = TenantClass::new("lo");
        lo.priority = 1;
        lo.weight = 1.0;
        vec![hi, lo]
    }

    fn class_sched(cfg: ClassAwareConfig) -> Scheduler {
        Scheduler::with_policy(
            SchedulerConfig {
                max_batch: 64,
                admit_reserve_tokens: 0,
                tpot_slo: None,
            },
            &AdmissionPolicyConfig::ClassAware(cfg),
        )
    }

    #[test]
    fn priority_tier_wins_over_arrival_order() {
        let tenants = two_tenants();
        let mut s = class_sched(ClassAwareConfig::default());
        let mut q = RequestQueue::new();
        q.push(creq(1, 4, 1, 0.0)); // low prio, arrived first
        q.push(creq(2, 4, 0, 0.0)); // high prio
        let kvm = kv(100);
        let ctx = AdmissionContext {
            kv: &kvm,
            running: &[],
            ceiling: 1,
            now: 0.0,
            tenants: &tenants,
            class_ceilings: None,
            oracle: None,
        };
        let admitted = s.admit_with(&mut q, &ctx);
        assert_eq!(admitted.len(), 1);
        assert_eq!(admitted[0].id, 2, "priority beats arrival order");
        assert_eq!(q.len(), 1);
        assert_eq!(q.peek().unwrap().id, 1);
    }

    #[test]
    fn fifo_preserved_within_class_and_aging_promotes() {
        let tenants = two_tenants();
        let mut s = class_sched(ClassAwareConfig {
            aging_tau: 10.0,
            ..ClassAwareConfig::default()
        });
        let kvm = kv(1000);
        // Within a class, arrival order is preserved.
        let mut q = RequestQueue::new();
        q.push(creq(10, 4, 0, 0.0));
        q.push(creq(11, 4, 0, 1.0));
        q.push(creq(12, 4, 0, 2.0));
        let ctx = AdmissionContext {
            kv: &kvm,
            running: &[],
            ceiling: 3,
            now: 5.0,
            tenants: &tenants,
            class_ceilings: None,
            oracle: None,
        };
        let ids: Vec<u64> = s.admit_with(&mut q, &ctx).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![10, 11, 12]);
        // Aging: a low-priority request 10+ seconds old outranks a fresh
        // high-priority one (its tier is promoted by wait/tau).
        let mut q = RequestQueue::new();
        q.push(creq(20, 4, 1, 0.0)); // low prio, waited 15 s
        q.push(creq(21, 4, 0, 15.0)); // high prio, fresh
        let ctx = AdmissionContext {
            kv: &kvm,
            running: &[],
            ceiling: 1,
            now: 15.0,
            tenants: &tenants,
            class_ceilings: None,
            oracle: None,
        };
        let admitted = s.admit_with(&mut q, &ctx);
        assert_eq!(admitted[0].id, 20, "aging must bound starvation");
    }

    #[test]
    fn weighted_fairness_tracks_weights_in_one_tier() {
        let mut a = TenantClass::new("a");
        a.weight = 3.0;
        let mut b = TenantClass::new("b");
        b.weight = 1.0;
        let tenants = vec![a, b];
        let mut s = class_sched(ClassAwareConfig::default());
        let kvm = kv(100_000);
        let mut q = RequestQueue::new();
        for i in 0..200u64 {
            q.push(creq(i, 4, (i % 2) as usize, 0.0));
        }
        let ctx = AdmissionContext {
            kv: &kvm,
            running: &[],
            ceiling: 80,
            now: 0.0,
            tenants: &tenants,
            class_ceilings: None,
            oracle: None,
        };
        let admitted = s.admit_with(&mut q, &ctx);
        assert_eq!(admitted.len(), 80);
        let n_a = admitted.iter().filter(|r| r.class == 0).count();
        let share = n_a as f64 / 80.0;
        assert!(
            (share - 0.75).abs() < 0.07,
            "weight-3 class should take ~75% of admissions: {share}"
        );
    }

    #[test]
    fn byte_accounting_charges_long_prompts_more() {
        // Equal weights, one tier; class 0 sends 15× longer prompts.
        // Byte-accounted DWRR must equalize claimed *tokens*, so class 1
        // wins far more admission slots than class 0.
        let a = TenantClass::new("long");
        let b = TenantClass::new("short");
        let tenants = vec![a, b];
        let mut s = class_sched(ClassAwareConfig::default());
        let kvm = kv(100_000);
        let mut q = RequestQueue::new();
        for i in 0..300u64 {
            let (class, len) = if i % 2 == 0 { (0, 60) } else { (1, 4) };
            q.push(creq(i, len, class, 0.0));
        }
        let ctx = AdmissionContext {
            kv: &kvm,
            running: &[],
            ceiling: 64,
            now: 0.0,
            tenants: &tenants,
            class_ceilings: None,
            oracle: None,
        };
        let admitted = s.admit_with(&mut q, &ctx);
        assert_eq!(admitted.len(), 64);
        let n_long = admitted.iter().filter(|r| r.class == 0).count();
        let n_short = admitted.len() - n_long;
        assert!(
            n_short >= 5 * n_long.max(1),
            "short prompts should dominate slots: long={n_long} short={n_short}"
        );
        // And the claimed-token totals are comparable (within one long
        // prompt's worth of rounding).
        let toks = |c: usize| -> usize {
            admitted
                .iter()
                .filter(|r| r.class == c)
                .map(|r| r.prompt.len())
                .sum()
        };
        let (t_long, t_short) = (toks(0) as f64, toks(1) as f64);
        assert!(
            (t_long - t_short).abs() <= 60.0,
            "byte shares should balance: long={t_long} short={t_short}"
        );
    }

    #[test]
    fn per_class_ceilings_and_kv_block_one_class_only() {
        let tenants = two_tenants();
        let mut s = class_sched(ClassAwareConfig::default());
        let kvm = kv(1000);
        // Class 0 capped at 1 running; class 1 fills the rest.
        let mut q = RequestQueue::new();
        q.push(creq(1, 4, 0, 0.0));
        q.push(creq(2, 4, 0, 0.0));
        q.push(creq(3, 4, 1, 0.0));
        let ceilings = [1usize, 64];
        let ctx = AdmissionContext {
            kv: &kvm,
            running: &[],
            ceiling: 10,
            now: 0.0,
            tenants: &tenants,
            class_ceilings: Some(&ceilings),
            oracle: None,
        };
        let ids: Vec<u64> = s.admit_with(&mut q, &ctx).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 3], "class cap holds back the second class-0 request");
        // A giant head blocks only its own class; others keep admitting.
        let mut s = class_sched(ClassAwareConfig::default());
        let mut q = RequestQueue::new();
        q.push(creq(1, 100_000, 0, 0.0)); // cannot fit
        q.push(creq(2, 4, 0, 0.0)); // behind it: must NOT bypass
        q.push(creq(3, 4, 1, 0.0)); // other class: admitted
        let ctx = AdmissionContext {
            kv: &kvm,
            running: &[],
            ceiling: 10,
            now: 0.0,
            tenants: &tenants,
            class_ceilings: None,
            oracle: None,
        };
        let ids: Vec<u64> = s.admit_with(&mut q, &ctx).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![3]);
    }

    /// Oracle stub: inside the batch band, predicted speedup scales with
    /// the mix α (2·α); outside the band speculation loses.
    struct BandOracle {
        band: usize,
    }

    impl RegimeOracle for BandOracle {
        fn predicted_speedup(&self, batch: usize, alpha: Option<f64>) -> f64 {
            if batch <= self.band {
                2.0 * alpha.unwrap_or(0.8)
            } else {
                0.9
            }
        }
    }

    #[test]
    fn mix_aware_pauses_at_band_edge_and_prefers_easy_mixes() {
        let mut easy = TenantClass::new("easy");
        easy.alpha_hint = Some(0.9);
        let mut hard = TenantClass::new("hard");
        hard.alpha_hint = Some(0.3);
        let tenants = vec![easy, hard];
        let oracle = BandOracle { band: 4 };
        let mut s = class_sched(ClassAwareConfig::mix_aware(1.0));
        let kvm = kv(10_000);
        let mut q = RequestQueue::new();
        for i in 0..10u64 {
            q.push(creq(i, 4, (i % 2) as usize, 0.0));
        }
        let ctx = AdmissionContext {
            kv: &kvm,
            running: &[],
            ceiling: 64,
            now: 0.0,
            tenants: &tenants,
            class_ceilings: None,
            oracle: Some(&oracle),
        };
        let admitted = s.admit_with(&mut q, &ctx);
        // The band caps the batch at 4 even though ceiling/KV allow more.
        assert_eq!(admitted.len(), 4, "regime test must pause at the band edge");
        // And the picks lean easy: hard admissions would sink the mix
        // below the oracle's α floor, so the easy class dominates.
        let n_easy = admitted.iter().filter(|r| r.class == 0).count();
        assert!(n_easy >= 3, "mix-aware should prefer easy candidates: {n_easy}");
        // The hold-max guard overrides the pause for SLO safety.
        let mut s = class_sched(ClassAwareConfig {
            mix_speedup_floor: Some(1.0),
            mix_hold_max: 5.0,
            ..ClassAwareConfig::default()
        });
        let mut q = RequestQueue::new();
        for i in 0..6u64 {
            q.push(creq(i, 4, 1, 0.0)); // all hard, waited 20 s
        }
        let ctx = AdmissionContext {
            kv: &kvm,
            running: &[],
            ceiling: 64,
            now: 20.0,
            tenants: &tenants,
            class_ceilings: None,
            oracle: Some(&oracle),
        };
        let admitted = s.admit_with(&mut q, &ctx);
        assert_eq!(admitted.len(), 6, "aged requests bypass the regime hold");
    }

    #[test]
    fn mix_pause_considers_lower_tiers_before_holding() {
        // A high-priority hard class whose heads price below the floor
        // must not pause admission while a lower-tier easy class could
        // keep the batch in the band: the fallback crosses tiers.
        let mut hard = TenantClass::new("hard");
        hard.priority = 2;
        hard.alpha_hint = Some(0.3);
        let mut easy = TenantClass::new("easy");
        easy.priority = 1;
        easy.alpha_hint = Some(0.9);
        let tenants = vec![hard, easy];
        let oracle = BandOracle { band: 10 };
        let mut s = class_sched(ClassAwareConfig::mix_aware(1.0));
        let kvm = kv(10_000);
        let mut q = RequestQueue::new();
        for i in 0..3u64 {
            q.push(creq(i, 4, 0, 0.0)); // hard, fresh
        }
        for i in 3..6u64 {
            q.push(creq(i, 4, 1, 0.0)); // easy
        }
        let ctx = AdmissionContext {
            kv: &kvm,
            running: &[],
            ceiling: 6,
            now: 0.0,
            tenants: &tenants,
            class_ceilings: None,
            oracle: Some(&oracle),
        };
        let admitted = s.admit_with(&mut q, &ctx);
        // No pause: everything is admitted, and the second pick already
        // reaches across the tier to the easy class (2·mix ≥ 1 only with
        // an easy candidate once a hard one is running).
        assert_eq!(admitted.len(), 6, "cross-tier fallback must avoid the pause");
        assert_eq!(admitted[0].class, 0, "priority still wins the first slot");
        assert_eq!(admitted[1].class, 1, "band rescue comes from the lower tier");
    }

    #[test]
    fn one_class_class_aware_equals_fifo() {
        // The degeneracy contract, unit-level (the whole-engine property
        // test lives in rust/tests/prop_scheduler.rs): one neutral class,
        // identical admitted ids in identical order, for several shapes.
        for (blocks, ceiling, n) in [(1000usize, usize::MAX, 12u64), (5, 3, 6), (2, 8, 5)] {
            let mk_queue = || {
                let mut q = RequestQueue::new();
                for i in 0..n {
                    q.push(req(i, 4 + (i as usize % 3) * 20));
                }
                q
            };
            let kvm = kv(blocks);
            let mut fifo = sched(8, 4, None);
            let mut qa = mk_queue();
            let a = fifo.admit(&mut qa, &kvm, 1, ceiling, 0.0);
            let mut cls = Scheduler::with_policy(
                SchedulerConfig {
                    max_batch: 8,
                    admit_reserve_tokens: 4,
                    tpot_slo: None,
                },
                &AdmissionPolicyConfig::ClassAware(ClassAwareConfig::default()),
            );
            let mut qb = mk_queue();
            let running = [RunningInfo {
                class: 0,
                alpha: None,
            }];
            let ctx = AdmissionContext::simple(&kvm, &running, ceiling, 0.0);
            let b = cls.admit_with(&mut qb, &ctx);
            let ids = |v: &[Request]| v.iter().map(|r| r.id).collect::<Vec<_>>();
            assert_eq!(ids(&a), ids(&b), "blocks={blocks} ceiling={ceiling}");
            assert_eq!(qa.len(), qb.len());
        }
    }

    #[test]
    fn future_arrivals_not_admitted_by_either_policy() {
        let kvm = kv(100);
        let mut q = RequestQueue::new();
        q.push(creq(1, 4, 0, 5.0));
        let mut fifo = sched(8, 0, None);
        assert!(fifo.admit(&mut q, &kvm, 0, 8, 1.0).is_empty());
        let mut cls = class_sched(ClassAwareConfig::default());
        let ctx = AdmissionContext::simple(&kvm, &[], 8, 1.0);
        assert!(cls.admit_with(&mut q, &ctx).is_empty());
        assert_eq!(q.len(), 1);
    }
}
