//! Adaptive speculation control plane (the online §3 loop).
//!
//! The paper's central observation is that SD speedup for sparse MoE is a
//! *moving target*: it depends jointly on batch size B, acceptance σ(α, γ)
//! (Eq. 5) and target efficiency T_T(B,1)/T_T(B,γ+1) (§3.1), so a draft
//! length γ that wins at B=32 can lose outright at B=256. The offline
//! layers (`theory`, `perfmodel`, `simulator`) can evaluate those
//! trade-offs ahead of time; this module closes the loop **online**:
//!
//! ```text
//!             ┌────────────────────────────────────────────┐
//!             │                 Engine::step               │
//!             │   propose(γ) → verify → reject-sample      │
//!             └──────┬─────────────────────────▲───────────┘
//!     RoundObservation│                        │ γ, batch ceiling
//!             ┌──────▼─────────────────────────┴───────────┐
//!             │              SpecController                │
//!             │  · windowed α̂/σ̂ (Eq. 5 inverse)           │
//!             │  · measured cost table per (B-bucket, s)   │
//!             │    → online target-efficiency estimates    │
//!             │  · GammaPolicy (static / model-guided)     │
//!             └────────────────────────────────────────────┘
//! ```
//!
//! Every decode round the engine reports what it measured — batch size,
//! accepted/proposed draft tokens, and the per-stage clock costs the paper
//! calls T_D, T_T and T_reject. The controller maintains:
//!
//! 1. **Acceptance estimates**: per control interval, the mean accepted
//!    chain length inverts through Eq. 5 ([`crate::theory::alpha_from_sigma`])
//!    to an α̂ that is EWMA-smoothed across intervals.
//! 2. **A measured cost table** keyed by (power-of-two batch bucket,
//!    verify width s = γ+1). Where both an s=1 and an s>1 entry exist for a
//!    bucket this yields a *measured* target efficiency — the paper's §3.1
//!    quantity observed in production rather than simulated.
//! 3. **A policy decision** each `interval_rounds` rounds: a
//!    [`GammaPolicy`] maps the estimates to the γ for the next interval.
//!    [`StaticPolicy`] pins γ (the baseline); [`ModelGuidedPolicy`] plugs
//!    α̂ into the Eq. 4 speedup decomposition over an analytic cost model
//!    ([`CostModelSpec`]: Alg. 1 relaxation or the roofline simulator),
//!    rescaled by the measured costs, and picks the argmax γ — including
//!    γ = 0, the autoregressive fallback for when target efficiency
//!    collapses at large B. Hysteresis and a dwell time keep γ from
//!    thrashing on noisy α̂, and periodic probes keep α̂ fresh while in
//!    the AR fallback.
//!
//! The controller also co-tunes the scheduler's batch ceiling: with a TPOT
//! SLO configured it converts the measured round economics into an
//! est-TPOT(B) curve and asks [`crate::scheduler::Scheduler::batch_ceiling`]
//! for the largest compliant batch (§3.4's latency-critical scenario).
//!
//! ## Ragged rounds (per-sequence γᵢ)
//!
//! With [`ControlConfig::ragged`] on, the controller additionally keeps a
//! **windowed per-sequence α̂ᵢ** (the MLE ratio over each sequence's
//! recent accept outcomes, fed by [`SpecController::observe_sequences`])
//! and refines the scalar decision every round through
//! [`GammaPolicy::gamma_for_sequences`]: easy sequences draft deeper,
//! hard ones shallower, within the regime the scalar loop chose. The
//! scalar loop keeps sole authority over regimes — bootstrap,
//! batch-bucket shifts, the γ=0 AR fallback, hysteresis and probing are
//! untouched, and uniform-α workloads (or sequences still in window
//! warm-up) run the exact scalar γ, bit-for-bit.

pub mod policy;

pub use policy::{
    DecisionKind, Estimates, GammaDecision, GammaPolicy, ModelGuidedPolicy, StaticPolicy,
};

use crate::hardware::ShardingSpec;
use crate::kvcache::SeqId;
use crate::perfmodel::{PerfModel, PerfParams};
use crate::scheduler::{RegimeOracle, Scheduler};
use crate::simulator::ExecSim;
use crate::theory;
use crate::util::json::Json;
use crate::workload::TenantClass;
use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};

/// Analytic cost oracle the model-guided policy extrapolates with.
///
/// Only *relative* costs matter to the argmax over γ, so any consistent
/// scale works; measured entries from the [`CostTable`] re-anchor the
/// absolute level where observations exist.
pub trait CostModel: Send {
    /// Target forward time for `b` sequences × `s` tokens each.
    fn t_target(&self, b: usize, s: usize) -> f64;
    /// Draft forward time for one token across `b` sequences.
    fn t_draft(&self, b: usize) -> f64;
    /// Rejection-sampling stage time.
    fn t_reject(&self, b: usize, gamma: usize) -> f64;
    /// Target forward time for a **packed ragged** round: `b` sequences
    /// contributing `tokens = Σ(γᵢ+1)` new tokens in total. The default
    /// interpolates linearly between the two adjacent uniform widths;
    /// [`CostModelSpec`] overrides it with the exact packed price.
    fn t_target_tokens(&self, b: usize, tokens: usize) -> f64 {
        let b = b.max(1);
        let s_lo = (tokens / b).max(1);
        let rem = tokens.saturating_sub(b * s_lo);
        if rem == 0 {
            return self.t_target(b, s_lo);
        }
        let lo = self.t_target(b, s_lo);
        let hi = self.t_target(b, s_lo + 1);
        lo + (hi - lo) * rem as f64 / b as f64
    }

    /// Packed verify price under a verify-expert budget (`None` =
    /// unbudgeted; the default ignores the budget, which is correct for
    /// cost models without a MoE gate to cap). [`CostModelSpec`]
    /// overrides it with the Eq. 8/10 capped surfaces.
    fn t_target_tokens_budgeted(&self, b: usize, tokens: usize, budget: Option<usize>) -> f64 {
        let _ = budget;
        self.t_target_tokens(b, tokens)
    }
}

/// Plain-data cost model description (keeps [`ControlConfig`] `Clone`).
#[derive(Debug, Clone)]
pub enum CostModelSpec {
    /// The paper's Alg. 1 relaxation model with explicit parameters.
    Perf {
        ridge_point: f64,
        params: PerfParams,
        /// Activated experts per token (K) of the target.
        k: usize,
        /// Total expert count (E) of the target.
        e: usize,
        /// Expert-parallel deployment the target runs under
        /// ([`ShardingSpec::single`] for one group).
        sharding: ShardingSpec,
    },
    /// The roofline simulator pair — the same oracle the synthetic
    /// backend prices rounds with. The target simulator carries its own
    /// [`ShardingSpec`] (see [`crate::simulator::ExecSim::with_sharding`]).
    Roofline {
        target: ExecSim,
        draft: ExecSim,
        /// Context length used when pricing forwards.
        ctx: usize,
    },
}

impl CostModelSpec {
    /// Roofline spec at the synthetic backend's default pricing context.
    pub fn roofline(target: ExecSim, draft: ExecSim) -> CostModelSpec {
        CostModelSpec::Roofline {
            target,
            draft,
            ctx: 512,
        }
    }

    /// Alg. 1 spec from fitted (or physically-derived) parameters.
    pub fn perf(ridge_point: f64, params: PerfParams, k: usize, e: usize) -> CostModelSpec {
        CostModelSpec::Perf {
            ridge_point,
            params,
            k,
            e,
            sharding: ShardingSpec::single(),
        }
    }

    /// Re-anchor this cost model on an EP-sharded deployment: the policy's
    /// γ argmax then reflects the topology's cost surface (wider
    /// SD-favorable batch ranges on fast fabrics, smaller γ on
    /// communication-bound ones).
    pub fn with_sharding(self, spec: ShardingSpec) -> CostModelSpec {
        match self {
            CostModelSpec::Perf {
                ridge_point,
                params,
                k,
                e,
                ..
            } => CostModelSpec::Perf {
                ridge_point,
                params,
                k,
                e,
                sharding: spec,
            },
            CostModelSpec::Roofline { target, draft, ctx } => CostModelSpec::Roofline {
                target: target.with_sharding(spec),
                draft,
                ctx,
            },
        }
    }

    /// The EP sharding this cost model prices against.
    pub fn sharding(&self) -> &ShardingSpec {
        match self {
            CostModelSpec::Perf { sharding, .. } => sharding,
            CostModelSpec::Roofline { target, .. } => target.sharding(),
        }
    }

    /// The target's MoE gate shape `(E, K)`, if it has one — the inputs
    /// the budget coverage curve ([`theory::budget_coverage`]) needs.
    /// `None` for dense targets, where a verify-expert budget is
    /// meaningless and the policy treats every budget as transparent.
    pub fn moe_dims(&self) -> Option<(usize, usize)> {
        match self {
            CostModelSpec::Perf { k, e, .. } => Some((*e, *k)),
            CostModelSpec::Roofline { target, .. } => target.moe_dims(),
        }
    }
}

impl CostModel for CostModelSpec {
    fn t_target(&self, b: usize, s: usize) -> f64 {
        match self {
            CostModelSpec::Perf {
                ridge_point,
                params,
                k,
                e,
                sharding,
            } => PerfModel::with_ridge_point(*ridge_point)
                .t_target_sharded(params, b, s, *k, *e, sharding),
            CostModelSpec::Roofline { target, ctx, .. } => target.t_forward(b, s, *ctx),
        }
    }

    fn t_draft(&self, b: usize) -> f64 {
        match self {
            CostModelSpec::Perf {
                ridge_point,
                params,
                ..
            } => PerfModel::with_ridge_point(*ridge_point).t_draft(params, b),
            CostModelSpec::Roofline { draft, ctx, .. } => draft.t_forward(b, 1, *ctx),
        }
    }

    fn t_reject(&self, b: usize, gamma: usize) -> f64 {
        match self {
            CostModelSpec::Perf {
                ridge_point,
                params,
                ..
            } => PerfModel::with_ridge_point(*ridge_point).t_reject(params, b, gamma),
            CostModelSpec::Roofline { target, .. } => target.t_reject(b, gamma),
        }
    }

    fn t_target_tokens(&self, b: usize, tokens: usize) -> f64 {
        match self {
            // Alg. 1's surface depends on (b, s) only through t = b·s, so
            // the packed form is exact: t_target(tokens, 1).
            CostModelSpec::Perf {
                ridge_point,
                params,
                k,
                e,
                sharding,
            } => PerfModel::with_ridge_point(*ridge_point)
                .t_target_sharded(params, tokens, 1, *k, *e, sharding),
            CostModelSpec::Roofline { target, ctx, .. } => {
                target.t_forward_tokens(b.max(1), tokens, *ctx)
            }
        }
    }

    fn t_target_tokens_budgeted(&self, b: usize, tokens: usize, budget: Option<usize>) -> f64 {
        match self {
            CostModelSpec::Perf {
                ridge_point,
                params,
                k,
                e,
                sharding,
            } => PerfModel::with_ridge_point(*ridge_point)
                .t_target_sharded_budgeted(params, tokens, 1, *k, *e, sharding, budget),
            CostModelSpec::Roofline { target, ctx, .. } => {
                target.t_forward_tokens_budgeted(b.max(1), tokens, *ctx, budget)
            }
        }
    }
}

/// Which policy the controller runs.
#[derive(Debug, Clone)]
pub enum PolicyKind {
    /// Fixed γ; the controller still maintains estimates (observability).
    Static { gamma: usize },
    /// Eq. 4 argmax-γ with measured α̂ and AR fallback.
    ModelGuided { cost: CostModelSpec },
}

/// Controller configuration — plain data so [`crate::engine::EngineConfig`]
/// stays `Clone + Debug + Send`.
#[derive(Debug, Clone)]
pub struct ControlConfig {
    pub policy: PolicyKind,
    /// Sequence-rounds (batch × rounds) per control interval. Closing on
    /// accumulated *samples* rather than rounds keeps the α̂ estimator
    /// quality independent of batch size: at B=1 an interval spans many
    /// rounds, at B=512 a single round already carries 512 samples.
    pub interval_seq_rounds: usize,
    /// Largest γ the policy may select.
    pub gamma_max: usize,
    /// Relative predicted improvement required to switch γ (0.05 = 5%).
    pub hysteresis: f64,
    /// Minimum control intervals between γ switches.
    pub min_dwell_intervals: usize,
    /// While in the γ=0 fallback, probe a speculative γ for one interval
    /// after this many intervals (0 disables probing).
    pub probe_every_intervals: usize,
    /// α̂ prior used before any speculative rounds have been observed.
    pub alpha_prior: f64,
    /// EWMA weight of the newest interval estimate, in (0, 1].
    pub alpha_smoothing: f64,
    /// Enable **ragged rounds**: per-sequence γᵢ refined every round from
    /// windowed per-sequence α̂ᵢ via [`GammaPolicy::gamma_for_sequences`].
    /// Off by default — the scalar control loop is unchanged, and ragged
    /// refinement only ever applies *within* a speculative regime (the
    /// γ=0 AR fallback stays uniform).
    pub ragged: bool,
    /// Per-sequence α̂ window: the number of recent speculative rounds a
    /// sequence must have (and that are averaged) before its own α̂ᵢ is
    /// trusted. Sequences with fewer observations fall back to the
    /// batch-level estimate (warm-up).
    pub seq_window_rounds: usize,
    /// Minimum spread (max α̂ᵢ − min α̂ᵢ) before a round is actually made
    /// ragged; below it the uniform scalar decision applies unchanged.
    /// Damps estimator noise from masquerading as workload heterogeneity:
    /// at the default window of 8 rounds a per-sequence α̂ᵢ carries a
    /// sampling std of roughly 0.07, so the max−min spread of a large
    /// *homogeneous* batch routinely reaches ~0.2 — the default gate of
    /// 0.25 sits above that noise floor, while genuinely bimodal mixes
    /// (spreads ≥ 0.3 for e.g. α 0.9/0.5) clear it immediately.
    /// Deployments with longer windows (less noise) can lower it.
    pub ragged_min_spread: f64,
    /// Track per-sequence α̂ᵢ windows even with `ragged` off. The
    /// multi-tenant mix-aware admission policy reads the running batch's
    /// α̂ᵢ through the engine without requiring ragged rounds; scalar
    /// deployments that don't need either keep the map empty (default).
    pub track_seq_alpha: bool,
    /// Candidate verify-expert budgets the model-guided policy may pick
    /// jointly with γ. **Empty (the default) disables the budget axis
    /// entirely** — the controller never touches the backend's budget and
    /// every decision is bit-identical to the unbudgeted controller.
    pub budget_grid: Vec<usize>,
    /// Exponent of the acceptance-degradation prior `α_eff = α·cov^sens`
    /// used to price budget candidates before the measured
    /// acceptance-vs-budget curve has samples (see
    /// [`theory::budgeted_alpha`]). Ignored while `budget_grid` is empty.
    pub budget_sensitivity: f64,
}

impl Default for ControlConfig {
    fn default() -> Self {
        ControlConfig {
            policy: PolicyKind::Static { gamma: 3 },
            interval_seq_rounds: 64,
            gamma_max: 8,
            hysteresis: 0.05,
            min_dwell_intervals: 2,
            probe_every_intervals: 8,
            alpha_prior: 0.8,
            alpha_smoothing: 0.4,
            ragged: false,
            seq_window_rounds: 8,
            ragged_min_spread: 0.25,
            track_seq_alpha: false,
            budget_grid: Vec::new(),
            budget_sensitivity: 1.0,
        }
    }
}

impl ControlConfig {
    pub fn static_gamma(gamma: usize) -> ControlConfig {
        ControlConfig {
            policy: PolicyKind::Static { gamma },
            ..ControlConfig::default()
        }
    }

    pub fn model_guided(cost: CostModelSpec) -> ControlConfig {
        ControlConfig {
            policy: PolicyKind::ModelGuided { cost },
            ..ControlConfig::default()
        }
    }

    /// Model-guided with ragged rounds enabled (per-sequence γᵢ).
    pub fn model_guided_ragged(cost: CostModelSpec) -> ControlConfig {
        ControlConfig {
            ragged: true,
            ..ControlConfig::model_guided(cost)
        }
    }

    /// Check the knobs for validity. Surfaces configuration errors at API
    /// boundaries (e.g. [`crate::server::Server::start_with`]) instead of
    /// panicking on the engine thread.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.interval_seq_rounds >= 1,
            "interval_seq_rounds must be >= 1"
        );
        anyhow::ensure!(self.gamma_max >= 1, "gamma_max must be >= 1");
        anyhow::ensure!(self.hysteresis >= 0.0, "hysteresis must be non-negative");
        anyhow::ensure!(
            self.alpha_smoothing > 0.0 && self.alpha_smoothing <= 1.0,
            "alpha_smoothing must be in (0, 1]"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.alpha_prior),
            "alpha_prior must be in [0, 1]"
        );
        anyhow::ensure!(
            self.seq_window_rounds >= 1,
            "seq_window_rounds must be >= 1"
        );
        anyhow::ensure!(
            self.ragged_min_spread >= 0.0,
            "ragged_min_spread must be non-negative"
        );
        anyhow::ensure!(
            self.budget_grid.iter().all(|&b| b >= 1),
            "budget_grid entries must be >= 1"
        );
        anyhow::ensure!(
            self.budget_sensitivity.is_finite() && self.budget_sensitivity >= 0.0,
            "budget_sensitivity must be finite and non-negative"
        );
        Ok(())
    }

    /// Clamp every knob into its valid range. [`SpecController::new`] runs
    /// on whatever thread owns the engine, where a panic would silently
    /// kill serving — so it sanitizes rather than asserts; callers that
    /// want loud failures use [`ControlConfig::validate`] up front.
    fn sanitized(&self) -> ControlConfig {
        ControlConfig {
            policy: self.policy.clone(),
            interval_seq_rounds: self.interval_seq_rounds.max(1),
            gamma_max: self.gamma_max.max(1),
            hysteresis: self.hysteresis.max(0.0),
            min_dwell_intervals: self.min_dwell_intervals,
            probe_every_intervals: self.probe_every_intervals,
            alpha_prior: self.alpha_prior.clamp(0.0, 1.0),
            alpha_smoothing: if self.alpha_smoothing > 0.0 && self.alpha_smoothing <= 1.0 {
                self.alpha_smoothing
            } else {
                ControlConfig::default().alpha_smoothing
            },
            ragged: self.ragged,
            seq_window_rounds: self.seq_window_rounds.max(1),
            ragged_min_spread: self.ragged_min_spread.max(0.0),
            track_seq_alpha: self.track_seq_alpha,
            budget_grid: self.budget_grid.iter().copied().filter(|&b| b >= 1).collect(),
            budget_sensitivity: if self.budget_sensitivity.is_finite() && self.budget_sensitivity >= 0.0
            {
                self.budget_sensitivity
            } else {
                ControlConfig::default().budget_sensitivity
            },
        }
    }
}

/// What the engine reports after each decode round.
#[derive(Debug, Clone, Copy)]
pub struct RoundObservation {
    pub round: u64,
    /// Decode batch size this round.
    pub batch: usize,
    /// γ in effect this round.
    pub gamma: usize,
    /// Draft tokens proposed (batch · γ).
    pub proposed: u64,
    /// Draft tokens accepted by rejection sampling.
    pub accepted: u64,
    /// Tokens committed this round (accepted + one per sequence).
    pub emitted: u64,
    /// Stage costs on the engine clock (the paper's T_D, T_T, T_reject).
    pub t_draft: f64,
    pub t_verify: f64,
    pub t_reject: f64,
    /// Verify-expert budget the round's target forward ran under
    /// (`None` = unbudgeted — the backend's [`crate::spec::SdBackend::verify_budget`]
    /// at verify time). Budgeted rounds feed a separate cost column and
    /// the acceptance-vs-budget curve so the unbudgeted table stays pure.
    pub budget: Option<usize>,
}

/// One sequence's acceptance outcome in one decode round — the
/// per-sequence accounting the engine reports alongside the aggregate
/// [`RoundObservation`], feeding the windowed per-sequence α̂ᵢ estimators
/// behind ragged-γ decisions.
#[derive(Debug, Clone, Copy)]
pub struct SeqRoundSample {
    pub seq: SeqId,
    /// The draft length this sequence ran this round (its γᵢ).
    pub gamma: usize,
    /// Draft tokens accepted by rejection sampling (≤ γᵢ).
    pub accepted: usize,
}

/// Windowed per-sequence acceptance estimator. Each speculative round
/// contributes a `(attempts, successes)` pair — the chain consumes
/// `accepted + 1` Bernoulli(α) trials when it rejects inside the draft
/// and `γ` when it accepts everything — so the window ratio
/// `Σ successes / Σ attempts` is the maximum-likelihood α̂ for the
/// truncated-geometric acceptance process, and it composes across rounds
/// with *different* γᵢ (unlike an Eq. 5 inversion, which needs one γ).
#[derive(Debug, Clone, Default)]
struct SeqWindow {
    /// Ring of (attempts, successes) from recent speculative rounds.
    samples: VecDeque<(u32, u32)>,
}

impl SeqWindow {
    fn push(&mut self, gamma: usize, accepted: usize, cap: usize) {
        if gamma == 0 {
            return; // AR rounds carry no acceptance signal
        }
        let attempts = if accepted < gamma { accepted + 1 } else { gamma };
        self.samples.push_back((attempts as u32, accepted as u32));
        while self.samples.len() > cap {
            self.samples.pop_front();
        }
    }

    /// α̂ over a **full** window; `None` during warm-up (fewer than
    /// `window` speculative rounds observed), when callers fall back to
    /// the batch-level estimate.
    fn alpha(&self, window: usize) -> Option<f64> {
        if self.samples.len() < window {
            return None;
        }
        let (att, succ) = self
            .samples
            .iter()
            .fold((0u64, 0u64), |(a, s), &(at, su)| (a + at as u64, s + su as u64));
        if att == 0 {
            return None;
        }
        Some((succ as f64 / att as f64).clamp(0.0, 1.0))
    }
}

/// Exponentially-weighted moving average with a sample counter.
#[derive(Debug, Clone, Copy, Default)]
pub struct Ewma {
    value: f64,
    samples: u64,
}

/// Smoothing weight for cost-table entries.
const COST_BETA: f64 = 0.3;

impl Ewma {
    pub fn update(&mut self, x: f64) {
        if self.samples == 0 {
            self.value = x;
        } else {
            self.value = COST_BETA * x + (1.0 - COST_BETA) * self.value;
        }
        self.samples += 1;
    }

    pub fn get(&self) -> Option<f64> {
        (self.samples > 0).then_some(self.value)
    }

    pub fn samples(&self) -> u64 {
        self.samples
    }
}

/// Batch sizes are bucketed to powers of two so estimates pool across the
/// small batch fluctuations continuous batching produces.
pub fn bucket_of(batch: usize) -> usize {
    batch.max(1).next_power_of_two()
}

/// Sentinel key for the unbudgeted arm of the acceptance-vs-budget curve.
const NO_BUDGET_KEY: usize = usize::MAX;

/// Measured per-stage costs keyed by (batch bucket, verify width).
#[derive(Debug, Clone, Default)]
pub struct CostTable {
    /// (bucket, s = γ+1) → target forward time for the round
    /// (**unbudgeted** rounds only — budget off-switch purity).
    verify: BTreeMap<(usize, usize), Ewma>,
    /// (bucket, s, budget) → target forward time for budgeted rounds.
    budget_verify: BTreeMap<(usize, usize, usize), Ewma>,
    /// Online acceptance-vs-budget curve: budget key
    /// ([`NO_BUDGET_KEY`] for unbudgeted rounds) → per-round
    /// accepted/proposed ratio EWMA. The unbudgeted arm is the baseline
    /// the budgeted arms' degradation ratios are measured against.
    accept_by_budget: BTreeMap<usize, Ewma>,
    /// bucket → per-forward draft time.
    draft: BTreeMap<usize, Ewma>,
    /// Rejection cost per verified row (B·(γ+1) rows per round).
    reject_per_row: Ewma,
}

impl CostTable {
    pub fn is_empty(&self) -> bool {
        self.verify.is_empty()
    }

    pub fn observe(&mut self, obs: &RoundObservation) {
        let bucket = bucket_of(obs.batch);
        match obs.budget {
            // Budgeted verify forwards are a different cost surface;
            // routing them into the plain table would corrupt the
            // unbudgeted anchors the off-switch guarantees depend on.
            Some(bud) => self
                .budget_verify
                .entry((bucket, obs.gamma + 1, bud))
                .or_default()
                .update(obs.t_verify),
            None => self
                .verify
                .entry((bucket, obs.gamma + 1))
                .or_default()
                .update(obs.t_verify),
        }
        if obs.gamma > 0 && obs.proposed > 0 {
            let key = obs.budget.unwrap_or(NO_BUDGET_KEY);
            self.accept_by_budget
                .entry(key)
                .or_default()
                .update((obs.accepted as f64 / obs.proposed as f64).clamp(0.0, 1.0));
        }
        if obs.gamma > 0 && obs.t_draft > 0.0 {
            self.draft
                .entry(bucket)
                .or_default()
                .update(obs.t_draft / obs.gamma as f64);
        }
        let rows = (obs.batch * (obs.gamma + 1)) as f64;
        if rows > 0.0 && obs.t_reject > 0.0 {
            self.reject_per_row.update(obs.t_reject / rows);
        }
    }

    /// Measured verify time of budgeted rounds at exactly
    /// (bucket, s, budget), if any have been observed.
    pub fn budget_verify_time(&self, bucket: usize, s: usize, budget: usize) -> Option<f64> {
        self.budget_verify
            .get(&(bucket, s, budget))
            .and_then(|e| e.get())
    }

    /// Smoothed per-round acceptance ratio at a budget arm (`None` = the
    /// unbudgeted baseline arm).
    pub fn accept_rate(&self, budget: Option<usize>) -> Option<f64> {
        self.accept_by_budget
            .get(&budget.unwrap_or(NO_BUDGET_KEY))
            .and_then(|e| e.get())
    }

    /// Measured acceptance degradation of a budget arm relative to the
    /// unbudgeted baseline: `accept_rate(budget) / accept_rate(None)`,
    /// clamped to [0, 1]. `None` until both arms have samples — callers
    /// fall back to the model prior (`α·cov^sens`).
    pub fn measured_budget_alpha_ratio(&self, budget: usize) -> Option<f64> {
        let base = self.accept_rate(None)?;
        let at = self.accept_rate(Some(budget))?;
        (base > 0.0).then(|| (at / base).clamp(0.0, 1.0))
    }

    /// The measured acceptance-vs-budget curve for reporting:
    /// `(budget, rate)` pairs, unbudgeted arm as `None`.
    pub fn accept_curve(&self) -> Vec<(Option<usize>, f64)> {
        self.accept_by_budget
            .iter()
            .filter_map(|(&k, e)| {
                e.get()
                    .map(|r| ((k != NO_BUDGET_KEY).then_some(k), r))
            })
            .collect()
    }

    pub fn verify_time(&self, bucket: usize, s: usize) -> Option<f64> {
        self.verify.get(&(bucket, s)).and_then(|e| e.get())
    }

    pub fn draft_per_forward(&self, bucket: usize) -> Option<f64> {
        self.draft.get(&bucket).and_then(|e| e.get())
    }

    pub fn reject_per_row(&self) -> Option<f64> {
        self.reject_per_row.get()
    }

    /// The observed verify entry at this bucket whose width is closest to
    /// `want_s` (more samples win ties). Returns `(s, time)`.
    pub fn verify_nearest(&self, bucket: usize, want_s: usize) -> Option<(usize, f64)> {
        self.verify
            .iter()
            .filter(|((b, _), e)| *b == bucket && e.samples > 0)
            .min_by_key(|((_, s), e)| {
                ((*s as i64 - want_s as i64).unsigned_abs(), u64::MAX - e.samples)
            })
            .map(|((_, s), e)| (*s, e.value))
    }

    /// The verify entry with the most samples across all buckets.
    pub fn busiest_verify(&self) -> Option<(usize, usize, f64)> {
        self.verify
            .iter()
            .filter(|(_, e)| e.samples > 0)
            .max_by_key(|(_, e)| e.samples)
            .map(|((b, s), e)| (*b, *s, e.value))
    }

    /// Measured target efficiency T(B,1)/T(B,s) for a bucket: requires an
    /// AR (s=1) observation and a speculative one (largest observed s>1).
    pub fn measured_target_efficiency(&self, bucket: usize) -> Option<(usize, f64)> {
        let t1 = self.verify_time(bucket, 1)?;
        self.verify
            .iter()
            .filter(|((b, s), e)| *b == bucket && *s > 1 && e.samples > 0)
            .max_by_key(|((_, s), _)| *s)
            .map(|((_, s), e)| (*s, t1 / e.value))
    }

    /// All (bucket, measured target efficiency) pairs, for reporting.
    pub fn target_efficiency_by_bucket(&self) -> Vec<(usize, f64)> {
        let buckets: BTreeSet<usize> = self.verify.keys().map(|(b, _)| *b).collect();
        buckets
            .into_iter()
            .filter_map(|b| self.measured_target_efficiency(b).map(|(_, te)| (b, te)))
            .collect()
    }
}

/// Snapshot of controller state for metrics/server reporting.
#[derive(Debug, Clone)]
pub struct ControllerState {
    pub policy: String,
    pub gamma: usize,
    /// Verify-expert budget currently applied by the controller (`None`
    /// when the budget axis is off or the joint argmax picked unbudgeted).
    pub budget: Option<usize>,
    pub alpha_hat: Option<f64>,
    pub sigma_hat: Option<f64>,
    pub intervals: u64,
    pub switches: u64,
    pub probes: u64,
    /// Rounds that ran a non-uniform per-sequence γ assignment.
    pub ragged_rounds: u64,
    /// Sequences currently carrying a per-sequence α̂ window.
    pub tracked_sequences: usize,
    /// Measured target efficiency per batch bucket (§3.1, online).
    pub target_efficiency: Vec<(usize, f64)>,
    /// Online acceptance-vs-budget curve (`None` = unbudgeted arm).
    pub accept_by_budget: Vec<(Option<usize>, f64)>,
    /// Bounded (round, new γ) switch log.
    pub history: Vec<(u64, usize)>,
}

impl ControllerState {
    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| match v {
            Some(x) => x.into(),
            None => Json::Null,
        };
        Json::from_pairs(vec![
            ("policy", self.policy.as_str().into()),
            ("gamma", self.gamma.into()),
            (
                "verify_budget",
                match self.budget {
                    Some(b) => b.into(),
                    None => Json::Null,
                },
            ),
            ("alpha_hat", opt(self.alpha_hat)),
            ("sigma_hat", opt(self.sigma_hat)),
            ("intervals", self.intervals.into()),
            ("switches", self.switches.into()),
            ("probes", self.probes.into()),
            ("ragged_rounds", self.ragged_rounds.into()),
            ("tracked_sequences", self.tracked_sequences.into()),
            (
                "target_efficiency",
                Json::Arr(
                    self.target_efficiency
                        .iter()
                        .map(|(b, te)| {
                            Json::from_pairs(vec![("bucket", (*b).into()), ("teff", (*te).into())])
                        })
                        .collect(),
                ),
            ),
            (
                "accept_by_budget",
                Json::Arr(
                    self.accept_by_budget
                        .iter()
                        .map(|(bud, rate)| {
                            Json::from_pairs(vec![
                                (
                                    "budget",
                                    match bud {
                                        Some(b) => (*b).into(),
                                        None => Json::Null,
                                    },
                                ),
                                ("rate", (*rate).into()),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "history",
                Json::Arr(
                    self.history
                        .iter()
                        .rev()
                        .take(HISTORY_JSON_CAP)
                        .rev()
                        .map(|(round, gamma)| {
                            Json::from_pairs(vec![
                                ("round", (*round).into()),
                                ("gamma", (*gamma).into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Switch-log capacity (the oldest entries are dropped once full).
const HISTORY_CAP: usize = 256;

/// How many of the most recent switches `ControllerState::to_json` emits.
const HISTORY_JSON_CAP: usize = 16;

/// The online speculation controller (owned by the engine).
pub struct SpecController {
    cfg: ControlConfig,
    policy: Box<dyn GammaPolicy>,
    gamma: usize,
    /// Verify-expert budget the controller currently wants applied. Stays
    /// `None` forever while `cfg.budget_grid` is empty (the controller
    /// then never overrides a statically-configured backend budget).
    budget: Option<usize>,
    bootstrapped: bool,
    alpha_hat: Option<f64>,
    sigma_hat: Option<f64>,
    costs: CostTable,
    last_batch: usize,
    /// Batch bucket of the most recent decision — a bucket change is a
    /// load-regime shift and triggers an immediate unguarded re-consult.
    last_bucket: Option<usize>,
    last_round: u64,
    // Accumulators for the open control interval.
    int_rounds: usize,
    int_gamma: usize,
    int_seq_rounds: u64,
    int_accepted: u64,
    int_emitted: u64,
    // Counters.
    intervals: u64,
    switches: u64,
    probes: u64,
    history: Vec<(u64, usize)>,
    /// Windowed per-sequence acceptance estimators (ragged mode only;
    /// entries are dropped when the engine releases a sequence).
    seq_windows: HashMap<SeqId, SeqWindow>,
    /// Reused per-round α̂ᵢ buffer (ragged mode), so steady-state rounds
    /// avoid a fresh B-sized allocation.
    alpha_scratch: Vec<f64>,
    /// Rounds that actually ran a non-uniform γ assignment.
    ragged_rounds: u64,
}

impl SpecController {
    pub fn new(cfg: ControlConfig) -> SpecController {
        let cfg = cfg.sanitized();
        let (policy, gamma0): (Box<dyn GammaPolicy>, usize) = match &cfg.policy {
            PolicyKind::Static { gamma } => (Box::new(StaticPolicy { gamma: *gamma }), *gamma),
            // Model-guided starts conservatively at AR; the bootstrap
            // consult picks the prior-α argmax before the first round.
            PolicyKind::ModelGuided { cost } => {
                (Box::new(ModelGuidedPolicy::new(cost.clone(), &cfg)), 0)
            }
        };
        SpecController {
            cfg,
            policy,
            gamma: gamma0,
            budget: None,
            bootstrapped: false,
            alpha_hat: None,
            sigma_hat: None,
            costs: CostTable::default(),
            last_batch: 1,
            last_bucket: None,
            last_round: 0,
            int_rounds: 0,
            int_gamma: 0,
            int_seq_rounds: 0,
            int_accepted: 0,
            int_emitted: 0,
            intervals: 0,
            switches: 0,
            probes: 0,
            history: Vec::new(),
            seq_windows: HashMap::new(),
            alpha_scratch: Vec::new(),
            ragged_rounds: 0,
        }
    }

    /// γ for the coming round. The first call runs the policy once so even
    /// round 0 uses a considered γ rather than a hard-coded one; after
    /// that, a batch-bucket change (the load regime moved) re-consults
    /// immediately — B is a *known input*, not noise, so waiting out a
    /// control interval (or hysteresis) would just burn rounds at a γ
    /// tuned for the old load.
    pub fn gamma_for_round(&mut self, batch: usize) -> usize {
        let batch = batch.max(1);
        let bucket = bucket_of(batch);
        if !self.bootstrapped || Some(bucket) != self.last_bucket {
            let regime_shift = self.bootstrapped;
            self.bootstrapped = true;
            if self.int_rounds > 0 {
                self.close_interval();
            }
            self.last_bucket = Some(bucket);
            self.last_batch = batch;
            self.consult(batch, self.last_round, regime_shift);
        }
        self.gamma
    }

    /// Per-sequence γᵢ for the coming round (ragged rounds). Runs the
    /// scalar [`SpecController::gamma_for_round`] consult first — regime
    /// decisions (bootstrap, batch-bucket shifts, the γ=0 AR fallback,
    /// hysteresis/dwell) are unchanged — then, with ragged mode on and a
    /// speculative regime in effect, refines per sequence through
    /// [`GammaPolicy::gamma_for_sequences`] using windowed α̂ᵢ (sequences
    /// still in warm-up fall back to the batch-level estimate). Rounds
    /// whose α̂ᵢ spread stays under `ragged_min_spread` — in particular
    /// every round of a uniform-α workload — run the scalar γ uniformly,
    /// bit-for-bit identical to the non-ragged controller.
    pub fn gammas_for_round(&mut self, seqs: &[SeqId], out: &mut Vec<usize>) {
        out.clear();
        let b = seqs.len();
        let g0 = self.gamma_for_round(b.max(1));
        if !self.cfg.ragged || g0 == 0 || b == 0 {
            out.extend(std::iter::repeat(g0).take(b));
            return;
        }
        let base = self.alpha_hat.unwrap_or(self.cfg.alpha_prior);
        // Quantize α̂ᵢ to a 0.01 grid: round-to-round estimator drift then
        // only moves γᵢ when an estimate crosses a grid line, damping
        // assignment jitter without a second smoothing stage. The buffer
        // is controller-owned scratch; the remaining per-round work of a
        // ragged decision (the water-fill candidate sweep in the policy)
        // is O(distinct-α̂ · γmax) small vectors — a deliberate, bounded
        // exception to the engine's zero-alloc round discipline, spent
        // only in ragged mode on rounds whose α̂ spread clears the gate.
        let quant = |a: f64| (a * 100.0).round() / 100.0;
        let mut alphas = std::mem::take(&mut self.alpha_scratch);
        alphas.clear();
        let (mut lo, mut hi) = (f64::MAX, f64::MIN);
        for &s in seqs {
            let a = quant(self.seq_alpha_hat(s).unwrap_or(base).clamp(0.0, 1.0));
            lo = lo.min(a);
            hi = hi.max(a);
            alphas.push(a);
        }
        if hi - lo < self.cfg.ragged_min_spread {
            self.alpha_scratch = alphas;
            out.extend(std::iter::repeat(g0).take(b));
            return;
        }
        let est = Estimates {
            batch: b,
            alpha: self.alpha_hat,
            sigma: self.sigma_hat,
            current_gamma: g0,
            current_budget: self.budget,
            regime_shift: false,
            costs: &self.costs,
        };
        let bud = self.policy.gamma_budget_for_sequences(&est, &alphas, out);
        if self.owns_budget() {
            self.budget = bud;
        }
        self.alpha_scratch = alphas;
        debug_assert_eq!(out.len(), b, "policy must fill one γ per sequence");
        for g in out.iter_mut() {
            // Floor at 1 inside a speculative regime: a sequence at γᵢ=0
            // would stop producing acceptance samples, freezing its
            // window at the stale low α̂ᵢ that earned it γᵢ=0 — permanent
            // starvation. One draft token per round keeps the estimator
            // live (the per-sequence analogue of the scalar loop's AR
            // probes) for the price of one extra verify token.
            *g = (*g).clamp(1, self.cfg.gamma_max);
        }
        let first = out[0];
        if out.iter().any(|&g| g != first) {
            self.ragged_rounds += 1;
        }
    }

    /// Record per-sequence acceptance outcomes (ragged mode, or
    /// `track_seq_alpha` for mix-aware admission). Uses the window
    /// capacity from `seq_window_rounds`; a no-op otherwise so the map
    /// cannot grow in scalar deployments.
    pub fn observe_sequences(&mut self, samples: &[SeqRoundSample]) {
        if !self.cfg.ragged && !self.cfg.track_seq_alpha {
            return;
        }
        let cap = self.cfg.seq_window_rounds;
        for s in samples {
            if s.gamma > 0 {
                self.seq_windows
                    .entry(s.seq)
                    .or_default()
                    .push(s.gamma, s.accepted, cap);
            }
        }
    }

    /// Windowed per-sequence α̂ᵢ — `None` until the sequence has a full
    /// window of speculative rounds (warm-up; callers fall back to the
    /// batch-level [`SpecController::alpha_hat`]).
    pub fn seq_alpha_hat(&self, seq: SeqId) -> Option<f64> {
        self.seq_windows
            .get(&seq)
            .and_then(|w| w.alpha(self.cfg.seq_window_rounds))
    }

    /// Drop a finished/released sequence's estimator state.
    pub fn release_sequence(&mut self, seq: SeqId) {
        self.seq_windows.remove(&seq);
    }

    /// Currently-applied γ (without consulting).
    pub fn gamma(&self) -> usize {
        self.gamma
    }

    /// Verify-expert budget the controller currently wants the backend to
    /// run (`None` = unbudgeted). Meaningful only when the controller
    /// [owns the budget axis](SpecController::owns_budget).
    pub fn verify_budget(&self) -> Option<usize> {
        self.budget
    }

    /// Whether the controller owns the verify-budget axis (a non-empty
    /// `budget_grid`). When it does, the engine pushes
    /// [`SpecController::verify_budget`] into the backend before every
    /// round; when it doesn't, any statically-configured backend budget
    /// (`--verify-budget`) is left untouched.
    pub fn owns_budget(&self) -> bool {
        !self.cfg.budget_grid.is_empty()
    }

    pub fn alpha_hat(&self) -> Option<f64> {
        self.alpha_hat
    }

    pub fn sigma_hat(&self) -> Option<f64> {
        self.sigma_hat
    }

    pub fn costs(&self) -> &CostTable {
        &self.costs
    }

    /// Record one decode round; on interval boundaries, refresh the
    /// estimates and consult the policy.
    pub fn observe(&mut self, obs: RoundObservation) {
        // The engine's round clock is the controller's only notion of
        // time (interval boundaries, switch history, probe cadence); a
        // backwards-running clock means the engine is feeding rounds out
        // of order and every windowed estimate silently mixes epochs.
        debug_assert!(
            obs.round >= self.last_round,
            "RoundObservation clock must be monotone: got round {} after {}",
            obs.round,
            self.last_round
        );
        self.last_batch = obs.batch.max(1);
        self.last_round = obs.round;
        self.costs.observe(&obs);
        if self.int_rounds > 0 && obs.gamma != self.int_gamma {
            // γ changed mid-interval (probe or regime shift): close the
            // partial interval so α̂ never mixes γ regimes.
            self.close_interval();
        }
        self.int_gamma = obs.gamma;
        self.int_rounds += 1;
        self.int_seq_rounds += obs.batch as u64;
        self.int_accepted += obs.accepted;
        self.int_emitted += obs.emitted;
        if self.int_seq_rounds >= self.cfg.interval_seq_rounds as u64 {
            self.close_interval();
            self.consult(obs.batch, obs.round, false);
        }
    }

    fn close_interval(&mut self) {
        if self.int_seq_rounds > 0 {
            let gamma = self.int_gamma;
            let seq_rounds = self.int_seq_rounds as f64;
            let beta = self.cfg.alpha_smoothing;
            // σ and α carry signal only in speculative intervals: at γ=0
            // σ is identically 1, and blending that in would drag σ̂
            // toward 1 during AR stretches and corrupt the TPOT estimate
            // when speculation resumes. α̂ is the γ-invariant quantity;
            // σ for any γ is re-derived from it via Eq. 5 where needed.
            if gamma > 0 {
                let sigma = self.int_emitted as f64 / (seq_rounds * (gamma + 1) as f64);
                self.sigma_hat = Some(blend(self.sigma_hat, sigma, beta));
                // Mean accepted length + the bonus token, over the γ+1
                // maximum, is exactly Eq. 5's σ — invert it for α̂.
                let mean_accept = self.int_accepted as f64 / seq_rounds;
                let lo = 1.0 / (gamma + 1) as f64;
                let sig = ((mean_accept + 1.0) / (gamma + 1) as f64).clamp(lo, 1.0);
                let alpha = theory::alpha_from_sigma(sig, gamma);
                self.alpha_hat = Some(blend(self.alpha_hat, alpha, beta));
            }
            self.intervals += 1;
        }
        self.int_rounds = 0;
        self.int_seq_rounds = 0;
        self.int_accepted = 0;
        self.int_emitted = 0;
    }

    fn consult(&mut self, batch: usize, round: u64, regime_shift: bool) {
        let est = Estimates {
            batch: batch.max(1),
            alpha: self.alpha_hat,
            sigma: self.sigma_hat,
            current_gamma: self.gamma,
            current_budget: self.budget,
            regime_shift,
            costs: &self.costs,
        };
        let decision = self.policy.decide(&est);
        match decision.kind {
            DecisionKind::Probe => self.probes += 1,
            DecisionKind::Switch if decision.gamma != self.gamma => self.switches += 1,
            _ => {}
        }
        if self.owns_budget() {
            self.budget = decision.budget;
        }
        if decision.gamma != self.gamma {
            self.gamma = decision.gamma;
            // Ring semantics: keep the most recent HISTORY_CAP switches.
            if self.history.len() == HISTORY_CAP {
                self.history.remove(0);
            }
            self.history.push((round, decision.gamma));
        }
    }

    /// Measured round economics at the current γ: `(round_time,
    /// reference_batch, round_len)`. The reference batch is the *actual*
    /// batch the engine has been running (not its power-of-two bucket) —
    /// the cost EWMAs track recent rounds, which ran at ≈ `last_batch`
    /// sequences, so attributing them to the bucket top would understate
    /// TPOT by up to 2× and over-admit against the SLO.
    fn round_economics(&self) -> Option<(f64, usize, f64)> {
        let gamma = self.gamma;
        let bucket = bucket_of(self.last_batch);
        let (b0, t_verify) = match self.costs.verify_nearest(bucket, gamma + 1) {
            Some((_, t)) => (self.last_batch, t),
            None => match self.costs.busiest_verify() {
                Some((b, _, t)) => (b, t),
                None => return None,
            },
        };
        let b0 = b0.max(1);
        let t_draft = gamma as f64
            * self
                .costs
                .draft_per_forward(bucket_of(b0))
                .unwrap_or(0.0);
        let t_rej = self.costs.reject_per_row().unwrap_or(0.0) * (b0 * (gamma + 1)) as f64;
        let round_len = if gamma == 0 {
            1.0
        } else {
            // Derive σ for the *current* γ from the γ-invariant α̂ (σ̂ is
            // an observability value tied to whatever γ it was measured
            // at, so it cannot be used across γ regimes directly).
            let alpha = self.alpha_hat.unwrap_or(self.cfg.alpha_prior);
            theory::expected_round_length(alpha, gamma)
        };
        Some((t_verify + t_draft + t_rej, b0, round_len))
    }

    /// Predicted seconds/token at batch size `b` from the measured round
    /// economics (linearly scaled from the reference batch — the same
    /// conservative rule the engine's built-in SLO estimator uses).
    pub fn est_tpot(&self, b: usize) -> f64 {
        match self.round_economics() {
            None => 0.0,
            Some((round, b0, round_len)) => {
                let scale = (b as f64 / b0 as f64).max(0.25);
                round * scale / round_len.max(1e-9)
            }
        }
    }

    /// Controller-driven batch ceiling for the scheduler. Without a TPOT
    /// SLO this is just `max_batch`; with one, the measured economics feed
    /// the scheduler's ceiling search. Before any data exists a small
    /// pilot batch is admitted so the estimators can observe something.
    pub fn batch_ceiling(&self, scheduler: &Scheduler) -> usize {
        self.slo_batch_ceiling(scheduler, scheduler.config.tpot_slo)
    }

    /// The same priced ceiling search for an arbitrary TPOT SLO — this is
    /// how **per-tenant-class** batch ceilings are derived (each class's
    /// SLO against the one measured cost table), so the class-aware
    /// admission policy's caps are priced, not guessed.
    pub fn slo_batch_ceiling(&self, scheduler: &Scheduler, tpot_slo: Option<f64>) -> usize {
        let max = scheduler.config.max_batch;
        if tpot_slo.is_none() || max == 0 {
            return max;
        }
        // Hoist the b-independent economics out of the ceiling search so
        // the per-candidate closure is pure arithmetic (the search runs
        // every admit call).
        match self.round_economics() {
            None => 4.min(max),
            Some((round, b0, round_len)) => {
                Scheduler::ceiling_for(&scheduler.config, tpot_slo, |b| {
                    let scale = (b as f64 / b0 as f64).max(0.25);
                    round * scale / round_len.max(1e-9)
                })
            }
        }
    }

    /// Per-class batch ceilings for a tenant table (indexed by
    /// [`crate::batching::ClassId`]): each class's TPOT SLO through
    /// [`SpecController::slo_batch_ceiling`]. Classes without an SLO get
    /// `max_batch`.
    pub fn class_ceilings(&self, scheduler: &Scheduler, tenants: &[TenantClass]) -> Vec<usize> {
        tenants
            .iter()
            .map(|t| self.slo_batch_ceiling(scheduler, t.tpot_slo))
            .collect()
    }

    /// The priced speculative-regime test (see
    /// [`crate::scheduler::RegimeOracle`]): best-γ speedup vs AR at
    /// `batch` for an acceptance mix `alpha`, from the policy's
    /// measured-cost-anchored Eq. 4 surface. `None` falls back to the
    /// controller's own α̂ (or prior).
    pub fn predicted_speedup(&self, batch: usize, alpha: Option<f64>) -> f64 {
        let est = Estimates {
            batch: batch.max(1),
            alpha: self.alpha_hat,
            sigma: self.sigma_hat,
            current_gamma: self.gamma,
            current_budget: self.budget,
            regime_shift: false,
            costs: &self.costs,
        };
        self.policy.predict(&est, alpha).1
    }

    /// Per-class regime estimates for observability: at the current batch
    /// regime, what γ and speedup the policy predicts for each class's α
    /// hint. Published in the server's per-tenant stats.
    pub fn class_estimates(&self, tenants: &[TenantClass], batch: usize) -> Vec<ClassRegimeEstimate> {
        let est = Estimates {
            batch: batch.max(1),
            alpha: self.alpha_hat,
            sigma: self.sigma_hat,
            current_gamma: self.gamma,
            current_budget: self.budget,
            regime_shift: false,
            costs: &self.costs,
        };
        tenants
            .iter()
            .enumerate()
            .map(|(i, t)| {
                let alpha = t.alpha_hint.or(self.alpha_hat).unwrap_or(self.cfg.alpha_prior);
                let (gamma, speedup) = self.policy.predict(&est, Some(alpha));
                ClassRegimeEstimate {
                    class: i,
                    name: t.name.clone(),
                    alpha,
                    gamma,
                    speedup,
                }
            })
            .collect()
    }

    pub fn state(&self) -> ControllerState {
        ControllerState {
            policy: self.policy.name().to_string(),
            gamma: self.gamma,
            budget: self.budget,
            alpha_hat: self.alpha_hat,
            sigma_hat: self.sigma_hat,
            intervals: self.intervals,
            switches: self.switches,
            probes: self.probes,
            ragged_rounds: self.ragged_rounds,
            tracked_sequences: self.seq_windows.len(),
            target_efficiency: self.costs.target_efficiency_by_bucket(),
            accept_by_budget: self.costs.accept_curve(),
            history: self.history.clone(),
        }
    }
}

impl RegimeOracle for SpecController {
    fn predicted_speedup(&self, batch: usize, alpha: Option<f64>) -> f64 {
        SpecController::predicted_speedup(self, batch, alpha)
    }
}

/// One tenant class's priced regime estimate (observability surface for
/// the server's per-tenant stats).
#[derive(Debug, Clone)]
pub struct ClassRegimeEstimate {
    pub class: usize,
    pub name: String,
    /// The α the estimate was priced at (class hint, else batch α̂/prior).
    pub alpha: f64,
    pub gamma: usize,
    pub speedup: f64,
}

impl ClassRegimeEstimate {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("class", self.class.into()),
            ("name", self.name.as_str().into()),
            ("alpha", self.alpha.into()),
            ("gamma", self.gamma.into()),
            ("speedup", self.speedup.into()),
        ])
    }
}

fn blend(prev: Option<f64>, x: f64, beta: f64) -> f64 {
    match prev {
        None => x,
        Some(p) => beta * x + (1.0 - beta) * p,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::hardware::platform_2x_gpu_a;
    use crate::scheduler::{Scheduler, SchedulerConfig};
    use crate::util::rng::Rng;

    fn roofline_spec() -> CostModelSpec {
        let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
        let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
        CostModelSpec::roofline(target, draft)
    }

    /// Simulate the acceptance outcome of one sequence-round: Bernoulli(α)
    /// chain truncation, exactly what the engine's rejection sampler does
    /// against the synthetic backend.
    fn sim_round(rng: &mut Rng, alpha: f64, gamma: usize, batch: usize) -> (u64, u64) {
        let mut accepted = 0u64;
        for _ in 0..batch {
            for _ in 0..gamma {
                if rng.bernoulli(alpha) {
                    accepted += 1;
                } else {
                    break;
                }
            }
        }
        (accepted, accepted + batch as u64)
    }

    fn observe_rounds(
        ctl: &mut SpecController,
        rng: &mut Rng,
        alpha: f64,
        gamma: usize,
        batch: usize,
        rounds: usize,
    ) {
        // Resume the controller's own round clock so successive calls
        // keep the observation stream monotone (the clock invariant the
        // controller asserts on).
        let start = ctl.last_round + 1;
        for r in 0..rounds {
            let (accepted, emitted) = sim_round(rng, alpha, gamma, batch);
            ctl.observe(RoundObservation {
                round: start + r as u64,
                batch,
                gamma,
                proposed: (batch * gamma) as u64,
                accepted,
                emitted,
                t_draft: 0.001 * gamma as f64,
                t_verify: 0.01,
                t_reject: 1e-4,
                budget: None,
            });
        }
    }

    #[test]
    fn bucket_and_ewma_basics() {
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(3), 4);
        assert_eq!(bucket_of(32), 32);
        assert_eq!(bucket_of(33), 64);
        let mut e = Ewma::default();
        assert_eq!(e.get(), None);
        e.update(10.0);
        assert_eq!(e.get(), Some(10.0));
        e.update(0.0);
        let v = e.get().unwrap();
        assert!(v < 10.0 && v > 0.0);
        assert_eq!(e.samples(), 2);
    }

    #[test]
    fn cost_table_records_and_measures_target_efficiency() {
        let mut t = CostTable::default();
        assert!(t.is_empty());
        let mk = |gamma: usize, t_verify: f64| RoundObservation {
            round: 0,
            batch: 16,
            gamma,
            proposed: 0,
            accepted: 0,
            emitted: 16,
            t_draft: 0.004,
            t_verify,
            t_reject: 1e-4,
            budget: None,
        };
        for _ in 0..5 {
            t.observe(&mk(0, 0.010)); // AR rounds: s = 1
            t.observe(&mk(3, 0.012)); // SD rounds: s = 4
        }
        assert!(!t.is_empty());
        assert!(t.verify_time(16, 1).is_some());
        assert!(t.verify_time(16, 4).is_some());
        let (s, teff) = t.measured_target_efficiency(16).unwrap();
        assert_eq!(s, 4);
        assert!((teff - 0.010 / 0.012).abs() < 1e-6, "teff={teff}");
        let all = t.target_efficiency_by_bucket();
        assert_eq!(all.len(), 1);
        assert_eq!(all[0].0, 16);
        // Nearest-s lookup prefers the closest width.
        assert_eq!(t.verify_nearest(16, 4).unwrap().0, 4);
        assert_eq!(t.verify_nearest(16, 1).unwrap().0, 1);
        assert!(t.verify_nearest(8, 1).is_none());
        assert!(t.draft_per_forward(16).is_some());
        assert!(t.reject_per_row().is_some());
    }

    #[test]
    fn sigma_window_converges_to_true_alpha() {
        // Satellite requirement: σ-window convergence. Feed simulated
        // rounds at a known α and check α̂ and σ̂ converge.
        for &alpha in &[0.5, 0.8, 0.95] {
            let gamma = 3;
            let mut ctl = SpecController::new(ControlConfig::static_gamma(gamma));
            let mut rng = Rng::seeded(42);
            observe_rounds(&mut ctl, &mut rng, alpha, gamma, 16, 400);
            let a = ctl.alpha_hat().expect("alpha estimated");
            assert!((a - alpha).abs() < 0.05, "α̂={a} vs α={alpha}");
            let s = ctl.sigma_hat().expect("sigma estimated");
            let want = theory::sigma_from_alpha(alpha, gamma);
            assert!((s - want).abs() < 0.05, "σ̂={s} vs Eq.5 {want}");
            assert!(ctl.state().intervals > 0);
        }
    }

    #[test]
    fn static_policy_never_moves_gamma() {
        let mut ctl = SpecController::new(ControlConfig::static_gamma(5));
        assert_eq!(ctl.gamma_for_round(8), 5);
        let mut rng = Rng::seeded(1);
        observe_rounds(&mut ctl, &mut rng, 0.3, 5, 8, 100);
        assert_eq!(ctl.gamma(), 5);
        assert_eq!(ctl.state().switches, 0);
    }

    #[test]
    fn model_guided_bootstraps_speculative_at_small_batch() {
        let mut ctl = SpecController::new(ControlConfig::model_guided(roofline_spec()));
        // At B=1 the MoE target is totally memory-bound: SD should win the
        // bootstrap consult with the default α prior.
        let g = ctl.gamma_for_round(1);
        assert!(g >= 1, "expected speculative bootstrap at B=1, got γ={g}");
    }

    #[test]
    fn interval_flushes_when_gamma_changes_midstream() {
        let mut ctl = SpecController::new(ControlConfig {
            interval_seq_rounds: 10_000, // interval would normally stay open
            ..ControlConfig::static_gamma(2)
        });
        let mut rng = Rng::seeded(3);
        observe_rounds(&mut ctl, &mut rng, 0.9, 2, 4, 10);
        assert_eq!(ctl.state().intervals, 0); // interval still open
        observe_rounds(&mut ctl, &mut rng, 0.9, 3, 4, 1); // γ changed
        assert_eq!(ctl.state().intervals, 1, "partial interval must flush");
    }

    #[test]
    fn bucket_shift_triggers_immediate_reconsult() {
        // Model-guided at a small batch picks a speculative γ; when the
        // load jumps to a compute-bound batch the very next round must
        // already run the re-seated γ (no interval/hysteresis lag).
        let mut ctl = SpecController::new(ControlConfig::model_guided(roofline_spec()));
        let g_small = ctl.gamma_for_round(4);
        assert!(g_small >= 1, "γ={g_small}");
        let g_huge = ctl.gamma_for_round(4096);
        assert_eq!(g_huge, 0, "bucket shift must re-seat γ to AR instantly");
        // And back: the small-batch regime re-enables speculation.
        let g_back = ctl.gamma_for_round(4);
        assert!(g_back >= 1, "γ={g_back}");
    }

    #[test]
    fn batch_ceiling_pilot_then_slo_bound() {
        let cfg = ControlConfig::static_gamma(3);
        let mut ctl = SpecController::new(cfg);
        let sched = Scheduler::new(SchedulerConfig {
            max_batch: 64,
            admit_reserve_tokens: 0,
            tpot_slo: Some(0.02),
        });
        // No data yet: pilot batch.
        assert_eq!(ctl.batch_ceiling(&sched), 4);
        // Feed rounds at B=16 where TPOT is comfortably inside the SLO.
        let mut rng = Rng::seeded(9);
        observe_rounds(&mut ctl, &mut rng, 0.9, 3, 16, 50);
        let c = ctl.batch_ceiling(&sched);
        assert!(c >= 16, "SLO should allow at least the observed batch: {c}");
        // A much tighter SLO must clamp the ceiling down.
        let tight = Scheduler::new(SchedulerConfig {
            max_batch: 64,
            admit_reserve_tokens: 0,
            tpot_slo: Some(1e-5),
        });
        assert!(ctl.batch_ceiling(&tight) < c);
        // No SLO: ceiling is max_batch regardless of data.
        let free = Scheduler::new(SchedulerConfig {
            max_batch: 64,
            admit_reserve_tokens: 0,
            tpot_slo: None,
        });
        assert_eq!(ctl.batch_ceiling(&free), 64);
    }

    /// Feed one sequence `rounds` speculative outcomes at a fixed
    /// per-round accept count (deterministic window content).
    fn feed_seq(ctl: &mut SpecController, seq: u64, gamma: usize, accepted: usize, rounds: usize) {
        for _ in 0..rounds {
            ctl.observe_sequences(&[SeqRoundSample {
                seq,
                gamma,
                accepted,
            }]);
        }
    }

    #[test]
    fn seq_window_warmup_falls_back_to_batch_estimate() {
        // Satellite edge case: a sequence with fewer than `window`
        // observations has no per-seq α̂ and the ragged path hands it the
        // batch-level estimate's γ.
        let cfg = ControlConfig {
            ragged: true,
            seq_window_rounds: 8,
            ..ControlConfig::model_guided(roofline_spec())
        };
        let mut ctl = SpecController::new(cfg);
        // Seq 1: full window at a hard α (γ=4, 0 accepted → α̂ ≈ 0).
        feed_seq(&mut ctl, 1, 4, 0, 8);
        assert!(ctl.seq_alpha_hat(1).is_some());
        assert!(ctl.seq_alpha_hat(1).unwrap() < 0.05);
        // Seq 2: only 3 observations — still warming up.
        feed_seq(&mut ctl, 2, 4, 4, 3);
        assert_eq!(ctl.seq_alpha_hat(2), None, "warm-up must report None");
        // Ragged assignment at a small (memory-bound) batch: the hard
        // sequence gets a shallower draft than the warm-up sequence,
        // which inherits the batch-level prior (0.8 by default).
        let mut out = Vec::new();
        ctl.gammas_for_round(&[1, 2], &mut out);
        assert_eq!(out.len(), 2);
        assert!(
            out[0] < out[1],
            "hard seq should draft shallower than warm-up seq: {out:?}"
        );
        // Depths are floored at 1 in speculative regimes so every
        // sequence keeps emitting acceptance samples — a γᵢ=0 assignment
        // would freeze its window at the stale α̂ᵢ forever.
        assert!(out[0] >= 1, "ragged depths must stay probeable: {out:?}");
        // Releasing drops the window; the sequence re-enters warm-up.
        ctl.release_sequence(1);
        assert_eq!(ctl.seq_alpha_hat(1), None);
        assert_eq!(ctl.state().tracked_sequences, 1);
    }

    #[test]
    fn seq_window_estimates_track_true_alpha() {
        // The MLE ratio over mixed-γ windows recovers α.
        let cfg = ControlConfig {
            ragged: true,
            seq_window_rounds: 64,
            ..ControlConfig::static_gamma(4)
        };
        let mut ctl = SpecController::new(cfg);
        let mut rng = Rng::seeded(77);
        let alpha = 0.7;
        for r in 0..400 {
            // Alternate γ 3 and 5: the estimator must compose across γ.
            let gamma = if r % 2 == 0 { 3 } else { 5 };
            let mut accepted = 0;
            for _ in 0..gamma {
                if rng.bernoulli(alpha) {
                    accepted += 1;
                } else {
                    break;
                }
            }
            ctl.observe_sequences(&[SeqRoundSample {
                seq: 9,
                gamma,
                accepted,
            }]);
        }
        let a = ctl.seq_alpha_hat(9).expect("window full");
        assert!((a - alpha).abs() < 0.1, "α̂ᵢ={a} vs α={alpha}");
    }

    #[test]
    fn ragged_uniform_alpha_reproduces_scalar_bit_for_bit() {
        // The issue's property: uniform-α inputs reproduce today's scalar
        // behavior exactly. Two controllers — ragged on/off — fed the
        // same observation stream must agree on every round's assignment.
        let mk = |ragged: bool| {
            SpecController::new(ControlConfig {
                ragged,
                ..ControlConfig::model_guided(roofline_spec())
            })
        };
        let mut a = mk(true);
        let mut b = mk(false);
        let mut rng = Rng::seeded(5);
        let seqs: Vec<u64> = (0..8).collect();
        for round in 0..60u64 {
            let mut out_a = Vec::new();
            let mut out_b = Vec::new();
            a.gammas_for_round(&seqs, &mut out_a);
            b.gammas_for_round(&seqs, &mut out_b);
            assert_eq!(out_a, out_b, "round {round}");
            assert!(out_a.iter().all(|&g| g == out_a[0]), "must stay uniform");
            let gamma = out_a[0];
            let (accepted, emitted) = sim_round(&mut rng, 0.85, gamma, seqs.len());
            let samples: Vec<SeqRoundSample> = seqs
                .iter()
                .map(|&s| SeqRoundSample {
                    seq: s,
                    gamma,
                    accepted: (accepted / seqs.len() as u64) as usize,
                })
                .collect();
            a.observe_sequences(&samples);
            let obs = RoundObservation {
                round,
                batch: seqs.len(),
                gamma,
                proposed: (seqs.len() * gamma) as u64,
                accepted,
                emitted,
                t_draft: 0.001 * gamma as f64,
                t_verify: 0.01,
                t_reject: 1e-4,
                budget: None,
            };
            a.observe(obs);
            b.observe(obs);
        }
        assert_eq!(a.state().ragged_rounds, 0, "uniform α must never go ragged");
    }

    #[test]
    fn ragged_respects_regime_shifts() {
        // Regime-shift re-consult with ragged γ (satellite edge case): a
        // bimodal batch runs ragged at a small batch, collapses to the
        // uniform γ=0 AR fallback the moment the bucket jumps to a
        // compute-bound size, and resumes ragged refinement on return.
        let cfg = ControlConfig {
            ragged: true,
            seq_window_rounds: 4,
            ..ControlConfig::model_guided(roofline_spec())
        };
        let mut ctl = SpecController::new(cfg);
        // Two full windows: seq 1 easy (all accepted at γ=6), seq 2 hard.
        feed_seq(&mut ctl, 1, 6, 6, 4);
        feed_seq(&mut ctl, 2, 6, 0, 4);
        let mut out = Vec::new();
        ctl.gammas_for_round(&[1, 2], &mut out);
        assert!(out[0] > out[1], "bimodal batch should be ragged: {out:?}");
        assert!(ctl.state().ragged_rounds >= 1);
        // Compute-bound bucket: uniform AR for everyone, instantly.
        let big: Vec<u64> = (0..4096).collect();
        let mut out_big = Vec::new();
        ctl.gammas_for_round(&big, &mut out_big);
        assert_eq!(out_big.len(), 4096);
        assert!(out_big.iter().all(|&g| g == 0), "AR fallback must stay uniform");
        // Back to the small regime: ragged again, same ordering.
        let mut out_back = Vec::new();
        ctl.gammas_for_round(&[1, 2], &mut out_back);
        assert!(out_back[0] > out_back[1], "{out_back:?}");
    }

    #[test]
    fn predicted_speedup_traces_the_band_and_class_surfaces() {
        let mut ctl = SpecController::new(ControlConfig::model_guided(roofline_spec()));
        // Memory-bound batch: inside the band; compute-bound: out of it.
        let s8 = ctl.predicted_speedup(8, Some(0.9));
        assert!(s8 > 1.2, "B=8 α=0.9 should be well inside the band: {s8}");
        let s4096 = ctl.predicted_speedup(4096, Some(0.9));
        assert!((s4096 - 1.0).abs() < 1e-9, "B=4096 should fall back to AR: {s4096}");
        // Harder mixes predict less speedup at the same batch.
        assert!(ctl.predicted_speedup(8, Some(0.4)) < s8);
        // The RegimeOracle trait view agrees with the inherent method.
        let oracle: &dyn crate::scheduler::RegimeOracle = &ctl;
        assert_eq!(oracle.predicted_speedup(8, Some(0.9)), s8);
        // Per-class estimates price each class's hint.
        let mut easy = TenantClass::new("easy");
        easy.alpha_hint = Some(0.92);
        let mut hard = TenantClass::new("hard");
        hard.alpha_hint = Some(0.45);
        let ests = ctl.class_estimates(&[easy, hard], 8);
        assert_eq!(ests.len(), 2);
        assert!(ests[0].speedup > ests[1].speedup);
        assert!(ests[0].gamma >= ests[1].gamma);
        assert!(ests[0].to_json().to_string().contains("\"speedup\""));
        // Per-class ceilings: a tight-TPOT class gets a lower ceiling
        // than an SLO-free one once economics exist.
        let sched = Scheduler::new(SchedulerConfig {
            max_batch: 64,
            admit_reserve_tokens: 0,
            tpot_slo: None,
        });
        let mut rng = Rng::seeded(3);
        observe_rounds(&mut ctl, &mut rng, 0.9, 3, 16, 50);
        let mut tight = TenantClass::new("tight");
        tight.tpot_slo = Some(1e-5);
        let free = TenantClass::new("free");
        let ceilings = ctl.class_ceilings(&sched, &[tight, free]);
        assert_eq!(ceilings.len(), 2);
        assert!(ceilings[0] < ceilings[1], "{ceilings:?}");
        assert_eq!(ceilings[1], 64);
    }

    #[test]
    fn track_seq_alpha_enables_windows_without_ragged() {
        let cfg = ControlConfig {
            track_seq_alpha: true,
            seq_window_rounds: 4,
            ..ControlConfig::static_gamma(4)
        };
        let mut ctl = SpecController::new(cfg);
        feed_seq(&mut ctl, 5, 4, 4, 4);
        assert!(ctl.seq_alpha_hat(5).is_some(), "tracking must fill windows");
        // And the rounds stay uniform (ragged is still off).
        let mut out = Vec::new();
        ctl.gammas_for_round(&[5, 6], &mut out);
        assert!(out.iter().all(|&g| g == out[0]));
        assert_eq!(ctl.state().ragged_rounds, 0);
        // Default scalar config keeps the map empty.
        let mut plain = SpecController::new(ControlConfig::static_gamma(4));
        feed_seq(&mut plain, 5, 4, 4, 4);
        assert_eq!(plain.state().tracked_sequences, 0);
    }

    #[test]
    fn state_renders_to_json() {
        let mut ctl = SpecController::new(ControlConfig::static_gamma(2));
        let mut rng = Rng::seeded(5);
        observe_rounds(&mut ctl, &mut rng, 0.8, 2, 8, 20);
        let s = ctl.state();
        let j = s.to_json().to_string();
        assert!(j.contains("\"policy\""));
        assert!(j.contains("\"gamma\""));
        assert!(j.contains("\"alpha_hat\""));
        assert!(j.contains("\"target_efficiency\""));
        assert!(j.contains("\"verify_budget\""));
        assert!(j.contains("\"accept_by_budget\""));
    }

    #[test]
    fn cost_table_budget_column_stays_separate() {
        // Budgeted rounds must not pollute the unbudgeted verify anchors,
        // and the acceptance curve must expose a measured degradation
        // ratio once both arms have samples.
        let mut t = CostTable::default();
        let mk = |budget: Option<usize>, accepted: u64, t_verify: f64| RoundObservation {
            round: 0,
            batch: 16,
            gamma: 3,
            proposed: 48,
            accepted,
            emitted: accepted + 16,
            t_draft: 0.004,
            t_verify,
            t_reject: 1e-4,
            budget,
        };
        for _ in 0..5 {
            t.observe(&mk(None, 40, 0.012));
            t.observe(&mk(Some(16), 24, 0.008));
        }
        // Unbudgeted rounds land in the plain table only.
        assert!(t.verify_time(16, 4).is_some());
        assert!((t.verify_time(16, 4).unwrap() - 0.012).abs() < 1e-9);
        // Budgeted rounds land in the budget column only.
        assert!((t.budget_verify_time(16, 4, 16).unwrap() - 0.008).abs() < 1e-9);
        assert!(t.budget_verify_time(16, 4, 32).is_none());
        // Acceptance curve: both arms, ratio = (24/48)/(40/48) = 0.6.
        let base = t.accept_rate(None).unwrap();
        let capped = t.accept_rate(Some(16)).unwrap();
        assert!((base - 40.0 / 48.0).abs() < 1e-9);
        assert!((capped - 24.0 / 48.0).abs() < 1e-9);
        let ratio = t.measured_budget_alpha_ratio(16).unwrap();
        assert!((ratio - 0.6).abs() < 1e-9, "ratio={ratio}");
        assert!(t.measured_budget_alpha_ratio(32).is_none());
        let curve = t.accept_curve();
        assert_eq!(curve.len(), 2);
        assert_eq!(curve[0].0, Some(16));
        assert_eq!(curve[1].0, None);
    }

    #[test]
    fn empty_budget_grid_keeps_controller_budget_off() {
        // The budget off-switch at controller level: without a grid the
        // controller never owns the axis, reports None forever, and its
        // sanitized config preserves the empty grid.
        let mut ctl = SpecController::new(ControlConfig::model_guided(roofline_spec()));
        assert!(!ctl.owns_budget());
        assert_eq!(ctl.verify_budget(), None);
        let g = ctl.gamma_for_round(8);
        assert!(g >= 1);
        let mut rng = Rng::seeded(11);
        observe_rounds(&mut ctl, &mut rng, 0.9, g, 8, 200);
        assert_eq!(ctl.verify_budget(), None, "no grid ⇒ budget never set");
        assert_eq!(ctl.state().budget, None);
    }

    #[test]
    fn budget_grid_makes_controller_own_and_pick_a_budget() {
        // With a grid and a measured acceptance curve showing *no*
        // degradation, a capped verify is strictly cheaper at a
        // memory-bound batch, so the joint consult must select a budget.
        let cfg = ControlConfig {
            budget_grid: vec![16, 32],
            budget_sensitivity: 1.0,
            ..ControlConfig::model_guided(roofline_spec())
        };
        cfg.validate().unwrap();
        let mut ctl = SpecController::new(cfg);
        assert!(ctl.owns_budget());
        let g = ctl.gamma_for_round(8);
        assert!(g >= 1, "SD regime expected at B=8");
        // Feed rounds alternating budget arms with identical acceptance:
        // the measured ratio pins the degradation prior to 1.0.
        let mut round = 1u64;
        for _ in 0..200 {
            for bud in [None, Some(16), Some(32)] {
                ctl.observe(RoundObservation {
                    round,
                    batch: 8,
                    gamma: g,
                    proposed: (8 * g) as u64,
                    accepted: (7 * g) as u64,
                    emitted: (7 * g + 8) as u64,
                    t_draft: 0.001 * g as f64,
                    t_verify: if bud.is_some() { 0.008 } else { 0.012 },
                    t_reject: 1e-4,
                    budget: bud,
                });
                round += 1;
            }
        }
        let picked = ctl.verify_budget();
        assert!(
            picked.is_some(),
            "measured-equal acceptance + cheaper capped verify must pick a budget"
        );
        assert!([16, 32].contains(&picked.unwrap()), "{picked:?}");
        assert_eq!(ctl.state().budget, picked);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "monotone")]
    fn observe_rejects_backwards_round_clock() {
        let mut ctl = SpecController::new(ControlConfig::static_gamma(2));
        let obs = |round: u64| RoundObservation {
            round,
            batch: 4,
            gamma: 2,
            proposed: 8,
            accepted: 6,
            emitted: 10,
            t_draft: 1e-3,
            t_verify: 1e-2,
            t_reject: 1e-4,
            budget: None,
        };
        ctl.observe(obs(5));
        ctl.observe(obs(3)); // clock ran backwards: must trip the invariant
    }
}
