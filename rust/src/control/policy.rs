//! γ-selection policies behind the [`GammaPolicy`] trait.
//!
//! [`StaticPolicy`] pins γ (the launch-config baseline every current
//! serving stack uses). [`ModelGuidedPolicy`] re-solves the paper's Eq. 4
//! speedup decomposition each control interval with the *measured* α̂
//! plugged into σ(α, γ) (Eq. 5), over an analytic cost model rescaled by
//! the measured cost table — and selects the argmax γ, including the γ=0
//! autoregressive fallback for regimes where SD loses (large compute-bound
//! batches, §3.1's collapsing target efficiency).

use super::{bucket_of, ControlConfig, CostModel, CostModelSpec, CostTable};
use crate::theory;
use crate::util::stats::argmax;

/// Inputs to a policy decision: the controller's current online estimates.
pub struct Estimates<'a> {
    /// Decode batch size of the closing round.
    pub batch: usize,
    /// Windowed per-token acceptance estimate (None before any SD round).
    pub alpha: Option<f64>,
    /// Windowed σ estimate.
    pub sigma: Option<f64>,
    /// γ currently in effect.
    pub current_gamma: usize,
    /// Verify-expert budget currently in effect (`None` = unbudgeted —
    /// always `None` when the controller's budget axis is off).
    pub current_budget: Option<usize>,
    /// The batch bucket just changed (load shift): the decision should be
    /// taken fresh, without hysteresis/dwell damping — those guards exist
    /// to absorb estimator noise, not real regime changes.
    pub regime_shift: bool,
    /// Measured per-stage costs.
    pub costs: &'a CostTable,
}

/// How a decision came about (observability + probe bookkeeping).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecisionKind {
    /// Keep the current γ.
    Hold,
    /// Move to a better γ.
    Switch,
    /// Temporary speculative interval to refresh α̂ while in AR fallback.
    Probe,
}

/// A policy's output for the next control interval.
#[derive(Debug, Clone, Copy)]
pub struct GammaDecision {
    pub gamma: usize,
    pub kind: DecisionKind,
    /// Verify-expert budget to run alongside `gamma` (`None` =
    /// unbudgeted). Policies without a budget grid echo the estimate's
    /// current budget back, so the controller's choice is a fixed point.
    pub budget: Option<usize>,
}

/// A γ-selection policy consulted once per control interval.
pub trait GammaPolicy: Send {
    fn name(&self) -> &'static str;
    fn decide(&mut self, est: &Estimates) -> GammaDecision;

    /// Vectorized per-sequence decision for **ragged** rounds: fill `out`
    /// with one γᵢ per entry of `seq_alphas` (the controller's windowed
    /// per-sequence α̂ᵢ, batch-level fallback already applied). Side-effect
    /// free — hysteresis/dwell/probe state belongs to the scalar
    /// [`GammaPolicy::decide`] path, which still owns regime decisions;
    /// this refines *within* the current regime every round.
    ///
    /// Best (γ, speedup-vs-AR) this policy predicts at `est.batch` for an
    /// acceptance mix `alpha` (`None` = use the policy's own estimate /
    /// prior). This is the **priced regime test** the admission layer's
    /// mix-aware policy consults through
    /// [`crate::scheduler::RegimeOracle`]: speedup ≤ 1 means the batch
    /// has left the speculative band. Policies without a cost model (the
    /// static baseline) report a neutral (current γ, 1.0).
    fn predict(&self, est: &Estimates, alpha: Option<f64>) -> (usize, f64) {
        let _ = alpha;
        (est.current_gamma, 1.0)
    }

    /// The default (and the guaranteed behavior of every policy when all
    /// α̂ᵢ are equal) is the uniform round the scalar path would run:
    /// every sequence at `est.current_gamma`.
    ///
    /// ```
    /// use moesd::control::{ControlConfig, CostModelSpec, CostTable, Estimates};
    /// use moesd::control::{GammaPolicy, ModelGuidedPolicy};
    /// use moesd::hardware::platform_2x_gpu_a;
    /// use moesd::perfmodel::PerfParams;
    /// let spec = CostModelSpec::perf(
    ///     platform_2x_gpu_a().ridge_point(),
    ///     PerfParams {
    ///         bias: 0.02, k1: 1e-4, k2: 2e-4, k3: 5e-4,
    ///         draft_bias: 0.001, draft_k: 1e-5,
    ///         reject_bias: 1e-4, reject_k: 1e-7,
    ///         lambda: 0.5, s: 1.02,
    ///     },
    ///     8,
    ///     64,
    /// );
    /// let policy = ModelGuidedPolicy::new(spec, &ControlConfig::default());
    /// let costs = CostTable::default();
    /// let est = Estimates {
    ///     batch: 8, alpha: Some(0.8), sigma: None,
    ///     current_gamma: 3, current_budget: None,
    ///     regime_shift: false, costs: &costs,
    /// };
    /// let mut out = Vec::new();
    /// // An easy (α̂=0.98) and a hard (α̂=0.3) sequence in the same round:
    /// // the easy one gets a strictly deeper draft.
    /// policy.gamma_for_sequences(&est, &[0.98, 0.3], &mut out);
    /// assert!(out[0] > out[1], "{out:?}");
    /// // All-equal α̂ reproduces the scalar path's uniform round exactly.
    /// out.clear();
    /// policy.gamma_for_sequences(&est, &[0.8, 0.8], &mut out);
    /// assert_eq!(out, vec![3, 3]);
    /// ```
    fn gamma_for_sequences(&self, est: &Estimates, seq_alphas: &[f64], out: &mut Vec<usize>) {
        out.extend(std::iter::repeat(est.current_gamma).take(seq_alphas.len()));
    }

    /// Joint (γ⃗, budget) refinement for ragged rounds: fill `out` exactly
    /// like [`GammaPolicy::gamma_for_sequences`] and return the
    /// verify-expert budget the round should run under. The default —
    /// and the exact behavior of every policy whose budget grid is empty
    /// — delegates to `gamma_for_sequences` and echoes the current
    /// budget, so the controller's budget is a fixed point (bit-identical
    /// off-switch).
    fn gamma_budget_for_sequences(
        &self,
        est: &Estimates,
        seq_alphas: &[f64],
        out: &mut Vec<usize>,
    ) -> Option<usize> {
        self.gamma_for_sequences(est, seq_alphas, out);
        est.current_budget
    }
}

/// Fixed γ — the baseline against which adaptation is measured.
pub struct StaticPolicy {
    pub gamma: usize,
}

impl GammaPolicy for StaticPolicy {
    fn name(&self) -> &'static str {
        "static"
    }

    fn decide(&mut self, est: &Estimates) -> GammaDecision {
        GammaDecision {
            gamma: self.gamma,
            kind: DecisionKind::Hold,
            budget: est.current_budget,
        }
    }
}

/// Eq. 4 argmax-γ with measured-cost grounding, hysteresis, dwell time and
/// AR-fallback probing.
pub struct ModelGuidedPolicy {
    cost: CostModelSpec,
    gamma_max: usize,
    hysteresis: f64,
    min_dwell: usize,
    probe_every: usize,
    alpha_prior: f64,
    /// Candidate verify-expert budgets for the joint (γ, budget) argmax.
    /// Empty ⇒ the budget axis is off and every decision is bit-identical
    /// to the unbudgeted policy.
    budget_grid: Vec<usize>,
    /// Acceptance-degradation prior exponent (`α_eff = α·cov^sens`) used
    /// until the measured acceptance-vs-budget curve has both arms.
    budget_sensitivity: f64,
    intervals_since_switch: usize,
    intervals_at_ar: usize,
    probing: bool,
}

impl ModelGuidedPolicy {
    pub fn new(cost: CostModelSpec, cfg: &ControlConfig) -> ModelGuidedPolicy {
        assert!(cfg.gamma_max >= 1, "model-guided policy needs gamma_max >= 1");
        ModelGuidedPolicy {
            cost,
            gamma_max: cfg.gamma_max,
            hysteresis: cfg.hysteresis,
            min_dwell: cfg.min_dwell_intervals,
            probe_every: cfg.probe_every_intervals,
            alpha_prior: cfg.alpha_prior,
            budget_grid: cfg.budget_grid.clone(),
            budget_sensitivity: cfg.budget_sensitivity,
            intervals_since_switch: usize::MAX / 2,
            intervals_at_ar: 0,
            probing: false,
        }
    }

    /// Predicted committed tokens/second per sequence at (B, γ): the Eq. 4
    /// round economics, σ(α̂, γ)·(γ+1) over the round time. Model costs are
    /// re-anchored by measured entries where the cost table has them, so
    /// the s-shape comes from the model but the absolute levels track
    /// production reality.
    pub fn score(&self, batch: usize, gamma: usize, alpha: f64, costs: &CostTable) -> f64 {
        let round_len = theory::expected_round_length(alpha, gamma);
        round_len / self.round_cost(batch, gamma, costs).max(1e-300)
    }

    /// The Eq. 4 denominator at (B, γ): measured-cost-anchored model time
    /// of one uniform round — α-independent, so the per-sequence argmax in
    /// [`ModelGuidedPolicy::gamma_for_sequences`] computes it once per γ.
    fn round_cost(&self, batch: usize, gamma: usize, costs: &CostTable) -> f64 {
        let b = batch.max(1);
        let bucket = bucket_of(b);
        let model_verify = self.cost.t_target(b, gamma + 1);
        let verify = match costs.verify_nearest(bucket, gamma + 1) {
            Some((s_obs, measured)) => {
                let model_at_obs = self.cost.t_target(b, s_obs);
                if model_at_obs > 0.0 {
                    model_verify * (measured / model_at_obs)
                } else {
                    model_verify
                }
            }
            None => model_verify,
        };
        let draft1 = match costs.draft_per_forward(bucket) {
            Some(measured) => measured,
            None => self.cost.t_draft(b),
        };
        let reject = match costs.reject_per_row() {
            Some(per_row) => per_row * (b * (gamma + 1)) as f64,
            None => self.cost.t_reject(b, gamma),
        };
        gamma as f64 * draft1 + verify + reject
    }

    /// Measured-cost-anchored time of one **ragged** round: packed verify
    /// over `Σ count·(γ+1)` tokens (re-anchored exactly like
    /// [`ModelGuidedPolicy::score`]'s verify term), sequential draft steps
    /// over the shrinking active set, and Σ-rows rejection. `groups` is
    /// the round's assignment as `(count, γ)` per distinct-α̂ group.
    fn ragged_round_cost(&self, batch: usize, groups: &[(usize, usize)], costs: &CostTable) -> f64 {
        let b = batch.max(1);
        let bucket = bucket_of(b);
        let tokens: usize = groups.iter().map(|&(c, g)| c * (g + 1)).sum();
        let model_verify = self.cost.t_target_tokens(b, tokens);
        let verify = match costs.verify_nearest(bucket, (tokens + b / 2) / b) {
            Some((s_obs, measured)) => {
                let model_at_obs = self.cost.t_target(b, s_obs);
                if model_at_obs > 0.0 {
                    model_verify * (measured / model_at_obs)
                } else {
                    model_verify
                }
            }
            None => model_verify,
        };
        // Draft steps at the shrinking batch, re-anchored by the measured
        // per-forward ratio at the full batch where available.
        let draft_ratio = match (costs.draft_per_forward(bucket), self.cost.t_draft(b)) {
            (Some(measured), model) if model > 0.0 => measured / model,
            _ => 1.0,
        };
        let gamma_top = groups.iter().map(|&(_, g)| g).max().unwrap_or(0);
        let mut draft = 0.0;
        for step in 0..gamma_top {
            let bg: usize = groups
                .iter()
                .filter(|&&(_, g)| g > step)
                .map(|&(c, _)| c)
                .sum();
            draft += self.cost.t_draft(bg.max(1)) * draft_ratio;
        }
        let reject = match costs.reject_per_row() {
            Some(per_row) => per_row * tokens as f64,
            None => {
                let mean_gamma = ((tokens + b / 2) / b).saturating_sub(1);
                self.cost.t_reject(b, mean_gamma)
            }
        };
        draft + verify + reject
    }

    fn scores(&self, batch: usize, alpha: f64, costs: &CostTable) -> Vec<f64> {
        (0..=self.gamma_max)
            .map(|g| self.score(batch, g, alpha, costs))
            .collect()
    }

    /// Multiplicative acceptance-degradation factor for pricing a budget
    /// candidate at a verify width of `rows` total tokens. The **measured**
    /// acceptance-vs-budget ratio wins once the cost table has both arms
    /// (the online curve); before that the coverage prior
    /// `cov^budget_sensitivity` from the Eq. 8 activation curve applies.
    /// `None` budgets — and dense targets, where a budget caps nothing —
    /// are exactly transparent (factor 1).
    fn budget_alpha_factor(&self, rows: usize, budget: Option<usize>, costs: &CostTable) -> f64 {
        let bud = match budget {
            Some(b) => b,
            None => return 1.0,
        };
        if let Some(ratio) = costs.measured_budget_alpha_ratio(bud) {
            return ratio;
        }
        match self.cost.moe_dims() {
            Some((e, k)) => {
                let cov = theory::budget_coverage(e, k, rows as u64, Some(bud));
                if cov >= 1.0 {
                    1.0
                } else {
                    cov.powf(self.budget_sensitivity)
                }
            }
            None => 1.0,
        }
    }

    /// [`ModelGuidedPolicy::score`] under a verify-expert budget: the
    /// verify term is priced on the capped cost surface and α is degraded
    /// by the acceptance-vs-budget curve. `budget = None` delegates to
    /// the unbudgeted score verbatim (bit-identical off-switch).
    pub fn score_budgeted(
        &self,
        batch: usize,
        gamma: usize,
        alpha: f64,
        costs: &CostTable,
        budget: Option<usize>,
    ) -> f64 {
        if budget.is_none() {
            return self.score(batch, gamma, alpha, costs);
        }
        let rows = batch.max(1) * (gamma + 1);
        let factor = self.budget_alpha_factor(rows, budget, costs);
        let a_eff = (alpha * factor).clamp(0.0, 1.0);
        let round_len = theory::expected_round_length(a_eff, gamma);
        round_len
            / self
                .round_cost_budgeted(batch, gamma, costs, budget)
                .max(1e-300)
    }

    /// [`ModelGuidedPolicy::round_cost`] with the verify term on the
    /// budgeted surface. A measured budgeted entry at exactly this
    /// (bucket, s, budget) wins outright; otherwise the budgeted model
    /// price is re-anchored by the *unbudgeted* measured ratio (the only
    /// anchor available before budgeted rounds have run).
    fn round_cost_budgeted(
        &self,
        batch: usize,
        gamma: usize,
        costs: &CostTable,
        budget: Option<usize>,
    ) -> f64 {
        let bud = match budget {
            Some(b) => b,
            None => return self.round_cost(batch, gamma, costs),
        };
        let b = batch.max(1);
        let bucket = bucket_of(b);
        let model_verify = self
            .cost
            .t_target_tokens_budgeted(b, b * (gamma + 1), budget);
        let verify = match costs.budget_verify_time(bucket, gamma + 1, bud) {
            Some(measured) => measured,
            None => match costs.verify_nearest(bucket, gamma + 1) {
                Some((s_obs, measured)) => {
                    let model_at_obs = self.cost.t_target(b, s_obs);
                    if model_at_obs > 0.0 {
                        model_verify * (measured / model_at_obs)
                    } else {
                        model_verify
                    }
                }
                None => model_verify,
            },
        };
        let draft1 = match costs.draft_per_forward(bucket) {
            Some(measured) => measured,
            None => self.cost.t_draft(b),
        };
        let reject = match costs.reject_per_row() {
            Some(per_row) => per_row * (b * (gamma + 1)) as f64,
            None => self.cost.t_reject(b, gamma),
        };
        gamma as f64 * draft1 + verify + reject
    }

    /// [`ModelGuidedPolicy::ragged_round_cost`] with the packed verify on
    /// the budgeted surface (same anchoring rules as
    /// [`ModelGuidedPolicy::round_cost_budgeted`]).
    fn ragged_round_cost_budgeted(
        &self,
        batch: usize,
        groups: &[(usize, usize)],
        costs: &CostTable,
        budget: Option<usize>,
    ) -> f64 {
        let bud = match budget {
            Some(b) => b,
            None => return self.ragged_round_cost(batch, groups, costs),
        };
        let b = batch.max(1);
        let bucket = bucket_of(b);
        let tokens: usize = groups.iter().map(|&(c, g)| c * (g + 1)).sum();
        let model_verify = self.cost.t_target_tokens_budgeted(b, tokens, budget);
        let s_mean = (tokens + b / 2) / b;
        let verify = match costs.budget_verify_time(bucket, s_mean, bud) {
            Some(measured) => measured,
            None => match costs.verify_nearest(bucket, s_mean) {
                Some((s_obs, measured)) => {
                    let model_at_obs = self.cost.t_target(b, s_obs);
                    if model_at_obs > 0.0 {
                        model_verify * (measured / model_at_obs)
                    } else {
                        model_verify
                    }
                }
                None => model_verify,
            },
        };
        let draft_ratio = match (costs.draft_per_forward(bucket), self.cost.t_draft(b)) {
            (Some(measured), model) if model > 0.0 => measured / model,
            _ => 1.0,
        };
        let gamma_top = groups.iter().map(|&(_, g)| g).max().unwrap_or(0);
        let mut draft = 0.0;
        for step in 0..gamma_top {
            let bg: usize = groups
                .iter()
                .filter(|&&(_, g)| g > step)
                .map(|&(c, _)| c)
                .sum();
            draft += self.cost.t_draft(bg.max(1)) * draft_ratio;
        }
        let reject = match costs.reject_per_row() {
            Some(per_row) => per_row * tokens as f64,
            None => {
                let mean_gamma = ((tokens + b / 2) / b).saturating_sub(1);
                self.cost.t_reject(b, mean_gamma)
            }
        };
        draft + verify + reject
    }
}

impl GammaPolicy for ModelGuidedPolicy {
    fn name(&self) -> &'static str {
        "model-guided"
    }

    /// Measured-cost-anchored Eq. 4 argmax: the best γ's goodput over the
    /// AR (γ=0) goodput at the same batch. >1 ⇔ speculation pays.
    fn predict(&self, est: &Estimates, alpha: Option<f64>) -> (usize, f64) {
        let alpha = alpha
            .or(est.alpha)
            .unwrap_or(self.alpha_prior)
            .clamp(0.0, 1.0);
        let scores = self.scores(est.batch, alpha, est.costs);
        let best = argmax(&scores);
        let ar = scores[0].max(1e-300);
        (best, scores[best] / ar)
    }

    fn decide(&mut self, est: &Estimates) -> GammaDecision {
        let alpha = est.alpha.unwrap_or(self.alpha_prior);
        let scores = self.scores(est.batch, alpha, est.costs);
        // Best speculative candidate over the joint (γ ≥ 1, budget) grid.
        // The unbudgeted arm seeds the running best and budgeted arms
        // must beat it *strictly*, so an empty grid reproduces the
        // unbudgeted argmax bit-for-bit.
        let mut spec_g = 1 + argmax(&scores[1..]);
        let mut spec_budget: Option<usize> = None;
        let mut spec_score = scores[spec_g];
        for &bud in &self.budget_grid {
            for g in 1..=self.gamma_max {
                let s = self.score_budgeted(est.batch, g, alpha, est.costs, Some(bud));
                if s > spec_score {
                    spec_score = s;
                    spec_g = g;
                    spec_budget = Some(bud);
                }
            }
        }
        // γ = 0 never carries a budget: an AR round verifies one token
        // per sequence and the cap would only distort the baseline.
        let (best, best_budget, best_score) = if spec_score > scores[0] {
            (spec_g, spec_budget, spec_score)
        } else {
            (0, None, scores[0])
        };
        let cur = est.current_gamma.min(self.gamma_max);
        let cur_budget = if cur == 0 { None } else { est.current_budget };
        let cur_score = if cur_budget.is_none() {
            scores[cur]
        } else {
            self.score_budgeted(est.batch, cur, alpha, est.costs, cur_budget)
        };

        // A probe interval just ended, or the load regime shifted:
        // re-decide unguarded so a failed probe drops straight back to AR
        // and a batch jump re-seats γ before paying a single stale round.
        if self.probing || est.regime_shift {
            self.probing = false;
            self.intervals_since_switch = 0;
            if best > 0 {
                self.intervals_at_ar = 0;
            }
            let kind = if best == cur && best_budget == cur_budget {
                DecisionKind::Hold
            } else {
                DecisionKind::Switch
            };
            return GammaDecision {
                gamma: best,
                kind,
                budget: best_budget,
            };
        }

        if cur == 0 {
            self.intervals_at_ar += 1;
            // The AR fallback produces no acceptance signal, so α̂ goes
            // stale; periodically spend one interval on the best
            // speculative (γ, budget) to refresh it (and to notice
            // regime shifts).
            if self.probe_every > 0 && best == 0 && self.intervals_at_ar >= self.probe_every {
                self.intervals_at_ar = 0;
                self.probing = true;
                return GammaDecision {
                    gamma: spec_g,
                    kind: DecisionKind::Probe,
                    budget: spec_budget,
                };
            }
        } else {
            self.intervals_at_ar = 0;
        }

        self.intervals_since_switch = self.intervals_since_switch.saturating_add(1);
        if best == cur && best_budget == cur_budget {
            return GammaDecision {
                gamma: cur,
                kind: DecisionKind::Hold,
                budget: cur_budget,
            };
        }
        // Dwell: don't even consider switching right after a switch.
        if self.intervals_since_switch <= self.min_dwell {
            return GammaDecision {
                gamma: cur,
                kind: DecisionKind::Hold,
                budget: cur_budget,
            };
        }
        // Hysteresis: the candidate must beat the incumbent by a margin.
        if best_score < cur_score * (1.0 + self.hysteresis) {
            return GammaDecision {
                gamma: cur,
                kind: DecisionKind::Hold,
                budget: cur_budget,
            };
        }
        self.intervals_since_switch = 0;
        GammaDecision {
            gamma: best,
            kind: DecisionKind::Switch,
            budget: best_budget,
        }
    }

    /// Per-sequence Eq. 4 over the *shared* ragged round time: the
    /// water-filling argmax of `Σᵢ σ(α̂ᵢ, γᵢ)·(γᵢ+1) / T_round(γ⃗)`.
    /// Sequences are grouped by their (already-quantized) α̂, candidate
    /// assignments are every uniform γ plus every water level θ = α̂ᵏ
    /// (`γ(θ) = max{γ : α̂^γ ≥ θ}` per group, the closed form of
    /// [`crate::perfmodel::PerfModel::argmax_gamma_ragged`]), and each
    /// candidate is scored with the measured-cost-anchored ragged round
    /// time. Uniform candidates are evaluated first, so ties collapse to
    /// uniform rounds; the independent per-sequence argmax (each sequence
    /// against the *full* round cost) is deliberately not used — it
    /// over-drafts easy sequences because it ignores that the round time
    /// is shared.
    fn gamma_for_sequences(&self, est: &Estimates, seq_alphas: &[f64], out: &mut Vec<usize>) {
        self.water_fill_joint(est, seq_alphas, out, &[]);
    }

    /// Joint (γ⃗, budget) ragged refinement: the same shared-round-time
    /// water-fill, crossed with the budget grid. The unbudgeted arm runs
    /// first and budgeted arms must win strictly, so an empty grid is
    /// bit-identical to [`ModelGuidedPolicy::gamma_for_sequences`].
    fn gamma_budget_for_sequences(
        &self,
        est: &Estimates,
        seq_alphas: &[f64],
        out: &mut Vec<usize>,
    ) -> Option<usize> {
        self.water_fill_joint(est, seq_alphas, out, &self.budget_grid)
    }
}

impl ModelGuidedPolicy {
    /// Shared implementation of the ragged water-fill, optionally crossed
    /// with a verify-expert budget grid. Candidate assignments come from
    /// the **raw** α̂ᵢ for every budget arm — a budget rescales all α by
    /// the same coverage factor, which preserves the water-level order,
    /// so one candidate set serves the whole grid. Returns the winning
    /// budget (`est.current_budget` on the uniform early-outs).
    fn water_fill_joint(
        &self,
        est: &Estimates,
        seq_alphas: &[f64],
        out: &mut Vec<usize>,
        grid: &[usize],
    ) -> Option<usize> {
        let n = seq_alphas.len();
        if n == 0 {
            return est.current_budget;
        }
        // All-equal α̂ is the uniform special case: reproduce the scalar
        // path's held (γ, budget) exactly (bit-for-bit — no model
        // evaluation; the scalar consult already priced uniform rounds).
        if seq_alphas.windows(2).all(|w| w[0] == w[1]) {
            out.extend(std::iter::repeat(est.current_gamma).take(n));
            return est.current_budget;
        }
        // Distinct-α̂ groups (the controller quantizes to a 0.01 grid, so
        // there are at most ~100; exact match is intentional).
        let mut groups: Vec<(f64, usize)> = Vec::new();
        for &a in seq_alphas {
            match groups.iter_mut().find(|(ga, _)| *ga == a) {
                Some((_, c)) => *c += 1,
                None => groups.push((a, 1)),
            }
        }
        // One shared candidate set with the offline argmax
        // ([`crate::perfmodel::water_fill_assignments`] — uniforms first,
        // then the closed-form γ(θ) per water level), scored here with
        // the measured-cost-anchored ragged round time. Inside a
        // speculative regime every depth is floored at 1 *before*
        // scoring, so the argmax runs over exactly the feasible set the
        // controller will execute (a γᵢ=0 sequence would stop producing
        // acceptance samples and freeze its own α̂ᵢ window — see
        // `SpecController::gammas_for_round`).
        let floor = if est.current_gamma >= 1 { 1 } else { 0 };
        let group_alphas: Vec<f64> = groups.iter().map(|&(a, _)| a).collect();
        let cands = crate::perfmodel::water_fill_assignments(&group_alphas, self.gamma_max);
        let mut assignment: Vec<(usize, usize)> = Vec::with_capacity(groups.len());
        let mut best: Vec<usize> = Vec::new();
        let mut best_budget: Option<usize> = None;
        let mut best_score = f64::MIN;
        let mut budgets: Vec<Option<usize>> = Vec::with_capacity(grid.len() + 1);
        budgets.push(None);
        budgets.extend(grid.iter().map(|&b| Some(b)));
        for &bud in &budgets {
            for cand0 in &cands {
                let mut cand = cand0.clone();
                for g in cand.iter_mut() {
                    *g = (*g).max(floor);
                }
                assignment.clear();
                let mut tokens = 0usize;
                for ((_, c), &g) in groups.iter().zip(cand.iter()) {
                    assignment.push((*c, g));
                    tokens += *c * (g + 1);
                }
                let factor = self.budget_alpha_factor(tokens, bud, est.costs);
                let mut toks = 0.0;
                for ((a, c), &g) in groups.iter().zip(cand.iter()) {
                    // factor ≥ 1 short-circuits to the raw α so the
                    // unbudgeted arm's arithmetic is untouched.
                    let a_eff = if factor >= 1.0 { *a } else { (*a * factor).min(1.0) };
                    toks += *c as f64 * theory::expected_round_length(a_eff, g);
                }
                let s = toks
                    / self
                        .ragged_round_cost_budgeted(est.batch, &assignment, est.costs, bud)
                        .max(1e-300);
                if s > best_score {
                    best_score = s;
                    best = cand;
                    best_budget = bud;
                }
            }
        }
        // Expand the winning per-group depths back to per-sequence order.
        for &a in seq_alphas {
            let gi = groups.iter().position(|&(ga, _)| ga == a).unwrap();
            out.push(best[gi]);
        }
        best_budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::hardware::platform_2x_gpu_a;
    use crate::perfmodel::PerfParams;
    use crate::simulator::ExecSim;

    fn roofline_spec() -> CostModelSpec {
        let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
        let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
        CostModelSpec::roofline(target, draft)
    }

    fn perf_spec() -> CostModelSpec {
        // The perfmodel's demo-scale parameters (same orders as its tests).
        CostModelSpec::perf(
            platform_2x_gpu_a().ridge_point(),
            PerfParams {
                bias: 0.02,
                k1: 1e-4,
                k2: 2e-4,
                k3: 5e-4,
                draft_bias: 0.001,
                draft_k: 1e-5,
                reject_bias: 1e-4,
                reject_k: 1e-7,
                lambda: 0.5,
                s: 1.02,
            },
            8,
            64,
        )
    }

    fn policy(cost: CostModelSpec, hysteresis: f64, dwell: usize) -> ModelGuidedPolicy {
        let cfg = ControlConfig {
            hysteresis,
            min_dwell_intervals: dwell,
            probe_every_intervals: 0,
            ..ControlConfig::model_guided(cost.clone())
        };
        ModelGuidedPolicy::new(cost, &cfg)
    }

    fn est<'a>(batch: usize, alpha: f64, cur: usize, costs: &'a CostTable) -> Estimates<'a> {
        Estimates {
            batch,
            alpha: Some(alpha),
            sigma: None,
            current_gamma: cur,
            current_budget: None,
            regime_shift: false,
            costs,
        }
    }

    #[test]
    fn static_policy_is_constant() {
        let mut p = StaticPolicy { gamma: 4 };
        let costs = CostTable::default();
        for b in [1usize, 64, 512] {
            let d = p.decide(&est(b, 0.1, 4, &costs));
            assert_eq!(d.gamma, 4);
            assert_eq!(d.kind, DecisionKind::Hold);
        }
    }

    #[test]
    fn speculative_wins_small_batch_ar_wins_compute_bound() {
        // The paper's core trade-off reproduced by the policy scores: at
        // B=4 (memory-bound) SD wins big; at B=4096 (compute-bound) the
        // verify step costs ≈(γ+1)×, so γ=0 is optimal for any α<1.
        for spec in [roofline_spec(), perf_spec()] {
            let p = policy(spec, 0.05, 0);
            let costs = CostTable::default();
            let small: Vec<f64> = (0..=8).map(|g| p.score(4, g, 0.9, &costs)).collect();
            assert!(argmax(&small) >= 1, "SD should win at B=4: {small:?}");
            let huge: Vec<f64> = (0..=8).map(|g| p.score(4096, g, 0.6, &costs)).collect();
            assert_eq!(argmax(&huge), 0, "AR should win at B=4096: {huge:?}");
        }
    }

    #[test]
    fn gamma0_fallback_when_target_efficiency_collapses() {
        // Satellite requirement: the γ=0 fallback at large B. Even with a
        // decent α the model-guided policy must fall back to AR.
        let mut p = policy(roofline_spec(), 0.05, 0);
        let costs = CostTable::default();
        let d = p.decide(&est(4096, 0.8, 4, &costs));
        assert_eq!(d.gamma, 0, "expected AR fallback at B=4096");
        assert_eq!(d.kind, DecisionKind::Switch);
    }

    #[test]
    fn hysteresis_prevents_oscillation_under_noisy_alpha() {
        // Find an α where the argmax γ sits on a decision boundary, then
        // feed the policy alternating α̂ on either side of it. With
        // hysteresis + dwell the γ trace must not thrash; without them it
        // flips continuously.
        let probe = policy(roofline_spec(), 0.0, 0);
        let costs = CostTable::default();
        let batch = 48;
        let argmax_at = |a: f64| {
            argmax(
                &(0..=8)
                    .map(|g| probe.score(batch, g, a, &costs))
                    .collect::<Vec<_>>(),
            )
        };
        let mut boundary = None;
        let mut a = 0.30;
        while a < 0.98 {
            if argmax_at(a) != argmax_at(a + 0.02) {
                boundary = Some(a);
                break;
            }
            a += 0.02;
        }
        let a = boundary.expect("no γ decision boundary found in α ∈ [0.3, 0.98]");
        let (lo, hi) = (a, a + 0.02);

        let run = |mut p: ModelGuidedPolicy| -> usize {
            let mut switches = 0;
            let mut cur = argmax_at(lo);
            for i in 0..40 {
                let alpha = if i % 2 == 0 { lo } else { hi };
                let d = p.decide(&est(batch, alpha, cur, &costs));
                if d.gamma != cur {
                    switches += 1;
                    cur = d.gamma;
                }
            }
            switches
        };

        let guarded = run(policy(roofline_spec(), 0.15, 3));
        let naive = run(policy(roofline_spec(), 0.0, 0));
        assert!(guarded <= 2, "hysteresis should damp switching: {guarded}");
        assert!(
            naive > guarded,
            "without hysteresis the policy should thrash more: naive={naive} guarded={guarded}"
        );
    }

    #[test]
    fn sharded_cost_model_shifts_gamma_per_topology() {
        // The tentpole's control-plane surface: the same policy, handed a
        // cost model re-anchored on an EP topology, picks a γ tuned to
        // that topology. On a communication-bound fabric every extra
        // verified token pays all-to-all bandwidth, so the argmax γ drops
        // (validated against the python replica: γ=8 → γ=6 at B=8).
        use crate::hardware::{ShardingSpec, Topology};
        let arch = crate::arch::presets::qwen2_57b_a14b();
        let d1 = policy(roofline_spec(), 0.0, 0);
        let pcie_spec = roofline_spec()
            .with_sharding(ShardingSpec::for_arch(Topology::pcie(4), &arch));
        let pcie = policy(pcie_spec, 0.0, 0);
        let costs = CostTable::default();
        let best = |p: &ModelGuidedPolicy, b: usize| {
            argmax(
                &(0..=8)
                    .map(|g| p.score(b, g, 0.85, &costs))
                    .collect::<Vec<_>>(),
            )
        };
        let g1 = best(&d1, 8);
        let gp = best(&pcie, 8);
        assert!(g1 >= 1 && gp >= 1, "SD should win at B=8: {g1} / {gp}");
        assert!(
            gp < g1,
            "comm-bound fabric should shrink the argmax γ: d1={g1} pcie={gp}"
        );
        // Both topologies still fall back to AR once compute-bound.
        assert_eq!(best(&d1, 4096), 0);
        assert_eq!(best(&pcie, 4096), 0);
    }

    #[test]
    fn gamma_for_sequences_water_fill_matches_replica() {
        // Validated against the python replica of the roofline pricing:
        // B=16, bimodal α 0.9/0.5 → depths (8, 3); the compute-bound
        // B=4096 collapses to the uniform AR round.
        let p = policy(roofline_spec(), 0.05, 0);
        let costs = CostTable::default();
        let est = |b: usize, cur: usize| Estimates {
            batch: b,
            alpha: Some(0.7),
            sigma: None,
            current_gamma: cur,
            current_budget: None,
            regime_shift: false,
            costs: &costs,
        };
        let mut out = Vec::new();
        let alphas: Vec<f64> = (0..16).map(|i| if i % 2 == 0 { 0.9 } else { 0.5 }).collect();
        p.gamma_for_sequences(&est(16, 3), &alphas, &mut out);
        assert_eq!(out.len(), 16);
        assert_eq!((out[0], out[1]), (8, 3), "{out:?}");
        // Group expansion keeps per-sequence order (all evens equal, etc.).
        assert!(out.iter().step_by(2).all(|&g| g == 8));
        assert!(out.iter().skip(1).step_by(2).all(|&g| g == 3));
        // Compute-bound: the uniform γ=0 candidate wins for everyone.
        out.clear();
        let big: Vec<f64> = (0..4096).map(|i| if i % 2 == 0 { 0.9 } else { 0.5 }).collect();
        p.gamma_for_sequences(&est(4096, 0), &big, &mut out);
        assert!(out.iter().all(|&g| g == 0), "non-uniform at B=4096");
    }

    #[test]
    fn gamma_for_sequences_uniform_alpha_is_identity() {
        // The uniform special case is exact: all-equal α̂ returns the held
        // γ with no model evaluation, for both policies.
        let p = policy(roofline_spec(), 0.05, 0);
        let costs = CostTable::default();
        let est = Estimates {
            batch: 8,
            alpha: Some(0.8),
            sigma: None,
            current_gamma: 5,
            current_budget: None,
            regime_shift: false,
            costs: &costs,
        };
        let mut out = Vec::new();
        p.gamma_for_sequences(&est, &[0.8; 6], &mut out);
        assert_eq!(out, vec![5; 6]);
        let stat = StaticPolicy { gamma: 2 };
        out.clear();
        stat.gamma_for_sequences(&est, &[0.9, 0.4], &mut out);
        assert_eq!(out, vec![5, 5], "default impl holds the current γ");
    }

    #[test]
    fn water_fill_beats_independent_argmax_objective() {
        // The shared-round-time objective the water-fill maximizes: its
        // assignment must score at least as high as both every uniform
        // assignment and the independent per-sequence argmax (which
        // over-drafts easy sequences by privatizing the round cost).
        let p = policy(roofline_spec(), 0.05, 0);
        let costs = CostTable::default();
        let batch = 16usize;
        let alphas: Vec<f64> = (0..batch).map(|i| if i % 2 == 0 { 0.95 } else { 0.6 }).collect();
        let goodput = |gammas: &[usize]| -> f64 {
            let groups: Vec<(usize, usize)> = gammas.iter().map(|&g| (1, g)).collect();
            let toks: f64 = alphas
                .iter()
                .zip(gammas)
                .map(|(&a, &g)| crate::theory::expected_round_length(a, g))
                .sum();
            toks / p.ragged_round_cost(batch, &groups, &costs)
        };
        let est = Estimates {
            batch,
            alpha: Some(0.775),
            sigma: None,
            current_gamma: 3,
            current_budget: None,
            regime_shift: false,
            costs: &costs,
        };
        let mut wf = Vec::new();
        p.gamma_for_sequences(&est, &alphas, &mut wf);
        let wf_score = goodput(&wf);
        for g in 0..=8usize {
            let uni = goodput(&vec![g; batch]);
            assert!(
                wf_score >= uni - 1e-12,
                "uniform γ={g} ({uni}) beat the water-fill ({wf_score})"
            );
        }
        // Independent per-sequence argmax over the *full* round cost:
        let indep: Vec<usize> = alphas
            .iter()
            .map(|&a| {
                (0..=8usize)
                    .max_by(|&x, &y| {
                        let sx = crate::theory::expected_round_length(a, x)
                            / p.round_cost(batch, x, &costs);
                        let sy = crate::theory::expected_round_length(a, y)
                            / p.round_cost(batch, y, &costs);
                        sx.partial_cmp(&sy).unwrap()
                    })
                    .unwrap()
            })
            .collect();
        assert!(wf_score >= goodput(&indep) - 1e-12);
    }

    #[test]
    fn predict_reports_regime_band_and_mix_sensitivity() {
        let p = policy(roofline_spec(), 0.05, 0);
        let costs = CostTable::default();
        // Memory-bound batch: speculative γ with a real (>1) speedup.
        let (g_small, s_small) = p.predict(&est(8, 0.9, 3, &costs), None);
        assert!(g_small >= 1 && s_small > 1.2, "γ={g_small} s={s_small}");
        // Compute-bound batch: AR, speedup pinned at 1 (scores[0]/scores[0]).
        let (g_big, s_big) = p.predict(&est(4096, 0.9, 3, &costs), None);
        assert_eq!(g_big, 0);
        assert!((s_big - 1.0).abs() < 1e-12);
        // The mix override matters: a hard mix predicts less speedup than
        // an easy one at the same batch.
        let (_, s_easy) = p.predict(&est(8, 0.5, 3, &costs), Some(0.95));
        let (_, s_hard) = p.predict(&est(8, 0.5, 3, &costs), Some(0.35));
        assert!(s_easy > s_hard, "{s_easy} vs {s_hard}");
        // Static policies are neutral (no cost model to price with).
        let stat = StaticPolicy { gamma: 4 };
        assert_eq!(stat.predict(&est(8, 0.9, 4, &costs), None), (4, 1.0));
    }

    #[test]
    fn probe_cycle_refreshes_ar_fallback() {
        let cfg = ControlConfig {
            probe_every_intervals: 3,
            ..ControlConfig::model_guided(roofline_spec())
        };
        let mut p = ModelGuidedPolicy::new(roofline_spec(), &cfg);
        let costs = CostTable::default();
        // Park the policy in AR (B=4096 keeps best = 0).
        let mut cur = 0usize;
        let mut probes = 0;
        let mut trace = Vec::new();
        for _ in 0..12 {
            let d = p.decide(&est(4096, 0.6, cur, &costs));
            if d.kind == DecisionKind::Probe {
                probes += 1;
                assert!(d.gamma >= 1, "probe must be speculative");
            }
            cur = d.gamma;
            trace.push(cur);
        }
        assert!(probes >= 2, "expected periodic probes, trace={trace:?}");
        // Every probe must return to AR on the very next decision.
        for w in trace.windows(2) {
            if w[0] >= 1 {
                assert_eq!(w[1], 0, "probe should fall back immediately: {trace:?}");
            }
        }
    }

    #[test]
    fn measured_costs_reanchor_the_model() {
        let p = policy(roofline_spec(), 0.05, 0);
        let mut costs = CostTable::default();
        let base = p.score(16, 3, 0.9, &costs);
        // Report a verify cost 10× the model's prediction at (16, s=4):
        // the score must drop far below the pure-model value.
        let model_verify = p.cost.t_target(16, 4);
        costs.observe(&super::super::RoundObservation {
            round: 0,
            batch: 16,
            gamma: 3,
            proposed: 48,
            accepted: 40,
            emitted: 56,
            t_draft: 0.0,
            t_verify: 10.0 * model_verify,
            t_reject: 0.0,
            budget: None,
        });
        let grounded = p.score(16, 3, 0.9, &costs);
        assert!(
            grounded < 0.5 * base,
            "measured verify cost should pull the score down: {grounded} vs {base}"
        );
    }

    #[test]
    fn perf_spec_scores_are_finite_and_peak_interior() {
        let p = policy(perf_spec(), 0.05, 0);
        let costs = CostTable::default();
        for b in [1usize, 8, 64, 512] {
            for g in 0..=8usize {
                let s = p.score(b, g, 0.85, &costs);
                assert!(s.is_finite() && s > 0.0, "score(B={b}, γ={g}) = {s}");
            }
        }
    }

    fn policy_with_grid(
        cost: CostModelSpec,
        grid: Vec<usize>,
        sensitivity: f64,
    ) -> ModelGuidedPolicy {
        let cfg = ControlConfig {
            hysteresis: 0.0,
            min_dwell_intervals: 0,
            probe_every_intervals: 0,
            budget_grid: grid,
            budget_sensitivity: sensitivity,
            ..ControlConfig::model_guided(cost.clone())
        };
        ModelGuidedPolicy::new(cost, &cfg)
    }

    #[test]
    fn score_budgeted_none_is_bit_identical() {
        // The scalar off-switch at the policy layer: budget `None` — and
        // any budget that caps nothing (≥ E) with no measured curve —
        // scores exactly the unbudgeted Eq. 4 value.
        let p = policy_with_grid(roofline_spec(), vec![16, 64], 1.0);
        let costs = CostTable::default();
        for b in [1usize, 8, 48] {
            for g in 0..=8usize {
                let plain = p.score(b, g, 0.85, &costs);
                assert_eq!(p.score_budgeted(b, g, 0.85, &costs, None), plain);
                assert_eq!(p.score_budgeted(b, g, 0.85, &costs, Some(64)), plain);
            }
        }
    }

    #[test]
    fn joint_decide_with_transparent_budget_keeps_unbudgeted_arm() {
        // A grid whose only entry is ≥ E scores every candidate exactly
        // equal to the unbudgeted arm; the strict-improvement rule must
        // then keep budget = None and reproduce the plain γ decision.
        let mut plain = policy(roofline_spec(), 0.0, 0);
        let mut gridded = policy_with_grid(roofline_spec(), vec![64], 1.0);
        let costs = CostTable::default();
        for b in [4usize, 8, 48, 4096] {
            let d0 = plain.decide(&est(b, 0.85, 3, &costs));
            let d1 = gridded.decide(&est(b, 0.85, 3, &costs));
            assert_eq!(d0.gamma, d1.gamma, "B={b}");
            assert_eq!(d1.budget, None, "ties must stay unbudgeted (B={b})");
        }
    }

    #[test]
    fn joint_decide_picks_budget_when_measured_curve_is_flat() {
        // Feed the cost table a measured acceptance curve with *no*
        // degradation and a strictly cheaper budgeted verify: the joint
        // argmax must take the budget (cheaper verify, same α).
        let mut p = policy_with_grid(roofline_spec(), vec![16], 1.0);
        let mut costs = CostTable::default();
        let model_verify = p.cost.t_target(8, 4);
        for r in 0..10u64 {
            for bud in [None, Some(16)] {
                costs.observe(&super::super::RoundObservation {
                    round: r,
                    batch: 8,
                    gamma: 3,
                    proposed: 24,
                    accepted: 20,
                    emitted: 28,
                    t_draft: 0.0,
                    t_verify: if bud.is_some() {
                        0.5 * model_verify
                    } else {
                        model_verify
                    },
                    t_reject: 0.0,
                    budget: bud,
                });
            }
        }
        assert_eq!(costs.measured_budget_alpha_ratio(16), Some(1.0));
        let d = p.decide(&est(8, 0.85, 3, &costs));
        assert!(d.gamma >= 1, "SD regime expected at B=8");
        assert_eq!(d.budget, Some(16), "flat curve + cheap verify must cap");
    }

    #[test]
    fn joint_decide_rejects_budget_when_degradation_is_harsh() {
        // A measured curve showing severe acceptance collapse at the
        // capped arm must keep the policy unbudgeted even though the
        // capped verify is cheaper.
        let mut p = policy_with_grid(roofline_spec(), vec![8], 1.0);
        let mut costs = CostTable::default();
        for r in 0..10u64 {
            for (bud, accepted) in [(None, 22u64), (Some(8), 2u64)] {
                costs.observe(&super::super::RoundObservation {
                    round: r,
                    batch: 8,
                    gamma: 3,
                    proposed: 24,
                    accepted,
                    emitted: accepted + 8,
                    t_draft: 0.0,
                    t_verify: 0.0,
                    t_reject: 0.0,
                    budget: bud,
                });
            }
        }
        let ratio = costs.measured_budget_alpha_ratio(8).unwrap();
        assert!(ratio < 0.15, "ratio={ratio}");
        let d = p.decide(&est(8, 0.9, 3, &costs));
        assert_eq!(d.budget, None, "collapsed acceptance must stay unbudgeted");
    }

    #[test]
    fn gamma_budget_for_sequences_empty_grid_degenerates_exactly() {
        // Satellite: the joint water-fill with the budget axis disabled
        // is the PR-4 ragged water-fill, bit-for-bit — same depths, and
        // the returned budget echoes the current one.
        let p = policy(roofline_spec(), 0.05, 0);
        let transparent = policy_with_grid(roofline_spec(), vec![64], 1.0);
        let costs = CostTable::default();
        let est = Estimates {
            batch: 16,
            alpha: Some(0.7),
            sigma: None,
            current_gamma: 3,
            current_budget: None,
            regime_shift: false,
            costs: &costs,
        };
        let alphas: Vec<f64> = (0..16).map(|i| if i % 2 == 0 { 0.9 } else { 0.5 }).collect();
        let mut plain = Vec::new();
        p.gamma_for_sequences(&est, &alphas, &mut plain);
        let mut joint = Vec::new();
        let bud = p.gamma_budget_for_sequences(&est, &alphas, &mut joint);
        assert_eq!(plain, joint, "empty grid must degenerate exactly");
        assert_eq!(bud, None);
        // A transparent (≥ E) grid ties every candidate: strict
        // improvement keeps the unbudgeted arm and the same depths.
        let mut tied = Vec::new();
        let bud_t = transparent.gamma_budget_for_sequences(&est, &alphas, &mut tied);
        assert_eq!(plain, tied);
        assert_eq!(bud_t, None);
    }

    #[test]
    fn gamma_budget_for_sequences_joint_never_loses() {
        // The budget-blind water-fill assignment is in the joint
        // candidate set, so the joint winner's goodput can never be
        // below the decoupled (assignment-then-budget) score.
        let p = policy_with_grid(roofline_spec(), vec![8, 16, 32, 48], 0.35);
        let costs = CostTable::default();
        let est = Estimates {
            batch: 16,
            alpha: Some(0.7),
            sigma: None,
            current_gamma: 3,
            current_budget: None,
            regime_shift: false,
            costs: &costs,
        };
        let alphas: Vec<f64> = (0..16).map(|i| if i % 2 == 0 { 0.9 } else { 0.5 }).collect();
        let mut blind = Vec::new();
        p.gamma_for_sequences(&est, &alphas, &mut blind);
        let mut joint = Vec::new();
        let jbud = p.gamma_budget_for_sequences(&est, &alphas, &mut joint);
        let goodput = |gammas: &[usize], bud: Option<usize>| -> f64 {
            let groups: Vec<(usize, usize)> = gammas.iter().map(|&g| (1, g)).collect();
            let tokens: usize = gammas.iter().map(|&g| g + 1).sum();
            let factor = p.budget_alpha_factor(tokens, bud, &costs);
            let toks: f64 = alphas
                .iter()
                .zip(gammas)
                .map(|(&a, &g)| {
                    let a_eff = if factor >= 1.0 { a } else { (a * factor).min(1.0) };
                    theory::expected_round_length(a_eff, g)
                })
                .sum();
            toks / p.ragged_round_cost_budgeted(16, &groups, &costs, bud)
        };
        let joint_score = goodput(&joint, jbud);
        // Decoupled: keep the blind assignment, then pick its best budget.
        let mut decoupled = goodput(&blind, None);
        for &b in &[8usize, 16, 32, 48] {
            decoupled = decoupled.max(goodput(&blind, Some(b)));
        }
        assert!(
            joint_score >= decoupled - 1e-12,
            "joint {joint_score} < decoupled {decoupled}"
        );
    }
}
