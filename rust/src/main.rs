//! `moesd` — the launcher binary (L3 leader entrypoint).
//!
//! Subcommands:
//!   serve       start the TCP front-end (synthetic or real-HLO backend)
//!   bench       run a paper experiment (fig1|fig2|fig3|fig4|fig5|fig6|
//!               table1|table2|table3) and write results/
//!   fit         collect measurements and fit the Alg. 1 model
//!   selfcheck   verify artifacts: PJRT compile + numerics vs python
//!   list        list model presets and platforms
//!
//! Examples:
//!   moesd serve --mode hlo --port 7433 --gamma 3
//!   moesd bench fig2
//!   moesd selfcheck --artifacts artifacts

use moesd::arch::presets;
use moesd::config::{Config, Mode};
use moesd::hardware;
use moesd::simulator::ExecSim;
use moesd::spec::synthetic::SyntheticLm;
use moesd::util::cli::Args;
use moesd::util::logging;
use moesd::workload::{calibrated_alpha, Dataset};
use std::path::Path;

fn main() {
    let args = Args::from_env(&[
        "verbose",
        "help",
        "adaptive",
        "ragged",
        "mix-admission",
        "smoke",
        "continuous",
        "adaptive-budget",
    ]);
    if args.flag("verbose") {
        logging::set_level(logging::Level::Debug);
    }
    let result = match args.subcommand.as_deref() {
        Some("serve") => serve(&args),
        Some("bench") => bench(&args),
        Some("fit") => fit(&args),
        Some("selfcheck") => selfcheck(&args),
        Some("list") => list(),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "moesd — speculative decoding for sparse MoE serving\n\
         \n\
         USAGE: moesd <serve|bench|fit|selfcheck|list> [options]\n\
         \n\
         serve     --mode synthetic|hlo --port N --gamma N [--adaptive] [--ragged]\n\
                   [--tenants SPEC] [--mix-admission] [--config file.json]\n\
                   [--continuous] [--prefill-chunk N] [--record-trace PATH]\n\
                   [--verify-budget N] [--adaptive-budget] [--dist-workers N]\n\
                   [--draft-workers N]\n\
         bench     <fig1|fig2|fig3|fig4|fig5|fig6|table1|table2|table3|adaptive|vocab|\n\
                    sharding|ragged|multitenant|continuous|budget>\n\
                   multitenant: [--trace file.csv] [--loads 0.5,1.5,3] [--smoke]\n\
                   continuous:  [--trace file.csv] [--loads 0.5,1.5,3] [--smoke]\n\
                   budget:      [--smoke]\n\
         fit       --gamma N --alpha X\n\
         selfcheck --artifacts DIR\n\
         list\n\
         \n\
         --tenants SPEC: multi-tenant SLO classes, e.g.\n\
           \"chat:prio=2,share=0.2,ttft=0.5,tpot=0.02,alpha=0.9;bulk:share=0.8,alpha=0.5\""
    );
}

fn load_config(args: &Args) -> anyhow::Result<Config> {
    let mut cfg = match args.get("config") {
        Some(path) => Config::load(Path::new(path))?,
        None => Config::default(),
    };
    if let Some(mode) = args.get("mode") {
        cfg.mode = match mode {
            "synthetic" => Mode::Synthetic,
            "hlo" => Mode::Hlo,
            other => anyhow::bail!("unknown mode {other}"),
        };
    }
    cfg.gamma = args.usize_or("gamma", cfg.gamma)?;
    cfg.max_batch = args.usize_or("max-batch", cfg.max_batch)?;
    if args.flag("adaptive") {
        cfg.adaptive = true;
    }
    if args.flag("ragged") {
        // Ragged rounds are a control-plane refinement, so the flag
        // implies the adaptive controller.
        cfg.adaptive = true;
        cfg.ragged = true;
    }
    if let Some(spec) = args.get("tenants") {
        cfg.tenants = spec.to_string();
    }
    if let Some(path) = args.get("trace") {
        cfg.trace = path.to_string();
    }
    if args.flag("continuous") {
        cfg.continuous = true;
    }
    cfg.prefill_chunk = args.usize_or("prefill-chunk", cfg.prefill_chunk)?;
    if let Some(path) = args.get("record-trace") {
        cfg.record_trace = path.to_string();
    }
    if args.flag("mix-admission") {
        // The mix-aware regime test needs the adaptive controller's
        // priced oracle, so the flag implies it.
        cfg.adaptive = true;
        cfg.mix_admission = true;
    }
    cfg.verify_budget = args.usize_or("verify-budget", cfg.verify_budget)?;
    cfg.dist_workers = args.usize_or("dist-workers", cfg.dist_workers)?;
    cfg.draft_workers = args.usize_or("draft-workers", cfg.draft_workers)?;
    if args.flag("adaptive-budget") {
        // Joint (γ, budget) control is a control-plane refinement, so
        // the flag implies the adaptive controller.
        cfg.adaptive = true;
        cfg.adaptive_budget = true;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn serve(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let port = args.usize_or("port", 7433)?;
    let bind = format!("127.0.0.1:{port}");
    // engine_config() honors cfg.adaptive (validated against the mode).
    let engine_cfg = cfg.engine_config()?;
    println!("starting moesd server on {bind} (mode {:?}, γ={})", cfg.mode, cfg.gamma);
    if engine_cfg.control.is_some() {
        println!("adaptive speculation control plane: model-guided γ/batch co-tuning");
    }
    if !engine_cfg.tenants.is_empty() {
        println!(
            "multi-tenant classes ({}): {}{}",
            engine_cfg.tenants.len(),
            engine_cfg
                .tenants
                .iter()
                .map(|t| t.name.as_str())
                .collect::<Vec<_>>()
                .join(", "),
            if cfg.mix_admission {
                " — mix-aware admission"
            } else {
                ""
            }
        );
    }
    if cfg.continuous {
        println!(
            "continuous batching: chunked prefill ({} tok) + draft-ahead + per-seq rounds",
            cfg.prefill_chunk
        );
    }
    if cfg.verify_budget > 0 {
        println!("verify-expert budget: static cap {} experts", cfg.verify_budget);
    } else if cfg.adaptive_budget {
        println!("verify-expert budget: controller-owned (joint γ/budget selection)");
    }
    let opts = moesd::server::ServerOptions {
        record_trace: (!cfg.record_trace.is_empty())
            .then(|| std::path::PathBuf::from(&cfg.record_trace)),
    };
    let server = match cfg.mode {
        Mode::Hlo => {
            let dir = cfg.artifacts_dir.clone();
            // The PJRT backend holds non-Send XLA handles: build it on the
            // engine thread via the factory entry point.
            moesd::server::Server::start_with_opts(
                &bind,
                engine_cfg,
                move || moesd::runtime::hlo_model::HloBackend::new(Path::new(&dir)),
                opts,
            )?
        }
        Mode::Synthetic => {
            let target = presets::by_name(&cfg.model)?;
            let draft = presets::by_name(&cfg.draft)?;
            let platform = hardware::platform_by_name(&cfg.platform)?;
            let alpha = calibrated_alpha(
                moesd::workload::model_family(&cfg.model),
                Dataset::by_name(&cfg.dataset)?,
                cfg.temperature,
                cfg.gamma.clamp(2, 4),
            );
            let tsim = ExecSim::new(target, platform.clone());
            let dsim = ExecSim::new(draft, platform);
            if cfg.dist_workers > 0 {
                // Distributed serving: the engine drives a coordinator
                // backend whose workers each hold a full SyntheticLm
                // replica (bit-identical to single-process; the
                // conformance suite pins it).
                println!(
                    "distributed serving: coordinator + {} draft rank{} + {} verify rank{} \
                     (in-process loopback transport, pipelined)",
                    cfg.draft_workers,
                    if cfg.draft_workers == 1 { "" } else { "s" },
                    cfg.dist_workers,
                    if cfg.dist_workers == 1 { "" } else { "s" }
                );
                let verify_ranks = cfg.dist_workers;
                let draft_ranks = cfg.draft_workers;
                let budget_curve = cfg.verify_budget > 0 || cfg.adaptive_budget;
                let static_budget = cfg.verify_budget;
                let seed = cfg.seed;
                moesd::server::Server::start_with_opts(
                    &bind,
                    engine_cfg,
                    move || {
                        let factory = move || -> anyhow::Result<SyntheticLm> {
                            let mut b =
                                SyntheticLm::new(tsim.clone(), dsim.clone(), alpha, seed);
                            if budget_curve {
                                b = b.with_budget_alpha_curve(1.0);
                            }
                            Ok(b)
                        };
                        let dist_cfg = moesd::dist::DistConfig {
                            verify_ranks,
                            draft_ranks,
                            ..Default::default()
                        };
                        let mut backend = moesd::dist::DistBackend::launch(dist_cfg, factory)?;
                        if static_budget > 0 {
                            use moesd::spec::SdBackend;
                            backend.set_verify_budget(Some(static_budget));
                        }
                        Ok(backend)
                    },
                    opts,
                )?
            } else {
                let mut backend = SyntheticLm::new(tsim, dsim, alpha, cfg.seed);
                if cfg.verify_budget > 0 || cfg.adaptive_budget {
                    // Budgeted verify degrades acceptance for tokens routed
                    // past the cap; the calibratable curve models that.
                    backend = backend.with_budget_alpha_curve(1.0);
                }
                if cfg.verify_budget > 0 {
                    use moesd::spec::SdBackend;
                    backend.set_verify_budget(Some(cfg.verify_budget));
                }
                moesd::server::Server::start_with_opts(
                    &bind,
                    engine_cfg,
                    move || Ok(backend),
                    opts,
                )?
            }
        }
    };
    println!("listening on {} — newline-delimited JSON; Ctrl-C to stop", server.addr);
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn bench(args: &Args) -> anyhow::Result<()> {
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "bench needs an experiment id (fig1..fig6, table1..3, adaptive, vocab, \
                 sharding, ragged, multitenant, continuous, budget)"
            )
        })?;
    use moesd::experiments::*;
    match which {
        "fig1" => {
            let (a, b, c) = fig1::run(400, 42);
            println!("{}", a.to_string());
            println!("{}", b.to_string());
            moesd::benchlib::write_report("fig1a_activation.csv", &a.to_string())?;
            moesd::benchlib::write_report("fig1b_activation.csv", &b.to_string())?;
            moesd::benchlib::write_report("fig1c_expert_load.csv", &c.to_string())?;
        }
        "fig2" => {
            for (i, panel) in fig2::default_panels().iter().enumerate() {
                let stats = fig2::sweep_panel(panel, 42 + i as u64)?;
                let peak = peak_speedup(&stats);
                println!(
                    "{} on {}: peak {:.2}x at B={}",
                    panel.model, panel.platform, peak.speedup, peak.batch
                );
                moesd::benchlib::write_report(
                    &format!("fig2_panel{i}.csv"),
                    &fig2::panel_csv(panel, &stats).to_string(),
                )?;
            }
        }
        "fig3" => {
            let out = fig3::run(3);
            println!("{}", out.table.to_string());
            moesd::benchlib::write_report("fig3_target_efficiency.csv", &out.table.to_string())?;
        }
        "fig4" => {
            let out = fig4::run(0.88, 7)?;
            println!(
                "fit MSE {:.4}, full MSE {:.4} over {} points",
                out.fit_mse,
                out.full_mse,
                out.points.len()
            );
            moesd::benchlib::write_report(
                "fig4_model_vs_measured.csv",
                &fig4::to_csv(&out).to_string(),
            )?;
        }
        "fig5" => {
            let out = fig5::run("qwen2", "2xGPU-A", Dataset::HumanEval, 0.0, 3, 5)?;
            println!("{}", out.table.to_string());
            moesd::benchlib::write_report("fig5_panel0.csv", &out.table.to_string())?;
        }
        "fig6" => {
            let out = fig6::run(Dataset::HumanEval, 0.0, 3, 21)?;
            println!("{}", out.table.to_string());
            moesd::benchlib::write_report("fig6_humaneval_t0.csv", &out.table.to_string())?;
        }
        "table1" => {
            let rows = tables::table1(42)?;
            println!("{}", tables::render_markdown(&rows));
            moesd::benchlib::write_report("table1_peak_speedup.md", &tables::render_markdown(&rows))?;
        }
        "table2" => {
            let rows = tables::table2(42)?;
            println!("{}", tables::render_markdown(&rows));
            moesd::benchlib::write_report("table2_hardware.md", &tables::render_markdown(&rows))?;
        }
        "table3" => {
            let out = table3::run(0.88, 7)?;
            for r in &out.rows {
                println!("m={:3} stride={:3} MSE={:.4}", r.m, r.stride, r.mse);
            }
            moesd::benchlib::write_report("table3_fit_mse.csv", &table3::to_csv(&out).to_string())?;
        }
        "adaptive" => {
            let out = adaptive::run(0.85, 42)?;
            for r in &out.rows {
                println!(
                    "{:>10} B={:>3}: {:>8.1} tok/s (γ_end={}, ar_bulk={})",
                    r.policy, r.batch, r.tok_s, r.gamma_end, r.ar_bulk_rounds
                );
            }
            moesd::benchlib::write_report("adaptive_ramp.csv", &adaptive::to_csv(&out).to_string())?;
            if let Err(e) = adaptive::check_shape(&out) {
                anyhow::bail!("adaptive ramp shape check failed: {e}");
            }
            println!("shape check passed: adaptive tracks the best static γ per phase");
        }
        "sharding" => {
            let gamma = args.usize_or("gamma", 3)?;
            let alpha = args.f64_or("alpha", 0.9)?;
            let out = sharding::run(gamma, alpha);
            moesd::benchlib::write_report("sharding_sweep.csv", &out.table.to_string())?;
            let mut rows: Vec<moesd::benchlib::Json> = Vec::new();
            for &(fabric, d) in &sharding::default_configs() {
                let edge = sharding::crossover_batch(fabric, d, 8, gamma, alpha);
                let peak = out
                    .points
                    .iter()
                    .filter(|p| p.fabric == fabric && p.devices == d && p.k == 8)
                    .map(|p| p.speedup)
                    .fold(f64::NEG_INFINITY, f64::max);
                println!(
                    "{:>6} d={d}: K=8 peak {:.2}x, SD-favorable up to B≈{edge}",
                    fabric.name(),
                    peak
                );
                rows.push(moesd::benchlib::Json::from_pairs(vec![
                    ("fabric", fabric.name().into()),
                    ("devices", d.into()),
                    ("peak_speedup_k8", peak.into()),
                    ("favorable_edge_k8", edge.into()),
                ]));
            }
            let json = moesd::benchlib::Json::from_pairs(vec![
                ("gamma", gamma.into()),
                ("alpha", alpha.into()),
                ("summary", moesd::benchlib::Json::Arr(rows)),
            ]);
            moesd::benchlib::write_json_report("sharding_sweep.json", &json)?;
            if let Err(e) = sharding::check_shape(&out) {
                anyhow::bail!("sharding sweep shape check failed: {e}");
            }
            println!(
                "shape check passed: sparsity x EP degree widen the SD-favorable \
                 batch range; communication-bound fabrics narrow it"
            );
        }
        "ragged" => {
            let out = ragged::run(
                &ragged::default_alpha_pairs(),
                &ragged::default_batches(),
                &ragged::default_topks(),
                42,
            )?;
            for r in &out.rows {
                println!(
                    "α=({:.2},{:.2}) K={} B={:>3} {:>15}: {:>8.1} tok/s (γ {}/{})",
                    r.alpha_hi, r.alpha_lo, r.k, r.batch, r.policy, r.tok_s, r.gamma_hi, r.gamma_lo
                );
            }
            moesd::benchlib::write_report("ragged_sweep.csv", &ragged::to_csv(&out).to_string())?;
            moesd::benchlib::write_json_report("ragged_sweep.json", &ragged::to_json(&out))?;
            if let Err(e) = ragged::check_shape(&out) {
                anyhow::bail!("ragged sweep shape check failed: {e}");
            }
            println!(
                "shape check passed: per-sequence γ ≥ best uniform γ everywhere, \
                 with a strict win in the memory-bound regime"
            );
        }
        "multitenant" => {
            use moesd::workload::ArrivalTrace;
            let smoke = args.flag("smoke");
            // A supplied trace replays as-is (--trace beats the config
            // file's `trace`); otherwise the bundled production-shaped
            // synthetic trace (tiny in smoke mode).
            let trace_path: Option<String> = match args.get("trace") {
                Some(p) => Some(p.to_string()),
                None => match args.get("config") {
                    Some(cfg_path) => {
                        let cfg = Config::load(Path::new(cfg_path))?;
                        (!cfg.trace.is_empty()).then(|| cfg.trace.clone())
                    }
                    None => None,
                },
            };
            let trace = match &trace_path {
                Some(path) => ArrivalTrace::load(std::path::Path::new(path))?,
                None if smoke => {
                    ArrivalTrace::load(&moesd::benchlib::repo_path("examples/traces/tiny_production.csv"))?
                }
                None => ArrivalTrace::synthetic_production(
                    multitenant::TRACE_DURATION_S,
                    multitenant::TRACE_BASE_RATE,
                    42,
                ),
            };
            let loads: Vec<f64> = match args.get("loads") {
                Some(spec) => spec
                    .split(',')
                    .map(|s| {
                        s.trim()
                            .parse::<f64>()
                            .map_err(|_| anyhow::anyhow!("bad load factor `{s}`"))
                    })
                    .collect::<anyhow::Result<Vec<f64>>>()?,
                None if smoke => vec![4.0],
                None => multitenant::default_loads(),
            };
            println!(
                "multitenant sweep: {} trace events, loads {loads:?}",
                trace.len()
            );
            let out = multitenant::run(&trace, &loads, 42)?;
            for r in &out.rows {
                println!(
                    "load {:>4}x {:>10}: {:>8.1} tok/s (speedup {:.2}, mean B {:>5.1}, \
                     SLOs {} / chat TTFT p99 {:.3}s att {:?})",
                    r.load,
                    r.policy,
                    r.tok_s,
                    r.speedup,
                    r.mean_batch,
                    r.slos_met,
                    r.classes[0].ttft_p99,
                    r.classes[0].ttft_attainment,
                );
            }
            moesd::benchlib::write_report(
                "multitenant_sweep.csv",
                &multitenant::to_csv(&out).to_string(),
            )?;
            moesd::benchlib::write_json_report("multitenant.json", &multitenant::to_json(&out))?;
            // The shape check's margins are calibrated to the default
            // synthetic trace + load sweep; a custom --trace/--loads run
            // is a measurement, not a regression gate, and must not fail
            // on workloads the margins were never tuned for.
            let default_setup = trace_path.is_none() && args.get("loads").is_none();
            if smoke {
                println!("smoke run: per-tenant stats written to results/multitenant.json");
            } else if default_setup {
                if let Err(e) = multitenant::check_shape(&out) {
                    anyhow::bail!("multitenant shape check failed: {e}");
                }
                println!(
                    "shape check passed: class-aware admission meets strictly more SLOs \
                     than FIFO at overload; mix-aware admission sustains the measured \
                     speedup band"
                );
            } else {
                println!(
                    "custom trace/loads: measurement only (shape-check margins are \
                     calibrated to the default trace + loads)"
                );
            }
        }
        "continuous" => {
            use moesd::workload::ArrivalTrace;
            let smoke = args.flag("smoke");
            let trace_path: Option<String> = match args.get("trace") {
                Some(p) => Some(p.to_string()),
                None => match args.get("config") {
                    Some(cfg_path) => {
                        let cfg = Config::load(Path::new(cfg_path))?;
                        (!cfg.trace.is_empty()).then(|| cfg.trace.clone())
                    }
                    None => None,
                },
            };
            let trace = match &trace_path {
                Some(path) => ArrivalTrace::load(std::path::Path::new(path))?,
                None if smoke => {
                    ArrivalTrace::load(&moesd::benchlib::repo_path("examples/traces/tiny_production.csv"))?
                }
                None => ArrivalTrace::synthetic_production_heavy(
                    continuous::TRACE_DURATION_S,
                    continuous::TRACE_BASE_RATE,
                    42,
                ),
            };
            let loads: Vec<f64> = match args.get("loads") {
                Some(spec) => spec
                    .split(',')
                    .map(|s| {
                        s.trim()
                            .parse::<f64>()
                            .map_err(|_| anyhow::anyhow!("bad load factor `{s}`"))
                    })
                    .collect::<anyhow::Result<Vec<f64>>>()?,
                None if smoke => vec![2.0],
                None => continuous::default_loads(),
            };
            println!(
                "continuous-batching sweep: {} trace events, loads {loads:?}",
                trace.len()
            );
            let out = continuous::run(&trace, &loads, 42)?;
            for r in &out.rows {
                println!(
                    "load {:>4}x {:>16}: TTFT p99 {:>7.3}s mean {:>6.3}s | \
                     TPOT mean {:.5}s p99 {:.5}s | goodput {:>8.1} tok/s \
                     (B {:>5.1}, hidden {:>4.1}%, chunks {})",
                    r.load,
                    r.arm,
                    r.ttft_p99,
                    r.ttft_mean,
                    r.tpot_mean,
                    r.tpot_p99,
                    r.goodput,
                    r.mean_batch,
                    100.0 * r.hidden_frac,
                    r.prefill_chunks,
                );
            }
            moesd::benchlib::write_report(
                "continuous_sweep.csv",
                &continuous::to_csv(&out).to_string(),
            )?;
            moesd::benchlib::write_json_report("continuous.json", &continuous::to_json(&out))?;
            // Shape-check margins are calibrated to the default
            // prefill-heavy trace + load sweep only (same policy as the
            // multitenant bench).
            let default_setup = trace_path.is_none() && args.get("loads").is_none();
            if smoke {
                println!("smoke run: per-arm stats written to results/continuous.json");
            } else if default_setup {
                if let Err(e) = continuous::check_shape(&out) {
                    anyhow::bail!("continuous sweep shape check failed: {e}");
                }
                println!(
                    "shape check passed: full pipeline beats lock-step TTFT p99 at \
                     the saturation knee and its goodput at deep overload, without \
                     giving up TPOT or goodput anywhere"
                );
            } else {
                println!(
                    "custom trace/loads: measurement only (shape-check margins are \
                     calibrated to the default trace + loads)"
                );
            }
        }
        "budget" => {
            let smoke = args.flag("smoke");
            let out = budget::run(smoke, 42)?;
            for r in &out.rows {
                println!(
                    "α={:.2} K={} B={:>3} budget {:>8}: {:>8.1} tok/s (speedup {:.3}, γ={})",
                    r.alpha,
                    r.k,
                    r.batch,
                    r.budget
                        .map(|b| b.to_string())
                        .unwrap_or_else(|| "off".into()),
                    r.tok_s,
                    r.speedup,
                    r.gamma,
                );
            }
            moesd::benchlib::write_report("budget_sweep.csv", &budget::to_csv(&out).to_string())?;
            moesd::benchlib::write_json_report("budget.json", &budget::to_json(&out))?;
            if let Err(e) = budget::check_shape(&out) {
                anyhow::bail!("budget sweep shape check failed: {e}");
            }
            println!(
                "shape check passed: budget ≥ E is bit-identical to the unbudgeted \
                 path; a sub-coverage budget strictly wins in the memory-bound regime"
            );
        }
        "vocab" => {
            let out = vocab_scale::run(&vocab_scale::VOCABS, 4, 0.9, 42)?;
            println!("{}", out.table.to_string());
            moesd::benchlib::write_report("vocab_scale.csv", &out.table.to_string())?;
            if let Err(e) = vocab_scale::check_shape(&out) {
                anyhow::bail!("vocab-scale shape check failed: {e}");
            }
            println!("shape check passed: speedup invariant to synthetic vocab up to 151936");
        }
        other => anyhow::bail!("unknown experiment `{other}`"),
    }
    Ok(())
}

fn fit(args: &Args) -> anyhow::Result<()> {
    use moesd::experiments::{run_pair_grid, RunOpts};
    use moesd::fit::fit_perfmodel;
    use moesd::perfmodel::*;
    let gamma = args.usize_or("gamma", 4)?;
    let alpha = args.f64_or("alpha", 0.9)?;
    let target = presets::qwen2_57b_a14b();
    let draft = presets::qwen2_0_5b();
    let platform = hardware::platform_2x_gpu_a();
    let opts = RunOpts::default();
    let grid = moesd::experiments::paper_batch_grid();
    let stats = run_pair_grid(&target, &draft, &platform, alpha, gamma, &grid, &opts)?;
    let mut ms = Vec::new();
    for s in &stats {
        ms.push(Measurement {
            batch: s.batch,
            gamma,
            k: 8,
            e: 64,
            sigma: s.sigma,
            speedup: s.speedup,
        });
        println!("B={:3}: speedup {:.3} σ {:.3}", s.batch, s.speedup, s.sigma);
    }
    let model = PerfModel::new(&platform);
    let bounds = ParamBounds::for_setup(&target, &draft, &platform, 1e-3);
    let (params, mse) = fit_perfmodel(&model, &ms, &bounds, 42);
    println!("\nfitted parameters (MSE {mse:.4}):");
    for (name, v) in PerfParams::names().iter().zip(params.to_vec()) {
        println!("  {name:12} = {v:.6e}");
    }
    Ok(())
}

fn selfcheck(args: &Args) -> anyhow::Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let mut backend = moesd::runtime::hlo_model::HloBackend::new(Path::new(dir))?;
    println!("manifest OK: {} artifacts", backend.manifest().artifacts.len());
    backend.warmup(1)?;
    println!("warmup compile OK");
    backend.self_check()?;
    println!("numerics OK: rust PJRT logits match python reference");
    Ok(())
}

fn list() -> anyhow::Result<()> {
    println!("model presets:");
    for m in presets::all() {
        println!(
            "  {:22} {:>7.2}B total / {:>6.2}B active  ρ={:.3}",
            m.name,
            m.total_params() as f64 / 1e9,
            m.active_params() as f64 / 1e9,
            m.rho()
        );
    }
    println!("\nplatforms: 2xGPU-A, 2xGPU-B, 4xGPU-A, 4xGPU-C");
    for name in ["2xGPU-A", "2xGPU-B", "4xGPU-A", "4xGPU-C"] {
        let p = hardware::platform_by_name(name)?;
        println!(
            "  {name}: ridge point {:.0} tokens, {:.0} GB/s aggregate HBM",
            p.ridge_point(),
            p.total_mem_bw() / 1e9
        );
    }
    Ok(())
}
