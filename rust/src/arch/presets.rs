//! Architecture presets for every model the paper evaluates or references,
//! plus the tiny real model this repo serves end-to-end.
//!
//! Dimensions follow the models' published configs. Where the paper's text
//! disagrees with a public config (e.g. it describes DeepSeek-V2-Lite as
//! ρ = 6/62), we match the *paper*, since its figures are what we reproduce.

use super::{Ffn, ModelArch};

/// Qwen2-57B-A14B-Instruct: 64 routed experts, top-8, with a large shared
/// expert. The paper's primary target model (Tables 1–2, Figs. 2–5).
pub fn qwen2_57b_a14b() -> ModelArch {
    ModelArch {
        name: "qwen2-57b-a14b".into(),
        hidden: 3584,
        layers: 28,
        heads: 28,
        kv_heads: 4,
        head_dim: 128,
        vocab: 151_936,
        ffn: Ffn::Moe {
            experts: 64,
            topk: 8,
            expert_inter: 2560,
            shared_inter: 20_480,
        },
        dtype_bytes: 2.0,
        tied_embeddings: false,
    }
}

/// Qwen2-0.5B-Instruct — the standalone draft model paired with Qwen2-57B.
pub fn qwen2_0_5b() -> ModelArch {
    ModelArch {
        name: "qwen2-0.5b".into(),
        hidden: 896,
        layers: 24,
        heads: 14,
        kv_heads: 2,
        head_dim: 64,
        vocab: 151_936,
        ffn: Ffn::Dense { inter: 4864 },
        dtype_bytes: 2.0,
        tied_embeddings: true,
    }
}

/// Mixtral-8x7B-Instruct v0.1: 8 experts, top-2, no shared expert.
pub fn mixtral_8x7b() -> ModelArch {
    ModelArch {
        name: "mixtral-8x7b".into(),
        hidden: 4096,
        layers: 32,
        heads: 32,
        kv_heads: 8,
        head_dim: 128,
        vocab: 32_000,
        ffn: Ffn::Moe {
            experts: 8,
            topk: 2,
            expert_inter: 14_336,
            shared_inter: 0,
        },
        dtype_bytes: 2.0,
        tied_embeddings: false,
    }
}

/// EAGLE speculation head for Mixtral: a single decoder layer + fc head.
/// Modeled as a one-layer dense model (its cost profile on the draft path).
pub fn eagle_head_mixtral() -> ModelArch {
    ModelArch {
        name: "eagle-head-mixtral".into(),
        hidden: 4096,
        layers: 1,
        heads: 32,
        kv_heads: 8,
        head_dim: 128,
        vocab: 32_000,
        ffn: Ffn::Dense { inter: 14_336 },
        dtype_bytes: 2.0,
        tied_embeddings: true,
    }
}

/// Qwen1.5-MoE-A2.7B-Chat (paper Fig. 1b: ρ = 4/60).
pub fn qwen15_moe() -> ModelArch {
    ModelArch {
        name: "qwen1.5-moe-a2.7b".into(),
        hidden: 2048,
        layers: 24,
        heads: 16,
        kv_heads: 16,
        head_dim: 128,
        vocab: 151_936,
        ffn: Ffn::Moe {
            experts: 60,
            topk: 4,
            expert_inter: 1408,
            shared_inter: 5632,
        },
        dtype_bytes: 2.0,
        tied_embeddings: false,
    }
}

/// DeepSeek-V2-Lite-Chat as described by the paper (Fig. 1a: ρ = 6/62).
pub fn deepseek_v2_lite() -> ModelArch {
    ModelArch {
        name: "deepseek-v2-lite".into(),
        hidden: 2048,
        layers: 27,
        heads: 16,
        kv_heads: 16,
        head_dim: 128,
        vocab: 102_400,
        ffn: Ffn::Moe {
            experts: 62,
            topk: 6,
            expert_inter: 1408,
            shared_inter: 2816,
        },
        dtype_bytes: 2.0,
        tied_embeddings: false,
    }
}

/// OPT-30B — the dense comparison target (Figs. 3, 6).
pub fn opt_30b() -> ModelArch {
    ModelArch {
        name: "opt-30b".into(),
        hidden: 7168,
        layers: 48,
        heads: 56,
        kv_heads: 56,
        head_dim: 128,
        vocab: 50_272,
        // OPT uses a plain (non-gated) 4x FFN: 2 matrices of size h×4h.
        // Our accounting assumes 3 gated matrices, so use inter = 8/3·h to
        // match OPT's true 2·h·4h FFN parameter count.
        ffn: Ffn::Dense { inter: 19_114 },
        dtype_bytes: 2.0,
        tied_embeddings: true,
    }
}

/// OPT-350M — draft for OPT-30B.
pub fn opt_350m() -> ModelArch {
    ModelArch {
        name: "opt-350m".into(),
        hidden: 1024,
        layers: 24,
        heads: 16,
        kv_heads: 16,
        head_dim: 64,
        vocab: 50_272,
        ffn: Ffn::Dense { inter: 2731 },
        dtype_bytes: 2.0,
        tied_embeddings: true,
    }
}

/// The tiny MoE model this repository actually trains, AOT-compiles and
/// serves end-to-end (dims must match `python/compile/model.py`).
pub fn moesd_tiny() -> ModelArch {
    ModelArch {
        name: "moesd-tiny".into(),
        hidden: 128,
        layers: 4,
        heads: 4,
        kv_heads: 4,
        head_dim: 32,
        vocab: 256,
        ffn: Ffn::Moe {
            experts: 8,
            topk: 2,
            expert_inter: 256,
            shared_inter: 0,
        },
        dtype_bytes: 4.0, // served in f32 on the CPU PJRT backend
        tied_embeddings: true,
    }
}

/// Dense draft for the tiny model (dims must match `python/compile/model.py`).
pub fn moesd_tiny_draft() -> ModelArch {
    ModelArch {
        name: "moesd-tiny-draft".into(),
        hidden: 128,
        layers: 2,
        heads: 4,
        kv_heads: 4,
        head_dim: 32,
        vocab: 256,
        ffn: Ffn::Dense { inter: 256 },
        dtype_bytes: 4.0,
        tied_embeddings: true,
    }
}

/// All presets (used by validation tests and the CLI `list-models`).
pub fn all() -> Vec<ModelArch> {
    vec![
        qwen2_57b_a14b(),
        qwen2_0_5b(),
        mixtral_8x7b(),
        eagle_head_mixtral(),
        qwen15_moe(),
        deepseek_v2_lite(),
        opt_30b(),
        opt_350m(),
        moesd_tiny(),
        moesd_tiny_draft(),
    ]
}

/// Look up a preset by name.
pub fn by_name(name: &str) -> anyhow::Result<ModelArch> {
    all()
        .into_iter()
        .find(|m| m.name == name)
        .ok_or_else(|| anyhow::anyhow!("unknown model preset `{name}`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_unique_and_resolvable() {
        let models = all();
        let mut names: Vec<&str> = models.iter().map(|m| m.name.as_str()).collect();
        names.sort_unstable();
        let len_before = names.len();
        names.dedup();
        assert_eq!(names.len(), len_before, "duplicate preset names");
        for m in &models {
            assert_eq!(by_name(&m.name).unwrap(), *m);
        }
        assert!(by_name("nope").is_err());
    }

    #[test]
    fn paper_sparsities() {
        assert!((deepseek_v2_lite().rho() - 6.0 / 62.0).abs() < 1e-12);
        assert!((qwen15_moe().rho() - 4.0 / 60.0).abs() < 1e-12);
        assert!((mixtral_8x7b().rho() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn draft_is_much_smaller_than_target() {
        // §3.1: T_D/T_T is kept small, "usually less than 1/10"; at equal
        // bandwidth the params ratio bounds the time ratio.
        let ratio = qwen2_0_5b().total_params() as f64 / qwen2_57b_a14b().total_params() as f64;
        assert!(ratio < 0.1, "draft/target param ratio {ratio}");
        let tiny_ratio =
            moesd_tiny_draft().total_params() as f64 / moesd_tiny().total_params() as f64;
        assert!(tiny_ratio < 0.55, "tiny draft ratio {tiny_ratio}");
    }
}
