//! Model architecture descriptions: dimensions, parameter counts, and
//! per-operator FLOP / memory-traffic accounting for dense and MoE
//! transformers. The roofline simulator and the analytic perf model both
//! consume these (the paper's "target model architecture" axis).

pub mod presets;

/// Feed-forward block kind.
#[derive(Debug, Clone, PartialEq)]
pub enum Ffn {
    /// Standard dense (gated) FFN with the given intermediate size.
    Dense { inter: usize },
    /// Sparse MoE FFN: `experts` routed experts with `topk` activated per
    /// token, each with intermediate size `expert_inter`, plus an optional
    /// always-on shared expert (`shared_inter` = 0 to disable, as in
    /// Mixtral).
    Moe {
        experts: usize,
        topk: usize,
        expert_inter: usize,
        shared_inter: usize,
    },
}

/// A transformer architecture, parameterized the way the paper's analysis
/// needs: enough to count parameters, FLOPs and bytes for every operator
/// on the decode path.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelArch {
    pub name: String,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    /// KV heads (grouped-query attention); equals `heads` for MHA.
    pub kv_heads: usize,
    pub head_dim: usize,
    pub vocab: usize,
    pub ffn: Ffn,
    /// Bytes per weight element (2.0 for bf16/f16 serving).
    pub dtype_bytes: f64,
    /// Whether input/output embeddings are tied.
    pub tied_embeddings: bool,
}

impl ModelArch {
    /// MoE sparsity ρ = K / E (ρ = 1 for dense models; §3.2).
    pub fn rho(&self) -> f64 {
        match &self.ffn {
            Ffn::Dense { .. } => 1.0,
            Ffn::Moe { experts, topk, .. } => *topk as f64 / *experts as f64,
        }
    }

    pub fn is_moe(&self) -> bool {
        matches!(self.ffn, Ffn::Moe { .. })
    }

    pub fn experts(&self) -> usize {
        match &self.ffn {
            Ffn::Dense { .. } => 1,
            Ffn::Moe { experts, .. } => *experts,
        }
    }

    pub fn topk(&self) -> usize {
        match &self.ffn {
            Ffn::Dense { .. } => 1,
            Ffn::Moe { topk, .. } => *topk,
        }
    }

    /// Clone with a different number of activated experts per token — the
    /// paper's Fig. 4 experiment ("we modify num_experts_per_token in the
    /// model's config.json").
    pub fn with_topk(&self, new_topk: usize) -> ModelArch {
        let mut arch = self.clone();
        if let Ffn::Moe { experts, topk, .. } = &mut arch.ffn {
            assert!(new_topk >= 1 && new_topk <= *experts, "topk out of range");
            *topk = new_topk;
            arch.name = format!("{}-k{}", self.name, new_topk);
        } else {
            panic!("with_topk on a dense model");
        }
        arch
    }

    // ---- parameter counts (elements, not bytes) ---------------------------

    /// Q/K/V/O projections per layer (GQA-aware, no biases).
    pub fn attn_params_per_layer(&self) -> usize {
        let q = self.hidden * self.heads * self.head_dim;
        let kv = 2 * self.hidden * self.kv_heads * self.head_dim;
        let o = self.heads * self.head_dim * self.hidden;
        q + kv + o
    }

    /// One routed expert (gated FFN: up + gate + down).
    pub fn params_per_expert(&self) -> usize {
        match &self.ffn {
            Ffn::Dense { inter } => 3 * self.hidden * inter,
            Ffn::Moe { expert_inter, .. } => 3 * self.hidden * expert_inter,
        }
    }

    /// All FFN parameters in one layer (experts + shared + router gate).
    pub fn ffn_params_per_layer(&self) -> usize {
        match &self.ffn {
            Ffn::Dense { inter } => 3 * self.hidden * inter,
            Ffn::Moe {
                experts,
                expert_inter,
                shared_inter,
                ..
            } => {
                experts * 3 * self.hidden * expert_inter
                    + 3 * self.hidden * shared_inter
                    + self.hidden * experts // router
            }
        }
    }

    pub fn embed_params(&self) -> usize {
        let factor = if self.tied_embeddings { 1 } else { 2 };
        factor * self.vocab * self.hidden
    }

    /// Total parameters (attention + FFN + embeddings; norms are negligible
    /// and omitted, as in the paper's accounting).
    pub fn total_params(&self) -> usize {
        self.layers * (self.attn_params_per_layer() + self.ffn_params_per_layer())
            + self.embed_params()
    }

    /// Parameters touched by a single token (the "A14B" in Qwen2-57B-A14B):
    /// attention + top-K experts + shared expert + router + embeddings.
    pub fn active_params(&self) -> usize {
        let ffn_active = match &self.ffn {
            Ffn::Dense { inter } => 3 * self.hidden * inter,
            Ffn::Moe {
                topk,
                expert_inter,
                shared_inter,
                experts,
            } => topk * 3 * self.hidden * expert_inter
                + 3 * self.hidden * shared_inter
                + self.hidden * experts,
        };
        self.layers * (self.attn_params_per_layer() + ffn_active) + self.embed_params()
    }

    /// Non-FFN ("dense path") parameters: attention + embeddings + shared
    /// expert + router. This is the `V_dense` used for the perf-model `bias`
    /// bound (Appendix C.2).
    pub fn dense_path_params(&self) -> usize {
        let shared = match &self.ffn {
            Ffn::Dense { .. } => 0,
            Ffn::Moe {
                shared_inter,
                experts,
                ..
            } => 3 * self.hidden * shared_inter + self.hidden * experts,
        };
        self.layers * (self.attn_params_per_layer() + shared) + self.embed_params()
    }

    // ---- bytes -------------------------------------------------------------

    pub fn bytes_per_expert(&self) -> f64 {
        self.params_per_expert() as f64 * self.dtype_bytes
    }

    pub fn dense_path_bytes(&self) -> f64 {
        self.dense_path_params() as f64 * self.dtype_bytes
    }

    pub fn total_bytes(&self) -> f64 {
        self.total_params() as f64 * self.dtype_bytes
    }

    /// KV-cache bytes per token across all layers.
    pub fn kv_bytes_per_token(&self) -> f64 {
        (2 * self.layers * self.kv_heads * self.head_dim) as f64 * self.dtype_bytes
    }

    // ---- FLOPs -------------------------------------------------------------

    /// Attention projection + score FLOPs for one token at context length
    /// `ctx` (one layer): 2·params for the GEMMs plus 4·heads·head_dim·ctx
    /// for QK^T and PV.
    pub fn attn_flops_per_token(&self, ctx: usize) -> f64 {
        let proj = 2.0 * self.attn_params_per_layer() as f64;
        let scores = 4.0 * (self.heads * self.head_dim * ctx) as f64;
        proj + scores
    }

    /// FFN FLOPs for one token in one layer (active path only).
    pub fn ffn_flops_per_token(&self) -> f64 {
        match &self.ffn {
            Ffn::Dense { inter } => 2.0 * 3.0 * (self.hidden * inter) as f64,
            Ffn::Moe {
                topk,
                expert_inter,
                shared_inter,
                experts,
            } => {
                2.0 * 3.0 * (*topk * self.hidden * expert_inter) as f64
                    + 2.0 * 3.0 * (self.hidden * shared_inter) as f64
                    + 2.0 * (self.hidden * experts) as f64
            }
        }
    }

    /// End-to-end FLOPs per generated token (all layers + LM head).
    pub fn flops_per_token(&self, ctx: usize) -> f64 {
        self.layers as f64 * (self.attn_flops_per_token(ctx) + self.ffn_flops_per_token())
            + 2.0 * (self.vocab * self.hidden) as f64
    }

    /// Fraction of total parameters living in routed experts — governs how
    /// strongly MoE memory-boundness shows up end-to-end (the Amdahl
    /// argument for the K=1,2 anomaly in §4.2).
    pub fn expert_param_fraction(&self) -> f64 {
        match &self.ffn {
            Ffn::Dense { .. } => 0.0,
            Ffn::Moe { experts, .. } => {
                let expert_total = self.layers * experts * self.params_per_expert();
                expert_total as f64 / self.total_params() as f64
            }
        }
    }

    /// Sanity-check invariants; called by config loading.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.hidden > 0 && self.layers > 0 && self.vocab > 0);
        anyhow::ensure!(self.heads > 0 && self.kv_heads > 0 && self.head_dim > 0);
        anyhow::ensure!(
            self.heads % self.kv_heads == 0,
            "heads must be divisible by kv_heads"
        );
        if let Ffn::Moe { experts, topk, .. } = &self.ffn {
            anyhow::ensure!(*topk >= 1 && topk <= experts, "invalid topk");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::presets;

    #[test]
    fn qwen2_moe_totals_are_plausible() {
        let m = presets::qwen2_57b_a14b();
        let total = m.total_params() as f64 / 1e9;
        let active = m.active_params() as f64 / 1e9;
        // Paper model: 57B total, 14B active. Our accounting (no norms,
        // approximate shared-expert size) should land within ~10%.
        assert!((total - 57.0).abs() < 6.0, "total={total}B");
        assert!((active - 14.0).abs() < 2.0, "active={active}B");
        assert!((m.rho() - 8.0 / 64.0).abs() < 1e-12);
    }

    #[test]
    fn mixtral_totals() {
        let m = presets::mixtral_8x7b();
        let total = m.total_params() as f64 / 1e9;
        let active = m.active_params() as f64 / 1e9;
        assert!((total - 46.7).abs() < 3.0, "total={total}B");
        assert!((active - 12.9).abs() < 2.0, "active={active}B");
        assert_eq!(m.experts(), 8);
        assert_eq!(m.topk(), 2);
    }

    #[test]
    fn opt30b_dense_totals() {
        let m = presets::opt_30b();
        let total = m.total_params() as f64 / 1e9;
        assert!((total - 30.0).abs() < 3.0, "total={total}B");
        assert_eq!(m.rho(), 1.0);
        assert!(!m.is_moe());
        assert_eq!(m.expert_param_fraction(), 0.0);
    }

    #[test]
    fn with_topk_rescales_sparsity() {
        let m = presets::qwen2_57b_a14b();
        let m2 = m.with_topk(2);
        assert_eq!(m2.topk(), 2);
        assert!((m2.rho() - 2.0 / 64.0).abs() < 1e-12);
        // Total params unchanged; active params shrink.
        assert_eq!(m.total_params(), m2.total_params());
        assert!(m2.active_params() < m.active_params());
    }

    #[test]
    #[should_panic(expected = "with_topk on a dense model")]
    fn with_topk_rejects_dense() {
        presets::opt_30b().with_topk(2);
    }

    #[test]
    fn active_leq_total() {
        for m in presets::all() {
            assert!(
                m.active_params() <= m.total_params(),
                "{}: active > total",
                m.name
            );
            m.validate().unwrap();
        }
    }

    #[test]
    fn flops_scale_with_context() {
        let m = presets::qwen2_57b_a14b();
        assert!(m.flops_per_token(4096) > m.flops_per_token(128));
    }

    #[test]
    fn expert_fraction_dominates_for_sparse_moe() {
        // The paper's §4.2 Amdahl argument: Qwen2-57B is expert-dominated.
        let m = presets::qwen2_57b_a14b();
        assert!(m.expert_param_fraction() > 0.7, "{}", m.expert_param_fraction());
    }

    #[test]
    fn kv_bytes_positive_and_gqa_smaller() {
        let qwen = presets::qwen2_57b_a14b(); // GQA, 4 kv heads
        let mixtral = presets::mixtral_8x7b(); // GQA, 8 kv heads
        assert!(qwen.kv_bytes_per_token() > 0.0);
        assert!(mixtral.kv_bytes_per_token() > 0.0);
    }

    #[test]
    fn tiny_model_matches_python_side() {
        // These dims must agree with python/compile/model.py (AOT side).
        let t = presets::moesd_tiny();
        assert_eq!(t.hidden, 128);
        assert_eq!(t.layers, 4);
        assert_eq!(t.experts(), 8);
        assert_eq!(t.topk(), 2);
        assert_eq!(t.vocab, 256);
        let d = presets::moesd_tiny_draft();
        assert_eq!(d.layers, 2);
        assert!(!d.is_moe());
    }
}
