//! PJRT runtime: load AOT artifacts (HLO text) and execute them on the
//! CPU client from the L3 hot path. Python never runs at serve time.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO **text** is the interchange format
//! (xla_extension 0.5.1 rejects jax≥0.5 serialized protos).

pub mod hlo_model;
pub mod weights;

use crate::util::json::Json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Model dims as recorded in the artifact manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelDims {
    pub vocab: usize,
    pub hidden: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub layers: usize,
    pub kv_max: usize,
    pub moe: bool,
}

impl ModelDims {
    fn from_json(j: &Json) -> anyhow::Result<ModelDims> {
        Ok(ModelDims {
            vocab: j.req_usize("vocab")?,
            hidden: j.req_usize("hidden")?,
            heads: j.req_usize("heads")?,
            head_dim: j.req_usize("head_dim")?,
            layers: j.req_usize("layers")?,
            kv_max: j.req_usize("kv_max")?,
            moe: j.get("moe").and_then(Json::as_bool).unwrap_or(false),
        })
    }

    /// Elements in one sequence's per-layer KV slab [Smax, H, Dh].
    pub fn kv_slab_elems(&self) -> usize {
        self.kv_max * self.heads * self.head_dim
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub buckets: Vec<usize>,
    pub target_steps: Vec<usize>,
    pub draft_steps: Vec<usize>,
    pub prefill_s: usize,
    pub gamma_max: usize,
    pub target: ModelDims,
    pub draft: ModelDims,
    /// key (e.g. "target_b4_s2") → file name.
    pub artifacts: HashMap<String, String>,
    /// Expected logits for the numerics self-check.
    pub numerics_tokens: Vec<u32>,
    pub numerics_logits_row1: Vec<f64>,
    pub numerics_argmax_row1: usize,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))?;
        let usize_list = |key: &str| -> anyhow::Result<Vec<usize>> {
            Ok(j.req_arr(key)?
                .iter()
                .filter_map(Json::as_usize)
                .collect())
        };
        let mut artifacts = HashMap::new();
        if let Some(obj) = j.get("artifacts").and_then(Json::as_obj) {
            for (k, v) in obj.iter() {
                artifacts.insert(
                    k.to_string(),
                    v.as_str()
                        .ok_or_else(|| anyhow::anyhow!("bad artifact entry {k}"))?
                        .to_string(),
                );
            }
        }
        let numerics = j
            .get("numerics")
            .and_then(|n| n.get("target"))
            .ok_or_else(|| anyhow::anyhow!("manifest missing numerics.target"))?;
        Ok(Manifest {
            dir: dir.to_path_buf(),
            buckets: usize_list("buckets")?,
            target_steps: usize_list("target_steps")?,
            draft_steps: usize_list("draft_steps")?,
            prefill_s: j.req_usize("prefill_s")?,
            gamma_max: j.req_usize("gamma_max")?,
            target: ModelDims::from_json(
                j.get("target").ok_or_else(|| anyhow::anyhow!("no target"))?,
            )?,
            draft: ModelDims::from_json(
                j.get("draft").ok_or_else(|| anyhow::anyhow!("no draft"))?,
            )?,
            artifacts,
            numerics_tokens: numerics
                .req_arr("tokens")?
                .iter()
                .filter_map(|t| t.as_usize().map(|v| v as u32))
                .collect(),
            numerics_logits_row1: numerics
                .req_arr("logits_row1_first8")?
                .iter()
                .filter_map(Json::as_f64)
                .collect(),
            numerics_argmax_row1: numerics.req_usize("argmax_row1")?,
        })
    }

    /// Smallest bucket ≥ n (the batch padding target).
    pub fn bucket_for(&self, n: usize) -> anyhow::Result<usize> {
        self.buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .ok_or_else(|| {
                anyhow::anyhow!("batch {n} exceeds largest compiled bucket {:?}", self.buckets)
            })
    }

    pub fn artifact_path(&self, model: &str, b: usize, s: usize) -> anyhow::Result<PathBuf> {
        let key = format!("{model}_b{b}_s{s}");
        let fname = self
            .artifacts
            .get(&key)
            .ok_or_else(|| anyhow::anyhow!("no artifact `{key}` in manifest"))?;
        Ok(self.dir.join(fname))
    }
}

/// A compiled-executable cache over the PJRT CPU client.
pub struct PjrtEngine {
    pub client: xla::PjRtClient,
    manifest: Manifest,
    executables: HashMap<(String, usize, usize), xla::PjRtLoadedExecutable>,
}

impl PjrtEngine {
    pub fn new(artifacts_dir: &Path) -> anyhow::Result<PjrtEngine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(PjrtEngine {
            client,
            manifest,
            executables: HashMap::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Get (compiling on first use) the executable for (model, B, S).
    pub fn executable(
        &mut self,
        model: &str,
        b: usize,
        s: usize,
    ) -> anyhow::Result<&xla::PjRtLoadedExecutable> {
        let key = (model.to_string(), b, s);
        if !self.executables.contains_key(&key) {
            let path = self.manifest.artifact_path(model, b, s)?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {model}_b{b}_s{s}: {e:?}"))?;
            self.executables.insert(key.clone(), exe);
        }
        Ok(self.executables.get(&key).unwrap())
    }

    pub fn compiled_count(&self) -> usize {
        self.executables.len()
    }
}

/// Build an f32 literal of the given logical dims.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> anyhow::Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "literal size mismatch");
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

/// Build an i32 literal of the given logical dims.
pub fn literal_i32(data: &[i32], dims: &[usize]) -> anyhow::Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "literal size mismatch");
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let p = PathBuf::from("artifacts");
        if p.join("manifest.json").exists() {
            Some(p)
        } else {
            None
        }
    }

    #[test]
    fn manifest_parses_if_present() {
        let Some(dir) = artifacts_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.target.vocab, 256);
        assert_eq!(m.target.layers, 4);
        assert!(m.target.moe);
        assert!(!m.draft.moe);
        assert_eq!(m.bucket_for(3).unwrap(), 4);
        assert_eq!(m.bucket_for(1).unwrap(), 1);
        assert!(m.bucket_for(100).is_err());
        assert!(m.artifact_path("target", 1, 1).unwrap().exists());
        assert!(m.artifact_path("target", 3, 1).is_err());
        assert_eq!(m.numerics_logits_row1.len(), 8);
    }

    #[test]
    fn literal_builders() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(literal_f32(&[1.0], &[2]).is_err());
        let i = literal_i32(&[5, 6], &[2]).unwrap();
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![5, 6]);
    }
}
