//! Loader for `artifacts/weights.bin` (format written by
//! `python/compile/aot.py::write_weights_bin`):
//!
//! ```text
//! magic "MOESDW01" | u32 tensor_count | tensor*
//! tensor: u32 name_len | name bytes | u32 ndim | u32 dims[ndim] | f32 data
//! ```
//! All integers little-endian; data is row-major f32.

use std::collections::HashMap;
use std::path::Path;

/// One named tensor.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }
}

/// The full weight set, preserving file order (= `param_specs` order).
#[derive(Debug, Default)]
pub struct Weights {
    pub tensors: Vec<Tensor>,
    index: HashMap<String, usize>,
}

impl Weights {
    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.index.get(name).map(|&i| &self.tensors[i])
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Tensors whose name starts with `prefix.`, in file order.
    pub fn with_prefix(&self, prefix: &str) -> Vec<&Tensor> {
        let pat = format!("{prefix}.");
        self.tensors
            .iter()
            .filter(|t| t.name.starts_with(&pat))
            .collect()
    }

    pub fn parse(bytes: &[u8]) -> anyhow::Result<Weights> {
        anyhow::ensure!(bytes.len() >= 12, "weights.bin truncated");
        anyhow::ensure!(&bytes[..8] == b"MOESDW01", "bad magic in weights.bin");
        let mut off = 8usize;
        let read_u32 = |off: &mut usize| -> anyhow::Result<u32> {
            anyhow::ensure!(*off + 4 <= bytes.len(), "truncated at {off}");
            let v = u32::from_le_bytes(bytes[*off..*off + 4].try_into().unwrap());
            *off += 4;
            Ok(v)
        };
        let count = read_u32(&mut off)? as usize;
        anyhow::ensure!(count < 100_000, "implausible tensor count {count}");
        let mut w = Weights::default();
        for _ in 0..count {
            let name_len = read_u32(&mut off)? as usize;
            anyhow::ensure!(off + name_len <= bytes.len(), "truncated name");
            let name = std::str::from_utf8(&bytes[off..off + name_len])?.to_string();
            off += name_len;
            let ndim = read_u32(&mut off)? as usize;
            anyhow::ensure!(ndim <= 8, "implausible rank {ndim} for {name}");
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(read_u32(&mut off)? as usize);
            }
            let n: usize = dims.iter().product();
            anyhow::ensure!(
                off + 4 * n <= bytes.len(),
                "truncated data for {name}: need {n} f32s"
            );
            let mut data = vec![0f32; n];
            for (i, chunk) in bytes[off..off + 4 * n].chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes(chunk.try_into().unwrap());
            }
            off += 4 * n;
            anyhow::ensure!(
                !w.index.contains_key(&name),
                "duplicate tensor `{name}`"
            );
            w.index.insert(name.clone(), w.tensors.len());
            w.tensors.push(Tensor { name, dims, data });
        }
        anyhow::ensure!(off == bytes.len(), "trailing bytes in weights.bin");
        Ok(w)
    }

    pub fn load(path: &Path) -> anyhow::Result<Weights> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Weights::parse(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn encode(tensors: &[(&str, &[usize], &[f32])]) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(b"MOESDW01");
        out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
        for (name, dims, data) in tensors {
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.extend_from_slice(&(dims.len() as u32).to_le_bytes());
            for &d in *dims {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            for &v in *data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    #[test]
    fn parse_roundtrip() {
        let bytes = encode(&[
            ("target.embed", &[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            ("draft.ln_f", &[4], &[1.0, 1.0, 1.0, 1.0]),
        ]);
        let w = Weights::parse(&bytes).unwrap();
        assert_eq!(w.len(), 2);
        let t = w.get("target.embed").unwrap();
        assert_eq!(t.dims, vec![2, 3]);
        assert_eq!(t.data[4], 5.0);
        assert_eq!(w.with_prefix("target").len(), 1);
        assert_eq!(w.with_prefix("draft").len(), 1);
        assert!(w.get("missing").is_none());
    }

    #[test]
    fn rejects_corruption() {
        let good = encode(&[("a", &[1], &[1.0])]);
        assert!(Weights::parse(&good[..4]).is_err()); // truncated magic
        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        assert!(Weights::parse(&bad_magic).is_err());
        let mut trailing = good.clone();
        trailing.push(0);
        assert!(Weights::parse(&trailing).is_err());
        let truncated = &good[..good.len() - 2];
        assert!(Weights::parse(truncated).is_err());
    }

    #[test]
    fn rejects_duplicates() {
        let bytes = encode(&[("a", &[1], &[1.0]), ("a", &[1], &[2.0])]);
        assert!(Weights::parse(&bytes).is_err());
    }

    #[test]
    fn loads_real_artifact_if_present() {
        let path = std::path::Path::new("artifacts/weights.bin");
        if !path.exists() {
            return; // `make artifacts` not run yet — covered in integration
        }
        let w = Weights::load(path).unwrap();
        assert!(w.get("target.embed").is_some());
        assert!(w.get("draft.embed").is_some());
        let embed = w.get("target.embed").unwrap();
        assert_eq!(embed.dims, vec![256, 128]);
        assert!(embed.data.iter().all(|v| v.is_finite()));
    }
}
