//! [`SdBackend`] over the real AOT-compiled models (PJRT CPU).
//!
//! This is the serve path of the three-layer architecture: the tiny MoE
//! target and dense draft, trained and lowered by `python/compile/`, are
//! executed through the `xla` crate with **measured wall-clock costs** —
//! no Python anywhere.
//!
//! KV caches are canonical on the host (one slab per sequence per layer);
//! each call assembles the batch tensors for the executable's fixed
//! (bucket, step) shape, padding unused slots. Rollback is O(1): the
//! per-sequence length decreases and stale cache positions are ignored by
//! the causal mask, then overwritten (the property pytest pins down in
//! `test_rollback_by_lens_is_exact`).

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use super::{ModelDims, PjrtEngine};
use crate::kvcache::SeqId;
use crate::sampling::{argmax_f32, softmax_with_temperature};
use crate::spec::{LogitsView, ProposeOut, SdBackend, VerifyOut};
use crate::util::rng::Rng;

/// One logits row → the cheapest exact [`LogitsView`]: greedy rows
/// (temperature 0) are degenerate, so they ship as a two-word `OneHot`
/// instead of a vocab-sized softmax output; positive temperatures have
/// full support and stay `Dense`.
fn row_view(logits: &[f32], temp: f64) -> LogitsView {
    if temp <= 0.0 {
        LogitsView::one_hot(argmax_f32(logits) as u32, logits.len())
    } else {
        LogitsView::dense(softmax_with_temperature(logits, temp))
    }
}

/// Host-side state for one model of one sequence.
#[derive(Debug, Clone)]
struct ModelSeqState {
    /// [L][Smax·H·Dh] flattened KV slabs.
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    len: usize,
}

impl ModelSeqState {
    fn new(dims: &ModelDims) -> ModelSeqState {
        let slab = dims.kv_slab_elems();
        ModelSeqState {
            k: vec![vec![0.0; slab]; dims.layers],
            v: vec![vec![0.0; slab]; dims.layers],
            len: 0,
        }
    }
}

struct SeqState {
    target: ModelSeqState,
    draft: ModelSeqState,
}

/// Whole-batch host KV from one forward of one model.
///
/// §Perf L3 optimization #2: in steady state the decode batch composition
/// is stable, so the KV tensors produced by one forward are exactly the
/// inputs of the next. Ideally they would stay on device, but the pinned
/// `xla` crate hardcodes `ExecuteOptions::untuple_result = false`, so the
/// (logits, k, v) root tuple always comes back as one host literal — the
/// device→host readback is unavoidable. What *can* be skipped is the
/// per-sequence scatter/gather on the host: cache the whole-batch k/v
/// vectors and re-upload them directly while the composition repeats,
/// scattering to per-seq slabs only on eviction.
struct KvBatchCache {
    seq_ids: Vec<SeqId>,
    bucket: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Creation generation: a sequence's KV in this entry is current iff
    /// `ModelKvCaches::latest[seq] == gen` (no later forward touched it).
    gen: u64,
}

/// Cached batch-KV snapshots per model, **keyed by composition** (PR-4
/// follow-up): ragged draft rounds forward a shrinking active subset each
/// step, so one most-recent-forward slot missed on every step. Instead a
/// small ring of recent compositions is kept, with a per-sequence
/// `latest`-generation map deciding both exact hits (every sequence's
/// latest KV lives in the matched entry) and row-level assembly sources
/// (copy each sequence's rows from whichever entry — or host slab — holds
/// its latest KV, with no whole-batch flush on a composition change).
/// Repeated compositions (the steady-state ragged schedule, prefill
/// chunk streams) hit; correctness never depends on hitting — stale rows
/// beyond a sequence's `len` are masked, and rows of sequences advanced
/// elsewhere are never current by the generation rule.
#[derive(Default)]
struct ModelKvCaches {
    entries: Vec<KvBatchCache>,
    /// seq → generation of the entry holding its latest KV; absent means
    /// the host slab is current.
    latest: HashMap<SeqId, u64>,
    next_gen: u64,
}

/// Composition cache capacity per model: the full batch plus the distinct
/// shrinking subsets of a steady ragged round schedule.
const KV_CACHE_ENTRIES: usize = 4;

/// Output of one raw model forward.
struct ForwardOut {
    /// [real_b][s][vocab] logits.
    logits: Vec<Vec<Vec<f32>>>,
    seconds: f64,
}

/// The PJRT-backed model pair.
pub struct HloBackend {
    engine: PjrtEngine,
    /// Model weights resident on the PJRT device, uploaded once at load
    /// time (§Perf L2/L3: re-uploading ~11 MB of literals per forward was
    /// the dominant per-call overhead before this).
    target_params: Vec<xla::PjRtBuffer>,
    draft_params: Vec<xla::PjRtBuffer>,
    seqs: HashMap<SeqId, SeqState>,
    kv_cache: HashMap<String, ModelKvCaches>,
    rng: Rng,
}

impl HloBackend {
    pub fn new(artifacts_dir: &Path) -> anyhow::Result<HloBackend> {
        let engine = PjrtEngine::new(artifacts_dir)?;
        let weights = super::weights::Weights::load(&artifacts_dir.join("weights.bin"))?;
        let mk_params = |prefix: &str| -> anyhow::Result<Vec<xla::PjRtBuffer>> {
            let tensors = weights.with_prefix(prefix);
            anyhow::ensure!(!tensors.is_empty(), "no `{prefix}.*` weights");
            tensors
                .iter()
                .map(|t| {
                    engine
                        .client
                        .buffer_from_host_buffer::<f32>(&t.data, &t.dims, None)
                        .map_err(|e| anyhow::anyhow!("uploading {}: {e:?}", t.name))
                })
                .collect()
        };
        let target_params = mk_params("target")?;
        let draft_params = mk_params("draft")?;
        Ok(HloBackend {
            engine,
            target_params,
            draft_params,
            seqs: HashMap::new(),
            kv_cache: HashMap::new(),
            rng: Rng::seeded(0x410),
        })
    }

    pub fn manifest(&self) -> &super::Manifest {
        self.engine.manifest()
    }

    /// Pre-compile the executables for a batch-size bucket (avoids paying
    /// compile time inside the serving loop).
    pub fn warmup(&mut self, bucket: usize) -> anyhow::Result<()> {
        let m = self.engine.manifest().clone();
        for &s in &m.target_steps {
            self.engine.executable("target", bucket, s)?;
        }
        self.engine.executable("target", bucket, m.prefill_s)?;
        for &s in &m.draft_steps {
            self.engine.executable("draft", bucket, s)?;
        }
        self.engine.executable("draft", bucket, m.prefill_s)?;
        Ok(())
    }

    /// Numerics self-check against the manifest's expected logits — the
    /// Python↔Rust AOT round-trip gate (run by `moesd selfcheck` and the
    /// integration tests).
    pub fn self_check(&mut self) -> anyhow::Result<()> {
        let m = self.engine.manifest().clone();
        let tokens = m.numerics_tokens.clone();
        anyhow::ensure!(tokens.len() == 2, "unexpected numerics vector");
        self.seqs.insert(u64::MAX, SeqState {
            target: ModelSeqState::new(&m.target),
            draft: ModelSeqState::new(&m.draft),
        });
        let out = self.forward_model("target", &[u64::MAX], &[tokens], 2)?;
        self.release(u64::MAX);
        let row1 = &out.logits[0][1];
        for (i, &want) in m.numerics_logits_row1.iter().enumerate() {
            let got = row1[i] as f64;
            anyhow::ensure!(
                (got - want).abs() < 1e-3 * (1.0 + want.abs()),
                "numerics mismatch at logit {i}: rust {got} vs python {want}"
            );
        }
        let argmax = crate::sampling::argmax_f32(row1);
        anyhow::ensure!(
            argmax == m.numerics_argmax_row1,
            "argmax mismatch: {argmax} vs {}",
            m.numerics_argmax_row1
        );
        Ok(())
    }

    /// Evict one cached entry: rows still holding a sequence's latest KV
    /// flush to the per-sequence host slabs (released sequences are
    /// skipped), then the entry is dropped.
    fn evict_kv_entry(&mut self, model: &str, entry_idx: usize) {
        let dims = self.dims(model);
        let slab = dims.kv_slab_elems();
        let Some(caches) = self.kv_cache.get_mut(model) else {
            return;
        };
        let old = caches.entries.remove(entry_idx);
        for (i, id) in old.seq_ids.iter().enumerate() {
            if caches.latest.get(id) != Some(&old.gen) {
                continue; // a newer forward owns this sequence's KV
            }
            caches.latest.remove(id);
            let Some(st) = self.seqs.get_mut(id) else { continue };
            let ms = if model == "target" {
                &mut st.target
            } else {
                &mut st.draft
            };
            for l in 0..dims.layers {
                let off = (l * old.bucket + i) * slab;
                ms.k[l].copy_from_slice(&old.k[off..off + slab]);
                ms.v[l].copy_from_slice(&old.v[off..off + slab]);
            }
        }
    }

    fn dims(&self, model: &str) -> ModelDims {
        if model == "target" {
            self.engine.manifest().target.clone()
        } else {
            self.engine.manifest().draft.clone()
        }
    }

    /// Run one forward of `s` tokens per sequence for `model`, updating
    /// the per-sequence KV slabs and lengths.
    fn forward_model(
        &mut self,
        model: &str,
        seq_ids: &[SeqId],
        tokens: &[Vec<u32>],
        s: usize,
    ) -> anyhow::Result<ForwardOut> {
        let t0 = Instant::now();
        let dims = self.dims(model);
        let n = seq_ids.len();
        anyhow::ensure!(n > 0 && tokens.len() == n);
        let bucket = self.engine.manifest().bucket_for(n)?;
        let slab = dims.kv_slab_elems();

        // Assemble batch inputs.
        let mut tok_data = vec![0i32; bucket * s];
        let mut lens_data = vec![0i32; bucket];
        for (i, &id) in seq_ids.iter().enumerate() {
            anyhow::ensure!(tokens[i].len() <= s, "too many tokens for step {s}");
            for (j, &t) in tokens[i].iter().enumerate() {
                tok_data[i * s + j] = t as i32;
            }
            let st = self.seqs.get(&id).expect("unknown sequence");
            let ms = if model == "target" { &st.target } else { &st.draft };
            lens_data[i] = ms.len as i32;
            anyhow::ensure!(
                ms.len + s <= dims.kv_max,
                "KV overflow: seq {id} at {} + {s} > {}",
                ms.len,
                dims.kv_max
            );
        }
        let kv_dims = [dims.layers, bucket, dims.kv_max, dims.heads, dims.head_dim];
        let client = &self.engine.client;
        let to_buf_f32 = |data: &[f32], d: &[usize]| -> anyhow::Result<xla::PjRtBuffer> {
            client
                .buffer_from_host_buffer::<f32>(data, d, None)
                .map_err(|e| anyhow::anyhow!("upload f32: {e:?}"))
        };
        let to_buf_i32 = |data: &[i32], d: &[usize]| -> anyhow::Result<xla::PjRtBuffer> {
            client
                .buffer_from_host_buffer::<i32>(data, d, None)
                .map_err(|e| anyhow::anyhow!("upload i32: {e:?}"))
        };
        let tok_buf = to_buf_i32(&tok_data, &[bucket, s])?;
        let lens_buf = to_buf_i32(&lens_data, &[bucket])?;
        // Upload KV. Composition-keyed fast path: if some cached entry
        // has this exact (bucket, composition) AND still holds every
        // sequence's latest KV, its buffers upload verbatim (rollback
        // only shrinks `len`; stale positions are masked). Otherwise the
        // batch assembles row-by-row from wherever each sequence's latest
        // KV lives — a cached entry's row or the host slab — with no
        // whole-batch flush on the way.
        let caches = self.kv_cache.entry(model.to_string()).or_default();
        let exact = caches.entries.iter().position(|e| {
            e.bucket == bucket
                && e.seq_ids == seq_ids
                && seq_ids
                    .iter()
                    .all(|id| caches.latest.get(id) == Some(&e.gen))
        });
        let (k_buf, v_buf) = match exact {
            Some(idx) => {
                let e = &caches.entries[idx];
                (to_buf_f32(&e.k, &kv_dims)?, to_buf_f32(&e.v, &kv_dims)?)
            }
            None => {
                let mut k_data = vec![0f32; dims.layers * bucket * slab];
                let mut v_data = vec![0f32; dims.layers * bucket * slab];
                for (i, &id) in seq_ids.iter().enumerate() {
                    let cached = caches.latest.get(&id).and_then(|gen| {
                        caches.entries.iter().find(|e| e.gen == *gen).map(|e| {
                            let row = e
                                .seq_ids
                                .iter()
                                .position(|&s| s == id)
                                .expect("latest entry contains its sequence");
                            (e, row)
                        })
                    });
                    match cached {
                        Some((e, row)) => {
                            for l in 0..dims.layers {
                                let src = (l * e.bucket + row) * slab;
                                let dst = (l * bucket + i) * slab;
                                k_data[dst..dst + slab]
                                    .copy_from_slice(&e.k[src..src + slab]);
                                v_data[dst..dst + slab]
                                    .copy_from_slice(&e.v[src..src + slab]);
                            }
                        }
                        None => {
                            let st = self.seqs.get(&id).unwrap();
                            let ms = if model == "target" { &st.target } else { &st.draft };
                            for l in 0..dims.layers {
                                let off = (l * bucket + i) * slab;
                                k_data[off..off + slab].copy_from_slice(&ms.k[l]);
                                v_data[off..off + slab].copy_from_slice(&ms.v[l]);
                            }
                        }
                    }
                }
                (to_buf_f32(&k_data, &kv_dims)?, to_buf_f32(&v_data, &kv_dims)?)
            }
        };

        let params = if model == "target" {
            &self.target_params
        } else {
            &self.draft_params
        };
        let mut args: Vec<&xla::PjRtBuffer> = params.iter().collect();
        args.push(&tok_buf);
        args.push(&k_buf);
        args.push(&v_buf);
        args.push(&lens_buf);

        let exe = self.engine.executable(model, bucket, s)?;
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .map_err(|e| anyhow::anyhow!("execute {model}_b{bucket}_s{s}: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        let (logits_l, new_k, new_v) = out
            .to_tuple3()
            .map_err(|e| anyhow::anyhow!("tuple3: {e:?}"))?;

        // Keep the whole-batch KV for the next same-composition call; the
        // per-seq slabs are refreshed lazily on entry eviction.
        let new_k: Vec<f32> = new_k
            .to_vec()
            .map_err(|e| anyhow::anyhow!("kv readback: {e:?}"))?;
        let new_v: Vec<f32> = new_v
            .to_vec()
            .map_err(|e| anyhow::anyhow!("kv readback: {e:?}"))?;
        {
            let caches = self.kv_cache.get_mut(model).expect("entry created above");
            let gen = caches.next_gen;
            caches.next_gen += 1;
            for &id in seq_ids {
                caches.latest.insert(id, gen);
            }
            caches.entries.push(KvBatchCache {
                seq_ids: seq_ids.to_vec(),
                bucket,
                k: new_k,
                v: new_v,
                gen,
            });
        }
        while self.kv_cache[model].entries.len() > KV_CACHE_ENTRIES {
            self.evict_kv_entry(model, 0);
        }
        for (i, &id) in seq_ids.iter().enumerate() {
            let st = self.seqs.get_mut(&id).unwrap();
            let ms = if model == "target" {
                &mut st.target
            } else {
                &mut st.draft
            };
            ms.len += tokens[i].len(); // only the real tokens advance `len`
        }

        // Unpack logits rows for the real sequences.
        let flat: Vec<f32> = logits_l
            .to_vec()
            .map_err(|e| anyhow::anyhow!("logits readback: {e:?}"))?;
        let v_sz = dims.vocab;
        let mut logits = Vec::with_capacity(n);
        for i in 0..n {
            let mut rows = Vec::with_capacity(s);
            for j in 0..s {
                let off = (i * s + j) * v_sz;
                rows.push(flat[off..off + v_sz].to_vec());
            }
            logits.push(rows);
        }
        Ok(ForwardOut {
            logits,
            seconds: t0.elapsed().as_secs_f64(),
        })
    }

    /// Chunked prompt ingestion for one model (processes `n_tokens` of the
    /// given token streams through fixed-size prefill executables).
    fn prefill_model(
        &mut self,
        model: &str,
        batch: &[(SeqId, Vec<u32>)],
    ) -> anyhow::Result<f64> {
        let prefill_s = self.engine.manifest().prefill_s;
        let mut total = 0.0;
        let max_len = batch
            .iter()
            .map(|(_, p)| p.len().saturating_sub(1))
            .max()
            .unwrap_or(0);
        let seq_ids: Vec<SeqId> = batch.iter().map(|(id, _)| *id).collect();
        let mut offset = 0;
        while offset < max_len {
            let chunk_real: Vec<Vec<u32>> = batch
                .iter()
                .map(|(_, p)| {
                    let body = &p[..p.len() - 1];
                    let lo = offset.min(body.len());
                    let hi = (offset + prefill_s).min(body.len());
                    body[lo..hi].to_vec()
                })
                .collect();
            let out = self.forward_model(model, &seq_ids, &chunk_real, prefill_s)?;
            total += out.seconds;
            offset += prefill_s;
        }
        Ok(total)
    }
}

impl SdBackend for HloBackend {
    fn vocab(&self) -> usize {
        self.engine.manifest().target.vocab
    }

    fn prefill(&mut self, batch: &[(SeqId, Vec<u32>)]) -> anyhow::Result<f64> {
        for (id, prompt) in batch {
            anyhow::ensure!(!prompt.is_empty(), "empty prompt for {id}");
            anyhow::ensure!(!self.seqs.contains_key(id), "seq {id} already exists");
            anyhow::ensure!(
                prompt.len() < self.engine.manifest().target.kv_max,
                "prompt too long for KV capacity"
            );
            let m = self.engine.manifest();
            self.seqs.insert(
                *id,
                SeqState {
                    target: ModelSeqState::new(&m.target.clone()),
                    draft: ModelSeqState::new(&m.draft.clone()),
                },
            );
        }
        let mut cost = self.prefill_model("target", batch)?;
        cost += self.prefill_model("draft", batch)?;
        Ok(cost)
    }

    fn propose(
        &mut self,
        seqs: &[SeqId],
        pending: &[Vec<u32>],
        gammas: &[usize],
        temps: &[f64],
        seed: u64,
    ) -> anyhow::Result<ProposeOut> {
        anyhow::ensure!(seqs.len() == pending.len() && seqs.len() == temps.len());
        anyhow::ensure!(seqs.len() == gammas.len(), "gammas length mismatch");
        let n = seqs.len();
        let gamma_max = gammas.iter().copied().max().unwrap_or(0);
        let mut tokens: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut probs: Vec<Vec<LogitsView>> = vec![Vec::new(); n];
        let mut cost = 0.0;
        let mut rng = self.rng.fork(seed);
        // First forward consumes each sequence's pending backlog; the
        // backlog can be ragged (1 or 2 tokens) — pad to the max and step
        // the shorter sequences' lengths accordingly (their extra slot is
        // a pad the mask ignores; len advances only by real tokens).
        // Ragged γᵢ: draft step g only runs the sequences still drafting
        // (γᵢ > g), so late steps forward a shrinking sub-batch. The
        // sub-batch changes composition, which misses the whole-batch KV
        // cache — correctness is untouched (the cache flushes to the
        // per-sequence slabs), it just pays the per-seq gather on those
        // steps.
        let mut feeds: Vec<Vec<u32>> = pending.to_vec();
        // Backlog catch-up: a sequence that sat at γᵢ=0 for some rounds
        // (ragged assignments, or static overrides) accumulates more than
        // the usual ≤2 pending tokens, which the sampling loop's step
        // widths (clamped to the compiled 1–2-token draft executables)
        // cannot consume in one forward. Drain oversized backlogs two
        // tokens at a time first, without sampling — only KV/length
        // advance — so the loop below always starts within step width.
        loop {
            let lagging: Vec<usize> = (0..n)
                .filter(|&i| gammas[i] > 0 && feeds[i].len() > 2)
                .collect();
            if lagging.is_empty() {
                break;
            }
            let lag_seqs: Vec<SeqId> = lagging.iter().map(|&i| seqs[i]).collect();
            let chunks: Vec<Vec<u32>> = lagging.iter().map(|&i| feeds[i][..2].to_vec()).collect();
            let out = self.forward_model("draft", &lag_seqs, &chunks, 2)?;
            cost += out.seconds;
            for &i in &lagging {
                feeds[i].drain(..2);
            }
        }
        for g in 0..gamma_max {
            let active: Vec<usize> = (0..n).filter(|&i| gammas[i] > g).collect();
            if active.is_empty() {
                break;
            }
            let act_seqs: Vec<SeqId> = active.iter().map(|&i| seqs[i]).collect();
            let act_feeds: Vec<Vec<u32>> = active.iter().map(|&i| feeds[i].clone()).collect();
            let s = act_feeds.iter().map(Vec::len).max().unwrap_or(1).clamp(1, 2);
            let out = self.forward_model("draft", &act_seqs, &act_feeds, s)?;
            cost += out.seconds;
            for (j, &i) in active.iter().enumerate() {
                let last_real = act_feeds[j].len().saturating_sub(1);
                let row = &out.logits[j][last_real];
                let view = row_view(row, temps[i]);
                let tok = view.sample(&mut rng);
                tokens[i].push(tok);
                probs[i].push(view);
                if g + 1 < gammas[i] {
                    feeds[i] = vec![tok];
                }
            }
        }
        Ok(ProposeOut {
            tokens,
            probs,
            cost,
        })
    }

    fn verify(
        &mut self,
        seqs: &[SeqId],
        feed: &[u32],
        drafts: &[Vec<u32>],
        temps: &[f64],
    ) -> anyhow::Result<VerifyOut> {
        anyhow::ensure!(seqs.len() == feed.len() && seqs.len() == drafts.len());
        // Ragged drafts: pad the batch to the widest sequence's γᵢ+1 (the
        // executable's fixed step shape); pad slots sit *after* each
        // sequence's real tokens, so the causal mask keeps them out of the
        // real rows and `forward_model` advances lengths by real tokens
        // only. Surplus logit rows are dropped per sequence below.
        let s = drafts.iter().map(Vec::len).max().unwrap_or(0) + 1;
        let tokens: Vec<Vec<u32>> = (0..seqs.len())
            .map(|i| {
                let mut t = Vec::with_capacity(drafts[i].len() + 1);
                t.push(feed[i]);
                t.extend_from_slice(&drafts[i]);
                t
            })
            .collect();
        let out = self.forward_model("target", seqs, &tokens, s)?;
        let probs: Vec<Vec<LogitsView>> = out
            .logits
            .iter()
            .zip(temps)
            .zip(drafts)
            .map(|((rows, &temp), draft)| {
                rows.iter()
                    .take(draft.len() + 1)
                    .map(|r| row_view(r, temp))
                    .collect()
            })
            .collect();
        Ok(VerifyOut {
            probs,
            cost: out.seconds,
        })
    }

    fn rollback_target(&mut self, seq: SeqId, len: usize) {
        let st = self.seqs.get_mut(&seq).expect("unknown sequence");
        assert!(len <= st.target.len, "target rollback beyond context");
        st.target.len = len;
    }

    fn rollback_draft(&mut self, seq: SeqId, len: usize) {
        let st = self.seqs.get_mut(&seq).expect("unknown sequence");
        st.draft.len = st.draft.len.min(len);
    }

    fn target_len(&self, seq: SeqId) -> usize {
        self.seqs[&seq].target.len
    }

    fn draft_len(&self, seq: SeqId) -> usize {
        self.seqs[&seq].draft.len
    }

    fn release(&mut self, seq: SeqId) {
        self.seqs.remove(&seq);
        // Orphan the sequence's cached rows: with no `latest` pointer the
        // composition cache can neither exact-hit nor source them, so a
        // later sequence reusing this id starts from its fresh slabs.
        for caches in self.kv_cache.values_mut() {
            caches.latest.remove(&seq);
        }
    }

    fn reject_cost(&self, _gammas: &[usize]) -> f64 {
        // Rejection sampling happens inside the engine on the host; its
        // wall cost is captured by the engine's overhead timer.
        0.0
    }

    fn prefill_chunk_cost(&self, _tokens: usize, _ctx: usize) -> f64 {
        // Wall-clock backend: the real prefill is measured inside
        // `prefill` when the sequence registers, so chunk steps carry no
        // extra virtual price — the continuous engine's residual charge
        // then equals the full measured cost. (Made explicit rather than
        // relying on the trait default so the pricing contract is
        // documented next to the measurement it interacts with.)
        0.0
    }
}

#[cfg(test)]
mod tests {
    // Exercised by rust/tests/integration_runtime.rs (needs artifacts).
}
