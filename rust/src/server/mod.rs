//! TCP front-end: the private-serving deployment surface.
//!
//! Protocol: newline-delimited JSON. One request object per line:
//! `{"id": 7, "prompt": "text", "max_new_tokens": 32, "temperature": 0.0}`
//! answered by
//! `{"id": 7, "text": "...", "n_tokens": 32, "ttft": 0.01, "latency": 0.2,
//! "gamma": 3, ...}` (plus `ctl_*` fields when the adaptive controller is
//! active). A line `{"stats": true}` returns the aggregate serving stats
//! instead — throughput, acceptance, and the full controller state
//! (γ, α̂, σ̂, measured target efficiency per batch bucket, switch/probe
//! counters) as published by the engine thread after every step.
//!
//! Architecture (std-threads; tokio is unavailable offline):
//! - an **engine thread** owns the [`Engine`] and loops
//!   `drain submissions → step → dispatch completions`;
//! - the **accept loop** spawns one lightweight connection thread per
//!   client; connection threads submit into an mpsc channel and park on a
//!   per-request response channel.
//!
//! Tokens go over the wire as text through [`crate::tokenizer`] (byte
//! vocab), so the server is only meaningful for the tiny-real-model and
//! synthetic backends — which is exactly the repo's serving scope.

use crate::batching::{ClassId, Completion, Request, SamplingParams, DEFAULT_CLASS};
use crate::control::ControllerState;
use crate::engine::{Engine, EngineConfig};
use crate::spec::SdBackend;
use crate::tokenizer;
use crate::util::json::Json;
use crate::workload::{ArrivalTrace, TenantClass, TraceEvent};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// A submitted job: the request plus where to send the completion.
struct Job {
    request: Request,
    respond: Sender<Completion>,
}

/// One tenant class's published serving stats (p50/p99 latencies, SLO
/// attainment, and — with the adaptive controller — the priced per-class
/// regime estimate at the current batch).
#[derive(Debug, Clone, Default)]
pub struct ClassStats {
    pub name: String,
    pub requests_completed: u64,
    pub tokens_generated: u64,
    pub seq_rounds: u64,
    pub preemptions: u64,
    pub ttft_p50: f64,
    pub ttft_p99: f64,
    pub tpot_p50: f64,
    pub tpot_p99: f64,
    pub ttft_slo_attainment: Option<f64>,
    pub tpot_slo_attainment: Option<f64>,
    /// Controller-priced per-class estimate (γ, speedup vs AR) at the
    /// current batch regime, from the class's α hint.
    pub predicted_gamma: Option<usize>,
    pub predicted_speedup: Option<f64>,
}

impl ClassStats {
    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| match v {
            Some(x) => x.into(),
            None => Json::Null,
        };
        Json::from_pairs(vec![
            ("name", self.name.as_str().into()),
            ("requests_completed", self.requests_completed.into()),
            ("tokens_generated", self.tokens_generated.into()),
            ("seq_rounds", self.seq_rounds.into()),
            ("preemptions", self.preemptions.into()),
            ("ttft_p50", self.ttft_p50.into()),
            ("ttft_p99", self.ttft_p99.into()),
            ("tpot_p50", self.tpot_p50.into()),
            ("tpot_p99", self.tpot_p99.into()),
            ("ttft_slo_attainment", opt(self.ttft_slo_attainment)),
            ("tpot_slo_attainment", opt(self.tpot_slo_attainment)),
            (
                "predicted_gamma",
                match self.predicted_gamma {
                    Some(g) => g.into(),
                    None => Json::Null,
                },
            ),
            ("predicted_speedup", opt(self.predicted_speedup)),
        ])
    }
}

/// Aggregate serving stats, published by the engine thread after every
/// step and served to clients via `{"stats": true}`.
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub requests_completed: u64,
    pub tokens_generated: u64,
    pub rounds: u64,
    pub mean_batch: f64,
    pub tokens_per_second: f64,
    pub acceptance_rate: f64,
    /// γ currently in effect (controller-owned when one is configured).
    pub gamma: usize,
    /// Verify-expert budget in effect on the backend (`None` = unbudgeted).
    pub verify_budget: Option<usize>,
    /// Adaptive-controller snapshot, when the engine runs one.
    pub controller: Option<ControllerState>,
    /// Per-tenant-class stats (one entry per configured tenant; classless
    /// deployments publish a single "default" entry once traffic flows).
    pub classes: Vec<ClassStats>,
    /// Worker-fleet health when serving through the distributed
    /// coordinator (`--dist-workers`); `None` for single-process.
    pub dist: Option<crate::dist::DistStatus>,
}

impl ServerStats {
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("requests_completed", self.requests_completed.into()),
            ("tokens_generated", self.tokens_generated.into()),
            ("rounds", self.rounds.into()),
            ("mean_batch", self.mean_batch.into()),
            ("tokens_per_second", self.tokens_per_second.into()),
            ("acceptance_rate", self.acceptance_rate.into()),
            ("gamma", self.gamma.into()),
            (
                "verify_budget",
                self.verify_budget.map_or(Json::Null, Json::from),
            ),
        ];
        if let Some(ctl) = &self.controller {
            pairs.push(("controller", ctl.to_json()));
        }
        pairs.push((
            "classes",
            Json::Arr(self.classes.iter().map(ClassStats::to_json).collect()),
        ));
        if let Some(dist) = &self.dist {
            pairs.push(("dist", dist.to_json()));
        }
        Json::from_pairs(pairs)
    }
}

type SharedStats = Arc<Mutex<ServerStats>>;

/// Optional server behaviors beyond the engine config.
#[derive(Debug, Clone, Default)]
pub struct ServerOptions {
    /// Record every submitted request as a trace event (arrival stamped
    /// with the engine clock at submission, `output_len` = the request's
    /// token budget) and write the [`ArrivalTrace`] CSV here on shutdown
    /// — live traffic becomes a replayable `--trace` input for the
    /// benches (`--record-trace PATH`).
    pub record_trace: Option<std::path::PathBuf>,
}

/// Server handle: join/shutdown control.
pub struct Server {
    pub addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    engine_thread: Option<JoinHandle<()>>,
}

impl Server {
    /// Start serving on `bind_addr` (use port 0 for an ephemeral port)
    /// with a ready backend (must be `Send`; used by the synthetic mode).
    pub fn start<B: SdBackend + Send + 'static>(
        bind_addr: &str,
        config: EngineConfig,
        backend: B,
    ) -> anyhow::Result<Server> {
        Self::start_with(bind_addr, config, move || Ok(backend))
    }

    /// Start serving with a backend *factory* that runs on the engine
    /// thread. This is how non-`Send` backends (the PJRT-backed
    /// [`crate::runtime::hlo_model::HloBackend`] holds `Rc` XLA handles)
    /// are hosted: the backend never crosses a thread boundary.
    pub fn start_with<B, F>(
        bind_addr: &str,
        config: EngineConfig,
        make_backend: F,
    ) -> anyhow::Result<Server>
    where
        B: SdBackend + 'static,
        F: FnOnce() -> anyhow::Result<B> + Send + 'static,
    {
        Self::start_with_opts(bind_addr, config, make_backend, ServerOptions::default())
    }

    /// [`Server::start_with`] plus [`ServerOptions`] (trace recording).
    pub fn start_with_opts<B, F>(
        bind_addr: &str,
        config: EngineConfig,
        make_backend: F,
        opts: ServerOptions,
    ) -> anyhow::Result<Server>
    where
        B: SdBackend + 'static,
        F: FnOnce() -> anyhow::Result<B> + Send + 'static,
    {
        // Surface controller misconfiguration here, where the caller can
        // see it — not as a silent engine-thread death later.
        if let Some(ctl) = &config.control {
            ctl.validate()?;
        }
        let listener = TcpListener::bind(bind_addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let stats: SharedStats = Arc::new(Mutex::new(ServerStats::default()));
        // The connection side resolves `"tenant"` names to class ids
        // against the same table the engine accounts with.
        let tenants: Arc<Vec<TenantClass>> = Arc::new(config.tenants.clone());
        let (job_tx, job_rx) = channel::<Job>();

        let engine_thread = {
            let shutdown = shutdown.clone();
            let stats = stats.clone();
            std::thread::Builder::new()
                .name("moesd-engine".into())
                .spawn(move || {
                    let backend = match make_backend() {
                        Ok(b) => b,
                        Err(e) => {
                            crate::util::logging::log(
                                crate::util::logging::Level::Error,
                                "server",
                                &format!("backend construction failed: {e:#}"),
                            );
                            return;
                        }
                    };
                    engine_loop(config, backend, job_rx, shutdown, stats, opts)
                })?
        };

        let accept_thread = {
            let shutdown = shutdown.clone();
            std::thread::Builder::new()
                .name("moesd-accept".into())
                .spawn(move || accept_loop(listener, job_tx, shutdown, stats, tenants))?
        };

        Ok(Server {
            addr,
            shutdown,
            accept_thread: Some(accept_thread),
            engine_thread: Some(engine_thread),
        })
    }

    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.engine_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.engine_thread.take() {
            let _ = t.join();
        }
    }
}

fn publish_stats<B: SdBackend>(engine: &Engine<B>, stats: &SharedStats) {
    let m = &engine.metrics;
    // Per-class view: every configured tenant (even if idle so far) plus
    // any extra classes traffic has touched.
    let tenants = &engine.config.tenants;
    let n_classes = tenants.len().max(m.class.len());
    let estimates = engine.controller().map(|ctl| {
        ctl.class_estimates(tenants, (m.mean_batch().round() as usize).max(1))
    });
    let mut classes = Vec::with_capacity(n_classes);
    for i in 0..n_classes {
        let name = tenants
            .get(i)
            .map_or_else(|| format!("class{i}"), |t| t.name.clone());
        let mut cs = ClassStats {
            name,
            ..ClassStats::default()
        };
        if let Some(cm) = m.class.get(i) {
            cs.requests_completed = cm.requests_completed;
            cs.tokens_generated = cm.tokens_generated;
            cs.seq_rounds = cm.seq_rounds;
            cs.preemptions = cm.preemptions;
            cs.ttft_p50 = cm.ttft.0.quantile(0.5);
            cs.ttft_p99 = cm.ttft.0.quantile(0.99);
            cs.tpot_p50 = cm.tpot.0.quantile(0.5);
            cs.tpot_p99 = cm.tpot.0.quantile(0.99);
            cs.ttft_slo_attainment = cm.ttft_attainment();
            cs.tpot_slo_attainment = cm.tpot_attainment();
        }
        if let Some(ests) = &estimates {
            if let Some(e) = ests.get(i) {
                cs.predicted_gamma = Some(e.gamma);
                cs.predicted_speedup = Some(e.speedup);
            }
        }
        classes.push(cs);
    }
    let snapshot = ServerStats {
        requests_completed: m.requests_completed,
        tokens_generated: m.tokens_generated,
        rounds: m.rounds,
        mean_batch: m.mean_batch(),
        tokens_per_second: m.tokens_per_second(),
        acceptance_rate: m.acceptance_rate(),
        gamma: engine.current_gamma(),
        verify_budget: engine.verify_budget(),
        controller: engine.controller_state(),
        classes,
        dist: engine.backend().dist_status(),
    };
    *stats.lock().unwrap() = snapshot;
}

fn engine_loop<B: SdBackend>(
    config: EngineConfig,
    backend: B,
    jobs: Receiver<Job>,
    shutdown: Arc<AtomicBool>,
    stats: SharedStats,
    opts: ServerOptions,
) {
    let mut engine = Engine::new(config, backend);
    let mut pending: HashMap<u64, Sender<Completion>> = HashMap::new();
    let mut recorded: Vec<TraceEvent> = Vec::new();
    publish_stats(&engine, &stats);
    // Snapshotting clones the controller state (history + per-bucket
    // vectors), so don't pay it on every decode round of a busy engine:
    // publish when work completes (responses read the snapshot) and on a
    // step cadence so pure-decode stretches stay observable.
    const PUBLISH_EVERY_STEPS: usize = 16;
    let mut steps_since_publish = 0usize;
    while !shutdown.load(Ordering::SeqCst) {
        // Drain new submissions, stamping arrival with the engine clock
        // at receipt: TTFT / per-class SLO attainment, starvation aging,
        // and the mix hold-max all measure wait from this moment. (The
        // connection thread can't stamp it — the engine clock is virtual
        // in synthetic mode — and a 0.0 arrival would measure every wait
        // from server start.)
        let mut got_work = false;
        while let Ok(job) = jobs.try_recv() {
            pending.insert(job.request.id, job.respond);
            let mut request = job.request;
            request.arrival = engine.clock();
            if opts.record_trace.is_some() {
                recorded.push(TraceEvent {
                    t: request.arrival,
                    prompt_len: request.prompt.len().max(1),
                    output_len: request.params.max_new_tokens.max(1),
                });
            }
            engine.submit(request);
            got_work = true;
        }
        if engine.is_idle() {
            if !got_work {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            continue;
        }
        match engine.step() {
            Ok(completions) => {
                steps_since_publish += 1;
                if !completions.is_empty() || steps_since_publish >= PUBLISH_EVERY_STEPS {
                    publish_stats(&engine, &stats);
                    steps_since_publish = 0;
                }
                for c in completions {
                    if let Some(tx) = pending.remove(&c.id) {
                        let _ = tx.send(c);
                    }
                }
            }
            Err(e) => {
                crate::util::logging::log(
                    crate::util::logging::Level::Error,
                    "server",
                    &format!("engine step failed: {e}"),
                );
            }
        }
    }
    // Flush the recorded trace on shutdown: a replayable CSV of what the
    // deployment actually served (empty sessions write nothing).
    if let Some(path) = &opts.record_trace {
        if recorded.is_empty() {
            return;
        }
        let flushed = ArrivalTrace::new(recorded)
            .and_then(|t| std::fs::write(path, t.to_csv()).map_err(Into::into));
        match flushed {
            Ok(()) => crate::util::logging::log(
                crate::util::logging::Level::Info,
                "server",
                &format!("recorded arrival trace to {}", path.display()),
            ),
            Err(e) => crate::util::logging::log(
                crate::util::logging::Level::Error,
                "server",
                &format!("failed to record arrival trace: {e:#}"),
            ),
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    jobs: Sender<Job>,
    shutdown: Arc<AtomicBool>,
    stats: SharedStats,
    tenants: Arc<Vec<TenantClass>>,
) {
    let next_id = Arc::new(AtomicU64::new(1));
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let jobs = jobs.clone();
                let next_id = next_id.clone();
                let stats = stats.clone();
                let tenants = tenants.clone();
                let _ = std::thread::Builder::new()
                    .name("moesd-conn".into())
                    .spawn(move || {
                        let _ = handle_connection(stream, jobs, next_id, stats, tenants);
                    });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            Err(_) => return,
        }
    }
}

fn handle_connection(
    stream: TcpStream,
    jobs: Sender<Job>,
    next_id: Arc<AtomicU64>,
    stats: SharedStats,
    tenants: Arc<Vec<TenantClass>>,
) -> anyhow::Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let response = match serve_one(&line, &jobs, &next_id, &stats, &tenants) {
            Ok(resp) => resp,
            Err(e) => Json::from_pairs(vec![("error", format!("{e}").into())]),
        };
        writer.write_all(response.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    Ok(())
}

fn serve_one(
    line: &str,
    jobs: &Sender<Job>,
    next_id: &AtomicU64,
    stats: &SharedStats,
    tenants: &[TenantClass],
) -> anyhow::Result<Json> {
    let j = Json::parse(line)?;
    if j.get("stats").and_then(Json::as_bool) == Some(true) {
        return Ok(stats.lock().unwrap().to_json());
    }
    let prompt_text = j.req_str("prompt")?;
    anyhow::ensure!(!prompt_text.is_empty(), "empty prompt");
    let client_id = j.get("id").and_then(Json::as_i64).unwrap_or(-1);
    // Optional tenant tag: resolved by name against the configured table
    // (unknown names are a client error, not silently class 0). Untagged
    // requests on a multi-tenant server go to the tenant named "default"
    // if one exists, else the *lowest-priority* class — anonymous traffic
    // must never inherit the premium tier just because it was listed
    // first, nor corrupt its SLO-attainment stats.
    let class: ClassId = match j.get("tenant").and_then(Json::as_str) {
        None => tenants
            .iter()
            .position(|t| t.name == "default")
            .or_else(|| {
                tenants
                    .iter()
                    .enumerate()
                    .min_by_key(|(i, t)| (t.priority, *i))
                    .map(|(i, _)| i)
            })
            .unwrap_or(DEFAULT_CLASS),
        Some(name) => tenants
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| anyhow::anyhow!("unknown tenant `{name}`"))?,
    };
    let id = next_id.fetch_add(1, Ordering::SeqCst);
    let request = Request {
        id,
        prompt: tokenizer::encode(prompt_text, true),
        params: SamplingParams {
            temperature: j.get("temperature").and_then(Json::as_f64).unwrap_or(0.0),
            max_new_tokens: j
                .get("max_new_tokens")
                .and_then(Json::as_usize)
                .unwrap_or(32),
            eos_token: Some(tokenizer::EOS),
        },
        arrival: 0.0,
        class,
    };
    let (tx, rx) = channel();
    jobs.send(Job {
        request,
        respond: tx,
    })
    .map_err(|_| anyhow::anyhow!("engine stopped"))?;
    let completion = rx
        .recv_timeout(std::time::Duration::from_secs(120))
        .map_err(|_| anyhow::anyhow!("request timed out"))?;
    // Controller state at completion time (per-request observability).
    let snap = stats.lock().unwrap().clone();
    let mut pairs: Vec<(&str, Json)> = vec![
        (
            "id",
            if client_id >= 0 {
                client_id.into()
            } else {
                (id as i64).into()
            },
        ),
        ("text", tokenizer::decode(&completion.tokens).into()),
        ("n_tokens", completion.tokens.len().into()),
        ("ttft", completion.ttft().into()),
        (
            "latency",
            (completion.finished_at - completion.arrival).into(),
        ),
        ("rounds", (completion.rounds as usize).into()),
        ("gamma", snap.gamma.into()),
    ];
    if let Some(t) = tenants.get(class) {
        pairs.push(("tenant", t.name.as_str().into()));
    }
    if let Some(ctl) = &snap.controller {
        pairs.push(("ctl_policy", ctl.policy.as_str().into()));
        pairs.push((
            "ctl_alpha_hat",
            match ctl.alpha_hat {
                Some(a) => a.into(),
                None => Json::Null,
            },
        ));
        pairs.push(("ctl_switches", ctl.switches.into()));
    }
    Ok(Json::from_pairs(pairs))
}

/// Blocking client for tests/examples.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    /// Send one request line and block for its response.
    pub fn generate(
        &mut self,
        prompt: &str,
        max_new_tokens: usize,
        temperature: f64,
    ) -> anyhow::Result<Json> {
        self.request(prompt, max_new_tokens, temperature, None)
    }

    /// [`Client::generate`] tagged with a tenant class name (must be one
    /// of the server's configured `--tenants` classes).
    pub fn generate_as(
        &mut self,
        tenant: &str,
        prompt: &str,
        max_new_tokens: usize,
        temperature: f64,
    ) -> anyhow::Result<Json> {
        self.request(prompt, max_new_tokens, temperature, Some(tenant))
    }

    fn request(
        &mut self,
        prompt: &str,
        max_new_tokens: usize,
        temperature: f64,
        tenant: Option<&str>,
    ) -> anyhow::Result<Json> {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("prompt", prompt.into()),
            ("max_new_tokens", max_new_tokens.into()),
            ("temperature", temperature.into()),
        ];
        if let Some(t) = tenant {
            pairs.push(("tenant", t.into()));
        }
        let req = Json::from_pairs(pairs);
        self.stream.write_all(req.to_string().as_bytes())?;
        self.stream.write_all(b"\n")?;
        self.stream.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let resp = Json::parse(&line)?;
        if let Some(err) = resp.get("error") {
            anyhow::bail!("server error: {err}");
        }
        Ok(resp)
    }

    /// Query the aggregate serving stats (throughput, acceptance, γ, and
    /// the adaptive-controller state when one is running).
    pub fn stats(&mut self) -> anyhow::Result<Json> {
        let req = Json::from_pairs(vec![("stats", true.into())]);
        self.stream.write_all(req.to_string().as_bytes())?;
        self.stream.write_all(b"\n")?;
        self.stream.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let resp = Json::parse(&line)?;
        if let Some(err) = resp.get("error") {
            anyhow::bail!("server error: {err}");
        }
        Ok(resp)
    }
}

#[cfg(test)]
mod tests {
    // End-to-end server tests live in rust/tests/integration_server.rs
    // (they spin up real TCP listeners).
}
