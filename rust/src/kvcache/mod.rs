//! Paged KV-cache management (vLLM-style PagedAttention bookkeeping).
//!
//! The coordinator tracks cache capacity in fixed-size token blocks; each
//! sequence owns a block table. Speculative decoding adds one wrinkle over
//! plain paged serving: a verify step appends up to γ+1 tokens and then
//! *rolls back* the rejected suffix, so the manager supports `truncate`.
//! Allocation failures surface as `None` so the scheduler can pause
//! admission (capacity backpressure) instead of crashing.

use std::collections::HashMap;

/// Opaque sequence handle.
pub type SeqId = u64;

/// Block index into the (conceptual) physical KV pool.
pub type BlockId = u32;

/// Static cache geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvConfig {
    /// Total physical blocks available.
    pub num_blocks: usize,
    /// Tokens per block.
    pub block_size: usize,
}

impl KvConfig {
    pub fn total_tokens(&self) -> usize {
        self.num_blocks * self.block_size
    }
}

/// Per-sequence cache state.
#[derive(Debug, Clone, Default)]
struct SeqState {
    block_table: Vec<BlockId>,
    len_tokens: usize,
}

/// The paged allocator + per-sequence block tables.
#[derive(Debug)]
pub struct KvManager {
    config: KvConfig,
    free: Vec<BlockId>,
    seqs: HashMap<SeqId, SeqState>,
    /// High-water mark of simultaneously allocated blocks (capacity
    /// planning metric).
    peak_used: usize,
}

impl KvManager {
    pub fn new(config: KvConfig) -> KvManager {
        assert!(config.num_blocks > 0 && config.block_size > 0);
        KvManager {
            config,
            free: (0..config.num_blocks as BlockId).rev().collect(),
            seqs: HashMap::new(),
            peak_used: 0,
        }
    }

    pub fn config(&self) -> KvConfig {
        self.config
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.config.num_blocks - self.free.len()
    }

    pub fn peak_used_blocks(&self) -> usize {
        self.peak_used
    }

    pub fn num_seqs(&self) -> usize {
        self.seqs.len()
    }

    pub fn seq_len(&self, seq: SeqId) -> Option<usize> {
        self.seqs.get(&seq).map(|s| s.len_tokens)
    }

    /// Blocks needed to extend a sequence of `cur` tokens by `extra`.
    fn blocks_needed(&self, cur: usize, extra: usize) -> usize {
        let bs = self.config.block_size;
        let have = cur.div_ceil(bs);
        let want = (cur + extra).div_ceil(bs);
        want - have
    }

    /// Can `extra` tokens be appended to `seq` (or a new seq) right now?
    pub fn can_append(&self, seq: SeqId, extra: usize) -> bool {
        let cur = self.seqs.get(&seq).map_or(0, |s| s.len_tokens);
        self.blocks_needed(cur, extra) <= self.free.len()
    }

    /// Register a new sequence and reserve capacity for its prompt.
    /// Returns `None` (no state change) if capacity is insufficient.
    pub fn allocate(&mut self, seq: SeqId, prompt_tokens: usize) -> Option<()> {
        assert!(
            !self.seqs.contains_key(&seq),
            "sequence {seq} already allocated"
        );
        let needed = self.blocks_needed(0, prompt_tokens);
        if needed > self.free.len() {
            return None;
        }
        let mut state = SeqState::default();
        for _ in 0..needed {
            state.block_table.push(self.free.pop().unwrap());
        }
        state.len_tokens = prompt_tokens;
        self.seqs.insert(seq, state);
        self.peak_used = self.peak_used.max(self.used_blocks());
        Some(())
    }

    /// Append `extra` tokens to an existing sequence, growing its block
    /// table. Returns `None` (no state change) on capacity exhaustion.
    pub fn append(&mut self, seq: SeqId, extra: usize) -> Option<()> {
        let cur = self.seqs.get(&seq).expect("unknown sequence").len_tokens;
        let needed = self.blocks_needed(cur, extra);
        if needed > self.free.len() {
            return None;
        }
        let state = self.seqs.get_mut(&seq).unwrap();
        for _ in 0..needed {
            state.block_table.push(self.free.pop().unwrap());
        }
        state.len_tokens += extra;
        self.peak_used = self.peak_used.max(self.used_blocks());
        Some(())
    }

    /// Shrink a sequence to `new_len` tokens (SD rollback of rejected
    /// draft tokens), returning now-unused blocks to the pool.
    pub fn truncate(&mut self, seq: SeqId, new_len: usize) {
        let bs = self.config.block_size;
        let state = self.seqs.get_mut(&seq).expect("unknown sequence");
        assert!(
            new_len <= state.len_tokens,
            "truncate {new_len} > current {}",
            state.len_tokens
        );
        let keep_blocks = new_len.div_ceil(bs);
        while state.block_table.len() > keep_blocks {
            self.free.push(state.block_table.pop().unwrap());
        }
        state.len_tokens = new_len;
    }

    /// Release a sequence entirely.
    pub fn release(&mut self, seq: SeqId) {
        if let Some(state) = self.seqs.remove(&seq) {
            self.free.extend(state.block_table);
        }
    }

    /// The sequence's block table (for handing to an attention kernel).
    pub fn block_table(&self, seq: SeqId) -> Option<&[BlockId]> {
        self.seqs.get(&seq).map(|s| s.block_table.as_slice())
    }

    /// Internal invariant checker used by property tests: every block is
    /// either free or owned by exactly one sequence.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = vec![false; self.config.num_blocks];
        for &b in &self.free {
            let i = b as usize;
            if seen[i] {
                return Err(format!("block {b} duplicated in free list"));
            }
            seen[i] = true;
        }
        for (seq, state) in &self.seqs {
            let max_tokens = state.block_table.len() * self.config.block_size;
            if state.len_tokens > max_tokens {
                return Err(format!(
                    "seq {seq}: {} tokens in {} blocks",
                    state.len_tokens,
                    state.block_table.len()
                ));
            }
            // No over-allocation beyond one block of slack.
            if state.len_tokens + self.config.block_size <= max_tokens
                && !state.block_table.is_empty()
            {
                return Err(format!("seq {seq}: over-allocated blocks"));
            }
            for &b in &state.block_table {
                let i = b as usize;
                if seen[i] {
                    return Err(format!("block {b} double-owned"));
                }
                seen[i] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("leaked blocks (neither free nor owned)".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{ensure, Runner};
    use crate::util::rng::Rng;

    fn mgr(blocks: usize, bs: usize) -> KvManager {
        KvManager::new(KvConfig {
            num_blocks: blocks,
            block_size: bs,
        })
    }

    #[test]
    fn allocate_append_release_cycle() {
        let mut kv = mgr(10, 16);
        kv.allocate(1, 20).unwrap(); // 2 blocks
        assert_eq!(kv.used_blocks(), 2);
        assert_eq!(kv.seq_len(1), Some(20));
        kv.append(1, 12).unwrap(); // 32 tokens → still 2 blocks
        assert_eq!(kv.used_blocks(), 2);
        kv.append(1, 1).unwrap(); // 33 tokens → 3 blocks
        assert_eq!(kv.used_blocks(), 3);
        kv.release(1);
        assert_eq!(kv.used_blocks(), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn capacity_exhaustion_returns_none_without_state_change() {
        let mut kv = mgr(2, 16);
        kv.allocate(1, 30).unwrap(); // uses both blocks
        assert!(kv.allocate(2, 1).is_none());
        assert_eq!(kv.num_seqs(), 1);
        assert!(kv.append(1, 10).is_none()); // would need a third block
        assert_eq!(kv.seq_len(1), Some(30));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn truncate_rolls_back_blocks() {
        let mut kv = mgr(8, 4);
        kv.allocate(7, 4).unwrap(); // 1 block
        kv.append(7, 5).unwrap(); // 9 tokens → 3 blocks
        assert_eq!(kv.used_blocks(), 3);
        // SD rollback: verify appended γ+1=5, only 1 accepted → back to 5.
        kv.truncate(7, 5);
        assert_eq!(kv.seq_len(7), Some(5));
        assert_eq!(kv.used_blocks(), 2);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn can_append_is_accurate() {
        let mut kv = mgr(3, 4);
        kv.allocate(1, 4).unwrap();
        assert!(kv.can_append(1, 8)); // two more blocks available
        assert!(!kv.can_append(1, 9)); // would need three
    }

    #[test]
    #[should_panic(expected = "already allocated")]
    fn double_allocate_panics() {
        let mut kv = mgr(4, 4);
        kv.allocate(1, 1).unwrap();
        kv.allocate(1, 1).unwrap();
    }

    #[test]
    fn peak_usage_tracked() {
        let mut kv = mgr(4, 4);
        kv.allocate(1, 16).unwrap();
        kv.release(1);
        assert_eq!(kv.used_blocks(), 0);
        assert_eq!(kv.peak_used_blocks(), 4);
    }

    /// Property: a random sequence of operations never violates block
    /// conservation, regardless of interleaving or capacity pressure.
    #[test]
    fn prop_random_ops_preserve_invariants() {
        let mut runner = Runner::new("kv_invariants");
        runner.run(60, |g| {
            let blocks = g.usize_in(1, 24);
            let bs = g.usize_in(1, 8);
            let mut kv = mgr(blocks, bs);
            let mut rng = Rng::seeded(g.u64_in(0, 1 << 30));
            let mut live: Vec<SeqId> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..120 {
                match rng.below(4) {
                    0 => {
                        let len = rng.range_inclusive(1, 20) as usize;
                        if kv.allocate(next_id, len).is_some() {
                            live.push(next_id);
                        }
                        next_id += 1;
                    }
                    1 if !live.is_empty() => {
                        let seq = live[rng.below(live.len() as u64) as usize];
                        let _ = kv.append(seq, rng.range_inclusive(1, 6) as usize);
                    }
                    2 if !live.is_empty() => {
                        let idx = rng.below(live.len() as u64) as usize;
                        let seq = live.swap_remove(idx);
                        kv.release(seq);
                    }
                    3 if !live.is_empty() => {
                        let seq = live[rng.below(live.len() as u64) as usize];
                        let len = kv.seq_len(seq).unwrap();
                        if len > 0 {
                            kv.truncate(seq, rng.below(len as u64 + 1) as usize);
                        }
                    }
                    _ => {}
                }
                if let Err(e) = kv.check_invariants() {
                    return Err(format!("invariant violated: {e}"));
                }
            }
            ensure(true, "")
        });
    }
}
