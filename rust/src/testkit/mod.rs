//! Mini property-based testing framework (proptest is not available in this
//! offline build environment).
//!
//! Usage (runs as a doctest — the vendored `xla` stub is pure Rust, so
//! doctest binaries link without any native library):
//! ```
//! use moesd::testkit::Runner;
//! let mut runner = Runner::new("my_property");
//! runner.run(200, |g| {
//!     let x = g.usize_in(1, 100);
//!     let y = g.f64_in(0.0, 1.0);
//!     moesd::testkit::ensure(x as f64 * y <= 100.0, format!("x={x} y={y}"))
//! });
//! ```
//!
//! On failure the runner re-runs the failing case with progressively
//! "smaller" draws (values biased toward the low end of each requested
//! range) to report a near-minimal counterexample, then panics with the
//! seed so the case can be replayed exactly.

use crate::util::rng::Rng;

/// Result of a single property check.
pub type PropResult = Result<(), String>;

/// Convenience constructor for property failures.
pub fn ensure(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Approximate-equality property helper.
pub fn ensure_close(a: f64, b: f64, tol: f64, label: &str) -> PropResult {
    if (a - b).abs() <= tol * (1.0 + b.abs()) {
        Ok(())
    } else {
        Err(format!("{label}: {a} !~ {b} (tol {tol})"))
    }
}

/// Value generator handed to each property case. `shrink` in [0,1] biases
/// draws toward minimal values as the runner attempts shrinking.
pub struct Gen {
    rng: Rng,
    shrink: f64,
}

impl Gen {
    fn new(seed: u64, case: u64, shrink: f64) -> Self {
        Gen {
            rng: Rng::new(seed ^ case.wrapping_mul(0x9e3779b97f4a7c15), case | 1),
            shrink,
        }
    }

    /// Raw RNG access for custom generators.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = (hi - lo) as f64;
        let scaled = span * (1.0 - self.shrink);
        let v = self.rng.f64() * (scaled + 1.0);
        lo + (v as usize).min(hi - lo)
    }

    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        self.usize_in(lo as usize, hi as usize) as u64
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo <= hi);
        let hi_eff = lo + (hi - lo) * (1.0 - self.shrink * 0.9);
        self.rng.uniform(lo, hi_eff.max(lo + f64::EPSILON))
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty());
        &items[self.rng.below(items.len() as u64) as usize]
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, len: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..len).map(|_| self.usize_in(lo, hi)).collect()
    }

    /// A probability strictly inside (0, 1) — common in SD properties.
    pub fn prob(&mut self) -> f64 {
        self.f64_in(1e-6, 1.0 - 1e-6)
    }
}

/// Property runner. Seed comes from `MOESD_PROP_SEED` if set (replay),
/// otherwise a fixed default keeps CI deterministic.
pub struct Runner {
    name: String,
    seed: u64,
}

impl Runner {
    pub fn new(name: &str) -> Self {
        let seed = std::env::var("MOESD_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0x4d6f45_53445f5052); // "MoE SD_PR"
        Runner {
            name: name.to_string(),
            seed,
        }
    }

    pub fn with_seed(name: &str, seed: u64) -> Self {
        Runner {
            name: name.to_string(),
            seed,
        }
    }

    /// Run `cases` random cases of the property; on failure, attempt biased
    /// shrinking and panic with a replayable report.
    pub fn run<F: Fn(&mut Gen) -> PropResult>(&mut self, cases: u64, prop: F) {
        for case in 0..cases {
            let mut g = Gen::new(self.seed, case, 0.0);
            if let Err(msg) = prop(&mut g) {
                // Shrinking: retry the same case seed with increasing bias
                // toward minimal values; keep the last failure as the report.
                let mut best = msg;
                for step in 1..=8 {
                    let shrink = step as f64 / 8.0;
                    let mut g = Gen::new(self.seed, case, shrink);
                    if let Err(msg) = prop(&mut g) {
                        best = msg;
                    }
                }
                panic!(
                    "property `{}` failed (seed={}, case={case}): {best}\n\
                     replay with MOESD_PROP_SEED={}",
                    self.name, self.seed, self.seed
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut r = Runner::new("tautology");
        r.run(50, |g| {
            let x = g.usize_in(0, 10);
            ensure(x <= 10, "bound")
        });
    }

    #[test]
    #[should_panic(expected = "property `falsifiable` failed")]
    fn failing_property_panics_with_seed() {
        let mut r = Runner::new("falsifiable");
        r.run(100, |g| {
            let x = g.usize_in(0, 100);
            ensure(x < 95, format!("x={x}"))
        });
    }

    #[test]
    fn generators_respect_ranges() {
        let mut g = Gen::new(1, 2, 0.0);
        for _ in 0..1000 {
            let u = g.usize_in(3, 9);
            assert!((3..=9).contains(&u));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..=1.0).contains(&f));
            let p = g.prob();
            assert!(p > 0.0 && p < 1.0);
        }
    }

    #[test]
    fn shrink_biases_low() {
        let mut lo = Gen::new(1, 2, 1.0);
        let mut any_large = false;
        for _ in 0..200 {
            if lo.usize_in(0, 1000) > 100 {
                any_large = true;
            }
        }
        assert!(!any_large, "shrink=1.0 should bias to minimal values");
    }

    #[test]
    fn ensure_close_tolerance() {
        assert!(ensure_close(1.0, 1.0000001, 1e-5, "x").is_ok());
        assert!(ensure_close(1.0, 2.0, 1e-5, "x").is_err());
    }
}
