//! The serving engine — the L3 coordinator's core loop.
//!
//! One `Engine` owns a model-pair backend ([`crate::spec::SdBackend`]), the
//! paged KV accounting, the admission scheduler and the metrics registry,
//! and drives batched speculative decoding:
//!
//! ```text
//! step(): admit → (propose γᵢ) → verify → rejection-sample → commit/rollback
//! ```
//!
//! Speculation depth is **per sequence** (ragged rounds): every round each
//! running sequence gets its own draft length γᵢ — from the control
//! plane's vectorized policy ([`crate::control::SpecController::gammas_for_round`]),
//! from static [`EngineConfig::gamma_overrides`], or the uniform
//! `config.gamma` when neither applies. KV reservation, draft backlogs,
//! verify rows and accept accounting all follow the per-sequence depth;
//! a uniform assignment reproduces the scalar-γ engine bit-for-bit
//! (property-tested in `rust/tests/prop_invariants.rs`).
//!
//! The engine clock is *whatever the backend's costs are denominated in*:
//! the synthetic backend returns roofline-simulated seconds (virtual
//! clock, used for all paper-scale experiments), the HLO backend returns
//! measured wall seconds. Coordinator-side overhead is measured with a
//! monotonic timer separately (`metrics.time_overhead`) so the §Perf pass
//! can verify L3 is not the bottleneck.
//!
//! γ = 0 turns the same loop into plain autoregressive decoding — that's
//! how every T_AR baseline in the experiments is measured, guaranteeing
//! AR and SD share scheduler/batcher/sampler code paths.

mod continuous;

pub use continuous::PipelineConfig;

use crate::batching::{Buckets, ClassId, Completion, Request, RequestQueue, SamplingParams};
use crate::control::{
    ControlConfig, ControllerState, RoundObservation, SeqRoundSample, SpecController,
};
use crate::kvcache::{KvConfig, KvManager, SeqId};
use crate::metrics::{Counters, EngineMetrics};
use crate::sampling::verify_chain_views;
use crate::scheduler::{
    AdmissionContext, AdmissionPolicyConfig, RegimeOracle, RunningInfo, Scheduler, SchedulerConfig,
};
use crate::spec::{LogitsView, ProposeOut, SdBackend};
use crate::util::rng::Rng;
use crate::workload::TenantClass;

/// Engine configuration (the "launcher config" surface).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Draft length γ; 0 = autoregressive baseline. When a controller is
    /// configured this is only the pre-bootstrap value — the control plane
    /// owns γ from the first round on.
    pub gamma: usize,
    pub kv: KvConfig,
    pub scheduler: SchedulerConfig,
    /// Compiled batch-shape buckets (informational for the synthetic
    /// backend; binding for the HLO backend, which pads to these).
    pub buckets: Buckets,
    pub seed: u64,
    /// Optional adaptive speculation controller (γ / batch-ceiling
    /// co-tuning from measured target efficiency; see [`crate::control`]).
    pub control: Option<ControlConfig>,
    /// Static per-sequence draft-length overrides (ragged rounds without a
    /// controller): sequence `id` speculates `gamma_overrides[id]` tokens
    /// per round instead of `gamma`. Used by the ragged experiments' oracle
    /// arm and tests; online per-sequence γ comes from the control plane
    /// ([`ControlConfig::ragged`]). Ignored when a controller is set.
    pub gamma_overrides: std::collections::HashMap<SeqId, usize>,
    /// Tenant/SLO class table, indexed by [`ClassId`]. Empty = classless
    /// deployment (every request is the implicit default class); entries
    /// drive per-class SLO attainment accounting, class-aware preemption
    /// order, and the class-aware admission policy.
    pub tenants: Vec<TenantClass>,
    /// Admission policy. The default [`AdmissionPolicyConfig::Fifo`]
    /// reproduces the pre-multi-tenant scheduler bit-for-bit.
    pub admission: AdmissionPolicyConfig,
    /// Continuous-batching pipeline knobs (chunked prefill, draft-ahead
    /// overlap, per-sequence round boundaries). The default is the
    /// lock-step round loop; with `continuous: true` but every feature
    /// disabled, the event-driven path reproduces lock-step bit-for-bit
    /// (property-tested in `rust/tests/prop_continuous.rs`).
    pub pipeline: PipelineConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            gamma: 3,
            kv: KvConfig {
                num_blocks: 4096,
                block_size: 16,
            },
            scheduler: SchedulerConfig::default(),
            buckets: Buckets::pow2_up_to(64),
            seed: 0,
            control: None,
            gamma_overrides: std::collections::HashMap::new(),
            tenants: Vec::new(),
            admission: AdmissionPolicyConfig::Fifo,
            pipeline: PipelineConfig::default(),
        }
    }
}

/// A sequence currently in the running batch.
#[derive(Debug, Clone)]
struct RunningSeq {
    id: SeqId,
    /// prompt ++ emitted tokens.
    stream: Vec<u32>,
    prompt_len: usize,
    /// Committed target-KV length; `stream[base]` is the next feed token.
    base: usize,
    params: SamplingParams,
    arrival: f64,
    first_token_at: Option<f64>,
    rounds: u64,
    class: ClassId,
}

impl RunningSeq {
    fn generated(&self) -> usize {
        self.stream.len() - self.prompt_len
    }
}

/// Reusable per-round buffers. In steady state (stable batch composition)
/// `step()` performs no coordinator-side heap allocation of its own: the
/// per-round `seq_ids`/`temps`/`feeds` vectors and the per-sequence
/// `pending` backlog buffers are cleared and refilled in place (§Perf L3;
/// `micro_hotpath` tracks the step wall time this buys).
#[derive(Debug, Default)]
struct RoundScratch {
    seq_ids: Vec<SeqId>,
    /// Per-sequence draft length γᵢ for the round (ragged; a uniform
    /// round fills equal entries), aligned with `seq_ids`/`running`.
    gammas: Vec<usize>,
    temps: Vec<f64>,
    feeds: Vec<u32>,
    /// Draft token backlogs, one reused buffer per running slot.
    pending: Vec<Vec<u32>>,
    /// Permanently-empty per-sequence draft lists for γ = 0 (AR) verify
    /// calls, so the AR path allocates nothing per round either.
    empty_drafts: Vec<Vec<u32>>,
    /// Per-sequence acceptance samples reported to the controller.
    seq_samples: Vec<SeqRoundSample>,
    /// Indices of sequences that finished this round (ascending).
    finished: Vec<usize>,
    /// Per-running-sequence admission view (class + α̂ᵢ), rebuilt each
    /// admit call in place.
    run_infos: Vec<RunningInfo>,
}

/// The coordinator.
pub struct Engine<B: SdBackend> {
    pub config: EngineConfig,
    backend: B,
    kv: KvManager,
    queue: RequestQueue,
    scheduler: Scheduler,
    running: Vec<RunningSeq>,
    controller: Option<SpecController>,
    scratch: RoundScratch,
    /// Continuous-pipeline state (resource timelines, chunked-prefill
    /// queue, per-sequence phases). Inert on the lock-step path.
    pipeline: continuous::PipelineState,
    pub metrics: EngineMetrics,
    pub counters: Counters,
    clock: f64,
    rng: Rng,
    round_counter: u64,
}

impl<B: SdBackend> Engine<B> {
    pub fn new(config: EngineConfig, backend: B) -> Engine<B> {
        let kv = KvManager::new(config.kv);
        let scheduler = Scheduler::with_policy(config.scheduler.clone(), &config.admission);
        let rng = Rng::new(config.seed, 0x5d);
        let queue = RequestQueue::new();
        let controller = config.control.clone().map(SpecController::new);
        Engine {
            config,
            backend,
            kv,
            queue,
            scheduler,
            running: Vec::new(),
            controller,
            scratch: RoundScratch::default(),
            pipeline: continuous::PipelineState::default(),
            metrics: EngineMetrics::default(),
            counters: Counters::default(),
            clock: 0.0,
            rng,
            round_counter: 0,
        }
    }

    /// Submit a request (requests must be pushed in arrival order).
    pub fn submit(&mut self, req: Request) {
        self.metrics.requests_submitted += 1;
        self.queue.push(req);
    }

    pub fn clock(&self) -> f64 {
        self.clock
    }

    pub fn num_running(&self) -> usize {
        self.running.len()
    }

    pub fn num_waiting(&self) -> usize {
        self.queue.len()
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    pub fn kv(&self) -> &KvManager {
        &self.kv
    }

    pub fn scheduler(&self) -> &Scheduler {
        &self.scheduler
    }

    /// Admission priority of a tenant class (classes beyond the table are
    /// neutral tier 1).
    fn class_priority(&self, class: ClassId) -> u32 {
        self.config.tenants.get(class).map_or(1, |t| t.priority)
    }

    /// γ that would apply to the next round (controller-owned if present).
    pub fn current_gamma(&self) -> usize {
        self.controller
            .as_ref()
            .map_or(self.config.gamma, |c| c.gamma())
    }

    pub fn controller(&self) -> Option<&SpecController> {
        self.controller.as_ref()
    }

    /// Snapshot of the adaptive controller (None without one).
    pub fn controller_state(&self) -> Option<ControllerState> {
        self.controller.as_ref().map(|c| c.state())
    }

    /// The verify-expert budget currently in effect on the backend
    /// (`None` when unbudgeted — the paper's full-gate path).
    pub fn verify_budget(&self) -> Option<usize> {
        self.backend.verify_budget()
    }

    /// Whether any work remains.
    pub fn is_idle(&self) -> bool {
        self.running.is_empty() && self.queue.is_empty() && self.pipeline.prefilling.is_empty()
    }

    /// One scheduling step. On the default (lock-step) path this is one
    /// full decode round; with [`PipelineConfig::continuous`] it is one
    /// event of the pipelined loop (a prefill chunk, a propose op, a
    /// verify+commit op, or some combination). Returns completions
    /// finished in it.
    pub fn step(&mut self) -> anyhow::Result<Vec<Completion>> {
        if self.config.pipeline.continuous {
            return self.step_continuous();
        }
        self.step_lockstep()
    }

    /// One synchronous scheduling + decode round (the lock-step path).
    fn step_lockstep(&mut self) -> anyhow::Result<Vec<Completion>> {
        let t0 = std::time::Instant::now();
        let mut completions = Vec::new();

        // Fast-forward the clock to the next arrival if the engine is idle
        // but requests exist in the future.
        if self.running.is_empty() {
            if let Some(head) = self.queue.peek() {
                if head.arrival > self.clock {
                    self.clock = head.arrival;
                }
            }
        }

        self.admit()?;

        if self.running.is_empty() {
            self.metrics.time_overhead += t0.elapsed().as_secs_f64();
            return Ok(completions);
        }

        // The control plane owns γ when configured: it re-decides on batch
        // regime shifts and control-interval boundaries, so this is a
        // cheap lookup on the hot path. Depths are per sequence (ragged):
        // the controller's vectorized path refines its scalar decision
        // with windowed per-sequence α̂ᵢ; without a controller, static
        // `gamma_overrides` apply on top of the uniform `config.gamma`.
        self.scratch.seq_ids.clear();
        for s in &self.running {
            self.scratch.seq_ids.push(s.id);
        }
        self.scratch.gammas.clear();
        match self.controller.as_mut() {
            Some(ctl) => ctl.gammas_for_round(&self.scratch.seq_ids, &mut self.scratch.gammas),
            None if self.config.gamma_overrides.is_empty() => self
                .scratch
                .gammas
                .extend(std::iter::repeat(self.config.gamma).take(self.running.len())),
            None => {
                for s in &self.running {
                    self.scratch.gammas.push(
                        self.config
                            .gamma_overrides
                            .get(&s.id)
                            .copied()
                            .unwrap_or(self.config.gamma),
                    );
                }
            }
        }

        // The controller owns the verify-expert budget when its grid is
        // configured: push the joint (γ⃗, budget) decision into the
        // backend before this round's forwards. Without a grid the
        // backend's statically-configured budget (`--verify-budget`) is
        // left untouched.
        if let Some(ctl) = self.controller.as_ref() {
            if ctl.owns_budget() {
                self.backend.set_verify_budget(ctl.verify_budget());
            }
        }

        // --- capacity reservation: γᵢ+1 tokens per sequence ----------------
        // Sequences that don't fit trigger a preemption (release + requeue)
        // so the batch call below operates on a consistent survivor set; the
        // per-sequence γ/id scratch stays index-aligned through removals.
        // Victim order is class-aware: evict from the lowest-priority class
        // first, and within it the most-KV-recoverable sequence (least
        // generated progress — cheapest to redo after requeue). Only a
        // *strictly* lower-priority victim spares the starved sequence;
        // otherwise it is preempted itself — exactly the classless behavior
        // whenever every sequence shares one priority tier.
        let mut i = 0;
        while i < self.running.len() {
            let id = self.running[i].id;
            if self.kv.append(id, self.scratch.gammas[i] + 1).is_some() {
                i += 1;
                continue;
            }
            let my_prio = self.class_priority(self.running[i].class);
            let victim = self
                .running
                .iter()
                .enumerate()
                .filter(|(j, s)| *j != i && self.class_priority(s.class) < my_prio)
                .min_by_key(|(j, s)| (self.class_priority(s.class), s.generated(), *j))
                .map(|(j, _)| j);
            let j = victim.unwrap_or(i);
            self.preempt(j);
            self.scratch.gammas.remove(j);
            self.scratch.seq_ids.remove(j);
            if j < i {
                i -= 1;
            }
            // j == i: the starved sequence itself went; j != i: retry its
            // reservation against the freed capacity.
        }
        if self.running.is_empty() {
            self.metrics.time_overhead += t0.elapsed().as_secs_f64();
            return Ok(completions);
        }

        let b = self.running.len();
        let gamma_max = self.scratch.gammas.iter().copied().max().unwrap_or(0);
        let total_gamma: usize = self.scratch.gammas.iter().sum();
        self.metrics.rounds += 1;
        self.metrics.batch_size_sum += b as u64;
        self.round_counter += 1;
        // Per-class round participation (the multi-tenant analogue of
        // batch_size_sum; classless deployments keep one slot).
        for s in &self.running {
            self.metrics.class_mut(s.class).seq_rounds += 1;
        }

        // Per-round inputs live in reusable scratch buffers — no fresh
        // allocation in steady state.
        self.scratch.temps.clear();
        self.scratch.feeds.clear();
        for s in &self.running {
            self.scratch.temps.push(s.params.temperature);
            self.scratch.feeds.push(s.stream[s.base]);
        }

        // Stages ① and ② run as a transaction: on a backend error, roll
        // every sequence's model state and KV reservation back to its
        // committed prefix so the caller can retry the round (exercised by
        // the failure-injection integration test).
        // --- stage ①: draft propose ----------------------------------------
        let propose_result = if gamma_max > 0 {
            if self.scratch.pending.len() < b {
                self.scratch.pending.resize_with(b, Vec::new);
            }
            for (i, s) in self.running.iter().enumerate() {
                let dlen = self.backend.draft_len(s.id);
                let buf = &mut self.scratch.pending[i];
                buf.clear();
                buf.extend_from_slice(&s.stream[dlen..=s.base]);
            }
            self.backend
                .propose(
                    &self.scratch.seq_ids,
                    &self.scratch.pending[..b],
                    &self.scratch.gammas,
                    &self.scratch.temps,
                    self.round_counter,
                )
                .map(Some)
        } else {
            Ok(None)
        };
        let mut round_draft_cost = 0.0;
        let propose_out: Option<ProposeOut> = match propose_result {
            Ok(Some(out)) => {
                self.clock += out.cost;
                self.metrics.time_draft += out.cost;
                self.metrics.draft_tokens_proposed += total_gamma as u64;
                round_draft_cost = out.cost;
                Some(out)
            }
            Ok(None) => None,
            Err(e) => {
                self.abort_round();
                return Err(e.context("draft propose failed (round rolled back)"));
            }
        };

        // --- stage ②: target verify ----------------------------------------
        if propose_out.is_none() && self.scratch.empty_drafts.len() < b {
            self.scratch.empty_drafts.resize_with(b, Vec::new);
        }
        let drafts: &[Vec<u32>] = match &propose_out {
            Some(out) => &out.tokens,
            None => &self.scratch.empty_drafts[..b],
        };
        let verify = match self.backend.verify(
            &self.scratch.seq_ids,
            &self.scratch.feeds,
            drafts,
            &self.scratch.temps,
        ) {
            Ok(v) => v,
            Err(e) => {
                self.abort_round();
                return Err(e.context("target verify failed (round rolled back)"));
            }
        };
        self.clock += verify.cost;
        self.metrics.time_verify += verify.cost;

        // --- stage ③: rejection sampling ------------------------------------
        let rcost = self.backend.reject_cost(&self.scratch.gammas);
        self.clock += rcost;
        self.metrics.time_reject += rcost;

        self.scratch.finished.clear();
        self.scratch.seq_samples.clear();
        let mut round_accepted: u64 = 0;
        let mut round_emitted: u64 = 0;
        for (i, seq) in self.running.iter_mut().enumerate() {
            let (draft_toks, draft_rows): (&[u32], &[LogitsView]) = match &propose_out {
                Some(out) => (out.tokens[i].as_slice(), out.probs[i].as_slice()),
                None => (&[], &[]),
            };
            let outcome =
                verify_chain_views(draft_toks, draft_rows, &verify.probs[i], &mut self.rng);
            self.metrics.draft_tokens_accepted += outcome.accepted as u64;
            round_accepted += outcome.accepted as u64;
            round_emitted += outcome.tokens.len() as u64;
            // Per-sequence accept accounting: the controller's windowed
            // α̂ᵢ estimators consume these (ragged γ decisions).
            self.scratch.seq_samples.push(SeqRoundSample {
                seq: seq.id,
                gamma: self.scratch.gammas[i],
                accepted: outcome.accepted,
            });
            seq.rounds += 1;

            if seq.first_token_at.is_none() {
                seq.first_token_at = Some(self.clock);
            }

            // Commit the emitted tokens.
            seq.stream.extend_from_slice(&outcome.tokens);
            seq.base += 1 + outcome.accepted;
            self.metrics.tokens_generated += outcome.tokens.len() as u64;

            // Roll both models back to the committed prefix; the fresh
            // token (last emitted) is fed next round.
            self.backend.rollback_target(seq.id, seq.base);
            self.backend.rollback_draft(seq.id, seq.base);
            self.kv.truncate(seq.id, seq.stream.len());

            // Completion checks: EOS in the emitted tokens, or budget.
            // Tokens cut by truncation are removed from the generated-token
            // count again so σ reflects *kept* tokens only.
            let len_with_emitted = seq.stream.len();
            let mut done = false;
            if let Some(eos) = seq.params.eos_token {
                if let Some(pos) = outcome.tokens.iter().position(|&t| t == eos) {
                    let cut = seq.stream.len() - outcome.tokens.len() + pos + 1;
                    seq.stream.truncate(cut);
                    done = true;
                }
            }
            if seq.generated() >= seq.params.max_new_tokens {
                seq.stream
                    .truncate(seq.prompt_len + seq.params.max_new_tokens);
                done = true;
            }
            let discarded = len_with_emitted - seq.stream.len();
            self.metrics.tokens_generated -= discarded as u64;
            self.metrics.class_mut(seq.class).tokens_generated +=
                (outcome.tokens.len() - discarded) as u64;
            if done {
                self.scratch.finished.push(i);
            }
        }

        // Close the control loop: report what this round measured. The
        // round-level γ attributed to the cost table is the *mean* verify
        // width minus one (rounded) — exactly γ for uniform rounds, the
        // nearest uniform equivalent for ragged ones.
        if let Some(ctl) = self.controller.as_mut() {
            ctl.observe_sequences(&self.scratch.seq_samples);
            let rows = b + total_gamma;
            let gamma_obs = ((rows + b / 2) / b).saturating_sub(1);
            ctl.observe(RoundObservation {
                round: self.round_counter,
                batch: b,
                gamma: gamma_obs,
                proposed: total_gamma as u64,
                accepted: round_accepted,
                emitted: round_emitted,
                t_draft: round_draft_cost,
                t_verify: verify.cost,
                t_reject: rcost,
                budget: self.backend.verify_budget(),
            });
        }

        // Retire finished sequences (descending index for stable removal).
        for k in (0..self.scratch.finished.len()).rev() {
            let i = self.scratch.finished[k];
            let seq = self.running.remove(i);
            self.backend.release(seq.id);
            self.kv.release(seq.id);
            if let Some(ctl) = self.controller.as_mut() {
                ctl.release_sequence(seq.id);
            }
            self.metrics.requests_completed += 1;
            let completion = Completion {
                id: seq.id,
                tokens: seq.stream[seq.prompt_len..].to_vec(),
                arrival: seq.arrival,
                first_token_at: seq.first_token_at.unwrap_or(self.clock),
                finished_at: self.clock,
                rounds: seq.rounds,
                class: seq.class,
            };
            self.metrics.ttft.0.record(completion.ttft());
            self.metrics.tpot.0.record(completion.tpot());
            self.metrics
                .e2e_latency
                .0
                .record(completion.finished_at - completion.arrival);
            // Per-class latency + SLO attainment (SLOs come from the
            // tenant table; classes beyond it record latency only).
            let (ttft, tpot) = (completion.ttft(), completion.tpot());
            let cm = self.metrics.class_mut(seq.class);
            cm.requests_completed += 1;
            cm.ttft.0.record(ttft);
            cm.tpot.0.record(tpot);
            if let Some(t) = self.config.tenants.get(seq.class) {
                if let Some(slo) = t.ttft_slo {
                    cm.ttft_slo_total += 1;
                    if ttft <= slo {
                        cm.ttft_slo_met += 1;
                    }
                }
                if let Some(slo) = t.tpot_slo {
                    cm.tpot_slo_total += 1;
                    if tpot <= slo {
                        cm.tpot_slo_met += 1;
                    }
                }
            }
            completions.push(completion);
        }

        self.metrics.time_overhead += t0.elapsed().as_secs_f64();
        Ok(completions)
    }

    /// Roll every running sequence back to its committed prefix after a
    /// mid-round backend failure: draft/target model state and the KV
    /// reservation all return to `base`/`stream.len()`. The round's
    /// requests stay running and the next `step()` retries cleanly.
    fn abort_round(&mut self) {
        for seq in &self.running {
            self.backend.rollback_target(seq.id, seq.base);
            self.backend.rollback_draft(seq.id, seq.base);
            self.kv.truncate(seq.id, seq.stream.len());
        }
        self.counters.inc("round_failures");
    }

    /// Admit waiting requests whose arrival time has come.
    fn admit(&mut self) -> anyhow::Result<()> {
        let ceiling = self.admission_ceiling();
        self.admit_with_ceiling(ceiling)
    }

    /// Effective batch ceiling for this step's admission call.
    fn admission_ceiling(&self) -> usize {
        // With a controller, the ceiling comes from its measured cost
        // table (γ-aware round economics). Otherwise the built-in SLO
        // estimator below applies (§3.4 latency-critical serving):
        // estimate TPOT(b) from observed round economics, assuming round
        // time scales linearly with batch size in the compute-bound
        // direction.
        if let Some(ctl) = self.controller.as_ref() {
            return ctl.batch_ceiling(&self.scheduler);
        }
        match self.scheduler.config.tpot_slo {
            // No round economics observed yet: admit a small pilot batch
            // so the estimator has data before committing to a large one.
            Some(_) if self.metrics.rounds == 0 => 4.min(self.scheduler.config.max_batch),
            Some(_) if self.metrics.tokens_generated > 0 => {
                let per_round = self.metrics.decode_time() / self.metrics.rounds as f64;
                let mean_b = self.metrics.mean_batch().max(1.0);
                let tokens_per_seq_round = self.metrics.tokens_generated as f64
                    / self.metrics.batch_size_sum.max(1) as f64;
                self.scheduler.batch_ceiling(|b| {
                    per_round * (b as f64 / mean_b) / tokens_per_seq_round.max(1e-9)
                })
            }
            _ => self.scheduler.config.max_batch,
        }
    }

    /// One policy-dispatched admission call against the current state.
    fn admission_try(&mut self, ceiling: usize) -> Vec<Request> {
        // The per-class context (α̂ᵢ lookups, priced per-class ceilings,
        // the regime oracle) is only computed for the class-aware policy;
        // FIFO reads nothing but the running count, and its per-round
        // path must stay as cheap as the pre-multi-tenant scheduler.
        let class_aware = matches!(self.config.admission, AdmissionPolicyConfig::ClassAware(_));
        self.scratch.run_infos.clear();
        for s in &self.running {
            self.scratch.run_infos.push(RunningInfo {
                class: s.class,
                alpha: if class_aware {
                    self.controller
                        .as_ref()
                        .and_then(|c| c.seq_alpha_hat(s.id))
                } else {
                    None
                },
            });
        }
        // Chunk-prefilling sequences hold KV and a batch slot already:
        // they count against the ceiling like running ones (lock-step
        // never populates this queue).
        for p in self.pipeline.prefilling.iter() {
            self.scratch.run_infos.push(RunningInfo {
                class: p.req.class,
                alpha: None,
            });
        }
        // Per-class batch ceilings, priced from each class's TPOT SLO
        // against the measured cost table (only when classes declare one).
        let class_ceilings: Option<Vec<usize>> = match self.controller.as_ref() {
            Some(ctl)
                if class_aware
                    && self
                        .config
                        .tenants
                        .iter()
                        .any(|t| t.tpot_slo.is_some()) =>
            {
                Some(ctl.class_ceilings(&self.scheduler, &self.config.tenants))
            }
            _ => None,
        };
        let ctx = AdmissionContext {
            kv: &self.kv,
            running: &self.scratch.run_infos,
            ceiling,
            now: self.clock,
            tenants: &self.config.tenants,
            class_ceilings: class_ceilings.as_deref(),
            oracle: if class_aware {
                self.controller.as_ref().map(|c| c as &dyn RegimeOracle)
            } else {
                None
            },
        };
        self.scheduler.admit_with(&mut self.queue, &ctx)
    }

    /// Whether the class-aware policy asked for preemptive eviction on
    /// admission pressure.
    fn preempt_on_admission_enabled(&self) -> bool {
        matches!(&self.config.admission,
            AdmissionPolicyConfig::ClassAware(c) if c.preempt_on_admission)
    }

    /// Preempt-on-admission victim: the lowest-priority least-progress
    /// running sequence strictly below the best waiting (arrival-due)
    /// request's priority tier. `None` when no running sequence sits
    /// strictly below that tier — in particular in one-class deployments,
    /// so the knob is inert there and the class-aware ≡ FIFO degeneracy
    /// holds with it enabled.
    fn admission_eviction_victim(&self) -> Option<usize> {
        let wait_prio = self
            .queue
            .iter()
            .take_while(|r| r.arrival <= self.clock)
            .map(|r| self.class_priority(r.class))
            .max()?;
        self.running
            .iter()
            .enumerate()
            .filter(|(_, s)| self.class_priority(s.class) < wait_prio)
            .min_by_key(|(j, s)| (self.class_priority(s.class), s.generated(), *j))
            .map(|(j, _)| j)
    }

    /// Select requests to admit this step: one policy call, plus (when
    /// the class-aware policy enables it) at most one preemptive eviction
    /// retry so a high-priority arrival is not stuck behind a full batch
    /// of low-priority work until natural completion.
    fn admission_select(&mut self, ceiling: usize) -> Vec<Request> {
        let mut admitted = self.admission_try(ceiling);
        if admitted.is_empty() && self.preempt_on_admission_enabled() {
            if let Some(j) = self.admission_eviction_victim() {
                self.preempt(j);
                self.counters.inc("admission_evictions");
                admitted = self.admission_try(ceiling);
            }
        }
        admitted
    }

    fn admit_with_ceiling(&mut self, ceiling: usize) -> anyhow::Result<()> {
        let admitted = self.admission_select(ceiling);
        if admitted.is_empty() {
            return Ok(());
        }
        if self.config.pipeline.continuous {
            return self.register_admitted_continuous(admitted);
        }

        let mut prefill_batch = Vec::with_capacity(admitted.len());
        for req in &admitted {
            // Reserve the prompt; the scheduler pre-checked capacity.
            if self.kv.allocate(req.id, req.prompt.len()).is_none() {
                anyhow::bail!("KV allocation failed after admission check");
            }
            prefill_batch.push((req.id, req.prompt.clone()));
        }
        let cost = self.backend.prefill(&prefill_batch)?;
        self.clock += cost;
        self.metrics.time_prefill += cost;
        for req in admitted {
            let prompt_len = req.prompt.len();
            self.running.push(RunningSeq {
                id: req.id,
                stream: req.prompt,
                prompt_len,
                base: prompt_len - 1,
                params: req.params,
                arrival: req.arrival,
                first_token_at: None,
                rounds: 0,
                class: req.class,
            });
        }
        Ok(())
    }

    /// Preempt the running sequence at index `i`: drop its progress,
    /// release all state, and requeue the original request at the front.
    /// On the continuous path the per-sequence phase table is aligned
    /// with `running`, so the victim's phase goes with it (the table is
    /// empty on the lock-step path).
    fn preempt(&mut self, i: usize) {
        if i < self.pipeline.phases.len() {
            self.pipeline.phases.remove(i);
        }
        let seq = self.running.remove(i);
        self.backend.release(seq.id);
        self.kv.release(seq.id);
        self.counters.inc("preemptions");
        self.metrics.class_mut(seq.class).preemptions += 1;
        self.queue.push_front(Request {
            id: seq.id,
            prompt: seq.stream[..seq.prompt_len].to_vec(),
            params: seq.params,
            arrival: seq.arrival,
            class: seq.class,
        });
    }

    /// Drive the engine until every submitted request completes (or the
    /// step budget is exhausted — a safety net for tests).
    pub fn run_to_completion(&mut self, max_steps: usize) -> anyhow::Result<Vec<Completion>> {
        let mut all = Vec::new();
        for _ in 0..max_steps {
            if self.is_idle() {
                return Ok(all);
            }
            all.extend(self.step()?);
        }
        anyhow::bail!(
            "run_to_completion: {} sequences still active after {max_steps} steps",
            self.running.len() + self.queue.len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::hardware::platform_2x_gpu_a;
    use crate::simulator::ExecSim;
    use crate::spec::synthetic::SyntheticLm;

    fn synthetic(alpha: f64, seed: u64) -> SyntheticLm {
        let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
        let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
        SyntheticLm::new(target, draft, alpha, seed)
    }

    fn engine(gamma: usize, alpha: f64) -> Engine<SyntheticLm> {
        let config = EngineConfig {
            gamma,
            ..Default::default()
        };
        Engine::new(config, synthetic(alpha, 99))
    }

    fn req(id: u64, prompt_len: usize, max_new: usize, arrival: f64) -> Request {
        Request {
            id,
            prompt: (0..prompt_len as u32).collect(),
            params: SamplingParams {
                temperature: 0.0,
                max_new_tokens: max_new,
                eos_token: None,
            },
            arrival,
            class: 0,
        }
    }

    #[test]
    fn single_request_alpha1_emits_exact_chain() {
        let mut e = engine(4, 1.0);
        e.submit(req(1, 8, 20, 0.0));
        let done = e.run_to_completion(100).unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens.len(), 20);
        let expected = e.backend().expected_chain(1, 8, 20);
        assert_eq!(done[0].tokens, expected);
        // α=1 ⇒ every draft accepted ⇒ σ = 1 and minimal rounds.
        assert!((e.metrics.sigma(4) - 1.0).abs() < 1e-9);
        assert_eq!(e.metrics.rounds, 4); // 20 tokens / 5 per round
    }

    #[test]
    fn sd_output_equals_ar_output_any_alpha() {
        // Losslessness end-to-end: SD (γ=3, α=0.6) and AR (γ=0) emit the
        // same tokens for the same requests.
        let run = |gamma: usize| -> Vec<Vec<u32>> {
            let mut e = engine(gamma, 0.6);
            for id in 0..5 {
                e.submit(req(id, 6, 25, 0.0));
            }
            let mut done = e.run_to_completion(300).unwrap();
            done.sort_by_key(|c| c.id);
            done.into_iter().map(|c| c.tokens).collect()
        };
        assert_eq!(run(3), run(0));
    }

    #[test]
    fn ar_mode_gamma0_works() {
        let mut e = engine(0, 0.5);
        e.submit(req(1, 4, 10, 0.0));
        let done = e.run_to_completion(100).unwrap();
        assert_eq!(done[0].tokens, e.backend().expected_chain(1, 4, 10));
        assert_eq!(e.metrics.rounds, 10); // one token per round
        assert_eq!(e.metrics.time_draft, 0.0);
    }

    #[test]
    fn sd_beats_ar_at_moderate_batch_on_virtual_clock() {
        let batch = 32;
        let run = |gamma: usize| -> f64 {
            let mut e = engine(gamma, 0.9);
            for id in 0..batch {
                e.submit(req(id, 8, 32, 0.0));
            }
            e.run_to_completion(1000).unwrap();
            e.metrics.decode_time()
        };
        let t_ar = run(0);
        let t_sd = run(3);
        let speedup = t_ar / t_sd;
        assert!(
            speedup > 1.3,
            "SD should beat AR at B={batch}: speedup={speedup}"
        );
    }

    #[test]
    fn sigma_matches_eq5_prediction() {
        let alpha = 0.8;
        let gamma = 3;
        let mut e = engine(gamma, alpha);
        for id in 0..64 {
            e.submit(req(id, 4, 40, 0.0));
        }
        e.run_to_completion(2000).unwrap();
        let sigma_measured = e.metrics.sigma(gamma);
        let sigma_theory = crate::theory::sigma_from_alpha(alpha, gamma);
        assert!(
            (sigma_measured - sigma_theory).abs() < 0.05,
            "σ measured {sigma_measured} vs Eq.5 {sigma_theory}"
        );
        // Empirical accepted/proposed ratio: chain truncation means the
        // expectation is α(1−α^γ)/((1−α)γ), not α itself.
        let expect_ratio =
            alpha * (1.0 - alpha.powi(gamma as i32)) / ((1.0 - alpha) * gamma as f64);
        assert!(
            (e.metrics.acceptance_rate() - expect_ratio).abs() < 0.05,
            "accept ratio {} vs expected {expect_ratio}",
            e.metrics.acceptance_rate()
        );
    }

    #[test]
    fn capacity_pressure_triggers_preemption_and_recovers() {
        let config = EngineConfig {
            gamma: 3,
            kv: KvConfig {
                num_blocks: 12,
                block_size: 4,
            },
            scheduler: SchedulerConfig {
                max_batch: 8,
                admit_reserve_tokens: 4,
                tpot_slo: None,
            },
            ..Default::default()
        };
        let mut e = Engine::new(config, synthetic(0.9, 7));
        for id in 0..6 {
            e.submit(req(id, 6, 24, 0.0));
        }
        let done = e.run_to_completion(5000).unwrap();
        assert_eq!(done.len(), 6, "all requests should eventually finish");
        assert!(
            e.counters.get("preemptions") > 0,
            "tiny cache should force preemptions"
        );
        // Every sequence still got the right tokens despite preemption.
        for c in &done {
            assert_eq!(c.tokens, e.backend().expected_chain(c.id, 6, 24));
        }
        e.kv().check_invariants().unwrap();
    }

    #[test]
    fn eos_stops_generation() {
        let mut e = engine(2, 1.0);
        // Find what token the chain emits at position 8+2, use it as EOS.
        let chain = e.backend().expected_chain(1, 8, 10);
        let eos = chain[2];
        let mut r = req(1, 8, 100, 0.0);
        r.params.eos_token = Some(eos);
        e.submit(r);
        let done = e.run_to_completion(200).unwrap();
        assert!(done[0].tokens.len() <= 4, "stopped at eos: {:?}", done[0].tokens);
        assert_eq!(*done[0].tokens.last().unwrap(), eos);
    }

    #[test]
    fn arrivals_respected_and_clock_fast_forwards() {
        let mut e = engine(2, 0.9);
        e.submit(req(1, 4, 8, 5.0)); // arrives at t=5 virtual seconds
        let done = e.run_to_completion(100).unwrap();
        assert!(e.clock() >= 5.0);
        assert!(done[0].first_token_at >= 5.0);
        assert!(done[0].ttft() > 0.0);
    }

    #[test]
    fn continuous_batching_admits_midstream() {
        let mut e = engine(2, 0.9);
        e.submit(req(1, 4, 60, 0.0));
        // Second request arrives while the first is mid-generation.
        e.step().unwrap();
        let mid_clock = e.clock();
        e.submit(req(2, 4, 10, mid_clock));
        let done = e.run_to_completion(500).unwrap();
        assert_eq!(done.len(), 2);
        // Request 2 must have joined the running batch (batch of 2 seen).
        assert!(e.metrics.mean_batch() > 1.0);
    }

    #[test]
    fn gamma_overrides_drive_ragged_rounds_losslessly() {
        // Static ragged rounds: two sequences at γ=6, two at γ=1, mixed
        // per-sequence α — every chain still exact.
        let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
        let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
        let backend = SyntheticLm::new(target, draft, 0.9, 17)
            .with_seq_alphas(&[(2, 0.4), (3, 0.4)]);
        let mut overrides = std::collections::HashMap::new();
        overrides.insert(0u64, 6usize);
        overrides.insert(1, 6);
        overrides.insert(2, 1);
        overrides.insert(3, 1);
        let config = EngineConfig {
            gamma: 3,
            gamma_overrides: overrides,
            ..Default::default()
        };
        let mut e = Engine::new(config, backend);
        for id in 0..4 {
            e.submit(req(id, 6, 24, 0.0));
        }
        let done = e.run_to_completion(1000).unwrap();
        assert_eq!(done.len(), 4);
        for c in &done {
            assert_eq!(c.tokens, e.backend().expected_chain(c.id, 6, 24));
        }
        // The deep-γ sequences finish in fewer rounds than the shallow
        // ones (α=0.9 at γ=6 vs α=0.4 at γ=1).
        let rounds = |id: u64| done.iter().find(|c| c.id == id).unwrap().rounds;
        assert!(rounds(0) < rounds(2), "{} vs {}", rounds(0), rounds(2));
    }

    #[test]
    fn uniform_overrides_are_identical_to_plain_config() {
        // Overrides that equal config.gamma for every sequence take the
        // ragged code path but must reproduce the plain run bit-for-bit.
        let run = |with_overrides: bool| -> (Vec<Vec<u32>>, u64, f64) {
            let mut overrides = std::collections::HashMap::new();
            if with_overrides {
                for id in 0..5u64 {
                    overrides.insert(id, 3usize);
                }
            }
            let config = EngineConfig {
                gamma: 3,
                gamma_overrides: overrides,
                ..Default::default()
            };
            let mut e = Engine::new(config, synthetic(0.7, 23));
            for id in 0..5 {
                e.submit(req(id, 6, 25, 0.0));
            }
            let mut done = e.run_to_completion(500).unwrap();
            done.sort_by_key(|c| c.id);
            (
                done.into_iter().map(|c| c.tokens).collect(),
                e.metrics.rounds,
                e.clock(),
            )
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn adaptive_controller_drives_gamma_and_stays_lossless() {
        use crate::control::{ControlConfig, CostModelSpec};
        let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
        let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
        let config = EngineConfig {
            gamma: 0, // the controller owns γ from round 0
            control: Some(ControlConfig::model_guided(CostModelSpec::roofline(
                target, draft,
            ))),
            ..Default::default()
        };
        let mut e = Engine::new(config, synthetic(0.9, 11));
        for id in 0..4 {
            e.submit(req(id, 6, 24, 0.0));
        }
        let done = e.run_to_completion(1000).unwrap();
        assert_eq!(done.len(), 4);
        // Losslessness holds under adaptive γ: the emitted chains are
        // exactly what the target would have produced autoregressively.
        for c in &done {
            assert_eq!(c.tokens, e.backend().expected_chain(c.id, 6, 24));
        }
        let st = e.controller_state().unwrap();
        assert!(st.gamma >= 1, "small-batch adaptive should speculate: {st:?}");
        assert!(e.metrics.draft_tokens_proposed > 0);
        assert_eq!(e.current_gamma(), st.gamma);
    }

    #[test]
    fn per_class_accounting_and_slo_attainment() {
        use crate::workload::TenantClass;
        let mut fast = TenantClass::new("fast");
        fast.ttft_slo = Some(1e9); // trivially met
        fast.tpot_slo = Some(1e9);
        let mut slow = TenantClass::new("slow");
        slow.ttft_slo = Some(1e-12); // unmeetable
        let config = EngineConfig {
            gamma: 2,
            tenants: vec![fast, slow],
            ..Default::default()
        };
        let mut e = Engine::new(config, synthetic(0.9, 3));
        e.submit(req(1, 6, 12, 0.0).with_class(0));
        e.submit(req(2, 6, 12, 0.0).with_class(1));
        let done = e.run_to_completion(200).unwrap();
        assert_eq!(done.len(), 2);
        for c in &done {
            let want = if c.id == 1 { 0 } else { 1 };
            assert_eq!(c.class, want, "completions carry their class");
        }
        let m = &e.metrics;
        assert!(m.class.len() >= 2);
        assert_eq!(m.class[0].requests_completed, 1);
        assert_eq!(m.class[1].requests_completed, 1);
        assert_eq!(m.class[0].tokens_generated, 12);
        assert_eq!(m.class[1].tokens_generated, 12);
        assert!(m.class[0].seq_rounds > 0 && m.class[1].seq_rounds > 0);
        // Both classes' seq-rounds sum to the global batch_size_sum.
        let sum: u64 = m.class.iter().map(|c| c.seq_rounds).sum();
        assert_eq!(sum, m.batch_size_sum);
        assert_eq!(m.class[0].ttft_attainment(), Some(1.0));
        assert_eq!(m.class[0].tpot_attainment(), Some(1.0));
        assert_eq!(m.class[1].ttft_attainment(), Some(0.0));
        assert_eq!(m.class[1].tpot_attainment(), None, "slow has no TPOT SLO");
    }

    #[test]
    fn preemption_prefers_lowest_priority_least_progress() {
        use crate::workload::TenantClass;
        // Tiny cache forces preemption; the high-priority sequence must
        // never be the victim while low-priority ones are running.
        let mut hi = TenantClass::new("hi");
        hi.priority = 2;
        let lo = TenantClass::new("lo"); // priority 1
        let config = EngineConfig {
            gamma: 3,
            kv: KvConfig {
                num_blocks: 14,
                block_size: 4,
            },
            scheduler: SchedulerConfig {
                max_batch: 8,
                admit_reserve_tokens: 4,
                tpot_slo: None,
            },
            tenants: vec![hi, lo],
            ..Default::default()
        };
        let mut e = Engine::new(config, synthetic(0.9, 7));
        e.submit(req(0, 6, 24, 0.0).with_class(0)); // high priority
        for id in 1..6u64 {
            e.submit(req(id, 6, 24, 0.0).with_class(1));
        }
        let done = e.run_to_completion(5000).unwrap();
        assert_eq!(done.len(), 6, "all requests should eventually finish");
        assert!(
            e.counters.get("preemptions") > 0,
            "tiny cache should force preemptions"
        );
        // Victim accounting is per class: every eviction hit class 1.
        assert_eq!(e.metrics.class[0].preemptions, 0, "high priority never evicted");
        assert!(e.metrics.class[1].preemptions > 0);
        // Losslessness survives class-aware preemption.
        for c in &done {
            assert_eq!(c.tokens, e.backend().expected_chain(c.id, 6, 24));
        }
        e.kv().check_invariants().unwrap();
    }

    #[test]
    fn class_aware_single_class_matches_fifo_engine_run() {
        use crate::scheduler::{AdmissionPolicyConfig, ClassAwareConfig};
        // The acceptance criterion at engine level: a single-class
        // class-aware config reproduces the FIFO engine bit-for-bit.
        let run = |admission: AdmissionPolicyConfig| -> (Vec<Vec<u32>>, u64, f64, u64) {
            let config = EngineConfig {
                gamma: 3,
                kv: KvConfig {
                    num_blocks: 24,
                    block_size: 4,
                },
                scheduler: SchedulerConfig {
                    max_batch: 4,
                    admit_reserve_tokens: 4,
                    tpot_slo: None,
                },
                admission,
                ..Default::default()
            };
            let mut e = Engine::new(config, synthetic(0.8, 21));
            for id in 0..7 {
                e.submit(req(id, 6, 18, 0.2 * id as f64));
            }
            let mut done = e.run_to_completion(2000).unwrap();
            done.sort_by_key(|c| c.id);
            (
                done.into_iter().map(|c| c.tokens).collect(),
                e.metrics.rounds,
                e.clock(),
                e.counters.get("preemptions"),
            )
        };
        let fifo = run(AdmissionPolicyConfig::Fifo);
        let class = run(AdmissionPolicyConfig::ClassAware(ClassAwareConfig::default()));
        assert_eq!(fifo, class);
    }

    #[test]
    fn overhead_is_measured_but_not_on_virtual_clock() {
        let mut e = engine(3, 0.9);
        e.submit(req(1, 4, 16, 0.0));
        e.run_to_completion(100).unwrap();
        assert!(e.metrics.time_overhead > 0.0);
        // Virtual decode time is orders of magnitude above wall overhead in
        // this tiny run only if sim times are large; just check accounting
        // separation: decode_time excludes overhead.
        let total = e.metrics.total_time();
        assert!(
            (total - (e.metrics.decode_time() + e.metrics.time_prefill + e.metrics.time_overhead))
                .abs()
                < 1e-12
        );
    }
}
