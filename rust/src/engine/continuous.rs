//! Continuous batching: the event-driven decode pipeline.
//!
//! The lock-step `Engine::step_lockstep` round has three built-in stalls:
//! every sequence waits for the slowest γᵢ in its round, a long prompt's
//! prefill blocks all running decodes for its full duration, and the
//! draft model sits idle while the target verifies. This module replaces
//! the synchronous round with an event-driven pipeline over **two virtual
//! resource timelines** — the draft model (`free_draft`) and the target
//! model (`free_target`) — while the engine clock remains the *commit
//! frontier* (the time by which emitted tokens exist):
//!
//! ```text
//!             ┌ admission ─► [chunked prefill queue] ─► Ready ┐
//!             │                                               ▼
//!   queue ────┤                                   propose op (draft lane)
//!             │                                               │ Drafted
//!             │                                               ▼
//!             └───────────◄─ retire ◄─ commit ◄─ verify op (target lane)
//! ```
//!
//! Three independently-gated mechanisms (see [`PipelineConfig`]):
//!
//! - **Chunked prefill** — admitted prompts enter a prefill queue and
//!   are processed as batched chunk ops: each op draws up to
//!   `prefill_chunk` prompt-body tokens across the queue front (spanning
//!   prompts), at most one op per step while decode work exists, so a
//!   prefill wave inserts bounded bubbles between decode rounds instead
//!   of one long stall (the Sarathi/vLLM chunked-prefill idea). Drawing
//!   the budget across prompts keeps the op wide enough that MoE expert
//!   weight reads amortize like a bulk prefill. Virtual-clock backends
//!   price each op via [`crate::spec::SdBackend::prefill_chunks_cost`];
//!   the final registration call charges only the residual above what
//!   the chunks already paid, so wall-clock backends (which measure at
//!   `prefill`) stay correctly priced.
//! - **Draft-ahead** — the next round's proposal for sequences whose
//!   previous round was *fully accepted* overlaps the previous verify:
//!   their draft context is already final when the verify launches, so a
//!   real deployment drafts them on the idle draft model during
//!   verification (SP-MoE / PEARL-style pipelining). Priced as overlap
//!   accounting: each verify op grants an `ahead_budget` equal to its
//!   duration, and the eligible share of the next propose op hides up to
//!   that budget (total draft spend is metered in
//!   `metrics.time_draft_hidden`), making round time `max(draft,
//!   verify)` instead of the sum in the fully-accepted steady state.
//! - **Per-sequence round boundaries** — propose/verify ops take ready
//!   *cohorts* instead of the whole batch, so a fully-accepted sequence
//!   re-enters proposal without waiting for stragglers. A coalescing
//!   guard defers ops smaller than half the ready set to protect verify
//!   batch efficiency in the memory-bound regime.
//!
//! With all three off (`PipelineConfig { continuous: true, ..off }`), the
//! pipeline degenerates to the lock-step loop **bit-for-bit**: every op
//! starts at the shared resource frontier (== the clock), membership is
//! the whole batch, and the backend-call/RNG/accounting order is
//! identical. `rust/tests/prop_continuous.rs` pins this equivalence on
//! random workloads.

use crate::batching::{Completion, Request};
use crate::control::RoundObservation;
use crate::kvcache::SeqId;
use crate::sampling::verify_chain_views;
use crate::spec::{LogitsView, SdBackend};
use std::collections::VecDeque;

use super::{Engine, RunningSeq};

/// Continuous-batching knobs (all off by default = lock-step engine).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct PipelineConfig {
    /// Use the event-driven pipeline instead of the lock-step round loop.
    pub continuous: bool,
    /// Chunked prefill: per-op token budget. Each chunk op processes up
    /// to this many prompt-body tokens drawn across the front of the
    /// prefill queue, interleaved with decode ops. `None` = bulk
    /// prefill at admission (the lock-step behavior). Budgets well
    /// below the weight/compute roofline crossover (~512 tokens for the
    /// default MoE target) re-read expert weights per op and waste
    /// bandwidth.
    pub prefill_chunk: Option<usize>,
    /// Overlap the next proposal of fully-accepted sequences with the
    /// current verify (cost-overlap accounting; see the module docs).
    pub draft_ahead: bool,
    /// Let ready cohorts start propose/verify ops without waiting for
    /// the whole batch (per-sequence round boundaries). `false` =
    /// batch-synchronized rounds.
    pub per_seq_boundaries: bool,
}

impl PipelineConfig {
    /// The lock-step engine (identical to `Default`).
    pub fn lockstep() -> PipelineConfig {
        PipelineConfig::default()
    }

    /// The full pipeline: chunked prefill at `chunk` tokens, draft-ahead
    /// overlap, per-sequence round boundaries.
    pub fn full(chunk: usize) -> PipelineConfig {
        PipelineConfig {
            continuous: true,
            prefill_chunk: Some(chunk.max(1)),
            draft_ahead: true,
            per_seq_boundaries: true,
        }
    }
}

/// Where a running sequence stands in the propose→verify cycle. The
/// table is index-aligned with `Engine::running` (preemption and
/// retirement remove entries from both).
#[derive(Debug)]
pub(super) enum Phase {
    /// Committed through `base`; eligible for the next propose op once
    /// the draft lane reaches `ready_at`. `ahead` marks a sequence whose
    /// previous round was fully accepted (draft-ahead eligible).
    Ready { ready_at: f64, ahead: bool },
    /// Proposal done (op finished at `ready_at`); awaiting verify.
    Drafted {
        ready_at: f64,
        gamma: usize,
        tokens: Vec<u32>,
        probs: Vec<LogitsView>,
    },
}

impl Phase {
    fn ready_at(&self) -> f64 {
        match self {
            Phase::Ready { ready_at, .. } | Phase::Drafted { ready_at, .. } => *ready_at,
        }
    }
}

/// A request admitted under chunked prefill, not yet fully prefilled.
#[derive(Debug)]
pub(super) struct Prefilling {
    pub(super) req: Request,
    /// Prompt-body tokens already chunk-processed.
    done: usize,
    /// Virtual seconds already charged for those chunks (the final
    /// `prefill` registration charges only the residual above this).
    paid: f64,
}

/// Mutable pipeline state. Inert (empty/zero) on the lock-step path.
#[derive(Debug, Default)]
pub(super) struct PipelineState {
    /// Draft-lane frontier: virtual time the draft model is busy until.
    free_draft: f64,
    /// Target-lane frontier: virtual time the target model is busy until.
    free_target: f64,
    /// Remaining verify-window seconds the next propose op may hide
    /// under (set to the verify cost at each verify op; draft-ahead).
    ahead_budget: f64,
    /// Draft cost accumulated since the last controller observation
    /// (flushed into `RoundObservation::t_draft` at the next verify op).
    draft_cost_unreported: f64,
    /// Draft tokens proposed since the last controller observation.
    proposed_unreported: u64,
    /// Chunked-prefill queue (FIFO; sequences here hold KV and count
    /// against the admission ceiling).
    pub(super) prefilling: VecDeque<Prefilling>,
    /// Per-sequence phases, index-aligned with `Engine::running`.
    pub(super) phases: Vec<Phase>,
}

/// Pick the cohort for an op from `(running index, ready_at)` candidates.
/// Returns the chosen indices and the op start time.
///
/// Batch mode: everyone, starting when the last candidate is ready.
/// Per-sequence mode: candidates already ready at the resource frontier
/// `t_floor` (or, if none, the earliest-ready one), with a coalescing
/// guard — a cohort smaller than half the candidate set waits for the
/// stragglers instead, protecting op batch efficiency in the
/// memory-bound regime.
fn select_cohort(cands: &[(usize, f64)], t_floor: f64, per_seq: bool) -> (Vec<usize>, f64) {
    if cands.is_empty() {
        return (Vec::new(), t_floor);
    }
    if !per_seq {
        let t = cands.iter().fold(t_floor, |acc, &(_, r)| acc.max(r));
        return (cands.iter().map(|&(i, _)| i).collect(), t);
    }
    let mut cut = t_floor;
    if !cands.iter().any(|&(_, r)| r <= cut) {
        cut = cands
            .iter()
            .map(|&(_, r)| r)
            .fold(f64::INFINITY, f64::min);
    }
    let mut included: Vec<(usize, f64)> =
        cands.iter().copied().filter(|&(_, r)| r <= cut).collect();
    if included.len() * 2 < cands.len() {
        included = cands.to_vec();
    }
    let t = included.iter().fold(t_floor, |acc, &(_, r)| acc.max(r));
    (included.into_iter().map(|(i, _)| i).collect(), t)
}

impl<B: SdBackend> Engine<B> {
    /// One event of the continuous pipeline: admission, at most one
    /// batched prefill chunk op (while decode work exists), at most one
    /// propose op and one verify+commit op.
    pub(super) fn step_continuous(&mut self) -> anyhow::Result<Vec<Completion>> {
        let t0 = std::time::Instant::now();
        let mut completions = Vec::new();

        // Fast-forward to the next arrival when fully drained; the
        // resource frontiers never lag the clock.
        if self.running.is_empty() && self.pipeline.prefilling.is_empty() {
            if let Some(head) = self.queue.peek() {
                if head.arrival > self.clock {
                    self.clock = head.arrival;
                }
            }
            self.pipeline.free_draft = self.pipeline.free_draft.max(self.clock);
            self.pipeline.free_target = self.pipeline.free_target.max(self.clock);
        }

        self.admit()?;
        self.prefill_chunk_work()?;

        if self.running.is_empty() {
            self.metrics.time_overhead += t0.elapsed().as_secs_f64();
            return Ok(completions);
        }

        self.propose_op()?;
        self.verify_commit_op(&mut completions)?;

        self.metrics.time_overhead += t0.elapsed().as_secs_f64();
        Ok(completions)
    }

    /// Route admitted requests into the pipeline: bulk prefill (chunking
    /// off — identical to the lock-step admission path) or the chunked
    /// prefill queue.
    pub(super) fn register_admitted_continuous(
        &mut self,
        admitted: Vec<Request>,
    ) -> anyhow::Result<()> {
        match self.config.pipeline.prefill_chunk {
            None => {
                let mut prefill_batch = Vec::with_capacity(admitted.len());
                for req in &admitted {
                    // Reserve the prompt; the scheduler pre-checked capacity.
                    if self.kv.allocate(req.id, req.prompt.len()).is_none() {
                        anyhow::bail!("KV allocation failed after admission check");
                    }
                    prefill_batch.push((req.id, req.prompt.clone()));
                }
                let cost = self.backend.prefill(&prefill_batch)?;
                let t_start = self.pipeline.free_draft.max(self.pipeline.free_target);
                let t_end = t_start + cost;
                self.pipeline.free_draft = t_end;
                self.pipeline.free_target = t_end;
                self.clock = self.clock.max(t_end);
                self.metrics.time_prefill += cost;
                for req in admitted {
                    let prompt_len = req.prompt.len();
                    self.running.push(RunningSeq {
                        id: req.id,
                        stream: req.prompt,
                        prompt_len,
                        base: prompt_len - 1,
                        params: req.params,
                        arrival: req.arrival,
                        first_token_at: None,
                        rounds: 0,
                        class: req.class,
                    });
                    self.pipeline.phases.push(Phase::Ready {
                        ready_at: t_end,
                        ahead: false,
                    });
                }
            }
            Some(_) => {
                for req in admitted {
                    // KV for the whole prompt is claimed up front (the
                    // scheduler pre-checked it); chunking spreads the
                    // *compute*, not the memory footprint.
                    if self.kv.allocate(req.id, req.prompt.len()).is_none() {
                        anyhow::bail!("KV allocation failed after admission check");
                    }
                    self.pipeline
                        .prefilling
                        .push_back(Prefilling { req, done: 0, paid: 0.0 });
                }
            }
        }
        Ok(())
    }

    /// Advance the chunked-prefill queue: at most one chunk *op* per
    /// step while decode work exists (bounded TPOT bubble), otherwise
    /// chunk until a sequence becomes decodable. Each op draws up to
    /// `prefill_chunk` prompt-body tokens across the *front* of the
    /// queue (spanning prompt boundaries), so a single packed forward
    /// amortizes weight traffic over the whole cohort — a per-prompt
    /// batch-1 chunk would re-read every MoE expert per chunk and
    /// inflate prefill cost severalfold. Fully-chunked prompts are
    /// registered with the backend in one batch, charging only the cost
    /// residual the chunks didn't already pay.
    fn prefill_chunk_work(&mut self) -> anyhow::Result<()> {
        let Some(budget) = self.config.pipeline.prefill_chunk else {
            return Ok(());
        };
        let mut ops_this_step = 0usize;
        loop {
            // Register anything already fully chunked (including
            // zero-body prompts that never need an op).
            self.register_chunked_ready()?;

            // Draw this op's token budget from the queue front. The
            // registration pass above drained every completed entry, so
            // all remaining entries still need body work.
            let mut draws: Vec<(usize, usize)> = Vec::new(); // (queue idx, take)
            let mut parts: Vec<(usize, usize)> = Vec::new(); // (tokens, ctx)
            let mut left = budget.max(1);
            for (qi, pf) in self.pipeline.prefilling.iter().enumerate() {
                if left == 0 {
                    break;
                }
                let body = pf.req.prompt.len().saturating_sub(1);
                let take = left.min(body - pf.done);
                draws.push((qi, take));
                parts.push((take, pf.done));
                left -= take;
            }
            if draws.is_empty() {
                break;
            }
            if ops_this_step >= 1 && !self.running.is_empty() {
                break;
            }

            let cost = self.backend.prefill_chunks_cost(&parts);
            let total: usize = draws.iter().map(|&(_, take)| take).sum();
            for &(qi, take) in &draws {
                let pf = &mut self.pipeline.prefilling[qi];
                pf.done += take;
                // Apportion the op cost by token share; the batched
                // registration below pools `paid` again, so the split
                // only matters if a member is preempted mid-prefill.
                pf.paid += cost * take as f64 / total as f64;
            }
            let t_start = self.pipeline.free_draft.max(self.pipeline.free_target);
            let t_end = t_start + cost;
            self.pipeline.free_draft = t_end;
            self.pipeline.free_target = t_end;
            self.clock = self.clock.max(t_end);
            self.metrics.time_prefill += cost;
            self.metrics.prefill_chunks += draws.len() as u64;
            ops_this_step += 1;
        }
        Ok(())
    }

    /// Drain every fully-chunked prompt from the prefill queue and
    /// register the batch with the backend. Virtual-clock backends
    /// already priced the work chunk-wise, so only the residual above
    /// the pooled chunk payments (if any) is charged; wall-clock
    /// backends measure everything here (their chunk costs are 0).
    fn register_chunked_ready(&mut self) -> anyhow::Result<()> {
        let mut ready: Vec<Prefilling> = Vec::new();
        let mut qi = 0;
        while qi < self.pipeline.prefilling.len() {
            let body = self.pipeline.prefilling[qi]
                .req
                .prompt
                .len()
                .saturating_sub(1);
            if self.pipeline.prefilling[qi].done >= body {
                ready.push(
                    self.pipeline
                        .prefilling
                        .remove(qi)
                        .expect("index checked against len"),
                );
            } else {
                qi += 1;
            }
        }
        if ready.is_empty() {
            return Ok(());
        }
        let batch: Vec<_> = ready
            .iter()
            .map(|pf| (pf.req.id, pf.req.prompt.clone()))
            .collect();
        let cost = self.backend.prefill(&batch)?;
        let paid: f64 = ready.iter().map(|pf| pf.paid).sum();
        let residual = (cost - paid).max(0.0);
        if residual > 0.0 {
            let t_start = self.pipeline.free_draft.max(self.pipeline.free_target);
            let t_end = t_start + residual;
            self.pipeline.free_draft = t_end;
            self.pipeline.free_target = t_end;
            self.clock = self.clock.max(t_end);
            self.metrics.time_prefill += residual;
        }
        let ready_at = self.pipeline.free_target.max(self.pipeline.free_draft);
        for pf in ready {
            let prompt_len = pf.req.prompt.len();
            self.running.push(RunningSeq {
                id: pf.req.id,
                stream: pf.req.prompt,
                prompt_len,
                base: prompt_len - 1,
                params: pf.req.params,
                arrival: pf.req.arrival,
                first_token_at: None,
                rounds: 0,
                class: pf.req.class,
            });
            self.pipeline.phases.push(Phase::Ready {
                ready_at,
                ahead: false,
            });
        }
        Ok(())
    }

    /// One draft-propose op over the ready cohort (if any): assign γᵢ,
    /// reserve KV (class-aware preemption on pressure), run the draft,
    /// and move the cohort to `Drafted`.
    fn propose_op(&mut self) -> anyhow::Result<()> {
        let per_seq = self.config.pipeline.per_seq_boundaries;
        let ahead_on = self.config.pipeline.draft_ahead;

        // Batch-synchronized boundaries: propose only at a clean round
        // boundary (nobody mid-verify); mid-flight joins wait as Ready.
        if !per_seq
            && self
                .pipeline
                .phases
                .iter()
                .any(|p| matches!(p, Phase::Drafted { .. }))
        {
            return Ok(());
        }

        let cands: Vec<(usize, f64)> = self
            .pipeline
            .phases
            .iter()
            .enumerate()
            .filter(|(_, p)| matches!(p, Phase::Ready { .. }))
            .map(|(i, p)| (i, p.ready_at()))
            .collect();
        let t_floor = if ahead_on {
            self.pipeline.free_draft
        } else {
            self.pipeline.free_draft.max(self.pipeline.free_target)
        };
        let (mut members, _) = select_cohort(&cands, t_floor, per_seq);
        if members.is_empty() {
            return Ok(());
        }

        // γᵢ for the cohort: controller-owned when configured, else
        // static overrides on top of the uniform config.gamma — the same
        // precedence as the lock-step round.
        self.scratch.seq_ids.clear();
        for &i in &members {
            self.scratch.seq_ids.push(self.running[i].id);
        }
        self.scratch.gammas.clear();
        match self.controller.as_mut() {
            Some(ctl) => {
                ctl.gammas_for_round(&self.scratch.seq_ids, &mut self.scratch.gammas);
                // The controller owns the verify-expert budget when its
                // grid is configured: push the joint (γ⃗, budget) decision
                // into the backend before this round's ops are priced.
                if ctl.owns_budget() {
                    self.backend.set_verify_budget(ctl.verify_budget());
                }
            }
            None if self.config.gamma_overrides.is_empty() => self
                .scratch
                .gammas
                .extend(std::iter::repeat(self.config.gamma).take(members.len())),
            None => {
                for &i in &members {
                    self.scratch.gammas.push(
                        self.config
                            .gamma_overrides
                            .get(&self.running[i].id)
                            .copied()
                            .unwrap_or(self.config.gamma),
                    );
                }
            }
        }

        // --- capacity reservation: γᵢ+1 tokens per cohort member -----------
        // Same victim policy as the lock-step round: lowest-priority
        // class first, least generated progress within it; only a
        // strictly lower-priority victim spares the starved member.
        let mut k = 0;
        while k < members.len() {
            let i = members[k];
            let id = self.running[i].id;
            if self.kv.append(id, self.scratch.gammas[k] + 1).is_some() {
                k += 1;
                continue;
            }
            let my_prio = self.class_priority(self.running[i].class);
            let victim = self
                .running
                .iter()
                .enumerate()
                .filter(|(j, s)| *j != i && self.class_priority(s.class) < my_prio)
                .min_by_key(|(j, s)| (self.class_priority(s.class), s.generated(), *j))
                .map(|(j, _)| j);
            let j = victim.unwrap_or(i);
            self.preempt(j); // also drops phases[j]
            if let Some(pos) = members.iter().position(|&m| m == j) {
                members.remove(pos);
                self.scratch.gammas.remove(pos);
                self.scratch.seq_ids.remove(pos);
                if pos < k {
                    k -= 1;
                }
                // pos == k: the starved member itself went; the next
                // member retries against the freed capacity.
            }
            for m in members.iter_mut() {
                if *m > j {
                    *m -= 1;
                }
            }
        }
        if members.is_empty() {
            return Ok(());
        }

        let b_op = members.len();
        let gamma_max = self.scratch.gammas.iter().copied().max().unwrap_or(0);
        let total_gamma: usize = self.scratch.gammas.iter().sum();
        self.round_counter += 1;

        self.scratch.temps.clear();
        for &i in &members {
            self.scratch.temps.push(self.running[i].params.temperature);
        }

        // Op start: the cohort's last ready_at, floored by the draft
        // lane (and the target lane too when draft-ahead is off — the
        // serial regime where both models share one execution stream).
        let ready_max = members
            .iter()
            .fold(f64::MIN, |acc, &i| acc.max(self.pipeline.phases[i].ready_at()));
        let t_start = t_floor.max(ready_max);

        let (mut tokens, mut probs): (Vec<Vec<u32>>, Vec<Vec<LogitsView>>);
        let mut exposed = 0.0f64;
        if gamma_max > 0 {
            if self.scratch.pending.len() < b_op {
                self.scratch.pending.resize_with(b_op, Vec::new);
            }
            for (k, &i) in members.iter().enumerate() {
                let s = &self.running[i];
                let dlen = self.backend.draft_len(s.id);
                let buf = &mut self.scratch.pending[k];
                buf.clear();
                buf.extend_from_slice(&s.stream[dlen..=s.base]);
            }

            // Draft-ahead split: the eligible share (fully accepted last
            // round, so its draft context was final during the previous
            // verify) runs as its own op and hides under the verify
            // window granted by `ahead_budget`.
            let elig: Vec<usize> = if ahead_on {
                (0..b_op)
                    .filter(|&k| {
                        self.scratch.gammas[k] > 0
                            && matches!(
                                self.pipeline.phases[members[k]],
                                Phase::Ready { ahead: true, .. }
                            )
                    })
                    .collect()
            } else {
                Vec::new()
            };

            let mut total_cost = 0.0f64;
            let mut hidden = 0.0f64;
            if elig.is_empty() || elig.len() == b_op {
                let out = match self.backend.propose(
                    &self.scratch.seq_ids,
                    &self.scratch.pending[..b_op],
                    &self.scratch.gammas,
                    &self.scratch.temps,
                    self.round_counter,
                ) {
                    Ok(out) => out,
                    Err(e) => {
                        self.abort_members(&members);
                        return Err(e.context("draft propose failed (cohort rolled back)"));
                    }
                };
                total_cost = out.cost;
                if !elig.is_empty() {
                    hidden = out.cost.min(self.pipeline.ahead_budget);
                }
                tokens = out.tokens;
                probs = out.probs;
            } else {
                // Mixed cohort: two draft ops, overlap-priced separately.
                let rest: Vec<usize> = (0..b_op).filter(|k| !elig.contains(k)).collect();
                tokens = vec![Vec::new(); b_op];
                probs = vec![Vec::new(); b_op];
                for (sub, overlapped) in [(&elig, true), (&rest, false)] {
                    let ids: Vec<SeqId> =
                        sub.iter().map(|&k| self.scratch.seq_ids[k]).collect();
                    let pend: Vec<Vec<u32>> =
                        sub.iter().map(|&k| self.scratch.pending[k].clone()).collect();
                    let gam: Vec<usize> =
                        sub.iter().map(|&k| self.scratch.gammas[k]).collect();
                    let tmp: Vec<f64> =
                        sub.iter().map(|&k| self.scratch.temps[k]).collect();
                    let out = match self
                        .backend
                        .propose(&ids, &pend, &gam, &tmp, self.round_counter)
                    {
                        Ok(out) => out,
                        Err(e) => {
                            self.abort_members(&members);
                            return Err(e.context("draft propose failed (cohort rolled back)"));
                        }
                    };
                    self.round_counter += 1; // unique seed per sub-op
                    total_cost += out.cost;
                    if overlapped {
                        hidden = out.cost.min(self.pipeline.ahead_budget);
                    }
                    for (slot, (t, p)) in sub
                        .iter()
                        .zip(out.tokens.into_iter().zip(out.probs.into_iter()))
                    {
                        tokens[*slot] = t;
                        probs[*slot] = p;
                    }
                }
            }
            self.pipeline.ahead_budget -= hidden;
            exposed = total_cost - hidden;
            self.metrics.time_draft += total_cost;
            self.metrics.time_draft_hidden += hidden;
            self.metrics.draft_tokens_proposed += total_gamma as u64;
            self.pipeline.draft_cost_unreported += total_cost;
            self.pipeline.proposed_unreported += total_gamma as u64;
        } else {
            // AR cohort (all γᵢ = 0): no draft forwards — straight to
            // the verify op with empty drafts, zero draft cost.
            tokens = vec![Vec::new(); b_op];
            probs = vec![Vec::new(); b_op];
        }

        let t_end = t_start + exposed;
        self.pipeline.free_draft = self.pipeline.free_draft.max(t_end);
        if !ahead_on {
            // Serial regime: the models share one execution stream, so
            // draft time also occupies the target lane and the commit
            // frontier tracks it (exactly the lock-step clock rule).
            self.pipeline.free_target = self.pipeline.free_target.max(t_end);
            self.clock = self.clock.max(t_end);
        }

        for (k, &i) in members.iter().enumerate() {
            self.pipeline.phases[i] = Phase::Drafted {
                ready_at: t_end,
                gamma: self.scratch.gammas[k],
                tokens: std::mem::take(&mut tokens[k]),
                probs: std::mem::take(&mut probs[k]),
            };
        }
        Ok(())
    }

    /// One target verify + rejection-sample + commit op over the drafted
    /// cohort (if any). Closes the control loop and retires finished
    /// sequences.
    fn verify_commit_op(&mut self, completions: &mut Vec<Completion>) -> anyhow::Result<()> {
        let per_seq = self.config.pipeline.per_seq_boundaries;
        let ahead_on = self.config.pipeline.draft_ahead;

        let cands: Vec<(usize, f64)> = self
            .pipeline
            .phases
            .iter()
            .enumerate()
            .filter(|(_, p)| matches!(p, Phase::Drafted { .. }))
            .map(|(i, p)| (i, p.ready_at()))
            .collect();
        if cands.is_empty() {
            return Ok(());
        }
        let t_floor = if ahead_on {
            self.pipeline.free_target
        } else {
            self.pipeline.free_target.max(self.pipeline.free_draft)
        };
        let (members, t_start) = select_cohort(&cands, t_floor, per_seq);
        if members.is_empty() {
            return Ok(());
        }

        // Assemble the op inputs; the drafts move out of their phases
        // (they return to `Ready` after the commit).
        self.scratch.seq_ids.clear();
        self.scratch.gammas.clear();
        self.scratch.temps.clear();
        self.scratch.feeds.clear();
        let mut drafts: Vec<Vec<u32>> = Vec::with_capacity(members.len());
        let mut dprobs: Vec<Vec<LogitsView>> = Vec::with_capacity(members.len());
        for &i in &members {
            let s = &self.running[i];
            self.scratch.seq_ids.push(s.id);
            self.scratch.temps.push(s.params.temperature);
            self.scratch.feeds.push(s.stream[s.base]);
            match &mut self.pipeline.phases[i] {
                Phase::Drafted { gamma, tokens, probs, .. } => {
                    self.scratch.gammas.push(*gamma);
                    drafts.push(std::mem::take(tokens));
                    dprobs.push(std::mem::take(probs));
                }
                Phase::Ready { .. } => unreachable!("cohort members are Drafted"),
            }
        }

        let verify = match self.backend.verify(
            &self.scratch.seq_ids,
            &self.scratch.feeds,
            &drafts,
            &self.scratch.temps,
        ) {
            Ok(v) => v,
            Err(e) => {
                // Roll the cohort back to its committed prefix and
                // return it to Ready (drafts discarded); the next step
                // retries the whole cycle for it.
                self.abort_members(&members);
                for &i in &members {
                    self.pipeline.phases[i] = Phase::Ready {
                        ready_at: t_start,
                        ahead: false,
                    };
                }
                return Err(e.context("target verify failed (cohort rolled back)"));
            }
        };
        self.metrics.time_verify += verify.cost;
        let rcost = self.backend.reject_cost(&self.scratch.gammas);
        self.metrics.time_reject += rcost;

        let t_end = t_start + verify.cost + rcost;
        self.pipeline.free_target = t_end;
        if !ahead_on {
            self.pipeline.free_draft = self.pipeline.free_draft.max(t_end);
        }
        self.clock = self.clock.max(t_end);
        // Each verify grants the next propose its overlap window.
        self.pipeline.ahead_budget = verify.cost;

        let b_op = members.len();
        let total_gamma: usize = self.scratch.gammas.iter().sum();
        self.metrics.rounds += 1;
        self.metrics.batch_size_sum += b_op as u64;
        for &i in &members {
            let class = self.running[i].class;
            self.metrics.class_mut(class).seq_rounds += 1;
        }

        self.scratch.finished.clear();
        self.scratch.seq_samples.clear();
        let mut round_accepted: u64 = 0;
        let mut round_emitted: u64 = 0;
        for (k, &i) in members.iter().enumerate() {
            let gamma_k = self.scratch.gammas[k];
            let seq = &mut self.running[i];
            let outcome =
                verify_chain_views(&drafts[k], &dprobs[k], &verify.probs[k], &mut self.rng);
            self.metrics.draft_tokens_accepted += outcome.accepted as u64;
            round_accepted += outcome.accepted as u64;
            round_emitted += outcome.tokens.len() as u64;
            self.scratch.seq_samples.push(crate::control::SeqRoundSample {
                seq: seq.id,
                gamma: gamma_k,
                accepted: outcome.accepted,
            });
            seq.rounds += 1;

            if seq.first_token_at.is_none() {
                seq.first_token_at = Some(self.clock);
            }

            // Commit the emitted tokens.
            seq.stream.extend_from_slice(&outcome.tokens);
            seq.base += 1 + outcome.accepted;
            self.metrics.tokens_generated += outcome.tokens.len() as u64;

            // Roll both models back to the committed prefix; the fresh
            // token (last emitted) is fed next round.
            self.backend.rollback_target(seq.id, seq.base);
            self.backend.rollback_draft(seq.id, seq.base);
            self.kv.truncate(seq.id, seq.stream.len());

            // Completion checks: EOS in the emitted tokens, or budget.
            let len_with_emitted = seq.stream.len();
            let mut done = false;
            if let Some(eos) = seq.params.eos_token {
                if let Some(pos) = outcome.tokens.iter().position(|&t| t == eos) {
                    let cut = seq.stream.len() - outcome.tokens.len() + pos + 1;
                    seq.stream.truncate(cut);
                    done = true;
                }
            }
            if seq.generated() >= seq.params.max_new_tokens {
                seq.stream
                    .truncate(seq.prompt_len + seq.params.max_new_tokens);
                done = true;
            }
            let discarded = len_with_emitted - seq.stream.len();
            self.metrics.tokens_generated -= discarded as u64;
            let class = seq.class;
            self.metrics.class_mut(class).tokens_generated +=
                (outcome.tokens.len() - discarded) as u64;

            // A fully-accepted round makes the sequence draft-ahead
            // eligible: its next proposal overlaps the next verify.
            let full = gamma_k > 0 && outcome.accepted == gamma_k;
            self.pipeline.phases[i] = Phase::Ready {
                ready_at: t_end,
                ahead: ahead_on && full,
            };
            if done {
                self.scratch.finished.push(i);
            }
        }

        // Close the control loop (per-sequence samples + round-level
        // observation; draft spend accumulated since the last verify is
        // attributed here).
        let t_draft_flush = self.pipeline.draft_cost_unreported;
        let proposed_flush = self.pipeline.proposed_unreported;
        self.pipeline.draft_cost_unreported = 0.0;
        self.pipeline.proposed_unreported = 0;
        if let Some(ctl) = self.controller.as_mut() {
            ctl.observe_sequences(&self.scratch.seq_samples);
            let rows = b_op + total_gamma;
            let gamma_obs = ((rows + b_op / 2) / b_op).saturating_sub(1);
            ctl.observe(RoundObservation {
                round: self.round_counter,
                batch: b_op,
                gamma: gamma_obs,
                proposed: proposed_flush,
                accepted: round_accepted,
                emitted: round_emitted,
                t_draft: t_draft_flush,
                t_verify: verify.cost,
                t_reject: rcost,
                budget: self.backend.verify_budget(),
            });
        }

        // Retire finished sequences (descending index for stable removal
        // from both `running` and the phase table).
        for k in (0..self.scratch.finished.len()).rev() {
            let i = self.scratch.finished[k];
            self.pipeline.phases.remove(i);
            let seq = self.running.remove(i);
            self.backend.release(seq.id);
            self.kv.release(seq.id);
            if let Some(ctl) = self.controller.as_mut() {
                ctl.release_sequence(seq.id);
            }
            self.metrics.requests_completed += 1;
            let completion = Completion {
                id: seq.id,
                tokens: seq.stream[seq.prompt_len..].to_vec(),
                arrival: seq.arrival,
                first_token_at: seq.first_token_at.unwrap_or(self.clock),
                finished_at: self.clock,
                rounds: seq.rounds,
                class: seq.class,
            };
            self.metrics.ttft.0.record(completion.ttft());
            self.metrics.tpot.0.record(completion.tpot());
            self.metrics
                .e2e_latency
                .0
                .record(completion.finished_at - completion.arrival);
            let (ttft, tpot) = (completion.ttft(), completion.tpot());
            let cm = self.metrics.class_mut(seq.class);
            cm.requests_completed += 1;
            cm.ttft.0.record(ttft);
            cm.tpot.0.record(tpot);
            if let Some(t) = self.config.tenants.get(seq.class) {
                if let Some(slo) = t.ttft_slo {
                    cm.ttft_slo_total += 1;
                    if ttft <= slo {
                        cm.ttft_slo_met += 1;
                    }
                }
                if let Some(slo) = t.tpot_slo {
                    cm.tpot_slo_total += 1;
                    if tpot <= slo {
                        cm.tpot_slo_met += 1;
                    }
                }
            }
            completions.push(completion);
        }
        Ok(())
    }

    /// Roll an op cohort back to its committed prefix after a mid-op
    /// backend failure (the continuous analogue of `abort_round`, scoped
    /// to the failed op's members).
    fn abort_members(&mut self, members: &[usize]) {
        for &i in members {
            let seq = &self.running[i];
            self.backend.rollback_target(seq.id, seq.base);
            self.backend.rollback_draft(seq.id, seq.base);
            self.kv.truncate(seq.id, seq.stream.len());
        }
        self.counters.inc("round_failures");
    }
}
