//! Op-level roofline timing simulator — the testbed substitute for the
//! paper's GPU clusters (see DESIGN.md §Substitutions).
//!
//! For a given [`ModelArch`] on a [`Platform`], it walks the decode-path
//! operators (attention, router gate, shared expert, routed experts, LM
//! head, tensor-parallel collectives) and prices each with the roofline
//! rule (Eq. 1): `time = max(flops / peak_compute, bytes / bandwidth)`.
//! The three effects §3.3 identifies fall out naturally:
//! 1. roofline ramp with token count,
//! 2. expert-activation-dependent weight traffic (Eq. 8),
//! 3. per-expert load T̄_exp rather than total tokens (Eq. 10),
//! plus GPU tile quantization [47] for the Fig. 5 sawtooth.
//!
//! ## Expert-parallel sharding
//!
//! [`ExecSim::with_sharding`] reprices the forward pass for an EP group of
//! `d` [`Platform`] ranks (§3.4's "extensive EP configurations"):
//! - non-expert work (embedding, attention, router gate, shared expert,
//!   LM head, TP collectives) is data-parallel — per-rank token count
//!   `t/d` against fully *replicated* weights, per-rank KV `B/d`;
//! - routed experts are partitioned: per-rank activation `N(t)/d`
//!   ([`theory::ep_active_experts_per_device`]) with the *global*
//!   per-expert load `T̄_exp` (the token pool is shared via all-to-all),
//!   scaled by the spec's straggler `imbalance`;
//! - dispatch/combine crosses the fabric: [`ShardingSpec::comm_time`]
//!   prices the `(d−1)/d` remote fraction on the topology's link
//!   bandwidth plus per-collective latency.
//!
//! `d = 1` takes the *identical* unsharded code path, bit-for-bit
//! (property-tested in `rust/tests/prop_invariants.rs`).
//!
//! ## Ragged verify passes
//!
//! A ragged speculative round gives every sequence its own draft length
//! γᵢ, so the verify forward processes `widths[i] = γᵢ + 1` tokens for
//! sequence `i`. The simulator prices that **packed**: the roofline cost
//! surface depends on batch and step width only through the total token
//! count `t = Σ widths` (the dense GEMM arm runs at the sum of widths and
//! the expert arm at the realized token count), so
//! [`ExecSim::t_forward_ragged`] is `t_forward_tokens(b, Σ widths)` and a
//! uniform-width call reproduces [`ExecSim::t_forward`] **bit-for-bit**
//! (property-tested in `rust/tests/prop_invariants.rs`).

pub mod routing;

use std::cell::RefCell;
use std::collections::HashMap;

use crate::arch::{Ffn, ModelArch};
use crate::hardware::{tile_quantize, Platform, ShardingSpec};
use crate::theory;
use crate::util::rng::Rng;

/// Per-component forward-pass time breakdown (seconds).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TimeBreakdown {
    pub embed: f64,
    pub attn: f64,
    /// Router gate + shared expert (always-on FFN path).
    pub ffn_dense: f64,
    /// Routed experts (the sparsity-sensitive part).
    pub ffn_experts: f64,
    pub comm: f64,
    pub head: f64,
}

impl TimeBreakdown {
    pub fn total(&self) -> f64 {
        self.embed + self.attn + self.ffn_dense + self.ffn_experts + self.comm + self.head
    }

    /// FFN share of the step — the Amdahl knob of §4.2.
    pub fn ffn_fraction(&self) -> f64 {
        (self.ffn_dense + self.ffn_experts) / self.total()
    }
}

/// How expert activation is evaluated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActivationMode {
    /// Use the closed-form expectation N(t) (Eq. 8) — deterministic.
    Expected,
    /// Sample token→expert routing (run-to-run noise, Fig. 5's per-run
    /// curves).
    Sampled,
}

/// The simulator: immutable model+platform description plus evaluation
/// options.
///
/// All price-affecting state is private and set only through `new` and the
/// cache-invalidating builder methods — prices are memoized (see
/// `price_cache`), so uncontrolled field mutation would silently serve
/// stale timings.
#[derive(Debug, Clone)]
pub struct ExecSim {
    arch: ModelArch,
    platform: Platform,
    activation: ActivationMode,
    /// Apply GEMM tile quantization (the sawtooth effect).
    tile_effects: bool,
    /// Fixed per-step launch/runtime overhead (scheduler, kernel launches).
    step_overhead: f64,
    /// Expert-parallel deployment this simulator prices. The default
    /// [`ShardingSpec::single`] keeps the original single-group path.
    sharding: ShardingSpec,
    /// Memoized rng-free forward prices keyed by (b, total new tokens,
    /// ctx, expert budget) — the cost surface depends on batch and width
    /// only through the token total, so uniform (`t_forward`) and ragged
    /// (`t_forward_ragged`) calls share entries, and budgeted/unbudgeted
    /// prices share the map (`NO_BUDGET` = `usize::MAX` is the
    /// unbudgeted column). An engine run prices thousands of rounds over
    /// a handful of distinct shapes, and the figure sweeps re-ask the
    /// same points per grid cell — re-walking the roofline each call was
    /// measurable coordinator overhead. Interior mutability keeps the
    /// pricing API `&self`; the builder methods clear the cache because
    /// prices depend on their settings.
    price_cache: RefCell<HashMap<(usize, usize, usize, usize), f64>>,
}

/// Cache-key sentinel for "no expert budget" (a real budget of
/// `usize::MAX` is indistinguishable from unbudgeted anyway: N(t) ≤ E).
const NO_BUDGET: usize = usize::MAX;

impl ExecSim {
    pub fn new(arch: ModelArch, platform: Platform) -> ExecSim {
        // Fixed per-forward overhead: kernel launches + framework
        // scheduling scale with layer count (this is what keeps small
        // draft models from being free in real serving stacks — §4.1's
        // observation that the draft's relative cost grows under TP).
        let step_overhead = 150e-6 + arch.layers as f64 * 40e-6;
        ExecSim {
            arch,
            platform,
            activation: ActivationMode::Expected,
            tile_effects: false,
            step_overhead,
            sharding: ShardingSpec::single(),
            price_cache: RefCell::new(HashMap::new()),
        }
    }

    pub fn with_activation(mut self, mode: ActivationMode) -> Self {
        self.activation = mode;
        self.price_cache.get_mut().clear();
        self
    }

    pub fn with_tile_effects(mut self, on: bool) -> Self {
        self.tile_effects = on;
        self.price_cache.get_mut().clear();
        self
    }

    /// Price forwards for an expert-parallel deployment of `spec.devices()`
    /// ranks, each a copy of this simulator's [`Platform`]. Passing
    /// [`ShardingSpec::single`] restores the unsharded path exactly.
    pub fn with_sharding(mut self, spec: ShardingSpec) -> Self {
        self.sharding = spec;
        self.price_cache.get_mut().clear();
        self
    }

    pub fn arch(&self) -> &ModelArch {
        &self.arch
    }

    pub fn platform(&self) -> &Platform {
        &self.platform
    }

    pub fn sharding(&self) -> &ShardingSpec {
        &self.sharding
    }

    /// `(E, K)` of the routed-expert gate, or `None` for dense archs —
    /// what budget-curve consumers (acceptance degradation, candidate
    /// grids) need from the target model.
    pub fn moe_dims(&self) -> Option<(usize, usize)> {
        match &self.arch.ffn {
            Ffn::Moe { experts, topk, .. } => Some((*experts, *topk)),
            Ffn::Dense { .. } => None,
        }
    }

    /// Number of activated experts for `t` tokens through one gate,
    /// optionally capped at a verify-time expert budget (`min(N(t),
    /// budget)`, the MoE-Spec knob). `budget = None` is the uncapped
    /// value bit-for-bit: `min` against `+∞` returns the finite operand
    /// unchanged, and any budget ≥ E is likewise a no-op since N(t) ≤ E.
    fn activated_experts(&self, t: u64, rng: Option<&mut Rng>, budget: Option<usize>) -> f64 {
        let cap = budget.map(|b| b as f64).unwrap_or(f64::INFINITY);
        match &self.arch.ffn {
            Ffn::Dense { .. } => 1.0,
            Ffn::Moe { experts, topk, .. } => match (self.activation, rng) {
                (ActivationMode::Expected, _) | (ActivationMode::Sampled, None) => {
                    theory::expected_active_experts(*experts, *topk, t).min(cap)
                }
                (ActivationMode::Sampled, Some(rng)) => {
                    let router = routing::Router::balanced(*experts, *topk);
                    (router.route(t, rng).activated as f64).min(cap)
                }
            },
        }
    }

    /// Effective token count for a GEMM after optional tile quantization.
    fn q(&self, tokens: f64) -> f64 {
        if self.tile_effects {
            tile_quantize(tokens, self.platform.gpu.tile)
        } else {
            tokens
        }
    }

    /// Time for one forward pass processing `s` new tokens for each of `b`
    /// sequences at context length `ctx` (decode: s = 1; SD verify: s = γ+1;
    /// prefill: s = prompt length).
    pub fn forward_time(
        &self,
        b: usize,
        s: usize,
        ctx: usize,
        rng: Option<&mut Rng>,
    ) -> TimeBreakdown {
        assert!(s > 0);
        self.forward_time_tokens(b, b * s, ctx, rng)
    }

    /// Token-count form of [`ExecSim::forward_time`]: one forward pass over
    /// `b` sequences contributing `tokens` new tokens **in total** (ragged
    /// verify passes pack per-sequence widths; `tokens = Σ(γᵢ+1)`). The
    /// roofline walk depends on `(b, s)` only through `t = b·s`, so a
    /// uniform call `forward_time(b, s, ..)` is exactly
    /// `forward_time_tokens(b, b·s, ..)` — same arithmetic, bit-for-bit.
    pub fn forward_time_tokens(
        &self,
        b: usize,
        tokens: usize,
        ctx: usize,
        rng: Option<&mut Rng>,
    ) -> TimeBreakdown {
        self.forward_time_tokens_budgeted(b, tokens, ctx, rng, None)
    }

    /// Expert-budgeted form of [`ExecSim::forward_time_tokens`]: the
    /// routed-expert arm runs at most `budget` experts (`min(N(t),
    /// budget)`, Eq. 8 capped), with per-expert load recomputed against
    /// the capped count — fewer experts each absorb more tokens, so the
    /// budget trades weight traffic for per-expert compute. Dispatch
    /// traffic is unchanged (every token is still routed, to a smaller
    /// expert set). `budget = None` and any budget ≥ E take the
    /// *identical* arithmetic path, bit-for-bit (property-tested in
    /// `rust/tests/prop_invariants.rs`).
    pub fn forward_time_tokens_budgeted(
        &self,
        b: usize,
        tokens: usize,
        ctx: usize,
        mut rng: Option<&mut Rng>,
        budget: Option<usize>,
    ) -> TimeBreakdown {
        assert!(b > 0 && tokens > 0);
        if self.sharding.is_sharded() {
            // The EP-sharded walk lives in its own function; the d = 1
            // path below stays byte-identical to the pre-sharding pricing.
            return self.forward_time_ep(b, tokens, ctx, rng, budget);
        }
        let a = &self.arch;
        let p = &self.platform;
        let t = tokens as f64;
        let tq = self.q(t);
        let dt = a.dtype_bytes;
        let h = a.hidden as f64;
        let layers = a.layers as f64;

        let mut out = TimeBreakdown::default();

        // Embedding lookup: gather t rows of the embedding table.
        out.embed = p.sharded_op_time(0.0, 0.0, t * h * dt);

        // --- per-layer costs, multiplied by layer count ---------------------

        // Attention: QKVO GEMMs (weights resident per layer) + score/PV over
        // the KV cache.
        let attn_w = a.attn_params_per_layer() as f64 * dt;
        let attn_flops = tq * a.attn_flops_per_token(ctx);
        let kv_read = (b * ctx) as f64 * a.kv_bytes_per_token() / layers;
        let act_rw = 4.0 * t * h * dt;
        out.attn = layers * p.sharded_op_time(attn_flops, attn_w, kv_read + act_rw);

        // FFN path.
        match &a.ffn {
            Ffn::Dense { inter } => {
                let w = 3.0 * h * *inter as f64 * dt;
                let flops = self.q(t) * 6.0 * h * *inter as f64;
                out.ffn_dense = layers * p.sharded_op_time(flops, w, 2.0 * t * h * dt);
            }
            Ffn::Moe {
                experts,
                topk,
                expert_inter,
                shared_inter,
            } => {
                // Router gate + shared expert: always-on dense work.
                let gate_w = h * *experts as f64 * dt;
                let gate_flops = t * 2.0 * h * *experts as f64;
                let shared_w = 3.0 * h * *shared_inter as f64 * dt;
                let shared_flops = self.q(t) * 6.0 * h * *shared_inter as f64;
                out.ffn_dense = layers
                    * (p.sharded_op_time(gate_flops, gate_w, t * h * dt)
                        + if *shared_inter > 0 {
                            p.sharded_op_time(shared_flops, shared_w, 2.0 * t * h * dt)
                        } else {
                            0.0
                        });

                // Routed experts: the §3.2 effect. Weight traffic scales
                // with the *activated* expert count N(t) — capped at the
                // verify-expert budget when one is set; compute scales
                // with per-expert load T̄_exp (tile-quantized per expert),
                // recomputed against the capped count below.
                let n_act = self.activated_experts(tokens as u64, rng.as_deref_mut(), budget);
                let expert_w = n_act * a.bytes_per_expert();
                let load = t * *topk as f64 / n_act.max(1e-9);
                let expert_flops = n_act * self.q(load) * 6.0 * h * *expert_inter as f64;
                // Dispatch/combine activation traffic: each token's hidden
                // state is scattered to K experts and gathered back.
                let dispatch = 2.0 * t * *topk as f64 * h * dt;
                out.ffn_experts =
                    layers * p.sharded_op_time(expert_flops, expert_w, dispatch);
            }
        }

        // Tensor-parallel collectives: two all-reduces per layer over the
        // token activations.
        out.comm = layers * 2.0 * p.allreduce_time(t * h * dt);

        // LM head.
        let head_w = (a.vocab as f64) * h * dt;
        let head_flops = tq * 2.0 * h * a.vocab as f64;
        out.head = p.sharded_op_time(head_flops, head_w, t * a.vocab as f64 * dt);

        out.embed += self.step_overhead;
        out
    }

    /// Expert-parallel variant of [`ExecSim::forward_time_tokens`]: `d`
    /// ranks, each this simulator's full [`Platform`]. Dense/attention
    /// work is data-parallel (`t/d` tokens per rank against replicated
    /// weights), routed experts are partitioned (`N(t)/d` activated per
    /// rank at the *global* per-expert load), and dispatch/combine pays
    /// the fabric ([`ShardingSpec::comm_time`]). The spec's `imbalance`
    /// multiplies the expert arm — the round completes when the straggler
    /// rank does. `tokens` is the packed total (b·s uniform, Σ(γᵢ+1)
    /// ragged).
    fn forward_time_ep(
        &self,
        b: usize,
        tokens: usize,
        ctx: usize,
        mut rng: Option<&mut Rng>,
        budget: Option<usize>,
    ) -> TimeBreakdown {
        let a = &self.arch;
        let p = &self.platform;
        let spec = &self.sharding;
        let d = spec.devices() as f64;
        let t = tokens as f64;
        let td = t / d; // per-rank token share (data parallel)
        let bd = b as f64 / d; // per-rank resident sequences
        let dt = a.dtype_bytes;
        let h = a.hidden as f64;
        let layers = a.layers as f64;

        let mut out = TimeBreakdown::default();

        // Embedding: each rank gathers rows for its own token share.
        out.embed = p.sharded_op_time(0.0, 0.0, td * h * dt);

        // Attention: weights fully replicated per rank (EP shards experts,
        // not attention), so the weight-load term does NOT divide by d —
        // this is what keeps small-EP-batch ranks memory-bound and SD
        // cheap to verify (§3.4).
        let attn_w = a.attn_params_per_layer() as f64 * dt;
        let attn_flops = self.q(td) * a.attn_flops_per_token(ctx);
        let kv_read = bd * ctx as f64 * a.kv_bytes_per_token() / layers;
        let act_rw = 4.0 * td * h * dt;
        out.attn = layers * p.sharded_op_time(attn_flops, attn_w, kv_read + act_rw);

        match &a.ffn {
            Ffn::Dense { inter } => {
                // EP of a dense model degenerates to plain data
                // parallelism over replicas.
                let w = 3.0 * h * *inter as f64 * dt;
                let flops = self.q(td) * 6.0 * h * *inter as f64;
                out.ffn_dense = layers * p.sharded_op_time(flops, w, 2.0 * td * h * dt);
            }
            Ffn::Moe {
                experts,
                topk,
                expert_inter,
                shared_inter,
            } => {
                // Router gate + shared expert: replicated, data-parallel.
                let gate_w = h * *experts as f64 * dt;
                let gate_flops = td * 2.0 * h * *experts as f64;
                let shared_w = 3.0 * h * *shared_inter as f64 * dt;
                let shared_flops = self.q(td) * 6.0 * h * *shared_inter as f64;
                out.ffn_dense = layers
                    * (p.sharded_op_time(gate_flops, gate_w, td * h * dt)
                        + if *shared_inter > 0 {
                            p.sharded_op_time(shared_flops, shared_w, 2.0 * td * h * dt)
                        } else {
                            0.0
                        });

                // Routed experts, the EP payoff: activation is computed on
                // the *global* token pool (every token can reach every
                // expert through the all-to-all), then splits evenly —
                // N(t)/d experts and their weights per rank (Expected mode
                // equals `theory::ep_active_experts_per_device`; Sampled
                // mode divides the sampled global draw the same way) —
                // while the per-expert load T̄_exp = t·K/N(t) is
                // d-invariant, so the arithmetic-intensity structure of
                // §3.2 survives sharding. A verify-expert budget caps the
                // *global* activation before the per-rank split.
                let n_act = self.activated_experts(tokens as u64, rng.as_deref_mut(), budget);
                let n_rank = n_act / d;
                let expert_w = n_rank * a.bytes_per_expert();
                let load = t * *topk as f64 / n_act.max(1e-9);
                let expert_flops = n_rank * self.q(load) * 6.0 * h * *expert_inter as f64;
                // Per-rank dispatch/combine HBM traffic for its t·K/d
                // token→expert assignments.
                let dispatch = 2.0 * (t * *topk as f64 / d) * h * dt;
                out.ffn_experts = layers
                    * spec.imbalance
                    * p.sharded_op_time(expert_flops, expert_w, dispatch);
            }
        }

        // Intra-rank TP all-reduces on the rank's token share, plus the
        // inter-rank EP all-to-all (dispatch + combine per MoE layer).
        out.comm = layers * 2.0 * p.allreduce_time(td * h * dt) + spec.comm_time(t);

        // LM head: replicated, data-parallel.
        let head_w = (a.vocab as f64) * h * dt;
        let head_flops = self.q(td) * 2.0 * h * a.vocab as f64;
        out.head = p.sharded_op_time(head_flops, head_w, td * a.vocab as f64 * dt);

        out.embed += self.step_overhead;
        out
    }

    /// T_T(B, s) — the scalar the paper's equations use. Without an RNG
    /// the walk is deterministic in (b, total tokens, ctx)
    /// (sampled-activation mode falls back to the Eq. 8 expectation), so
    /// results are memoized.
    pub fn t_forward(&self, b: usize, s: usize, ctx: usize) -> f64 {
        self.t_forward_tokens(b, b * s, ctx)
    }

    /// Memoized token-count form of [`ExecSim::t_forward`] — the price of
    /// one forward over `b` sequences and `tokens` packed new tokens
    /// (shares the cache with the uniform entry point: the surface only
    /// depends on the total).
    pub fn t_forward_tokens(&self, b: usize, tokens: usize, ctx: usize) -> f64 {
        self.t_forward_tokens_budgeted(b, tokens, ctx, None)
    }

    /// Memoized expert-budgeted forward price (see
    /// [`ExecSim::forward_time_tokens_budgeted`]). Budgeted and
    /// unbudgeted prices share one cache, keyed by the budget (the
    /// `NO_BUDGET` sentinel for `None`), and one arithmetic path — so
    /// `budget = None` is the unbudgeted price bit-for-bit.
    pub fn t_forward_tokens_budgeted(
        &self,
        b: usize,
        tokens: usize,
        ctx: usize,
        budget: Option<usize>,
    ) -> f64 {
        let key = (b, tokens, ctx, budget.unwrap_or(NO_BUDGET));
        if let Some(&t) = self.price_cache.borrow().get(&key) {
            return t;
        }
        let t = self
            .forward_time_tokens_budgeted(b, tokens, ctx, None, budget)
            .total();
        self.price_cache.borrow_mut().insert(key, t);
        t
    }

    /// Expert-budgeted uniform verify price: `t_forward(b, s, ctx)` with
    /// the routed-expert arm capped at `budget` experts.
    pub fn t_forward_budgeted(&self, b: usize, s: usize, ctx: usize, budget: Option<usize>) -> f64 {
        self.t_forward_tokens_budgeted(b, b * s, ctx, budget)
    }

    /// Expert-budgeted ragged verify price (packed, like
    /// [`ExecSim::t_forward_ragged`]).
    pub fn t_forward_ragged_budgeted(
        &self,
        widths: &[usize],
        ctx: usize,
        budget: Option<usize>,
    ) -> f64 {
        assert!(!widths.is_empty(), "ragged forward needs at least one sequence");
        self.t_forward_tokens_budgeted(widths.len(), widths.iter().sum(), ctx, budget)
    }

    /// Price a ragged verify pass: sequence `i` contributes `widths[i]`
    /// new tokens (γᵢ + 1 in an SD round). Packed pricing — the dense arm
    /// runs at the sum of widths, the expert arm at the realized token
    /// count — so uniform widths reproduce `t_forward(b, s, ctx)`
    /// bit-for-bit.
    pub fn t_forward_ragged(&self, widths: &[usize], ctx: usize) -> f64 {
        assert!(!widths.is_empty(), "ragged forward needs at least one sequence");
        self.t_forward_tokens(widths.len(), widths.iter().sum(), ctx)
    }

    /// Rejection-sampling stage cost (§3.1 stage ③): reading B·(γ+1) logit
    /// rows plus a fixed launch overhead. Much smaller than a model forward.
    pub fn t_reject(&self, b: usize, gamma: usize) -> f64 {
        self.t_reject_rows(b * (gamma + 1))
    }

    /// Row-count form of [`ExecSim::t_reject`] for ragged rounds, where
    /// the sampler reads `Σ(γᵢ+1)` logit rows. The uniform call is
    /// `t_reject_rows(b·(γ+1))` — identical arithmetic.
    pub fn t_reject_rows(&self, rows: usize) -> f64 {
        let rows = rows as f64;
        let bytes = rows * self.arch.vocab as f64 * 4.0; // f32 logits
        40e-6 + bytes / self.platform.total_mem_bw()
    }

    /// Target efficiency T_T(B,1)/T_T(B,γ) at context `ctx` (§3.1).
    pub fn target_efficiency(&self, b: usize, gamma: usize, ctx: usize) -> f64 {
        theory::target_efficiency(self.t_forward(b, 1, ctx), self.t_forward(b, gamma + 1, ctx))
    }

    /// Budgeted target efficiency: the AR decode numerator stays
    /// unbudgeted (the baseline never runs a capped gate), only the
    /// verify denominator is budget-priced.
    pub fn target_efficiency_budgeted(
        &self,
        b: usize,
        gamma: usize,
        ctx: usize,
        budget: Option<usize>,
    ) -> f64 {
        theory::target_efficiency(
            self.t_forward(b, 1, ctx),
            self.t_forward_budgeted(b, gamma + 1, ctx, budget),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::hardware::{platform_2x_gpu_a, platform_2x_gpu_b, Platform};

    fn qwen_sim() -> ExecSim {
        ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a())
    }

    fn dense_sim() -> ExecSim {
        ExecSim::new(presets::opt_30b(), platform_2x_gpu_a())
    }

    #[test]
    fn forward_time_positive_and_monotone_in_batch() {
        let sim = qwen_sim();
        let mut prev = 0.0;
        for b in [1usize, 2, 4, 8, 16, 32, 64, 128] {
            let t = sim.t_forward(b, 1, 512);
            assert!(t > prev, "T(B,1) should grow with B: b={b} t={t} prev={prev}");
            prev = t;
        }
    }

    #[test]
    fn small_batch_verify_costs_more_for_moe() {
        // §3.1 factor (2): at B=1, verifying γ tokens loads more experts.
        let sim = qwen_sim();
        let t1 = sim.t_forward(1, 1, 512);
        let t4 = sim.t_forward(1, 4, 512);
        assert!(
            t4 > 1.15 * t1,
            "B=1 verify should cost visibly more: {t1} vs {t4}"
        );
    }

    #[test]
    fn moderate_batch_verify_is_nearly_free_for_moe() {
        // §3.2: past T_thres (~24 for ρ=1/8, τ=0.95), all experts load
        // anyway and the system is memory-bound → T(B,γ) ≈ T(B,1).
        let sim = qwen_sim();
        let b = 32;
        let eff = sim.target_efficiency(b, 3, 512);
        assert!(eff > 0.8, "target efficiency at moderate B: {eff}");
    }

    #[test]
    fn large_batch_becomes_compute_bound() {
        let sim = qwen_sim();
        let eff = sim.target_efficiency(2048, 3, 512);
        assert!(
            eff < 0.45,
            "very large batch should be compute-bound: eff={eff}"
        );
    }

    #[test]
    fn moe_target_efficiency_rises_then_falls_dense_only_falls() {
        // The Fig. 3 contrast, asserted qualitatively.
        let moe = qwen_sim();
        let dense = dense_sim();
        let batches = [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024];
        let moe_eff: Vec<f64> = batches
            .iter()
            .map(|&b| moe.target_efficiency(b, 3, 512))
            .collect();
        let dense_eff: Vec<f64> = batches
            .iter()
            .map(|&b| dense.target_efficiency(b, 3, 512))
            .collect();
        // MoE: the max is strictly inside the sweep and above the B=1 value.
        let peak = crate::util::stats::argmax(&moe_eff);
        assert!(peak > 0, "MoE efficiency should rise first: {moe_eff:?}");
        assert!(
            moe_eff[peak] > moe_eff[0] + 0.05,
            "MoE peak should beat B=1: {moe_eff:?}"
        );
        assert!(
            moe_eff[peak] > *moe_eff.last().unwrap(),
            "MoE efficiency should fall at large B: {moe_eff:?}"
        );
        // Dense: monotone non-increasing (within tolerance).
        for w in dense_eff.windows(2) {
            assert!(
                w[1] <= w[0] + 0.02,
                "dense efficiency should not rise: {dense_eff:?}"
            );
        }
    }

    #[test]
    fn sparser_moe_peaks_at_larger_batch() {
        // §4.2 observation: smaller ρ ⇒ peak batch size grows.
        let arch = presets::qwen2_57b_a14b();
        let batches: Vec<usize> = (0..14).map(|i| 1usize << i).collect();
        let peak_b = |k: usize| -> usize {
            let sim = ExecSim::new(arch.with_topk(k), platform_2x_gpu_a());
            let eff: Vec<f64> = batches
                .iter()
                .map(|&b| sim.target_efficiency(b, 3, 512))
                .collect();
            batches[crate::util::stats::argmax(&eff)]
        };
        // K=4 vs K=8: the paper's §4.2 shift (very sparse K=1,2 instead
        // decay continuously — the Amdahl anomaly, asserted in fig4).
        let p8 = peak_b(8);
        let p4 = peak_b(4);
        assert!(
            p4 >= p8,
            "sparser (K=4) should peak at >= batch than K=8: {p4} vs {p8}"
        );
    }

    #[test]
    fn ffn_dominates_for_k8_but_not_k1() {
        // §4.2's Amdahl explanation for the K=1/K=2 anomaly.
        let arch = presets::qwen2_57b_a14b();
        let sim8 = ExecSim::new(arch.clone(), platform_2x_gpu_a());
        let sim1 = ExecSim::new(arch.with_topk(1), platform_2x_gpu_a());
        let f8 = sim8.forward_time(32, 1, 512, None).ffn_fraction();
        let f1 = sim1.forward_time(32, 1, 512, None).ffn_fraction();
        assert!(f8 > f1, "K=8 FFN share {f8} should exceed K=1 share {f1}");
    }

    #[test]
    fn t_forward_memoization_is_transparent() {
        let sim = qwen_sim();
        let fresh = sim.forward_time(16, 4, 512, None).total();
        let a = sim.t_forward(16, 4, 512);
        let b = sim.t_forward(16, 4, 512); // cache hit
        assert_eq!(a, fresh);
        assert_eq!(a, b);
        // Builder methods invalidate: the tiled price differs from the
        // untiled one but still matches its own fresh walk.
        let tiled = sim.clone().with_tile_effects(true);
        assert_eq!(
            tiled.t_forward(63, 1, 512),
            tiled.forward_time(63, 1, 512, None).total()
        );
    }

    #[test]
    fn reject_time_is_small_and_scales() {
        let sim = qwen_sim();
        let r = sim.t_reject(16, 3);
        assert!(r < 0.1 * sim.t_forward(16, 1, 512));
        assert!(sim.t_reject(32, 3) > sim.t_reject(1, 3));
        // Ragged row-count form: uniform rows reproduce t_reject exactly.
        assert_eq!(sim.t_reject_rows(16 * 4), sim.t_reject(16, 3));
        assert!(sim.t_reject_rows(10) < sim.t_reject_rows(100));
    }

    #[test]
    fn ragged_uniform_widths_price_bit_identical() {
        // The ragged-verify pricing contract: uniform widths are exactly
        // the scalar path, for MoE and dense archs, sharded and not.
        let arch = presets::qwen2_57b_a14b();
        let sims = [
            qwen_sim(),
            dense_sim(),
            qwen_sim().with_tile_effects(true),
            qwen_sim().with_sharding(crate::hardware::ShardingSpec::for_arch(
                crate::hardware::Topology::nvlink(4),
                &arch,
            )),
        ];
        for sim in &sims {
            for (b, s) in [(1usize, 1usize), (4, 4), (16, 5), (128, 3)] {
                let widths = vec![s; b];
                assert_eq!(
                    sim.t_forward_ragged(&widths, 512),
                    sim.t_forward(b, s, 512),
                    "uniform ragged must equal scalar at b={b} s={s}"
                );
            }
        }
    }

    #[test]
    fn ragged_mixed_widths_price_between_uniform_extremes() {
        let sim = qwen_sim();
        // 4 sequences at widths {1, 1, 5, 5} — total 12 tokens — must cost
        // the same as any packing with the same total, and sit strictly
        // between the all-1 and all-5 uniform rounds.
        let mixed = sim.t_forward_ragged(&[1, 1, 5, 5], 512);
        assert_eq!(mixed, sim.t_forward_ragged(&[5, 1, 5, 1], 512));
        assert_eq!(mixed, sim.t_forward_tokens(4, 12, 512));
        let lo = sim.t_forward(4, 1, 512);
        let hi = sim.t_forward(4, 5, 512);
        assert!(lo < mixed && mixed < hi, "{lo} < {mixed} < {hi}");
    }

    #[test]
    fn tile_effects_create_sawtooth() {
        let sim = qwen_sim().with_tile_effects(true);
        // Crossing a tile boundary bumps time; staying inside does not add
        // compute cost (in the compute-bound regime).
        let t63 = sim.t_forward(63, 1, 512);
        let t64 = sim.t_forward(64, 1, 512);
        let t65 = sim.t_forward(65, 1, 512);
        let bump_inside = (t64 - t63).abs();
        let bump_cross = t65 - t64;
        assert!(
            bump_cross >= bump_inside,
            "tile crossing should dominate: inside={bump_inside} cross={bump_cross}"
        );
    }

    #[test]
    fn sampled_activation_is_noisy_but_unbiased() {
        let mut rng = Rng::seeded(7);
        let sim = qwen_sim().with_activation(ActivationMode::Sampled);
        let n = 40;
        let ts: Vec<f64> = (0..n)
            .map(|_| sim.forward_time(12, 4, 512, Some(&mut rng)).total())
            .collect();
        let expected = qwen_sim().t_forward(12, 4, 512);
        let mean = crate::util::stats::mean(&ts);
        assert!(
            (mean - expected).abs() / expected < 0.05,
            "sampled mean {mean} vs expected {expected}"
        );
        assert!(crate::util::stats::stddev(&ts) > 0.0);
    }

    #[test]
    fn ep_single_rank_spec_is_identical_path() {
        use crate::hardware::{ShardingSpec, Topology};
        let base = qwen_sim();
        let single = qwen_sim().with_sharding(ShardingSpec::single());
        // Also a 1-rank "nvlink" topology: devices == 1 must short-circuit.
        let arch = presets::qwen2_57b_a14b();
        let one = qwen_sim().with_sharding(ShardingSpec::for_arch(Topology::nvlink(1), &arch));
        for (b, s) in [(1usize, 1usize), (8, 4), (32, 5), (256, 1), (1024, 4)] {
            let want = base.t_forward(b, s, 512);
            assert_eq!(single.t_forward(b, s, 512), want, "single spec B={b} s={s}");
            assert_eq!(one.t_forward(b, s, 512), want, "1-rank topo B={b} s={s}");
        }
    }

    #[test]
    fn ep_lifts_target_efficiency_monotonically() {
        use crate::hardware::{ShardingSpec, Topology};
        // Validated against the python replica of this pricing model:
        // teff(B, γ=3) rises with EP degree at every batch size (per-rank
        // dense work shrinks as B/d while replicated weights keep ranks
        // memory-bound; constants dilute the verify-term growth).
        let arch = presets::qwen2_57b_a14b();
        let sims: Vec<ExecSim> = [1usize, 2, 4, 8]
            .iter()
            .map(|&d| {
                qwen_sim().with_sharding(ShardingSpec::for_arch(Topology::nvlink(d), &arch))
            })
            .collect();
        for b in [1usize, 4, 16, 64, 256, 1024, 4096] {
            let effs: Vec<f64> = sims.iter().map(|s| s.target_efficiency(b, 3, 512)).collect();
            for w in effs.windows(2) {
                assert!(
                    w[1] >= w[0] - 1e-9,
                    "teff must not drop with EP degree at B={b}: {effs:?}"
                );
            }
        }
        // §3.4's claim that the small-batch inefficiency "may vanish":
        // B=1, γ=4 efficiency climbs from ~0.48 unsharded to ~0.84 at d=8.
        let e1 = sims[0].target_efficiency(1, 4, 512);
        let e8 = sims[3].target_efficiency(1, 4, 512);
        assert!(e1 < 0.55, "unsharded B=1 teff should be poor: {e1}");
        assert!(e8 > 0.80, "8-way EP should nearly erase it: {e8}");
    }

    #[test]
    fn ep_absolute_forward_time_shrinks() {
        use crate::hardware::{ShardingSpec, Topology};
        let arch = presets::qwen2_57b_a14b();
        let base = qwen_sim();
        let nv4 = qwen_sim().with_sharding(ShardingSpec::for_arch(Topology::nvlink(4), &arch));
        let pc4 = qwen_sim().with_sharding(ShardingSpec::for_arch(Topology::pcie(4), &arch));
        for b in [1usize, 32, 256, 1024] {
            let t0 = base.t_forward(b, 1, 512);
            let t4 = nv4.t_forward(b, 1, 512);
            let tp = pc4.t_forward(b, 1, 512);
            assert!(t4 < t0, "4-way EP must be absolutely faster at B={b}: {t4} vs {t0}");
            assert!(tp < t0, "even PCIe EP beats one rank at B={b}: {tp} vs {t0}");
            assert!(tp >= t4, "PCIe pays more fabric than NVLink at B={b}");
        }
    }

    #[test]
    fn ep_communication_bound_fabric_hurts_efficiency() {
        use crate::hardware::{ShardingSpec, Topology};
        let arch = presets::qwen2_57b_a14b();
        let nv = qwen_sim().with_sharding(ShardingSpec::for_arch(Topology::nvlink(4), &arch));
        let pc = qwen_sim().with_sharding(ShardingSpec::for_arch(Topology::pcie(4), &arch));
        // All-to-all traffic scales with the verified token count, so a
        // slow fabric behaves compute-bound-like and drags teff down
        // (validated: 0.885 vs 0.930 at B=16, 0.81 vs 0.96 at B=64).
        for b in [16usize, 32, 64, 128] {
            let e_nv = nv.target_efficiency(b, 3, 512);
            let e_pc = pc.target_efficiency(b, 3, 512);
            assert!(
                e_pc < e_nv,
                "PCIe fabric should cost target efficiency at B={b}: {e_pc} vs {e_nv}"
            );
        }
        // The comm component itself is visibly larger.
        let c_nv = nv.forward_time(64, 4, 512, None).comm;
        let c_pc = pc.forward_time(64, 4, 512, None).comm;
        assert!(c_pc > 3.0 * c_nv, "comm {c_pc} vs {c_nv}");
    }

    #[test]
    fn ep_imbalance_slows_the_expert_arm_only() {
        use crate::hardware::{ShardingSpec, Topology};
        let arch = presets::qwen2_57b_a14b();
        let spec = ShardingSpec::for_arch(Topology::nvlink(4), &arch);
        let balanced = qwen_sim().with_sharding(spec.clone());
        let skewed = qwen_sim().with_sharding(spec.with_imbalance(1.5));
        let tb = balanced.forward_time(32, 4, 512, None);
        let ts = skewed.forward_time(32, 4, 512, None);
        assert!(
            (ts.ffn_experts / tb.ffn_experts - 1.5).abs() < 1e-9,
            "straggler factor scales the expert arm: {} vs {}",
            ts.ffn_experts,
            tb.ffn_experts
        );
        assert_eq!(ts.attn, tb.attn);
        assert_eq!(ts.ffn_dense, tb.ffn_dense);
        assert!(ts.total() > tb.total());
    }

    #[test]
    fn ep_sampled_activation_stays_unbiased() {
        use crate::hardware::{ShardingSpec, Topology};
        let arch = presets::qwen2_57b_a14b();
        let spec = ShardingSpec::for_arch(Topology::nvlink(4), &arch);
        let mut rng = Rng::seeded(11);
        let noisy = qwen_sim()
            .with_sharding(spec.clone())
            .with_activation(ActivationMode::Sampled);
        let expected = qwen_sim().with_sharding(spec).t_forward(12, 4, 512);
        let ts: Vec<f64> = (0..40)
            .map(|_| noisy.forward_time(12, 4, 512, Some(&mut rng)).total())
            .collect();
        let mean = crate::util::stats::mean(&ts);
        assert!(
            (mean - expected).abs() / expected < 0.05,
            "sharded sampled mean {mean} vs expected {expected}"
        );
        assert!(crate::util::stats::stddev(&ts) > 0.0);
    }

    #[test]
    fn offload_platform_is_more_memory_bound() {
        // §3.4: offloading degrades weight bandwidth → verification becomes
        // cheaper *relative* to decode (higher target efficiency).
        let arch = presets::qwen2_57b_a14b();
        let normal = ExecSim::new(arch.clone(), platform_2x_gpu_a());
        let offload = ExecSim::new(
            arch,
            platform_2x_gpu_a().with_offload(30e9),
        );
        let b = 256; // a batch where the normal platform is compute-leaning
        let eff_n = normal.target_efficiency(b, 3, 512);
        let eff_o = offload.target_efficiency(b, 3, 512);
        assert!(
            eff_o > eff_n,
            "offload should raise target efficiency at B={b}: {eff_o} vs {eff_n}"
        );
    }

    #[test]
    fn higher_ridge_point_gpu_keeps_efficiency_longer() {
        // §4.1 obs (1): GPU-B (higher RP) sustains target efficiency to
        // larger batches than GPU-A.
        let arch = presets::qwen2_57b_a14b();
        let a = ExecSim::new(arch.clone(), platform_2x_gpu_a());
        let b = ExecSim::new(arch, platform_2x_gpu_b());
        let batch = 512;
        assert!(
            b.target_efficiency(batch, 3, 512) > a.target_efficiency(batch, 3, 512),
            "GPU-B should hold efficiency at B={batch}"
        );
    }

    #[test]
    fn budget_off_switch_prices_bit_identical() {
        use crate::hardware::{ShardingSpec, Topology};
        // budget=None and budget ≥ E must be the unbudgeted price
        // bit-for-bit, for MoE and dense archs, tiled, and EP-sharded.
        let arch = presets::qwen2_57b_a14b();
        let e = 64; // qwen2_57b_a14b expert count
        let sims = [
            qwen_sim(),
            dense_sim(),
            qwen_sim().with_tile_effects(true),
            qwen_sim().with_sharding(ShardingSpec::for_arch(Topology::nvlink(4), &arch)),
        ];
        for sim in &sims {
            for (b, s) in [(1usize, 1usize), (4, 4), (16, 5), (128, 3)] {
                let want = sim.t_forward(b, s, 512);
                assert_eq!(sim.t_forward_budgeted(b, s, 512, None), want);
                assert_eq!(sim.t_forward_budgeted(b, s, 512, Some(e)), want);
                assert_eq!(sim.t_forward_budgeted(b, s, 512, Some(e + 100)), want);
            }
        }
    }

    #[test]
    fn tight_budget_cheapens_the_verify() {
        // At a small batch the verify is expert-weight-bound (§3.2), so
        // capping the activated experts must strictly cut the price, and
        // tighter caps cut more.
        let sim = qwen_sim();
        let (b, s) = (4usize, 7usize); // t = 28 → N(t) ≈ 62.5 of 64
        let full = sim.t_forward(b, s, 512);
        let b32 = sim.t_forward_budgeted(b, s, 512, Some(32));
        let b16 = sim.t_forward_budgeted(b, s, 512, Some(16));
        assert!(b32 < full, "budget 32 must cut the verify: {b32} vs {full}");
        assert!(b16 < b32, "tighter budget cuts more: {b16} vs {b32}");
        // The expert arm specifically shrinks; dense arms are untouched.
        let tf = sim.forward_time_tokens_budgeted(b, b * s, 512, None, None);
        let tb = sim.forward_time_tokens_budgeted(b, b * s, 512, None, Some(16));
        assert!(tb.ffn_experts < tf.ffn_experts);
        assert_eq!(tb.attn, tf.attn);
        assert_eq!(tb.ffn_dense, tf.ffn_dense);
        assert_eq!(tb.head, tf.head);
    }

    #[test]
    fn budgeted_ragged_uniform_matches_scalar() {
        let sim = qwen_sim();
        let widths = vec![4usize; 8];
        assert_eq!(
            sim.t_forward_ragged_budgeted(&widths, 512, Some(24)),
            sim.t_forward_budgeted(8, 4, 512, Some(24))
        );
        assert_eq!(
            sim.t_forward_ragged_budgeted(&widths, 512, None),
            sim.t_forward_ragged(&widths, 512)
        );
    }

    #[test]
    fn moe_dims_reports_gate_shape() {
        assert_eq!(qwen_sim().moe_dims(), Some((64, 8)));
        assert_eq!(dense_sim().moe_dims(), None);
    }

    #[test]
    fn dense_draft_is_fast_relative_to_target() {
        let target = qwen_sim();
        let draft = ExecSim::new(presets::qwen2_0_5b(), Platform::new(crate::hardware::gpu_a(), 1, 300e9));
        let ratio = draft.t_forward(8, 1, 512) / target.t_forward(8, 1, 512);
        assert!(ratio < 0.35, "draft/target time ratio {ratio}");
    }
}
