//! Expert-routing simulation.
//!
//! The paper derives N(t) (Eq. 8) under i.i.d. uniform routing and verifies
//! it against real gate traces (Fig. 1a/b). We reproduce the "actual" side
//! by sampling token→expert assignments from a router distribution that can
//! be uniform (well-balanced, the paper's assumption for modern MoEs) or
//! skewed via a Dirichlet prior (to study imbalance, which the paper notes
//! breaks the derivation).

use crate::util::rng::Rng;

/// A sampled routing outcome for a batch of tokens through one MoE gate.
#[derive(Debug, Clone)]
pub struct RoutingOutcome {
    /// Tokens assigned to each expert (length E); sums to t·K.
    pub tokens_per_expert: Vec<u64>,
    /// Number of experts with at least one token.
    pub activated: usize,
}

impl RoutingOutcome {
    /// Average tokens per *activated* expert — the empirical T̄_exp.
    pub fn mean_load(&self) -> f64 {
        if self.activated == 0 {
            return 0.0;
        }
        let total: u64 = self.tokens_per_expert.iter().sum();
        total as f64 / self.activated as f64
    }

    /// Max tokens on any expert (the straggler that sets MoE GEMM time when
    /// experts execute as a grouped GEMM).
    pub fn max_load(&self) -> u64 {
        self.tokens_per_expert.iter().copied().max().unwrap_or(0)
    }
}

/// Router model: per-expert selection propensities.
#[derive(Debug, Clone)]
pub struct Router {
    /// Unnormalized expert weights (length E). Uniform ⇒ balanced routing.
    weights: Vec<f64>,
    topk: usize,
}

impl Router {
    /// Perfectly balanced router (the paper's modeling assumption for
    /// well-trained MoEs with aux-loss balancing).
    pub fn balanced(experts: usize, topk: usize) -> Router {
        assert!(topk >= 1 && topk <= experts);
        Router {
            weights: vec![1.0; experts],
            topk,
        }
    }

    /// Imbalanced router: propensities drawn from a symmetric
    /// Dirichlet(alpha). Small alpha ⇒ heavy skew (routing collapse regime).
    pub fn imbalanced(experts: usize, topk: usize, alpha: f64, rng: &mut Rng) -> Router {
        assert!(topk >= 1 && topk <= experts);
        Router {
            weights: rng.dirichlet(alpha, experts),
            topk,
        }
    }

    pub fn experts(&self) -> usize {
        self.weights.len()
    }

    pub fn topk(&self) -> usize {
        self.topk
    }

    /// Route `t` tokens; each token picks `topk` distinct experts.
    pub fn route(&self, t: u64, rng: &mut Rng) -> RoutingOutcome {
        let mut tokens_per_expert = vec![0u64; self.weights.len()];
        for _ in 0..t {
            for idx in rng.categorical_k(&self.weights, self.topk) {
                tokens_per_expert[idx] += 1;
            }
        }
        let activated = tokens_per_expert.iter().filter(|&&c| c > 0).count();
        RoutingOutcome {
            tokens_per_expert,
            activated,
        }
    }

    /// Monte-Carlo estimate of E[N(t)] with `trials` independent batches —
    /// the "actual" curve of Fig. 1a/b.
    pub fn empirical_activation(&self, t: u64, trials: usize, rng: &mut Rng) -> f64 {
        let mut total = 0usize;
        for _ in 0..trials {
            total += self.route(t, rng).activated;
        }
        total as f64 / trials as f64
    }

    /// Empirical mean tokens per activated expert over `trials`.
    pub fn empirical_load(&self, t: u64, trials: usize, rng: &mut Rng) -> f64 {
        let mut total = 0.0;
        for _ in 0..trials {
            total += self.route(t, rng).mean_load();
        }
        total / trials as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::theory::{expected_active_experts, expert_load};

    #[test]
    fn route_conserves_token_assignments() {
        let mut rng = Rng::seeded(1);
        let r = Router::balanced(16, 3);
        let out = r.route(50, &mut rng);
        let total: u64 = out.tokens_per_expert.iter().sum();
        assert_eq!(total, 150);
        assert!(out.activated <= 16);
        assert!(out.activated >= 3);
    }

    #[test]
    fn balanced_routing_matches_eq8() {
        // Fig. 1a/b's claim: the i.i.d. derivation matches sampled routing.
        let mut rng = Rng::seeded(2);
        let r = Router::balanced(62, 6);
        for &t in &[1u64, 4, 16, 64, 128] {
            let emp = r.empirical_activation(t, 400, &mut rng);
            let theory = expected_active_experts(62, 6, t);
            assert!(
                (emp - theory).abs() < 0.05 * 62.0,
                "t={t}: empirical {emp} vs theory {theory}"
            );
        }
    }

    #[test]
    fn topk_distinctness_bounds_single_token() {
        let mut rng = Rng::seeded(3);
        let r = Router::balanced(8, 8);
        let out = r.route(1, &mut rng);
        assert_eq!(out.activated, 8); // K = E activates everything.
    }

    #[test]
    fn empirical_load_matches_eq10() {
        let mut rng = Rng::seeded(4);
        let r = Router::balanced(60, 4);
        for &t in &[2u64, 8, 32, 128] {
            let emp = r.empirical_load(t, 400, &mut rng);
            let theory = expert_load(t as f64, 4.0 / 60.0);
            // Eq. 10 uses E[sum]/E[count]; the per-trial ratio mean is close
            // but not identical — allow a modest tolerance.
            assert!(
                (emp - theory).abs() / theory < 0.08,
                "t={t}: empirical {emp} vs theory {theory}"
            );
        }
    }

    #[test]
    fn imbalanced_router_activates_fewer_experts() {
        let mut rng = Rng::seeded(5);
        let balanced = Router::balanced(64, 8);
        let skewed = Router::imbalanced(64, 8, 0.05, &mut rng);
        let t = 24;
        let nb = balanced.empirical_activation(t, 300, &mut rng);
        let ns = skewed.empirical_activation(t, 300, &mut rng);
        assert!(
            ns < nb - 2.0,
            "skewed routing should activate fewer experts: {ns} vs {nb}"
        );
    }
}
