//! Byte-level tokenizer for the tiny real model.
//!
//! The AOT-compiled `MoesdNet` uses a 256-entry vocabulary: token id =
//! byte value. Ids 0 and 1 are reserved by the training corpus generator
//! as BOS/EOS (the corpus is ASCII text, so bytes 0/1 never occur in
//! content). Must agree with `python/compile/corpus.py`.

pub const VOCAB: usize = 256;
pub const BOS: u32 = 1;
pub const EOS: u32 = 0;

/// Encode text to token ids (bytes), with optional BOS prefix.
pub fn encode(text: &str, add_bos: bool) -> Vec<u32> {
    let mut out = Vec::with_capacity(text.len() + 1);
    if add_bos {
        out.push(BOS);
    }
    out.extend(text.bytes().map(|b| b as u32));
    out
}

/// Decode token ids back to text; control tokens and non-UTF8 bytes are
/// rendered as escapes (lossy but total).
pub fn decode(tokens: &[u32]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .filter(|&&t| t != BOS && t != EOS)
        .map(|&t| (t & 0xff) as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let text = "GET /metrics 200 17ms";
        let toks = encode(text, true);
        assert_eq!(toks[0], BOS);
        assert_eq!(toks.len(), text.len() + 1);
        assert_eq!(decode(&toks), text);
    }

    #[test]
    fn tokens_fit_vocab() {
        for t in encode("hello \x7f", false) {
            assert!((t as usize) < VOCAB);
        }
    }

    #[test]
    fn decode_skips_specials() {
        assert_eq!(decode(&[BOS, 104, 105, EOS]), "hi");
    }
}
