//! Speculative-decoding backend abstraction.
//!
//! The engine (L3 coordinator) drives a [`SdBackend`] through the SD round
//! protocol and owns rejection sampling itself, so losslessness logic lives
//! in exactly one place ([`crate::sampling::verify_chain`]). Two backends
//! implement the trait:
//!
//! - [`synthetic::SyntheticLm`] — paper-scale experiments: token chains are
//!   deterministic hash sequences, draft accuracy is the calibrated α, and
//!   step costs come from the roofline simulator (virtual clock).
//! - [`crate::runtime::hlo_model::HloBackend`] — the real tiny MoE model
//!   executed through PJRT (wall clock).
//!
//! ## Round protocol (chain speculation, ragged shapes)
//!
//! Let `S` be a sequence's token stream (prompt ++ emitted tokens), and
//! `base` the number of tokens committed to the target KV. The *feed*
//! token `S[base]` is known but not yet processed. Each round the engine
//! assigns every sequence its own draft length γᵢ (a uniform round is the
//! special case γᵢ = γ):
//!
//! 1. `propose(pending, gammas)` — the draft catches up on its `pending`
//!    token backlog (`S[draft_len .. base+1]`, usually just the feed) and
//!    samples γᵢ tokens autoregressively per sequence: `max γᵢ`
//!    sequential forwards over the shrinking set of sequences still
//!    drafting, ≈ Σ_g T_D(B_g, 1).
//! 2. `verify(feed, drafts)` — the target runs **one** forward over each
//!    sequence's γᵢ+1 tokens `[feed, d1, …, dγᵢ]`, returning γᵢ+1
//!    next-token distributions per sequence (priced Σ(γᵢ+1)-based: the
//!    synthetic backend packs the ragged widths into one roofline walk,
//!    ≈ T_T over Σ(γᵢ+1) tokens — the paper's verification step).
//! 3. The engine rejection-samples ([`crate::sampling::verify_chain`]),
//!    emits `accepted + 1` tokens, rolls both models back to the accepted
//!    prefix, and the fresh token becomes the next round's feed.
//!
//! With γᵢ = 0 for every sequence the same protocol is plain
//! autoregressive decoding (the baseline T_AR measurement): verify
//! forwards just the feed token and the engine samples from the single
//! returned row.
//!
//! ## Distribution representation
//!
//! Probability rows cross the trait boundary as [`LogitsView`]s, not
//! dense `Vec<f64>`s: a backend whose rows are degenerate (the synthetic
//! oracle's one-hot chains, greedy temperature-0 rows) emits
//! `OneHot`/`TopK` without a per-token vocab-sized allocation, and the
//! engine's rejection sampler consumes them directly with bit-identical
//! semantics to the dense path. Backends with genuinely full-support
//! rows (the real-model HLO backend at temperature > 0) emit `Dense`.

pub mod synthetic;

use crate::kvcache::SeqId;
pub use crate::sampling::LogitsView;

/// Output of a draft propose step.
#[derive(Debug, Clone)]
pub struct ProposeOut {
    /// Proposed tokens per sequence: `tokens[i].len() == gammas[i]`
    /// (ragged; uniform rounds have equal lengths).
    pub tokens: Vec<Vec<u32>>,
    /// Draft distributions the tokens were sampled from (same shape),
    /// already temperature-adjusted.
    pub probs: Vec<Vec<LogitsView>>,
    /// Cost in seconds (simulated or measured, per the backend's clock).
    pub cost: f64,
}

/// Output of a target verify step.
#[derive(Debug, Clone)]
pub struct VerifyOut {
    /// Target distributions per sequence: `probs[i].len() ==
    /// drafts[i].len() + 1` (one row to verify each draft token, plus the
    /// bonus row), already temperature-adjusted.
    pub probs: Vec<Vec<LogitsView>>,
    /// Cost in seconds.
    pub cost: f64,
}

/// The model-pair backend the coordinator schedules against.
pub trait SdBackend {
    fn vocab(&self) -> usize;

    /// Register sequences and process their prompts *minus the final
    /// token* on both models. Fails if backend capacity is exhausted —
    /// the scheduler treats that as admission backpressure.
    fn prefill(&mut self, batch: &[(SeqId, Vec<u32>)]) -> anyhow::Result<f64>;

    /// Price of prefilling `tokens` prompt tokens on top of `ctx`
    /// already-processed ones for a *single* sequence, *without*
    /// touching model state. The default (0.0) is correct for
    /// wall-clock backends, which measure the real prefill inside
    /// `prefill` itself; virtual-clock backends override it with
    /// their roofline pricing.
    fn prefill_chunk_cost(&self, tokens: usize, ctx: usize) -> f64 {
        let _ = (tokens, ctx);
        0.0
    }

    /// Price one *batched* chunked-prefill op: `parts[i]` is
    /// `(tokens, ctx)` for the i-th sequence sharing the forward. This
    /// is the op the continuous engine actually schedules — it draws a
    /// token budget across the front of the prefill queue so weight
    /// traffic (all experts, for a sparse-MoE target) amortizes over
    /// the cohort exactly as it does in a lock-step bulk prefill. The
    /// engine pays these op costs as it interleaves them with decode
    /// and charges the final `prefill` registration only for the
    /// residual above what the chunks already paid. Default: the
    /// unamortized per-sequence sum (0.0 for wall-clock backends).
    fn prefill_chunks_cost(&self, parts: &[(usize, usize)]) -> f64 {
        parts
            .iter()
            .map(|&(tokens, ctx)| self.prefill_chunk_cost(tokens, ctx))
            .sum()
    }

    /// Draft-propose `gammas[i]` tokens for sequence `i` (ragged; a
    /// uniform round passes equal entries). `pending[i]` is the token
    /// backlog to feed into the draft context first (last prompt token,
    /// previous fresh token, and — after a fully-accepted round — the
    /// final draft token it never consumed). `temps[i]` controls the
    /// per-sequence sampling temperature. Sequences with `gammas[i] == 0`
    /// take no draft forwards and return empty rows.
    fn propose(
        &mut self,
        seqs: &[SeqId],
        pending: &[Vec<u32>],
        gammas: &[usize],
        temps: &[f64],
        seed: u64,
    ) -> anyhow::Result<ProposeOut>;

    /// Target-verify: one forward over `[feed[i], drafts[i]...]` per
    /// sequence, returning `drafts[i].len() + 1` distribution rows each.
    /// Draft lists may be ragged; pricing is Σ(γᵢ+1)-based.
    fn verify(
        &mut self,
        seqs: &[SeqId],
        feed: &[u32],
        drafts: &[Vec<u32>],
        temps: &[f64],
    ) -> anyhow::Result<VerifyOut>;

    /// Roll the target KV back to `len` tokens (drop rejected drafts).
    fn rollback_target(&mut self, seq: SeqId, len: usize);

    /// Roll the draft KV back to `len` tokens. `len` larger than the
    /// current draft length is a no-op (the draft may legitimately lag the
    /// committed stream after a fully-accepted round).
    fn rollback_draft(&mut self, seq: SeqId, len: usize);

    /// Current target-context length in tokens.
    fn target_len(&self, seq: SeqId) -> usize;

    /// Current draft-context length in tokens.
    fn draft_len(&self, seq: SeqId) -> usize;

    /// Release all state for a finished sequence.
    fn release(&mut self, seq: SeqId);

    /// Rejection-sampling stage cost for a (possibly ragged) round: the
    /// sampler reads `Σ(gammas[i] + 1)` distribution rows. Backends price
    /// this from their simulator or measure it; the engine adds it to the
    /// clock.
    fn reject_cost(&self, gammas: &[usize]) -> f64;

    /// Cap the experts activated during *verify* forwards at `budget`
    /// (`None` = unbudgeted, the default). The MoE-Spec trade: a capped
    /// gate loads fewer expert weights (cheaper verify) but degrades
    /// acceptance for tokens whose top-K routing falls outside the
    /// budget. Backends without a budget notion ignore the call — the
    /// engine may invoke it every round with the controller's current
    /// choice.
    fn set_verify_budget(&mut self, budget: Option<usize>) {
        let _ = budget;
    }

    /// The verify-expert budget currently in effect (`None` when off or
    /// unsupported). The engine stamps this into each
    /// `RoundObservation` so the controller's measured table can grow a
    /// budget dimension.
    fn verify_budget(&self) -> Option<usize> {
        None
    }

    /// Force the committed (target) context length to exactly `len`
    /// without touching draft state. Only the distributed draft worker
    /// uses this: its replica never executes verify, so the coordinator
    /// pushes the authoritative base its next propose continues from
    /// (`dist::wire::StateOp::SyncBase`). Unknown sequences are ignored.
    /// Single-process backends never see this call — the default is a
    /// no-op.
    fn sync_target_base(&mut self, seq: SeqId, len: usize) {
        let _ = (seq, len);
    }

    /// Worker-fleet health snapshot when this backend is a distributed
    /// coordinator (`dist::DistBackend`); `None` for single-process
    /// backends. Surfaced through `ServerStats` as the `"dist"` key.
    fn dist_status(&self) -> Option<crate::dist::DistStatus> {
        None
    }
}

#[cfg(test)]
mod tests {
    // The trait itself is exercised end-to-end via `synthetic` and the
    // engine integration tests; shape conventions are asserted there.
}
