//! Synthetic SD backend for paper-scale experiments.
//!
//! The paper's measurements need Qwen2-57B on multi-GPU nodes; this backend
//! substitutes (per DESIGN.md) a deterministic token oracle plus the
//! roofline simulator for timing:
//!
//! - The **target model** is a deterministic chain: the "correct" token at
//!   position `p` of sequence `s` is `hash(stream, s, p)`. Target
//!   distributions are one-hot at the correct token (greedy target), so the
//!   emitted text is exactly the chain — which makes losslessness trivially
//!   auditable in tests.
//! - The **draft model** proposes the correct token with probability α
//!   (the calibrated acceptance rate; see
//!   [`crate::theory::alpha_from_sigma`]) and a deliberately-wrong token
//!   otherwise. With one-hot target rows, rejection sampling accepts
//!   exactly the correct proposals: chain acceptance is Bernoulli(α), the
//!   regime Eq. 5 models.
//! - **Costs** come from two [`ExecSim`] instances (target + draft model on
//!   the platform under study), giving the virtual clock the same roofline
//!   / expert-activation behavior the paper measures on GPUs.

use std::collections::HashMap;

use super::{LogitsView, ProposeOut, SdBackend, VerifyOut};
use crate::kvcache::SeqId;
use crate::simulator::{ActivationMode, ExecSim};
use crate::util::rng::Rng;

/// Deterministic "correct token" oracle (splitmix64 finalizer).
fn chain_token(stream: u64, seq: SeqId, pos: usize, vocab: usize) -> u32 {
    let mut h = stream
        .wrapping_mul(0x9e3779b97f4a7c15)
        .wrapping_add(seq.wrapping_mul(0xd1b54a32d192ed03))
        .wrapping_add(pos as u64);
    h ^= h >> 30;
    h = h.wrapping_mul(0xbf58476d1ce4e5b9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94d049bb133111eb);
    h ^= h >> 31;
    (h % vocab as u64) as u32
}

#[derive(Debug, Clone, Default)]
struct SeqState {
    target_len: usize,
    draft_len: usize,
}

/// The synthetic backend.
pub struct SyntheticLm {
    target_sim: ExecSim,
    draft_sim: ExecSim,
    /// Pre-built sampled-activation clone of `target_sim` for noisy
    /// pricing. Built once in [`Self::with_noise`] — cloning the whole
    /// simulator (arch + platform) on every verify call was a measurable
    /// per-round cost.
    noisy_target_sim: Option<ExecSim>,
    /// Probability that the draft proposes the correct chain token.
    pub alpha: f64,
    /// Per-sequence overrides of `alpha` (mixed-acceptance populations for
    /// the ragged-γ experiments); sequences not present use `alpha`.
    seq_alpha: HashMap<SeqId, f64>,
    vocab: usize,
    stream: u64,
    seqs: HashMap<SeqId, SeqState>,
    /// Context length used when pricing forwards (the paper works at
    /// typical sequence lengths where KV impact is limited; footnote 2).
    pub ctx_for_pricing: usize,
    /// Use sampled (noisy) expert activation when pricing — run-to-run
    /// variation for Fig. 5's individual-run curves.
    noise_rng: Option<Rng>,
    /// Emit dense vocab-sized rows instead of sparse `OneHot` views.
    /// Byte-compatible with the pre-sparse backend — reference mode for
    /// the equivalence property tests and the micro-bench dense baseline.
    dense_rows: bool,
    /// Verify-time expert budget (`None` = unbudgeted): verify forwards
    /// are priced with the routed-expert arm capped at this many
    /// experts, and draft acceptance degrades by the coverage curve
    /// below. Draft, prefill and rejection pricing never see the budget.
    verify_budget: Option<usize>,
    /// Acceptance-vs-budget curve exponent: the effective α of every
    /// sequence is `α · coverage^sensitivity` with
    /// `coverage = min(1, budget / N(Σ(γᵢ+1)))`
    /// ([`crate::theory::budgeted_alpha`]). The default 1.0 is the
    /// linear prior; [`SyntheticLm::with_budget_alpha_curve`] calibrates
    /// it (MoE-Spec-style mild degradation sits well below 1).
    budget_sensitivity: f64,
}

impl SyntheticLm {
    pub fn new(target_sim: ExecSim, draft_sim: ExecSim, alpha: f64, seed: u64) -> SyntheticLm {
        assert!((0.0..=1.0).contains(&alpha));
        SyntheticLm {
            target_sim,
            draft_sim,
            noisy_target_sim: None,
            alpha,
            seq_alpha: HashMap::new(),
            vocab: 64,
            stream: seed,
            seqs: HashMap::new(),
            ctx_for_pricing: 512,
            noise_rng: None,
            dense_rows: false,
            verify_budget: None,
            budget_sensitivity: 1.0,
        }
    }

    /// Enable run-to-run pricing noise (sampled expert activation).
    pub fn with_noise(mut self, seed: u64) -> Self {
        self.noise_rng = Some(Rng::new(seed, 3));
        self.noisy_target_sim = Some(
            self.target_sim
                .clone()
                .with_activation(ActivationMode::Sampled),
        );
        self
    }

    /// Set the synthetic token space. The default 64 was the largest the
    /// dense-row interface could afford; with sparse [`LogitsView`] rows
    /// the backend runs at Qwen2's real 151 936 without any per-token
    /// vocab-sized work (see `experiments::vocab_scale`).
    pub fn with_vocab(mut self, vocab: usize) -> Self {
        assert!(vocab >= 2, "vocab must be at least 2");
        self.vocab = vocab;
        self
    }

    /// Emit dense rows exactly like the pre-sparse backend (reference /
    /// baseline mode; O(vocab) per emitted row).
    pub fn with_dense_rows(mut self) -> Self {
        self.dense_rows = true;
        self
    }

    /// Override the acceptance probability for specific sequences —
    /// mixed-α populations for the ragged-speculation experiments
    /// (`experiments::ragged`). Sequences without an entry keep the
    /// backend-wide `alpha`, so an empty map is exactly the uniform
    /// backend.
    pub fn with_seq_alphas(mut self, pairs: &[(SeqId, f64)]) -> Self {
        for &(seq, a) in pairs {
            assert!((0.0..=1.0).contains(&a), "per-seq alpha out of [0,1]: {a}");
            self.seq_alpha.insert(seq, a);
        }
        self
    }

    /// Calibrate the acceptance-vs-budget degradation curve: under a
    /// verify budget, every sequence's effective α becomes
    /// `α · coverage^sensitivity` where coverage is the budget's share
    /// of the expectedly-activated experts at the round's verify width.
    /// `sensitivity = 0` models budget-oblivious acceptance (free
    /// lunch); larger values punish under-coverage harder. Without a
    /// budget set the curve is inert, whatever the sensitivity.
    pub fn with_budget_alpha_curve(mut self, sensitivity: f64) -> Self {
        assert!(
            sensitivity >= 0.0 && sensitivity.is_finite(),
            "budget sensitivity must be finite and non-negative: {sensitivity}"
        );
        self.budget_sensitivity = sensitivity;
        self
    }

    /// The acceptance probability in effect for one sequence.
    pub fn alpha_for(&self, seq: SeqId) -> f64 {
        self.seq_alpha.get(&seq).copied().unwrap_or(self.alpha)
    }

    /// Acceptance degradation factor for a round drafting `gammas`:
    /// `coverage^sensitivity` at verify width `Σ(γᵢ+1)`. Exactly 1.0 —
    /// and bit-transparent to the α draw — when no budget is set, the
    /// budget covers N(t), or the target is dense.
    fn budget_alpha_factor(&self, gammas: &[usize]) -> f64 {
        let (bud, (e, k)) = match (self.verify_budget, self.target_sim.moe_dims()) {
            (Some(b), Some(dims)) => (b, dims),
            _ => return 1.0,
        };
        let t = crate::perfmodel::ragged_verify_tokens(gammas) as u64;
        let cov = crate::theory::budget_coverage(e, k, t, Some(bud));
        if cov >= 1.0 {
            return 1.0;
        }
        cov.powf(self.budget_sensitivity)
    }

    /// The ground-truth continuation this backend will deterministically
    /// emit for a sequence (test hook for losslessness assertions).
    pub fn expected_chain(&self, seq: SeqId, start_pos: usize, n: usize) -> Vec<u32> {
        (0..n)
            .map(|i| chain_token(self.stream, seq, start_pos + i, self.vocab))
            .collect()
    }

    pub fn target_sim(&self) -> &ExecSim {
        &self.target_sim
    }

    /// One distribution row: a two-word `OneHot` view in the default
    /// sparse mode, a vocab-sized vector in the dense reference mode.
    fn row(&self, tok: u32) -> LogitsView {
        if self.dense_rows {
            let mut row = vec![0.0; self.vocab];
            row[tok as usize] = 1.0;
            LogitsView::dense(row)
        } else {
            LogitsView::one_hot(tok, self.vocab)
        }
    }

    fn state(&self, seq: SeqId) -> &SeqState {
        self.seqs.get(&seq).expect("unknown sequence")
    }

    /// Price one (possibly ragged) verify forward: `b` sequences, `tokens`
    /// packed new tokens (Σ(γᵢ+1)). Uniform rounds pass `tokens = b·(γ+1)`
    /// and price bit-identically to the pre-ragged backend.
    /// Verify forwards run under the backend's verify budget (`None`
    /// takes the identical unbudgeted arithmetic, so prices — and the
    /// noisy path's RNG draw sequence — are bit-for-bit the pre-budget
    /// backend's).
    fn price_target_tokens(&mut self, b: usize, tokens: usize) -> f64 {
        let ctx = self.ctx_for_pricing;
        let budget = self.verify_budget;
        match (&mut self.noise_rng, &self.noisy_target_sim) {
            (Some(rng), Some(sim)) => sim
                .forward_time_tokens_budgeted(b, tokens, ctx, Some(rng), budget)
                .total(),
            _ => self.target_sim.t_forward_tokens_budgeted(b, tokens, ctx, budget),
        }
    }
}

impl SdBackend for SyntheticLm {
    fn vocab(&self) -> usize {
        self.vocab
    }

    fn prefill(&mut self, batch: &[(SeqId, Vec<u32>)]) -> anyhow::Result<f64> {
        let mut max_prompt = 0usize;
        for (seq, prompt) in batch {
            anyhow::ensure!(!prompt.is_empty(), "empty prompt for seq {seq}");
            anyhow::ensure!(
                !self.seqs.contains_key(seq),
                "sequence {seq} already prefilled"
            );
            let processed = prompt.len() - 1;
            self.seqs.insert(
                *seq,
                SeqState {
                    target_len: processed,
                    draft_len: processed,
                },
            );
            max_prompt = max_prompt.max(processed);
        }
        if max_prompt == 0 {
            return Ok(0.0);
        }
        let b = batch.len();
        Ok(self.target_sim.t_forward(b, max_prompt, max_prompt)
            + self.draft_sim.t_forward(b, max_prompt, max_prompt))
    }

    fn prefill_chunk_cost(&self, tokens: usize, ctx: usize) -> f64 {
        // One single-sequence chunked-prefill step: both models process
        // `tokens` new prompt tokens on top of `ctx` committed ones at
        // batch 1. Small batch-1 chunks are *weight-bound* for a sparse
        // MoE (a 64-token chunk activates essentially every expert), so
        // per-chunk pricing is an upper bound on the bulk price and the
        // engine's residual charge at registration is zero.
        if tokens == 0 {
            return 0.0;
        }
        self.target_sim.t_forward(1, tokens, ctx + tokens)
            + self.draft_sim.t_forward(1, tokens, ctx + tokens)
    }

    fn prefill_chunks_cost(&self, parts: &[(usize, usize)]) -> f64 {
        // One batched chunk op: the cohort's new tokens share a single
        // packed forward, so expert weights are read once per op — the
        // same amortization a lock-step bulk prefill gets. Attention is
        // priced at the deepest context in the cohort (conservative;
        // attention is a small share of prefill for these shapes).
        let total: usize = parts.iter().map(|&(tokens, _)| tokens).sum();
        if total == 0 {
            return 0.0;
        }
        let b = parts.len();
        let ctx = parts
            .iter()
            .map(|&(tokens, ctx)| ctx + tokens)
            .max()
            .unwrap_or(0);
        self.target_sim.t_forward_tokens(b, total, ctx)
            + self.draft_sim.t_forward_tokens(b, total, ctx)
    }

    fn propose(
        &mut self,
        seqs: &[SeqId],
        pending: &[Vec<u32>],
        gammas: &[usize],
        temps: &[f64],
        seed: u64,
    ) -> anyhow::Result<ProposeOut> {
        anyhow::ensure!(seqs.len() == pending.len() && seqs.len() == temps.len());
        anyhow::ensure!(seqs.len() == gammas.len(), "gammas length mismatch");
        let mut rng = Rng::new(self.stream ^ seed, 13);
        let mut tokens = Vec::with_capacity(seqs.len());
        let mut probs = Vec::with_capacity(seqs.len());
        // Acceptance-vs-budget degradation, shared by the whole round
        // (coverage depends on the round's packed verify width). 1.0 —
        // and `α · 1.0 = α` exactly, same Bernoulli threshold, same RNG
        // draw count — whenever the budget axis is off.
        let budget_factor = self.budget_alpha_factor(gammas);
        for (i, &seq) in seqs.iter().enumerate() {
            let gamma = gammas[i];
            anyhow::ensure!(!pending[i].is_empty() || gamma == 0, "no pending feed");
            let alpha = self.alpha_for(seq) * budget_factor;
            let base = self.state(seq).target_len; // committed stream length
            let mut toks = Vec::with_capacity(gamma);
            let mut rows = Vec::with_capacity(gamma);
            for g in 0..gamma {
                // Stream position of this proposal: base is the feed token's
                // index, proposals continue at base+1+g.
                let correct = chain_token(self.stream, seq, base + 1 + g, self.vocab);
                let tok = if rng.bernoulli(alpha) {
                    correct
                } else {
                    let mut t = rng.below(self.vocab as u64 - 1) as u32;
                    if t >= correct {
                        t += 1;
                    }
                    t
                };
                rows.push(self.row(tok));
                toks.push(tok);
            }
            if gamma > 0 {
                let st = self.seqs.get_mut(&seq).unwrap();
                // Fed the pending backlog plus γᵢ−1 of its own proposals.
                st.draft_len += pending[i].len() + gamma - 1;
            }
            tokens.push(toks);
            probs.push(rows);
        }
        let b = seqs.len();
        let gamma_max = gammas.iter().copied().max().unwrap_or(0);
        let cost = if gamma_max == 0 {
            0.0
        } else if gammas.iter().all(|&g| g == gamma_max) {
            // Uniform round: γ sequential draft forwards (the first
            // consumes the pending backlog; backlog is ≤ 2 tokens so
            // single-token pricing holds). Kept as a multiply — not the
            // stepped sum below — so uniform pricing stays bit-identical
            // to the pre-ragged backend.
            gamma_max as f64 * self.draft_sim.t_forward(b, 1, self.ctx_for_pricing)
        } else {
            // Ragged round: the draft still runs max γᵢ sequential steps,
            // but step g only carries the sequences still drafting
            // (γᵢ > g), so late steps run at a smaller batch (the shared
            // schedule helper — same accounting the perf model uses).
            crate::perfmodel::ragged_draft_schedule(gammas)
                .iter()
                .map(|&bg| self.draft_sim.t_forward(bg, 1, self.ctx_for_pricing))
                .sum()
        };
        Ok(ProposeOut {
            tokens,
            probs,
            cost,
        })
    }

    fn verify(
        &mut self,
        seqs: &[SeqId],
        feed: &[u32],
        drafts: &[Vec<u32>],
        temps: &[f64],
    ) -> anyhow::Result<VerifyOut> {
        anyhow::ensure!(seqs.len() == feed.len() && seqs.len() == drafts.len());
        anyhow::ensure!(seqs.len() == temps.len());
        let mut total_tokens = 0usize;
        let mut probs = Vec::with_capacity(seqs.len());
        for (i, &seq) in seqs.iter().enumerate() {
            // Ragged rounds: each sequence verifies its own γᵢ+1 tokens.
            let gamma = drafts[i].len();
            let base = self.state(seq).target_len;
            // Row g is the target's next-token distribution after
            // [.., feed, d1..dg] — one-hot at the chain token (the chain
            // defines the target's behavior regardless of draft content).
            let rows: Vec<LogitsView> = (0..=gamma)
                .map(|g| self.row(chain_token(self.stream, seq, base + 1 + g, self.vocab)))
                .collect();
            let st = self.seqs.get_mut(&seq).unwrap();
            st.target_len += gamma + 1; // consumed [feed, d1..dγᵢ]
            total_tokens += gamma + 1;
            probs.push(rows);
        }
        let b = seqs.len();
        // Σ(γᵢ+1)-based pricing: the packed roofline walk; uniform widths
        // reproduce the old T_T(B, γ+1) price bit-for-bit.
        let cost = self.price_target_tokens(b, total_tokens);
        Ok(VerifyOut { probs, cost })
    }

    fn rollback_target(&mut self, seq: SeqId, len: usize) {
        let st = self.seqs.get_mut(&seq).expect("unknown sequence");
        assert!(len <= st.target_len, "target rollback beyond context");
        st.target_len = len;
    }

    fn rollback_draft(&mut self, seq: SeqId, len: usize) {
        let st = self.seqs.get_mut(&seq).expect("unknown sequence");
        st.draft_len = st.draft_len.min(len);
    }

    fn sync_target_base(&mut self, seq: SeqId, len: usize) {
        // Distributed draft replicas never run verify, so the
        // coordinator sets the committed base directly; unlike
        // `rollback_target` this may move the base *forward* (the
        // replica is catching up to verifies it didn't execute).
        // Tolerates unknown sequences: a replayed SyncBase can land
        // after the sequence's Release on a rebuilt replica.
        if let Some(st) = self.seqs.get_mut(&seq) {
            st.target_len = len;
        }
    }

    fn target_len(&self, seq: SeqId) -> usize {
        self.state(seq).target_len
    }

    fn draft_len(&self, seq: SeqId) -> usize {
        self.state(seq).draft_len
    }

    fn release(&mut self, seq: SeqId) {
        self.seqs.remove(&seq);
    }

    fn reject_cost(&self, gammas: &[usize]) -> f64 {
        // Σ(γᵢ+1) rows (the shared accounting helper); uniform rounds
        // reproduce t_reject(b, γ) exactly.
        self.target_sim
            .t_reject_rows(crate::perfmodel::ragged_verify_tokens(gammas))
    }

    fn set_verify_budget(&mut self, budget: Option<usize>) {
        self.verify_budget = budget;
    }

    fn verify_budget(&self) -> Option<usize> {
        self.verify_budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::hardware::platform_2x_gpu_a;

    fn backend(alpha: f64) -> SyntheticLm {
        let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
        let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
        SyntheticLm::new(target, draft, alpha, 42)
    }

    #[test]
    fn chain_is_deterministic() {
        let b = backend(0.8);
        assert_eq!(b.expected_chain(1, 0, 5), b.expected_chain(1, 0, 5));
        assert_ne!(b.expected_chain(1, 0, 8), b.expected_chain(2, 0, 8));
    }

    #[test]
    fn prefill_then_propose_verify_shapes() {
        let mut b = backend(1.0);
        let prompt = vec![1u32, 2, 3, 4];
        b.prefill(&[(7, prompt.clone())]).unwrap();
        assert_eq!(b.target_len(7), 3);
        let p = b.propose(&[7], &[vec![4]], &[3], &[0.0], 1).unwrap();
        assert_eq!(p.tokens[0].len(), 3);
        assert_eq!(p.probs[0].len(), 3);
        assert!(p.cost > 0.0);
        assert_eq!(b.draft_len(7), 6); // 3 + pending(1) + γ−1
        let v = b
            .verify(&[7], &[4], &[p.tokens[0].clone()], &[0.0])
            .unwrap();
        assert_eq!(v.probs[0].len(), 4);
        assert!(v.cost > 0.0);
        assert_eq!(b.target_len(7), 7); // 3 + (γ+1)
    }

    #[test]
    fn alpha_one_draft_always_matches_target() {
        let mut b = backend(1.0);
        b.prefill(&[(1, vec![5, 6])]).unwrap();
        let p = b.propose(&[1], &[vec![6]], &[4], &[0.0], 3).unwrap();
        let expected = b.expected_chain(1, 2, 4);
        assert_eq!(p.tokens[0], expected);
    }

    #[test]
    fn alpha_zero_draft_never_matches_target() {
        let mut b = backend(0.0);
        b.prefill(&[(1, vec![5, 6])]).unwrap();
        let p = b.propose(&[1], &[vec![6]], &[4], &[0.0], 3).unwrap();
        let expected = b.expected_chain(1, 2, 4);
        for (got, want) in p.tokens[0].iter().zip(&expected) {
            assert_ne!(got, want);
        }
    }

    #[test]
    fn empirical_match_rate_tracks_alpha() {
        let alpha = 0.7;
        let mut b = backend(alpha);
        let mut matches = 0;
        let mut total = 0;
        for s in 0..200u64 {
            b.prefill(&[(s, vec![1, 2])]).unwrap();
            let p = b.propose(&[s], &[vec![2]], &[1], &[0.0], s).unwrap();
            let expected = b.expected_chain(s, 2, 1);
            if p.tokens[0][0] == expected[0] {
                matches += 1;
            }
            total += 1;
            b.release(s);
        }
        let rate = matches as f64 / total as f64;
        assert!((rate - alpha).abs() < 0.12, "rate={rate}");
    }

    #[test]
    fn verify_cost_exceeds_single_token_cost_at_small_batch() {
        let mut b = backend(0.8);
        b.prefill(&[(1, vec![1, 2])]).unwrap();
        let v4 = b.verify(&[1], &[2], &[vec![0, 0, 0]], &[0.0]).unwrap().cost;
        b.rollback_target(1, 1);
        let v1 = b.verify(&[1], &[2], &[vec![]], &[0.0]).unwrap().cost;
        assert!(v4 > v1, "γ=3 verify {v4} should cost more than γ=0 {v1}");
    }

    #[test]
    fn rollback_semantics() {
        let mut b = backend(0.5);
        b.prefill(&[(9, vec![1, 2, 3])]).unwrap();
        b.rollback_target(9, 1);
        assert_eq!(b.target_len(9), 1);
        // Draft rollback past current length is a clamp-style no-op.
        b.rollback_draft(9, 100);
        assert_eq!(b.draft_len(9), 2);
        b.rollback_draft(9, 1);
        assert_eq!(b.draft_len(9), 1);
    }

    #[test]
    #[should_panic(expected = "rollback beyond context")]
    fn target_rollback_forward_panics() {
        let mut b = backend(0.5);
        b.prefill(&[(9, vec![1, 2, 3])]).unwrap();
        b.rollback_target(9, 10);
    }

    #[test]
    fn duplicate_prefill_rejected() {
        let mut b = backend(0.5);
        b.prefill(&[(1, vec![1, 2])]).unwrap();
        assert!(b.prefill(&[(1, vec![1, 2])]).is_err());
    }

    #[test]
    fn sparse_rows_by_default_dense_in_reference_mode() {
        let mut b = backend(1.0);
        b.prefill(&[(1, vec![1, 2])]).unwrap();
        let p = b.propose(&[1], &[vec![2]], &[2], &[0.0], 1).unwrap();
        assert!(matches!(p.probs[0][0], LogitsView::OneHot { .. }));
        let v = b.verify(&[1], &[2], &[p.tokens[0].clone()], &[0.0]).unwrap();
        assert!(matches!(v.probs[0][0], LogitsView::OneHot { .. }));

        let mut d = backend(1.0).with_dense_rows();
        d.prefill(&[(1, vec![1, 2])]).unwrap();
        let p = d.propose(&[1], &[vec![2]], &[2], &[0.0], 1).unwrap();
        match &p.probs[0][0] {
            LogitsView::Dense(row) => assert_eq!(row.len(), 64),
            other => panic!("expected dense row, got {other:?}"),
        }
    }

    #[test]
    fn realistic_vocab_runs_without_dense_allocations() {
        let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
        let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
        let mut b = SyntheticLm::new(target, draft, 1.0, 9).with_vocab(151_936);
        assert_eq!(b.vocab(), 151_936);
        b.prefill(&[(1, vec![5, 6])]).unwrap();
        let p = b.propose(&[1], &[vec![6]], &[4], &[0.0], 3).unwrap();
        assert_eq!(p.tokens[0], b.expected_chain(1, 2, 4));
        assert!(p.tokens[0].iter().all(|&t| (t as usize) < 151_936));
        let v = b.verify(&[1], &[6], &[p.tokens[0].clone()], &[0.0]).unwrap();
        assert_eq!(v.probs[0].len(), 5);
        assert!(matches!(v.probs[0][0], LogitsView::OneHot { .. }));
        // The sparse row still reports the full vocabulary.
        assert_eq!(v.probs[0][0].vocab(), 151_936);
    }

    #[test]
    fn ragged_propose_and_verify_shapes() {
        let mut b = backend(1.0);
        b.prefill(&[(1, vec![1, 2]), (2, vec![1, 2]), (3, vec![1, 2])])
            .unwrap();
        let p = b
            .propose(
                &[1, 2, 3],
                &[vec![2], vec![2], vec![2]],
                &[4, 1, 0],
                &[0.0; 3],
                9,
            )
            .unwrap();
        assert_eq!(p.tokens[0].len(), 4);
        assert_eq!(p.tokens[1].len(), 1);
        assert!(p.tokens[2].is_empty() && p.probs[2].is_empty());
        assert!(p.cost > 0.0);
        // α=1: every ragged proposal is the sequence's own chain.
        assert_eq!(p.tokens[0], b.expected_chain(1, 1, 4));
        assert_eq!(p.tokens[1], b.expected_chain(2, 1, 1));
        let v = b
            .verify(
                &[1, 2, 3],
                &[2, 2, 2],
                &[p.tokens[0].clone(), p.tokens[1].clone(), vec![]],
                &[0.0; 3],
            )
            .unwrap();
        assert_eq!(v.probs[0].len(), 5);
        assert_eq!(v.probs[1].len(), 2);
        assert_eq!(v.probs[2].len(), 1);
        // Per-sequence target advance: γᵢ + 1 each.
        assert_eq!(b.target_len(1), 1 + 5);
        assert_eq!(b.target_len(2), 1 + 2);
        assert_eq!(b.target_len(3), 1 + 1);
    }

    #[test]
    fn uniform_ragged_pricing_matches_scalar_paths() {
        // The bit-for-bit uniform special case: a ragged round with equal
        // γᵢ prices propose/verify/reject exactly like the scalar round.
        let mk = || {
            let mut b = backend(0.9);
            b.prefill(&[(1, vec![1, 2]), (2, vec![1, 2])]).unwrap();
            b
        };
        let mut a = mk();
        let pa = a
            .propose(&[1, 2], &[vec![2], vec![2]], &[3, 3], &[0.0; 2], 5)
            .unwrap();
        let va = a
            .verify(&[1, 2], &[2, 2], &[pa.tokens[0].clone(), pa.tokens[1].clone()], &[0.0; 2])
            .unwrap();
        // Reference: scalar-style uniform pricing computed directly.
        let b_ref = mk();
        let draft_ref = 3.0 * b_ref.draft_sim.t_forward(2, 1, b_ref.ctx_for_pricing);
        let verify_ref = b_ref.target_sim.t_forward(2, 4, b_ref.ctx_for_pricing);
        assert_eq!(pa.cost, draft_ref);
        assert_eq!(va.cost, verify_ref);
        assert_eq!(a.reject_cost(&[3, 3]), b_ref.target_sim.t_reject(2, 3));
        // Mixed γᵢ genuinely changes the prices.
        let mut c = mk();
        let pc = c
            .propose(&[1, 2], &[vec![2], vec![2]], &[5, 1], &[0.0; 2], 5)
            .unwrap();
        assert!(pc.cost != pa.cost);
        assert!(c.reject_cost(&[5, 1]) == c.reject_cost(&[3, 3]), "same total rows");
    }

    #[test]
    fn per_sequence_alpha_overrides() {
        let mut b = backend(0.0).with_seq_alphas(&[(1, 1.0)]);
        assert_eq!(b.alpha_for(1), 1.0);
        assert_eq!(b.alpha_for(2), 0.0);
        b.prefill(&[(1, vec![5, 6]), (2, vec![5, 6])]).unwrap();
        let p = b
            .propose(&[1, 2], &[vec![6], vec![6]], &[4, 4], &[0.0; 2], 3)
            .unwrap();
        // Seq 1 (α=1) always matches its chain; seq 2 (α=0) never does.
        assert_eq!(p.tokens[0], b.expected_chain(1, 2, 4));
        for (got, want) in p.tokens[1].iter().zip(b.expected_chain(2, 2, 4)) {
            assert_ne!(*got, want);
        }
    }

    #[test]
    fn budget_off_switch_is_bit_transparent() {
        // budget=None (default) and budget ≥ E must produce the exact
        // same proposed tokens (same RNG stream) and verify prices as
        // the pre-budget backend.
        let run = |budget: Option<usize>| {
            let mut b = backend(0.7).with_budget_alpha_curve(2.0);
            if let Some(bud) = budget {
                b.set_verify_budget(Some(bud));
            }
            b.prefill(&[(1, vec![1, 2]), (2, vec![1, 2])]).unwrap();
            let p = b
                .propose(&[1, 2], &[vec![2], vec![2]], &[5, 2], &[0.0; 2], 11)
                .unwrap();
            let v = b
                .verify(&[1, 2], &[2, 2], &[p.tokens[0].clone(), p.tokens[1].clone()], &[0.0; 2])
                .unwrap();
            (p.tokens, p.cost, v.cost)
        };
        let base = run(None);
        assert_eq!(run(Some(64)), base, "budget = E must be a no-op");
        assert_eq!(run(Some(1000)), base, "budget > E must be a no-op");
    }

    #[test]
    fn tight_budget_cheapens_verify_and_degrades_acceptance() {
        let mk = |budget: Option<usize>| {
            let mut b = backend(0.9).with_budget_alpha_curve(1.0);
            b.set_verify_budget(budget);
            b
        };
        // Verify price drops under the cap (γ=6, B=4 → 28 packed tokens,
        // N ≈ 62.5 of 64 experts; budget 16 cuts the weight traffic 4×).
        let mut full = mk(None);
        let mut capped = mk(Some(16));
        for b in [&mut full, &mut capped] {
            b.prefill(&[(1, vec![1, 2]), (2, vec![1, 2]), (3, vec![1, 2]), (4, vec![1, 2])])
                .unwrap();
        }
        let drafts = vec![vec![0u32; 6], vec![0; 6], vec![0; 6], vec![0; 6]];
        let vf = full
            .verify(&[1, 2, 3, 4], &[2; 4], &drafts, &[0.0; 4])
            .unwrap()
            .cost;
        let vc = capped
            .verify(&[1, 2, 3, 4], &[2; 4], &drafts, &[0.0; 4])
            .unwrap()
            .cost;
        assert!(vc < vf, "capped verify {vc} must undercut {vf}");
        // Acceptance degrades: empirical match rate under budget 16 at
        // coverage 16/62.5 ≈ 0.256 should land near α·0.256 ≈ 0.23.
        let count_matches = |budget: Option<usize>| {
            let mut hits = 0usize;
            let mut total = 0usize;
            for s in 0..150u64 {
                let mut b = mk(budget);
                b.prefill(&[(s, vec![1, 2]), (s + 1000, vec![1, 2]), (s + 2000, vec![1, 2]), (s + 3000, vec![1, 2])])
                    .unwrap();
                let seqs = [s, s + 1000, s + 2000, s + 3000];
                let p = b
                    .propose(&seqs, &[vec![2], vec![2], vec![2], vec![2]], &[6; 4], &[0.0; 4], s)
                    .unwrap();
                for (i, &seq) in seqs.iter().enumerate() {
                    let want = b.expected_chain(seq, 2, 6);
                    hits += p.tokens[i].iter().zip(&want).filter(|(a, b)| a == b).count();
                    total += 6;
                }
            }
            hits as f64 / total as f64
        };
        let rate_full = count_matches(None);
        let rate_capped = count_matches(Some(16));
        assert!(
            rate_full - rate_capped > 0.4,
            "budget 16 should visibly degrade acceptance: {rate_full} vs {rate_capped}"
        );
        // Sensitivity 0 restores budget-oblivious acceptance while still
        // taking the cheaper verify.
        let mut zero = backend(0.9).with_budget_alpha_curve(0.0);
        zero.set_verify_budget(Some(16));
        zero.prefill(&[(7, vec![1, 2])]).unwrap();
        let p = zero.propose(&[7], &[vec![2]], &[6], &[0.0], 7).unwrap();
        let mut plain = backend(0.9);
        plain.prefill(&[(7, vec![1, 2])]).unwrap();
        let q = plain.propose(&[7], &[vec![2]], &[6], &[0.0], 7).unwrap();
        assert_eq!(p.tokens, q.tokens, "sensitivity 0 must not touch the draw");
    }

    #[test]
    fn noisy_pricing_varies_but_tracks_expectation() {
        let mut quiet = backend(0.9);
        let mut noisy = backend(0.9).with_noise(5);
        quiet.prefill(&[(1, vec![1, 2])]).unwrap();
        noisy.prefill(&[(1, vec![1, 2])]).unwrap();
        let qc = quiet.verify(&[1], &[2], &[vec![0, 0]], &[0.0]).unwrap().cost;
        let mut costs = Vec::new();
        for _ in 0..20 {
            noisy.rollback_target(1, 1);
            costs.push(noisy.verify(&[1], &[2], &[vec![0, 0]], &[0.0]).unwrap().cost);
        }
        let mean = crate::util::stats::mean(&costs);
        assert!((mean - qc).abs() / qc < 0.15, "mean {mean} vs {qc}");
        assert!(crate::util::stats::stddev(&costs) > 0.0);
    }
}
